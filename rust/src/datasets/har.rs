//! Synthetic UCI-HAR: 6 activities of daily living, 2.56 s windows of
//! 128 samples × 9 channels (3-axis total acceleration, angular velocity,
//! body acceleration — §6.1.1).
//!
//! Each class is a characteristic locomotion pattern: periodic gait
//! harmonics for the walking classes (with class-specific cadence and
//! vertical-impact signatures), and low-motion gravity-vector postures for
//! sitting/standing/lying. Random phase, amplitude jitter and sensor noise
//! make the task non-trivial; classes share harmonics so confusions mirror
//! the real dataset's (walking vs upstairs vs downstairs).

use crate::util::prng::Pcg32;

use super::{RawDataModel, Sizes};

pub const SAMPLES: usize = 128;
pub const CHANNELS: usize = 9;
pub const CLASSES: usize = 6; // walk, up, down, sit, stand, lay

pub fn sizes() -> Sizes {
    // Paper: 7352 train / 2947 test; scaled ~1/6 keeping the ratio.
    Sizes { train: 1228, test: 492 }
}

fn synth_example(rng: &mut Pcg32, class: usize, out: &mut Vec<f32>) {
    let phase = rng.uniform() * std::f32::consts::TAU;
    let amp_jit = 0.8 + 0.4 * rng.uniform();
    // Class-specific cadence (Hz at 50 Hz sampling) and impact asymmetry.
    let (cadence, impact, tilt, motion) = match class {
        0 => (1.9, 0.55, 0.0, 1.0), // walking
        1 => (1.7, 0.75, 0.15, 1.0), // walking upstairs: slower, harder push
        2 => (2.1, 0.95, -0.15, 1.0), // walking downstairs: faster, impacts
        3 => (0.0, 0.0, 0.35, 0.10), // sitting: tilted gravity, tiny motion
        4 => (0.0, 0.0, 0.12, 0.09), // standing: upright, tiny motion
        _ => (0.0, 0.0, 0.8, 0.07),  // laying: rotated gravity
    };
    let w = cadence * std::f32::consts::TAU / 50.0;
    for t in 0..SAMPLES {
        let tf = t as f32;
        let gait = if cadence > 0.0 {
            (w * tf + phase).sin() + impact * (2.0 * w * tf + phase).sin().max(0.0)
        } else {
            0.0
        };
        for ch in 0..CHANNELS {
            let chf = ch as f32;
            // Gravity projection differs per axis group and posture tilt.
            let gravity = match ch {
                0..=2 => (tilt + 0.3 * chf).cos(),
                _ => 0.0,
            };
            // Channel-specific gait coupling (arms/legs phase offsets).
            let coupled = motion * amp_jit * gait * (0.5 + 0.5 * ((chf * 1.3) + phase).cos());
            let noise = rng.normal() * 0.55;
            out.push(gravity + coupled + noise);
        }
    }
}

pub fn generate(seed: u64) -> RawDataModel {
    let sz = sizes();
    let mut rng = Pcg32::seeded(seed ^ 0x4841_5221);
    let gen_split = |rng: &mut Pcg32, n: usize| {
        let mut xs = Vec::with_capacity(n * SAMPLES * CHANNELS);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % CLASSES;
            synth_example(rng, class, &mut xs);
            ys.push(class as i32);
        }
        (xs, ys)
    };
    let (train_x, train_y) = gen_split(&mut rng, sz.train);
    let (test_x, test_y) = gen_split(&mut rng, sz.test);
    let mut d = RawDataModel {
        name: "har",
        shape: vec![SAMPLES, CHANNELS],
        classes: CLASSES,
        train_x,
        train_y,
        test_x,
        test_y,
    };
    d.normalize();
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper() {
        let d = generate(1);
        assert_eq!(d.shape, vec![128, 9]);
        assert_eq!(d.classes, 6);
    }

    #[test]
    fn classes_are_separable_by_energy() {
        // Walking classes should have much larger signal variance than
        // postural classes — the key structure a CNN exploits.
        let d = generate(2);
        let l = d.example_len();
        let var_of = |xs: &[f32]| {
            let m: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
            xs.iter().map(|&x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32
        };
        let mut walk_var = 0.0;
        let mut lay_var = 0.0;
        let mut walks = 0;
        let mut lays = 0;
        for i in 0..d.n_train() {
            let v = var_of(&d.train_x[i * l..(i + 1) * l]);
            match d.train_y[i] {
                0 => {
                    walk_var += v;
                    walks += 1;
                }
                5 => {
                    lay_var += v;
                    lays += 1;
                }
                _ => {}
            }
        }
        assert!(walk_var / walks as f32 > 1.2 * lay_var / lays as f32);
    }
}
