//! Synthetic Spoken-MNIST: 10 spoken digits as 39-step series of 12 MFCC +
//! 1 energy coefficient (§6.1.2: 50 ms windows, 50% overlap, ~1 s audio).
//!
//! Each digit is modeled as a sequence of 2–3 "phoneme" segments with
//! digit-specific formant targets; MFCC channels follow smooth trajectories
//! between targets with speaker-dependent offsets, rate jitter, and noise —
//! the same cepstral-trajectory structure a real keyword CNN keys on.

use crate::util::prng::Pcg32;

use super::{RawDataModel, Sizes};

pub const STEPS: usize = 39;
pub const COEFFS: usize = 13;
pub const CLASSES: usize = 10;

pub fn sizes() -> Sizes {
    // Paper: 60000/10000 after duplication; scaled way down.
    Sizes { train: 1500, test: 500 }
}

/// Digit-specific phoneme target matrix: per segment, per coefficient base.
fn targets(digit: usize, seg: usize, coeff: usize) -> f32 {
    // Deterministic pseudo-random but fixed structure per (digit, seg, c).
    let h = (digit * 31 + seg * 7 + coeff * 13) % 17;
    ((h as f32) / 8.5 - 1.0) * 0.6
}

fn n_segments(digit: usize) -> usize {
    2 + (digit % 2) // "one" vs "seven" style lengths
}

fn synth_example(rng: &mut Pcg32, digit: usize, out: &mut Vec<f32>) {
    let segs = n_segments(digit);
    let speaker_off: Vec<f32> = (0..COEFFS).map(|_| rng.normal() * 0.45).collect();
    let rate = 0.85 + 0.3 * rng.uniform(); // speaking-rate jitter
    for t in 0..STEPS {
        // Position within the utterance, jittered.
        let pos = (t as f32 * rate / STEPS as f32).min(0.999) * segs as f32;
        let seg = pos as usize;
        let frac = pos - seg as f32;
        let seg = seg.min(segs - 1);
        let nxt = (seg + 1).min(segs - 1);
        for c in 0..COEFFS {
            let a = targets(digit, seg, c);
            let b = targets(digit, nxt, c);
            // Smoothstep interpolation between phoneme targets.
            let s = frac * frac * (3.0 - 2.0 * frac);
            let mut v = a + (b - a) * s + speaker_off[c];
            if c == 0 {
                // Energy coefficient: rises then decays over the utterance.
                let u = t as f32 / STEPS as f32;
                v += 1.5 * (std::f32::consts::PI * u).sin();
            }
            v += rng.normal() * 0.6;
            out.push(v);
        }
    }
}

pub fn generate(seed: u64) -> RawDataModel {
    let sz = sizes();
    let mut rng = Pcg32::seeded(seed ^ 0x534D_4E49);
    let gen_split = |rng: &mut Pcg32, n: usize| {
        let mut xs = Vec::with_capacity(n * STEPS * COEFFS);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let digit = i % CLASSES;
            synth_example(rng, digit, &mut xs);
            ys.push(digit as i32);
        }
        (xs, ys)
    };
    let (train_x, train_y) = gen_split(&mut rng, sz.train);
    let (test_x, test_y) = gen_split(&mut rng, sz.test);
    let mut d = RawDataModel {
        name: "smnist",
        shape: vec![STEPS, COEFFS],
        classes: CLASSES,
        train_x,
        train_y,
        test_x,
        test_y,
    };
    d.normalize();
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper() {
        let d = generate(1);
        assert_eq!(d.shape, vec![39, 13]);
        assert_eq!(d.classes, 10);
    }

    #[test]
    fn digits_have_distinct_mean_trajectories() {
        let d = generate(2);
        let l = d.example_len();
        // Average per-class profiles must differ pairwise (separability).
        let mut profiles = vec![vec![0.0f32; l]; CLASSES];
        let mut counts = vec![0usize; CLASSES];
        for i in 0..d.n_train() {
            let y = d.train_y[i] as usize;
            for (j, &v) in d.train_x[i * l..(i + 1) * l].iter().enumerate() {
                profiles[y][j] += v;
            }
            counts[y] += 1;
        }
        for (p, &c) in profiles.iter_mut().zip(&counts) {
            for v in p.iter_mut() {
                *v /= c as f32;
            }
        }
        for a in 0..CLASSES {
            for b in (a + 1)..CLASSES {
                let dist: f32 = profiles[a]
                    .iter()
                    .zip(&profiles[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum::<f32>()
                    .sqrt();
                assert!(dist > 1.0, "classes {a}/{b} too close: {dist}");
            }
        }
    }
}
