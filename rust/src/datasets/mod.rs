//! Synthetic stand-ins for the paper's datasets (DESIGN.md §3):
//! UCI-HAR (6-class IMU windows, 128×9), Spoken-MNIST (10-class MFCC
//! series, 39×13) and GTSRB (43-class RGB images, 32×32×3).
//!
//! The real datasets are not available in this environment; these
//! generators produce class-conditional signals with the same tensor
//! shapes, class counts and difficulty knobs (noise, jitter), normalized
//! with the z-score of the training set exactly as §6 prescribes. The
//! quantization claims under test (int16 ≈ float32; int8 QAT drops ≲1%)
//! concern the quantizer, not the specific data.

pub mod gtsrb;
pub mod har;
pub mod smnist;

use crate::util::prng::Pcg32;

/// The paper's RawDataModel (§5.4): train/test tensors + labels.
#[derive(Clone, Debug)]
pub struct RawDataModel {
    pub name: &'static str,
    /// Per-example shape, channels-last.
    pub shape: Vec<usize>,
    pub classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
}

impl RawDataModel {
    pub fn example_len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    pub fn n_test(&self) -> usize {
        self.test_y.len()
    }

    pub fn train_example(&self, i: usize) -> &[f32] {
        let l = self.example_len();
        &self.train_x[i * l..(i + 1) * l]
    }

    pub fn test_example(&self, i: usize) -> &[f32] {
        let l = self.example_len();
        &self.test_x[i * l..(i + 1) * l]
    }

    /// z-score normalization using TRAIN statistics (§6: "training and
    /// testing sets are normalized using the z-score of the training set").
    pub fn normalize(&mut self) {
        let n = self.train_x.len() as f64;
        let mean = self.train_x.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = self
            .train_x
            .iter()
            .map(|&x| (x as f64 - mean) * (x as f64 - mean))
            .sum::<f64>()
            / n;
        let std = var.sqrt().max(1e-9);
        for v in self.train_x.iter_mut() {
            *v = ((*v as f64 - mean) / std) as f32;
        }
        for v in self.test_x.iter_mut() {
            *v = ((*v as f64 - mean) / std) as f32;
        }
    }

    /// Stratified batch of indices for training (balanced classes).
    pub fn sample_batch(&self, rng: &mut Pcg32, batch: usize) -> Vec<usize> {
        (0..batch).map(|_| rng.below(self.n_train() as u32) as usize).collect()
    }
}

/// Dataset registry by paper name.
pub fn load(name: &str, seed: u64) -> Option<RawDataModel> {
    match name {
        "har" | "uci-har" => Some(har::generate(seed)),
        "smnist" => Some(smnist::generate(seed)),
        "gtsrb" => Some(gtsrb::generate(seed)),
        _ => None,
    }
}

/// Shared sizing used by the generators (scaled-down versions of the
/// paper's set sizes, keeping the train:test ratios similar).
pub struct Sizes {
    pub train: usize,
    pub test: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_paper_datasets() {
        for name in ["har", "smnist", "gtsrb"] {
            let d = load(name, 7).unwrap();
            assert!(d.n_train() > 0 && d.n_test() > 0);
            assert_eq!(d.train_x.len(), d.n_train() * d.example_len());
            assert_eq!(d.test_x.len(), d.n_test() * d.example_len());
        }
        assert!(load("imagenet", 0).is_none());
    }

    #[test]
    fn normalization_zeroes_train_mean() {
        let mut d = load("har", 3).unwrap();
        d.normalize();
        let mean: f64 =
            d.train_x.iter().map(|&x| x as f64).sum::<f64>() / d.train_x.len() as f64;
        assert!(mean.abs() < 1e-3, "mean {mean}");
        let var: f64 = d
            .train_x
            .iter()
            .map(|&x| (x as f64 - mean) * (x as f64 - mean))
            .sum::<f64>()
            / d.train_x.len() as f64;
        assert!((var - 1.0).abs() < 1e-2, "var {var}");
    }

    #[test]
    fn labels_cover_all_classes() {
        for name in ["har", "smnist", "gtsrb"] {
            let d = load(name, 5).unwrap();
            let mut seen = vec![false; d.classes];
            for &y in &d.train_y {
                seen[y as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "{name}: missing classes");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = load("smnist", 11).unwrap();
        let b = load("smnist", 11).unwrap();
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.test_y, b.test_y);
        let c = load("smnist", 12).unwrap();
        assert_ne!(a.train_x, c.train_x);
    }
}
