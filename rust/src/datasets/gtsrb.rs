//! Synthetic GTSRB: 43 road-sign classes as 32×32 RGB images (§6.1.3).
//!
//! Each class is a sign template: background color band (red-rim
//! prohibitory / blue mandatory / yellow priority), a geometric silhouette
//! (disc, triangle, diamond, octagon) and a class-specific inner glyph
//! pattern. Examples vary in position, scale, brightness and noise —
//! modeling the photometric/geometric variation of the real benchmark
//! after its 32×32 rescale.

use crate::util::prng::Pcg32;

use super::{RawDataModel, Sizes};

pub const SIZE: usize = 32;
pub const CHANNELS: usize = 3;
pub const CLASSES: usize = 43;

pub fn sizes() -> Sizes {
    // Paper: 39209 train / 12630 test; scaled down, keeping every class.
    Sizes { train: 1290, test: 430 }
}

#[derive(Clone, Copy)]
enum Shape {
    Disc,
    Triangle,
    Diamond,
    Octagon,
}

fn class_style(class: usize) -> (Shape, [f32; 3], [f32; 3]) {
    let shape = match class % 4 {
        0 => Shape::Disc,
        1 => Shape::Triangle,
        2 => Shape::Diamond,
        _ => Shape::Octagon,
    };
    // Rim color family by class band (prohibitory/mandatory/priority/other).
    let rim = match (class / 4) % 4 {
        0 => [0.9, 0.1, 0.1],
        1 => [0.1, 0.2, 0.9],
        2 => [0.9, 0.8, 0.1],
        _ => [0.3, 0.3, 0.3],
    };
    // Inner glyph tone varies with the class index.
    let g = (class as f32 * 0.618) % 1.0;
    let glyph = [g, 1.0 - g, 0.5 + 0.5 * ((class as f32) * 0.37).sin()];
    (shape, rim, glyph)
}

fn inside(shape: Shape, dx: f32, dy: f32, r: f32) -> bool {
    match shape {
        Shape::Disc => dx * dx + dy * dy <= r * r,
        Shape::Triangle => dy >= -r * 0.6 && dy <= r && dx.abs() <= (r - dy) * 0.6,
        Shape::Diamond => dx.abs() + dy.abs() <= r,
        Shape::Octagon => dx.abs().max(dy.abs()) + 0.41 * (dx.abs() + dy.abs()) <= 1.2 * r,
    }
}

fn synth_example(rng: &mut Pcg32, class: usize, out: &mut Vec<f32>) {
    let (shape, rim, glyph) = class_style(class);
    let cx = 16.0 + rng.normal() * 1.0;
    let cy = 16.0 + rng.normal() * 1.0;
    let r = 11.0 + rng.normal() * 1.2;
    let brightness = 0.75 + 0.5 * rng.uniform();
    // Class-specific glyph stripe frequency/orientation.
    let freq = 0.5 + (class % 7) as f32 * 0.35;
    let angle = (class % 5) as f32 * 0.6;
    let (ca, sa) = (angle.cos(), angle.sin());
    for y in 0..SIZE {
        for x in 0..SIZE {
            let dx = x as f32 - cx;
            let dy = y as f32 - cy;
            let in_sign = inside(shape, dx, dy, r);
            let in_core = inside(shape, dx, dy, r * 0.65);
            for ch in 0..CHANNELS {
                let mut v = 0.25; // road background
                if in_sign {
                    v = rim[ch];
                    if in_core {
                        // Glyph: oriented stripes with class frequency.
                        let u = (dx * ca + dy * sa) * freq;
                        let stripe = 0.5 + 0.5 * u.sin();
                        v = glyph[ch] * stripe + 0.9 * (1.0 - stripe);
                    }
                }
                v = v * brightness + rng.normal() * 0.10;
                out.push(v.clamp(0.0, 1.5));
            }
        }
    }
}

pub fn generate(seed: u64) -> RawDataModel {
    let sz = sizes();
    let mut rng = Pcg32::seeded(seed ^ 0x4754_5352);
    let gen_split = |rng: &mut Pcg32, n: usize| {
        let mut xs = Vec::with_capacity(n * SIZE * SIZE * CHANNELS);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % CLASSES;
            synth_example(rng, class, &mut xs);
            ys.push(class as i32);
        }
        (xs, ys)
    };
    let (train_x, train_y) = gen_split(&mut rng, sz.train);
    let (test_x, test_y) = gen_split(&mut rng, sz.test);
    let mut d = RawDataModel {
        name: "gtsrb",
        shape: vec![SIZE, SIZE, CHANNELS],
        classes: CLASSES,
        train_x,
        train_y,
        test_x,
        test_y,
    };
    d.normalize();
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper() {
        let d = generate(1);
        assert_eq!(d.shape, vec![32, 32, 3]);
        assert_eq!(d.classes, 43);
        assert_eq!(d.n_train() % CLASSES, 0);
    }

    #[test]
    fn color_bands_differ_between_families() {
        // Class 0 (red rim) and class 4 (blue rim) must differ strongly in
        // the R/B channel balance inside the sign area.
        let d = generate(2);
        let l = d.example_len();
        let chan_mean = |i: usize, ch: usize| {
            let ex = &d.train_x[i * l..(i + 1) * l];
            let mut s = 0.0f32;
            let mut n = 0;
            for p in 0..SIZE * SIZE {
                s += ex[p * 3 + ch];
                n += 1;
            }
            s / n as f32
        };
        let i_red = d.train_y.iter().position(|&y| y == 0).unwrap();
        let i_blue = d.train_y.iter().position(|&y| y == 4).unwrap();
        let red_balance = chan_mean(i_red, 0) - chan_mean(i_red, 2);
        let blue_balance = chan_mean(i_blue, 0) - chan_mean(i_blue, 2);
        assert!(red_balance > blue_balance + 0.1);
    }
}
