//! Build-time fixed-point range verifier (DESIGN.md §10): abstract
//! interpretation over the quantized graph IR with signed-integer
//! interval domains.
//!
//! For every node of a [`QuantizedGraph`] (Qm.n engine) or
//! [`AffineQuantizedGraph`] (TFLite-scheme engine) the pass propagates a
//! payload interval through the SAME dataflow the integer executors run
//! (`nn::int_exec` / `nn::affine_exec`), using the actual quantized
//! weight payloads — per-filter `Σ max(|w·x_lo|, |w·x_hi|)` bounds, not
//! worst-case width bounds. The result is a [`VerifiedFacts`] report
//! consumed by:
//!
//! - `nn::session::SessionBuilder::try_build` — a graph whose
//!   accumulator can exceed its widest lane (the i64 MACC for Qm.n, the
//!   `as i32` requantize cast for affine) is REJECTED at build time
//!   instead of silently wrapping in release mode;
//! - `nn::packed` — the i32/i64 accumulator lane per conv/dense node
//!   (and per attention projection) becomes a proven fact instead of the
//!   `accum_fits_i32` call-site heuristic, falling back to i64 only
//!   where the proof fails;
//! - `codegen` — per-node facts ship in model.c as `_Static_assert`s.
//!
//! Soundness argument (§10): every transfer function is a monotone
//! over-approximation of the exact integer kernel. For MACC nodes the
//! per-filter magnitude bound `|b| + Σ_taps max(|w·x_lo|, |w·x_hi|)`
//! dominates EVERY partial sum under any accumulation order — the
//! kernels tile arbitrarily, skip zero activations (contribution 0,
//! inside the tap interval whenever 0 is a reachable payload), and SAME
//! zero-padding taps contribute 0 (the tap interval is unioned with 0) —
//! so lane admission by `mag ≤ i32::MAX` is safe for any loop schedule.
//! Interval arithmetic is carried in i128 so the verifier itself cannot
//! overflow; any bound that fails to fit the runtime's lane is a
//! verification error, not a wrap. The primitive transfers
//! (`fixedpoint::qformat::{rescale_interval, clamp_interval}`,
//! `fixedpoint::lut::{exp_q_index, rsqrt_r_bounds, rsqrt_h_max}`) are
//! property-tested against their kernels in their home modules; the
//! per-node containment property is tested here against capture runs of
//! both integer executors.

// The analyzers are pure graph-walking proofs; nothing here may touch
// raw memory (ISSUE 9 satellite: the planner/checker chain must be
// trivially sound to audit).
#![forbid(unsafe_code)]

pub mod liveness;

use std::fmt;

use crate::fixedpoint::lut::{exp_q_index, rsqrt_h_max, rsqrt_r_bounds, EXP_IDX_SHIFT};
use crate::fixedpoint::qformat::{clamp_interval, rescale_interval, QFormat};
use crate::graph::ir::{LayerKind, Node, Padding};
use crate::quant::affine::{decompose, AffineNodeWeights, AffineQuantizedGraph, AffineTxWeights};
use crate::quant::ptq::{QNodeWeights, QTxWeights, QuantizedGraph};

/// Closed signed-integer interval `[lo, hi]` — the abstract payload /
/// accumulator domain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interval {
    pub lo: i64,
    pub hi: i64,
}

impl Interval {
    pub fn new(lo: i64, hi: i64) -> Self {
        assert!(lo <= hi, "inverted interval [{lo}, {hi}]");
        Self { lo, hi }
    }

    #[inline]
    pub fn contains(&self, v: i64) -> bool {
        self.lo <= v && v <= self.hi
    }

    pub fn union(a: Self, b: Self) -> Self {
        Self { lo: a.lo.min(b.lo), hi: a.hi.max(b.hi) }
    }

    /// Union with the point 0 (ZeroPad fill payloads).
    pub fn with_zero(self) -> Self {
        Self { lo: self.lo.min(0), hi: self.hi.max(0) }
    }

    /// Magnitude bound max(|lo|, |hi|).
    pub fn mag(&self) -> i64 {
        self.lo.abs().max(self.hi.abs())
    }

    /// Payload interval of a `width`-bit format (its saturation limits).
    pub fn of_width(width: u32) -> Self {
        let (lo, hi) = QFormat::new(width, 0).payload_interval();
        Self { lo, hi }
    }
}

impl fmt::Display for Interval {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}, {}]", self.lo, self.hi)
    }
}

/// Accumulator lane a MACC node was proven into.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    I32,
    I64,
}

impl Lane {
    fn admit(mag: i64) -> Lane {
        if mag <= i32::MAX as i64 {
            Lane::I32
        } else {
            Lane::I64
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Lane::I32 => "i32",
            Lane::I64 => "i64",
        }
    }
}

/// Op-specific proven facts beyond the accumulator interval.
#[derive(Clone, Debug)]
pub enum OpCheck {
    /// Largest exp-LUT index a softmax can compute; indices past the
    /// table (≥ 256) underflow to probability 0 by design, so this is
    /// reachability information, not an error.
    ExpLutIndex { max: i64 },
    /// Proven range of the layernorm per-row rescale shift
    /// (`30 + h + g_n − n_out` in the Qm.n scheme, `30 + h + g_n` in the
    /// affine scheme whose beta is pre-divided into output quanta).
    NormShift { lo: i32, hi: i32 },
    /// i64 magnitude bound of an internal attention-stage accumulator.
    AttnStage { stage: &'static str, mag: i64 },
    /// Magnitude bound of the affine zero-point fold `b_eff = b − zp·Σw`
    /// performed at pack time in `nn::packed`.
    BiasFold { mag: i64 },
    /// Magnitude bound of an affine accumulator at its `as i32`
    /// requantize cast — proven < 2^31, else the build is rejected.
    RequantAcc { stage: &'static str, mag: i64 },
}

impl fmt::Display for OpCheck {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpCheck::ExpLutIndex { max } => write!(f, "exp-lut idx<={max}"),
            OpCheck::NormShift { lo, hi } => write!(f, "norm-shift in [{lo}, {hi}]"),
            OpCheck::AttnStage { stage, mag } => write!(f, "{stage} |acc|<={mag}"),
            OpCheck::BiasFold { mag } => write!(f, "|b_eff|<={mag}"),
            OpCheck::RequantAcc { stage, mag } => write!(f, "{stage} requant |acc|<={mag}"),
        }
    }
}

/// Per-node proven facts.
#[derive(Clone, Debug)]
pub struct NodeFacts {
    pub id: usize,
    pub name: String,
    pub kind: &'static str,
    /// Proven payload interval of the node output.
    pub out: Interval,
    /// Proven accumulator value interval (bias included), MACC-like nodes.
    pub acc: Option<Interval>,
    /// Order-free bound on |any partial sum| of the accumulation — the
    /// lane-admission fact (covers every tiling / sparsity-skip order).
    pub acc_mag: Option<i64>,
    /// Proven accumulator lane (conv/dense nodes only).
    pub lane: Option<Lane>,
    /// Per-projection lanes (wq, wk, wv, wo) of a self-attention node —
    /// the packed lowering packs each projection separately.
    pub attn_lanes: Option<[Lane; 4]>,
    /// Whether the output clamp / requantize saturation is reachable
    /// under the proven pre-clamp interval (advisory, not an error).
    pub saturates: bool,
    pub checks: Vec<OpCheck>,
}

impl NodeFacts {
    fn flow(node: &Node, out: Interval) -> Self {
        Self {
            id: node.id,
            name: node.name.clone(),
            kind: node.kind.type_name(),
            out,
            acc: None,
            acc_mag: None,
            lane: None,
            attn_lanes: None,
            saturates: false,
            checks: Vec::new(),
        }
    }
}

/// The report a verification pass attaches to a `Plan`.
#[derive(Clone, Debug)]
pub struct VerifiedFacts {
    /// Which analyzer produced the facts ("fixed-qmn" / "affine-i8"), or
    /// "unverified" for backends without integer accumulators.
    pub backend: &'static str,
    /// One entry per graph node (empty when unverified).
    pub nodes: Vec<NodeFacts>,
}

impl VerifiedFacts {
    /// Trivial facts for backends with nothing to prove (float32). Lane
    /// queries return `None`, so weight packing keeps its legacy
    /// heuristic.
    pub fn unverified() -> Self {
        Self { backend: "unverified", nodes: Vec::new() }
    }

    pub fn node(&self, id: usize) -> Option<&NodeFacts> {
        self.nodes.iter().find(|n| n.id == id)
    }

    /// Proven lane of a conv/dense node: `Some(true)` = i32 admitted.
    /// `None` when the node has no MACC lane or the graph is unverified.
    pub fn lane_is_i32(&self, id: usize) -> Option<bool> {
        self.node(id)?.lane.map(|l| l == Lane::I32)
    }

    /// Proven per-projection lanes (wq, wk, wv, wo) of an attention node.
    pub fn attn_lanes_i32(&self, id: usize) -> Option<[bool; 4]> {
        self.node(id)?.attn_lanes.map(|ls| ls.map(|l| l == Lane::I32))
    }

    /// Human-readable report (README: "Reading the VerifiedFacts report").
    pub fn render_report(&self) -> String {
        let mut i32_lanes = 0usize;
        let mut i64_lanes = 0usize;
        let mut saturable = 0usize;
        for n in &self.nodes {
            match n.lane {
                Some(Lane::I32) => i32_lanes += 1,
                Some(Lane::I64) => i64_lanes += 1,
                None => {}
            }
            if n.saturates {
                saturable += 1;
            }
        }
        let mut s = format!(
            "VerifiedFacts ({}): {} nodes, lanes i32={} i64={}, {} saturable clamp(s)\n",
            self.backend,
            self.nodes.len(),
            i32_lanes,
            i64_lanes,
            saturable,
        );
        for n in &self.nodes {
            s.push_str(&format!("  [{:>2}] {:<12} {:<13} out {}", n.id, n.name, n.kind, n.out));
            if let (Some(acc), Some(mag)) = (n.acc, n.acc_mag) {
                s.push_str(&format!("  acc {acc} |part|<={mag}"));
            }
            if let Some(l) = n.lane {
                s.push_str(&format!("  lane {}", l.label()));
            }
            if let Some(ls) = n.attn_lanes {
                s.push_str(&format!(
                    "  proj q/k/v/o {}/{}/{}/{}",
                    ls[0].label(),
                    ls[1].label(),
                    ls[2].label(),
                    ls[3].label()
                ));
            }
            if n.saturates {
                s.push_str("  SAT");
            }
            for c in &n.checks {
                s.push_str(&format!("  {c}"));
            }
            s.push('\n');
        }
        s
    }
}

/// A range proof failed: the graph can overflow an integer lane at
/// runtime. `SessionBuilder::try_build` surfaces this instead of letting
/// release-mode arithmetic wrap silently.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VerifyError {
    pub node: String,
    pub reason: String,
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "range verifier: node `{}`: {}", self.node, self.reason)
    }
}

impl std::error::Error for VerifyError {}

fn verr(node: &Node, reason: String) -> VerifyError {
    VerifyError { node: format!("{} ({})", node.name, node.kind.type_name()), reason }
}

fn fit64(node: &Node, v: i128, what: &str) -> Result<i64, VerifyError> {
    i64::try_from(v).map_err(|_| {
        verr(node, format!("{what}: bound {v} exceeds the wide i64 accumulator lane"))
    })
}

/// Interval transfer of `ops::rescale` over i128 accumulator bounds;
/// errors when an endpoint escapes i64 or a left shift would drop high
/// bits in the runtime's i64 lane.
fn rescale_iv(
    node: &Node,
    lo: i128,
    hi: i128,
    shift: i32,
    what: &str,
) -> Result<(i128, i128), VerifyError> {
    let lo = fit64(node, lo, what)?;
    let hi = fit64(node, hi, what)?;
    let (rlo, rhi) = rescale_interval(lo, hi, shift).ok_or_else(|| {
        verr(node, format!("{what}: rescale by {shift} overflows the i64 lane on [{lo}, {hi}]"))
    })?;
    Ok((rlo as i128, rhi as i128))
}

/// Clamp-transfer to a width's saturation limits; reports whether the
/// clamp is reachable. Pre-clamp bounds may exceed i64 (e.g. the Add
/// realignment sum), so the clamp itself runs in i128.
fn clamp_iv(lo: i128, hi: i128, width: u32) -> (Interval, bool) {
    let (llo, lhi) = QFormat::new(width, 0).payload_interval();
    if lo >= llo as i128 && hi <= lhi as i128 {
        let ((clo, chi), sat) = clamp_interval(lo as i64, hi as i64, width);
        (Interval::new(clo, chi), sat)
    } else {
        let clo = lo.clamp(llo as i128, lhi as i128) as i64;
        let chi = hi.clamp(llo as i128, lhi as i128) as i64;
        (Interval::new(clo, chi), true)
    }
}

/// Clamped upper payload bound of a softmax probability at `n_out`
/// fractional bits: `p = (e << n_out) / sum ≤ 2^n_out` since `e ≤ sum`,
/// then the width clamp applies.
fn prob_hi(n_out: i32, width: u32) -> i64 {
    let (_, hi) = QFormat::new(width, 0).payload_interval();
    (1i64 << n_out.clamp(0, 62)).min(hi)
}

/// Proven exp-LUT index bound for a softmax whose (max-subtracted) input
/// distance is at most `span` payloads at format `n_in`.
fn softmax_lut_fact(node: &Node, span: i64, n_in: i32) -> Result<i64, VerifyError> {
    fit64(node, (span as i128) << EXP_IDX_SHIFT, "softmax exp-LUT argument")?;
    Ok(exp_q_index(span, n_in))
}

/// (taps, filters) of a conv/dense weight in the packed column layout.
fn mac_dims(kind: &LayerKind) -> (usize, usize) {
    match kind {
        LayerKind::Conv { w, .. } => {
            (w.shape[..w.shape.len() - 1].iter().product(), *w.shape.last().unwrap())
        }
        LayerKind::Dense { w, .. } => (w.shape[0], w.shape[1]),
        _ => unreachable!("mac_dims on non-MACC node"),
    }
}

fn is_same_conv(kind: &LayerKind) -> bool {
    matches!(kind, LayerKind::Conv { padding: Padding::Same, .. })
}

/// Result of the shared fixed-point MACC transfer.
struct MacFacts {
    acc: Interval,
    mag: i64,
    out: Interval,
    saturates: bool,
}

/// Exact per-filter accumulator bounds for a Qm.n conv/dense/projection:
/// weights in (taps, filters) layout, input payloads in `x`, optional
/// zero-padding taps, per-filter (or uniform) rescale shift, clamp to
/// `width`, optional fused ReLU.
fn mac_transfer_fixed(
    node: &Node,
    qw: &QNodeWeights,
    taps: usize,
    filters: usize,
    x: Interval,
    pad_zero: bool,
    relu: bool,
    width: u32,
) -> Result<MacFacts, VerifyError> {
    let (xlo, xhi) = (x.lo as i128, x.hi as i128);
    let mut acc_lo = i128::MAX;
    let mut acc_hi = i128::MIN;
    let mut mag_all = 0i64;
    let mut out_all: Option<Interval> = None;
    let mut sat_all = false;
    for f in 0..filters {
        let b = qw.b_acc[f] as i128;
        let (mut lo, mut hi, mut mag) = (b, b, b.abs());
        for t in 0..taps {
            let w = qw.w[t * filters + f] as i128;
            let (c1, c2) = (w * xlo, w * xhi);
            let (mut clo, mut chi) = if c1 <= c2 { (c1, c2) } else { (c2, c1) };
            if pad_zero {
                clo = clo.min(0);
                chi = chi.max(0);
            }
            lo += clo;
            hi += chi;
            mag += clo.abs().max(chi.abs());
        }
        // Every partial sum (any order, bias first or last, zero skips)
        // is bounded by mag; the runtime's widest lane is i64.
        let mag = fit64(node, mag, "accumulator partial-sum bound")?;
        let (plo, phi) = rescale_iv(node, lo, hi, qw.shift_for(f), "requantize shift")?;
        let (mut out_f, sat_f) = clamp_iv(plo, phi, width);
        if relu {
            out_f = Interval::new(out_f.lo.max(0), out_f.hi.max(0));
        }
        acc_lo = acc_lo.min(lo);
        acc_hi = acc_hi.max(hi);
        mag_all = mag_all.max(mag);
        sat_all |= sat_f;
        out_all = Some(match out_all {
            Some(o) => Interval::union(o, out_f),
            None => out_f,
        });
    }
    let acc = Interval::new(
        fit64(node, acc_lo, "accumulator interval")?,
        fit64(node, acc_hi, "accumulator interval")?,
    );
    Ok(MacFacts {
        acc,
        mag: mag_all,
        out: out_all.expect("MACC node with zero filters"),
        saturates: sat_all,
    })
}

/// Result of the shared layernorm transfer.
struct NormFacts {
    out: Interval,
    mag: i64,
    sh_lo: i32,
    sh_hi: i32,
    saturates: bool,
}

/// Shared fixed/affine layernorm transfer. The per-row rescale shift is
/// `30 + h + g_n + extra_sh` (`extra_sh = −n_out` for Qm.n whose beta
/// sits at n_out, 0 for the affine scheme whose beta is pre-divided into
/// output quanta); `h` is bounded via `rsqrt_h_max` over the proven
/// variance range, and the row accumulator `d·r·gamma` must fit i64.
#[allow(clippy::too_many_arguments)]
fn norm_transfer(
    node: &Node,
    x: Interval,
    c: usize,
    gamma: &[i32],
    g_n: i32,
    beta_lo: i128,
    beta_hi: i128,
    extra_sh: i32,
    width: u32,
) -> Result<NormFacts, VerifyError> {
    let span = (x.hi - x.lo) as i128;
    // mean = trunc(Σ_c x / c) stays inside the integer-endpoint interval,
    // so |d| = |x − mean| ≤ span; all three row accumulators (mean sum,
    // variance sum, d·r product chain) must fit i64.
    fit64(node, c as i128 * x.mag() as i128, "layernorm mean accumulator")?;
    fit64(node, c as i128 * span * span, "layernorm variance accumulator")?;
    let v_max = fit64(node, span * span + 1, "layernorm rsqrt argument")?;
    let h_max = rsqrt_h_max(v_max);
    let (_, r_max) = rsqrt_r_bounds();
    fit64(node, span * r_max as i128, "layernorm normalized row value")?;
    let g_max = gamma.iter().map(|v| (*v as i128).abs()).max().unwrap_or(0);
    let mag = fit64(node, span * r_max as i128 * g_max, "layernorm row accumulator")?;
    let sh_lo = 30 + g_n + extra_sh; // h = 0
    let sh_hi = 30 + h_max + g_n + extra_sh;
    // Widest pre-clamp interval at the smallest shift.
    let (plo, phi) =
        rescale_iv(node, -(mag as i128), mag as i128, sh_lo, "layernorm output rescale")?;
    let (out, sat) = clamp_iv(plo + beta_lo, phi + beta_hi, width);
    Ok(NormFacts { out, mag, sh_lo, sh_hi, saturates: sat })
}

/// Abstract-interpretation pass over a Qm.n quantized graph. Returns the
/// proven per-node facts, or an error naming the first node whose
/// accumulator/shift can escape its integer lane.
pub fn analyze_fixed(qg: &QuantizedGraph) -> Result<VerifiedFacts, VerifyError> {
    let g = &qg.graph;
    let width = qg.width;
    let mut out: Vec<Interval> = Vec::with_capacity(g.nodes.len());
    let mut nodes: Vec<NodeFacts> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let nf = match &node.kind {
            LayerKind::Input => NodeFacts::flow(node, Interval::of_width(width)),
            LayerKind::Conv { .. } | LayerKind::Dense { .. } => {
                let (taps, filters) = mac_dims(&node.kind);
                let x = out[node.inputs[0]];
                let m = mac_transfer_fixed(
                    node,
                    &qg.weights[&node.id],
                    taps,
                    filters,
                    x,
                    is_same_conv(&node.kind),
                    node.fused_relu,
                    width,
                )?;
                let mut nf = NodeFacts::flow(node, m.out);
                nf.acc = Some(m.acc);
                nf.acc_mag = Some(m.mag);
                nf.lane = Some(Lane::admit(m.mag));
                nf.saturates = m.saturates;
                nf
            }
            LayerKind::MaxPool { .. } => {
                let x = out[node.inputs[0]];
                let o = if node.fused_relu {
                    Interval::new(x.lo.max(0), x.hi.max(0))
                } else {
                    x
                };
                NodeFacts::flow(node, o)
            }
            LayerKind::AvgPool { .. } | LayerKind::GlobalAvgPool => {
                // Truncating integer mean of payloads in [lo, hi] stays in
                // [lo, hi] (integer endpoints); the i64 window sum is
                // bounded by elems·mag.
                let x = out[node.inputs[0]];
                let elems: usize = g.nodes[node.inputs[0]].out_shape.iter().product();
                fit64(node, elems as i128 * x.mag() as i128, "pool window sum")?;
                NodeFacts::flow(node, x)
            }
            LayerKind::Add => {
                let (ia, ib) = (node.inputs[0], node.inputs[1]);
                let n_out = qg.act_n[node.id];
                let (a, b) = (out[ia], out[ib]);
                let (alo, ahi) = rescale_iv(
                    node, a.lo as i128, a.hi as i128, qg.act_n[ia] - n_out, "add lhs realign",
                )?;
                let (blo, bhi) = rescale_iv(
                    node, b.lo as i128, b.hi as i128, qg.act_n[ib] - n_out, "add rhs realign",
                )?;
                let (mut o, sat) = clamp_iv(alo + blo, ahi + bhi, width);
                if node.fused_relu {
                    o = Interval::new(o.lo.max(0), o.hi.max(0));
                }
                let mut nf = NodeFacts::flow(node, o);
                nf.saturates = sat;
                nf
            }
            LayerKind::ReLU => {
                let x = out[node.inputs[0]];
                NodeFacts::flow(node, Interval::new(x.lo.max(0), x.hi.max(0)))
            }
            LayerKind::Flatten => NodeFacts::flow(node, out[node.inputs[0]]),
            LayerKind::ZeroPad { .. } => NodeFacts::flow(node, out[node.inputs[0]].with_zero()),
            LayerKind::Softmax => {
                let x = out[node.inputs[0]];
                let n_out = qg.act_n[node.id];
                let jmax = softmax_lut_fact(node, x.hi - x.lo, qg.act_n[node.inputs[0]])?;
                let p_hi = prob_hi(n_out, width);
                let mut nf = NodeFacts::flow(node, Interval::new(0, p_hi));
                nf.saturates = (1i64 << n_out.clamp(0, 62)) > p_hi;
                nf.checks.push(OpCheck::ExpLutIndex { max: jmax });
                nf
            }
            LayerKind::Embedding { .. } => {
                let QTxWeights::Embed { table } = &qg.tx[&node.id] else {
                    return Err(verr(node, "embedding node without Embed params".into()));
                };
                let lo = table.iter().copied().min().unwrap_or(0) as i64;
                let hi = table.iter().copied().max().unwrap_or(0) as i64;
                NodeFacts::flow(node, Interval::new(lo, hi))
            }
            LayerKind::LayerNorm { .. } => {
                let QTxWeights::Norm { gamma, g_n, beta } = &qg.tx[&node.id] else {
                    return Err(verr(node, "layernorm node without Norm params".into()));
                };
                let x = out[node.inputs[0]];
                let c = *g.nodes[node.inputs[0]].out_shape.last().unwrap();
                let beta_lo = beta.iter().copied().min().unwrap_or(0) as i128;
                let beta_hi = beta.iter().copied().max().unwrap_or(0) as i128;
                let ln = norm_transfer(
                    node, x, c, gamma, *g_n, beta_lo, beta_hi, -qg.act_n[node.id], width,
                )?;
                let mut nf = NodeFacts::flow(node, ln.out);
                nf.acc = Some(Interval::new(-ln.mag, ln.mag));
                nf.acc_mag = Some(ln.mag);
                nf.saturates = ln.saturates;
                nf.checks.push(OpCheck::NormShift { lo: ln.sh_lo, hi: ln.sh_hi });
                nf
            }
            LayerKind::SelfAttention { head_dim, .. } => {
                let QTxWeights::Attn {
                    wq, wk, wv, wo, n_q, n_k, n_v, n_s, n_p, n_ctx, inv_sqrt_hd_q15,
                } = &qg.tx[&node.id]
                else {
                    return Err(verr(node, "attention node without Attn params".into()));
                };
                let x = out[node.inputs[0]];
                let ish = &g.nodes[node.inputs[0]].out_shape;
                let (seq, dm) = (ish[0], ish[1]);
                let q = mac_transfer_fixed(node, wq, dm, dm, x, false, false, width)?;
                let k = mac_transfer_fixed(node, wk, dm, dm, x, false, false, width)?;
                let v = mac_transfer_fixed(node, wv, dm, dm, x, false, false, width)?;
                // score = rescale(Σ_hd q·k · inv_sqrt_hd_q15, n_q+n_k+15−n_s):
                // both the raw i64 accumulator and its Q0.15 scaling must
                // fit the runtime lane.
                let s_acc = *head_dim as i128 * q.out.mag() as i128 * k.out.mag() as i128;
                fit64(node, s_acc, "attention score accumulator")?;
                let s_scaled = s_acc * *inv_sqrt_hd_q15 as i128;
                let s_mag = fit64(node, s_scaled, "attention scaled score")?;
                let (slo, shi) =
                    rescale_iv(node, -s_scaled, s_scaled, n_q + n_k + 15 - n_s, "score rescale")?;
                let (s_iv, s_sat) = clamp_iv(slo, shi, width);
                // Probability payloads (softmax over scores at n_s → n_p):
                // each p ≤ 2^n_p (width-clamped), and one row sums to at
                // most 2^n_p — the floor-division mass bound.
                let jmax = softmax_lut_fact(node, s_iv.hi - s_iv.lo, *n_s)?;
                let p_hi = prob_hi(*n_p, width);
                let mass = (seq as i128 * p_hi as i128).min(1i128 << (*n_p).clamp(0, 62));
                // ctx = rescale(Σ_seq p·v, n_p+n_v−n_ctx); p ≥ 0 keeps
                // every prefix sum inside the total's interval.
                let clo = (mass * v.out.lo as i128).min(0);
                let chi = (mass * v.out.hi as i128).max(0);
                let c_mag =
                    fit64(node, chi.abs().max(clo.abs()), "attention context accumulator")?;
                let (rlo, rhi) = rescale_iv(node, clo, chi, n_p + n_v - n_ctx, "context rescale")?;
                let (ctx_iv, c_sat) = clamp_iv(rlo, rhi, width);
                let o = mac_transfer_fixed(node, wo, dm, dm, ctx_iv, false, false, width)?;
                let mut nf = NodeFacts::flow(node, o.out);
                nf.acc = Some(o.acc);
                nf.acc_mag = Some(o.mag);
                nf.attn_lanes = Some([
                    Lane::admit(q.mag),
                    Lane::admit(k.mag),
                    Lane::admit(v.mag),
                    Lane::admit(o.mag),
                ]);
                nf.saturates =
                    q.saturates || k.saturates || v.saturates || s_sat || c_sat || o.saturates;
                nf.checks.push(OpCheck::AttnStage { stage: "score", mag: s_mag });
                nf.checks.push(OpCheck::AttnStage { stage: "ctx", mag: c_mag });
                nf.checks.push(OpCheck::ExpLutIndex { max: jmax });
                nf
            }
            LayerKind::BatchNorm { .. } => {
                return Err(verr(
                    node,
                    "BatchNorm must be folded before integer execution (run deploy_pipeline)"
                        .into(),
                ));
            }
        };
        out.push(nf.out);
        nodes.push(nf);
    }
    Ok(VerifiedFacts { backend: "fixed-qmn", nodes })
}

// ---------------------------------------------------------------------------
// Affine (TFLite-scheme) analyzer
// ---------------------------------------------------------------------------

/// Result of the shared affine MACC transfer.
struct AffMacFacts {
    acc: Interval,
    mag: i64,
    fold_mag: i64,
    requant_mag: i64,
    out: Interval,
}

/// Exact per-filter bounds for an affine conv/dense/projection. The
/// runtime computes `acc = b + Σ (x − zp_in)·w` (staged per call) or
/// equivalently `b_eff + Σ x·w` with `b_eff = b − zp_in·Σw` (prepacked
/// fold) — identical totals — then casts `acc as i32` into gemmlowp
/// requantization. Both orders' partial sums are bounded here; the cast
/// demands |acc| ≤ i32::MAX or the build is rejected.
#[allow(clippy::too_many_arguments)]
fn mac_transfer_affine(
    node: &Node,
    qw: &AffineNodeWeights,
    taps: usize,
    filters: usize,
    x: Interval,
    zp_in: i32,
    pad_zero: bool,
    relu: bool,
    zp_out: i32,
) -> Result<AffMacFacts, VerifyError> {
    // Staged operand (x − zp) interval; SAME padding taps contribute
    // exactly 0 in both lowerings (skipped in the staged path, raw
    // payload zp_in cancelling against the fold in the prepacked path).
    let (dlo, dhi) = ((x.lo - zp_in as i64) as i128, (x.hi - zp_in as i64) as i128);
    let x_raw_mag = x.mag().max(zp_in.unsigned_abs() as i64) as i128;
    let d_mag = dlo.abs().max(dhi.abs());
    let mut acc_lo = i128::MAX;
    let mut acc_hi = i128::MIN;
    let mut mag_all = 0i64;
    let mut fold_all = 0i64;
    let mut req_all = 0i64;
    for f in 0..filters {
        let b = qw.b[f] as i128;
        let mut col_sum = 0i128;
        let mut abs_col = 0i128;
        let (mut lo, mut hi) = (b, b);
        for t in 0..taps {
            let w = qw.w[t * filters + f] as i128;
            col_sum += w;
            abs_col += w.abs();
            let (c1, c2) = (w * dlo, w * dhi);
            let (mut clo, mut chi) = if c1 <= c2 { (c1, c2) } else { (c2, c1) };
            if pad_zero {
                clo = clo.min(0);
                chi = chi.max(0);
            }
            lo += clo;
            hi += chi;
        }
        // Pack-time zero-point fold must not wrap i64.
        let fold = fit64(node, (b - zp_in as i128 * col_sum).abs(), "zero-point bias fold")?;
        // Order-free i64 partial-sum bound covering BOTH lowerings: the
        // prepacked path accumulates raw payloads onto b_eff, the staged
        // path (x − zp) operands onto b.
        let mag = fit64(
            node,
            (fold as i128 + abs_col * x_raw_mag).max(b.abs() + abs_col * d_mag),
            "affine accumulator partial-sum bound",
        )?;
        // The gemmlowp requantize consumes the total through an `as i32`
        // cast — a total outside i32 wraps silently in release builds.
        let req = lo.abs().max(hi.abs());
        if req > i32::MAX as i128 {
            return Err(verr(
                node,
                format!(
                    "affine accumulator can reach magnitude {req} (> i32::MAX) at the \
                     requantize cast — the graph would wrap silently at runtime"
                ),
            ));
        }
        acc_lo = acc_lo.min(lo);
        acc_hi = acc_hi.max(hi);
        mag_all = mag_all.max(mag);
        fold_all = fold_all.max(fold);
        req_all = req_all.max(req as i64);
    }
    // requantize clamps to [-128, 127]; fused ReLU floors at zp_out.
    let out = if relu {
        Interval::new((zp_out as i64).min(127), 127)
    } else {
        Interval::new(-128, 127)
    };
    Ok(AffMacFacts {
        acc: Interval::new(acc_lo as i64, acc_hi as i64),
        mag: mag_all,
        fold_mag: fold_all,
        requant_mag: req_all,
        out,
    })
}

fn check_requant(node: &Node, stage: &str, mag: i128) -> Result<i64, VerifyError> {
    if mag > i32::MAX as i128 {
        return Err(verr(
            node,
            format!(
                "{stage}: accumulator can reach magnitude {mag} (> i32::MAX) at the \
                 requantize cast — the graph would wrap silently at runtime"
            ),
        ));
    }
    Ok(mag as i64)
}

/// Affine softmax exp-LUT index bound, mirroring `softmax_affine_row`:
/// `d15 = (dist · sm_mult) >> (16 + sm_shift)` then the Q0.15 lookup.
fn affine_softmax_lut_fact(
    node: &Node,
    span: i64,
    sm_mult: i32,
    sm_shift: i32,
) -> Result<i64, VerifyError> {
    let d15 = (span * sm_mult as i64) >> (16 + sm_shift).clamp(0, 63);
    softmax_lut_fact(node, d15, 15)
}

/// Abstract-interpretation pass over an affine (TFLite-scheme) quantized
/// graph. Every payload is an int8 in [-128, 127] by construction (every
/// producer requantizes or clamps), so the proofs concern the i64 MACC
/// partial sums, the pack-time zero-point fold, and the `as i32`
/// requantize casts.
pub fn analyze_affine(aq: &AffineQuantizedGraph) -> Result<VerifiedFacts, VerifyError> {
    let g = &aq.graph;
    let i8_full = Interval::new(-128, 127);
    let mut out: Vec<Interval> = Vec::with_capacity(g.nodes.len());
    let mut nodes: Vec<NodeFacts> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let nf = match &node.kind {
            LayerKind::Input => NodeFacts::flow(node, i8_full),
            LayerKind::Conv { .. } | LayerKind::Dense { .. } => {
                let (taps, filters) = mac_dims(&node.kind);
                let src = node.inputs[0];
                let m = mac_transfer_affine(
                    node,
                    &aq.weights[&node.id],
                    taps,
                    filters,
                    out[src],
                    aq.act[src].zero_point,
                    is_same_conv(&node.kind),
                    node.fused_relu,
                    aq.act[node.id].zero_point,
                )?;
                let mut nf = NodeFacts::flow(node, m.out);
                nf.acc = Some(m.acc);
                nf.acc_mag = Some(m.mag);
                nf.lane = Some(Lane::I64); // affine panels always pack i64
                nf.saturates = true; // the requantize clamp defines the output
                nf.checks.push(OpCheck::BiasFold { mag: m.fold_mag });
                nf.checks.push(OpCheck::RequantAcc { stage: "out", mag: m.requant_mag });
                nf
            }
            LayerKind::MaxPool { .. } => {
                let x = out[node.inputs[0]];
                let zp = aq.act[node.id].zero_point as i64;
                let o = if node.fused_relu {
                    Interval::new(x.lo.max(zp), x.hi.max(zp))
                } else {
                    x
                };
                NodeFacts::flow(node, o)
            }
            LayerKind::AvgPool { .. } | LayerKind::GlobalAvgPool => {
                // Rounding integer means stay inside the integer-endpoint
                // input interval; the i64 window sum is bounded.
                let x = out[node.inputs[0]];
                let elems: usize = g.nodes[node.inputs[0]].out_shape.iter().product();
                fit64(node, elems as i128 * x.mag() as i128, "pool window sum")?;
                NodeFacts::flow(node, x)
            }
            LayerKind::Add => {
                // Scale-ratio add, clamped to [-128, 127]; fused ReLU
                // floors at the output zero point.
                let zp = aq.act[node.id].zero_point as i64;
                let o = if node.fused_relu {
                    Interval::new(zp.min(127), 127)
                } else {
                    i8_full
                };
                NodeFacts::flow(node, o)
            }
            LayerKind::ReLU => {
                let x = out[node.inputs[0]];
                let zp = aq.act[node.id].zero_point as i64;
                NodeFacts::flow(node, Interval::new(x.lo.max(zp), x.hi.max(zp)))
            }
            LayerKind::Flatten => NodeFacts::flow(node, out[node.inputs[0]]),
            LayerKind::ZeroPad { .. } => {
                // The pad fill is the real value 0 = payload zp.
                let x = out[node.inputs[0]];
                let zp = aq.act[node.id].zero_point as i64;
                NodeFacts::flow(node, Interval::new(x.lo.min(zp), x.hi.max(zp)))
            }
            LayerKind::Softmax => {
                let x = out[node.inputs[0]];
                let (sm_mult, sm_shift) = decompose(aq.act[node.inputs[0]].scale as f64);
                let jmax = affine_softmax_lut_fact(node, x.hi - x.lo, sm_mult, sm_shift)?;
                let mut nf = NodeFacts::flow(node, i8_full);
                nf.checks.push(OpCheck::ExpLutIndex { max: jmax });
                nf
            }
            LayerKind::Embedding { .. } => {
                let AffineTxWeights::Embed { table } = &aq.tx[&node.id] else {
                    return Err(verr(node, "embedding node without Embed params".into()));
                };
                let lo = table.iter().copied().min().unwrap_or(0) as i64;
                let hi = table.iter().copied().max().unwrap_or(0) as i64;
                NodeFacts::flow(node, Interval::new(lo, hi))
            }
            LayerKind::LayerNorm { .. } => {
                let AffineTxWeights::Norm { gamma, g_n, beta } = &aq.tx[&node.id] else {
                    return Err(verr(node, "layernorm node without Norm params".into()));
                };
                let x = out[node.inputs[0]];
                let c = *g.nodes[node.inputs[0]].out_shape.last().unwrap();
                let zp = aq.act[node.id].zero_point as i128;
                let beta_lo = beta.iter().copied().min().unwrap_or(0) as i128 + zp;
                let beta_hi = beta.iter().copied().max().unwrap_or(0) as i128 + zp;
                // The affine layernorm clamps straight to int8; beta is
                // pre-divided into output quanta (no −n_out term).
                let ln = norm_transfer(node, x, c, gamma, *g_n, beta_lo, beta_hi, 0, 8)?;
                let mut nf = NodeFacts::flow(node, ln.out);
                nf.acc = Some(Interval::new(-ln.mag, ln.mag));
                nf.acc_mag = Some(ln.mag);
                nf.saturates = ln.saturates;
                nf.checks.push(OpCheck::NormShift { lo: ln.sh_lo, hi: ln.sh_hi });
                nf
            }
            LayerKind::SelfAttention { head_dim, .. } => {
                let AffineTxWeights::Attn {
                    wq, wk, wv, wo, q, k, v, ctx, sm_mult, sm_shift, ..
                } = &aq.tx[&node.id]
                else {
                    return Err(verr(node, "attention node without Attn params".into()));
                };
                let x = out[node.inputs[0]];
                let ish = &g.nodes[node.inputs[0]].out_shape;
                let (seq, dm) = (ish[0], ish[1]);
                let zp_in = aq.act[node.inputs[0]].zero_point;
                let mq =
                    mac_transfer_affine(node, wq, dm, dm, x, zp_in, false, false, q.zero_point)?;
                let mk =
                    mac_transfer_affine(node, wk, dm, dm, x, zp_in, false, false, k.zero_point)?;
                let mv =
                    mac_transfer_affine(node, wv, dm, dm, x, zp_in, false, false, v.zero_point)?;
                // score acc = Σ_hd (q − zp_q)(k − zp_k), consumed `as i32`.
                let dq = 128i128 + q.zero_point.unsigned_abs() as i128;
                let dk = 128i128 + k.zero_point.unsigned_abs() as i128;
                let s_mag = check_requant(node, "attention score", *head_dim as i128 * dq * dk)?;
                // Probability rows arrive at prob_params (zero point −128),
                // staged as (p + 128) ∈ [0, 255]; ctx acc = Σ_seq
                // (p + 128)(v − zp_v), consumed `as i32`.
                let jmax = affine_softmax_lut_fact(node, 255, *sm_mult, *sm_shift)?;
                let dv = 128i128 + v.zero_point.unsigned_abs() as i128;
                let c_mag = check_requant(node, "attention context", seq as i128 * 255 * dv)?;
                let mo = mac_transfer_affine(
                    node,
                    wo,
                    dm,
                    dm,
                    i8_full,
                    ctx.zero_point,
                    false,
                    false,
                    aq.act[node.id].zero_point,
                )?;
                let mut nf = NodeFacts::flow(node, mo.out);
                nf.acc = Some(mo.acc);
                nf.acc_mag = Some(mo.mag);
                nf.attn_lanes = Some([Lane::I64; 4]);
                nf.saturates = true;
                nf.checks.push(OpCheck::RequantAcc { stage: "score", mag: s_mag });
                nf.checks.push(OpCheck::RequantAcc { stage: "ctx", mag: c_mag });
                nf.checks.push(OpCheck::BiasFold {
                    mag: mq.fold_mag.max(mk.fold_mag).max(mv.fold_mag).max(mo.fold_mag),
                });
                nf.checks.push(OpCheck::ExpLutIndex { max: jmax });
                nf
            }
            LayerKind::BatchNorm { .. } => {
                return Err(verr(
                    node,
                    "BatchNorm must be folded before integer execution (run deploy_pipeline)"
                        .into(),
                ));
            }
        };
        out.push(nf.out);
        nodes.push(nf);
    }
    Ok(VerifiedFacts { backend: "affine-i8", nodes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::transformer;
    use crate::graph::deploy_pipeline;
    use crate::graph::ir::Graph;
    use crate::nn::int_exec::{calib, random_inputs, randomized_resnet};
    use crate::nn::int_ops::accum_fits_i32;
    use crate::nn::{affine_exec, int_exec};
    use crate::quant::affine::quantize_affine;
    use crate::quant::{quantize, QuantSpec};
    use crate::tensor::TensorF;
    use crate::util::prng::Pcg32;

    /// Randomize the zero-weight transformer builder output so the
    /// quantized formats are non-degenerate.
    fn randomized_transformer(seed: u64) -> Graph {
        let mut g = transformer("ctx", 12, 24, 16, 2, 2, 2, 4);
        let mut rng = Pcg32::seeded(seed);
        for node in &mut g.nodes {
            match &mut node.kind {
                LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } => {
                    for v in &mut w.data {
                        *v = rng.normal() * 0.3;
                    }
                    for v in &mut b.data {
                        *v = rng.normal() * 0.05;
                    }
                }
                LayerKind::Embedding { w } => {
                    for v in &mut w.data {
                        *v = rng.normal() * 0.5;
                    }
                }
                LayerKind::LayerNorm { gamma, beta, .. } => {
                    for v in &mut gamma.data {
                        *v = 1.0 + rng.normal() * 0.2;
                    }
                    for v in &mut beta.data {
                        *v = rng.normal() * 0.1;
                    }
                }
                LayerKind::SelfAttention { w, .. } => {
                    for t in [&mut w.wq, &mut w.wk, &mut w.wv, &mut w.wo] {
                        for v in &mut t.data {
                            *v = rng.normal() * 0.3;
                        }
                    }
                    for t in [&mut w.bq, &mut w.bk, &mut w.bv, &mut w.bo] {
                        for v in &mut t.data {
                            *v = rng.normal() * 0.05;
                        }
                    }
                }
                _ => {}
            }
        }
        deploy_pipeline(&g)
    }

    fn token_inputs(n: usize, seq: usize, vocab: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|s| (0..seq).map(|i| ((i * 7 + s * 3) % vocab) as f32).collect())
            .collect()
    }

    fn assert_contained(facts: &VerifiedFacts, observed: &[Vec<i32>], what: &str) {
        assert_eq!(facts.nodes.len(), observed.len(), "{what}: node count");
        for (nf, vals) in facts.nodes.iter().zip(observed) {
            for &v in vals {
                assert!(
                    nf.out.contains(v as i64),
                    "{what}: node {} ({}) payload {v} escapes proven {}",
                    nf.name,
                    nf.kind,
                    nf.out
                );
            }
        }
    }

    // Tentpole soundness property: the proven per-node output intervals
    // contain every intermediate payload the integer executor actually
    // produces, across random inputs, both model families, widths 8/16.
    #[test]
    fn fixed_facts_contain_all_observed_convnet_payloads() {
        for (seed, spec) in [
            (7u64, QuantSpec::int8_per_layer()),
            (7, QuantSpec::int16_per_layer()),
            (11, QuantSpec::int8_per_filter()),
        ] {
            let g = randomized_resnet(seed);
            let inputs = random_inputs(6, g.input_shape.iter().product(), seed ^ 0xbeef);
            let qg = quantize(&g, &calib(&g, &inputs), spec);
            let facts = crate::analysis::analyze_fixed(&qg).expect("convnet must verify");
            for x in &inputs {
                let captured = int_exec::run_capture(&qg, x);
                assert_contained(&facts, &captured, "fixed convnet");
            }
        }
    }

    #[test]
    fn fixed_facts_contain_all_observed_transformer_payloads() {
        for spec in [QuantSpec::int8_per_layer(), QuantSpec::int16_per_layer()] {
            let g = randomized_transformer(13);
            let inputs = token_inputs(5, 12, 24);
            let qg = quantize(&g, &calib(&g, &inputs), spec);
            let facts = crate::analysis::analyze_fixed(&qg).expect("transformer must verify");
            for x in &inputs {
                let captured = int_exec::run_capture(&qg, x);
                assert_contained(&facts, &captured, "fixed transformer");
            }
        }
    }

    #[test]
    fn affine_facts_contain_all_observed_payloads() {
        let g = randomized_resnet(5);
        let inputs = random_inputs(6, g.input_shape.iter().product(), 0x51de);
        let aq = quantize_affine(&g, &calib(&g, &inputs));
        let facts = crate::analysis::analyze_affine(&aq).expect("affine convnet must verify");
        for x in &inputs {
            let captured = affine_exec::run_capture(&aq, x);
            assert_contained(&facts, &captured, "affine convnet");
        }

        let tg = randomized_transformer(17);
        let tin = token_inputs(4, 12, 24);
        let taq = quantize_affine(&tg, &calib(&tg, &tin));
        let tfacts = crate::analysis::analyze_affine(&taq).expect("affine transformer");
        for x in &tin {
            let captured = affine_exec::run_capture(&taq, x);
            assert_contained(&tfacts, &captured, "affine transformer");
        }
    }

    // Lane admission must be a superset of the legacy heuristic: wherever
    // `accum_fits_i32` admitted i32, the exact proof must too (the facts
    // can only move i64 lanes down to i32, never the reverse).
    #[test]
    fn proven_lanes_refine_the_heuristic() {
        for spec in [QuantSpec::int8_per_layer(), QuantSpec::int16_per_layer()] {
            let g = randomized_resnet(3);
            let inputs = random_inputs(4, g.input_shape.iter().product(), 99);
            let qg = quantize(&g, &calib(&g, &inputs), spec);
            let facts = crate::analysis::analyze_fixed(&qg).unwrap();
            for node in &qg.graph.nodes {
                if !matches!(node.kind, LayerKind::Conv { .. } | LayerKind::Dense { .. }) {
                    continue;
                }
                let (taps, _) = mac_dims(&node.kind);
                if accum_fits_i32(&qg.weights[&node.id], taps, qg.width) {
                    assert_eq!(
                        facts.lane_is_i32(node.id),
                        Some(true),
                        "node {} heuristic admits i32 but proof does not",
                        node.name
                    );
                }
            }
        }
    }

    /// A tiny dense graph whose bias is crafted to overflow: the affine
    /// accumulator escapes the i32 requantize cast, and the fixed-point
    /// `b_acc` fold saturates the i64 lane.
    fn overflow_graph(bias: f32) -> Graph {
        let mut g = Graph::new("overflow", 1, &[4, 1], 2);
        let f = g.add("fl", LayerKind::Flatten, vec![0]);
        let w = TensorF::from_vec(&[4, 2], vec![0.01; 8]);
        let mut b = TensorF::from_vec(&[2], vec![0.0, 0.0]);
        b.data[0] = bias;
        g.add("fc", LayerKind::Dense { w, b }, vec![f]);
        g
    }

    #[test]
    fn crafted_affine_overflow_is_rejected() {
        let g = deploy_pipeline(&overflow_graph(1.0e7));
        let inputs = random_inputs(4, 4, 42)
            .into_iter()
            .map(|v| v.into_iter().map(|x| x * 0.01).collect::<Vec<f32>>())
            .collect::<Vec<_>>();
        let aq = quantize_affine(&g, &calib(&g, &inputs));
        let err = crate::analysis::analyze_affine(&aq).unwrap_err();
        assert!(
            err.reason.contains("requantize cast"),
            "wrong rejection reason: {err}"
        );
    }

    #[test]
    fn crafted_fixed_overflow_is_rejected() {
        let g = deploy_pipeline(&overflow_graph(1.0e16));
        let inputs = random_inputs(4, 4, 43);
        let qg = quantize(&g, &calib(&g, &inputs), QuantSpec::int16_per_layer());
        let err = crate::analysis::analyze_fixed(&qg).unwrap_err();
        assert!(
            err.reason.contains("i64"),
            "wrong rejection reason: {err}"
        );
    }

    #[test]
    fn report_renders_lanes_and_checks() {
        let g = randomized_transformer(23);
        let inputs = token_inputs(3, 12, 24);
        let qg = quantize(&g, &calib(&g, &inputs), QuantSpec::int8_per_layer());
        let facts = crate::analysis::analyze_fixed(&qg).unwrap();
        let report = facts.render_report();
        assert!(report.contains("VerifiedFacts (fixed-qmn)"));
        assert!(report.contains("exp-lut idx<="));
        assert!(report.contains("norm-shift in ["));
        assert!(report.contains("proj q/k/v/o"));
        assert!(facts.nodes.iter().any(|n| n.lane.is_some()));
    }
}
