//! Byte-exact liveness analysis over the graph IR (DESIGN.md §12).
//!
//! The executors run nodes in topological order (node ids ARE the
//! schedule), so every buffer's lifetime is a closed interval of node
//! ids: it is *born* when its producer writes it and *dies* at its last
//! reader. This module computes those intervals exactly — no
//! approximation lattice is needed because the schedule is total — and
//! states the companion facts the memory planner consumes:
//!
//! - **Activation ranges**: node `i`'s output is live over
//!   `[i, last_use(i)]` inclusive. The graph output is read by the
//!   caller after the last node, so its death is `usize::MAX`. The
//!   Input node's payload lives in the caller's buffer for as long as
//!   any node reads it; it never occupies planner-managed memory.
//! - **Attention stage windows**: a `SelfAttention` node stages its
//!   q/k/v/context projections in four scratch buffers of `seq × d_model`
//!   elements each. They are born and die inside the node's own
//!   execution — the point interval `[n, n]` — which is exactly why the
//!   planner may overlap them with any buffer NOT live at `n`.
//! - **GEMM/im2col scratch**: host-side packing panels are live only
//!   inside one node's execution and are sized by the worst node
//!   (`nn::gemm::scratch_elems`), one slab per intra-op thread. They
//!   stay host-only facts (the generated C runs loop-nest kernels, not
//!   the packed GEMM), carried here so the planner/report can account
//!   them without re-deriving.
//! - **`max_batch` staging slabs**: a batch-capable host arena scales
//!   every activation slot by `max_batch` and adds one `max(node_elems)`
//!   staging buffer for unfoldable layers (DESIGN.md §11). Scaling a
//!   whole layout uniformly preserves every disjointness fact, so the
//!   planner plans single-example element offsets and the arena
//!   multiplies; `staging_elems` reports the slab for completeness.
//!
//! Overlap rule: intervals `[b1, d1]` and `[b2, d2]` conflict iff
//! `b1 <= d2 && b2 <= d1` (saturating at `usize::MAX`). The INCLUSIVE
//! comparison is load-bearing: a consumer born at its producer's death
//! node reads the producer *while* writing itself, so same-address
//! placement is only sound for the planner's explicitly sanctioned
//! in-place pairs (`allocator::planner`), never by interval accident.

use crate::graph::ir::{Graph, LayerKind};

/// Closed live interval of one node's output buffer, in schedule
/// (node-id) coordinates, plus its single-example element count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LiveRange {
    /// Producing node id (== position in the topological schedule).
    pub node: usize,
    /// First schedule point at which the buffer holds the payload.
    pub birth: usize,
    /// Last schedule point that reads the buffer (`usize::MAX` for the
    /// graph output, which the caller reads after every node).
    pub death: usize,
    /// Payload elements for ONE example (batched arenas scale by
    /// `max_batch`; dtype width multiplies at pricing time).
    pub elems: usize,
    /// Whether this is the caller-owned Input buffer (never planned).
    pub caller_owned: bool,
}

impl LiveRange {
    /// Inclusive interval overlap (see module docs for why inclusive).
    #[inline]
    pub fn overlaps(&self, other: &LiveRange) -> bool {
        self.birth <= other.death && other.birth <= self.death
    }
}

/// Exact liveness facts for one graph under its topological schedule.
#[derive(Clone, Debug)]
pub struct Liveness {
    /// Per-node live range, indexed by node id.
    pub ranges: Vec<LiveRange>,
    /// Per-node attention stage-window size: `Some(seq * d_model)` (the
    /// size of EACH of the four q/k/v/ctx windows, all live exactly at
    /// `[node, node]`) for `SelfAttention` nodes, `None` otherwise.
    pub attn_window_elems: Vec<Option<usize>>,
    /// Host-side GEMM/im2col packing scratch (elements per intra-op
    /// thread), live only within a single node's execution.
    pub gemm_scratch_elems: usize,
    /// Host-side staging slab for unfoldable layers in batched runs:
    /// `max(node_elems)` elements per example (DESIGN.md §11).
    pub staging_elems: usize,
}

impl Liveness {
    /// Number of scheduled nodes.
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }
}

/// Last node (in topological order) that reads each node's output; the
/// graph output is read by the caller after everything (`usize::MAX`).
/// A node nobody reads dies the moment it is written (its own id).
pub fn last_use(graph: &Graph) -> Vec<usize> {
    let mut last: Vec<usize> = (0..graph.nodes.len()).collect();
    for node in &graph.nodes {
        for &i in &node.inputs {
            last[i] = last[i].max(node.id);
        }
    }
    last[graph.output_id()] = usize::MAX;
    last
}

/// Compute the exact per-node live intervals for `graph`.
pub fn analyze(graph: &Graph) -> Liveness {
    let last = last_use(graph);
    let mut ranges = Vec::with_capacity(graph.nodes.len());
    let mut attn_window_elems = vec![None; graph.nodes.len()];
    let mut staging_elems = 0usize;
    for node in &graph.nodes {
        let elems: usize = node.out_shape.iter().product();
        let caller_owned = matches!(node.kind, LayerKind::Input);
        ranges.push(LiveRange {
            node: node.id,
            birth: node.id,
            death: last[node.id],
            elems,
            caller_owned,
        });
        if !caller_owned {
            staging_elems = staging_elems.max(elems);
        }
        if let LayerKind::SelfAttention { heads, head_dim, .. } = &node.kind {
            let seq = node.out_shape[0];
            attn_window_elems[node.id] = Some(seq * heads * head_dim);
        }
    }
    Liveness {
        ranges,
        attn_window_elems,
        gemm_scratch_elems: crate::nn::gemm::scratch_elems(graph),
        staging_elems,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::{cnn, resnet_v1_6_shapes, transformer};
    use crate::graph::deploy_pipeline;

    #[test]
    fn chain_intervals_tile_the_schedule() {
        let g = cnn("lc", 1, &[64, 4], 5, &[8, 8], 3, 16);
        let lv = analyze(&g);
        assert_eq!(lv.len(), g.nodes.len());
        for r in &lv.ranges {
            assert!(r.birth <= r.death, "inverted interval on node {}", r.node);
        }
        // In a pure chain every node is read exactly by its successor.
        for node in &g.nodes {
            for &i in &node.inputs {
                assert!(
                    lv.ranges[i].death >= node.id,
                    "read of {} at {} lands outside its live range",
                    i,
                    node.id
                );
            }
        }
        assert_eq!(lv.ranges[g.output_id()].death, usize::MAX);
    }

    #[test]
    fn residual_tap_outlives_block_body() {
        // The resnet skip connection keeps the tap alive until the Add.
        let g = deploy_pipeline(&resnet_v1_6_shapes("lr", 1, &[128, 9], 6, 16));
        let lv = analyze(&g);
        let add = g
            .nodes
            .iter()
            .find(|n| matches!(n.kind, LayerKind::Add))
            .expect("resnet has a residual Add");
        let tap = *add.inputs.iter().min().unwrap();
        assert!(lv.ranges[tap].death >= add.id);
        // The tap's interval must overlap every body node in between.
        for id in tap + 1..add.id {
            assert!(lv.ranges[tap].overlaps(&lv.ranges[id]));
        }
    }

    #[test]
    fn attention_windows_are_point_intervals() {
        let g = deploy_pipeline(&transformer("lt", 12, 20, 16, 2, 2, 2, 5));
        let lv = analyze(&g);
        let mut seen = 0;
        for node in &g.nodes {
            match &node.kind {
                LayerKind::SelfAttention { heads, head_dim, .. } => {
                    let sd = node.out_shape[0] * heads * head_dim;
                    assert_eq!(lv.attn_window_elems[node.id], Some(sd));
                    seen += 1;
                }
                _ => assert_eq!(lv.attn_window_elems[node.id], None),
            }
        }
        assert!(seen >= 2, "fixture should carry attention nodes");
        assert!(lv.gemm_scratch_elems > 0);
        assert!(lv.staging_elems > 0);
    }

    #[test]
    fn overlap_is_inclusive_and_symmetric() {
        let mk = |b, d| LiveRange { node: 0, birth: b, death: d, elems: 1, caller_owned: false };
        // Adjacent producer/consumer intervals DO overlap (read-during-write).
        assert!(mk(1, 3).overlaps(&mk(3, 5)));
        assert!(mk(3, 5).overlaps(&mk(1, 3)));
        assert!(!mk(1, 2).overlaps(&mk(3, 5)));
        // MAX-death (graph output) overlaps everything after its birth.
        assert!(mk(4, usize::MAX).overlaps(&mk(9, 9)));
        assert!(!mk(4, usize::MAX).overlaps(&mk(1, 3)));
    }

    #[test]
    fn input_is_caller_owned() {
        let g = cnn("li", 1, &[64, 4], 5, &[8], 3, 16);
        let lv = analyze(&g);
        assert!(lv.ranges[0].caller_owned);
        assert!(lv.ranges[1..].iter().all(|r| !r.caller_owned));
    }
}
