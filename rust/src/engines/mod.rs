//! Embedded inference engine models: MicroAI (ours), TensorFlow Lite for
//! Microcontrollers and STM32Cube.AI (§5.1, Table 4).
//!
//! Each engine couples (a) a capability descriptor (supported dtypes,
//! quantizer, portability — Table 4), (b) calibrated latency/ROM models per
//! board+dtype (`mcu::cost`), and (c) for the engines we fully implement,
//! the executor that actually runs: MicroAI's Qm.n integer engine
//! (`nn::int_exec`) and the TFLite affine scheme (`nn::affine_exec`).

use std::collections::BTreeMap;

use crate::graph::ir::Graph;
use crate::mcu::board::Board;
use crate::mcu::cost::{energy_uwh, LatencyModel, RomModel};
use crate::mcu::paper_data::{self, DType};

/// Quantized-coding style (Table 4 row "Quantized coding").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Coding {
    /// Power-of-two scale, symmetric (MicroAI Qm.n).
    FixedQmn,
    /// Offset + non-power-of-two scale (TFLite/Cube.AI affine).
    OffsetScale,
}

/// Capability descriptor (Table 4).
#[derive(Clone, Debug)]
pub struct Capabilities {
    pub sources: &'static [&'static str],
    pub validation: &'static str,
    pub metrics: &'static str,
    pub portability: &'static str,
    pub open_source: bool,
    pub dtypes: &'static [DType],
    pub coding: Coding,
    /// Deploys as generated code (true) or interpreted microcode (false) —
    /// §5.1.1 vs §5.7.
    pub compiled: bool,
}

pub struct Engine {
    pub name: &'static str,
    pub caps: Capabilities,
    /// (board name, dtype) -> calibrated models.
    latency: BTreeMap<(String, DTypeKey), LatencyModel>,
    rom: BTreeMap<DTypeKey, RomModel>,
}

type DTypeKey = &'static str;

fn key(d: DType) -> DTypeKey {
    d.label()
}

impl Engine {
    fn calibrated(name: &'static str, caps: Capabilities) -> Engine {
        let mut latency = BTreeMap::new();
        let mut rom = BTreeMap::new();
        for s in &paper_data::TABLE_A4_MS {
            if s.framework == name {
                let board = Board::by_name(s.board).unwrap();
                latency.insert(
                    (s.board.to_string(), key(s.dtype)),
                    LatencyModel::calibrate(s, board),
                );
            }
        }
        for s in &paper_data::TABLE_A3_KIB {
            if s.framework == name {
                rom.entry(key(s.dtype)).or_insert_with(|| RomModel::calibrate(s));
            }
        }
        Engine { name, caps, latency, rom }
    }

    pub fn supports(&self, dtype: DType) -> bool {
        self.caps.dtypes.contains(&dtype)
    }

    pub fn supports_board(&self, board: &Board) -> bool {
        match self.name {
            // STM32Cube.AI only targets STM32 parts (§5.1.2).
            "STM32Cube.AI" => board.mcu.starts_with("STM32"),
            _ => true,
        }
    }

    /// Predicted one-input latency (s). Falls back to the nearest
    /// calibrated board when this engine was never measured on `board`.
    pub fn latency_s(&self, graph: &Graph, board: &Board, dtype: DType) -> Option<f64> {
        if !self.supports(dtype) || !self.supports_board(board) {
            return None;
        }
        let model = self
            .latency
            .get(&(board.name.to_string(), key(dtype)))
            .or_else(|| {
                self.latency
                    .iter()
                    .find(|((_, d), _)| *d == key(dtype))
                    .map(|(_, m)| m)
            })?;
        Some(model.latency_s(graph, board))
    }

    /// Predicted ROM footprint (bytes).
    pub fn rom_bytes(&self, graph: &Graph, filters: usize, dtype: DType) -> Option<f64> {
        if !self.supports(dtype) {
            return None;
        }
        self.rom.get(&key(dtype)).map(|m| m.rom_bytes(graph, filters))
    }

    /// Predicted energy per inference (µWh).
    pub fn energy_uwh(&self, graph: &Graph, board: &Board, dtype: DType) -> Option<f64> {
        self.latency_s(graph, board, dtype).map(|t| energy_uwh(t, board))
    }
}

pub fn microai() -> Engine {
    Engine::calibrated(
        "MicroAI",
        Capabilities {
            sources: &["Keras", "PyTorch"],
            validation: "Integrated tools",
            metrics: "ROM footprint, inference time",
            portability: "Any 32-bit MCU",
            open_source: true,
            dtypes: &[DType::F32, DType::I16, DType::I8],
            coding: Coding::FixedQmn,
            compiled: true,
        },
    )
}

pub fn tflite_micro() -> Engine {
    Engine::calibrated(
        "TFLiteMicro",
        Capabilities {
            sources: &["Keras", "TFLite"],
            validation: "None",
            metrics: "None",
            portability: "Any 32-bit MCU",
            open_source: true,
            dtypes: &[DType::F32, DType::I8],
            coding: Coding::OffsetScale,
            compiled: false, // interpreted microcode, §5.1.1
        },
    )
}

pub fn stm32cube_ai() -> Engine {
    Engine::calibrated(
        "STM32Cube.AI",
        Capabilities {
            sources: &["Keras", "TFLite"],
            validation: "Integrated tools",
            metrics: "RAM/ROM footprint, inference time, MACC",
            portability: "STM32 only",
            open_source: false,
            dtypes: &[DType::F32, DType::I8],
            coding: Coding::OffsetScale,
            compiled: true,
        },
    )
}

pub fn all_engines() -> Vec<Engine> {
    vec![microai(), tflite_micro(), stm32cube_ai()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::board::{NUCLEO_L452RE_P, SPARKFUN_EDGE};
    use crate::mcu::cost::har_graph;

    #[test]
    fn capability_matrix_table4() {
        let m = microai();
        assert!(m.supports(DType::I16)); // the paper's differentiator
        let t = tflite_micro();
        assert!(!t.supports(DType::I16));
        let c = stm32cube_ai();
        assert!(!c.supports(DType::I16));
        assert!(!c.caps.open_source);
        assert_eq!(m.caps.coding, Coding::FixedQmn);
        assert_eq!(t.caps.coding, Coding::OffsetScale);
    }

    #[test]
    fn cube_ai_refuses_non_stm32() {
        let c = stm32cube_ai();
        let g = har_graph(16);
        assert!(c.latency_s(&g, &SPARKFUN_EDGE, DType::I8).is_none());
        assert!(c.latency_s(&g, &NUCLEO_L452RE_P, DType::I8).is_some());
    }

    #[test]
    fn fig12_orderings_at_80_filters() {
        // Fig 12: CubeAI int8 fastest; TFLM float slowest.
        let g = har_graph(80);
        let cube = stm32cube_ai().latency_s(&g, &NUCLEO_L452RE_P, DType::I8).unwrap();
        let tflm_f = tflite_micro().latency_s(&g, &SPARKFUN_EDGE, DType::F32).unwrap();
        let micro8 = microai().latency_s(&g, &NUCLEO_L452RE_P, DType::I8).unwrap();
        assert!(cube < micro8);
        assert!(micro8 < tflm_f);
        // Paper headline: 352 ms vs 1034 ms vs 2087 ms.
        assert!((cube * 1e3 - 352.0).abs() < 5.0, "{}", cube * 1e3);
        assert!((tflm_f * 1e3 - 2087.0).abs() < 25.0, "{}", tflm_f * 1e3);
    }

    #[test]
    fn fig13_sparkfun_most_efficient() {
        // Fig 13 conclusion: "the SparkFun Edge board provides the best
        // power efficiency in all situations".
        let g = har_graph(80);
        let m = microai();
        for dt in [DType::F32, DType::I16, DType::I8] {
            let sf = m.energy_uwh(&g, &SPARKFUN_EDGE, dt).unwrap();
            let nu = m.energy_uwh(&g, &NUCLEO_L452RE_P, dt).unwrap();
            assert!(sf < nu, "{dt:?}: {sf} vs {nu}");
        }
    }

    #[test]
    fn fig11_rom_per_dtype_ordering() {
        // Fig 11: int8 < int16 < float32 ROM for MicroAI.
        let g = har_graph(80);
        let m = microai();
        let r8 = m.rom_bytes(&g, 80, DType::I8).unwrap();
        let r16 = m.rom_bytes(&g, 80, DType::I16).unwrap();
        let rf = m.rom_bytes(&g, 80, DType::F32).unwrap();
        assert!(r8 < r16 && r16 < rf);
        // TFLM carries a much larger runtime at small models.
        let t8 = tflite_micro().rom_bytes(&har_graph(16), 16, DType::I8).unwrap();
        let m8 = m.rom_bytes(&har_graph(16), 16, DType::I8).unwrap();
        assert!(t8 > 2.0 * m8, "TFLM {t8} vs MicroAI {m8}");
    }

    #[test]
    fn int16_beats_float_always_for_microai() {
        // §7: "fixed-point quantization on 16-bit integers can therefore
        // always be preferred to a 32-bit floating-point inference".
        let m = microai();
        for f in crate::mcu::paper_data::FILTERS {
            let g = har_graph(f);
            for b in [&NUCLEO_L452RE_P, &SPARKFUN_EDGE] {
                let t16 = m.latency_s(&g, b, DType::I16).unwrap();
                let tf = m.latency_s(&g, b, DType::F32).unwrap();
                assert!(t16 < tf, "f={f} board={}", b.name);
                let r16 = m.rom_bytes(&g, f, DType::I16).unwrap();
                let rf = m.rom_bytes(&g, f, DType::F32).unwrap();
                assert!(r16 < rf);
            }
        }
    }
}
