//! TFLite-style affine int8 quantization (the comparison scheme of
//! Appendix B / §7): per-tensor ASYMMETRIC activations (scale + zero
//! point), per-filter SYMMETRIC weights, int32 biases, and gemmlowp-style
//! integer requantization (rounding doubling high-mul + rounding shift).
//!
//! This is a faithful re-implementation of the TFLite 8-bit quantization
//! spec referenced by the paper [42, 43], used both as the Appendix B
//! baseline and to model the STM32Cube.AI engine (which reuses TFLite's
//! quantizer).

use std::collections::BTreeMap;

use crate::fixedpoint::QFormat;
use crate::graph::ir::{Graph, LayerKind};
use crate::nn::float_exec::{ActStats, ATTN_CTX, ATTN_K, ATTN_Q, ATTN_S, ATTN_V};

/// Per-tensor activation quantization: real = scale * (q - zero_point).
#[derive(Clone, Copy, Debug)]
pub struct AffineParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl AffineParams {
    /// TFLite rule for int8: nudge so that 0.0 is exactly representable.
    pub fn from_range(min: f32, max: f32) -> Self {
        let min = min.min(0.0);
        let max = max.max(0.0);
        let scale = if max > min { (max - min) / 255.0 } else { 1.0 };
        let zp_real = -128.0 - min / scale;
        let zero_point = zp_real.round().clamp(-128.0, 127.0) as i32;
        Self { scale, zero_point }
    }

    #[inline(always)]
    pub fn quantize(&self, x: f32) -> i32 {
        ((x / self.scale).round() as i32 + self.zero_point).clamp(-128, 127)
    }

    #[inline(always)]
    pub fn dequantize(&self, q: i32) -> f32 {
        (q - self.zero_point) as f32 * self.scale
    }
}

/// gemmlowp: SaturatingRoundingDoublingHighMul.
#[inline(always)]
pub fn srdhm(a: i32, b: i32) -> i32 {
    if a == i32::MIN && b == i32::MIN {
        return i32::MAX;
    }
    let ab = (a as i64) * (b as i64);
    let nudge = if ab >= 0 { 1i64 << 30 } else { 1 - (1i64 << 30) };
    ((ab + nudge) >> 31) as i32
}

/// gemmlowp: RoundingDivideByPOT (round-half-away from zero).
#[inline(always)]
pub fn rounding_divide_by_pot(x: i32, exponent: i32) -> i32 {
    assert!((0..=31).contains(&exponent));
    if exponent == 0 {
        return x;
    }
    let mask = (1i64 << exponent) - 1;
    let remainder = (x as i64) & mask;
    let threshold = (mask >> 1) + i64::from(x < 0);
    ((x as i64 >> exponent) + i64::from(remainder > threshold)) as i32
}

/// Decompose a real multiplier in (0, 1) as (int32 Q31 mantissa, right
/// shift): M ≈ M0 * 2^-shift with M0 in [2^30, 2^31).
pub fn quantize_multiplier(m: f64) -> (i32, i32) {
    assert!(m > 0.0 && m < 1.0, "multiplier {m} out of (0,1)");
    let mut shift = 0;
    let mut q = m;
    while q < 0.5 {
        q *= 2.0;
        shift += 1;
    }
    let mut mantissa = (q * (1i64 << 31) as f64).round() as i64;
    if mantissa == 1i64 << 31 {
        mantissa /= 2;
        shift -= 1;
    }
    (mantissa as i32, shift)
}

/// Apply the full requantization: acc (int32) -> int8 payload.
#[inline(always)]
pub fn requantize(acc: i32, mult: i32, shift: i32, zero_point: i32) -> i32 {
    let x = srdhm(acc, mult);
    let x = rounding_divide_by_pot(x, shift);
    (x + zero_point).clamp(-128, 127)
}

/// Quantized weights of one Conv/Dense node in the affine scheme.
#[derive(Clone, Debug)]
pub struct AffineNodeWeights {
    pub w: Vec<i32>,
    /// Per-filter symmetric weight scales.
    pub w_scale: Vec<f32>,
    /// int32 biases at scale s_in * s_w[f].
    pub b: Vec<i64>,
    /// Requantization multiplier/shift per filter: s_in*s_w[f]/s_out.
    pub mult: Vec<i32>,
    pub shift: Vec<i32>,
}

/// Fixed output params of every softmax (node-level or attention-internal
/// probability rows): real p = (q + 128) / 256, the TFLite convention.
pub fn prob_params() -> AffineParams {
    AffineParams { scale: 1.0 / 256.0, zero_point: -128 }
}

/// Transformer-op parameters in the affine scheme.
#[derive(Clone, Debug)]
pub enum AffineTxWeights {
    /// Table payloads at the node's activation params (a gather's output
    /// payloads ARE table payloads).
    Embed { table: Vec<i32> },
    /// LayerNorm: the normalized rows are scale-free (zero points cancel
    /// in the mean subtraction), so gamma is folded with 1/s_out into a
    /// Qm.n payload `gamma * 2^g_n / s_out` and beta becomes an integer
    /// offset in output quanta.
    Norm { gamma: Vec<i32>, g_n: i32, beta: Vec<i64> },
    /// SelfAttention: per-tensor symmetric projection weights plus affine
    /// params for every internal tensor and the gemmlowp requantization
    /// multipliers between the stages.
    Attn {
        wq: AffineNodeWeights,
        wk: AffineNodeWeights,
        wv: AffineNodeWeights,
        wo: AffineNodeWeights,
        q: AffineParams,
        k: AffineParams,
        v: AffineParams,
        s: AffineParams,
        ctx: AffineParams,
        /// Scores: s_q * s_k / (sqrt(hd) * s_s) as (mantissa, shift).
        s_mult: i32,
        s_shift: i32,
        /// Context: s_p * s_v / s_ctx (s_p = 1/256).
        c_mult: i32,
        c_shift: i32,
        /// Decomposition of s_s itself, used to turn integer score
        /// distances into the exp LUT's Q0.15 argument.
        sm_mult: i32,
        sm_shift: i32,
    },
}

#[derive(Clone, Debug)]
pub struct AffineQuantizedGraph {
    pub graph: Graph,
    pub act: Vec<AffineParams>,
    pub weights: BTreeMap<usize, AffineNodeWeights>,
    /// Transformer-op parameters (Embedding / LayerNorm / SelfAttention).
    pub tx: BTreeMap<usize, AffineTxWeights>,
}

fn passthrough(kind: &LayerKind) -> bool {
    matches!(
        kind,
        LayerKind::MaxPool { .. }
            | LayerKind::ReLU
            | LayerKind::Flatten
            | LayerKind::ZeroPad { .. }
            | LayerKind::GlobalAvgPool
            | LayerKind::AvgPool { .. }
    )
}

/// True when `id` is consumed by an Embedding node (integer token ids:
/// identity quantization).
fn feeds_embedding(graph: &Graph, id: usize) -> bool {
    graph
        .nodes
        .iter()
        .any(|n| matches!(n.kind, LayerKind::Embedding { .. }) && n.inputs.contains(&id))
}

/// Clamp a real multiplier into gemmlowp's (0, 1) domain and decompose.
/// Shared with the executor, which decomposes the input scale of a
/// node-level Softmax at dispatch time (attention-internal softmaxes get
/// their decomposition from the quantizer's `Attn` params).
pub fn decompose(m: f64) -> (i32, i32) {
    quantize_multiplier(m.clamp(1e-9, 0.999_999_999))
}

/// Quantize a calibrated graph into the affine scheme.
pub fn quantize_affine(graph: &Graph, stats: &ActStats) -> AffineQuantizedGraph {
    let mut act: Vec<AffineParams> = Vec::with_capacity(graph.nodes.len());
    for node in &graph.nodes {
        let p = match &node.kind {
            // Token ids quantize as identity: payload == id.
            LayerKind::Input if feeds_embedding(graph, node.id) => {
                AffineParams { scale: 1.0, zero_point: 0 }
            }
            LayerKind::Embedding { w } => {
                let (lo, hi) = w
                    .data
                    .iter()
                    .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
                AffineParams::from_range(lo, hi)
            }
            LayerKind::Softmax => prob_params(),
            kind if passthrough(kind) => act[node.inputs[0]],
            _ => AffineParams::from_range(stats.min[node.id], stats.max[node.id]),
        };
        act.push(p);
    }

    let mut weights = BTreeMap::new();
    for node in &graph.nodes {
        let (w, b, filters) = match &node.kind {
            LayerKind::Conv { w, b, .. } => (w, b, *w.shape.last().unwrap()),
            LayerKind::Dense { w, b } => (w, b, w.shape[1]),
            _ => continue,
        };
        let s_in = act[node.inputs[0]].scale;
        let s_out = act[node.id].scale;
        let per_filter = w.len() / filters;
        let mut w_scale = Vec::with_capacity(filters);
        let mut payload = vec![0i32; w.len()];
        let mut bias = Vec::with_capacity(filters);
        let mut mult = Vec::with_capacity(filters);
        let mut shift = Vec::with_capacity(filters);
        for f in 0..filters {
            let mut max_abs = 0.0f32;
            for e in 0..per_filter {
                max_abs = max_abs.max(w.data[e * filters + f].abs());
            }
            let sw = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
            w_scale.push(sw);
            for e in 0..per_filter {
                payload[e * filters + f] =
                    (w.data[e * filters + f] / sw).round().clamp(-127.0, 127.0) as i32;
            }
            bias.push((b.data[f] as f64 / (s_in as f64 * sw as f64)).round() as i64);
            let m = (s_in as f64 * sw as f64) / s_out as f64;
            // Clamp into (0,1): layers with huge scale ratios are clipped
            // (mirrors TFLite's multiplier check).
            let m = m.clamp(1e-9, 0.999_999_999);
            let (m0, sh) = quantize_multiplier(m);
            mult.push(m0);
            shift.push(sh);
        }
        weights.insert(
            node.id,
            AffineNodeWeights { w: payload, w_scale, b: bias, mult, shift },
        );
    }

    let mut tx = BTreeMap::new();
    for node in &graph.nodes {
        match &node.kind {
            LayerKind::Embedding { w } => {
                let p = act[node.id];
                tx.insert(
                    node.id,
                    AffineTxWeights::Embed {
                        table: w.data.iter().map(|&x| p.quantize(x)).collect(),
                    },
                );
            }
            LayerKind::LayerNorm { gamma, beta, .. } => {
                let s_out = act[node.id].scale;
                let folded: Vec<f32> = gamma.iter().map(|&g| g / s_out).collect();
                let gfmt = QFormat::from_slice(&folded, 16);
                tx.insert(
                    node.id,
                    AffineTxWeights::Norm {
                        gamma: gfmt.quantize_slice(&folded),
                        g_n: gfmt.n,
                        beta: beta
                            .iter()
                            .map(|&b| (b as f64 / s_out as f64).round() as i64)
                            .collect(),
                    },
                );
            }
            LayerKind::SelfAttention { head_dim, w, .. } => {
                let s_in = act[node.inputs[0]].scale;
                let st = stats.attn_of(node.id);
                let from = |t: &crate::nn::float_exec::TensorStats| {
                    AffineParams::from_range(t.min, t.max)
                };
                let (q, k, v) = (from(&st[ATTN_Q]), from(&st[ATTN_K]), from(&st[ATTN_V]));
                let (s, ctx) = (from(&st[ATTN_S]), from(&st[ATTN_CTX]));
                let p = prob_params();
                let dm = w.wq.shape[1];
                let (s_mult, s_shift) = decompose(
                    q.scale as f64 * k.scale as f64
                        / ((*head_dim as f64).sqrt() * s.scale as f64),
                );
                let (c_mult, c_shift) =
                    decompose(p.scale as f64 * v.scale as f64 / ctx.scale as f64);
                let (sm_mult, sm_shift) = decompose(s.scale as f64);
                tx.insert(
                    node.id,
                    AffineTxWeights::Attn {
                        wq: quantize_proj_affine(&w.wq.data, &w.bq.data, dm, s_in, q.scale),
                        wk: quantize_proj_affine(&w.wk.data, &w.bk.data, dm, s_in, k.scale),
                        wv: quantize_proj_affine(&w.wv.data, &w.bv.data, dm, s_in, v.scale),
                        wo: quantize_proj_affine(
                            &w.wo.data, &w.bo.data, dm, ctx.scale, act[node.id].scale,
                        ),
                        q,
                        k,
                        v,
                        s,
                        ctx,
                        s_mult,
                        s_shift,
                        c_mult,
                        c_shift,
                        sm_mult,
                        sm_shift,
                    },
                );
            }
            _ => {}
        }
    }
    AffineQuantizedGraph { graph: graph.clone(), act, weights, tx }
}

/// Quantize one attention projection: per-tensor symmetric weights (a
/// single scale — the fused attention epilogue applies one multiplier per
/// projection), int32-style bias at s_in * s_w, and the gemmlowp
/// requantization multiplier onto the projection's own output params.
fn quantize_proj_affine(
    w: &[f32],
    b: &[f32],
    filters: usize,
    s_in: f32,
    s_out: f32,
) -> AffineNodeWeights {
    let max_abs = w.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let sw = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
    let payload = w.iter().map(|&x| (x / sw).round().clamp(-127.0, 127.0) as i32).collect();
    let bias = b
        .iter()
        .map(|&x| (x as f64 / (s_in as f64 * sw as f64)).round() as i64)
        .collect();
    let (m0, sh) = decompose(s_in as f64 * sw as f64 / s_out as f64);
    debug_assert_eq!(b.len(), filters);
    // Per-tensor values broadcast to per-filter length: the reference and
    // prepacked kernels index mult/shift by filter, same as conv/dense.
    AffineNodeWeights {
        w: payload,
        w_scale: vec![sw; filters],
        b: bias,
        mult: vec![m0; filters],
        shift: vec![sh; filters],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::check::property;

    #[test]
    fn affine_params_represent_zero_exactly() {
        let p = AffineParams::from_range(-1.3, 2.6);
        let q0 = p.quantize(0.0);
        assert!((p.dequantize(q0)).abs() < 1e-6);
    }

    #[test]
    fn affine_roundtrip_error_bounded() {
        property(200, |g| {
            let lo = g.f32_in(-10.0, 0.0);
            let hi = g.f32_in(0.0, 10.0);
            let p = AffineParams::from_range(lo, hi);
            for _ in 0..32 {
                let x = g.f32_in(lo, hi);
                let rt = p.dequantize(p.quantize(x));
                prop_assert!(
                    (rt - x).abs() <= p.scale * 0.51 + 1e-6,
                    "x={x} rt={rt} scale={}",
                    p.scale
                );
            }
            Ok(())
        });
    }

    #[test]
    fn srdhm_matches_reference_values() {
        // Known gemmlowp identities.
        assert_eq!(srdhm(i32::MIN, i32::MIN), i32::MAX);
        assert_eq!(srdhm(1 << 30, 1 << 30), 1 << 29);
        assert_eq!(srdhm(0, 12345), 0);
    }

    #[test]
    fn rounding_divide_rounds_half_away() {
        assert_eq!(rounding_divide_by_pot(5, 1), 3); // 2.5 -> 3
        assert_eq!(rounding_divide_by_pot(-5, 1), -3); // -2.5 -> -3 (away)
        assert_eq!(rounding_divide_by_pot(4, 1), 2);
        assert_eq!(rounding_divide_by_pot(7, 2), 2); // 1.75 -> 2
    }

    #[test]
    fn quantize_multiplier_reconstructs() {
        property(200, |g| {
            let m = g.f32_in(1e-6, 0.999) as f64;
            let (m0, shift) = quantize_multiplier(m);
            let recon = m0 as f64 / (1i64 << 31) as f64 / f64::powi(2.0, shift);
            prop_assert!(
                (recon - m).abs() / m < 1e-6,
                "m={m} recon={recon} m0={m0} shift={shift}"
            );
            Ok(())
        });
    }

    #[test]
    fn requantize_approximates_real_arithmetic() {
        property(300, |g| {
            let m = g.f32_in(1e-4, 0.9) as f64;
            let (m0, sh) = quantize_multiplier(m);
            let acc = g.i32_in(-100_000, 100_000);
            let got = requantize(acc, m0, sh, 0);
            let want = (acc as f64 * m).round().clamp(-128.0, 127.0) as i32;
            prop_assert!(
                (got - want).abs() <= 1,
                "acc={acc} m={m} got={got} want={want}"
            );
            Ok(())
        });
    }
}
