//! Quantization scheme descriptors (§4.1.2–§4.1.3).

use crate::fixedpoint::QFormat;

/// Scale-factor granularity (§4.1.3). The paper's released implementation
/// supports per-network and per-layer; per-filter is the extension the
/// discussion (§7) identifies as required to match TFLite — implemented
/// here for both the Qm.n and affine schemes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    PerNetwork,
    PerLayer,
    PerFilter,
}

/// Post-training quantization configuration for the Qm.n scheme.
#[derive(Clone, Copy, Debug)]
pub struct QuantSpec {
    /// Payload width in bits: 8, 9 (Appendix B) or 16.
    pub width: u32,
    pub granularity: Granularity,
    /// Force a single network-wide format (the paper's int16 mode uses
    /// Q7.9 for the whole network, §6). When set, calibration is skipped
    /// for format selection.
    pub fixed_format: Option<QFormat>,
}

impl QuantSpec {
    /// The paper's int16 deployment: Q7.9 across the network.
    pub fn int16_q7_9() -> Self {
        Self { width: 16, granularity: Granularity::PerNetwork, fixed_format: Some(QFormat::q7_9()) }
    }

    /// int16 with per-layer calibrated formats.
    pub fn int16_per_layer() -> Self {
        Self { width: 16, granularity: Granularity::PerLayer, fixed_format: None }
    }

    /// int8 per-layer PTQ (the baseline the paper's QAT improves on).
    pub fn int8_per_layer() -> Self {
        Self { width: 8, granularity: Granularity::PerLayer, fixed_format: None }
    }

    /// int9 per-layer PTQ (Appendix B: beats TFLite's int8 PTQ).
    pub fn int9_per_layer() -> Self {
        Self { width: 9, granularity: Granularity::PerLayer, fixed_format: None }
    }

    /// int8 with per-filter weight formats (§7 extension).
    pub fn int8_per_filter() -> Self {
        Self { width: 8, granularity: Granularity::PerFilter, fixed_format: None }
    }

    pub fn label(&self) -> String {
        let g = match self.granularity {
            Granularity::PerNetwork => "net",
            Granularity::PerLayer => "layer",
            Granularity::PerFilter => "filter",
        };
        match self.fixed_format {
            // Paper Q-notation: m includes the sign bit, m + n = width (§3.2).
            Some(q) => format!("int{}-Q{}.{}", self.width, self.width as i32 - q.n, q.n),
            None => format!("int{}-per-{}", self.width, g),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(QuantSpec::int16_q7_9().label(), "int16-Q7.9");
        assert_eq!(QuantSpec::int8_per_layer().label(), "int8-per-layer");
        assert_eq!(QuantSpec::int9_per_layer().label(), "int9-per-layer");
        assert_eq!(QuantSpec::int8_per_filter().label(), "int8-per-filter");
    }

    #[test]
    fn q7_9_format() {
        let s = QuantSpec::int16_q7_9();
        let f = s.fixed_format.unwrap();
        assert_eq!(f.width, 16);
        assert_eq!(f.n, 9);
    }
}
