//! Quantization (§4, §5.8): the Qm.n post-training quantizer (per-network /
//! per-layer / per-filter, 8/9/16-bit) and the TFLite-style affine scheme
//! used as the Appendix B comparison baseline.

pub mod affine;
pub mod ptq;
pub mod scheme;

pub use affine::{quantize_affine, AffineQuantizedGraph, AffineTxWeights};
pub use ptq::{quantize, QTxWeights, QuantizedGraph};
pub use scheme::{Granularity, QuantSpec};
