//! Post-training quantization for the Qm.n scheme (§4.2, §5.8).
//!
//! Produces a [`QuantizedGraph`]: integer weight payloads, per-filter or
//! per-layer weight formats, biases pre-converted to the accumulator scale,
//! and per-node activation formats derived from calibration statistics
//! (or a fixed network-wide format such as Q7.9).

use std::collections::BTreeMap;

use crate::fixedpoint::QFormat;
use crate::graph::ir::{Graph, LayerKind};
use crate::nn::float_exec::{ActStats, TensorStats, ATTN_CTX, ATTN_K, ATTN_Q, ATTN_S, ATTN_V};

use super::scheme::{Granularity, QuantSpec};

/// Quantized weights of one Conv/Dense node.
#[derive(Clone, Debug)]
pub struct QNodeWeights {
    /// Integer payloads, same layout as the float tensor.
    pub w: Vec<i32>,
    /// Fractional bits of the weight format; len == 1 (per-layer/network)
    /// or == filters (per-filter).
    pub w_n: Vec<i32>,
    /// Bias in the ACCUMULATOR scale: b_acc[f] = round(b * 2^(n_in + n_w[f])),
    /// round-to-nearest (ties away from zero). Unlike weight/activation
    /// payloads, which keep the paper's Eq 3 truncation (pinned by the
    /// Python quant-math contract), the bias is converted ONCE at deploy
    /// time into the wide i64 accumulator — truncating here added a
    /// systematic toward-zero offset to every accumulator with nothing to
    /// cancel it. The generated model.c ships these exact integers
    /// (`codegen::join_i64`), so Rust and C stay bit-exact either way.
    pub b_acc: Vec<i64>,
    /// Output rescale shift per filter: n_in + n_w[f] - n_out.
    pub shift: Vec<i32>,
}

impl QNodeWeights {
    #[inline(always)]
    pub fn w_n_for(&self, filter: usize) -> i32 {
        if self.w_n.len() == 1 {
            self.w_n[0]
        } else {
            self.w_n[filter]
        }
    }

    #[inline(always)]
    pub fn shift_for(&self, filter: usize) -> i32 {
        if self.shift.len() == 1 {
            self.shift[0]
        } else {
            self.shift[filter]
        }
    }
}

/// Quantized parameters of the transformer ops. A separate map from
/// `weights` keeps the Conv/Dense contract (payload layout, per-filter
/// formats, packed-panel consumers) untouched.
#[derive(Clone, Debug)]
pub enum QTxWeights {
    /// Embedding table rows quantized directly at the node's activation
    /// format: a gather IS the output, so table payloads and output
    /// payloads coincide.
    Embed { table: Vec<i32> },
    /// LayerNorm: gamma at its own per-tensor format `g_n`, beta directly
    /// at the node's output format (it adds post-normalization).
    Norm { gamma: Vec<i32>, g_n: i32, beta: Vec<i32> },
    /// SelfAttention: the four projections quantized dense-style
    /// (per-layer weight formats; each shift lands on the calibrated
    /// internal format), the internal activation formats, and the Q0.15
    /// 1/sqrt(head_dim) score multiplier.
    Attn {
        wq: QNodeWeights,
        wk: QNodeWeights,
        wv: QNodeWeights,
        wo: QNodeWeights,
        /// Fractional bits of Q / K / V payloads.
        n_q: i32,
        n_k: i32,
        n_v: i32,
        /// Scaled pre-softmax scores.
        n_s: i32,
        /// Softmax probabilities: always `width - 1` ([0, 1) needs no
        /// integer bits beyond the sign).
        n_p: i32,
        /// Concatenated head context (the Wo projection's input).
        n_ctx: i32,
        /// round(2^15 / sqrt(head_dim)).
        inv_sqrt_hd_q15: i32,
    },
}

/// A graph plus everything the integer engine needs to run it.
#[derive(Clone, Debug)]
pub struct QuantizedGraph {
    pub graph: Graph,
    pub width: u32,
    /// Fractional bits of each node's output activation format.
    pub act_n: Vec<i32>,
    pub weights: BTreeMap<usize, QNodeWeights>,
    /// Transformer-op parameters (Embedding / LayerNorm / SelfAttention).
    pub tx: BTreeMap<usize, QTxWeights>,
    pub spec: QuantSpec,
}

impl QuantizedGraph {
    /// Input scale factor (the INPUT_SCALE_FACTOR of the generated model.h).
    pub fn input_n(&self) -> i32 {
        self.act_n[0]
    }

    /// Bytes to store the parameters at this width (ROM contribution):
    /// weight payloads at the payload container width, biases at the
    /// 8-byte accumulator scale — both the Rust engine (`b_acc: Vec<i64>`)
    /// and the generated model.c (`long_number_t b_*[]`) store biases as
    /// i64, so charging them at payload width undercounted ROM.
    pub fn weight_bytes(&self) -> usize {
        let per = self.payload_bytes();
        let conv_dense: usize = self
            .weights
            .values()
            .map(|qw| qw.w.len() * per + qw.b_acc.len() * 8)
            .sum();
        let tx: usize = self
            .tx
            .values()
            .map(|t| match t {
                QTxWeights::Embed { table } => table.len() * per,
                QTxWeights::Norm { gamma, beta, .. } => (gamma.len() + beta.len()) * per,
                QTxWeights::Attn { wq, wk, wv, wo, .. } => [wq, wk, wv, wo]
                    .iter()
                    .map(|qw| qw.w.len() * per + qw.b_acc.len() * 8)
                    .sum(),
            })
            .sum();
        conv_dense + tx
    }

    /// Bytes per weight payload element (the C `number_t`).
    pub fn payload_bytes(&self) -> usize {
        if self.width <= 8 { 1 } else if self.width <= 16 { 2 } else { 4 }
    }
}

/// Nodes whose output format must equal their input's (no requantization:
/// max-pool "can only shrink data", ReLU, reshapes — §4.3). Softmax left
/// this list when it became a real inference-time op (transformer PR): its
/// output is a probability vector with its own fixed format `width - 1`.
fn passthrough(kind: &LayerKind) -> bool {
    matches!(
        kind,
        LayerKind::MaxPool { .. }
            | LayerKind::ReLU
            | LayerKind::Flatten
            | LayerKind::ZeroPad { .. }
    )
}

/// True when `id` is consumed by an Embedding node: its payloads are
/// integer token ids and must stay at n = 0 in every quantization mode.
fn feeds_embedding(graph: &Graph, id: usize) -> bool {
    graph
        .nodes
        .iter()
        .any(|n| matches!(n.kind, LayerKind::Embedding { .. }) && n.inputs.contains(&id))
}

/// Quantize a calibrated float graph.
///
/// `stats` must come from `nn::float_exec::run` over a calibration set on
/// the SAME (deployed) graph. With `spec.fixed_format` set, activation and
/// weight formats are all forced to it (per-network mode).
pub fn quantize(graph: &Graph, stats: &ActStats, spec: QuantSpec) -> QuantizedGraph {
    assert_eq!(stats.max_abs.len(), graph.nodes.len(), "stats/graph mismatch");
    let width = spec.width;

    // --- activation formats ---
    let mut act_n: Vec<i32> = vec![0; graph.nodes.len()];
    for node in &graph.nodes {
        act_n[node.id] = match &node.kind {
            // Token ids are integers; a network-wide Qm.n format would
            // saturate any id >= 2^m, so the embedding input overrides
            // even `fixed_format`.
            LayerKind::Input if feeds_embedding(graph, node.id) => 0,
            // A gather's output payloads ARE table payloads: the node
            // format is the table's format.
            LayerKind::Embedding { w } => match &spec.fixed_format {
                Some(q) => q.n,
                None => QFormat::from_slice(&w.data, width).n,
            },
            // Probabilities live in [0, 1): give them every fractional
            // bit regardless of the calibrated range.
            LayerKind::Softmax => width as i32 - 1,
            kind => match (&spec.fixed_format, passthrough(kind)) {
                (Some(q), _) => q.n,
                (None, true) => act_n[node.inputs[0]],
                (None, false) => {
                    if matches!(kind, LayerKind::GlobalAvgPool | LayerKind::AvgPool { .. }) {
                        // Averaging cannot expand the range; keep the input
                        // format so the engine divides payloads directly.
                        act_n[node.inputs[0]]
                    } else {
                        QFormat::from_max_abs(stats.max_abs[node.id], width).n
                    }
                }
            },
        };
    }

    // --- weights ---
    let mut weights = BTreeMap::new();
    for node in &graph.nodes {
        let (w, b, filters) = match &node.kind {
            LayerKind::Conv { w, b, .. } => (w, b, *w.shape.last().unwrap()),
            LayerKind::Dense { w, b } => (w, b, w.shape[1]),
            _ => continue,
        };
        let n_in = act_n[node.inputs[0]];
        let n_out = act_n[node.id];
        let per_filter = w.len() / filters;

        let (w_n, payload): (Vec<i32>, Vec<i32>) = match (spec.fixed_format, spec.granularity) {
            (Some(q), _) => {
                let fmt = QFormat::new(width, q.n);
                (vec![q.n], w.data.iter().map(|&x| fmt.quantize(x)).collect())
            }
            (None, Granularity::PerFilter) => {
                // Channels-last layout: filter index is the fastest axis.
                let mut ns = Vec::with_capacity(filters);
                let mut payload = vec![0i32; w.len()];
                for f in 0..filters {
                    let mut max_abs = 0.0f32;
                    for e in 0..per_filter {
                        max_abs = max_abs.max(w.data[e * filters + f].abs());
                    }
                    let fmt = QFormat::from_max_abs(max_abs, width);
                    ns.push(fmt.n);
                    for e in 0..per_filter {
                        payload[e * filters + f] = fmt.quantize(w.data[e * filters + f]);
                    }
                }
                (ns, payload)
            }
            (None, _) => {
                let fmt = QFormat::from_slice(&w.data, width);
                (vec![fmt.n], w.data.iter().map(|&x| fmt.quantize(x)).collect())
            }
        };

        let mut b_acc = Vec::with_capacity(filters);
        let mut shift = Vec::with_capacity(w_n.len().max(1));
        for f in 0..filters {
            let n_w = if w_n.len() == 1 { w_n[0] } else { w_n[f] };
            b_acc.push((b.data[f] as f64 * f64::powi(2.0, n_in + n_w)).round() as i64);
        }
        for &n_w in &w_n {
            shift.push(n_in + n_w - n_out);
        }
        weights.insert(node.id, QNodeWeights { w: payload, w_n, b_acc, shift });
    }

    // --- transformer-op parameters ---
    let mut tx = BTreeMap::new();
    for node in &graph.nodes {
        match &node.kind {
            LayerKind::Embedding { w } => {
                let fmt = QFormat::new(width, act_n[node.id]);
                tx.insert(node.id, QTxWeights::Embed { table: fmt.quantize_slice(&w.data) });
            }
            LayerKind::LayerNorm { gamma, beta, .. } => {
                let gfmt = match &spec.fixed_format {
                    Some(q) => QFormat::new(width, q.n),
                    None => QFormat::from_slice(gamma, width),
                };
                let bfmt = QFormat::new(width, act_n[node.id]);
                tx.insert(
                    node.id,
                    QTxWeights::Norm {
                        gamma: gfmt.quantize_slice(gamma),
                        g_n: gfmt.n,
                        beta: bfmt.quantize_slice(beta),
                    },
                );
            }
            LayerKind::SelfAttention { head_dim, w, .. } => {
                let n_in = act_n[node.inputs[0]];
                let n_out = act_n[node.id];
                let st = stats.attn_of(node.id);
                let internal = |t: &TensorStats| match &spec.fixed_format {
                    Some(q) => q.n,
                    None => QFormat::from_max_abs(t.max_abs, width).n,
                };
                let (n_q, n_k, n_v) =
                    (internal(&st[ATTN_Q]), internal(&st[ATTN_K]), internal(&st[ATTN_V]));
                let n_s = internal(&st[ATTN_S]);
                let n_p = width as i32 - 1;
                let n_ctx = internal(&st[ATTN_CTX]);
                tx.insert(
                    node.id,
                    QTxWeights::Attn {
                        wq: quantize_proj(&w.wq.data, &w.bq.data, n_in, n_q, width, &spec),
                        wk: quantize_proj(&w.wk.data, &w.bk.data, n_in, n_k, width, &spec),
                        wv: quantize_proj(&w.wv.data, &w.bv.data, n_in, n_v, width, &spec),
                        wo: quantize_proj(&w.wo.data, &w.bo.data, n_ctx, n_out, width, &spec),
                        n_q,
                        n_k,
                        n_v,
                        n_s,
                        n_p,
                        n_ctx,
                        inv_sqrt_hd_q15: (f64::powi(2.0, 15) / (*head_dim as f64).sqrt())
                            .round() as i32,
                    },
                );
            }
            _ => {}
        }
    }

    QuantizedGraph { graph: graph.clone(), width, act_n, weights, tx, spec }
}

/// Quantize one attention projection dense-style: per-layer weight format
/// (per-filter would force per-column shifts through the fused attention
/// epilogues for negligible gain at d_model <= 64), bias at the
/// accumulator scale `n_in + n_w`, shift landing on `n_out`.
fn quantize_proj(
    w: &[f32],
    b: &[f32],
    n_in: i32,
    n_out: i32,
    width: u32,
    spec: &QuantSpec,
) -> QNodeWeights {
    let fmt = match &spec.fixed_format {
        Some(q) => QFormat::new(width, q.n),
        None => QFormat::from_slice(w, width),
    };
    let b_acc = b
        .iter()
        .map(|&x| (x as f64 * f64::powi(2.0, n_in + fmt.n)).round() as i64)
        .collect();
    QNodeWeights {
        w: fmt.quantize_slice(w),
        w_n: vec![fmt.n],
        b_acc,
        shift: vec![n_in + fmt.n - n_out],
    }
}

/// Mean squared quantization error of the weights (diagnostics, Fig 1 era).
pub fn weight_mse(graph: &Graph, qg: &QuantizedGraph) -> f64 {
    let mut se = 0.0f64;
    let mut count = 0usize;
    for node in &graph.nodes {
        let (w, filters) = match &node.kind {
            LayerKind::Conv { w, .. } => (w, *w.shape.last().unwrap()),
            LayerKind::Dense { w, .. } => (w, w.shape[1]),
            _ => continue,
        };
        let qw = &qg.weights[&node.id];
        for (i, &x) in w.data.iter().enumerate() {
            let f = i % filters;
            let n = qw.w_n_for(f);
            let deq = qw.w[i] as f32 * (2.0f32).powi(-n);
            se += ((x - deq) as f64).powi(2);
            count += 1;
        }
    }
    se / count.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::resnet_v1_6_shapes;
    use crate::graph::deploy_pipeline;
    use crate::nn::float_exec;
    use crate::util::prng::Pcg32;

    fn randomized(seed: u64) -> Graph {
        let mut g = resnet_v1_6_shapes("t", 1, &[32, 3], 4, 8);
        let mut rng = Pcg32::seeded(seed);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.4;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.1;
                }
            }
        }
        deploy_pipeline(&g)
    }

    fn calibrated(g: &Graph, seed: u64) -> ActStats {
        let mut stats = ActStats::new(g.nodes.len());
        let mut rng = Pcg32::seeded(seed);
        for _ in 0..8 {
            let x: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
            float_exec::run(g, &x, Some(&mut stats));
        }
        stats
    }

    #[test]
    fn per_layer_quantize_builds_all_weighted_nodes() {
        let g = randomized(1);
        let stats = calibrated(&g, 2);
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let weighted = g.nodes.iter().filter(|n| n.kind.has_weights()).count();
        assert_eq!(qg.weights.len(), weighted);
        for qw in qg.weights.values() {
            assert_eq!(qw.w_n.len(), 1);
            assert!(qw.w.iter().all(|&p| (-128..=127).contains(&p)));
        }
    }

    #[test]
    fn per_filter_has_one_format_per_filter() {
        let g = randomized(3);
        let stats = calibrated(&g, 4);
        let qg = quantize(&g, &stats, QuantSpec::int8_per_filter());
        for (id, qw) in &qg.weights {
            let filters = match &g.nodes[*id].kind {
                LayerKind::Conv { w, .. } => *w.shape.last().unwrap(),
                LayerKind::Dense { w, .. } => w.shape[1],
                _ => unreachable!(),
            };
            assert_eq!(qw.w_n.len(), filters);
            assert_eq!(qw.shift.len(), filters);
        }
    }

    #[test]
    fn fixed_q7_9_forces_all_formats() {
        let g = randomized(5);
        let stats = calibrated(&g, 6);
        let qg = quantize(&g, &stats, QuantSpec::int16_q7_9());
        assert!(qg.act_n.iter().all(|&n| n == 9));
        for qw in qg.weights.values() {
            assert_eq!(qw.w_n, vec![9]);
            assert_eq!(qw.shift, vec![9]); // 9 + 9 - 9
        }
    }

    #[test]
    fn passthrough_nodes_inherit_format() {
        let g = randomized(7);
        let stats = calibrated(&g, 8);
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        for node in &g.nodes {
            if passthrough(&node.kind) {
                assert_eq!(qg.act_n[node.id], qg.act_n[node.inputs[0]], "{}", node.name);
            }
        }
    }

    #[test]
    fn per_filter_mse_not_worse_than_per_layer() {
        let g = randomized(9);
        let stats = calibrated(&g, 10);
        let per_layer = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let per_filter = quantize(&g, &stats, QuantSpec::int8_per_filter());
        let mse_l = weight_mse(&g, &per_layer);
        let mse_f = weight_mse(&g, &per_filter);
        assert!(mse_f <= mse_l * 1.0001, "per-filter {mse_f} vs per-layer {mse_l}");
    }

    #[test]
    fn wider_widths_reduce_mse() {
        let g = randomized(11);
        let stats = calibrated(&g, 12);
        let m8 = weight_mse(&g, &quantize(&g, &stats, QuantSpec::int8_per_layer()));
        let m9 = weight_mse(&g, &quantize(&g, &stats, QuantSpec::int9_per_layer()));
        let m16 = weight_mse(&g, &quantize(&g, &stats, QuantSpec::int16_per_layer()));
        assert!(m9 < m8);
        assert!(m16 < m9);
    }

    #[test]
    fn weight_bytes_scale_with_width_biases_fixed_at_i64() {
        let g = randomized(13);
        let stats = calibrated(&g, 14);
        let q8 = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let q16 = quantize(&g, &stats, QuantSpec::int16_per_layer());
        // Weight payloads double with the width; bias storage is 8 bytes
        // per filter at EVERY width (i64 accumulator scale, matching the
        // engine's b_acc and the generated C long_number_t arrays).
        let bias_bytes: usize = q8.weights.values().map(|qw| qw.b_acc.len() * 8).sum();
        assert!(bias_bytes > 0);
        assert_eq!(
            q16.weight_bytes() - bias_bytes,
            2 * (q8.weight_bytes() - bias_bytes)
        );
        // Pre-fix the estimate charged biases at payload width: the i64
        // ROM estimate must exceed that undercount.
        assert!(q8.weight_bytes() > q8.graph.param_count());
        assert_eq!(
            q8.weight_bytes(),
            q8.weights.values().map(|qw| qw.w.len() + qw.b_acc.len() * 8).sum::<usize>()
        );
    }

    #[test]
    fn bias_conversion_rounds_to_nearest_at_both_widths() {
        // Fixed network-wide formats pin n_in and n_w exactly, so the
        // accumulator scale 2^(n_in + n_w) is known in closed form.
        for (width, n, bias, expect) in [
            // width 8, Q8.0: scale 2^0 = 1. round(0.7) = 1, round(-0.7) = -1
            // (trunc gave 0 / 0 — the pre-fix toward-zero bias).
            (8u32, 0i32, 0.7f32, 1i64),
            (8, 0, -0.7, -1),
            // ties away from zero, like C round():
            (8, 0, 1.5, 2),
            (8, 0, -1.5, -2),
            // width 16, Q7.9: scale 2^(9+9) = 2^18. 2.6 payload units →
            // round = 3 (trunc gave 2).
            (16, 9, 2.6 * f32::powi(2.0, -18), 3),
            (16, 9, -2.6 * f32::powi(2.0, -18), -3),
        ] {
            let mut g = randomized(15);
            let conv = g
                .nodes
                .iter()
                .position(|nd| matches!(nd.kind, LayerKind::Conv { .. }))
                .unwrap();
            if let LayerKind::Conv { b, .. } = &mut g.nodes[conv].kind {
                b.data[0] = bias;
            }
            let spec = QuantSpec {
                width,
                granularity: Granularity::PerNetwork,
                fixed_format: Some(QFormat::new(width, n)),
            };
            let stats = ActStats::new(g.nodes.len());
            let qg = quantize(&g, &stats, spec);
            assert_eq!(
                qg.weights[&conv].b_acc[0], expect,
                "width={width} n={n} bias={bias}"
            );
        }
    }
}
