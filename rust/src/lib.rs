//! MicroAI: an end-to-end framework for training, quantization and
//! deployment of deep neural networks on microcontrollers.
//!
//! Rust + JAX + Pallas reproduction of:
//! Novac et al., "Quantization and Deployment of Deep Neural Networks on
//! Microcontrollers", Sensors 2021, 21, 2984.
//!
//! Architecture (see DESIGN.md):
//! - L3 (this crate): the MicroAI framework — quantizer, graph compiler,
//!   integer inference engine, RAM allocator, C code generator, MCU cost /
//!   ROM / energy models, engine baselines, experiment flow, serving.
//! - L2/L1 (python/compile): JAX ResNetv1-6 + Pallas kernels, AOT-lowered
//!   to HLO text artifacts executed through `runtime` (PJRT). Python never
//!   runs on the request path.

pub mod allocator;
pub mod analysis;
pub mod codegen;
pub mod coordinator;
pub mod datasets;
pub mod engines;
pub mod fixedpoint;
pub mod graph;
pub mod mcu;
pub mod metrics;
pub mod nn;
pub mod quant;
pub mod reproduce;
pub mod runtime;
pub mod tensor;
pub mod util;
