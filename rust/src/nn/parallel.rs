//! Deterministic intra-op worker pool for the GEMM kernel core.
//!
//! The serving scheduler (PR 2) parallelizes *across* requests; this
//! module parallelizes *within* one conv/dense node, so a single large
//! inference (the GTSRB conv2d shapes) can use more than one host core.
//! rayon is unavailable offline, so the pool is std-only: N−1 persistent
//! worker threads plus the calling thread, fed over per-worker channels.
//!
//! # Determinism contract
//!
//! [`IntraOpPool::run_partitioned`] splits `0..n` into one contiguous,
//! **statically sized** chunk per thread (chunk `i` gets
//! `n/t + (i < n%t)` items — no work stealing, no timing dependence) and
//! blocks until every chunk has run. The GEMM lowerings in
//! [`super::gemm`] arrange that
//!
//! 1. each output element is written by exactly one chunk (chunks own
//!    disjoint output ranges), and
//! 2. the per-element accumulation order (k-major, `0..k`) is identical
//!    to the single-thread schedule — thread assignment only decides
//!    *who* computes an element, never *how*.
//!
//! Integer results are therefore bit-identical across thread counts, and
//! f32 results are ULP-equivalent (property-pinned in `nn::gemm`).
//!
//! # Memory
//!
//! Workers borrow the caller's data for the duration of one
//! `run_partitioned` call. The pool erases the closure lifetime behind a
//! raw pointer, which is sound because the call joins (drains one
//! completion token per dispatched chunk) before returning. Disjoint
//! output writes go through [`SharedOut`], the unsafe-but-audited window
//! type whose callers must guarantee range disjointness.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// Work body: `(thread_index, start, end)` — run items `start..end`.
/// `thread_index` is stable per chunk (chunk `i` runs as thread `i`), so
/// it can index per-thread scratch slabs without aliasing.
pub type ParallelBody<'a> = &'a (dyn Fn(usize, usize, usize) + Sync);

/// One dispatched chunk. The raw body pointer is only dereferenced while
/// `run_partitioned` is blocked on the matching `done` token, so the
/// borrow it erases is always live.
struct Job {
    body: *const (dyn Fn(usize, usize, usize) + Sync),
    thread: usize,
    start: usize,
    end: usize,
    done: Sender<bool>,
}

// SAFETY: the pointee is `Sync` (shared by every worker for one call) and
// outlives the job by the join-before-return protocol above.
unsafe impl Send for Job {}

fn worker_loop(rx: Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        let ok = catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: see `Job` — the caller is blocked until `done`.
            let body = unsafe { &*job.body };
            body(job.thread, job.start, job.end);
        }))
        .is_ok();
        let _ = job.done.send(ok);
    }
}

/// Persistent intra-op worker pool: `threads − 1` OS threads plus the
/// caller. `threads <= 1` spawns nothing and runs everything inline, so a
/// serial pool is free.
pub struct IntraOpPool {
    txs: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl IntraOpPool {
    /// Pool with a total budget of `threads` (including the caller).
    pub fn new(threads: usize) -> IntraOpPool {
        let threads = threads.max(1);
        let mut txs = Vec::with_capacity(threads - 1);
        let mut handles = Vec::with_capacity(threads - 1);
        for i in 1..threads {
            let (tx, rx) = channel::<Job>();
            txs.push(tx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("microai-intra-op-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn intra-op worker"),
            );
        }
        IntraOpPool { txs, handles, threads }
    }

    /// The no-thread pool every legacy single-threaded entry point uses.
    pub fn serial() -> IntraOpPool {
        IntraOpPool::new(1)
    }

    /// Total thread budget (including the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Number of chunks `run_partitioned(n, ..)` will create — callers
    /// size per-thread scratch with this.
    pub fn chunks_for(&self, n: usize) -> usize {
        self.threads.min(n).max(1)
    }

    /// Split `0..n` into [`Self::chunks_for`]`(n)` contiguous chunks and
    /// run `body(thread, start, end)` for each, chunk 0 on the calling
    /// thread, the rest on the workers. Blocks until every chunk is done;
    /// propagates worker panics as a panic on the caller.
    pub fn run_partitioned(&self, n: usize, body: ParallelBody) {
        if n == 0 {
            return;
        }
        let t = self.chunks_for(n);
        if t == 1 {
            body(0, 0, n);
            return;
        }
        // Deterministic balanced partition: chunk i = [bounds(i), bounds(i+1)),
        // |chunk i| = n/t + (i < n%t).
        let (base, extra) = (n / t, n % t);
        let bounds = |i: usize| i * base + i.min(extra);
        let (done_tx, done_rx) = channel::<bool>();
        for w in 1..t {
            let job = Job {
                body: body as *const _,
                thread: w,
                start: bounds(w),
                end: bounds(w + 1),
                done: done_tx.clone(),
            };
            self.txs[w - 1].send(job).expect("intra-op worker exited");
        }
        drop(done_tx);
        // Run chunk 0 here, but join the workers BEFORE any unwind can
        // leave this frame — they hold a raw pointer into live borrows.
        let own = catch_unwind(AssertUnwindSafe(|| body(0, 0, bounds(1))));
        let mut ok = true;
        for _ in 1..t {
            match done_rx.recv() {
                Ok(o) => ok &= o,
                Err(_) => ok = false,
            }
        }
        if let Err(payload) = own {
            std::panic::resume_unwind(payload);
        }
        assert!(ok, "intra-op worker panicked");
    }
}

impl Drop for IntraOpPool {
    fn drop(&mut self) {
        // Closing the senders ends each worker loop; join for a clean exit.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A shared window over a caller-owned `&mut [T]` for disjoint-range
/// parallel writes (the column-panel outputs of the GEMM lowerings).
///
/// Safety protocol: every concurrent user must touch a range no other
/// user touches during the same `run_partitioned` call — the lowerings
/// guarantee this structurally (each chunk owns a disjoint output-row or
/// output-column range). The window never outlives the borrow it was
/// created from (it is only passed by reference into `run_partitioned`,
/// which joins before returning).
pub struct SharedOut<T> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: the window holds only a raw pointer and a length; moving it to
// another thread moves no `T`, and every dereference is gated behind
// `unsafe` methods whose contract is range disjointness.
unsafe impl<T: Send> Send for SharedOut<T> {}
// SAFETY: shared (`&SharedOut`) access exposes no safe dereference; the
// unsafe methods require callers to touch pairwise-disjoint ranges, so
// concurrent use from many threads cannot alias a `T`.
unsafe impl<T: Send> Sync for SharedOut<T> {}

impl<T> SharedOut<T> {
    pub fn new(slice: &mut [T]) -> SharedOut<T> {
        SharedOut { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    /// Write one element.
    ///
    /// # Safety
    /// `i` must be in bounds and not concurrently written by any other
    /// user of this window.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len, "SharedOut write {i} out of {}", self.len);
        // SAFETY: caller contract (above): `i < len`, and no other thread
        // touches index `i` during this call, so the write cannot alias.
        unsafe { *self.ptr.add(i) = v }
    }

    /// Exclusive subslice `start..start + len`.
    ///
    /// # Safety
    /// The range must be in bounds and disjoint from every range any
    /// other user of this window reads or writes concurrently.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(
            start + len <= self.len,
            "SharedOut slice {start}+{len} out of {}",
            self.len
        );
        // SAFETY: caller contract (above): the range is in bounds of the
        // borrowed slice and disjoint from every concurrent user, so this
        // is the only live reference to these elements.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_pool_runs_inline_without_threads() {
        let pool = IntraOpPool::serial();
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.chunks_for(100), 1);
        let mut hits = 0usize;
        // A serial pool runs the body inline, so &mut captures stay legal
        // through the Fn interface via a Cell-free local — use an atomic
        // to keep one code path for both tests.
        let counter = AtomicUsize::new(0);
        pool.run_partitioned(17, &|tid, s, e| {
            assert_eq!((tid, s, e), (0, 0, 17));
            counter.fetch_add(e - s, Ordering::Relaxed);
        });
        hits += counter.load(Ordering::Relaxed);
        assert_eq!(hits, 17);
    }

    #[test]
    fn partition_covers_every_index_exactly_once() {
        let pool = IntraOpPool::new(4);
        for n in [1usize, 2, 3, 4, 5, 7, 8, 64, 1000, 1001, 1003] {
            let marks: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run_partitioned(n, &|_tid, s, e| {
                for m in &marks[s..e] {
                    m.fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                marks.iter().all(|m| m.load(Ordering::Relaxed) == 1),
                "n={n}: some index not covered exactly once"
            );
        }
    }

    #[test]
    fn chunk_bounds_are_deterministic_and_balanced() {
        // 10 items over 4 threads: 3,3,2,2 — the static split the
        // determinism argument relies on.
        let pool = IntraOpPool::new(4);
        let sizes: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        pool.run_partitioned(10, &|tid, s, e| {
            sizes[tid].store(e - s, Ordering::Relaxed);
        });
        let got: Vec<usize> = sizes.iter().map(|s| s.load(Ordering::Relaxed)).collect();
        assert_eq!(got, vec![3, 3, 2, 2]);
    }

    #[test]
    fn fewer_items_than_threads_shrinks_chunk_count() {
        let pool = IntraOpPool::new(8);
        assert_eq!(pool.chunks_for(3), 3);
        let max_tid = AtomicUsize::new(0);
        pool.run_partitioned(3, &|tid, s, e| {
            assert_eq!(e - s, 1);
            max_tid.fetch_max(tid, Ordering::Relaxed);
        });
        assert_eq!(max_tid.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = IntraOpPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_partitioned(2, &|tid, _s, _e| {
                if tid == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must surface on the caller");
        // The worker thread caught the unwind and keeps serving.
        let counter = AtomicUsize::new(0);
        pool.run_partitioned(2, &|_tid, s, e| {
            counter.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn shared_out_disjoint_writes_land() {
        let pool = IntraOpPool::new(3);
        let mut out = vec![0usize; 100];
        let view = SharedOut::new(&mut out);
        pool.run_partitioned(100, &|_tid, s, e| {
            for i in s..e {
                // SAFETY: chunks own disjoint index ranges.
                unsafe { view.write(i, i * 2) };
            }
        });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i * 2));
    }
}
