//! Fixed-point integer layer kernels — the Rust twin of the generated C
//! inner loops (§5.8, Table A6): widen → MACC → arithmetic-shift-right →
//! saturate, with optional fused ReLU.
//!
//! The conv/dense kernels here are the NAIVE REFERENCE implementations
//! (`*_ref`): the executors run the im2col + blocked-GEMM lowerings in
//! [`super::gemm`], property-tested BIT-EXACT against these (integer sums
//! are order-independent; the i32-lane admission guard rules out
//! intermediate overflow for any summation order).

use crate::fixedpoint::lut::exp_q;
use crate::fixedpoint::lut::rsqrt_norm;
use crate::fixedpoint::ops::{clamp_to, rescale};
use crate::graph::ir::Padding;
use crate::graph::Graph;
use crate::quant::ptq::{QNodeWeights, QTxWeights};

/// 1-D fixed-point convolution on integer payloads, reference kernel.
/// x: (S, C) payloads at n_in; w/b/shift per `qw`; out at n_out.
#[allow(clippy::too_many_arguments)]
pub fn conv1d_q_ref(
    x: &[i32],
    s: usize,
    c: usize,
    qw: &QNodeWeights,
    k: usize,
    f: usize,
    stride: usize,
    padding: Padding,
    relu: bool,
    width: u32,
    out: &mut Vec<i32>,
) -> usize {
    let (pad_lo, s_out) = match padding {
        Padding::Same => (Graph::same_padding(s, k, stride).0, s.div_ceil(stride)),
        Padding::Valid => (0, (s - k) / stride + 1),
    };
    out.clear();
    out.reserve(s_out * f);
    // Perf pass P2: when the worst-case accumulator provably fits i32
    // (int8 operands), accumulate in i32 lanes — twice the SIMD width of
    // the generic i64 path. Semantically identical (no saturation can be
    // hit before the epilogue); the boundary property test pins the two
    // paths bit-identical right at the admission threshold.
    if accum_fits_i32(qw, k * c, width) {
        conv1d_q_i32(x, s, c, qw, k, f, stride, pad_lo, s_out, relu, width, out);
    } else {
        conv1d_q_i64(x, s, c, qw, k, f, stride, pad_lo, s_out, relu, width, out);
    }
    s_out
}

/// P2 fast path: i32 accumulator lanes. ONLY valid when
/// [`accum_fits_i32`] admits the node (no intermediate overflow possible).
#[allow(clippy::too_many_arguments)]
fn conv1d_q_i32(
    x: &[i32],
    s: usize,
    c: usize,
    qw: &QNodeWeights,
    k: usize,
    f: usize,
    stride: usize,
    pad_lo: usize,
    s_out: usize,
    relu: bool,
    width: u32,
    out: &mut Vec<i32>,
) {
    let w = &qw.w;
    let uniform_shift = qw.shift.len() == 1;
    // Perf pass P1 (EXPERIMENTS.md §Perf): filter-contiguous accumulation.
    // The weight layout (k, c, f) is contiguous in f, so accumulating a
    // whole filter row per (tap, channel) turns the inner loop into a
    // vectorizable acc[f] += x * w[f] sweep instead of a stride-f gather.
    let mut acc = vec![0i32; f];
    for o in 0..s_out {
        let base = (o * stride) as isize - pad_lo as isize;
        let k_lo = (-base).max(0) as usize;
        let k_hi = ((s as isize - base).min(k as isize)).max(0) as usize;
        for (a, &b) in acc.iter_mut().zip(&qw.b_acc) {
            *a = b as i32;
        }
        for ki in k_lo..k_hi {
            let xi = (base + ki as isize) as usize;
            let xrow = &x[xi * c..(xi + 1) * c];
            for (ci, &xv) in xrow.iter().enumerate() {
                if xv == 0 {
                    continue; // ReLU sparsity: skip zero activations
                }
                let wrow = &w[(ki * c + ci) * f..(ki * c + ci + 1) * f];
                for (a, &wv) in acc.iter_mut().zip(wrow) {
                    *a += xv * wv;
                }
            }
        }
        for fi in 0..f {
            let sh = if uniform_shift { qw.shift[0] } else { qw.shift[fi] };
            let mut v = clamp_to(rescale(acc[fi] as i64, sh), width);
            if relu && v < 0 {
                v = 0;
            }
            out.push(v);
        }
    }
}

/// Generic path: i64 accumulator lanes, correct for every operand width.
#[allow(clippy::too_many_arguments)]
fn conv1d_q_i64(
    x: &[i32],
    s: usize,
    c: usize,
    qw: &QNodeWeights,
    k: usize,
    f: usize,
    stride: usize,
    pad_lo: usize,
    s_out: usize,
    relu: bool,
    width: u32,
    out: &mut Vec<i32>,
) {
    let w = &qw.w;
    let uniform_shift = qw.shift.len() == 1;
    let mut acc = vec![0i64; f];
    for o in 0..s_out {
        let base = (o * stride) as isize - pad_lo as isize;
        // Valid tap range for this output position (hoists the bounds
        // check out of the MACC loop).
        let k_lo = (-base).max(0) as usize;
        let k_hi = ((s as isize - base).min(k as isize)).max(0) as usize;
        acc.copy_from_slice(&qw.b_acc);
        for ki in k_lo..k_hi {
            let xi = (base + ki as isize) as usize;
            let xrow = &x[xi * c..(xi + 1) * c];
            for (ci, &xv) in xrow.iter().enumerate() {
                if xv == 0 {
                    continue; // ReLU sparsity: skip zero activations
                }
                let xv = xv as i64;
                let wrow = &w[(ki * c + ci) * f..(ki * c + ci + 1) * f];
                for (a, &wv) in acc.iter_mut().zip(wrow) {
                    *a += xv * (wv as i64);
                }
            }
        }
        for fi in 0..f {
            let sh = if uniform_shift { qw.shift[0] } else { qw.shift[fi] };
            let mut v = clamp_to(rescale(acc[fi], sh), width);
            if relu && v < 0 {
                v = 0;
            }
            out.push(v);
        }
    }
}

/// P2 safety check: worst-case |accumulator| for `taps` MACCs of
/// `width`-bit operands plus the bias magnitude must fit in i32. Shared
/// with the GEMM lowering so both paths make the identical decision.
#[inline]
pub(crate) fn accum_fits_i32(qw: &QNodeWeights, taps: usize, width: u32) -> bool {
    if width > 8 {
        return false;
    }
    let max_prod = (1i64 << (width - 1)) * (1i64 << (width - 1));
    let max_bias = qw.b_acc.iter().map(|b| b.abs()).max().unwrap_or(0);
    (taps as i64) * max_prod + max_bias < i32::MAX as i64 / 2
}

/// 2-D fixed-point convolution, reference kernel.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_q_ref(
    x: &[i32],
    h: usize,
    wdt: usize,
    c: usize,
    qw: &QNodeWeights,
    kh: usize,
    kw: usize,
    f: usize,
    stride: usize,
    padding: Padding,
    relu: bool,
    width: u32,
    out: &mut Vec<i32>,
) -> (usize, usize) {
    let ((ph, _), h_out) = match padding {
        Padding::Same => (Graph::same_padding(h, kh, stride), h.div_ceil(stride)),
        Padding::Valid => ((0, 0), (h - kh) / stride + 1),
    };
    let ((pw, _), w_out) = match padding {
        Padding::Same => (Graph::same_padding(wdt, kw, stride), wdt.div_ceil(stride)),
        Padding::Valid => ((0, 0), (wdt - kw) / stride + 1),
    };
    out.clear();
    out.reserve(h_out * w_out * f);
    let w = &qw.w;
    let uniform_shift = qw.shift.len() == 1;
    // Perf passes P1 (filter-contiguous accumulation) + P3 (i32 lanes for
    // provably-safe int8 accumulators) — see conv1d_q_ref.
    let fits_i32 = accum_fits_i32(qw, kh * kw * c, width);
    let mut acc64 = vec![0i64; f];
    let mut acc32 = vec![0i32; f];
    for oh in 0..h_out {
        let hbase = (oh * stride) as isize - ph as isize;
        for ow in 0..w_out {
            let wbase = (ow * stride) as isize - pw as isize;
            if fits_i32 {
                for (a, &b) in acc32.iter_mut().zip(&qw.b_acc) {
                    *a = b as i32;
                }
            } else {
                acc64.copy_from_slice(&qw.b_acc);
            }
            for ki in 0..kh {
                let hi = hbase + ki as isize;
                if hi < 0 || hi >= h as isize {
                    continue;
                }
                for kj in 0..kw {
                    let wi = wbase + kj as isize;
                    if wi < 0 || wi >= wdt as isize {
                        continue;
                    }
                    let xrow = &x[((hi as usize) * wdt + wi as usize) * c..];
                    for ci in 0..c {
                        let xv = xrow[ci];
                        if xv == 0 {
                            continue;
                        }
                        let woff = ((ki * kw + kj) * c + ci) * f;
                        let wrow = &w[woff..woff + f];
                        if fits_i32 {
                            for (a, &wv) in acc32.iter_mut().zip(wrow) {
                                *a += xv * wv;
                            }
                        } else {
                            let xv = xv as i64;
                            for (a, &wv) in acc64.iter_mut().zip(wrow) {
                                *a += xv * (wv as i64);
                            }
                        }
                    }
                }
            }
            for fi in 0..f {
                let a = if fits_i32 { acc32[fi] as i64 } else { acc64[fi] };
                let sh = if uniform_shift { qw.shift[0] } else { qw.shift[fi] };
                let mut v = clamp_to(rescale(a, sh), width);
                if relu && v < 0 {
                    v = 0;
                }
                out.push(v);
            }
        }
    }
    (h_out, w_out)
}

/// Fixed-point dense layer, reference kernel.
pub fn dense_q_ref(
    x: &[i32],
    qw: &QNodeWeights,
    o: usize,
    relu: bool,
    width: u32,
    out: &mut Vec<i32>,
) {
    let i = x.len();
    out.clear();
    out.reserve(o);
    let uniform_shift = qw.shift.len() == 1;
    // Perf pass P1: output-contiguous accumulation over the (i, o) layout.
    let mut acc: Vec<i64> = qw.b_acc.clone();
    for (ii, &xv) in x.iter().enumerate().take(i) {
        if xv == 0 {
            continue;
        }
        let xv = xv as i64;
        let wrow = &qw.w[ii * o..(ii + 1) * o];
        for (a, &wv) in acc.iter_mut().zip(wrow) {
            *a += xv * (wv as i64);
        }
    }
    for oi in 0..o {
        let sh = if uniform_shift { qw.shift[0] } else { qw.shift[oi] };
        let mut v = clamp_to(rescale(acc[oi], sh), width);
        if relu && v < 0 {
            v = 0;
        }
        out.push(v);
    }
}

/// Max pooling on payloads (no requantization, §4.3). SAME-style windows:
/// odd spatial dims keep a remainder window over the in-range samples
/// (`Graph::pool_geometry`) — pre-fix they were silently truncated.
pub fn maxpool_q(x: &[i32], spatial: &[usize], c: usize, size: usize, relu: bool, out: &mut Vec<i32>) {
    out.clear();
    match spatial.len() {
        1 => {
            let s = spatial[0];
            let (lo, s_out) = Graph::pool_geometry(s, size);
            for o in 0..s_out {
                let (x_lo, x_hi) = Graph::pool_window(o, size, lo, s);
                for ci in 0..c {
                    let mut m = i32::MIN;
                    for xi in x_lo..x_hi {
                        m = m.max(x[xi * c + ci]);
                    }
                    out.push(if relu { m.max(0) } else { m });
                }
            }
        }
        2 => {
            let (h, w) = (spatial[0], spatial[1]);
            let (hlo, ho) = Graph::pool_geometry(h, size);
            let (wlo, wo) = Graph::pool_geometry(w, size);
            for oh in 0..ho {
                let (h_lo, h_hi) = Graph::pool_window(oh, size, hlo, h);
                for ow in 0..wo {
                    let (w_lo, w_hi) = Graph::pool_window(ow, size, wlo, w);
                    for ci in 0..c {
                        let mut m = i32::MIN;
                        for hi in h_lo..h_hi {
                            for wi in w_lo..w_hi {
                                m = m.max(x[(hi * w + wi) * c + ci]);
                            }
                        }
                        out.push(if relu { m.max(0) } else { m });
                    }
                }
            }
        }
        r => panic!("maxpool rank {r}"),
    }
}

/// Average pooling: i64 sum, integer division (truncation, like C `/`).
/// SAME-style remainder windows divide by the actual in-range sample
/// count — matching the generated C remainder loops bit-for-bit.
pub fn avgpool_q(x: &[i32], spatial: &[usize], c: usize, size: usize, out: &mut Vec<i32>) {
    out.clear();
    match spatial.len() {
        1 => {
            let s = spatial[0];
            let (lo, s_out) = Graph::pool_geometry(s, size);
            for o in 0..s_out {
                let (x_lo, x_hi) = Graph::pool_window(o, size, lo, s);
                let denom = (x_hi - x_lo) as i64;
                for ci in 0..c {
                    let mut a: i64 = 0;
                    for xi in x_lo..x_hi {
                        a += x[xi * c + ci] as i64;
                    }
                    out.push((a / denom) as i32);
                }
            }
        }
        2 => {
            let (h, w) = (spatial[0], spatial[1]);
            let (hlo, ho) = Graph::pool_geometry(h, size);
            let (wlo, wo) = Graph::pool_geometry(w, size);
            for oh in 0..ho {
                let (h_lo, h_hi) = Graph::pool_window(oh, size, hlo, h);
                for ow in 0..wo {
                    let (w_lo, w_hi) = Graph::pool_window(ow, size, wlo, w);
                    let denom = ((h_hi - h_lo) * (w_hi - w_lo)) as i64;
                    for ci in 0..c {
                        let mut a: i64 = 0;
                        for hi in h_lo..h_hi {
                            for wi in w_lo..w_hi {
                                a += x[(hi * w + wi) * c + ci] as i64;
                            }
                        }
                        out.push((a / denom) as i32);
                    }
                }
            }
        }
        r => panic!("avgpool rank {r}"),
    }
}

/// Global average pool on payloads (format preserved; truncating division).
/// Channel-major accumulation keeps the hot path allocation-free (the
/// Session arena contract); c is small, positions*c touches are the same.
pub fn global_avgpool_q(x: &[i32], positions: usize, c: usize, out: &mut Vec<i32>) {
    out.clear();
    out.reserve(c);
    for ci in 0..c {
        let mut sum = 0i64;
        for p in 0..positions {
            sum += x[p * c + ci] as i64;
        }
        out.push((sum / positions as i64) as i32);
    }
}

/// Element-wise Add: realign both operands to the output format, then
/// saturating add (Table A6: i shifts + (i-1) adds + saturate per element).
#[allow(clippy::too_many_arguments)]
pub fn add_q(
    a: &[i32],
    n_a: i32,
    b: &[i32],
    n_b: i32,
    n_out: i32,
    relu: bool,
    width: u32,
    out: &mut Vec<i32>,
) {
    out.clear();
    out.reserve(a.len());
    let sh_a = n_a - n_out;
    let sh_b = n_b - n_out;
    for (&x, &y) in a.iter().zip(b.iter()) {
        let xa = rescale(x as i64, sh_a);
        let yb = rescale(y as i64, sh_b);
        let mut v = clamp_to(xa + yb, width);
        if relu && v < 0 {
            v = 0;
        }
        out.push(v);
    }
}

/// In-place [`add_q`] for the planner's aliased residual tails
/// (DESIGN.md §12): `acc` holds one operand's payload and receives the
/// sum. The i64 `rescale(a) + rescale(b)` is commutative, so one kernel
/// serves whichever operand the planner aliased — bit-exact with
/// `add_q` by construction.
pub fn add_q_inplace(
    acc: &mut [i32],
    n_acc: i32,
    other: &[i32],
    n_other: i32,
    n_out: i32,
    relu: bool,
    width: u32,
) {
    let sh_a = n_acc - n_out;
    let sh_b = n_other - n_out;
    for (a, &y) in acc.iter_mut().zip(other.iter()) {
        let xa = rescale(*a as i64, sh_a);
        let yb = rescale(y as i64, sh_b);
        let mut v = clamp_to(xa + yb, width);
        if relu && v < 0 {
            v = 0;
        }
        *a = v;
    }
}

pub fn relu_q(x: &[i32], out: &mut Vec<i32>) {
    out.clear();
    out.extend(x.iter().map(|&v| v.max(0)));
}

/// In-place [`relu_q`] (element-wise, trivially alias-safe).
pub fn relu_q_inplace(x: &mut [i32]) {
    for v in x.iter_mut() {
        *v = (*v).max(0);
    }
}

/// Embedding gather on id payloads (n = 0): output rows ARE table rows
/// (quantized at the node's activation format), so no arithmetic at all.
/// Out-of-range ids clamp to the table edge, matching the float reference.
pub fn embedding_q(ids: &[i32], table: &[i32], d: usize, out: &mut Vec<i32>) {
    let vocab = table.len() / d;
    out.clear();
    out.reserve(ids.len() * d);
    for &id in ids {
        let i = (id as isize).clamp(0, vocab as isize - 1) as usize;
        out.extend_from_slice(&table[i * d..(i + 1) * d]);
    }
}

/// In-place [`embedding_q`]: `buf` arrives holding the id payloads and
/// leaves holding the gathered rows. Walking ids BACKWARDS makes the
/// aliasing safe — position `t` writes `[t*d, (t+1)*d)` after reading
/// the id at index `t`, and every still-unread id sits at an index
/// `t' < t <= t*d`. Batched callers pass the example-major concatenation
/// (`batch*ids` ids): the flat walk is exactly the single-example case.
pub fn embedding_q_inplace(buf: &mut Vec<i32>, table: &[i32], d: usize) {
    let n = buf.len();
    let vocab = table.len() / d;
    buf.resize(n * d, 0);
    for t in (0..n).rev() {
        let i = (buf[t] as isize).clamp(0, vocab as isize - 1) as usize;
        buf[t * d..(t + 1) * d].copy_from_slice(&table[i * d..(i + 1) * d]);
    }
}

/// Numerically-stable fixed-point softmax over one row: payloads at
/// `n_in` → probabilities at `n_out` (the quantizer pins `width - 1`).
/// Max-subtraction makes every exp argument a non-negative distance, so
/// the Q0.15 exp LUT covers the whole domain; the division truncates like
/// C `/`, keeping Rust and the emitted C bit-exact.
pub fn softmax_q_row(x: &[i32], n_in: i32, n_out: i32, width: u32, out: &mut [i32]) {
    debug_assert_eq!(x.len(), out.len());
    let m = x.iter().copied().max().unwrap_or(0) as i64;
    let mut sum = 0i64;
    for (&v, e) in x.iter().zip(out.iter_mut()) {
        let q = exp_q(m - v as i64, n_in);
        *e = q;
        sum += q as i64;
    }
    // The max element's distance is 0, so sum >= exp_lut()[0] > 0.
    for e in out.iter_mut() {
        *e = clamp_to(((*e as i64) << n_out) / sum, width);
    }
}

/// Softmax as a graph node (the transformer head): the whole tensor is
/// one distribution, like the float reference.
pub fn softmax_q_ref(x: &[i32], n_in: i32, n_out: i32, width: u32, out: &mut Vec<i32>) {
    out.clear();
    out.resize(x.len(), 0);
    softmax_q_row(x, n_in, n_out, width, out);
}

/// In-place [`softmax_q_row`]: the max pass is read-only, the exp pass
/// rewrites each element from its own (already-read) value, and the
/// normalize pass rewrites again — the exact element/accumulation order
/// of the two-buffer kernel, so the payloads are bit-identical.
pub fn softmax_q_inplace(x: &mut [i32], n_in: i32, n_out: i32, width: u32) {
    let m = x.iter().copied().max().unwrap_or(0) as i64;
    let mut sum = 0i64;
    for e in x.iter_mut() {
        let q = exp_q(m - *e as i64, n_in);
        *e = q;
        sum += q as i64;
    }
    for e in x.iter_mut() {
        *e = clamp_to(((*e as i64) << n_out) / sum, width);
    }
}

/// Fixed-point LayerNorm over rows of `c` channels, reference kernel.
///
/// Two-pass integer mean/variance at the input scale (truncating division,
/// like C `/`), then `rsqrt_norm` supplies 1/sqrt(var_payload + 1) as a
/// Q2.30 mantissa plus exponent. The variance `+1` keeps the rsqrt domain
/// valid and acts as an epsilon of one accumulator ulp (2^-2n_in real).
/// gamma payloads sit at `g_n`, beta payloads directly at `n_out`.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_q_ref(
    x: &[i32],
    c: usize,
    gamma: &[i32],
    g_n: i32,
    beta: &[i32],
    n_out: i32,
    width: u32,
    out: &mut Vec<i32>,
) {
    out.clear();
    out.reserve(x.len());
    for row in x.chunks_exact(c) {
        let sum: i64 = row.iter().map(|&v| v as i64).sum();
        let mean = sum / c as i64;
        let mut var_acc = 0i64;
        for &v in row {
            let d = v as i64 - mean;
            var_acc += d * d;
        }
        let (r, h) = rsqrt_norm(var_acc / c as i64 + 1);
        // x_hat = d * r * 2^(-30-h): the n_in scale of d cancels against
        // the payload-domain rsqrt, so the shift below is n_in-free.
        let sh = 30 + h + g_n - n_out;
        for (ci, &xv) in row.iter().enumerate() {
            let d = xv as i64 - mean;
            let acc = d * r * gamma[ci] as i64;
            out.push(clamp_to(rescale(acc, sh) + beta[ci] as i64, width));
        }
    }
}

/// Position-wise projection on payloads: x (P, D) rows through a
/// dense-style quantized weight (D, O) with a single per-layer shift.
pub(crate) fn proj_q_rows(
    x: &[i32],
    d: usize,
    o: usize,
    qw: &QNodeWeights,
    width: u32,
    out: &mut Vec<i32>,
) {
    out.clear();
    out.reserve((x.len() / d) * o);
    for row in x.chunks_exact(d) {
        for oi in 0..o {
            let mut acc = qw.b_acc[oi];
            for (ii, &xv) in row.iter().enumerate() {
                acc += xv as i64 * qw.w[ii * o + oi] as i64;
            }
            out.push(clamp_to(rescale(acc, qw.shift[0]), width));
        }
    }
}

/// Fixed-point multi-head self-attention, reference kernel: x (S, D)
/// payloads at the node input format, out (S, D) at the node output
/// format. Requantization points (Q/K/V, scaled scores, softmax rows,
/// context, output) follow the formats recorded in the `Attn` params; the
/// GEMM lowering must reproduce this kernel bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn attention_q_ref(
    x: &[i32],
    seq: usize,
    dm: usize,
    heads: usize,
    hd: usize,
    tx: &QTxWeights,
    width: u32,
    out: &mut Vec<i32>,
) {
    let QTxWeights::Attn {
        wq, wk, wv, wo, n_q, n_k, n_v, n_s, n_p, n_ctx, inv_sqrt_hd_q15, ..
    } = tx
    else {
        panic!("attention_q_ref wants Attn params");
    };
    let (mut q, mut k, mut v) = (Vec::new(), Vec::new(), Vec::new());
    proj_q_rows(x, dm, dm, wq, width, &mut q);
    proj_q_rows(x, dm, dm, wk, width, &mut k);
    proj_q_rows(x, dm, dm, wv, width, &mut v);
    let mut srow = vec![0i32; seq];
    let mut prow = vec![0i32; seq];
    let mut ctx = vec![0i32; seq * dm];
    let score_sh = n_q + n_k + 15 - n_s;
    let ctx_sh = n_p + n_v - n_ctx;
    for h in 0..heads {
        let off = h * hd;
        for i in 0..seq {
            for (j, sj) in srow.iter_mut().enumerate() {
                let mut acc = 0i64;
                for t in 0..hd {
                    acc += q[i * dm + off + t] as i64 * k[j * dm + off + t] as i64;
                }
                *sj = clamp_to(rescale(acc * *inv_sqrt_hd_q15 as i64, score_sh), width);
            }
            softmax_q_row(&srow, *n_s, *n_p, width, &mut prow);
            for t in 0..hd {
                let mut acc = 0i64;
                for (j, &pj) in prow.iter().enumerate() {
                    acc += pj as i64 * v[j * dm + off + t] as i64;
                }
                ctx[i * dm + off + t] = clamp_to(rescale(acc, ctx_sh), width);
            }
        }
    }
    proj_q_rows(&ctx, dm, dm, wo, width, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ptq::QNodeWeights;

    fn qw(w: Vec<i32>, b_acc: Vec<i64>, shift: i32) -> QNodeWeights {
        QNodeWeights { w, w_n: vec![0], b_acc, shift: vec![shift] }
    }

    #[test]
    fn conv1d_q_identity() {
        // k=1, single channel, weight payload 1, shift 0.
        let x = [10, -20, 30];
        let q = qw(vec![1], vec![0], 0);
        let mut out = Vec::new();
        let s = conv1d_q_ref(&x, 3, 1, &q, 1, 1, 1, Padding::Same, false, 8, &mut out);
        assert_eq!(s, 3);
        assert_eq!(out, vec![10, -20, 30]);
    }

    #[test]
    fn conv1d_q_shifts_and_saturates() {
        let x = [100, 100];
        let q = qw(vec![100], vec![0], 1); // acc = 10000, >>1 = 5000 -> sat 127
        let mut out = Vec::new();
        conv1d_q_ref(&x, 2, 1, &q, 1, 1, 1, Padding::Same, false, 8, &mut out);
        assert_eq!(out, vec![127, 127]);
    }

    #[test]
    fn conv1d_q_relu() {
        let x = [-50];
        let q = qw(vec![1], vec![0], 0);
        let mut out = Vec::new();
        conv1d_q_ref(&x, 1, 1, &q, 1, 1, 1, Padding::Same, true, 8, &mut out);
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn conv1d_q_same_padding_zero_taps() {
        // k=3 sum kernel: edges see two taps (pad contributes 0 payload).
        let x = [1, 2, 3];
        let q = qw(vec![1, 1, 1], vec![0], 0);
        let mut out = Vec::new();
        conv1d_q_ref(&x, 3, 1, &q, 3, 1, 1, Padding::Same, false, 16, &mut out);
        assert_eq!(out, vec![3, 6, 5]);
    }

    #[test]
    fn dense_q_matches_manual() {
        let x = [2, 3];
        let q = QNodeWeights {
            w: vec![1, 10, 2, 20], // (2 in, 2 out)
            w_n: vec![0],
            b_acc: vec![4, -4],
            shift: vec![1],
        };
        let mut out = Vec::new();
        dense_q_ref(&x, &q, 2, false, 16, &mut out);
        // o0: 2*1+3*2+4 = 12 >>1 = 6 ; o1: 2*10+3*20-4 = 76 >>1 = 38
        assert_eq!(out, vec![6, 38]);
    }

    #[test]
    fn add_q_realigns_formats() {
        // a at n=4, b at n=2, out at n=2: a>>2 + b.
        let a = [16]; // 1.0 at n=4
        let b = [4]; // 1.0 at n=2
        let mut out = Vec::new();
        add_q(&a, 4, &b, 2, 2, false, 8, &mut out);
        assert_eq!(out, vec![8]); // 2.0 at n=2
    }

    #[test]
    fn add_q_saturates() {
        let a = [120];
        let b = [120];
        let mut out = Vec::new();
        add_q(&a, 0, &b, 0, 0, false, 8, &mut out);
        assert_eq!(out, vec![127]);
    }

    #[test]
    fn global_avgpool_q_truncates() {
        let x = [1, 2, 2, 3]; // (2, 2): ch sums 3, 5 -> /2 -> 1, 2
        let mut out = Vec::new();
        global_avgpool_q(&x, 2, 2, &mut out);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn maxpool_q_takes_max() {
        let x = [5, -1, 3, 7]; // (2, 2)
        let mut out = Vec::new();
        maxpool_q(&x, &[2], 2, 2, false, &mut out);
        assert_eq!(out, vec![5, 7]);
    }

    #[test]
    fn maxpool_q_odd_keeps_remainder_window() {
        // Regression for the silent-truncation bug: an odd-length window
        // (3 samples, pool size 2) must emit the remainder window instead
        // of dropping the last sample.
        let x = [5, -1, 3, 7, 9, 2]; // (3, 2)
        let mut out = Vec::new();
        maxpool_q(&x, &[3], 2, 2, false, &mut out);
        assert_eq!(out, vec![5, 7, 9, 2]);
    }

    #[test]
    fn avgpool_q_odd_divides_by_actual_count() {
        let x = [1, 2, 7]; // (3, 1)
        let mut out = Vec::new();
        avgpool_q(&x, &[3], 1, 2, &mut out);
        // [1,2] -> 3/2 = 1 (trunc); remainder [7] -> 7/1 = 7.
        assert_eq!(out, vec![1, 7]);
    }

    #[test]
    fn i32_fast_path_bit_identical_at_admission_boundary() {
        use crate::util::check::property;
        // Fuzz bias magnitude right around the accum_fits_i32 admission
        // threshold (i32::MAX / 2 headroom guard) with full-scale int8
        // operands, and pin the i32 lanes bit-identical to the i64 path
        // whenever the node is admitted — plus that admission itself
        // flips exactly at the boundary.
        property(200, |g| {
            let width = 8u32;
            let k = g.usize_in(1, 5);
            let c = g.usize_in(1, 4);
            let f = g.usize_in(1, 4);
            let s = g.usize_in(k, 8);
            let stride = g.usize_in(1, 2);
            let relu = g.bool();
            let taps = k * c;
            let max_prod = (1i64 << (width - 1)) * (1i64 << (width - 1));
            // Largest bias magnitude the guard still admits for this node.
            let boundary = i32::MAX as i64 / 2 - taps as i64 * max_prod;

            let w: Vec<i32> = (0..k * c * f).map(|_| g.i32_in(-128, 127)).collect();
            let x: Vec<i32> = (0..s * c).map(|_| g.i32_in(-128, 127)).collect();
            let shift = vec![g.i32_in(0, 20)];
            let sign = if g.bool() { 1i64 } else { -1 };

            // Just inside the boundary: must be admitted AND bit-exact.
            let b_in: Vec<i64> = (0..f)
                .map(|_| sign * (boundary - 1 - g.i32_in(0, 4096) as i64))
                .collect();
            let qw = QNodeWeights { w: w.clone(), w_n: vec![0], b_acc: b_in, shift: shift.clone() };
            crate::prop_assert!(
                super::accum_fits_i32(&qw, taps, width),
                "bias just under the boundary must be admitted (taps={taps})"
            );
            let (pad_lo, s_out) = (0usize, (s - k) / stride + 1);
            let mut fast = Vec::new();
            let mut wide = Vec::new();
            super::conv1d_q_i32(&x, s, c, &qw, k, f, stride, pad_lo, s_out, relu, width, &mut fast);
            super::conv1d_q_i64(&x, s, c, &qw, k, f, stride, pad_lo, s_out, relu, width, &mut wide);
            crate::prop_assert!(
                fast == wide,
                "i32/i64 divergence at taps={taps} f={f} shift={} fast={fast:?} wide={wide:?}",
                shift[0]
            );
            // And through the public entry point (which routes to i32 here).
            let mut routed = Vec::new();
            conv1d_q_ref(&x, s, c, &qw, k, f, stride, Padding::Valid, relu, width, &mut routed);
            crate::prop_assert!(routed == wide, "public conv1d_q_ref diverged from i64 reference");

            // At/over the boundary: the guard must reject the fast path.
            let b_out: Vec<i64> = (0..f)
                .map(|_| sign * (boundary + g.i32_in(0, 4096) as i64))
                .collect();
            let qw_out = QNodeWeights { w, w_n: vec![0], b_acc: b_out, shift };
            crate::prop_assert!(
                !super::accum_fits_i32(&qw_out, taps, width),
                "bias at the boundary must fall back to i64 (taps={taps})"
            );
            Ok(())
        });
    }

    #[test]
    fn embedding_q_gathers_and_clamps() {
        let table = [1, 2, 3, 4, 5, 6]; // (3, 2)
        let mut out = Vec::new();
        embedding_q(&[2, 0, 9, -1], &table, 2, &mut out);
        assert_eq!(out, vec![5, 6, 1, 2, 5, 6, 1, 2]);
    }

    #[test]
    fn softmax_q_uniform_rows_are_uniform() {
        let x = [37, 37, 37, 37];
        let mut out = Vec::new();
        softmax_q_ref(&x, 9, 15, 16, &mut out);
        // All distances are 0: p = (e << 15) / (4e) = 8192 exactly.
        assert_eq!(out, vec![8192; 4]);
    }

    #[test]
    fn softmax_q_orders_and_normalizes() {
        // Q4.3 inputs 0.0, 1.0, 2.0.
        let x = [0, 8, 16];
        let mut out = Vec::new();
        softmax_q_ref(&x, 3, 7, 8, &mut out);
        assert!(out[2] > out[1] && out[1] > out[0], "{out:?}");
        let sum: i64 = out.iter().map(|&p| p as i64).sum();
        // Truncating division loses at most 1 ulp per element.
        assert!((sum - 128).unsigned_abs() <= 3, "sum {sum}");
    }

    #[test]
    fn layernorm_q_zero_mean_unit_var_row() {
        // Payloads at n=8: [-1.0, 1.0] normalizes to itself.
        let x = [-256, 256];
        let gamma = [1 << 6, 1 << 6]; // 1.0 at g_n=6
        let beta = [0, 0];
        let mut out = Vec::new();
        layernorm_q_ref(&x, 2, &gamma, 6, &beta, 8, 16, &mut out);
        // Expect ±1.0 at n=8 = ±256, within LUT tolerance (1/128 relative).
        assert!((out[0] + 256).abs() <= 4, "{out:?}");
        assert!((out[1] - 256).abs() <= 4, "{out:?}");
    }

    #[test]
    fn layernorm_q_beta_offsets_output() {
        let x = [100, 100]; // constant row: d = 0 everywhere
        let gamma = [1 << 6; 2];
        let beta = [7, -9];
        let mut out = Vec::new();
        layernorm_q_ref(&x, 2, &gamma, 6, &beta, 8, 16, &mut out);
        assert_eq!(out, vec![7, -9]);
    }

    #[test]
    fn attention_q_uniform_when_q_is_zero() {
        use crate::quant::ptq::QTxWeights;
        // Wq = 0: every probability row is uniform, context = mean of V
        // rows; V = identity projection of x. All formats equal, shifts 0.
        let (seq, dm) = (2, 2);
        let zero = QNodeWeights { w: vec![0; 4], w_n: vec![0], b_acc: vec![0; 2], shift: vec![0] };
        let eye = QNodeWeights {
            w: vec![1, 0, 0, 1],
            w_n: vec![0],
            b_acc: vec![0; 2],
            shift: vec![0],
        };
        let tx = QTxWeights::Attn {
            wq: zero.clone(),
            wk: eye.clone(),
            wv: eye.clone(),
            wo: eye,
            n_q: 0,
            n_k: 0,
            n_v: 0,
            n_s: 15,
            n_p: 15,
            n_ctx: 0,
            inv_sqrt_hd_q15: (f64::powi(2.0, 15) / (dm as f64).sqrt()).round() as i32,
        };
        // ctx shift n_p + n_v - n_ctx = 15: ctx = (p·v) >> 15.
        let x = [10, 0, 0, 10];
        let mut out = Vec::new();
        attention_q_ref(&x, seq, dm, 1, dm, &tx, 16, &mut out);
        // Uniform probs ≈ 16384 each: ctx ≈ (16384*10 + 16384*0) >> 15 = 4 (floor of 5 - ulp).
        assert_eq!(out.len(), 4);
        let m = out[0];
        assert!(out.iter().all(|&v| (v - m).abs() <= 1), "{out:?}");
        assert!((4..=5).contains(&m), "{out:?}");
    }

    #[test]
    fn per_filter_shift_applied() {
        let x = [8];
        let q = QNodeWeights {
            w: vec![1, 1],
            w_n: vec![0, 0],
            b_acc: vec![0, 0],
            shift: vec![0, 3],
        };
        let mut out = Vec::new();
        conv1d_q_ref(&x, 1, 1, &q, 1, 2, 1, Padding::Same, false, 8, &mut out);
        assert_eq!(out, vec![8, 1]);
    }
}
