//! Soundness property tests for the verified memory plan (DESIGN.md §12):
//! replay real captured payloads through the planner's device-arena
//! layout and assert that EVERY intermediate read a node performs still
//! observes its producer's payload — i.e. every read lands inside the
//! buffer's proven live range, for all four engine arms. A planner or
//! checker bug that let a live buffer be clobbered (bad offset, bogus
//! in-place annotation, attention window overlap) fails these asserts
//! with the exact node and element.
//!
//! The second half pins the in-place executor arms end to end: batched
//! threaded sessions over the planned (coalesced, in-place) arena must
//! be bit-exact with per-example serial runs, across engines × widths
//! {8,16} × batch {1,7} × threads {1,4}.

use std::sync::Arc;

use crate::graph::ir::{Graph, LayerKind};
use crate::nn::session::SessionBuilder;
use crate::quant::{quantize, QuantSpec};
use crate::util::prng::Pcg32;

/// Replay `captured` (per-node single-example payloads, entry 0 = the
/// input) through the planner's offset layout. `None` cells are
/// never-written arena bytes; every read must observe the producer's
/// exact payload, which fails loudly if any earlier write — including the
/// attention stage windows scribbled mid-node — clobbered a live range.
fn simulate_device_arena<T: Copy + PartialEq + std::fmt::Debug>(
    graph: &Graph,
    captured: &[Vec<T>],
    window_garbage: T,
) {
    let alloc = crate::allocator::allocate(graph);
    crate::allocator::check_no_conflict(graph, &alloc).expect("shipped plan refused");
    let node_elems = crate::nn::session::node_elems(graph);
    let mut arena: Vec<Option<T>> = vec![None; alloc.arena_elems];
    let check_inputs = |arena: &[Option<T>], node: &crate::graph::ir::Node, when: &str| {
        for &i in &node.inputs {
            let off = alloc.offset_of[i];
            if off == usize::MAX {
                continue; // caller-owned input buffer
            }
            for (k, &v) in captured[i].iter().enumerate() {
                assert_eq!(
                    arena[off + k],
                    Some(v),
                    "{} reads node {i} outside its live range ({when}, elem {k})",
                    node.name
                );
            }
        }
    };
    for node in &graph.nodes {
        if matches!(node.kind, LayerKind::Input) {
            continue;
        }
        check_inputs(&arena, node, "before execute");
        if let Some(wins) = alloc.attn_scratch_of[node.id] {
            // The fused attention kernel fills q/k/v/ctx while it still
            // reads x: scribble the windows, then re-check the inputs.
            for w in wins {
                for k in 0..node_elems[node.id] {
                    arena[w + k] = Some(window_garbage);
                }
            }
            check_inputs(&arena, node, "after stage windows");
        }
        let off = alloc.offset_of[node.id];
        for (k, &v) in captured[node.id].iter().enumerate() {
            arena[off + k] = Some(v);
        }
    }
    // The output buffer's death is ∞: it must survive the whole schedule.
    let out = graph.output_id();
    let off = alloc.offset_of[out];
    for (k, &v) in captured[out].iter().enumerate() {
        assert_eq!(arena[off + k], Some(v), "output payload clobbered at elem {k}");
    }
}

/// Float twin of `int_exec::run_capture`: dedicated pools, sequential
/// offsets, no in-place lowering — every node's payload survives.
fn capture_float(graph: &Graph, input: &[f32]) -> Vec<Vec<f32>> {
    let n = graph.nodes.len();
    let node_elems = crate::nn::session::node_elems(graph);
    let mut pool_of: Vec<usize> = (0..n).collect();
    pool_of[0] = usize::MAX;
    let mut offset_of = vec![usize::MAX; n];
    let mut total = 0usize;
    for id in 1..n {
        offset_of[id] = total;
        total += node_elems[id];
    }
    let alloc = crate::allocator::Allocation {
        pool_of,
        pool_elems: node_elems.clone(),
        inplace_with: vec![None; n],
        offset_of,
        arena_elems: total,
        pooled_elems: total,
        attn_scratch_of: vec![None; n],
        gemm_scratch_elems: 0,
        packed_b_elems: 0,
    };
    let mut pools: Vec<Vec<f32>> = vec![Vec::new(); n];
    let pool = crate::nn::parallel::IntraOpPool::serial();
    let mut scratch = vec![Vec::new()];
    let mut output = Vec::new();
    let packed = crate::nn::packed::PackedWeights::empty(n);
    crate::nn::float_exec::run_pooled(
        graph, input, &alloc, &node_elems, &mut pools, &pool, &mut scratch, &packed, None,
        &mut output,
    );
    pools[0] = input.to_vec();
    pools
}

/// Randomized one-block transformer (the codegen fixture's shape) plus a
/// calibration/test id set.
fn transformer_fixture(seed: u64) -> (Graph, Vec<Vec<f32>>) {
    const VOCAB: u32 = 20;
    let mut g = crate::graph::build::transformer("tx", 10, VOCAB as usize, 16, 2, 1, 2, 4);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        match &mut n.kind {
            LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
            LayerKind::Embedding { w } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.5;
                }
            }
            LayerKind::LayerNorm { gamma, beta, .. } => {
                for v in gamma.iter_mut() {
                    *v = 1.0 + rng.normal() * 0.2;
                }
                for v in beta.iter_mut() {
                    *v = rng.normal() * 0.1;
                }
            }
            LayerKind::SelfAttention { w, .. } => {
                for t in [&mut w.wq, &mut w.wk, &mut w.wv, &mut w.wo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.3;
                    }
                }
                for t in [&mut w.bq, &mut w.bk, &mut w.bv, &mut w.bo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.05;
                    }
                }
            }
            _ => {}
        }
    }
    let g = crate::graph::deploy_pipeline(&g);
    let inputs: Vec<Vec<f32>> =
        (0..7).map(|_| (0..10).map(|_| rng.below(VOCAB) as f32).collect()).collect();
    (g, inputs)
}

fn resnet_fixture(seed: u64) -> (Graph, Vec<Vec<f32>>) {
    let g = crate::nn::int_exec::randomized_resnet(seed);
    let inputs = crate::nn::int_exec::random_inputs(7, 96, seed + 1);
    (g, inputs)
}

fn fixtures() -> Vec<(Graph, Vec<Vec<f32>>)> {
    vec![resnet_fixture(61), transformer_fixture(62)]
}

fn spec_for(width: u32) -> QuantSpec {
    if width == 8 { QuantSpec::int8_per_layer() } else { QuantSpec::int16_per_layer() }
}

#[test]
fn qmn_reads_stay_inside_proven_live_ranges() {
    for width in [8u32, 16] {
        for (g, inputs) in fixtures() {
            let stats = crate::nn::int_exec::calib(&g, &inputs);
            let qg = quantize(&g, &stats, spec_for(width));
            for x in inputs.iter().take(3) {
                let captured = crate::nn::int_exec::run_capture(&qg, x);
                simulate_device_arena(&g, &captured, i32::MIN);
            }
        }
    }
}

#[test]
fn affine_reads_stay_inside_proven_live_ranges() {
    for (g, inputs) in fixtures() {
        let stats = crate::nn::int_exec::calib(&g, &inputs);
        let aq = crate::quant::quantize_affine(&g, &stats);
        for x in inputs.iter().take(3) {
            let captured = crate::nn::affine_exec::run_capture(&aq, x);
            simulate_device_arena(&g, &captured, i32::MIN);
        }
    }
}

#[test]
fn float_reads_stay_inside_proven_live_ranges() {
    for (g, inputs) in fixtures() {
        for x in inputs.iter().take(3) {
            let captured = capture_float(&g, x);
            simulate_device_arena(&g, &captured, f32::NEG_INFINITY);
        }
    }
}

/// End-to-end pin across all four engine arms: the batch-7, 4-thread
/// session (folded GEMMs + flat in-place arms over the coalesced arena)
/// is BIT-exact with the serial per-example session (batch 1, 1 thread).
#[test]
fn batched_threaded_sessions_bit_exact_over_planned_arena() {
    for (g, inputs) in fixtures() {
        let flat: Vec<f32> = inputs.iter().flatten().copied().collect();
        let stats = crate::nn::int_exec::calib(&g, &inputs);

        // float32 arm
        let mut s1 = SessionBuilder::float32(g.clone()).build();
        let singles: Vec<f32> = inputs.iter().flat_map(|x| s1.run(x).to_vec()).collect();
        let mut s7 = SessionBuilder::float32(g.clone()).threads(4).max_batch(7).build();
        assert_eq!(singles, s7.run_batch(&flat), "float arm diverged");

        // fixed Qm.n arms at both deployed widths
        for width in [8u32, 16] {
            let qg = Arc::new(quantize(&g, &stats, spec_for(width)));
            let mut s1 = SessionBuilder::fixed_qmn(qg.clone()).build();
            let singles: Vec<f32> = inputs.iter().flat_map(|x| s1.run(x).to_vec()).collect();
            let mut s7 =
                SessionBuilder::fixed_qmn(qg.clone()).threads(4).max_batch(7).build();
            assert_eq!(singles, s7.run_batch(&flat), "qmn{width} arm diverged");
        }

        // affine int8 arm
        let aq = Arc::new(crate::quant::quantize_affine(&g, &stats));
        let mut s1 = SessionBuilder::affine_i8(aq.clone()).build();
        let singles: Vec<f32> = inputs.iter().flat_map(|x| s1.run(x).to_vec()).collect();
        let mut s7 = SessionBuilder::affine_i8(aq).threads(4).max_batch(7).build();
        assert_eq!(singles, s7.run_batch(&flat), "affine arm diverged");
    }
}
