//! Float32 layer implementations (channels-last), matching XLA semantics so
//! Rust-side inference reproduces the HLO `fwd` artifacts bit-for-bit up to
//! summation order.
//!
//! The conv/dense kernels here are the NAIVE REFERENCE implementations
//! (`*_ref`): the executors run the im2col + blocked-GEMM lowerings in
//! [`super::gemm`], which are property-tested ULP-close against these.

use crate::graph::ir::{AttnWeights, Padding};
use crate::graph::Graph;

/// 1-D convolution, reference kernel: x (S, C), w (K, C, F), b (F) ->
/// (S_out, F).
pub fn conv1d_ref(
    x: &[f32],
    s: usize,
    c: usize,
    w: &[f32],
    k: usize,
    f: usize,
    b: &[f32],
    stride: usize,
    padding: Padding,
    relu: bool,
    out: &mut Vec<f32>,
) -> usize {
    let (pad_lo, s_out) = match padding {
        Padding::Same => (Graph::same_padding(s, k, stride).0, s.div_ceil(stride)),
        Padding::Valid => (0, (s - k) / stride + 1),
    };
    out.clear();
    out.reserve(s_out * f);
    for o in 0..s_out {
        let base = (o * stride) as isize - pad_lo as isize;
        for fi in 0..f {
            let mut acc = b[fi];
            for ki in 0..k {
                let xi = base + ki as isize;
                if xi < 0 || xi >= s as isize {
                    continue;
                }
                let xrow = &x[(xi as usize) * c..(xi as usize + 1) * c];
                let wrow = &w[(ki * c) * f..];
                for ci in 0..c {
                    acc += xrow[ci] * wrow[ci * f + fi];
                }
            }
            out.push(if relu { acc.max(0.0) } else { acc });
        }
    }
    s_out
}

/// 2-D convolution, reference kernel: x (H, W, C), w (KH, KW, C, F),
/// b (F) -> (H_out, W_out, F).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_ref(
    x: &[f32],
    h: usize,
    wdt: usize,
    c: usize,
    w: &[f32],
    kh: usize,
    kw: usize,
    f: usize,
    b: &[f32],
    stride: usize,
    padding: Padding,
    relu: bool,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    let ((ph, _), h_out) = match padding {
        Padding::Same => (Graph::same_padding(h, kh, stride), h.div_ceil(stride)),
        Padding::Valid => ((0, 0), (h - kh) / stride + 1),
    };
    let ((pw, _), w_out) = match padding {
        Padding::Same => (Graph::same_padding(wdt, kw, stride), wdt.div_ceil(stride)),
        Padding::Valid => ((0, 0), (wdt - kw) / stride + 1),
    };
    out.clear();
    out.reserve(h_out * w_out * f);
    for oh in 0..h_out {
        let hbase = (oh * stride) as isize - ph as isize;
        for ow in 0..w_out {
            let wbase = (ow * stride) as isize - pw as isize;
            for fi in 0..f {
                let mut acc = b[fi];
                for ki in 0..kh {
                    let hi = hbase + ki as isize;
                    if hi < 0 || hi >= h as isize {
                        continue;
                    }
                    for kj in 0..kw {
                        let wi = wbase + kj as isize;
                        if wi < 0 || wi >= wdt as isize {
                            continue;
                        }
                        let xrow = &x[((hi as usize) * wdt + wi as usize) * c..];
                        let wrow = &w[((ki * kw + kj) * c) * f..];
                        for ci in 0..c {
                            acc += xrow[ci] * wrow[ci * f + fi];
                        }
                    }
                }
                out.push(if relu { acc.max(0.0) } else { acc });
            }
        }
    }
    (h_out, w_out)
}

/// Dense, reference kernel: x (I,), w (I, O), b (O) -> (O,).
pub fn dense_ref(x: &[f32], w: &[f32], b: &[f32], o: usize, relu: bool, out: &mut Vec<f32>) {
    let i = x.len();
    out.clear();
    out.reserve(o);
    for oi in 0..o {
        let mut acc = b[oi];
        for ii in 0..i {
            acc += x[ii] * w[ii * o + oi];
        }
        out.push(if relu { acc.max(0.0) } else { acc });
    }
}

/// Max pooling over `spatial` dims, stride == size, fused ReLU option.
/// SAME-style windows: odd dims keep a remainder window over the actual
/// in-range samples (`Graph::pool_geometry`) instead of dropping them.
pub fn maxpool(x: &[f32], spatial: &[usize], c: usize, size: usize, relu: bool, out: &mut Vec<f32>) {
    out.clear();
    match spatial.len() {
        1 => {
            let s = spatial[0];
            let (lo, s_out) = Graph::pool_geometry(s, size);
            for o in 0..s_out {
                let (x_lo, x_hi) = Graph::pool_window(o, size, lo, s);
                for ci in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for xi in x_lo..x_hi {
                        m = m.max(x[xi * c + ci]);
                    }
                    out.push(if relu { m.max(0.0) } else { m });
                }
            }
        }
        2 => {
            let (h, w) = (spatial[0], spatial[1]);
            let (hlo, ho) = Graph::pool_geometry(h, size);
            let (wlo, wo) = Graph::pool_geometry(w, size);
            for oh in 0..ho {
                let (h_lo, h_hi) = Graph::pool_window(oh, size, hlo, h);
                for ow in 0..wo {
                    let (w_lo, w_hi) = Graph::pool_window(ow, size, wlo, w);
                    for ci in 0..c {
                        let mut m = f32::NEG_INFINITY;
                        for hi in h_lo..h_hi {
                            for wi in w_lo..w_hi {
                                m = m.max(x[(hi * w + wi) * c + ci]);
                            }
                        }
                        out.push(if relu { m.max(0.0) } else { m });
                    }
                }
            }
        }
        r => panic!("maxpool rank {r}"),
    }
}

/// Average pooling, stride == size; SAME-style remainder windows average
/// over the actual in-range sample count (padding excluded).
pub fn avgpool(x: &[f32], spatial: &[usize], c: usize, size: usize, out: &mut Vec<f32>) {
    out.clear();
    match spatial.len() {
        1 => {
            let s = spatial[0];
            let (lo, s_out) = Graph::pool_geometry(s, size);
            for o in 0..s_out {
                let (x_lo, x_hi) = Graph::pool_window(o, size, lo, s);
                let denom = (x_hi - x_lo) as f32;
                for ci in 0..c {
                    let mut a = 0.0;
                    for xi in x_lo..x_hi {
                        a += x[xi * c + ci];
                    }
                    out.push(a / denom);
                }
            }
        }
        2 => {
            let (h, w) = (spatial[0], spatial[1]);
            let (hlo, ho) = Graph::pool_geometry(h, size);
            let (wlo, wo) = Graph::pool_geometry(w, size);
            for oh in 0..ho {
                let (h_lo, h_hi) = Graph::pool_window(oh, size, hlo, h);
                for ow in 0..wo {
                    let (w_lo, w_hi) = Graph::pool_window(ow, size, wlo, w);
                    let denom = ((h_hi - h_lo) * (w_hi - w_lo)) as f32;
                    for ci in 0..c {
                        let mut a = 0.0;
                        for hi in h_lo..h_hi {
                            for wi in w_lo..w_hi {
                                a += x[(hi * w + wi) * c + ci];
                            }
                        }
                        out.push(a / denom);
                    }
                }
            }
        }
        r => panic!("avgpool rank {r}"),
    }
}

/// Global average pool: mean over all spatial positions per channel.
pub fn global_avgpool(x: &[f32], positions: usize, c: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(c, 0.0);
    for p in 0..positions {
        for ci in 0..c {
            out[ci] += x[p * c + ci];
        }
    }
    for v in out.iter_mut() {
        *v /= positions as f32;
    }
}

/// Element-wise add with optional fused ReLU.
pub fn add(a: &[f32], b: &[f32], relu: bool, out: &mut Vec<f32>) {
    out.clear();
    out.extend(a.iter().zip(b.iter()).map(|(&x, &y)| {
        let s = x + y;
        if relu {
            s.max(0.0)
        } else {
            s
        }
    }));
}

/// In-place [`add`] for the planner's aliased residuals (DESIGN.md
/// §12): IEEE f32 addition is commutative, so one kernel serves
/// whichever operand the planner aliased, bitwise equal to [`add`].
pub fn add_inplace(acc: &mut [f32], other: &[f32], relu: bool) {
    for (a, &y) in acc.iter_mut().zip(other.iter()) {
        let s = *a + y;
        *a = if relu { s.max(0.0) } else { s };
    }
}

pub fn relu(x: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.extend(x.iter().map(|&v| v.max(0.0)));
}

/// In-place [`relu`] (element-wise, trivially alias-safe).
pub fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.max(0.0);
    }
}

pub fn softmax(x: &[f32], out: &mut Vec<f32>) {
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    let exps: Vec<f32> = x.iter().map(|&v| (v - m).exp()).collect();
    let sum: f32 = exps.iter().sum();
    out.clear();
    out.extend(exps.iter().map(|&e| e / sum));
}

/// In-place [`softmax`]: max read-only, exp rewrites each element from
/// its own value, the sum runs over the SAME values in the SAME order
/// as the two-buffer kernel's `exps` vector, and the divide is
/// element-wise — bitwise identical output.
pub fn softmax_inplace(x: &mut [f32]) {
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    for v in x.iter_mut() {
        *v = (*v - m).exp();
    }
    let sum: f32 = x.iter().sum();
    for v in x.iter_mut() {
        *v /= sum;
    }
}

/// Embedding gather: ids (S, 1) — integer token ids carried as f32 — and
/// table (V, D) -> (S, D). Out-of-range ids clamp to the table edge (the
/// integer engines do the same, so all backends agree on malformed input).
pub fn embedding(ids: &[f32], table: &[f32], d: usize, out: &mut Vec<f32>) {
    let vocab = table.len() / d;
    out.clear();
    out.reserve(ids.len() * d);
    for &id in ids {
        let i = (id.round() as isize).clamp(0, vocab as isize - 1) as usize;
        out.extend_from_slice(&table[i * d..(i + 1) * d]);
    }
}

/// In-place [`embedding`]: `buf` holds the f32-carried ids and leaves
/// holding the gathered rows. Descending walk — position `t` writes
/// `[t*d, (t+1)*d)` after reading id `t`, and unread ids sit at
/// `t' < t <= t*d` — so growth over the alias is safe (DESIGN.md §12).
pub fn embedding_inplace(buf: &mut Vec<f32>, table: &[f32], d: usize) {
    let n = buf.len();
    let vocab = table.len() / d;
    buf.resize(n * d, 0.0);
    for t in (0..n).rev() {
        let i = (buf[t].round() as isize).clamp(0, vocab as isize - 1) as usize;
        buf[t * d..(t + 1) * d].copy_from_slice(&table[i * d..(i + 1) * d]);
    }
}

/// LayerNorm over the channel (last) axis: x (P, C) -> (P, C).
pub fn layernorm(x: &[f32], c: usize, gamma: &[f32], beta: &[f32], eps: f32, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(x.len());
    for row in x.chunks_exact(c) {
        let mean = row.iter().sum::<f32>() / c as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
        let r = 1.0 / (var + eps).sqrt();
        for (ci, &v) in row.iter().enumerate() {
            out.push((v - mean) * r * gamma[ci] + beta[ci]);
        }
    }
}

/// Internal activations of one self-attention node. The executors use the
/// fields as a reusable workspace; calibration reads them afterwards to
/// derive the fixed-point formats of the Q/K/V projections, the scaled
/// pre-softmax scores, and the concatenated head context.
#[derive(Clone, Debug, Default)]
pub struct AttnTmp {
    pub q: Vec<f32>,      // (S, D)
    pub k: Vec<f32>,      // (S, D)
    pub v: Vec<f32>,      // (S, D)
    pub scores: Vec<f32>, // (H, S, S) scaled, pre-softmax
    pub ctx: Vec<f32>,    // (S, D) concatenated head outputs, pre-Wo
}

/// Position-wise dense: x (S, D) with w (D, O), b (O) -> (S, O). The GEMM
/// executors lower this onto `gemm::dense`-shaped calls with m = S.
pub fn project(x: &[f32], d: usize, w: &[f32], b: &[f32], o: usize, out: &mut Vec<f32>) {
    out.clear();
    out.reserve((x.len() / d) * o);
    for row in x.chunks_exact(d) {
        for oi in 0..o {
            let mut acc = b[oi];
            for (ii, &xv) in row.iter().enumerate() {
                acc += xv * w[ii * o + oi];
            }
            out.push(acc);
        }
    }
}

/// Multi-head self-attention, reference kernel: x (S, D) -> (S, D) with
/// D = heads * hd. Scores are scaled by 1/sqrt(hd) before the row softmax.
#[allow(clippy::too_many_arguments)]
pub fn self_attention_ref(
    x: &[f32],
    seq: usize,
    dm: usize,
    heads: usize,
    hd: usize,
    w: &AttnWeights,
    tmp: &mut AttnTmp,
    out: &mut Vec<f32>,
) {
    project(x, dm, &w.wq.data, &w.bq.data, dm, &mut tmp.q);
    project(x, dm, &w.wk.data, &w.bk.data, dm, &mut tmp.k);
    project(x, dm, &w.wv.data, &w.bv.data, dm, &mut tmp.v);
    let scale = 1.0 / (hd as f32).sqrt();
    tmp.scores.clear();
    tmp.scores.reserve(heads * seq * seq);
    tmp.ctx.clear();
    tmp.ctx.resize(seq * dm, 0.0);
    let mut probs = vec![0.0f32; seq];
    for h in 0..heads {
        let off = h * hd;
        for i in 0..seq {
            let qrow = &tmp.q[i * dm + off..i * dm + off + hd];
            for j in 0..seq {
                let krow = &tmp.k[j * dm + off..j * dm + off + hd];
                let dot: f32 = qrow.iter().zip(krow).map(|(&a, &b)| a * b).sum();
                tmp.scores.push(dot * scale);
            }
            let row = &tmp.scores[(h * seq + i) * seq..(h * seq + i + 1) * seq];
            softmax(row, &mut probs);
            for (j, &p) in probs.iter().enumerate() {
                let vrow = &tmp.v[j * dm + off..j * dm + off + hd];
                let crow = &mut tmp.ctx[i * dm + off..i * dm + off + hd];
                for (cv, &vv) in crow.iter_mut().zip(vrow) {
                    *cv += p * vv;
                }
            }
        }
    }
    project(&tmp.ctx, dm, &w.wo.data, &w.bo.data, dm, out);
}

/// BatchNorm as affine y = w*x + b per channel.
pub fn batchnorm_affine(x: &[f32], c: usize, w: &[f32], b: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(x.len());
    for (i, &v) in x.iter().enumerate() {
        let ci = i % c;
        out.push(v * w[ci] + b[ci]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv1d_identity_kernel() {
        // k=1 identity over 2 channels.
        let x = [1.0, 2.0, 3.0, 4.0]; // (2, 2)
        let w = [1.0, 0.0, 0.0, 1.0]; // (1, 2, 2) identity
        let b = [0.0, 0.0];
        let mut out = Vec::new();
        let s_out = conv1d_ref(&x, 2, 2, &w, 1, 2, &b, 1, Padding::Same, false, &mut out);
        assert_eq!(s_out, 2);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn conv1d_same_padding_sums() {
        // k=3 all-ones kernel, single channel: y[i] = x[i-1] + x[i] + x[i+1]
        let x = [1.0, 2.0, 3.0, 4.0];
        let w = [1.0, 1.0, 1.0];
        let b = [0.0];
        let mut out = Vec::new();
        conv1d_ref(&x, 4, 1, &w, 3, 1, &b, 1, Padding::Same, false, &mut out);
        assert_eq!(out, vec![3.0, 6.0, 9.0, 7.0]);
    }

    #[test]
    fn conv1d_stride2_same() {
        let x = [1.0; 9];
        let w = [1.0, 1.0, 1.0];
        let b = [0.0];
        let mut out = Vec::new();
        let s_out = conv1d_ref(&x, 9, 1, &w, 3, 1, &b, 2, Padding::Same, false, &mut out);
        assert_eq!(s_out, 5); // ceil(9/2)
    }

    #[test]
    fn conv_relu_fusion() {
        let x = [-1.0, -2.0];
        let w = [1.0];
        let b = [0.0];
        let mut out = Vec::new();
        conv1d_ref(&x, 2, 1, &w, 1, 1, &b, 1, Padding::Same, true, &mut out);
        assert_eq!(out, vec![0.0, 0.0]);
    }

    #[test]
    fn dense_matches_manual() {
        let x = [1.0, 2.0];
        let w = [1.0, 3.0, 2.0, 4.0]; // (2, 2): w[i][o]
        let b = [0.5, -0.5];
        let mut out = Vec::new();
        dense_ref(&x, &w, &b, 2, false, &mut out);
        assert_eq!(out, vec![1.0 + 4.0 + 0.5, 3.0 + 8.0 - 0.5]);
    }

    #[test]
    fn maxpool_1d_keeps_remainder_window() {
        let x = [1.0, 5.0, 3.0, 2.0, 9.0, 0.0]; // (3, 2)
        let mut out = Vec::new();
        maxpool(&x, &[3], 2, 2, false, &mut out);
        // Window [0,2) then the remainder window [2,3) — pre-fix the last
        // row was silently dropped and the output was [3.0, 5.0].
        assert_eq!(out, vec![3.0, 5.0, 9.0, 0.0]);
    }

    #[test]
    fn maxpool_2d() {
        #[rustfmt::skip]
        let x = [
            1.0, 2.0,
            3.0, 4.0,
        ]; // (2, 2, 1)
        let mut out = Vec::new();
        maxpool(&x, &[2, 2], 1, 2, false, &mut out);
        assert_eq!(out, vec![4.0]);
    }

    #[test]
    fn maxpool_2d_odd_keeps_remainder() {
        #[rustfmt::skip]
        let x = [
            1.0, 2.0, 9.0,
            3.0, 4.0, 0.0,
            7.0, 1.0, 5.0,
        ]; // (3, 3, 1)
        let mut out = Vec::new();
        maxpool(&x, &[3, 3], 1, 2, false, &mut out);
        // Windows: [0..2)x[0..2) = 4, [0..2)x[2..3) = 9,
        //          [2..3)x[0..2) = 7, [2..3)x[2..3) = 5.
        assert_eq!(out, vec![4.0, 9.0, 7.0, 5.0]);
    }

    #[test]
    fn global_avgpool_means() {
        let x = [1.0, 10.0, 3.0, 20.0]; // (2, 2)
        let mut out = Vec::new();
        global_avgpool(&x, 2, 2, &mut out);
        assert_eq!(out, vec![2.0, 15.0]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut out = Vec::new();
        softmax(&[1.0, 2.0, 3.0], &mut out);
        let s: f32 = out.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(out[2] > out[1] && out[1] > out[0]);
    }

    #[test]
    fn add_and_relu() {
        let mut out = Vec::new();
        add(&[1.0, -3.0], &[1.0, 1.0], true, &mut out);
        assert_eq!(out, vec![2.0, 0.0]);
        relu(&[-1.0, 2.0], &mut out);
        assert_eq!(out, vec![0.0, 2.0]);
    }

    #[test]
    fn avgpool_1d() {
        let x = [2.0, 4.0, 6.0, 8.0]; // (4,1)
        let mut out = Vec::new();
        avgpool(&x, &[4], 1, 2, &mut out);
        assert_eq!(out, vec![3.0, 7.0]);
    }

    #[test]
    fn avgpool_1d_odd_averages_actual_count() {
        let x = [2.0, 4.0, 6.0, 8.0, 10.0]; // (5,1)
        let mut out = Vec::new();
        avgpool(&x, &[5], 1, 2, &mut out);
        // Remainder window holds one sample; its average is that sample,
        // not sample/size.
        assert_eq!(out, vec![3.0, 7.0, 10.0]);
    }

    #[test]
    fn embedding_gathers_and_clamps() {
        let table = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // (3, 2)
        let mut out = Vec::new();
        embedding(&[2.0, 0.0, 9.0, -1.0], &table, 2, &mut out);
        // id 9 and -1 clamp to the last/first row.
        assert_eq!(out, vec![5.0, 6.0, 1.0, 2.0, 5.0, 6.0, 1.0, 2.0]);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = [1.0, 3.0, -2.0, 2.0]; // (2, 2)
        let mut out = Vec::new();
        layernorm(&x, 2, &[1.0, 1.0], &[0.0, 0.0], 1e-5, &mut out);
        for row in out.chunks_exact(2) {
            let mean: f32 = row.iter().sum::<f32>() / 2.0;
            let var: f32 = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / 2.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn layernorm_applies_gamma_beta() {
        let x = [1.0, 3.0];
        let mut out = Vec::new();
        layernorm(&x, 2, &[2.0, 0.5], &[1.0, -1.0], 0.0, &mut out);
        // normalized row is [-1, 1].
        assert!((out[0] - (-2.0 + 1.0)).abs() < 1e-4);
        assert!((out[1] - (0.5 - 1.0)).abs() < 1e-4);
    }

    #[test]
    fn attention_uniform_when_queries_zero() {
        use crate::tensor::Tensor;
        // Wq = 0 makes every score row uniform: context = mean of V rows.
        let (seq, dm) = (3, 2);
        let eye = Tensor::from_vec(&[dm, dm], vec![1.0, 0.0, 0.0, 1.0]);
        let zero_w = Tensor::from_vec(&[dm, dm], vec![0.0; dm * dm]);
        let zero_b = Tensor::from_vec(&[dm], vec![0.0; dm]);
        let w = AttnWeights {
            wq: zero_w.clone(),
            bq: zero_b.clone(),
            wk: eye.clone(),
            bk: zero_b.clone(),
            wv: eye.clone(),
            bv: zero_b.clone(),
            wo: eye,
            bo: zero_b,
        };
        let x = [3.0, 0.0, 0.0, 3.0, 3.0, 3.0];
        let (mut tmp, mut out) = (AttnTmp::default(), Vec::new());
        self_attention_ref(&x, seq, dm, 1, dm, &w, &mut tmp, &mut out);
        for row in out.chunks_exact(dm) {
            assert!((row[0] - 2.0).abs() < 1e-5);
            assert!((row[1] - 2.0).abs() < 1e-5);
        }
    }

    #[test]
    fn batchnorm_affine_applies_per_channel() {
        let x = [1.0, 2.0, 3.0, 4.0]; // (2, 2)
        let mut out = Vec::new();
        batchnorm_affine(&x, 2, &[2.0, 0.5], &[0.0, 1.0], &mut out);
        assert_eq!(out, vec![2.0, 2.0, 6.0, 3.0]);
    }
}
