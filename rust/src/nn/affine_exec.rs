//! TFLite-semantics affine int8 executor (Appendix B baseline + the
//! Cube.AI engine model's numeric core): zero-point-corrected MACCs in
//! int32, gemmlowp requantization per filter, asymmetric activations.
//!
//! The conv/dense kernels here are the NAIVE REFERENCE implementations
//! (`*_ref`): the executor runs the im2col + blocked-GEMM lowerings in
//! [`super::gemm`] (zero-point pre-subtracted at pack time), which are
//! property-tested bit-exact against these.

use crate::fixedpoint::lut::{exp_q, rsqrt_norm};
use crate::fixedpoint::ops::rescale;
use crate::graph::ir::{LayerKind, Padding};
use crate::graph::Graph;
use crate::quant::affine::{
    decompose, requantize, AffineNodeWeights, AffineQuantizedGraph, AffineTxWeights,
};

use super::gemm;

/// Execute the affine-quantized graph on a float input; returns float
/// logits (dequantized at the output tensor's affine params).
///
/// Deprecated in favour of [`crate::nn::session::Session`]: this wrapper
/// re-runs the §5.7 lifetime analysis and reallocates the activation
/// pools on every call. A `Session` does both once and reuses the arena
/// across `run` calls.
pub fn run(aq: &AffineQuantizedGraph, input: &[f32]) -> Vec<f32> {
    let graph = &aq.graph;
    let alloc = crate::allocator::allocate(graph);
    let node_elems = crate::nn::session::node_elems(graph);
    let mut pools: Vec<Vec<i32>> = vec![Vec::new(); alloc.n_pools()];
    let mut qinput = Vec::new();
    let pool = crate::nn::parallel::IntraOpPool::serial();
    let mut scratch = vec![Vec::new()];
    let mut output = Vec::new();
    // Legacy per-call semantics: zero-point subtraction at pack/stage
    // time (bit-identical to the prepacked fold either way).
    let packed = crate::nn::packed::PackedWeights::empty(graph.nodes.len());
    run_pooled(
        aq, input, &alloc, &node_elems, &mut qinput, &mut pools, &pool, &mut scratch, &packed,
        &mut output,
    );
    output
}

/// Capture run for the range-verifier soundness tests (see
/// `int_exec::run_capture`): one dedicated pool per node, payloads
/// returned indexed by node id (entry 0 = the quantized input).
#[cfg(test)]
pub(crate) fn run_capture(aq: &AffineQuantizedGraph, input: &[f32]) -> Vec<Vec<i32>> {
    let graph = &aq.graph;
    let n = graph.nodes.len();
    let node_elems = crate::nn::session::node_elems(graph);
    let mut pool_of: Vec<usize> = (0..n).collect();
    pool_of[0] = usize::MAX; // Input payloads live in qinput
    // Dedicated pools and a sequential device layout, no in-place
    // lowering: every node's payload survives for inspection. (This
    // synthetic plan drives the pools only; it is never checker-gated.)
    let mut offset_of = vec![usize::MAX; n];
    let mut total = 0usize;
    for id in 1..n {
        offset_of[id] = total;
        total += node_elems[id];
    }
    let alloc = crate::allocator::Allocation {
        pool_of,
        pool_elems: node_elems.clone(),
        inplace_with: vec![None; n],
        offset_of,
        arena_elems: total,
        pooled_elems: total,
        attn_scratch_of: vec![None; n],
        gemm_scratch_elems: 0,
        packed_b_elems: 0,
    };
    let mut pools: Vec<Vec<i32>> = vec![Vec::new(); n];
    let mut qinput = Vec::new();
    let pool = crate::nn::parallel::IntraOpPool::serial();
    let mut scratch = vec![Vec::new()];
    let mut output = Vec::new();
    let packed = crate::nn::packed::PackedWeights::empty(n);
    run_pooled(
        aq, input, &alloc, &node_elems, &mut qinput, &mut pools, &pool, &mut scratch, &packed,
        &mut output,
    );
    pools[0] = qinput;
    pools
}

/// Pooled core shared by [`run`] and the affine [`crate::nn::session`]
/// backend (see `int_exec::run_pooled` for the pool discipline; `scratch`
/// carries one packing slab per intra-op thread of `pool`). Conv/dense
/// nodes present in `packed` run the prepacked kernels with the zero
/// point folded into the packed bias at build time — no per-call
/// `x − zp` packing or staging, and `aq.weights` is never read; absent
/// nodes keep the per-call zero-point-shifted GEMM lowering.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_pooled(
    aq: &AffineQuantizedGraph,
    input: &[f32],
    alloc: &crate::allocator::Allocation,
    node_elems: &[usize],
    qinput: &mut Vec<i32>,
    pools: &mut [Vec<i32>],
    pool: &crate::nn::parallel::IntraOpPool,
    scratch: &mut [Vec<i32>],
    packed: &crate::nn::packed::PackedWeights,
    output: &mut Vec<f32>,
) {
    let graph = &aq.graph;
    assert_eq!(input.len(), graph.input_shape.iter().product::<usize>());

    let in_params = aq.act[0];
    qinput.clear();
    qinput.extend(input.iter().map(|&x| in_params.quantize(x)));

    for node in &graph.nodes {
        if matches!(node.kind, LayerKind::Input) {
            continue;
        }
        let p = alloc.pool_of[node.id];
        if let Some(s) = alloc.inplace_with[node.id] {
            // In-place lowering: the slot already holds input `s`'s
            // payload (same class ⇒ same slot); mutate it directly.
            let mut buf = std::mem::take(&mut pools[p]);
            exec_node_inplace(aq, node, s, 1, qinput, pools, &alloc.pool_of, node_elems, &mut buf);
            pools[p] = buf;
            continue;
        }
        let mut out = std::mem::take(&mut pools[p]);
        {
            let qin: &[i32] = qinput;
            let src = |i: usize| {
                crate::nn::session::pool_src(pools, qin, &alloc.pool_of, node_elems, i)
            };
            exec_node(aq, node, &src, packed, pool, scratch, &mut out);
        }
        pools[p] = out;
    }

    dequantize_output(aq, alloc, node_elems, qinput, pools, 1, output);
}

/// Batch-folded twin of [`run_pooled`] — see `int_exec::run_pooled_batch`
/// for the fold criteria and the bit-exactness argument (the prepacked
/// affine kernels are the same `PackedB::I32/I64` + `BiasRequant` core,
/// so the same M-dimension/leading-spatial-axis stacking applies).
/// Unfoldable layers loop per example through the shared [`exec_node`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_pooled_batch(
    aq: &AffineQuantizedGraph,
    inputs: &[f32],
    batch: usize,
    alloc: &crate::allocator::Allocation,
    node_elems: &[usize],
    qinput: &mut Vec<i32>,
    pools: &mut [Vec<i32>],
    pool: &crate::nn::parallel::IntraOpPool,
    scratch: &mut [Vec<i32>],
    packed: &crate::nn::packed::PackedWeights,
    tmp: &mut Vec<i32>,
    output: &mut Vec<f32>,
) {
    if batch <= 1 {
        // Single example: the per-example driver IS the folded path
        // (no per-node fold dispatch to pay for).
        return run_pooled(
            aq, inputs, alloc, node_elems, qinput, pools, pool, scratch, packed, output,
        );
    }
    let graph = &aq.graph;
    let ilen: usize = graph.input_shape.iter().product();
    assert_eq!(inputs.len(), batch * ilen, "ragged batch");

    let in_params = aq.act[0];
    qinput.clear();
    qinput.extend(inputs.iter().map(|&x| in_params.quantize(x)));

    for node in &graph.nodes {
        if matches!(node.kind, LayerKind::Input) {
            continue;
        }
        let p = alloc.pool_of[node.id];
        let ne = node_elems[node.id];
        if let Some(s) = alloc.inplace_with[node.id] {
            // In-place lowering over the example-major slot (flat for
            // elementwise arms, per-example rows for softmax).
            let mut buf = std::mem::take(&mut pools[p]);
            exec_node_inplace(
                aq, node, s, batch, qinput, pools, &alloc.pool_of, node_elems, &mut buf,
            );
            pools[p] = buf;
            continue;
        }
        let mut out = std::mem::take(&mut pools[p]);
        let folded = {
            let qin: &[i32] = qinput;
            // Whole-batch producer slice: example-major payloads are
            // contiguous, so a folded GEMM reads them as one A matrix.
            let whole = |i: usize| {
                let q = alloc.pool_of[i];
                if q == usize::MAX {
                    qin
                } else {
                    &pools[q][..batch * node_elems[i]]
                }
            };
            match (&node.kind, packed.get(node.id)) {
                (LayerKind::Dense { .. }, Some(pn)) => {
                    crate::nn::packed::dense_int_batched(
                        whole(node.inputs[0]), batch, pn, pool, &mut out,
                    );
                    true
                }
                (LayerKind::Conv { stride: 1, padding, .. }, Some(pn))
                    if pn.ks.iter().all(|&k| k == 1) =>
                {
                    // Stride-1 1×1 conv is pointwise: concatenating the
                    // batch along the leading spatial axis runs the whole
                    // micro-batch as one call (see int_exec for why this
                    // is the example-major concatenation, bit-identical).
                    let ish = &graph.nodes[node.inputs[0]].out_shape;
                    if graph.dims == 1 {
                        crate::nn::packed::conv1d_int_packed(
                            whole(node.inputs[0]), batch * ish[0], pn, 1, *padding, pool,
                            scratch, &mut out,
                        );
                    } else {
                        crate::nn::packed::conv2d_int_packed(
                            whole(node.inputs[0]), batch * ish[0], ish[1], pn, 1, *padding,
                            pool, scratch, &mut out,
                        );
                    }
                    true
                }
                _ => false,
            }
        };
        if !folded {
            out.clear();
            out.resize(batch * ne, 0);
            for ex in 0..batch {
                {
                    let qin: &[i32] = qinput;
                    let src = |i: usize| {
                        let q = alloc.pool_of[i];
                        if q == usize::MAX {
                            &qin[ex * ilen..(ex + 1) * ilen]
                        } else {
                            let nei = node_elems[i];
                            &pools[q][ex * nei..(ex + 1) * nei]
                        }
                    };
                    exec_node(aq, node, &src, packed, pool, scratch, tmp);
                }
                out[ex * ne..(ex + 1) * ne].copy_from_slice(tmp);
            }
        }
        pools[p] = out;
    }

    dequantize_output(aq, alloc, node_elems, qinput, pools, batch, output);
}

/// One node's single-example compute, shared verbatim by the per-example
/// driver ([`run_pooled`]) and the unfoldable arm of the batch-folded
/// driver ([`run_pooled_batch`]) — the batched path inherits every
/// property pinned on this code.
fn exec_node<'a>(
    aq: &AffineQuantizedGraph,
    node: &crate::graph::ir::Node,
    src: &dyn Fn(usize) -> &'a [i32],
    packed: &crate::nn::packed::PackedWeights,
    pool: &crate::nn::parallel::IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) {
    let graph = &aq.graph;
    match &node.kind {
        LayerKind::Input => unreachable!(),
        LayerKind::Conv { w, stride, padding, .. } => {
            let src_id = node.inputs[0];
            let ish = &graph.nodes[src_id].out_shape;
            if let Some(pn) = packed.get(node.id) {
                if graph.dims == 1 {
                    crate::nn::packed::conv1d_int_packed(
                        src(src_id), ish[0], pn, *stride, *padding, pool, scratch, out,
                    );
                } else {
                    crate::nn::packed::conv2d_int_packed(
                        src(src_id), ish[0], ish[1], pn, *stride, *padding, pool, scratch,
                        out,
                    );
                }
            } else {
                gemm::conv_affine_gemm(
                    src(src_id), ish, &w.shape, &aq.weights[&node.id],
                    aq.act[src_id].zero_point, aq.act[node.id].zero_point,
                    *stride, *padding, node.fused_relu, graph.dims, pool, scratch, out,
                );
            }
        }
        LayerKind::Dense { w, .. } => {
            let src_id = node.inputs[0];
            if let Some(pn) = packed.get(node.id) {
                crate::nn::packed::dense_int_packed(src(src_id), pn, pool, out);
            } else {
                gemm::dense_affine_gemm(
                    src(src_id), &aq.weights[&node.id],
                    aq.act[src_id].zero_point, aq.act[node.id].zero_point,
                    w.shape[1], node.fused_relu, pool, scratch, out,
                );
            }
        }
        LayerKind::MaxPool { size } => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let c = *ish.last().unwrap();
            crate::nn::int_ops::maxpool_q(
                src(node.inputs[0]), &ish[..ish.len() - 1], c, *size, false, out,
            );
            if node.fused_relu {
                let zp = aq.act[node.id].zero_point;
                for v in out.iter_mut() {
                    *v = (*v).max(zp);
                }
            }
        }
        LayerKind::GlobalAvgPool => {
            // Mean of payloads; zero point is unchanged (same params in
            // and out — TFLite AVERAGE_POOL_2D requirement).
            // Channel-major accumulation: no per-request allocation.
            let x = src(node.inputs[0]);
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let c = *ish.last().unwrap();
            let positions: usize = ish[..ish.len() - 1].iter().product();
            out.clear();
            out.reserve(c);
            let n = positions as i64;
            for ci in 0..c {
                let mut s = 0i64;
                for p in 0..positions {
                    s += x[p * c + ci] as i64;
                }
                // Round-to-nearest division, per TFLite.
                let r = if s >= 0 { (s + n / 2) / n } else { (s - n / 2) / n };
                out.push(r.clamp(-128, 127) as i32);
            }
        }
        LayerKind::AvgPool { size } => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let c = *ish.last().unwrap();
            crate::nn::int_ops::avgpool_q(
                src(node.inputs[0]), &ish[..ish.len() - 1], c, *size, out,
            );
        }
        LayerKind::Add => {
            add_affine(
                aq, node.id, node.inputs[0], node.inputs[1],
                src(node.inputs[0]), src(node.inputs[1]),
                node.fused_relu, out,
            );
        }
        LayerKind::ReLU => {
            let zp = aq.act[node.id].zero_point;
            out.clear();
            out.extend(src(node.inputs[0]).iter().map(|&v| v.max(zp)));
        }
        LayerKind::Flatten => {
            out.clear();
            out.extend_from_slice(src(node.inputs[0]));
        }
        LayerKind::Softmax => {
            // Node-level softmax: decompose the input scale at
            // dispatch time (tiny final node; the attention-
            // internal softmaxes carry theirs in the Attn params).
            let (m, sh) = decompose(aq.act[node.inputs[0]].scale as f64);
            softmax_affine_ref(src(node.inputs[0]), m, sh, out);
        }
        LayerKind::Embedding { w } => {
            let AffineTxWeights::Embed { table } = &aq.tx[&node.id] else {
                panic!("embedding node without Embed params");
            };
            // Ids quantize as identity (scale 1, zp 0), so the
            // payload gather is the fixed-point one.
            crate::nn::int_ops::embedding_q(src(node.inputs[0]), table, w.shape[1], out);
        }
        LayerKind::LayerNorm { .. } => {
            let AffineTxWeights::Norm { gamma, g_n, beta } = &aq.tx[&node.id] else {
                panic!("layernorm node without Norm params");
            };
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let c = *ish.last().unwrap();
            layernorm_affine_ref(
                src(node.inputs[0]), c, gamma, *g_n, beta, aq.act[node.id].zero_point, out,
            );
        }
        LayerKind::SelfAttention { heads, head_dim, .. } => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let (seq, dm) = (ish[0], ish[1]);
            if let Some(pa) = packed.attn(node.id) {
                crate::nn::packed::attention_int_packed(
                    src(node.inputs[0]), seq, dm, *heads, *head_dim, pa, pool, scratch, out,
                );
            } else {
                attention_affine_ref(
                    src(node.inputs[0]), seq, dm, *heads, *head_dim,
                    &aq.tx[&node.id], aq.act[node.inputs[0]].zero_point,
                    aq.act[node.id].zero_point, out,
                );
            }
        }
        other => panic!("affine executor: unsupported layer {}", other.type_name()),
    }
}

/// In-place twin of [`exec_node`] for nodes the memory plan lowered onto
/// an input buffer (`alloc.inplace_with[id] = Some(s)`): the shared slot
/// already holds `s`'s example-major payloads, so the kernel mutates
/// `buf` directly. Only the planner's alias-safe kinds appear here
/// (checker-enforced); each arm is bit-exact against its out-of-place
/// twin. `batch` folds flat where the op is elementwise and loops
/// per-example rows where it is not.
#[allow(clippy::too_many_arguments)]
fn exec_node_inplace(
    aq: &AffineQuantizedGraph,
    node: &crate::graph::ir::Node,
    s: usize,
    batch: usize,
    qin: &[i32],
    pools: &[Vec<i32>],
    pool_of: &[usize],
    node_elems: &[usize],
    buf: &mut Vec<i32>,
) {
    match &node.kind {
        LayerKind::Add => {
            // The other operand is proven by the checker to live in a
            // different slot, so this read never aliases `buf`.
            let o = if node.inputs[0] == s { node.inputs[1] } else { node.inputs[0] };
            let q = pool_of[o];
            let other: &[i32] =
                if q == usize::MAX { qin } else { &pools[q][..batch * node_elems[o]] };
            add_affine_inplace(aq, node.id, s, o, buf, other, node.fused_relu);
        }
        LayerKind::ReLU => {
            let zp = aq.act[node.id].zero_point;
            for v in buf.iter_mut() {
                *v = (*v).max(zp);
            }
        }
        LayerKind::Flatten => {} // payload is already the flattened tensor
        LayerKind::Softmax => {
            let (m, sh) = decompose(aq.act[node.inputs[0]].scale as f64);
            let ne = node_elems[node.id];
            for row in buf.chunks_exact_mut(ne) {
                softmax_affine_inplace(row, m, sh);
            }
        }
        LayerKind::Embedding { w } => {
            let AffineTxWeights::Embed { table } = &aq.tx[&node.id] else {
                panic!("embedding node without Embed params");
            };
            crate::nn::int_ops::embedding_q_inplace(buf, table, w.shape[1]);
        }
        other => panic!("in-place lowering of non-elementwise layer {}", other.type_name()),
    }
}

/// Dequantize the output node's payloads — `batch` consecutive examples
/// when called from the batch-folded driver.
fn dequantize_output(
    aq: &AffineQuantizedGraph,
    alloc: &crate::allocator::Allocation,
    node_elems: &[usize],
    qinput: &[i32],
    pools: &[Vec<i32>],
    batch: usize,
    output: &mut Vec<f32>,
) {
    let out_id = aq.graph.output_id();
    let params = aq.act[out_id];
    output.clear();
    let p = alloc.pool_of[out_id];
    if p == usize::MAX {
        output.extend(qinput.iter().map(|&q| params.dequantize(q)));
    } else {
        let n = batch * node_elems[out_id];
        output.extend(pools[p][..n].iter().map(|&q| params.dequantize(q)));
    }
}

/// Naive reference affine conv (1-D or 2-D), kept for the GEMM property
/// tests and the `bench_hotpath` kernel race.
#[allow(clippy::too_many_arguments)]
pub fn conv_affine_ref(
    x: &[i32],
    ish: &[usize],
    wshape: &[usize],
    qw: &AffineNodeWeights,
    zp_in: i32,
    zp_out: i32,
    stride: usize,
    padding: Padding,
    relu: bool,
    dims: usize,
    out: &mut Vec<i32>,
) {
    out.clear();
    if dims == 1 {
        let (s, c) = (ish[0], ish[1]);
        let (k, f) = (wshape[0], wshape[2]);
        let (pad_lo, s_out) = match padding {
            Padding::Same => (Graph::same_padding(s, k, stride).0, s.div_ceil(stride)),
            Padding::Valid => (0, (s - k) / stride + 1),
        };
        out.reserve(s_out * f);
        for o in 0..s_out {
            let base = (o * stride) as isize - pad_lo as isize;
            for fi in 0..f {
                let mut acc: i64 = qw.b[fi];
                for ki in 0..k {
                    let xi = base + ki as isize;
                    if xi < 0 || xi >= s as isize {
                        continue; // zero-padding contributes (zp - zp) = 0
                    }
                    let xrow = &x[(xi as usize) * c..];
                    let wrow = &qw.w[(ki * c) * f + fi..];
                    let mut j = 0;
                    for ci in 0..c {
                        acc += ((xrow[ci] - zp_in) as i64) * (wrow[j] as i64);
                        j += f;
                    }
                }
                let mut v = requantize(acc as i32, qw.mult[fi], qw.shift[fi], zp_out);
                if relu {
                    v = v.max(zp_out);
                }
                out.push(v);
            }
        }
    } else {
        let (h, wd, c) = (ish[0], ish[1], ish[2]);
        let (kh, kw, f) = (wshape[0], wshape[1], wshape[3]);
        let ((ph, _), h_out) = match padding {
            Padding::Same => (Graph::same_padding(h, kh, stride), h.div_ceil(stride)),
            Padding::Valid => ((0, 0), (h - kh) / stride + 1),
        };
        let ((pw, _), w_out) = match padding {
            Padding::Same => (Graph::same_padding(wd, kw, stride), wd.div_ceil(stride)),
            Padding::Valid => ((0, 0), (wd - kw) / stride + 1),
        };
        out.reserve(h_out * w_out * f);
        for oh in 0..h_out {
            for ow in 0..w_out {
                for fi in 0..f {
                    let mut acc: i64 = qw.b[fi];
                    for ki in 0..kh {
                        let hi = (oh * stride + ki) as isize - ph as isize;
                        if hi < 0 || hi >= h as isize {
                            continue;
                        }
                        for kj in 0..kw {
                            let wi = (ow * stride + kj) as isize - pw as isize;
                            if wi < 0 || wi >= wd as isize {
                                continue;
                            }
                            let xrow = &x[((hi as usize) * wd + wi as usize) * c..];
                            let wrow = &qw.w[((ki * kw + kj) * c) * f + fi..];
                            let mut j = 0;
                            for ci in 0..c {
                                acc += ((xrow[ci] - zp_in) as i64) * (wrow[j] as i64);
                                j += f;
                            }
                        }
                    }
                    let mut v = requantize(acc as i32, qw.mult[fi], qw.shift[fi], zp_out);
                    if relu {
                        v = v.max(zp_out);
                    }
                    out.push(v);
                }
            }
        }
    }
}

/// Naive reference affine dense.
pub fn dense_affine_ref(
    x: &[i32],
    qw: &AffineNodeWeights,
    zp_in: i32,
    zp_out: i32,
    o: usize,
    relu: bool,
    out: &mut Vec<i32>,
) {
    let i = x.len();
    out.clear();
    out.reserve(o);
    for oi in 0..o {
        let mut acc: i64 = qw.b[oi];
        for ii in 0..i {
            acc += ((x[ii] - zp_in) as i64) * (qw.w[ii * o + oi] as i64);
        }
        let mut v = requantize(acc as i32, qw.mult[oi], qw.shift[oi], zp_out);
        if relu {
            v = v.max(zp_out);
        }
        out.push(v);
    }
}

/// Affine softmax over one row: payloads in (any zero point — distances
/// cancel it), probability payloads out at the fixed `prob_params`
/// convention (scale 1/256, zero point -128). `sm_mult/sm_shift` is the
/// gemmlowp decomposition of the INPUT scale: it turns integer payload
/// distances into the exp LUT's Q0.15 argument.
pub fn softmax_affine_row(x: &[i32], sm_mult: i32, sm_shift: i32, out: &mut [i32]) {
    debug_assert_eq!(x.len(), out.len());
    let m = x.iter().copied().max().unwrap_or(0) as i64;
    let mut sum = 0i64;
    for (&v, e) in x.iter().zip(out.iter_mut()) {
        // d15 = floor(d_q * s_in * 2^15): payload distance to real
        // distance to Q0.15, all in one multiply-shift.
        let d15 = ((m - v) * sm_mult as i64) >> (16 + sm_shift);
        let q = exp_q(d15, 15);
        *e = q;
        sum += q as i64;
    }
    // The max element's distance is 0, so sum >= exp_lut()[0] > 0.
    for e in out.iter_mut() {
        *e = (-128 + ((*e as i64) << 8) / sum).clamp(-128, 127) as i32;
    }
}

/// Whole-tensor affine softmax (node-level Softmax: one distribution).
pub fn softmax_affine_ref(x: &[i32], sm_mult: i32, sm_shift: i32, out: &mut Vec<i32>) {
    out.clear();
    out.resize(x.len(), 0);
    softmax_affine_row(x, sm_mult, sm_shift, out);
}

/// In-place twin of [`softmax_affine_row`]: the max pass is read-only,
/// the exp pass rewrites each element from its own already-read value,
/// and the normalize pass rewrites again — the exact element and
/// accumulation order of the two-buffer kernel, so the probability
/// payloads are bit-identical.
pub fn softmax_affine_inplace(x: &mut [i32], sm_mult: i32, sm_shift: i32) {
    let m = x.iter().copied().max().unwrap_or(0) as i64;
    let mut sum = 0i64;
    for v in x.iter_mut() {
        let d15 = ((m - *v) * sm_mult as i64) >> (16 + sm_shift);
        let q = exp_q(d15, 15);
        *v = q;
        sum += q as i64;
    }
    for v in x.iter_mut() {
        *v = (-128 + ((*v as i64) << 8) / sum).clamp(-128, 127) as i32;
    }
}

/// Affine LayerNorm reference over rows of `c` channels. Zero points
/// cancel in the mean subtraction, so the normalized rows are scale-free;
/// `gamma` payloads carry the build-time fold `gamma / s_out` at `g_n`
/// fractional bits and `beta` is pre-divided into output quanta.
pub fn layernorm_affine_ref(
    x: &[i32],
    c: usize,
    gamma: &[i32],
    g_n: i32,
    beta: &[i64],
    zp_out: i32,
    out: &mut Vec<i32>,
) {
    out.clear();
    out.reserve(x.len());
    for row in x.chunks_exact(c) {
        let sum: i64 = row.iter().map(|&v| v as i64).sum();
        let mean = sum / c as i64;
        let mut var_acc = 0i64;
        for &v in row {
            let d = v as i64 - mean;
            var_acc += d * d;
        }
        let (r, h) = rsqrt_norm(var_acc / c as i64 + 1);
        // d * r * 2^(-30-h) is the scale-free x_hat; gamma lands it on
        // output quanta directly (the input scale cancelled in rsqrt).
        let sh = 30 + h + g_n;
        for (ci, &xv) in row.iter().enumerate() {
            let d = xv as i64 - mean;
            let v = rescale(d * r * gamma[ci] as i64, sh) + beta[ci] + zp_out as i64;
            out.push(v.clamp(-128, 127) as i32);
        }
    }
}

/// Position-wise affine projection on payload rows: x (P, D) through a
/// per-tensor symmetric weight (D, O).
pub(crate) fn proj_affine_rows(
    x: &[i32],
    d: usize,
    o: usize,
    qw: &AffineNodeWeights,
    zp_in: i32,
    zp_out: i32,
    out: &mut Vec<i32>,
) {
    out.clear();
    out.reserve((x.len() / d) * o);
    for row in x.chunks_exact(d) {
        for oi in 0..o {
            let mut acc: i64 = qw.b[oi];
            for (ii, &xv) in row.iter().enumerate() {
                acc += ((xv - zp_in) as i64) * (qw.w[ii * o + oi] as i64);
            }
            out.push(requantize(acc as i32, qw.mult[oi], qw.shift[oi], zp_out));
        }
    }
}

/// Affine multi-head self-attention, reference kernel: x (S, D) payloads
/// at the node input params, out (S, D) at the node output params. The
/// GEMM lowering must reproduce this kernel bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn attention_affine_ref(
    x: &[i32],
    seq: usize,
    dm: usize,
    heads: usize,
    hd: usize,
    tx: &AffineTxWeights,
    zp_in: i32,
    zp_out: i32,
    out: &mut Vec<i32>,
) {
    let AffineTxWeights::Attn {
        wq, wk, wv, wo, q, k, v, s, ctx, s_mult, s_shift, c_mult, c_shift, sm_mult, sm_shift,
    } = tx
    else {
        panic!("attention_affine_ref wants Attn params");
    };
    let (mut qp, mut kp, mut vp) = (Vec::new(), Vec::new(), Vec::new());
    proj_affine_rows(x, dm, dm, wq, zp_in, q.zero_point, &mut qp);
    proj_affine_rows(x, dm, dm, wk, zp_in, k.zero_point, &mut kp);
    proj_affine_rows(x, dm, dm, wv, zp_in, v.zero_point, &mut vp);
    let mut srow = vec![0i32; seq];
    let mut prow = vec![0i32; seq];
    let mut ctxp = vec![0i32; seq * dm];
    for h in 0..heads {
        let off = h * hd;
        for i in 0..seq {
            for (j, sj) in srow.iter_mut().enumerate() {
                let mut acc = 0i64;
                for t in 0..hd {
                    acc += (qp[i * dm + off + t] - q.zero_point) as i64
                        * (kp[j * dm + off + t] - k.zero_point) as i64;
                }
                // s_mult/s_shift folds s_q*s_k/(sqrt(hd)*s_s).
                *sj = requantize(acc as i32, *s_mult, *s_shift, s.zero_point);
            }
            softmax_affine_row(&srow, *sm_mult, *sm_shift, &mut prow);
            for t in 0..hd {
                let mut acc = 0i64;
                for (j, &pj) in prow.iter().enumerate() {
                    acc += (pj + 128) as i64 * (vp[j * dm + off + t] - v.zero_point) as i64;
                }
                ctxp[i * dm + off + t] =
                    requantize(acc as i32, *c_mult, *c_shift, ctx.zero_point);
            }
        }
    }
    proj_affine_rows(&ctxp, dm, dm, wo, ctx.zero_point, zp_out, out);
}

#[allow(clippy::too_many_arguments)]
fn add_affine(
    aq: &AffineQuantizedGraph,
    id: usize,
    ia: usize,
    ib: usize,
    a: &[i32],
    b: &[i32],
    relu: bool,
    out: &mut Vec<i32>,
) {
    // Float-rescale-free integer add (TFLite's ADD kernel simplified to
    // double-precision scale ratios, then rounded — accurate enough for a
    // baseline model; the paper's comparison is about quantizer quality).
    let (pa, pb, po) = (aq.act[ia], aq.act[ib], aq.act[id]);
    let ra = pa.scale / po.scale;
    let rb = pb.scale / po.scale;
    out.clear();
    out.extend(a.iter().zip(b.iter()).map(|(&x, &y)| {
        let real = (x - pa.zero_point) as f32 * ra + (y - pb.zero_point) as f32 * rb;
        let mut v = (real.round() as i32 + po.zero_point).clamp(-128, 127);
        if relu {
            v = v.max(po.zero_point);
        }
        v
    }));
}

/// In-place twin of [`add_affine`]: `acc` holds operand `iacc`'s payloads
/// and receives the sum. The per-operand real terms are summed with one
/// f32 `+` (commutative), so which operand the planner aliased cannot
/// change the result — bit-exact with the out-of-place kernel either way.
fn add_affine_inplace(
    aq: &AffineQuantizedGraph,
    id: usize,
    iacc: usize,
    iother: usize,
    acc: &mut [i32],
    other: &[i32],
    relu: bool,
) {
    let (pa, pb, po) = (aq.act[iacc], aq.act[iother], aq.act[id]);
    let ra = pa.scale / po.scale;
    let rb = pb.scale / po.scale;
    for (x, &y) in acc.iter_mut().zip(other.iter()) {
        let real = (*x - pa.zero_point) as f32 * ra + (y - pb.zero_point) as f32 * rb;
        let mut v = (real.round() as i32 + po.zero_point).clamp(-128, 127);
        if relu {
            v = v.max(po.zero_point);
        }
        *x = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::resnet_v1_6_shapes;
    use crate::graph::deploy_pipeline;
    use crate::nn::float_exec::{self, ActStats};
    use crate::quant::affine::quantize_affine;
    use crate::util::prng::Pcg32;

    fn setup(seed: u64) -> (Graph, Vec<Vec<f32>>, AffineQuantizedGraph) {
        let mut g = resnet_v1_6_shapes("t", 1, &[32, 3], 4, 8);
        let mut rng = Pcg32::seeded(seed);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.4;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
        }
        let g = deploy_pipeline(&g);
        let mut rng = Pcg32::seeded(seed + 100);
        let inputs: Vec<Vec<f32>> =
            (0..12).map(|_| (0..96).map(|_| rng.normal()).collect()).collect();
        let mut stats = ActStats::new(g.nodes.len());
        for x in &inputs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let aq = quantize_affine(&g, &stats);
        (g, inputs, aq)
    }

    #[test]
    fn affine_int8_close_to_float() {
        let (g, inputs, aq) = setup(1);
        let mut agree = 0;
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            let ql = run(&aq, x);
            assert_eq!(fl.len(), ql.len());
            if float_exec::argmax(&fl) == float_exec::argmax(&ql) {
                agree += 1;
            }
        }
        assert!(agree >= 10, "argmax agreement {agree}/12");
    }

    #[test]
    fn affine_logit_error_reasonable() {
        let (g, inputs, aq) = setup(2);
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            let ql = run(&aq, x);
            let span = fl.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
            let diff = fl.iter().zip(&ql).fold(0.0f32, |a, (u, v)| a.max((u - v).abs()));
            assert!(diff / span < 0.35, "diff {diff} span {span}");
        }
    }

    #[test]
    fn affine_beats_qmn_int8_per_layer_on_average() {
        // The Appendix B claim: TFLite's per-filter asymmetric scheme has a
        // precision edge over per-layer power-of-two Qm.n at 8 bits.
        let (g, inputs, aq) = setup(3);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &inputs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qmn = crate::quant::quantize(&g, &stats, crate::quant::QuantSpec::int8_per_layer());
        let (mut e_aff, mut e_qmn) = (0.0f64, 0.0f64);
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            for (i, &v) in run(&aq, x).iter().enumerate() {
                e_aff += ((fl[i] - v) as f64).powi(2);
            }
            for (i, &v) in crate::nn::int_exec::run(&qmn, x).iter().enumerate() {
                e_qmn += ((fl[i] - v) as f64).powi(2);
            }
        }
        assert!(e_aff < e_qmn * 1.2, "affine {e_aff} vs qmn {e_qmn}");
    }
}
