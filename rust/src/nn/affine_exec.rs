//! TFLite-semantics affine int8 executor (Appendix B baseline + the
//! Cube.AI engine model's numeric core): zero-point-corrected MACCs in
//! int32, gemmlowp requantization per filter, asymmetric activations.
//!
//! The conv/dense kernels here are the NAIVE REFERENCE implementations
//! (`*_ref`): the executor runs the im2col + blocked-GEMM lowerings in
//! [`super::gemm`] (zero-point pre-subtracted at pack time), which are
//! property-tested bit-exact against these.

use crate::graph::ir::{LayerKind, Padding};
use crate::graph::Graph;
use crate::quant::affine::{requantize, AffineNodeWeights, AffineQuantizedGraph};

use super::gemm;

/// Execute the affine-quantized graph on a float input; returns float
/// logits (dequantized at the output tensor's affine params).
///
/// Deprecated in favour of [`crate::nn::session::Session`]: this wrapper
/// re-runs the §5.7 lifetime analysis and reallocates the activation
/// pools on every call. A `Session` does both once and reuses the arena
/// across `run` calls.
pub fn run(aq: &AffineQuantizedGraph, input: &[f32]) -> Vec<f32> {
    let graph = &aq.graph;
    let alloc = crate::allocator::allocate(graph);
    let node_elems = crate::nn::session::node_elems(graph);
    let mut pools: Vec<Vec<i32>> = vec![Vec::new(); alloc.n_pools()];
    let mut qinput = Vec::new();
    let pool = crate::nn::parallel::IntraOpPool::serial();
    let mut scratch = vec![Vec::new()];
    let mut output = Vec::new();
    // Legacy per-call semantics: zero-point subtraction at pack/stage
    // time (bit-identical to the prepacked fold either way).
    let packed = crate::nn::packed::PackedWeights::empty(graph.nodes.len());
    run_pooled(
        aq, input, &alloc, &node_elems, &mut qinput, &mut pools, &pool, &mut scratch, &packed,
        &mut output,
    );
    output
}

/// Pooled core shared by [`run`] and the affine [`crate::nn::session`]
/// backend (see `int_exec::run_pooled` for the pool discipline; `scratch`
/// carries one packing slab per intra-op thread of `pool`). Conv/dense
/// nodes present in `packed` run the prepacked kernels with the zero
/// point folded into the packed bias at build time — no per-call
/// `x − zp` packing or staging, and `aq.weights` is never read; absent
/// nodes keep the per-call zero-point-shifted GEMM lowering.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_pooled(
    aq: &AffineQuantizedGraph,
    input: &[f32],
    alloc: &crate::allocator::Allocation,
    node_elems: &[usize],
    qinput: &mut Vec<i32>,
    pools: &mut [Vec<i32>],
    pool: &crate::nn::parallel::IntraOpPool,
    scratch: &mut [Vec<i32>],
    packed: &crate::nn::packed::PackedWeights,
    output: &mut Vec<f32>,
) {
    let graph = &aq.graph;
    assert_eq!(input.len(), graph.input_shape.iter().product::<usize>());

    let in_params = aq.act[0];
    qinput.clear();
    qinput.extend(input.iter().map(|&x| in_params.quantize(x)));

    for node in &graph.nodes {
        if matches!(node.kind, LayerKind::Input) {
            continue;
        }
        let p = alloc.pool_of[node.id];
        let mut out = std::mem::take(&mut pools[p]);
        {
            let qin: &[i32] = qinput;
            let src = |i: usize| {
                crate::nn::session::pool_src(pools, qin, &alloc.pool_of, node_elems, i)
            };
            match &node.kind {
                LayerKind::Input => unreachable!(),
                LayerKind::Conv { w, stride, padding, .. } => {
                    let src_id = node.inputs[0];
                    let ish = &graph.nodes[src_id].out_shape;
                    if let Some(pn) = packed.get(node.id) {
                        if graph.dims == 1 {
                            crate::nn::packed::conv1d_int_packed(
                                src(src_id), ish[0], pn, *stride, *padding, pool, scratch,
                                &mut out,
                            );
                        } else {
                            crate::nn::packed::conv2d_int_packed(
                                src(src_id), ish[0], ish[1], pn, *stride, *padding, pool,
                                scratch, &mut out,
                            );
                        }
                    } else {
                        gemm::conv_affine_gemm(
                            src(src_id), ish, &w.shape, &aq.weights[&node.id],
                            aq.act[src_id].zero_point, aq.act[node.id].zero_point,
                            *stride, *padding, node.fused_relu, graph.dims, pool, scratch,
                            &mut out,
                        );
                    }
                }
                LayerKind::Dense { w, .. } => {
                    let src_id = node.inputs[0];
                    if let Some(pn) = packed.get(node.id) {
                        crate::nn::packed::dense_int_packed(src(src_id), pn, pool, &mut out);
                    } else {
                        gemm::dense_affine_gemm(
                            src(src_id), &aq.weights[&node.id],
                            aq.act[src_id].zero_point, aq.act[node.id].zero_point,
                            w.shape[1], node.fused_relu, pool, scratch, &mut out,
                        );
                    }
                }
                LayerKind::MaxPool { size } => {
                    let ish = &graph.nodes[node.inputs[0]].out_shape;
                    let c = *ish.last().unwrap();
                    crate::nn::int_ops::maxpool_q(
                        src(node.inputs[0]), &ish[..ish.len() - 1], c, *size, false, &mut out,
                    );
                    if node.fused_relu {
                        let zp = aq.act[node.id].zero_point;
                        for v in out.iter_mut() {
                            *v = (*v).max(zp);
                        }
                    }
                }
                LayerKind::GlobalAvgPool => {
                    // Mean of payloads; zero point is unchanged (same params in
                    // and out — TFLite AVERAGE_POOL_2D requirement).
                    // Channel-major accumulation: no per-request allocation.
                    let x = src(node.inputs[0]);
                    let ish = &graph.nodes[node.inputs[0]].out_shape;
                    let c = *ish.last().unwrap();
                    let positions: usize = ish[..ish.len() - 1].iter().product();
                    out.clear();
                    out.reserve(c);
                    let n = positions as i64;
                    for ci in 0..c {
                        let mut s = 0i64;
                        for p in 0..positions {
                            s += x[p * c + ci] as i64;
                        }
                        // Round-to-nearest division, per TFLite.
                        let r = if s >= 0 { (s + n / 2) / n } else { (s - n / 2) / n };
                        out.push(r.clamp(-128, 127) as i32);
                    }
                }
                LayerKind::AvgPool { size } => {
                    let ish = &graph.nodes[node.inputs[0]].out_shape;
                    let c = *ish.last().unwrap();
                    crate::nn::int_ops::avgpool_q(
                        src(node.inputs[0]), &ish[..ish.len() - 1], c, *size, &mut out,
                    );
                }
                LayerKind::Add => {
                    add_affine(
                        aq, node.id, node.inputs[0], node.inputs[1],
                        src(node.inputs[0]), src(node.inputs[1]),
                        node.fused_relu, &mut out,
                    );
                }
                LayerKind::ReLU => {
                    let zp = aq.act[node.id].zero_point;
                    out.clear();
                    out.extend(src(node.inputs[0]).iter().map(|&v| v.max(zp)));
                }
                LayerKind::Flatten | LayerKind::Softmax => {
                    out.clear();
                    out.extend_from_slice(src(node.inputs[0]));
                }
                other => panic!("affine executor: unsupported layer {}", other.type_name()),
            }
        }
        pools[p] = out;
    }

    let out_id = graph.output_id();
    let params = aq.act[out_id];
    output.clear();
    let p = alloc.pool_of[out_id];
    if p == usize::MAX {
        output.extend(qinput.iter().map(|&q| params.dequantize(q)));
    } else {
        output.extend(pools[p][..node_elems[out_id]].iter().map(|&q| params.dequantize(q)));
    }
}

/// Naive reference affine conv (1-D or 2-D), kept for the GEMM property
/// tests and the `bench_hotpath` kernel race.
#[allow(clippy::too_many_arguments)]
pub fn conv_affine_ref(
    x: &[i32],
    ish: &[usize],
    wshape: &[usize],
    qw: &AffineNodeWeights,
    zp_in: i32,
    zp_out: i32,
    stride: usize,
    padding: Padding,
    relu: bool,
    dims: usize,
    out: &mut Vec<i32>,
) {
    out.clear();
    if dims == 1 {
        let (s, c) = (ish[0], ish[1]);
        let (k, f) = (wshape[0], wshape[2]);
        let (pad_lo, s_out) = match padding {
            Padding::Same => (Graph::same_padding(s, k, stride).0, s.div_ceil(stride)),
            Padding::Valid => (0, (s - k) / stride + 1),
        };
        out.reserve(s_out * f);
        for o in 0..s_out {
            let base = (o * stride) as isize - pad_lo as isize;
            for fi in 0..f {
                let mut acc: i64 = qw.b[fi];
                for ki in 0..k {
                    let xi = base + ki as isize;
                    if xi < 0 || xi >= s as isize {
                        continue; // zero-padding contributes (zp - zp) = 0
                    }
                    let xrow = &x[(xi as usize) * c..];
                    let wrow = &qw.w[(ki * c) * f + fi..];
                    let mut j = 0;
                    for ci in 0..c {
                        acc += ((xrow[ci] - zp_in) as i64) * (wrow[j] as i64);
                        j += f;
                    }
                }
                let mut v = requantize(acc as i32, qw.mult[fi], qw.shift[fi], zp_out);
                if relu {
                    v = v.max(zp_out);
                }
                out.push(v);
            }
        }
    } else {
        let (h, wd, c) = (ish[0], ish[1], ish[2]);
        let (kh, kw, f) = (wshape[0], wshape[1], wshape[3]);
        let ((ph, _), h_out) = match padding {
            Padding::Same => (Graph::same_padding(h, kh, stride), h.div_ceil(stride)),
            Padding::Valid => ((0, 0), (h - kh) / stride + 1),
        };
        let ((pw, _), w_out) = match padding {
            Padding::Same => (Graph::same_padding(wd, kw, stride), wd.div_ceil(stride)),
            Padding::Valid => ((0, 0), (wd - kw) / stride + 1),
        };
        out.reserve(h_out * w_out * f);
        for oh in 0..h_out {
            for ow in 0..w_out {
                for fi in 0..f {
                    let mut acc: i64 = qw.b[fi];
                    for ki in 0..kh {
                        let hi = (oh * stride + ki) as isize - ph as isize;
                        if hi < 0 || hi >= h as isize {
                            continue;
                        }
                        for kj in 0..kw {
                            let wi = (ow * stride + kj) as isize - pw as isize;
                            if wi < 0 || wi >= wd as isize {
                                continue;
                            }
                            let xrow = &x[((hi as usize) * wd + wi as usize) * c..];
                            let wrow = &qw.w[((ki * kw + kj) * c) * f + fi..];
                            let mut j = 0;
                            for ci in 0..c {
                                acc += ((xrow[ci] - zp_in) as i64) * (wrow[j] as i64);
                                j += f;
                            }
                        }
                    }
                    let mut v = requantize(acc as i32, qw.mult[fi], qw.shift[fi], zp_out);
                    if relu {
                        v = v.max(zp_out);
                    }
                    out.push(v);
                }
            }
        }
    }
}

/// Naive reference affine dense.
pub fn dense_affine_ref(
    x: &[i32],
    qw: &AffineNodeWeights,
    zp_in: i32,
    zp_out: i32,
    o: usize,
    relu: bool,
    out: &mut Vec<i32>,
) {
    let i = x.len();
    out.clear();
    out.reserve(o);
    for oi in 0..o {
        let mut acc: i64 = qw.b[oi];
        for ii in 0..i {
            acc += ((x[ii] - zp_in) as i64) * (qw.w[ii * o + oi] as i64);
        }
        let mut v = requantize(acc as i32, qw.mult[oi], qw.shift[oi], zp_out);
        if relu {
            v = v.max(zp_out);
        }
        out.push(v);
    }
}

#[allow(clippy::too_many_arguments)]
fn add_affine(
    aq: &AffineQuantizedGraph,
    id: usize,
    ia: usize,
    ib: usize,
    a: &[i32],
    b: &[i32],
    relu: bool,
    out: &mut Vec<i32>,
) {
    // Float-rescale-free integer add (TFLite's ADD kernel simplified to
    // double-precision scale ratios, then rounded — accurate enough for a
    // baseline model; the paper's comparison is about quantizer quality).
    let (pa, pb, po) = (aq.act[ia], aq.act[ib], aq.act[id]);
    let ra = pa.scale / po.scale;
    let rb = pb.scale / po.scale;
    out.clear();
    out.extend(a.iter().zip(b.iter()).map(|(&x, &y)| {
        let real = (x - pa.zero_point) as f32 * ra + (y - pb.zero_point) as f32 * rb;
        let mut v = (real.round() as i32 + po.zero_point).clamp(-128, 127);
        if relu {
            v = v.max(po.zero_point);
        }
        v
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::resnet_v1_6_shapes;
    use crate::graph::deploy_pipeline;
    use crate::nn::float_exec::{self, ActStats};
    use crate::quant::affine::quantize_affine;
    use crate::util::prng::Pcg32;

    fn setup(seed: u64) -> (Graph, Vec<Vec<f32>>, AffineQuantizedGraph) {
        let mut g = resnet_v1_6_shapes("t", 1, &[32, 3], 4, 8);
        let mut rng = Pcg32::seeded(seed);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.4;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
        }
        let g = deploy_pipeline(&g);
        let mut rng = Pcg32::seeded(seed + 100);
        let inputs: Vec<Vec<f32>> =
            (0..12).map(|_| (0..96).map(|_| rng.normal()).collect()).collect();
        let mut stats = ActStats::new(g.nodes.len());
        for x in &inputs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let aq = quantize_affine(&g, &stats);
        (g, inputs, aq)
    }

    #[test]
    fn affine_int8_close_to_float() {
        let (g, inputs, aq) = setup(1);
        let mut agree = 0;
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            let ql = run(&aq, x);
            assert_eq!(fl.len(), ql.len());
            if float_exec::argmax(&fl) == float_exec::argmax(&ql) {
                agree += 1;
            }
        }
        assert!(agree >= 10, "argmax agreement {agree}/12");
    }

    #[test]
    fn affine_logit_error_reasonable() {
        let (g, inputs, aq) = setup(2);
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            let ql = run(&aq, x);
            let span = fl.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
            let diff = fl.iter().zip(&ql).fold(0.0f32, |a, (u, v)| a.max((u - v).abs()));
            assert!(diff / span < 0.35, "diff {diff} span {span}");
        }
    }

    #[test]
    fn affine_beats_qmn_int8_per_layer_on_average() {
        // The Appendix B claim: TFLite's per-filter asymmetric scheme has a
        // precision edge over per-layer power-of-two Qm.n at 8 bits.
        let (g, inputs, aq) = setup(3);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &inputs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qmn = crate::quant::quantize(&g, &stats, crate::quant::QuantSpec::int8_per_layer());
        let (mut e_aff, mut e_qmn) = (0.0f64, 0.0f64);
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            for (i, &v) in run(&aq, x).iter().enumerate() {
                e_aff += ((fl[i] - v) as f64).powi(2);
            }
            for (i, &v) in crate::nn::int_exec::run(&qmn, x).iter().enumerate() {
                e_qmn += ((fl[i] - v) as f64).powi(2);
            }
        }
        assert!(e_aff < e_qmn * 1.2, "affine {e_aff} vs qmn {e_qmn}");
    }
}
