//! Unified inference sessions: one backend trait, preallocated arenas,
//! batch execution — across the float32, fixed-point Qm.n and affine int8
//! engines.
//!
//! The paper positions MicroAI as "easily adjusted and/or extended"; this
//! module is that seam on the Rust side. A [`Session`] is built once per
//! (model, backend, board) via [`SessionBuilder`] and then serves many
//! requests:
//!
//! - **compile once**: [`InferenceBackend::prepare`] runs the §5.7
//!   lifetime analysis ([`crate::allocator`]) and produces a [`Plan`];
//!   [`InferenceBackend::new_arena`] preallocates the activation pools to
//!   their worst-case sizes.
//! - **run many**: [`Session::run`] executes one example with no
//!   per-request activation-buffer allocation (the arena pools are
//!   reused; see `bench_hotpath` for the measured win);
//!   [`Session::infer`] classifies a [`Batch`] view (contiguous or
//!   strided examples) in micro-batches of up to
//!   [`SessionBuilder::max_batch`] examples, folding each micro-batch
//!   into ONE GEMM per dense/1×1 layer (DESIGN.md §11).
//! - **priced**: [`SessionMeta`] carries the deployment facts every
//!   consumer used to hand-wire — dtype, weight bytes, device activation
//!   RAM, and (when a [`Board`] is attached) predicted per-inference
//!   latency and energy from the calibrated `mcu::cost` models.
//!
//! The serving cascade, the experiment flow, the reproduction harnesses
//! and the examples all run through this API; the legacy free functions
//! (`float_exec::run`, `int_exec::run`, `affine_exec::run`) remain as
//! thin wrappers for one release.

use std::sync::Arc;

use crate::allocator::{allocate, Allocation};
use crate::analysis::{VerifiedFacts, VerifyError};
use crate::graph::ir::Graph;
use crate::mcu::board::Board;
use crate::mcu::DType;
use crate::quant::affine::AffineQuantizedGraph;
use crate::quant::ptq::QuantizedGraph;

use super::float_exec::{self, ActStats};
use super::packed::PackedWeights;
use super::parallel::IntraOpPool;
use super::{affine_exec, argmax, int_exec};

/// Per-node output element counts (pool slice lengths).
pub(crate) fn node_elems(graph: &Graph) -> Vec<usize> {
    graph.nodes.iter().map(|n| n.out_shape.iter().product()).collect()
}

/// Producer slice for node `i` during pooled execution: the caller's
/// input buffer for the graph input (pool `usize::MAX`), otherwise the
/// head of the §5.7 pool node `i`'s output currently occupies. The
/// allocator invariant guarantees that slice is still live.
#[inline]
pub(crate) fn pool_src<'a, T>(
    pools: &'a [Vec<T>],
    input: &'a [T],
    pool_of: &[usize],
    node_elems: &[usize],
    i: usize,
) -> &'a [T] {
    let q = pool_of[i];
    if q == usize::MAX {
        input
    } else {
        &pools[q][..node_elems[i]]
    }
}

/// Compile-once execution plan: the §5.7 buffer assignment plus the shape
/// facts the pooled executors need per run, plus the build-time prepacked
/// weight arena (`nn::packed`) — NR-tiled B panels + fused-epilogue
/// parameters, shared READ-ONLY behind an `Arc` so [`Session::fork`]
/// aliases one allocation instead of re-packing or copying.
#[derive(Clone, Debug)]
pub struct Plan {
    pub alloc: Allocation,
    pub node_elems: Vec<usize>,
    pub input_len: usize,
    pub output_len: usize,
    /// Bytes per activation element at the DEVICE dtype (1/2/4); the host
    /// arena always stores i32/f32 lanes.
    pub device_bytes_per_elem: usize,
    /// Prepacked conv/dense weights, built once by
    /// [`InferenceBackend::pack_weights`]. Empty (per-call fallback) for
    /// backends without a packer.
    pub packed: Arc<PackedWeights>,
    /// Build-time range-verification facts from `crate::analysis`:
    /// per-node proven accumulator intervals, lane admissions and clamp
    /// saturation reachability. [`VerifiedFacts::unverified`] for
    /// backends with nothing to prove (float32, custom engines).
    pub facts: Arc<VerifiedFacts>,
}

impl Plan {
    pub fn for_graph(graph: &Graph, device_bytes_per_elem: usize) -> Plan {
        let alloc = allocate(graph);
        let node_elems = node_elems(graph);
        let input_len = graph.input_shape.iter().product();
        let output_len = node_elems[graph.output_id()];
        Plan {
            alloc,
            node_elems,
            input_len,
            output_len,
            device_bytes_per_elem,
            packed: Arc::new(PackedWeights::empty(graph.nodes.len())),
            facts: Arc::new(VerifiedFacts::unverified()),
        }
    }

    /// Build-time promotion of the kernels' release-invisible
    /// `debug_assert!` buffer guards ("A panel too small", "B matrix too
    /// small", pool sizing) to checked errors: every node's output slice
    /// must fit the pool the §5.7 assignment parked it in, and the plan's
    /// shape facts must be internally consistent. A violated invariant
    /// here would surface in release mode as silent out-of-bounds panics
    /// (or short slices) deep inside the GEMM hot path; `try_build`
    /// rejects the plan instead.
    pub fn validate(&self, graph: &Graph) -> Result<(), VerifyError> {
        let perr = |node: &str, reason: String| VerifyError { node: node.into(), reason };
        let n = graph.nodes.len();
        if self.node_elems.len() != n || self.alloc.pool_of.len() != n {
            return Err(perr(
                "<plan>",
                format!(
                    "plan shape tables cover {}/{} nodes ({} pool slots)",
                    self.node_elems.len(),
                    n,
                    self.alloc.pool_of.len()
                ),
            ));
        }
        for node in &graph.nodes {
            let pool = self.alloc.pool_of[node.id];
            if pool == usize::MAX {
                continue; // caller-owned input buffer
            }
            let Some(&cap) = self.alloc.pool_elems.get(pool) else {
                return Err(perr(&node.name, format!("assigned to missing pool {pool}")));
            };
            let need = self.node_elems[node.id];
            if cap < need {
                return Err(perr(
                    &node.name,
                    format!("pool {pool} holds {cap} elems but the node writes {need}"),
                ));
            }
        }
        let input_len: usize = graph.input_shape.iter().product();
        if self.input_len != input_len || self.output_len != self.node_elems[graph.output_id()] {
            return Err(perr(
                "<plan>",
                format!(
                    "stale I/O lengths {}x{} for a graph with {}x{}",
                    self.input_len,
                    self.output_len,
                    input_len,
                    self.node_elems[graph.output_id()]
                ),
            ));
        }
        // The memory plan itself is UNTRUSTED (allocator::planner): the
        // trusted byte-range checker must independently prove that no
        // two live buffers overlap (host slots and device offsets) and
        // that every in-place annotation is alias-safe, or the session
        // refuses to build (DESIGN.md §12).
        crate::allocator::check_no_conflict(graph, &self.alloc)
            .map_err(|reason| perr("<memory-plan>", format!("refused by the memory checker: {reason}")))?;
        Ok(())
    }

    /// Predicted device activation RAM: the planned coalesced arena
    /// (allocator offsets, checker-verified) + the input buffer held by
    /// the caller, at the device dtype width (§5.7 upgraded, §12).
    pub fn device_ram_bytes(&self) -> usize {
        self.alloc.ram_bytes(self.device_bytes_per_elem)
            + self.input_len * self.device_bytes_per_elem
    }
}

/// Preallocated activation buffers for one session. Built once by
/// [`InferenceBackend::new_arena`]; every pool is sized to its worst-case
/// occupant so `run` never reallocates.
pub struct Arena {
    pub(crate) f32_pools: Vec<Vec<f32>>,
    pub(crate) i32_pools: Vec<Vec<i32>>,
    /// Quantized input payloads (integer backends only).
    pub(crate) qinput: Vec<i32>,
    /// im2col packing slabs for the GEMM lowering (float backend): ONE
    /// slab per intra-op thread, each sized by the allocator's scratch
    /// lifetime analysis (`Allocation::gemm_scratch_elems`), so packing
    /// never allocates per request at any thread count.
    pub(crate) scratch_f32: Vec<Vec<f32>>,
    /// im2col / zero-point staging slabs (integer backends), one per
    /// intra-op thread.
    pub(crate) scratch_i32: Vec<Vec<i32>>,
    /// Dequantized output logits of the latest run (up to
    /// `max_batch × output_len` for batch-folded runs).
    pub(crate) output: Vec<f32>,
    /// Contiguous staging buffer for non-contiguous [`Batch`] views
    /// (sized `max_batch × input_len`).
    pub(crate) batch_stage: Vec<f32>,
    /// One example's output staging for the batch-folded executors'
    /// unfoldable-layer loop (float lane; empty when `max_batch == 1`).
    pub(crate) batch_tmp_f32: Vec<f32>,
    /// Integer-lane twin of `batch_tmp_f32`.
    pub(crate) batch_tmp_i32: Vec<i32>,
    /// Micro-batch capacity the pools / qinput / output are sized for.
    pub(crate) max_batch: usize,
    /// Persistent intra-op worker pool (thread budget from
    /// [`SessionBuilder::threads`]; 1 = serial, no OS threads).
    pub(crate) pool: IntraOpPool,
}

impl Arena {
    /// One GEMM packing slab per intra-op thread, each at the worst-case
    /// per-thread capacity from the allocator's lifetime analysis.
    fn slabs<T>(threads: usize, elems: usize) -> Vec<Vec<T>> {
        (0..threads).map(|_| Vec::with_capacity(elems)).collect()
    }

    fn preallocated(plan: &Plan, float: bool, threads: usize, max_batch: usize) -> Arena {
        let threads = threads.max(1);
        let mb = max_batch.max(1);
        let pools = &plan.alloc.pool_elems;
        let scratch = plan.alloc.gemm_scratch_elems;
        // Per-example staging for the batch-folded drivers' unfoldable
        // loop: one slab at the largest node output. Single-example
        // sessions never enter that loop, so they carry none.
        let tmp = if mb > 1 { plan.node_elems.iter().copied().max().unwrap_or(0) } else { 0 };
        let (f32_pools, i32_pools, qinput, scratch_f32, scratch_i32) = if float {
            (
                pools.iter().map(|&n| Vec::with_capacity(mb * n)).collect(),
                Vec::new(),
                Vec::new(),
                Arena::slabs(threads, scratch),
                Vec::new(),
            )
        } else {
            (
                Vec::new(),
                pools.iter().map(|&n| Vec::with_capacity(mb * n)).collect(),
                Vec::with_capacity(mb * plan.input_len),
                Vec::new(),
                Arena::slabs(threads, scratch),
            )
        };
        Arena {
            f32_pools,
            i32_pools,
            qinput,
            scratch_f32,
            scratch_i32,
            output: Vec::with_capacity(mb * plan.output_len),
            batch_stage: Vec::with_capacity(mb * plan.input_len),
            batch_tmp_f32: if float { Vec::with_capacity(tmp) } else { Vec::new() },
            batch_tmp_i32: if float { Vec::new() } else { Vec::with_capacity(tmp) },
            max_batch: mb,
            pool: IntraOpPool::new(threads),
        }
    }

    /// Host bytes this arena holds (capacity, not current lengths).
    pub fn host_bytes(&self) -> usize {
        self.f32_pools.iter().map(|p| p.capacity() * 4).sum::<usize>()
            + self.i32_pools.iter().map(|p| p.capacity() * 4).sum::<usize>()
            + self.qinput.capacity() * 4
            + self.scratch_f32.iter().map(|s| s.capacity() * 4).sum::<usize>()
            + self.scratch_i32.iter().map(|s| s.capacity() * 4).sum::<usize>()
            + self.output.capacity() * 4
            + self.batch_stage.capacity() * 4
            + self.batch_tmp_f32.capacity() * 4
            + self.batch_tmp_i32.capacity() * 4
    }

    /// Intra-op thread budget this arena executes with.
    pub fn intra_op_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Micro-batch capacity this arena is sized for.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Buffer base addresses — stable across `run` calls iff the arena is
    /// truly reused without reallocation (asserted by the session tests).
    /// Includes EVERY per-thread GEMM packing slab: an undersized scratch
    /// estimate on any worker would show up here as a reallocation.
    pub fn buffer_ptrs(&self) -> Vec<usize> {
        self.f32_pools
            .iter()
            .map(|p| p.as_ptr() as usize)
            .chain(self.i32_pools.iter().map(|p| p.as_ptr() as usize))
            .chain(std::iter::once(self.qinput.as_ptr() as usize))
            .chain(self.scratch_f32.iter().map(|s| s.as_ptr() as usize))
            .chain(self.scratch_i32.iter().map(|s| s.as_ptr() as usize))
            .chain(std::iter::once(self.output.as_ptr() as usize))
            .chain(std::iter::once(self.batch_stage.as_ptr() as usize))
            .chain(std::iter::once(self.batch_tmp_f32.as_ptr() as usize))
            .chain(std::iter::once(self.batch_tmp_i32.as_ptr() as usize))
            .collect()
    }
}

/// One inference engine behind the unified session API. Implementations:
/// [`Float32Backend`], [`FixedQmnBackend`], [`AffineI8Backend`]; external
/// engines plug in via [`SessionBuilder::from_backend`].
pub trait InferenceBackend: Send + Sync {
    /// Short engine label ("float32", "int8-per-layer", "int8-affine").
    fn label(&self) -> String;

    /// Deployment dtype this backend executes at (drives the cost model).
    fn dtype(&self) -> DType;

    /// Quantized-coding style (Table 4), used to pick the matching cost
    /// model: the MicroAI engine for float/fixed Qm.n backends, TFLite
    /// Micro for offset-scale (affine) backends.
    fn coding(&self) -> crate::engines::Coding {
        crate::engines::Coding::FixedQmn
    }

    fn graph(&self) -> &Graph;

    /// ROM weight bytes at the deployment dtype.
    fn weight_bytes(&self) -> usize;

    /// Build-time weight pre-packing: transform every conv/dense node's
    /// weights into NR-tiled B panels with fused-epilogue parameters
    /// (`nn::packed`), paid once per plan instead of per call. The
    /// default (no packing) keeps the per-call GEMM lowering — custom
    /// backends opt in by overriding.
    fn pack_weights(&self) -> PackedWeights {
        PackedWeights::empty(self.graph().nodes.len())
    }

    /// Build-time range verification (`crate::analysis`): prove every
    /// integer accumulator, rescale and requantize cast in the graph
    /// overflow-free under worst-case inputs, returning the per-node
    /// facts. Backends without integer arithmetic have nothing to prove
    /// and return [`VerifiedFacts::unverified`]. An `Err` means the
    /// quantized graph CAN wrap at runtime — `try_build` refuses to
    /// construct a session for it.
    fn verify(&self) -> Result<VerifiedFacts, VerifyError> {
        Ok(VerifiedFacts::unverified())
    }

    /// [`InferenceBackend::pack_weights`] with the verifier's facts in
    /// hand — backends whose packing makes lane decisions (fixed Qm.n)
    /// override this to use the proven bounds instead of the heuristic.
    fn pack_weights_with(&self, _facts: &VerifiedFacts) -> PackedWeights {
        self.pack_weights()
    }

    /// Compile-once step: range verification → §5.7 lifetime analysis →
    /// buffer plan → facts-driven weight packing. Fails (instead of
    /// building a session that wraps in release mode) when the range
    /// proof fails.
    fn prepare(&self) -> Result<Plan, VerifyError> {
        let facts = self.verify()?;
        let mut plan = Plan::for_graph(self.graph(), self.dtype().bytes());
        plan.packed = Arc::new(self.pack_weights_with(&facts));
        plan.facts = Arc::new(facts);
        Ok(plan)
    }

    /// Preallocate an activation arena for `plan`, with one GEMM scratch
    /// slab per intra-op thread, a worker pool of `threads` total threads
    /// (1 = serial), and activation pools sized for micro-batches of up
    /// to `max_batch` examples.
    fn new_arena(&self, plan: &Plan, threads: usize, max_batch: usize) -> Arena;

    /// Run one example; logits land in (and are returned from) the arena.
    fn run<'a>(&self, plan: &Plan, arena: &'a mut Arena, input: &[f32]) -> &'a [f32];

    /// Run `batch` examples laid out contiguously in `inputs` as ONE
    /// micro-batch; the concatenated logits (`batch × output_len`) land
    /// in (and are returned from) the arena. The default loops per
    /// example through [`InferenceBackend::run`]; the built-in backends
    /// override it with the batch-folded executors (one GEMM per
    /// dense/1×1 layer for the whole micro-batch — bit-exact with this
    /// loop by construction, see DESIGN.md §11). Callers must not exceed
    /// the arena's `max_batch` capacity.
    fn run_many<'a>(
        &self,
        plan: &Plan,
        arena: &'a mut Arena,
        inputs: &[f32],
        batch: usize,
    ) -> &'a [f32] {
        assert_eq!(inputs.len(), batch * plan.input_len, "ragged batch");
        let mut acc = std::mem::take(&mut arena.batch_tmp_f32);
        acc.clear();
        for ex in inputs.chunks_exact(plan.input_len.max(1)) {
            acc.extend_from_slice(self.run(plan, arena, ex));
        }
        arena.output.clear();
        arena.output.extend_from_slice(&acc);
        acc.clear();
        arena.batch_tmp_f32 = acc;
        &arena.output
    }

    /// Run a flattened batch (`inputs.len()` must be a multiple of the
    /// input length), appending each example's logits to `out`.
    fn run_batch(&self, plan: &Plan, arena: &mut Arena, inputs: &[f32], out: &mut Vec<f32>) {
        assert_eq!(inputs.len() % plan.input_len.max(1), 0, "ragged batch");
        for ex in inputs.chunks_exact(plan.input_len) {
            let logits = self.run(plan, arena, ex);
            out.extend_from_slice(logits);
        }
    }

    /// Calibration run (float reference backend only): records per-node
    /// activation ranges. Returns false when the backend cannot calibrate.
    fn run_calibrate(
        &self,
        _plan: &Plan,
        _arena: &mut Arena,
        _input: &[f32],
        _stats: &mut ActStats,
    ) -> bool {
        false
    }
}

/// The float32 reference engine (also the PTQ calibration pass).
pub struct Float32Backend {
    pub graph: Arc<Graph>,
}

impl InferenceBackend for Float32Backend {
    fn label(&self) -> String {
        "float32".into()
    }

    fn dtype(&self) -> DType {
        DType::F32
    }

    fn graph(&self) -> &Graph {
        &self.graph
    }

    fn weight_bytes(&self) -> usize {
        self.graph.param_count() * 4
    }

    fn new_arena(&self, plan: &Plan, threads: usize, max_batch: usize) -> Arena {
        Arena::preallocated(plan, true, threads, max_batch)
    }

    fn pack_weights(&self) -> PackedWeights {
        PackedWeights::for_float(&self.graph)
    }

    fn run<'a>(&self, plan: &Plan, arena: &'a mut Arena, input: &[f32]) -> &'a [f32] {
        float_exec::run_pooled(
            &self.graph, input, &plan.alloc, &plan.node_elems,
            &mut arena.f32_pools, &arena.pool, &mut arena.scratch_f32, &plan.packed, None,
            &mut arena.output,
        );
        &arena.output
    }

    fn run_many<'a>(
        &self,
        plan: &Plan,
        arena: &'a mut Arena,
        inputs: &[f32],
        batch: usize,
    ) -> &'a [f32] {
        float_exec::run_pooled_batch(
            &self.graph, inputs, batch, &plan.alloc, &plan.node_elems,
            &mut arena.f32_pools, &arena.pool, &mut arena.scratch_f32, &plan.packed,
            &mut arena.batch_tmp_f32, &mut arena.output,
        );
        &arena.output
    }

    fn run_calibrate(
        &self,
        plan: &Plan,
        arena: &mut Arena,
        input: &[f32],
        stats: &mut ActStats,
    ) -> bool {
        float_exec::run_pooled(
            &self.graph, input, &plan.alloc, &plan.node_elems,
            &mut arena.f32_pools, &arena.pool, &mut arena.scratch_f32, &plan.packed,
            Some(stats), &mut arena.output,
        );
        true
    }
}

/// The MicroAI fixed-point Qm.n engine (int8 / int9 / int16).
pub struct FixedQmnBackend {
    pub qg: Arc<QuantizedGraph>,
}

impl InferenceBackend for FixedQmnBackend {
    fn label(&self) -> String {
        self.qg.spec.label()
    }

    fn dtype(&self) -> DType {
        // int9 deploys in 16-bit containers, as the generated C does.
        if self.qg.width <= 8 {
            DType::I8
        } else {
            DType::I16
        }
    }

    fn graph(&self) -> &Graph {
        &self.qg.graph
    }

    fn weight_bytes(&self) -> usize {
        self.qg.weight_bytes()
    }

    fn new_arena(&self, plan: &Plan, threads: usize, max_batch: usize) -> Arena {
        Arena::preallocated(plan, false, threads, max_batch)
    }

    fn pack_weights(&self) -> PackedWeights {
        PackedWeights::for_fixed(&self.qg)
    }

    fn verify(&self) -> Result<VerifiedFacts, VerifyError> {
        crate::analysis::analyze_fixed(&self.qg)
    }

    fn pack_weights_with(&self, facts: &VerifiedFacts) -> PackedWeights {
        PackedWeights::for_fixed_facts(&self.qg, facts)
    }

    fn run<'a>(&self, plan: &Plan, arena: &'a mut Arena, input: &[f32]) -> &'a [f32] {
        int_exec::run_pooled(
            &self.qg, input, &plan.alloc, &plan.node_elems,
            &mut arena.qinput, &mut arena.i32_pools, &arena.pool,
            &mut arena.scratch_i32, &plan.packed, &mut arena.output,
        );
        &arena.output
    }

    fn run_many<'a>(
        &self,
        plan: &Plan,
        arena: &'a mut Arena,
        inputs: &[f32],
        batch: usize,
    ) -> &'a [f32] {
        int_exec::run_pooled_batch(
            &self.qg, inputs, batch, &plan.alloc, &plan.node_elems,
            &mut arena.qinput, &mut arena.i32_pools, &arena.pool,
            &mut arena.scratch_i32, &plan.packed, &mut arena.batch_tmp_i32,
            &mut arena.output,
        );
        &arena.output
    }
}

/// The TFLite-semantics affine int8 engine (Appendix B baseline).
pub struct AffineI8Backend {
    pub aq: Arc<AffineQuantizedGraph>,
}

impl InferenceBackend for AffineI8Backend {
    fn label(&self) -> String {
        "int8-affine".into()
    }

    fn dtype(&self) -> DType {
        DType::I8
    }

    fn coding(&self) -> crate::engines::Coding {
        crate::engines::Coding::OffsetScale
    }

    fn graph(&self) -> &Graph {
        &self.aq.graph
    }

    fn weight_bytes(&self) -> usize {
        // int8 weight payloads only; the per-filter scale/bias records
        // the affine scheme additionally ships are not counted here.
        self.aq.graph.param_count()
    }

    fn new_arena(&self, plan: &Plan, threads: usize, max_batch: usize) -> Arena {
        Arena::preallocated(plan, false, threads, max_batch)
    }

    fn pack_weights(&self) -> PackedWeights {
        PackedWeights::for_affine(&self.aq)
    }

    fn verify(&self) -> Result<VerifiedFacts, VerifyError> {
        crate::analysis::analyze_affine(&self.aq)
    }

    fn run<'a>(&self, plan: &Plan, arena: &'a mut Arena, input: &[f32]) -> &'a [f32] {
        affine_exec::run_pooled(
            &self.aq, input, &plan.alloc, &plan.node_elems,
            &mut arena.qinput, &mut arena.i32_pools, &arena.pool,
            &mut arena.scratch_i32, &plan.packed, &mut arena.output,
        );
        &arena.output
    }

    fn run_many<'a>(
        &self,
        plan: &Plan,
        arena: &'a mut Arena,
        inputs: &[f32],
        batch: usize,
    ) -> &'a [f32] {
        affine_exec::run_pooled_batch(
            &self.aq, inputs, batch, &plan.alloc, &plan.node_elems,
            &mut arena.qinput, &mut arena.i32_pools, &arena.pool,
            &mut arena.scratch_i32, &plan.packed, &mut arena.batch_tmp_i32,
            &mut arena.output,
        );
        &arena.output
    }
}

/// Deployment facts carried by every session, replacing the simulated
/// constants consumers used to hand-wire.
#[derive(Clone, Debug)]
pub struct SessionMeta {
    pub backend: String,
    pub dtype: DType,
    pub board: Option<&'static Board>,
    /// Predicted single-inference device latency (ms) on `board`, from
    /// the calibrated `mcu::cost` model of the engine matching this
    /// backend's coding scheme. None when no board is attached (or the
    /// engine model does not cover the board/dtype).
    pub device_latency_ms: Option<f64>,
    /// Predicted per-inference energy (µWh) on `board` (§6.2 E = t·V·I).
    pub device_energy_uwh: Option<f64>,
    pub weight_bytes: usize,
    /// Device activation RAM (§5.7 pools + input buffer) at dtype width.
    pub device_ram_bytes: usize,
    pub n_pools: usize,
    /// Host bytes preallocated in this session's arena.
    pub arena_bytes: usize,
    /// Host bytes of the plan's prepacked weight arena (`nn::packed`):
    /// NR-tiled B panels + epilogue copies, built once and ALIASED by
    /// every fork (not per-session memory). Host-only — device RAM/ROM
    /// pricing is untouched.
    pub packed_weight_bytes: usize,
    /// Intra-op thread budget (host-side GEMM parallelism; 1 = serial).
    /// Forked sessions inherit it unless overridden via
    /// [`Session::fork_with`].
    pub intra_op_threads: usize,
    /// Micro-batch capacity the arena is sized for
    /// ([`SessionBuilder::max_batch`]); [`Session::infer`] splits larger
    /// batches into micro-batches of this size. Host-side only.
    pub max_batch: usize,
    /// GEMM microkernel set the plan's packed weights dispatch to
    /// (`"scalar"` / `"avx2"` / `"avx2+fma"` — `nn::simd`), so bench
    /// artifacts and serving rows are attributable to the ISA that
    /// produced them. Forks inherit it (they alias the packed arena).
    pub kernel: &'static str,
}

/// Builder: pick a backend, optionally attach a deployment board, build.
pub struct SessionBuilder {
    backend: Arc<dyn InferenceBackend>,
    board: Option<&'static Board>,
    threads: usize,
    max_batch: usize,
    force_scalar: bool,
}

impl SessionBuilder {
    /// Float32 reference engine.
    pub fn float32(graph: impl Into<Arc<Graph>>) -> SessionBuilder {
        Self::from_backend(Arc::new(Float32Backend { graph: graph.into() }))
    }

    /// MicroAI fixed-point Qm.n engine (width taken from the quantized
    /// graph: 8, 9 or 16 bits).
    pub fn fixed_qmn(qg: impl Into<Arc<QuantizedGraph>>) -> SessionBuilder {
        Self::from_backend(Arc::new(FixedQmnBackend { qg: qg.into() }))
    }

    /// TFLite-semantics affine int8 engine.
    pub fn affine_i8(aq: impl Into<Arc<AffineQuantizedGraph>>) -> SessionBuilder {
        Self::from_backend(Arc::new(AffineI8Backend { aq: aq.into() }))
    }

    /// Any custom [`InferenceBackend`] implementation.
    pub fn from_backend(backend: Arc<dyn InferenceBackend>) -> SessionBuilder {
        SessionBuilder { backend, board: None, threads: 1, max_batch: 1, force_scalar: false }
    }

    /// Attach a deployment board: the session metadata then carries
    /// predicted latency/energy from the calibrated `mcu::cost` models.
    pub fn board(mut self, board: &'static Board) -> SessionBuilder {
        self.board = Some(board);
        self
    }

    /// Intra-op thread budget for the GEMM kernel core (default 1 =
    /// serial). The arena preallocates one packing slab per thread and a
    /// persistent worker pool; results are bit-identical across budgets
    /// for the integer backends and ULP-equivalent for float32 (see
    /// `nn::parallel` for the determinism argument). Host-side only —
    /// the device cost model is untouched.
    pub fn threads(mut self, n: usize) -> SessionBuilder {
        self.threads = n.max(1);
        self
    }

    /// Micro-batch capacity (default 1 = single-example serving): the
    /// arena's pools, quantized-input and output buffers are sized for up
    /// to `n` examples, and [`Session::infer`] folds each micro-batch of
    /// up to `n` examples into ONE GEMM per dense/1×1 layer. Larger
    /// batches split into `n`-sized micro-batches. Host-side only — the
    /// device cost model and RAM accounting stay per-example.
    pub fn max_batch(mut self, n: usize) -> SessionBuilder {
        self.max_batch = n.max(1);
        self
    }

    /// Pin every packed-weight GEMM in this session to the portable
    /// scalar microkernels instead of the runtime-detected SIMD set
    /// (`nn::simd::detected`). The dispatch-equivalence contract makes
    /// this behavior-preserving — integer logits are bit-identical, f32
    /// stays inside the 1e-4 budget — so the switch exists for A/B
    /// baselines (`bench_hotpath --force-scalar`) and cross-arch
    /// equivalence tests, not for correctness workarounds.
    pub fn force_scalar_kernels(mut self, force: bool) -> SessionBuilder {
        self.force_scalar = force;
        self
    }

    /// [`SessionBuilder::build`], surfacing verification failures as an
    /// error instead of a panic: the range proof (`crate::analysis`) must
    /// admit every integer accumulator and the plan's buffer invariants
    /// must hold ([`Plan::validate`] — the promoted kernel
    /// `debug_assert!` guards) before a session exists. A graph whose
    /// accumulators can wrap in release mode is REJECTED here at build
    /// time, never silently mis-inferred.
    pub fn try_build(self) -> Result<Session, VerifyError> {
        let plan = self.backend.prepare()?;
        plan.validate(self.backend.graph())?;
        Ok(self.finish(plan))
    }

    pub fn build(self) -> Session {
        let plan = self.backend.prepare().unwrap_or_else(|e| panic!("{e}"));
        plan.validate(self.backend.graph()).unwrap_or_else(|e| panic!("{e}"));
        self.finish(plan)
    }

    fn finish(self, mut plan: Plan) -> Session {
        if self.force_scalar {
            // The packed arena is freshly built by `prepare()` and not
            // yet shared with any fork, so make_mut never deep-copies.
            Arc::make_mut(&mut plan.packed).set_kernels(crate::nn::simd::scalar());
        }
        let arena = self.backend.new_arena(&plan, self.threads, self.max_batch);
        let dtype = self.backend.dtype();
        let (device_latency_ms, device_energy_uwh) = match self.board {
            None => (None, None),
            Some(board) => {
                // Cost model matching the backend's coding scheme: the
                // MicroAI engine for float/Qm.n, TFLite Micro for the
                // offset-scale affine engine.
                let engine = match self.backend.coding() {
                    crate::engines::Coding::OffsetScale => crate::engines::tflite_micro(),
                    crate::engines::Coding::FixedQmn => crate::engines::microai(),
                };
                let lat = engine.latency_s(self.backend.graph(), board, dtype);
                (
                    lat.map(|s| s * 1e3),
                    lat.map(|s| crate::mcu::cost::energy_uwh(s, board)),
                )
            }
        };
        let meta = SessionMeta {
            backend: self.backend.label(),
            dtype,
            board: self.board,
            device_latency_ms,
            device_energy_uwh,
            weight_bytes: self.backend.weight_bytes(),
            device_ram_bytes: plan.device_ram_bytes(),
            n_pools: plan.alloc.n_pools(),
            arena_bytes: arena.host_bytes(),
            packed_weight_bytes: plan.packed.host_bytes(),
            intra_op_threads: self.threads,
            max_batch: self.max_batch,
            kernel: plan.packed.kernel_name(),
        };
        Session { backend: self.backend, plan, arena, meta, runs: 0 }
    }
}

/// Classification outcome of [`Session::classify`].
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub class: usize,
    /// Softmax max-probability confidence of the logits.
    pub confidence: f32,
}

/// Softmax max-probability confidence. The max logit contributes
/// exp(0) = 1 after shifting, so this is 1/Σexp(v−m) — allocation-free
/// (it runs per request in the serving cascade).
pub fn confidence(logits: &[f32]) -> f32 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    let sum: f32 = logits.iter().map(|&v| (v - m).exp()).sum();
    1.0 / sum
}

/// A length-checked view over a micro-batch of examples for
/// [`Session::infer`] — either contiguous (flattened examples
/// back-to-back) or strided (examples embedded at a fixed stride in
/// larger records, e.g. a feature row followed by metadata columns).
/// Construction checks the geometry once, so the inference path never
/// smears payloads across neighbouring examples.
#[derive(Clone, Copy, Debug)]
pub struct Batch<'a> {
    data: &'a [f32],
    n: usize,
    example_len: usize,
    stride: usize,
}

impl<'a> Batch<'a> {
    /// Flattened contiguous examples: `data.len()` must be a whole
    /// multiple of `example_len`.
    pub fn contiguous(data: &'a [f32], example_len: usize) -> Batch<'a> {
        let el = example_len.max(1);
        assert_eq!(data.len() % el, 0, "ragged batch");
        Batch { data, n: data.len() / el, example_len: el, stride: el }
    }

    /// A single example.
    pub fn single(example: &'a [f32]) -> Batch<'a> {
        Batch { data: example, n: 1, example_len: example.len(), stride: example.len() }
    }

    /// `n` examples at a fixed `stride ≥ example_len` into `data`: the
    /// first `example_len` elements of each record are the example.
    pub fn strided(data: &'a [f32], n: usize, example_len: usize, stride: usize) -> Batch<'a> {
        assert!(
            stride >= example_len,
            "stride {stride} shorter than an example ({example_len})"
        );
        assert!(
            n == 0 || (n - 1) * stride + example_len <= data.len(),
            "strided batch overruns its backing slice"
        );
        Batch { data, n, example_len, stride }
    }

    /// Number of examples in the view.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Elements per example.
    pub fn example_len(&self) -> usize {
        self.example_len
    }

    /// Whether consecutive examples touch (the zero-copy fold path).
    pub fn is_contiguous(&self) -> bool {
        self.stride == self.example_len
    }

    /// Example `i` (panics when out of range).
    pub fn example(&self, i: usize) -> &'a [f32] {
        assert!(i < self.n, "example index {i} out of range ({} examples)", self.n);
        &self.data[i * self.stride..i * self.stride + self.example_len]
    }

    /// `count` consecutive examples starting at `lo` as one contiguous
    /// slice — contiguous views only.
    fn contiguous_slice(&self, lo: usize, count: usize) -> &'a [f32] {
        debug_assert!(self.is_contiguous());
        &self.data[lo * self.example_len..(lo + count) * self.example_len]
    }
}

/// Caller-owned prediction buffer for [`Session::infer`] (append-only;
/// reuse it across batches to classify allocation-free).
pub type Predictions = Vec<Prediction>;

/// Classify `n` examples' worth of concatenated logits into `out`.
fn push_predictions(logits: &[f32], olen: usize, n: usize, out: &mut Predictions) {
    for e in 0..n {
        let l = &logits[e * olen..(e + 1) * olen];
        out.push(Prediction { class: argmax(l), confidence: confidence(l) });
    }
}

/// Shape overrides for [`Session::fork_with`]: `None` fields inherit
/// from the parent session, so `ForkOpts::inherit()` reproduces
/// [`Session::fork`]. One builder carries BOTH knobs a serving worker
/// needs (thread budget and arena micro-batch capacity), replacing the
/// old two-place plumbing of `fork_with_threads` plus scheduler-side
/// batch sizing.
#[derive(Clone, Copy, Debug, Default)]
pub struct ForkOpts {
    /// Intra-op GEMM thread budget (`None` = inherit the parent's).
    pub threads: Option<usize>,
    /// Micro-batch capacity of the forked arena (`None` = inherit).
    pub max_batch: Option<usize>,
}

impl ForkOpts {
    /// Inherit everything from the parent session.
    pub fn inherit() -> ForkOpts {
        ForkOpts::default()
    }

    /// Override the intra-op thread budget.
    pub fn threads(mut self, n: usize) -> ForkOpts {
        self.threads = Some(n);
        self
    }

    /// Override the arena micro-batch capacity.
    pub fn max_batch(mut self, n: usize) -> ForkOpts {
        self.max_batch = Some(n);
        self
    }
}

/// A compiled, preallocated inference session (compile once, run many).
pub struct Session {
    backend: Arc<dyn InferenceBackend>,
    plan: Plan,
    arena: Arena,
    meta: SessionMeta,
    runs: u64,
}

impl Session {
    /// Run one example; the returned logits borrow the session arena.
    pub fn run(&mut self, input: &[f32]) -> &[f32] {
        self.runs += 1;
        self.backend.run(&self.plan, &mut self.arena, input)
    }

    /// The unified inference entry point: classify every example of
    /// `batch` in order, appending one [`Prediction`] per example to
    /// `out`. The batch splits into micro-batches of up to
    /// [`SessionMeta::max_batch`] examples; within a micro-batch, dense
    /// layers and stride-1 1×1 convs execute as ONE folded GEMM over the
    /// whole micro-batch while unfoldable layers (spatial convs,
    /// attention, pooling) loop per example inside the same plan — so
    /// batched results are bit-exact with the per-example path by
    /// construction (DESIGN.md §11). Everything runs through this
    /// session's one preallocated arena; non-contiguous views are staged
    /// into the arena first (the only copy on this path).
    pub fn infer(&mut self, batch: &Batch<'_>, out: &mut Predictions) {
        assert_eq!(batch.example_len(), self.plan.input_len, "example/input length mismatch");
        out.reserve(batch.len());
        let olen = self.plan.output_len;
        let mb = self.meta.max_batch.max(1);
        let mut lo = 0usize;
        while lo < batch.len() {
            let n = mb.min(batch.len() - lo);
            self.runs += n as u64;
            if batch.is_contiguous() {
                let logits = self.backend.run_many(
                    &self.plan,
                    &mut self.arena,
                    batch.contiguous_slice(lo, n),
                    n,
                );
                push_predictions(logits, olen, n, out);
            } else {
                let mut staged = std::mem::take(&mut self.arena.batch_stage);
                staged.clear();
                for i in lo..lo + n {
                    staged.extend_from_slice(batch.example(i));
                }
                let logits = self.backend.run_many(&self.plan, &mut self.arena, &staged, n);
                push_predictions(logits, olen, n, out);
                staged.clear();
                self.arena.batch_stage = staged;
            }
            lo += n;
        }
    }

    /// Run one example and classify it.
    #[deprecated(note = "use Session::infer with Batch::single")]
    pub fn classify(&mut self, input: &[f32]) -> Prediction {
        let mut out = Predictions::with_capacity(1);
        self.infer(&Batch::single(input), &mut out);
        out[0]
    }

    /// Classify a flattened batch (`inputs.len()` must be a multiple of
    /// the input length); returns one [`Prediction`] per example.
    #[deprecated(note = "use Session::infer with Batch::contiguous")]
    pub fn classify_batch(&mut self, inputs: &[f32]) -> Vec<Prediction> {
        let mut out = Vec::with_capacity(inputs.len() / self.plan.input_len.max(1));
        self.infer(&Batch::contiguous(inputs, self.plan.input_len), &mut out);
        out
    }

    /// Classify a flattened batch into a caller-owned buffer (appends).
    #[deprecated(note = "use Session::infer with Batch::contiguous")]
    pub fn classify_batch_into(&mut self, inputs: &[f32], out: &mut Vec<Prediction>) {
        self.infer(&Batch::contiguous(inputs, self.plan.input_len), out);
    }

    /// Classify each input slice in order (appends one [`Prediction`]
    /// per example). Kept as a real per-example loop: arbitrary
    /// unrelated slices cannot fold into one GEMM without staging — use
    /// [`Session::infer`] with [`Batch::strided`] (or stage into
    /// [`Batch::contiguous`]) to get the folded path. Every slice must
    /// be exactly one input long; a wrong-length example fails loudly
    /// instead of smearing payloads across its neighbours.
    #[deprecated(note = "use Session::infer with Batch::strided or Batch::contiguous")]
    pub fn classify_each_into<'a>(
        &mut self,
        inputs: impl IntoIterator<Item = &'a [f32]>,
        out: &mut Vec<Prediction>,
    ) {
        for ex in inputs {
            assert_eq!(ex.len(), self.plan.input_len, "example/input length mismatch");
            self.runs += 1;
            let logits = self.backend.run(&self.plan, &mut self.arena, ex);
            out.push(Prediction { class: argmax(logits), confidence: confidence(logits) });
        }
    }

    /// Run a flattened batch; returns `n_examples * output_len` logits.
    pub fn run_batch(&mut self, inputs: &[f32]) -> Vec<f32> {
        let mut out = Vec::with_capacity(inputs.len() / self.plan.input_len.max(1)
            * self.plan.output_len);
        self.run_batch_into(inputs, &mut out);
        out
    }

    /// Batch into a caller-owned buffer (appends; no arena allocation).
    /// Runs in batch-folded micro-batches of up to
    /// [`SessionMeta::max_batch`] examples, like [`Session::infer`].
    pub fn run_batch_into(&mut self, inputs: &[f32], out: &mut Vec<f32>) {
        let ilen = self.plan.input_len.max(1);
        assert_eq!(inputs.len() % ilen, 0, "ragged batch");
        let total = inputs.len() / ilen;
        self.runs += total as u64;
        let mb = self.meta.max_batch.max(1);
        let mut lo = 0usize;
        while lo < total {
            let n = mb.min(total - lo);
            let chunk = &inputs[lo * ilen..(lo + n) * ilen];
            let logits = self.backend.run_many(&self.plan, &mut self.arena, chunk, n);
            out.extend_from_slice(logits);
            lo += n;
        }
    }

    /// Calibration run (float backend): records activation ranges into
    /// `stats`. Returns false for backends that cannot calibrate.
    pub fn calibrate(&mut self, input: &[f32], stats: &mut ActStats) -> bool {
        let ok = self.backend.run_calibrate(&self.plan, &mut self.arena, input, stats);
        if ok {
            self.runs += 1;
        }
        ok
    }

    /// A new session sharing this one's backend (and therefore weights)
    /// and plan, with a freshly preallocated arena — one per worker
    /// thread. The §5.7 lifetime analysis is not recomputed and the
    /// prepacked weight arena is ALIASED (`Arc` clone, read-only), never
    /// re-packed or copied — N serving workers share one `PackedWeights`
    /// allocation. Thread budget and micro-batch capacity are inherited
    /// (each fork gets its OWN worker pool — pools are never shared
    /// across sessions); override them via [`Session::fork_with`].
    pub fn fork(&self) -> Session {
        self.fork_with(ForkOpts::inherit())
    }

    /// [`Session::fork`] with explicit shape overrides — the serving
    /// coordinator uses this both to cap `workers × intra_op_threads` at
    /// the host's available parallelism and to size each worker's arena
    /// for its micro-batch. Panicking twin of
    /// [`Session::try_fork_with`].
    pub fn fork_with(&self, opts: ForkOpts) -> Session {
        self.try_fork_with(opts).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible fork: rejects shapes whose arena sizing is degenerate or
    /// arithmetically unrepresentable (`max_batch == 0`, or a pool whose
    /// batched byte size overflows `usize`) instead of panicking deep in
    /// the allocator.
    pub fn try_fork_with(&self, opts: ForkOpts) -> Result<Session, VerifyError> {
        let perr = |reason: String| VerifyError { node: "<fork>".into(), reason };
        let threads = opts.threads.unwrap_or(self.meta.intra_op_threads).max(1);
        let max_batch = opts.max_batch.unwrap_or(self.meta.max_batch);
        if max_batch == 0 {
            return Err(perr("fork max_batch must be at least 1".into()));
        }
        for &elems in self
            .plan
            .alloc
            .pool_elems
            .iter()
            .chain([self.plan.input_len, self.plan.output_len].iter())
        {
            if elems.checked_mul(max_batch).and_then(|e| e.checked_mul(4)).is_none() {
                return Err(perr(format!(
                    "max_batch {max_batch} overflows the arena sizing of a \
                     {elems}-element buffer"
                )));
            }
        }
        let plan = self.plan.clone();
        let arena = self.backend.new_arena(&plan, threads, max_batch);
        let meta = SessionMeta {
            intra_op_threads: threads,
            max_batch,
            arena_bytes: arena.host_bytes(),
            ..self.meta.clone()
        };
        Ok(Session { backend: self.backend.clone(), plan, arena, meta, runs: 0 })
    }

    /// [`Session::fork`] with a different intra-op thread budget.
    #[deprecated(note = "use Session::fork_with(ForkOpts::inherit().threads(n))")]
    pub fn fork_with_threads(&self, threads: usize) -> Session {
        self.fork_with(ForkOpts::inherit().threads(threads))
    }

    pub fn meta(&self) -> &SessionMeta {
        &self.meta
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// The build-time range-verification facts this session was admitted
    /// under ([`VerifiedFacts::unverified`] for the float32 backend).
    pub fn facts(&self) -> &VerifiedFacts {
        &self.plan.facts
    }

    pub fn arena(&self) -> &Arena {
        &self.arena
    }

    pub fn backend(&self) -> &Arc<dyn InferenceBackend> {
        &self.backend
    }

    pub fn input_len(&self) -> usize {
        self.plan.input_len
    }

    pub fn output_len(&self) -> usize {
        self.plan.output_len
    }

    /// Number of examples this session has executed.
    pub fn runs(&self) -> u64 {
        self.runs
    }
}

#[cfg(test)]
mod tests {
    // The deprecated classify/fork wrappers must stay green — exercised
    // deliberately below.
    #![allow(deprecated)]

    use super::*;
    use crate::graph::build::resnet_v1_6_shapes;
    use crate::graph::deploy_pipeline;
    use crate::graph::ir::LayerKind;
    use crate::quant::{quantize, quantize_affine, QuantSpec};
    use crate::util::prng::Pcg32;

    fn randomized_graph(seed: u64) -> Graph {
        let mut g = resnet_v1_6_shapes("t", 1, &[32, 3], 4, 8);
        let mut rng = Pcg32::seeded(seed);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.4;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
        }
        deploy_pipeline(&g)
    }

    fn inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| (0..len).map(|_| rng.normal()).collect()).collect()
    }

    #[test]
    fn float_session_matches_legacy_run() {
        // Sessions run the prepacked fused path on EVERY conv/dense
        // (including shapes the per-call lowering routes to the naive
        // reference), so float logits agree with the legacy free
        // function within the established 1e-4 fused-reorder budget, not
        // bit-for-bit. Integer sessions stay bit-exact — see
        // `qmn_session_matches_legacy_run` below.
        let g = randomized_graph(1);
        let mut sess = SessionBuilder::float32(g.clone()).build();
        for x in inputs(5, 96, 2) {
            let legacy = float_exec::run(&g, &x, None);
            let s = sess.run(&x).to_vec();
            assert_eq!(legacy.len(), s.len());
            for (a, b) in legacy.iter().zip(&s) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
        assert_eq!(sess.runs(), 5);
    }

    #[test]
    fn qmn_session_matches_legacy_run() {
        let g = randomized_graph(3);
        let xs = inputs(6, 96, 4);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        for spec in [QuantSpec::int8_per_layer(), QuantSpec::int16_per_layer()] {
            let qg = quantize(&g, &stats, spec);
            let mut sess = SessionBuilder::fixed_qmn(qg.clone()).build();
            for x in &xs {
                assert_eq!(int_exec::run(&qg, x), sess.run(x).to_vec());
            }
        }
    }

    #[test]
    fn affine_session_matches_legacy_run() {
        let g = randomized_graph(5);
        let xs = inputs(6, 96, 6);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let aq = quantize_affine(&g, &stats);
        let mut sess = SessionBuilder::affine_i8(aq.clone()).build();
        for x in &xs {
            assert_eq!(affine_exec::run(&aq, x), sess.run(x).to_vec());
        }
    }

    #[test]
    fn arena_buffers_are_reused_across_runs() {
        let g = randomized_graph(7);
        let xs = inputs(4, 96, 8);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let mut sess = SessionBuilder::fixed_qmn(qg).build();
        sess.run(&xs[0]);
        let ptrs = sess.arena().buffer_ptrs();
        let bytes = sess.arena().host_bytes();
        for x in &xs {
            for _ in 0..3 {
                sess.run(x);
            }
        }
        assert_eq!(ptrs, sess.arena().buffer_ptrs(), "arena reallocated between runs");
        assert_eq!(bytes, sess.arena().host_bytes());
    }

    #[test]
    fn run_batch_equals_single_runs() {
        let g = randomized_graph(9);
        let xs = inputs(3, 96, 10);
        let mut sess = SessionBuilder::float32(g).build();
        let singles: Vec<f32> = {
            let mut v = Vec::new();
            for x in &xs {
                v.extend_from_slice(sess.run(x));
            }
            v
        };
        let flat: Vec<f32> = xs.iter().flatten().copied().collect();
        let batched = sess.run_batch(&flat);
        assert_eq!(singles, batched);
        assert_eq!(batched.len(), 3 * sess.output_len());
    }

    #[test]
    fn classify_batch_equals_single_classifies() {
        let g = randomized_graph(19);
        let xs = inputs(5, 96, 20);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let mut sess = SessionBuilder::fixed_qmn(qg).build();
        let singles: Vec<Prediction> = xs.iter().map(|x| sess.classify(x)).collect();
        let flat: Vec<f32> = xs.iter().flatten().copied().collect();
        sess.run(&xs[0]); // settle the arena before capturing addresses
        let ptrs = sess.arena().buffer_ptrs();
        let batched = sess.classify_batch(&flat);
        assert_eq!(batched.len(), singles.len());
        for (a, b) in singles.iter().zip(&batched) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.confidence, b.confidence);
        }
        // The batch ran inside the same preallocated arena.
        assert_eq!(ptrs, sess.arena().buffer_ptrs(), "classify_batch reallocated the arena");
        // Non-contiguous batch entry point: same results, same arena.
        let mut each = Vec::new();
        sess.classify_each_into(xs.iter().map(|x| x.as_slice()), &mut each);
        for (a, b) in singles.iter().zip(&each) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.confidence, b.confidence);
        }
        assert_eq!(ptrs, sess.arena().buffer_ptrs());
        assert_eq!(sess.runs(), 5 + 1 + 5 + 5);
    }

    #[test]
    #[should_panic(expected = "example/input length mismatch")]
    fn classify_each_rejects_wrong_length_examples() {
        let g = randomized_graph(21);
        let mut sess = SessionBuilder::float32(g).build();
        let short = vec![0.0f32; 95]; // model input is 96
        let mut out = Vec::new();
        sess.classify_each_into([short.as_slice()], &mut out);
    }

    #[test]
    #[should_panic(expected = "example/input length mismatch")]
    fn infer_rejects_wrong_length_examples() {
        let g = randomized_graph(21);
        let mut sess = SessionBuilder::float32(g).build();
        let short = vec![0.0f32; 95]; // model input is 96
        let mut out = Predictions::new();
        sess.infer(&Batch::single(&short), &mut out);
    }

    #[test]
    fn infer_matches_wrappers_across_batch_geometries() {
        let g = randomized_graph(37);
        let xs = inputs(7, 96, 38);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let mut sess = SessionBuilder::fixed_qmn(qg).max_batch(4).build();
        assert_eq!(sess.meta().max_batch, 4);
        assert_eq!(sess.arena().max_batch(), 4);
        let singles: Vec<Prediction> = xs.iter().map(|x| sess.classify(x)).collect();

        // Contiguous view, larger than max_batch → micro-batch chunking.
        let flat: Vec<f32> = xs.iter().flatten().copied().collect();
        let mut preds = Predictions::new();
        sess.infer(&Batch::contiguous(&flat, 96), &mut preds);
        assert_eq!(preds.len(), singles.len());
        for (a, b) in singles.iter().zip(&preds) {
            assert_eq!((a.class, a.confidence), (b.class, b.confidence));
        }

        // Strided view: examples padded with 4 garbage trailer columns.
        let stride = 96 + 4;
        let mut recs = vec![f32::NAN; xs.len() * stride];
        for (i, x) in xs.iter().enumerate() {
            recs[i * stride..i * stride + 96].copy_from_slice(x);
        }
        let strided = Batch::strided(&recs, xs.len(), 96, stride);
        assert!(!strided.is_contiguous());
        preds.clear();
        sess.infer(&strided, &mut preds);
        for (a, b) in singles.iter().zip(&preds) {
            assert_eq!((a.class, a.confidence), (b.class, b.confidence));
        }

        // All of the above ran in the session's one preallocated arena.
        let ptrs = sess.arena().buffer_ptrs();
        preds.clear();
        sess.infer(&Batch::contiguous(&flat, 96), &mut preds);
        assert_eq!(ptrs, sess.arena().buffer_ptrs(), "infer reallocated the arena");
        assert_eq!(sess.runs(), 7 + 7 + 7 + 7);
    }

    #[test]
    #[should_panic(expected = "ragged batch")]
    fn contiguous_batch_rejects_ragged_input() {
        let _ = Batch::contiguous(&[0.0; 97], 96);
    }

    #[test]
    #[should_panic(expected = "overruns its backing slice")]
    fn strided_batch_rejects_overrun() {
        let _ = Batch::strided(&[0.0; 100], 2, 96, 96);
    }

    #[test]
    fn fork_with_opts_shapes_the_worker() {
        let g = randomized_graph(39);
        let template = SessionBuilder::float32(g).threads(4).max_batch(8).build();
        let fork = template.fork();
        assert_eq!(fork.meta().intra_op_threads, 4);
        assert_eq!(fork.meta().max_batch, 8);
        let shaped = template.fork_with(ForkOpts::inherit().threads(2).max_batch(1));
        assert_eq!(shaped.meta().intra_op_threads, 2);
        assert_eq!(shaped.meta().max_batch, 1);
        assert_eq!(shaped.arena().max_batch(), 1);
        // Batched pools + extra scratch slabs show up in the accounting.
        assert!(fork.meta().arena_bytes > shaped.meta().arena_bytes);
        // Degenerate shapes are rejected, not built.
        let err = template.try_fork_with(ForkOpts::inherit().max_batch(0)).unwrap_err();
        assert!(err.reason.contains("max_batch"), "wrong reason: {err}");
        let err = template
            .try_fork_with(ForkOpts::inherit().max_batch(usize::MAX / 2))
            .unwrap_err();
        assert!(err.reason.contains("overflows"), "wrong reason: {err}");
    }

    #[test]
    fn calibration_through_session_matches_legacy() {
        let g = randomized_graph(11);
        let xs = inputs(4, 96, 12);
        let mut legacy = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut legacy));
        }
        let mut sess = SessionBuilder::float32(g.clone()).build();
        let mut via_sess = ActStats::new(g.nodes.len());
        for x in &xs {
            assert!(sess.calibrate(x, &mut via_sess));
        }
        // Prepacked sessions run the blocked kernel on every shape while
        // the legacy path falls back to the reference on tiny layers, so
        // recorded ranges agree within the f32 fused-reorder budget.
        let close = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).all(|(x, y)| (x - y).abs() < 1e-4)
        };
        assert!(close(&legacy.max_abs, &via_sess.max_abs));
        assert!(close(&legacy.min, &via_sess.min));
        assert!(close(&legacy.max, &via_sess.max));
    }

    #[test]
    fn meta_carries_cost_model_predictions() {
        let g = randomized_graph(13);
        let xs = inputs(4, 96, 14);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let sess = SessionBuilder::fixed_qmn(qg)
            .board(&crate::mcu::board::SPARKFUN_EDGE)
            .build();
        let m = sess.meta();
        assert_eq!(m.dtype, DType::I8);
        let lat = m.device_latency_ms.expect("latency prediction");
        let en = m.device_energy_uwh.expect("energy prediction");
        assert!(lat > 0.0 && en > 0.0);
        assert!(m.device_ram_bytes > 0);
        assert!(m.arena_bytes > 0);
        assert!(m.n_pools >= 2);

        // Without a board there is no cost prediction.
        let g2 = randomized_graph(15);
        let s2 = SessionBuilder::float32(g2).build();
        assert!(s2.meta().device_latency_ms.is_none());
    }

    #[test]
    fn threaded_session_bit_identical_to_serial() {
        let g = randomized_graph(23);
        let xs = inputs(4, 96, 24);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qg = Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()));
        let aq = Arc::new(quantize_affine(&g, &stats));
        let mut serial_q = SessionBuilder::fixed_qmn(qg.clone()).build();
        let mut serial_a = SessionBuilder::affine_i8(aq.clone()).build();
        for threads in [2usize, 4] {
            let mut par_q = SessionBuilder::fixed_qmn(qg.clone()).threads(threads).build();
            let mut par_a = SessionBuilder::affine_i8(aq.clone()).threads(threads).build();
            assert_eq!(par_q.meta().intra_op_threads, threads);
            assert_eq!(par_q.arena().intra_op_threads(), threads);
            for x in &xs {
                assert_eq!(serial_q.run(x).to_vec(), par_q.run(x).to_vec());
                assert_eq!(serial_a.run(x).to_vec(), par_a.run(x).to_vec());
            }
        }
    }

    #[test]
    fn fork_with_threads_rethreads_the_arena() {
        let g = randomized_graph(25);
        let template = SessionBuilder::float32(g).threads(4).build();
        let fork = template.fork();
        assert_eq!(fork.meta().intra_op_threads, 4);
        assert_eq!(fork.arena().intra_op_threads(), 4);
        let capped = template.fork_with_threads(2);
        assert_eq!(capped.meta().intra_op_threads, 2);
        assert_eq!(capped.arena().intra_op_threads(), 2);
        // One scratch slab per thread shows up in the arena accounting.
        assert!(fork.meta().arena_bytes > capped.meta().arena_bytes);
    }

    #[test]
    fn fork_shares_weights_but_not_arena() {
        let g = randomized_graph(17);
        let mut a = SessionBuilder::float32(g).build();
        let mut b = a.fork();
        let xs = inputs(1, 96, 18);
        let ra = a.run(&xs[0]).to_vec();
        let rb = b.run(&xs[0]).to_vec();
        assert_eq!(ra, rb);
        assert_ne!(a.arena().buffer_ptrs(), b.arena().buffer_ptrs());
    }

    #[test]
    fn fork_aliases_one_packed_weights_arena() {
        // The prepacked weight arena is read-only plan state: every fork
        // must point at the SAME allocation (Arc alias), never re-pack.
        let g = randomized_graph(27);
        let xs = inputs(4, 96, 28);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let root = SessionBuilder::fixed_qmn(qg).threads(2).build();
        assert!(root.meta().packed_weight_bytes > 0);
        assert!(!root.plan().packed.is_empty());
        let forks = [root.fork(), root.fork_with_threads(4)];
        for f in &forks {
            assert!(
                Arc::ptr_eq(&root.plan().packed, &f.plan().packed),
                "fork re-packed or copied the weight arena"
            );
            assert_eq!(f.meta().packed_weight_bytes, root.meta().packed_weight_bytes);
        }
        // Affine and float plans carry packed weights too.
        let aq = quantize_affine(&g, &stats);
        let sa = SessionBuilder::affine_i8(aq).build();
        assert!(sa.meta().packed_weight_bytes > 0);
        let sf = SessionBuilder::float32(g.clone()).build();
        assert!(sf.meta().packed_weight_bytes > 0);
    }

    #[test]
    fn verified_sessions_carry_facts_and_proven_lanes() {
        let g = randomized_graph(31);
        let xs = inputs(4, 96, 32);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let sess = SessionBuilder::fixed_qmn(qg.clone())
            .try_build()
            .expect("shipped resnet must verify");
        let facts = sess.facts();
        assert_eq!(facts.backend, "fixed-qmn");
        assert_eq!(facts.nodes.len(), qg.graph.nodes.len());
        // The packed lanes agree with the proof on every conv/dense node.
        for node in &qg.graph.nodes {
            if let (Some(pn), Some(i32_proven)) =
                (sess.plan().packed.get(node.id), facts.lane_is_i32(node.id))
            {
                assert_eq!(pn.is_i32_lane(), i32_proven, "lane/proof mismatch at {}", node.name);
            }
        }
        let aq = quantize_affine(&g, &stats);
        let sa = SessionBuilder::affine_i8(aq).try_build().expect("affine verifies");
        assert_eq!(sa.facts().backend, "affine-i8");
        // Float32 has nothing to prove: unverified facts, empty node list.
        let sf = SessionBuilder::float32(g).try_build().expect("float always builds");
        assert_eq!(sf.facts().backend, "unverified");
        assert!(sf.facts().nodes.is_empty());
    }

    #[test]
    fn try_build_rejects_crafted_overflow_graph() {
        // A Dense whose folded bias payload overflows the i64 accumulator
        // domain at width 16: pre-PR this built a session that silently
        // wrapped in release mode; now it is rejected at build time.
        let mut g0 = crate::graph::ir::Graph::new("overflow", 1, &[4, 1], 2);
        let f = g0.add("fl", LayerKind::Flatten, vec![0]);
        let w = crate::tensor::TensorF::from_vec(&[4, 2], vec![0.01; 8]);
        let mut b = crate::tensor::TensorF::from_vec(&[2], vec![0.0, 0.0]);
        b.data[0] = 1.0e16;
        g0.add("fc", LayerKind::Dense { w, b }, vec![f]);
        let g = deploy_pipeline(&g0);
        let xs = inputs(4, 4, 33);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qg = quantize(&g, &stats, QuantSpec::int16_per_layer());
        let err = SessionBuilder::fixed_qmn(qg).try_build().unwrap_err();
        assert!(err.reason.contains("i64"), "wrong rejection reason: {err}");
    }

    #[test]
    #[should_panic(expected = "range verifier")]
    fn build_panics_on_unverifiable_graph() {
        let mut g0 = crate::graph::ir::Graph::new("overflow", 1, &[4, 1], 2);
        let f = g0.add("fl", LayerKind::Flatten, vec![0]);
        let w = crate::tensor::TensorF::from_vec(&[4, 2], vec![0.01; 8]);
        let mut b = crate::tensor::TensorF::from_vec(&[2], vec![0.0, 0.0]);
        b.data[0] = 1.0e16;
        g0.add("fc", LayerKind::Dense { w, b }, vec![f]);
        let g = deploy_pipeline(&g0);
        let xs = inputs(4, 4, 34);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qg = quantize(&g, &stats, QuantSpec::int16_per_layer());
        SessionBuilder::fixed_qmn(qg).build();
    }

    #[test]
    fn plan_validate_catches_undersized_pools() {
        // Regression for the promoted debug_assert guards: a plan whose
        // pool table was corrupted (here: shrunk below a node's output
        // size) must fail validation instead of reaching the kernels,
        // where only debug builds would have caught the short buffer.
        let g = randomized_graph(35);
        let mut plan = Plan::for_graph(&g, 4);
        assert!(plan.validate(&g).is_ok());
        let victim = plan
            .alloc
            .pool_of
            .iter()
            .find(|&&p| p != usize::MAX)
            .copied()
            .expect("some pooled node");
        plan.alloc.pool_elems[victim] = 0;
        let err = plan.validate(&g).unwrap_err();
        assert!(err.reason.contains("pool"), "wrong reason: {err}");
    }

    #[test]
    fn outputs_independent_of_graph_weight_storage_after_packing() {
        // The acceptance property of the prepacked pipeline: once the
        // packed arena is built, NO per-inference code path reads (or
        // zero-point-adjusts) graph weight storage. Mutating every
        // weight payload, bias, shift and requant parameter after the
        // pack must leave outputs bit-identical.
        let g = randomized_graph(29);
        let xs = inputs(3, 96, 30);
        let mut stats = ActStats::new(g.nodes.len());
        for x in &xs {
            float_exec::run(&g, x, Some(&mut stats));
        }

        // Fixed-point executor.
        let mut qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let alloc = crate::allocator::allocate(&qg.graph);
        let ne = node_elems(&qg.graph);
        let pool = IntraOpPool::serial();
        let packed = PackedWeights::for_fixed(&qg);
        let run_fixed = |qg: &QuantizedGraph, x: &[f32]| {
            let mut pools: Vec<Vec<i32>> = vec![Vec::new(); alloc.n_pools()];
            let (mut qin, mut scratch, mut out) = (Vec::new(), vec![Vec::new()], Vec::new());
            int_exec::run_pooled(
                qg, x, &alloc, &ne, &mut qin, &mut pools, &pool, &mut scratch, &packed,
                &mut out,
            );
            out
        };
        let before: Vec<Vec<f32>> = xs.iter().map(|x| run_fixed(&qg, x)).collect();
        for qw in qg.weights.values_mut() {
            for v in qw.w.iter_mut() {
                *v = v.wrapping_mul(3).wrapping_add(11);
            }
            for b in qw.b_acc.iter_mut() {
                *b = b.wrapping_add(987_654);
            }
            for s in qw.shift.iter_mut() {
                *s = (*s + 3) % 15;
            }
        }
        for (x, want) in xs.iter().zip(&before) {
            assert_eq!(&run_fixed(&qg, x), want, "fixed executor read mutated weight storage");
        }

        // Affine executor (incl. the build-time zero-point fold).
        let mut aq = quantize_affine(&g, &stats);
        let a_alloc = crate::allocator::allocate(&aq.graph);
        let a_ne = node_elems(&aq.graph);
        let a_packed = PackedWeights::for_affine(&aq);
        let run_affine = |aq: &crate::quant::affine::AffineQuantizedGraph, x: &[f32]| {
            let mut pools: Vec<Vec<i32>> = vec![Vec::new(); a_alloc.n_pools()];
            let (mut qin, mut scratch, mut out) = (Vec::new(), vec![Vec::new()], Vec::new());
            affine_exec::run_pooled(
                aq, x, &a_alloc, &a_ne, &mut qin, &mut pools, &pool, &mut scratch, &a_packed,
                &mut out,
            );
            out
        };
        let a_before: Vec<Vec<f32>> = xs.iter().map(|x| run_affine(&aq, x)).collect();
        for qw in aq.weights.values_mut() {
            for v in qw.w.iter_mut() {
                *v = v.wrapping_mul(5).wrapping_sub(7);
            }
            for b in qw.b.iter_mut() {
                *b = b.wrapping_add(13_579);
            }
            for m in qw.mult.iter_mut() {
                *m = m.wrapping_add(101);
            }
            for s in qw.shift.iter_mut() {
                *s += 1;
            }
        }
        for (x, want) in xs.iter().zip(&a_before) {
            assert_eq!(&run_affine(&aq, x), want, "affine executor read mutated weight storage");
        }

        // Float executor.
        let mut gf = g.clone();
        let f_alloc = crate::allocator::allocate(&gf);
        let f_ne = node_elems(&gf);
        let f_packed = PackedWeights::for_float(&gf);
        let run_float = |gf: &Graph, x: &[f32]| {
            let mut pools: Vec<Vec<f32>> = vec![Vec::new(); f_alloc.n_pools()];
            let (mut scratch, mut out) = (vec![Vec::new()], Vec::new());
            float_exec::run_pooled(
                gf, x, &f_alloc, &f_ne, &mut pools, &pool, &mut scratch, &f_packed, None,
                &mut out,
            );
            out
        };
        let f_before: Vec<Vec<f32>> = xs.iter().map(|x| run_float(&gf, x)).collect();
        for n in gf.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = *v * -2.0 + 1.0;
                }
                for v in b.data.iter_mut() {
                    *v += 42.0;
                }
            }
        }
        for (x, want) in xs.iter().zip(&f_before) {
            assert_eq!(&run_float(&gf, x), want, "float executor read mutated weight storage");
        }
    }
}
