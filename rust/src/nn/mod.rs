//! The MicroAI inference engine: float32, fixed-point Qm.n (int8/int9/
//! int16) and affine int8 (TFLite-semantics) executors over the layer
//! graph IR — the Rust twin of the C library KerasCNN2C generates.

pub mod affine_exec;
pub mod float_exec;
pub mod float_ops;
pub mod gemm;
pub mod int_exec;
pub mod int_ops;
pub mod packed;
pub mod parallel;
#[cfg(test)]
mod plan_soundness;
pub mod session;
pub mod simd;

pub use float_exec::{argmax, ActStats};
pub use packed::{Epilogue, PackedNode, PackedWeights};
pub use parallel::IntraOpPool;
pub use session::{
    AffineI8Backend, Arena, Batch, FixedQmnBackend, Float32Backend, ForkOpts,
    InferenceBackend, Plan, Prediction, Predictions, Session, SessionBuilder, SessionMeta,
};
