//! Fixed-point graph executor: runs a [`QuantizedGraph`] on one example,
//! reproducing the generated-C dataflow end to end (input quantization at
//! INPUT_SCALE_FACTOR, integer layers, dequantized logits out).

use crate::fixedpoint::QFormat;
use crate::graph::ir::LayerKind;
use crate::quant::ptq::QuantizedGraph;

use super::gemm;
use super::int_ops as ops;

/// Execute the quantized graph on a float input; returns float logits
/// (payloads of the last node dequantized at its activation format).
///
/// Deprecated in favour of [`crate::nn::session::Session`]: this wrapper
/// re-runs the §5.7 lifetime analysis and reallocates the activation
/// pools on every call. A `Session` does both once and reuses the arena
/// across `run` calls.
pub fn run(qg: &QuantizedGraph, input: &[f32]) -> Vec<f32> {
    let graph = &qg.graph;
    let alloc = crate::allocator::allocate(graph);
    let node_elems = super::session::node_elems(graph);
    let mut pools: Vec<Vec<i32>> = vec![Vec::new(); alloc.n_pools()];
    let mut qinput = Vec::new();
    let pool = super::parallel::IntraOpPool::serial();
    let mut scratch = vec![Vec::new()];
    let mut output = Vec::new();
    // Legacy per-call semantics: no prepacked weights (bit-identical to
    // the prepacked path for the integer engines either way).
    let packed = super::packed::PackedWeights::empty(graph.nodes.len());
    run_pooled(
        qg, input, &alloc, &node_elems, &mut qinput, &mut pools, &pool, &mut scratch, &packed,
        &mut output,
    );
    output
}

/// Pooled core shared by [`run`] and the Qm.n [`crate::nn::session`]
/// backend: integer payloads live in the allocator's §5.7 pools, the
/// quantized input in `qinput`, the dequantized logits in `output`.
/// `scratch` carries one im2col slab per intra-op thread of `pool`. With
/// a preallocated arena no per-request heap allocation occurs. Conv and
/// dense nodes present in `packed` run the prepacked fused-epilogue
/// kernels (bit-exact with the per-call path) and never read
/// `qg.weights`; absent nodes keep the per-call GEMM lowering.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_pooled(
    qg: &QuantizedGraph,
    input: &[f32],
    alloc: &crate::allocator::Allocation,
    node_elems: &[usize],
    qinput: &mut Vec<i32>,
    pools: &mut [Vec<i32>],
    pool: &super::parallel::IntraOpPool,
    scratch: &mut [Vec<i32>],
    packed: &super::packed::PackedWeights,
    output: &mut Vec<f32>,
) {
    let graph = &qg.graph;
    assert_eq!(input.len(), graph.input_shape.iter().product::<usize>());

    let in_fmt = QFormat::new(qg.width, qg.act_n[0]);
    qinput.clear();
    qinput.extend(input.iter().map(|&x| in_fmt.quantize(x)));

    for node in &graph.nodes {
        if matches!(node.kind, LayerKind::Input) {
            continue;
        }
        let p = alloc.pool_of[node.id];
        if let Some(s) = alloc.inplace_with[node.id] {
            // In-place lowering: the slot already holds input `s`'s
            // payload (same class ⇒ same slot); mutate it directly.
            let mut buf = std::mem::take(&mut pools[p]);
            exec_node_inplace(qg, node, s, 1, qinput, pools, &alloc.pool_of, node_elems, &mut buf);
            pools[p] = buf;
            continue;
        }
        let mut out = std::mem::take(&mut pools[p]);
        {
            let qin: &[i32] = qinput;
            let src =
                |i: usize| super::session::pool_src(pools, qin, &alloc.pool_of, node_elems, i);
            exec_node(qg, node, &src, packed, pool, scratch, &mut out);
        }
        pools[p] = out;
    }

    dequantize_output(qg, alloc, node_elems, qinput, pools, 1, output);
}

/// Batch-folded twin of [`run_pooled`]: run `batch` examples laid out
/// contiguously in `inputs` through ONE pass over the graph. Per node,
/// dense layers and stride-1 1×1 convs fold the whole micro-batch into
/// one packed GEMM — the batch stacks into the M dimension (dense) or
/// the leading spatial axis (pointwise conv) of the SAME kernel call, so
/// every output element sees the identical k-major accumulation and
/// fused epilogue the per-example call produces, bit-exactly. Every
/// other layer loops per example through the shared [`exec_node`].
/// Pools hold example-major payloads (`pools[q][ex · node_elems[i]..]`
/// is example `ex` of producer `i`), sized by the arena's `max_batch`
/// factor; `tmp` stages one example's output in the unfoldable loop.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_pooled_batch(
    qg: &QuantizedGraph,
    inputs: &[f32],
    batch: usize,
    alloc: &crate::allocator::Allocation,
    node_elems: &[usize],
    qinput: &mut Vec<i32>,
    pools: &mut [Vec<i32>],
    pool: &super::parallel::IntraOpPool,
    scratch: &mut [Vec<i32>],
    packed: &super::packed::PackedWeights,
    tmp: &mut Vec<i32>,
    output: &mut Vec<f32>,
) {
    if batch <= 1 {
        // Single example: the per-example driver IS the folded path
        // (no per-node fold dispatch to pay for).
        return run_pooled(
            qg, inputs, alloc, node_elems, qinput, pools, pool, scratch, packed, output,
        );
    }
    let graph = &qg.graph;
    let ilen: usize = graph.input_shape.iter().product();
    assert_eq!(inputs.len(), batch * ilen, "ragged batch");

    let in_fmt = QFormat::new(qg.width, qg.act_n[0]);
    qinput.clear();
    qinput.extend(inputs.iter().map(|&x| in_fmt.quantize(x)));

    for node in &graph.nodes {
        if matches!(node.kind, LayerKind::Input) {
            continue;
        }
        let p = alloc.pool_of[node.id];
        let ne = node_elems[node.id];
        if let Some(s) = alloc.inplace_with[node.id] {
            // In-place lowering over the example-major slot (flat for
            // elementwise arms, per-example rows for softmax).
            let mut buf = std::mem::take(&mut pools[p]);
            exec_node_inplace(
                qg, node, s, batch, qinput, pools, &alloc.pool_of, node_elems, &mut buf,
            );
            pools[p] = buf;
            continue;
        }
        let mut out = std::mem::take(&mut pools[p]);
        let folded = {
            let qin: &[i32] = qinput;
            // Whole-batch producer slice: example-major payloads are
            // contiguous, so a folded GEMM reads them as one A matrix.
            let whole = |i: usize| {
                let q = alloc.pool_of[i];
                if q == usize::MAX {
                    qin
                } else {
                    &pools[q][..batch * node_elems[i]]
                }
            };
            match (&node.kind, packed.get(node.id)) {
                (LayerKind::Dense { .. }, Some(pn)) => {
                    super::packed::dense_int_batched(
                        whole(node.inputs[0]), batch, pn, pool, &mut out,
                    );
                    true
                }
                (LayerKind::Conv { stride: 1, padding, .. }, Some(pn))
                    if pn.ks.iter().all(|&k| k == 1) =>
                {
                    // A stride-1 1×1 conv is pointwise: no window ever
                    // crosses an example boundary, and its geometry maps
                    // every input position to one output position under
                    // either padding, so concatenating the batch along
                    // the leading spatial axis runs the whole micro-batch
                    // as one call with batch× the positions — output is
                    // the example-major concatenation, bit-identical.
                    let ish = &graph.nodes[node.inputs[0]].out_shape;
                    if graph.dims == 1 {
                        super::packed::conv1d_int_packed(
                            whole(node.inputs[0]), batch * ish[0], pn, 1, *padding, pool,
                            scratch, &mut out,
                        );
                    } else {
                        super::packed::conv2d_int_packed(
                            whole(node.inputs[0]), batch * ish[0], ish[1], pn, 1, *padding,
                            pool, scratch, &mut out,
                        );
                    }
                    true
                }
                _ => false,
            }
        };
        if !folded {
            // Unfoldable layer (spatial conv, pooling, attention, ...):
            // loop per example inside the same plan, staging each
            // example's output through `tmp`.
            out.clear();
            out.resize(batch * ne, 0);
            for ex in 0..batch {
                {
                    let qin: &[i32] = qinput;
                    let src = |i: usize| {
                        let q = alloc.pool_of[i];
                        if q == usize::MAX {
                            &qin[ex * ilen..(ex + 1) * ilen]
                        } else {
                            let nei = node_elems[i];
                            &pools[q][ex * nei..(ex + 1) * nei]
                        }
                    };
                    exec_node(qg, node, &src, packed, pool, scratch, tmp);
                }
                out[ex * ne..(ex + 1) * ne].copy_from_slice(tmp);
            }
        }
        pools[p] = out;
    }

    dequantize_output(qg, alloc, node_elems, qinput, pools, batch, output);
}

/// One node's single-example compute: read producer payloads through
/// `src`, write the node's output payload into `out`. Shared verbatim by
/// the per-example driver ([`run_pooled`]) and the unfoldable arm of the
/// batch-folded driver ([`run_pooled_batch`]) — so the batched path
/// inherits every property pinned on this code.
fn exec_node<'a>(
    qg: &QuantizedGraph,
    node: &crate::graph::ir::Node,
    src: &dyn Fn(usize) -> &'a [i32],
    packed: &super::packed::PackedWeights,
    pool: &super::parallel::IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) {
    let graph = &qg.graph;
    let width = qg.width;
    match &node.kind {
        LayerKind::Input => unreachable!(),
        LayerKind::Conv { w, stride, padding, .. } => {
            // Prepacked fused path (never touches qg.weights) or
            // per-call im2col + blocked GEMM — both bit-exact
            // with the naive int_ops::conv*_q_ref kernels
            // (property-pinned).
            let x = src(node.inputs[0]);
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            if let Some(pn) = packed.get(node.id) {
                if graph.dims == 1 {
                    super::packed::conv1d_int_packed(
                        x, ish[0], pn, *stride, *padding, pool, scratch, out,
                    );
                } else {
                    super::packed::conv2d_int_packed(
                        x, ish[0], ish[1], pn, *stride, *padding, pool, scratch, out,
                    );
                }
            } else {
                let qw = &qg.weights[&node.id];
                if graph.dims == 1 {
                    gemm::conv1d_q_gemm(
                        x, ish[0], ish[1], qw, w.shape[0], w.shape[2], *stride,
                        *padding, node.fused_relu, width, pool, scratch, out,
                    );
                } else {
                    gemm::conv2d_q_gemm(
                        x, ish[0], ish[1], ish[2], qw, w.shape[0], w.shape[1],
                        w.shape[3], *stride, *padding, node.fused_relu, width,
                        pool, scratch, out,
                    );
                }
            }
        }
        LayerKind::Dense { w, .. } => {
            if let Some(pn) = packed.get(node.id) {
                super::packed::dense_int_packed(src(node.inputs[0]), pn, pool, out);
            } else {
                let qw = &qg.weights[&node.id];
                gemm::dense_q_gemm(
                    src(node.inputs[0]), qw, w.shape[1], node.fused_relu, width, pool, out,
                );
            }
        }
        LayerKind::MaxPool { size } => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let c = *ish.last().unwrap();
            ops::maxpool_q(
                src(node.inputs[0]), &ish[..ish.len() - 1], c, *size, node.fused_relu, out,
            );
        }
        LayerKind::AvgPool { size } => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let c = *ish.last().unwrap();
            ops::avgpool_q(src(node.inputs[0]), &ish[..ish.len() - 1], c, *size, out);
        }
        LayerKind::GlobalAvgPool => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let c = *ish.last().unwrap();
            let positions: usize = ish[..ish.len() - 1].iter().product();
            ops::global_avgpool_q(src(node.inputs[0]), positions, c, out);
        }
        LayerKind::Add => {
            let (ia, ib) = (node.inputs[0], node.inputs[1]);
            ops::add_q(
                src(ia), qg.act_n[ia], src(ib), qg.act_n[ib],
                qg.act_n[node.id], node.fused_relu, width, out,
            );
        }
        LayerKind::ReLU => {
            ops::relu_q(src(node.inputs[0]), out);
        }
        LayerKind::Flatten => {
            out.clear();
            out.extend_from_slice(src(node.inputs[0]));
        }
        LayerKind::Softmax => {
            // Inference-time softmax: exp-LUT distances at the
            // input format, probabilities at width-1 fractional
            // bits (the quantizer pins act_n accordingly).
            ops::softmax_q_ref(
                src(node.inputs[0]), qg.act_n[node.inputs[0]], qg.act_n[node.id], width, out,
            );
        }
        LayerKind::Embedding { w } => {
            let crate::quant::ptq::QTxWeights::Embed { table } = &qg.tx[&node.id] else {
                panic!("embedding node without Embed params");
            };
            ops::embedding_q(src(node.inputs[0]), table, w.shape[1], out);
        }
        LayerKind::LayerNorm { .. } => {
            let crate::quant::ptq::QTxWeights::Norm { gamma, g_n, beta } = &qg.tx[&node.id]
            else {
                panic!("layernorm node without Norm params");
            };
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let c = *ish.last().unwrap();
            ops::layernorm_q_ref(
                src(node.inputs[0]), c, gamma, *g_n, beta, qg.act_n[node.id], width, out,
            );
        }
        LayerKind::SelfAttention { heads, head_dim, .. } => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let (seq, dm) = (ish[0], ish[1]);
            if let Some(pa) = packed.attn(node.id) {
                super::packed::attention_int_packed(
                    src(node.inputs[0]), seq, dm, *heads, *head_dim, pa, pool, scratch, out,
                );
            } else {
                ops::attention_q_ref(
                    src(node.inputs[0]), seq, dm, *heads, *head_dim, &qg.tx[&node.id], width,
                    out,
                );
            }
        }
        LayerKind::ZeroPad { pad } => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            zero_pad_q_into(src(node.inputs[0]), ish, pad, out);
        }
        LayerKind::BatchNorm { .. } => {
            panic!("BatchNorm must be folded before integer execution (run deploy_pipeline)")
        }
    }
}

/// In-place twin of [`exec_node`] for nodes the memory plan lowered onto
/// an input buffer (`alloc.inplace_with[id] = Some(s)`): the shared slot
/// already holds `s`'s example-major payloads, so the kernel mutates
/// `buf` directly. Only the planner's alias-safe kinds appear here
/// (checker-enforced); each arm is bit-exact against its out-of-place
/// twin (see the `int_ops` in-place kernels). `batch` folds flat where
/// the op is elementwise and loops per-example rows where it is not.
fn exec_node_inplace(
    qg: &QuantizedGraph,
    node: &crate::graph::ir::Node,
    s: usize,
    batch: usize,
    qin: &[i32],
    pools: &[Vec<i32>],
    pool_of: &[usize],
    node_elems: &[usize],
    buf: &mut Vec<i32>,
) {
    match &node.kind {
        LayerKind::Add => {
            // The other operand is proven by the checker to live in a
            // different slot, so this read never aliases `buf`.
            let o = if node.inputs[0] == s { node.inputs[1] } else { node.inputs[0] };
            let q = pool_of[o];
            let other: &[i32] =
                if q == usize::MAX { qin } else { &pools[q][..batch * node_elems[o]] };
            ops::add_q_inplace(
                buf, qg.act_n[s], other, qg.act_n[o], qg.act_n[node.id], node.fused_relu,
                qg.width,
            );
        }
        LayerKind::ReLU => ops::relu_q_inplace(buf),
        LayerKind::Flatten => {} // payload is already the flattened tensor
        LayerKind::Softmax => {
            let ne = node_elems[node.id];
            for row in buf.chunks_exact_mut(ne) {
                ops::softmax_q_inplace(row, qg.act_n[node.inputs[0]], qg.act_n[node.id], qg.width);
            }
        }
        LayerKind::Embedding { w } => {
            let crate::quant::ptq::QTxWeights::Embed { table } = &qg.tx[&node.id] else {
                panic!("embedding node without Embed params");
            };
            ops::embedding_q_inplace(buf, table, w.shape[1]);
        }
        other => panic!("in-place lowering of non-elementwise layer {}", other.type_name()),
    }
}

/// Dequantize the output node's example-major payloads into `output`.
fn dequantize_output(
    qg: &QuantizedGraph,
    alloc: &crate::allocator::Allocation,
    node_elems: &[usize],
    qinput: &[i32],
    pools: &[Vec<i32>],
    batch: usize,
    output: &mut Vec<f32>,
) {
    let graph = &qg.graph;
    let out_id = graph.output_id();
    let out_fmt = QFormat::new(qg.width, qg.act_n[out_id]);
    output.clear();
    let p = alloc.pool_of[out_id];
    if p == usize::MAX {
        output.extend(qinput.iter().map(|&q| out_fmt.dequantize(q)));
    } else {
        output.extend(
            pools[p][..batch * node_elems[out_id]].iter().map(|&q| out_fmt.dequantize(q)),
        );
    }
}

fn zero_pad_q_into(src: &[i32], ish: &[usize], pad: &[(usize, usize)], out: &mut Vec<i32>) {
    let c = *ish.last().unwrap();
    out.clear();
    match pad.len() {
        1 => {
            let (lo, hi) = pad[0];
            let s = ish[0];
            out.resize((s + lo + hi) * c, 0);
            out[lo * c..(lo + s) * c].copy_from_slice(src);
        }
        2 => {
            let (hlo, hhi) = pad[0];
            let (wlo, whi) = pad[1];
            let (h, w) = (ish[0], ish[1]);
            let nw = w + wlo + whi;
            out.resize((h + hlo + hhi) * nw * c, 0);
            for r in 0..h {
                let dst = ((r + hlo) * nw + wlo) * c;
                out[dst..dst + w * c].copy_from_slice(&src[r * w * c..(r + 1) * w * c]);
            }
        }
        r => panic!("zero_pad rank {r}"),
    }
}

/// Capture run for the range-verifier soundness tests
/// (`crate::analysis`): execute the pooled core with one dedicated pool
/// per node (no §5.7 sharing) so every node's payloads survive, and
/// return them indexed by node id (entry 0 = the quantized input).
#[cfg(test)]
pub(crate) fn run_capture(qg: &QuantizedGraph, input: &[f32]) -> Vec<Vec<i32>> {
    let graph = &qg.graph;
    let n = graph.nodes.len();
    let node_elems = super::session::node_elems(graph);
    let mut pool_of: Vec<usize> = (0..n).collect();
    pool_of[0] = usize::MAX; // Input payloads live in qinput
    // Dedicated pools and a sequential device layout, no in-place
    // lowering: every node's payload survives for inspection. (This
    // synthetic plan drives the pools only; it is never checker-gated.)
    let mut offset_of = vec![usize::MAX; n];
    let mut total = 0usize;
    for id in 1..n {
        offset_of[id] = total;
        total += node_elems[id];
    }
    let alloc = crate::allocator::Allocation {
        pool_of,
        pool_elems: node_elems.clone(),
        inplace_with: vec![None; n],
        offset_of,
        arena_elems: total,
        pooled_elems: total,
        attn_scratch_of: vec![None; n],
        gemm_scratch_elems: 0,
        packed_b_elems: 0,
    };
    let mut pools: Vec<Vec<i32>> = vec![Vec::new(); n];
    let mut qinput = Vec::new();
    let pool = super::parallel::IntraOpPool::serial();
    let mut scratch = vec![Vec::new()];
    let mut output = Vec::new();
    let packed = super::packed::PackedWeights::empty(n);
    run_pooled(
        qg, input, &alloc, &node_elems, &mut qinput, &mut pools, &pool, &mut scratch, &packed,
        &mut output,
    );
    pools[0] = qinput;
    pools
}

/// Randomized 6-layer resnet used by the executor, packing and analysis
/// tests (the builder's weights are zero; tests need non-degenerate
/// quantized formats).
#[cfg(test)]
pub(crate) fn randomized_resnet(seed: u64) -> crate::graph::ir::Graph {
    use crate::util::prng::Pcg32;
    let mut g = crate::graph::build::resnet_v1_6_shapes("t", 1, &[32, 3], 4, 8);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.4;
            }
            for v in b.data.iter_mut() {
                *v = rng.normal() * 0.05;
            }
        }
    }
    crate::graph::deploy_pipeline(&g)
}

/// Collect float-run activation stats over a calibration set.
#[cfg(test)]
pub(crate) fn calib(
    g: &crate::graph::ir::Graph,
    inputs: &[Vec<f32>],
) -> crate::nn::float_exec::ActStats {
    let mut stats = crate::nn::float_exec::ActStats::new(g.nodes.len());
    for x in inputs {
        crate::nn::float_exec::run(g, x, Some(&mut stats));
    }
    stats
}

#[cfg(test)]
pub(crate) fn random_inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = crate::util::prng::Pcg32::seeded(seed);
    (0..n).map(|_| (0..len).map(|_| rng.normal()).collect()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::resnet_v1_6_shapes;
    use crate::graph::deploy_pipeline;
    use crate::nn::float_exec;
    use crate::quant::{quantize, QuantSpec};
    use crate::util::prng::Pcg32;

    #[test]
    fn int16_logits_close_to_float() {
        let g = randomized_resnet(1);
        let inputs = random_inputs(8, 96, 2);
        let stats = calib(&g, &inputs);
        let qg = quantize(&g, &stats, QuantSpec::int16_per_layer());
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            let ql = run(&qg, x);
            let max_diff = fl
                .iter()
                .zip(&ql)
                .fold(0.0f32, |a, (u, v)| a.max((u - v).abs()));
            let span = fl.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
            assert!(max_diff / span < 0.02, "diff {max_diff} span {span}");
        }
    }

    #[test]
    fn int8_preserves_argmax_mostly() {
        let g = randomized_resnet(3);
        let inputs = random_inputs(16, 96, 4);
        let stats = calib(&g, &inputs);
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let mut agree = 0;
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            let ql = run(&qg, x);
            if float_exec::argmax(&fl) == float_exec::argmax(&ql) {
                agree += 1;
            }
        }
        assert!(agree >= 12, "argmax agreement {agree}/16");
    }

    #[test]
    fn q7_9_network_wide_runs() {
        let g = randomized_resnet(5);
        let inputs = random_inputs(4, 96, 6);
        let stats = calib(&g, &inputs);
        let qg = quantize(&g, &stats, QuantSpec::int16_q7_9());
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            let ql = run(&qg, x);
            // Q7.9 resolution is ~2e-3 but truncation error accumulates
            // across the 7 integer layers; logits are O(1).
            let max_diff = fl.iter().zip(&ql).fold(0.0f32, |a, (u, v)| a.max((u - v).abs()));
            assert!(max_diff < 0.2, "diff {max_diff}");
        }
    }

    #[test]
    fn per_filter_at_least_as_accurate_as_per_layer() {
        let g = randomized_resnet(7);
        let inputs = random_inputs(12, 96, 8);
        let stats = calib(&g, &inputs);
        let ql_spec = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let qf_spec = quantize(&g, &stats, QuantSpec::int8_per_filter());
        let mut err_l = 0.0f64;
        let mut err_f = 0.0f64;
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            let l = run(&ql_spec, x);
            let f = run(&qf_spec, x);
            for ((&flv, &lv), &fv) in fl.iter().zip(&l).zip(&f) {
                err_l += ((flv - lv) as f64).powi(2);
                err_f += ((flv - fv) as f64).powi(2);
            }
        }
        // Per-filter should not be dramatically worse (usually better).
        assert!(err_f <= err_l * 1.5, "per-filter {err_f} vs per-layer {err_l}");
    }

    #[test]
    fn int9_beats_int8_on_logit_error() {
        let g = randomized_resnet(9);
        let inputs = random_inputs(12, 96, 10);
        let stats = calib(&g, &inputs);
        let q8 = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let q9 = quantize(&g, &stats, QuantSpec::int9_per_layer());
        let (mut e8, mut e9) = (0.0f64, 0.0f64);
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            for (i, &v) in run(&q8, x).iter().enumerate() {
                e8 += ((fl[i] - v) as f64).powi(2);
            }
            for (i, &v) in run(&q9, x).iter().enumerate() {
                e9 += ((fl[i] - v) as f64).powi(2);
            }
        }
        assert!(e9 < e8, "int9 {e9} should beat int8 {e8}");
    }

    #[test]
    fn gtsrb_2d_int_path_runs() {
        let mut g = resnet_v1_6_shapes("g", 2, &[16, 16, 3], 5, 4);
        let mut rng = Pcg32::seeded(11);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = 0.02;
                }
            }
        }
        let g = deploy_pipeline(&g);
        let inputs = random_inputs(4, 16 * 16 * 3, 12);
        let stats = calib(&g, &inputs);
        let qg = quantize(&g, &stats, QuantSpec::int16_per_layer());
        let out = run(&qg, &inputs[0]);
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
