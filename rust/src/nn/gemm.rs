//! im2col + blocked-GEMM kernel core shared by ALL conv/dense backends.
//!
//! The paper's MicroAI engine wins on kernel efficiency; this module is
//! the Rust-side answer: every convolution is lowered to a matrix
//! multiply over an im2col-packed activation panel, and every dense layer
//! is the degenerate m = 1 case of the same multiply. One register-blocked
//! microkernel family serves three numeric flavors:
//!
//! - [`gemm_f32`] — float32 (the calibration / reference engine),
//! - [`gemm_i32`] — fixed-point Qm.n with i32 accumulator lanes (admitted
//!   per node by [`int_ops::accum_fits_i32`], twice the SIMD width),
//! - [`gemm_i64`] — fixed-point Qm.n wide accumulators and the affine
//!   (TFLite-semantics) engine, whose zero-point-shifted operands ride the
//!   same i64 kernel.
//!
//! Semantics contract (pinned by the property tests below): the integer
//! lowerings are **bit-exact** against the naive `*_ref` kernels in
//! [`super::int_ops`] / [`super::affine_exec`] — integer addition is
//! associative, and the i32 admission guard proves no intermediate
//! overflow for any summation order — while the f32 lowering is
//! **ULP-bounded** (reordered summation) against [`super::float_ops`].
//!
//! Memory contract: packing panels are carved from the Session arena.
//! [`scratch_elems`] is the lifetime fact the allocator records per graph
//! (§5.7 spirit: a panel is live only inside one node's execution, so a
//! worst-case buffer **per intra-op thread** serves every node);
//! `Arena::preallocated` reserves one slab per thread once. At the
//! default serial budget (threads = 1) steady-state requests never
//! allocate — the PR-1 contract, preserved by a dispatch-free fast path.
//! At threads > 1 each parallel node pays a few small bookkeeping
//! allocations (the slab-view list and the pool's per-call completion
//! channel) in exchange for multi-core execution; the arena buffers
//! themselves still never reallocate.
//!
//! Intra-op threading (see [`super::parallel`]): every lowered entry
//! point takes an [`IntraOpPool`]. Convolutions split the output-position
//! dimension (the N dimension of the `C = W·X` view) into column panels
//! dispatched across workers — each worker packs its panels into its own
//! scratch slab and writes a disjoint output-row range. Dense layers
//! split the filter dimension in NR-aligned column tiles. In both cases
//! the per-element accumulation order (k-major) is identical to the
//! single-thread schedule, so the integer flavors stay bit-exact across
//! thread counts and f32 stays ULP-equivalent (property-pinned below).
//!
//! Layout: for a conv with weights (k, C, F) (or (kh, kw, C, F)), the
//! packed panel row for output position `o` lists taps in (ki, ci) (or
//! (ki, kj, ci)) order — exactly the row order of the weight matrix viewed
//! as (K = k·C, N = F) row-major. The GEMM output C(m×n) is therefore the
//! channels-last activation block with no epilogue transpose.

use crate::fixedpoint::ops::{clamp_to, rescale};
use crate::graph::ir::{Graph, LayerKind, Padding};
use crate::quant::affine::{requantize, AffineNodeWeights};
use crate::quant::ptq::QNodeWeights;

use super::int_ops::{self, accum_fits_i32};
use super::parallel::{IntraOpPool, SharedOut};

/// Register tile height: output positions updated per microkernel step.
pub const MR: usize = 4;
/// Register tile width: filters updated per microkernel step.
pub const NR: usize = 8;
/// Target element count of one packed im2col panel (16 KiB of i32/f32
/// lanes) — small enough to stay hot in L1/L2 across all filter tiles of
/// the panel, the "cache tiling" half of the design.
const PANEL_TARGET_ELEMS: usize = 4096;
/// Below this many multiply-accumulates (m·n·k) the blocked path cannot
/// amortize packing and tile bookkeeping, so the lowered entry points fall
/// through to the naive reference kernels (bit-identical for the integer
/// flavors, and the f32 fallback IS the reference). Keeps the CI ratio
/// gate honest on tiny dense layers.
pub const GEMM_MIN_MACCS: usize = 2048;

/// Rows of one packed panel: as many output positions as keep the panel
/// near [`PANEL_TARGET_ELEMS`], never below one register tile.
pub fn panel_rows(taps: usize, positions: usize) -> usize {
    let cache = (PANEL_TARGET_ELEMS / taps.max(1)).max(MR);
    cache.min(positions.max(1))
}

/// Worst-case packing/staging scratch (elements) any node of `graph`
/// needs. The lifetime analysis behind it: a panel is live only within
/// one node's execution and nodes run sequentially, so one buffer sized
/// to the max serves the whole graph. Recorded on the allocator's
/// `Allocation` and preallocated by the Session arena.
pub fn scratch_elems(graph: &Graph) -> usize {
    let mut need = 0usize;
    for node in &graph.nodes {
        match &node.kind {
            LayerKind::Conv { w, .. } => {
                let taps: usize = w.shape[..w.shape.len() - 1].iter().product();
                let positions: usize =
                    node.out_shape[..node.out_shape.len() - 1].iter().product();
                need = need.max(panel_rows(taps, positions) * taps);
            }
            // The affine backend stages the zero-point-shifted input
            // before its dense GEMM.
            LayerKind::Dense { w, .. } => need = need.max(w.shape[0]),
            // The prepacked attention lowering carves its whole
            // workspace (Q/K/V/ctx staging, per-head GEMM operands, one
            // head's score matrix) out of scratch slab 0.
            LayerKind::SelfAttention { heads, head_dim, .. } => {
                let seq = node.out_shape[0];
                let dm = heads * head_dim;
                need = need.max(super::packed::attn_scratch_elems(seq, dm, *head_dim));
            }
            _ => {}
        }
    }
    need
}

// ---------------------------------------------------------------------------
// Microkernels
// ---------------------------------------------------------------------------

/// C(m×n) = A(m×k)·B(k×n), row-major, i32 accumulator lanes. ONLY valid
/// when the caller proves no intermediate overflow — for the fixed-point
/// path that proof is [`accum_fits_i32`], which bounds the worst-case
/// |partial sum| + |bias| under i32::MAX/2 for every summation order.
pub fn gemm_i32(
    a: &[i32],
    b: &[i32],
    m: usize,
    n: usize,
    k: usize,
    emit: impl FnMut(usize, usize, i32),
) {
    gemm_i32_cols(a, b, m, n, k, 0, n, emit);
}

/// Column-range variant of [`gemm_i32`]: computes only output columns
/// `j0..j1` (the intra-op pool hands disjoint column ranges to workers).
/// Per-element accumulation order is k-major and independent of `j0`, so
/// any column partition yields the same bits as the full-width call.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i32_cols(
    a: &[i32],
    b: &[i32],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    mut emit: impl FnMut(usize, usize, i32),
) {
    debug_assert!(a.len() >= m * k, "A panel too small");
    debug_assert!(b.len() >= k * n, "B matrix too small");
    debug_assert!(j0 <= j1 && j1 <= n, "bad column range");
    let mut i = 0usize;
    while i < m {
        let mr = MR.min(m - i);
        let mut j = j0;
        while j < j1 {
            let nr = NR.min(j1 - j);
            let mut acc: [[i32; NR]; MR] = [[0; NR]; MR];
            for p in 0..k {
                let brow = &b[p * n + j..p * n + j + nr];
                for (mi, accrow) in acc.iter_mut().enumerate().take(mr) {
                    let av = a[(i + mi) * k + p];
                    if av == 0 {
                        // ReLU sparsity: exact skip for integers.
                        continue;
                    }
                    for (accv, &bv) in accrow.iter_mut().zip(brow) {
                        *accv += av * bv;
                    }
                }
            }
            for (mi, accrow) in acc.iter().enumerate().take(mr) {
                for (ni, &accv) in accrow.iter().enumerate().take(nr) {
                    emit(i + mi, j + ni, accv);
                }
            }
            j += nr;
        }
        i += mr;
    }
}

/// C(m×n) = A(m×k)·B(k×n), row-major, i64 wide accumulators — correct
/// for every operand width (the generated C `long_number_t`).
pub fn gemm_i64(
    a: &[i32],
    b: &[i32],
    m: usize,
    n: usize,
    k: usize,
    emit: impl FnMut(usize, usize, i64),
) {
    gemm_i64_cols(a, b, m, n, k, 0, n, emit);
}

/// Column-range variant of [`gemm_i64`] (see [`gemm_i32_cols`]).
#[allow(clippy::too_many_arguments)]
pub fn gemm_i64_cols(
    a: &[i32],
    b: &[i32],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    mut emit: impl FnMut(usize, usize, i64),
) {
    debug_assert!(a.len() >= m * k, "A panel too small");
    debug_assert!(b.len() >= k * n, "B matrix too small");
    debug_assert!(j0 <= j1 && j1 <= n, "bad column range");
    let mut i = 0usize;
    while i < m {
        let mr = MR.min(m - i);
        let mut j = j0;
        while j < j1 {
            let nr = NR.min(j1 - j);
            let mut acc: [[i64; NR]; MR] = [[0; NR]; MR];
            for p in 0..k {
                let brow = &b[p * n + j..p * n + j + nr];
                for (mi, accrow) in acc.iter_mut().enumerate().take(mr) {
                    let av = a[(i + mi) * k + p];
                    if av == 0 {
                        // ReLU sparsity: exact skip for integers.
                        continue;
                    }
                    let av = av as i64;
                    for (accv, &bv) in accrow.iter_mut().zip(brow) {
                        *accv += av * (bv as i64);
                    }
                }
            }
            for (mi, accrow) in acc.iter().enumerate().take(mr) {
                for (ni, &accv) in accrow.iter().enumerate().take(nr) {
                    emit(i + mi, j + ni, accv);
                }
            }
            j += nr;
        }
        i += mr;
    }
}

/// C(m×n) = A(m×k)·B(k×n) over f32 with the same MR×NR register tile.
/// Accumulation order differs from the reference kernels (tile-local
/// k-major instead of bias-first row sweeps), so results are ULP-close,
/// not bit-equal — pinned by `f32_conv_gemm_is_ulp_close_to_ref`.
pub fn gemm_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    emit: impl FnMut(usize, usize, f32),
) {
    gemm_f32_cols(a, b, m, n, k, 0, n, emit);
}

/// Column-range variant of [`gemm_f32`]. Per-element accumulation stays
/// k-major regardless of the tile origin, so a column partition does not
/// change the f32 rounding relative to the full-width call.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_cols(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    mut emit: impl FnMut(usize, usize, f32),
) {
    debug_assert!(a.len() >= m * k, "A panel too small");
    debug_assert!(b.len() >= k * n, "B matrix too small");
    debug_assert!(j0 <= j1 && j1 <= n, "bad column range");
    let mut i = 0usize;
    while i < m {
        let mr = MR.min(m - i);
        let mut j = j0;
        while j < j1 {
            let nr = NR.min(j1 - j);
            let mut acc: [[f32; NR]; MR] = [[0.0; NR]; MR];
            for p in 0..k {
                let brow = &b[p * n + j..p * n + j + nr];
                for (mi, accrow) in acc.iter_mut().enumerate().take(mr) {
                    let av = a[(i + mi) * k + p];
                    for (accv, &bv) in accrow.iter_mut().zip(brow) {
                        *accv += av * bv;
                    }
                }
            }
            for (mi, accrow) in acc.iter().enumerate().take(mr) {
                for (ni, &accv) in accrow.iter().enumerate().take(nr) {
                    emit(i + mi, j + ni, accv);
                }
            }
            j += nr;
        }
        i += mr;
    }
}

// ---------------------------------------------------------------------------
// im2col packing
// ---------------------------------------------------------------------------

/// Pack `rows` im2col rows (output positions `row0..row0+rows`) of a 1-D
/// conv into `panel` (row-major rows × k·c, tap order (ki, ci) — the row
/// order of the (k, C, F) weight matrix). Out-of-range taps pack the
/// padding payload `pad`; `offset` is subtracted from every in-range
/// element. The per-call affine path packs (offset = zp_in, pad = 0):
/// zero-point pre-subtracted operands, where padding is `zp − zp = 0`.
/// The prepacked path (`nn::packed`) folds the zero point into the bias
/// at build time and packs RAW payloads (offset = 0, pad = zp_in), so
/// padded taps contribute `zp·w`, cancelled exactly by the folded bias.
/// The fixed-point and float paths use offset = 0, pad = 0 everywhere.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pack_1d_i32(
    x: &[i32],
    s: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad_lo: usize,
    row0: usize,
    rows: usize,
    offset: i32,
    pad: i32,
    panel: &mut [i32],
) {
    let taps = k * c;
    for r in 0..rows {
        let base = ((row0 + r) * stride) as isize - pad_lo as isize;
        let row = &mut panel[r * taps..(r + 1) * taps];
        for ki in 0..k {
            let xi = base + ki as isize;
            let dst = &mut row[ki * c..(ki + 1) * c];
            if xi < 0 || xi >= s as isize {
                dst.fill(pad);
            } else {
                let off = (xi as usize) * c;
                let src = &x[off..off + c];
                if offset == 0 {
                    dst.copy_from_slice(src);
                } else {
                    for (d, &v) in dst.iter_mut().zip(src) {
                        *d = v - offset;
                    }
                }
            }
        }
    }
}

/// f32 twin of [`pack_1d_i32`] (no offset: float padding packs 0.0, which
/// is exact — weights are finite, so 0·w contributes nothing).
#[allow(clippy::too_many_arguments)]
pub(crate) fn pack_1d_f32(
    x: &[f32],
    s: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad_lo: usize,
    row0: usize,
    rows: usize,
    panel: &mut [f32],
) {
    let taps = k * c;
    for r in 0..rows {
        let base = ((row0 + r) * stride) as isize - pad_lo as isize;
        let row = &mut panel[r * taps..(r + 1) * taps];
        for ki in 0..k {
            let xi = base + ki as isize;
            let dst = &mut row[ki * c..(ki + 1) * c];
            if xi < 0 || xi >= s as isize {
                dst.fill(0.0);
            } else {
                let off = (xi as usize) * c;
                dst.copy_from_slice(&x[off..off + c]);
            }
        }
    }
}

/// 2-D im2col: output position `p` is (oh, ow) = (p / w_out, p % w_out);
/// tap order (ki, kj, ci) matches the (kh, kw, C, F) weight row order.
/// `offset`/`pad` semantics as in [`pack_1d_i32`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn pack_2d_i32(
    x: &[i32],
    h: usize,
    wdt: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    ph: usize,
    pw: usize,
    w_out: usize,
    row0: usize,
    rows: usize,
    offset: i32,
    pad: i32,
    panel: &mut [i32],
) {
    let taps = kh * kw * c;
    for r in 0..rows {
        let pos = row0 + r;
        let (oh, ow) = (pos / w_out, pos % w_out);
        let hbase = (oh * stride) as isize - ph as isize;
        let wbase = (ow * stride) as isize - pw as isize;
        let row = &mut panel[r * taps..(r + 1) * taps];
        for ki in 0..kh {
            let hi = hbase + ki as isize;
            for kj in 0..kw {
                let wi = wbase + kj as isize;
                let dst = &mut row[(ki * kw + kj) * c..(ki * kw + kj + 1) * c];
                if hi < 0 || hi >= h as isize || wi < 0 || wi >= wdt as isize {
                    dst.fill(pad);
                } else {
                    let off = ((hi as usize) * wdt + wi as usize) * c;
                    let src = &x[off..off + c];
                    if offset == 0 {
                        dst.copy_from_slice(src);
                    } else {
                        for (d, &v) in dst.iter_mut().zip(src) {
                            *d = v - offset;
                        }
                    }
                }
            }
        }
    }
}

/// f32 twin of [`pack_2d_i32`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn pack_2d_f32(
    x: &[f32],
    h: usize,
    wdt: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    ph: usize,
    pw: usize,
    w_out: usize,
    row0: usize,
    rows: usize,
    panel: &mut [f32],
) {
    let taps = kh * kw * c;
    for r in 0..rows {
        let pos = row0 + r;
        let (oh, ow) = (pos / w_out, pos % w_out);
        let hbase = (oh * stride) as isize - ph as isize;
        let wbase = (ow * stride) as isize - pw as isize;
        let row = &mut panel[r * taps..(r + 1) * taps];
        for ki in 0..kh {
            let hi = hbase + ki as isize;
            for kj in 0..kw {
                let wi = wbase + kj as isize;
                let dst = &mut row[(ki * kw + kj) * c..(ki * kw + kj + 1) * c];
                if hi < 0 || hi >= h as isize || wi < 0 || wi >= wdt as isize {
                    dst.fill(0.0);
                } else {
                    let off = ((hi as usize) * wdt + wi as usize) * c;
                    dst.copy_from_slice(&x[off..off + c]);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Shared geometry
// ---------------------------------------------------------------------------

pub(crate) fn conv1d_geometry(
    s: usize,
    k: usize,
    stride: usize,
    padding: Padding,
) -> (usize, usize) {
    match padding {
        Padding::Same => (Graph::same_padding(s, k, stride).0, s.div_ceil(stride)),
        Padding::Valid => (0, (s - k) / stride + 1),
    }
}

#[allow(clippy::type_complexity)]
pub(crate) fn conv2d_geometry(
    h: usize,
    wdt: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    padding: Padding,
) -> ((usize, usize), (usize, usize)) {
    let (ph, h_out) = match padding {
        Padding::Same => (Graph::same_padding(h, kh, stride).0, h.div_ceil(stride)),
        Padding::Valid => (0, (h - kh) / stride + 1),
    };
    let (pw, w_out) = match padding {
        Padding::Same => (Graph::same_padding(wdt, kw, stride).0, wdt.div_ceil(stride)),
        Padding::Valid => (0, (wdt - kw) / stride + 1),
    };
    ((ph, pw), (h_out, w_out))
}

// ---------------------------------------------------------------------------
// Parallel dispatch
// ---------------------------------------------------------------------------

/// Split a conv's output positions into per-thread column panels: chunk
/// `t` of the pool's static partition packs its row panels into scratch
/// slab `t` (each resized to `panel_elems`) and calls
/// `body(panel, row0, rows)` once per panel. `body` must write only the
/// output rows `row0..row0 + rows` — chunks own disjoint position
/// ranges, so the writes never alias. Panel grouping does not affect
/// per-element results (packing a row is independent of its neighbours
/// and the kernels accumulate k-major per element), so every thread
/// count produces the single-thread bits.
pub(crate) fn split_positions<T: Copy + Default + Send>(
    pool: &IntraOpPool,
    scratch: &mut [Vec<T>],
    panel_elems: usize,
    rows_cache: usize,
    positions: usize,
    body: &(dyn Fn(&mut [T], usize, usize) + Sync),
) {
    let t = pool.chunks_for(positions);
    assert!(
        scratch.len() >= t,
        "need one GEMM scratch slab per intra-op thread ({} < {t})",
        scratch.len()
    );
    // Grow-only slab sizing: the pack_* functions fully overwrite the
    // panel prefix they use (padding taps included), so stale contents
    // are never read and re-zeroing every call would just burn serial
    // time on the hot path. Capacity is preallocated by the arena, so
    // growth never reallocates in steady state.
    for s in scratch[..t].iter_mut() {
        if s.len() < panel_elems {
            s.resize(panel_elems, T::default());
        }
    }
    if t == 1 {
        // Serial fast path: no views, no dispatch — steady-state requests
        // stay completely allocation-free (the PR-1 contract the arena
        // tests pin).
        let panel = &mut scratch[0][..panel_elems];
        let mut row0 = 0usize;
        while row0 < positions {
            let rows = rows_cache.min(positions - row0);
            body(&mut panel[..], row0, rows);
            row0 += rows;
        }
        return;
    }
    let views: Vec<SharedOut<T>> =
        scratch[..t].iter_mut().map(|s| SharedOut::new(&mut s[..])).collect();
    pool.run_partitioned(positions, &|tid, s0, s1| {
        // SAFETY: slab `tid` belongs to exactly this chunk.
        let panel: &mut [T] = unsafe { views[tid].slice_mut(0, panel_elems) };
        let mut row0 = s0;
        while row0 < s1 {
            let rows = rows_cache.min(s1 - row0);
            body(&mut panel[..], row0, rows);
            row0 += rows;
        }
    });
}

/// Split a dense layer's output units across the pool in NR-aligned
/// column tiles (`body(j0, j1)` computes columns `j0..j1`), so the
/// parallel tiling is the serial tiling and each tile is written by
/// exactly one worker.
pub(crate) fn split_col_tiles(pool: &IntraOpPool, n: usize, body: &(dyn Fn(usize, usize) + Sync)) {
    let tiles = n.div_ceil(NR);
    pool.run_partitioned(tiles, &|_tid, t0, t1| {
        body(t0 * NR, (t1 * NR).min(n));
    });
}

// ---------------------------------------------------------------------------
// Float32 lowering
// ---------------------------------------------------------------------------

/// GEMM-lowered float conv1d. Falls back to the naive reference below
/// [`GEMM_MIN_MACCS`] (where packing cannot be amortized).
#[allow(clippy::too_many_arguments)]
pub fn conv1d_gemm(
    x: &[f32],
    s: usize,
    c: usize,
    w: &[f32],
    k: usize,
    f: usize,
    b: &[f32],
    stride: usize,
    padding: Padding,
    relu: bool,
    pool: &IntraOpPool,
    scratch: &mut [Vec<f32>],
    out: &mut Vec<f32>,
) -> usize {
    let (_, s_out) = conv1d_geometry(s, k, stride, padding);
    if s_out * f * k * c < GEMM_MIN_MACCS {
        return super::float_ops::conv1d_ref(x, s, c, w, k, f, b, stride, padding, relu, out);
    }
    conv1d_gemm_impl(x, s, c, w, k, f, b, stride, padding, relu, pool, scratch, out)
}

#[allow(clippy::too_many_arguments)]
fn conv1d_gemm_impl(
    x: &[f32],
    s: usize,
    c: usize,
    w: &[f32],
    k: usize,
    f: usize,
    b: &[f32],
    stride: usize,
    padding: Padding,
    relu: bool,
    pool: &IntraOpPool,
    scratch: &mut [Vec<f32>],
    out: &mut Vec<f32>,
) -> usize {
    let (pad_lo, s_out) = conv1d_geometry(s, k, stride, padding);
    let taps = k * c;
    out.clear();
    out.resize(s_out * f, 0.0);
    let rows_cache = panel_rows(taps, s_out);
    let out_view = SharedOut::new(&mut out[..]);
    let body = |panel: &mut [f32], row0: usize, rows: usize| {
        pack_1d_f32(x, s, c, k, stride, pad_lo, row0, rows, &mut panel[..rows * taps]);
        gemm_f32(&panel[..rows * taps], w, rows, f, taps, |r, fi, acc| {
            let v = acc + b[fi];
            // SAFETY: this chunk owns output rows row0..row0+rows.
            unsafe { out_view.write((row0 + r) * f + fi, if relu { v.max(0.0) } else { v }) };
        });
    };
    split_positions(pool, scratch, rows_cache * taps, rows_cache, s_out, &body);
    s_out
}

/// GEMM-lowered float conv2d.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_gemm(
    x: &[f32],
    h: usize,
    wdt: usize,
    c: usize,
    w: &[f32],
    kh: usize,
    kw: usize,
    f: usize,
    b: &[f32],
    stride: usize,
    padding: Padding,
    relu: bool,
    pool: &IntraOpPool,
    scratch: &mut [Vec<f32>],
    out: &mut Vec<f32>,
) -> (usize, usize) {
    let (_, (h_out, w_out)) = conv2d_geometry(h, wdt, kh, kw, stride, padding);
    if h_out * w_out * f * kh * kw * c < GEMM_MIN_MACCS {
        return super::float_ops::conv2d_ref(
            x, h, wdt, c, w, kh, kw, f, b, stride, padding, relu, out,
        );
    }
    conv2d_gemm_impl(x, h, wdt, c, w, kh, kw, f, b, stride, padding, relu, pool, scratch, out)
}

#[allow(clippy::too_many_arguments)]
fn conv2d_gemm_impl(
    x: &[f32],
    h: usize,
    wdt: usize,
    c: usize,
    w: &[f32],
    kh: usize,
    kw: usize,
    f: usize,
    b: &[f32],
    stride: usize,
    padding: Padding,
    relu: bool,
    pool: &IntraOpPool,
    scratch: &mut [Vec<f32>],
    out: &mut Vec<f32>,
) -> (usize, usize) {
    let ((ph, pw), (h_out, w_out)) = conv2d_geometry(h, wdt, kh, kw, stride, padding);
    let positions = h_out * w_out;
    let taps = kh * kw * c;
    out.clear();
    out.resize(positions * f, 0.0);
    let rows_cache = panel_rows(taps, positions);
    let out_view = SharedOut::new(&mut out[..]);
    let body = |panel: &mut [f32], row0: usize, rows: usize| {
        pack_2d_f32(
            x, h, wdt, c, kh, kw, stride, ph, pw, w_out, row0, rows,
            &mut panel[..rows * taps],
        );
        gemm_f32(&panel[..rows * taps], w, rows, f, taps, |r, fi, acc| {
            let v = acc + b[fi];
            // SAFETY: this chunk owns output rows row0..row0+rows.
            unsafe { out_view.write((row0 + r) * f + fi, if relu { v.max(0.0) } else { v }) };
        });
    };
    split_positions(pool, scratch, rows_cache * taps, rows_cache, positions, &body);
    (h_out, w_out)
}

/// GEMM-lowered float dense (m = 1 GEMM; no packing). The filter
/// dimension is split across the pool in NR-aligned column tiles.
pub fn dense_gemm(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    o: usize,
    relu: bool,
    pool: &IntraOpPool,
    out: &mut Vec<f32>,
) {
    let i = x.len();
    if i * o < GEMM_MIN_MACCS {
        super::float_ops::dense_ref(x, w, b, o, relu, out);
        return;
    }
    out.clear();
    out.resize(o, 0.0);
    let out_view = SharedOut::new(&mut out[..]);
    split_col_tiles(pool, o, &|j0, j1| {
        gemm_f32_cols(x, w, 1, o, i, j0, j1, |_r, oi, acc| {
            let v = acc + b[oi];
            // SAFETY: this chunk owns output columns j0..j1.
            unsafe { out_view.write(oi, if relu { v.max(0.0) } else { v }) };
        });
    });
}

// ---------------------------------------------------------------------------
// Fixed-point Qm.n lowering
// ---------------------------------------------------------------------------

/// GEMM-lowered fixed-point conv1d: bit-exact with
/// [`int_ops::conv1d_q_ref`], including the i32-lane admission decision.
#[allow(clippy::too_many_arguments)]
pub fn conv1d_q_gemm(
    x: &[i32],
    s: usize,
    c: usize,
    qw: &QNodeWeights,
    k: usize,
    f: usize,
    stride: usize,
    padding: Padding,
    relu: bool,
    width: u32,
    pool: &IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) -> usize {
    let (_, s_out) = conv1d_geometry(s, k, stride, padding);
    if s_out * f * k * c < GEMM_MIN_MACCS {
        return int_ops::conv1d_q_ref(x, s, c, qw, k, f, stride, padding, relu, width, out);
    }
    conv1d_q_gemm_impl(x, s, c, qw, k, f, stride, padding, relu, width, pool, scratch, out)
}

#[allow(clippy::too_many_arguments)]
fn conv1d_q_gemm_impl(
    x: &[i32],
    s: usize,
    c: usize,
    qw: &QNodeWeights,
    k: usize,
    f: usize,
    stride: usize,
    padding: Padding,
    relu: bool,
    width: u32,
    pool: &IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) -> usize {
    let (pad_lo, s_out) = conv1d_geometry(s, k, stride, padding);
    let taps = k * c;
    out.clear();
    out.resize(s_out * f, 0);
    let rows_cache = panel_rows(taps, s_out);
    let fits = accum_fits_i32(qw, taps, width);
    let uniform = qw.shift.len() == 1;
    let out_view = SharedOut::new(&mut out[..]);
    let body = |panel: &mut [i32], row0: usize, rows: usize| {
        pack_1d_i32(x, s, c, k, stride, pad_lo, row0, rows, 0, 0, &mut panel[..rows * taps]);
        let panel = &panel[..rows * taps];
        if fits {
            gemm_i32(panel, &qw.w, rows, f, taps, |r, fi, acc| {
                let total = acc + qw.b_acc[fi] as i32;
                let sh = if uniform { qw.shift[0] } else { qw.shift[fi] };
                let mut v = clamp_to(rescale(i64::from(total), sh), width);
                if relu && v < 0 {
                    v = 0;
                }
                // SAFETY: this chunk owns output rows row0..row0+rows.
                unsafe { out_view.write((row0 + r) * f + fi, v) };
            });
        } else {
            gemm_i64(panel, &qw.w, rows, f, taps, |r, fi, acc| {
                let total = acc + qw.b_acc[fi];
                let sh = if uniform { qw.shift[0] } else { qw.shift[fi] };
                let mut v = clamp_to(rescale(total, sh), width);
                if relu && v < 0 {
                    v = 0;
                }
                // SAFETY: as above.
                unsafe { out_view.write((row0 + r) * f + fi, v) };
            });
        }
    };
    split_positions(pool, scratch, rows_cache * taps, rows_cache, s_out, &body);
    s_out
}

/// GEMM-lowered fixed-point conv2d (bit-exact with
/// [`int_ops::conv2d_q_ref`]).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_q_gemm(
    x: &[i32],
    h: usize,
    wdt: usize,
    c: usize,
    qw: &QNodeWeights,
    kh: usize,
    kw: usize,
    f: usize,
    stride: usize,
    padding: Padding,
    relu: bool,
    width: u32,
    pool: &IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) -> (usize, usize) {
    let (_, (h_out, w_out)) = conv2d_geometry(h, wdt, kh, kw, stride, padding);
    if h_out * w_out * f * kh * kw * c < GEMM_MIN_MACCS {
        return int_ops::conv2d_q_ref(
            x, h, wdt, c, qw, kh, kw, f, stride, padding, relu, width, out,
        );
    }
    conv2d_q_gemm_impl(
        x, h, wdt, c, qw, kh, kw, f, stride, padding, relu, width, pool, scratch, out,
    )
}

#[allow(clippy::too_many_arguments)]
fn conv2d_q_gemm_impl(
    x: &[i32],
    h: usize,
    wdt: usize,
    c: usize,
    qw: &QNodeWeights,
    kh: usize,
    kw: usize,
    f: usize,
    stride: usize,
    padding: Padding,
    relu: bool,
    width: u32,
    pool: &IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) -> (usize, usize) {
    let ((ph, pw), (h_out, w_out)) = conv2d_geometry(h, wdt, kh, kw, stride, padding);
    let positions = h_out * w_out;
    let taps = kh * kw * c;
    out.clear();
    out.resize(positions * f, 0);
    let rows_cache = panel_rows(taps, positions);
    let fits = accum_fits_i32(qw, taps, width);
    let uniform = qw.shift.len() == 1;
    let out_view = SharedOut::new(&mut out[..]);
    let body = |panel: &mut [i32], row0: usize, rows: usize| {
        pack_2d_i32(
            x, h, wdt, c, kh, kw, stride, ph, pw, w_out, row0, rows, 0, 0,
            &mut panel[..rows * taps],
        );
        let panel = &panel[..rows * taps];
        if fits {
            gemm_i32(panel, &qw.w, rows, f, taps, |r, fi, acc| {
                let total = acc + qw.b_acc[fi] as i32;
                let sh = if uniform { qw.shift[0] } else { qw.shift[fi] };
                let mut v = clamp_to(rescale(i64::from(total), sh), width);
                if relu && v < 0 {
                    v = 0;
                }
                // SAFETY: this chunk owns output rows row0..row0+rows.
                unsafe { out_view.write((row0 + r) * f + fi, v) };
            });
        } else {
            gemm_i64(panel, &qw.w, rows, f, taps, |r, fi, acc| {
                let total = acc + qw.b_acc[fi];
                let sh = if uniform { qw.shift[0] } else { qw.shift[fi] };
                let mut v = clamp_to(rescale(total, sh), width);
                if relu && v < 0 {
                    v = 0;
                }
                // SAFETY: as above.
                unsafe { out_view.write((row0 + r) * f + fi, v) };
            });
        }
    };
    split_positions(pool, scratch, rows_cache * taps, rows_cache, positions, &body);
    (h_out, w_out)
}

/// GEMM-lowered fixed-point dense (bit-exact with
/// [`int_ops::dense_q_ref`]; picks i32 lanes under the same admission
/// guard, which is semantics-neutral for exact integer sums). The filter
/// dimension is split across the pool in NR-aligned column tiles.
pub fn dense_q_gemm(
    x: &[i32],
    qw: &QNodeWeights,
    o: usize,
    relu: bool,
    width: u32,
    pool: &IntraOpPool,
    out: &mut Vec<i32>,
) {
    let i = x.len();
    if i * o < GEMM_MIN_MACCS {
        int_ops::dense_q_ref(x, qw, o, relu, width, out);
        return;
    }
    dense_q_gemm_impl(x, qw, o, relu, width, pool, out);
}

fn dense_q_gemm_impl(
    x: &[i32],
    qw: &QNodeWeights,
    o: usize,
    relu: bool,
    width: u32,
    pool: &IntraOpPool,
    out: &mut Vec<i32>,
) {
    let i = x.len();
    out.clear();
    out.resize(o, 0);
    let fits = accum_fits_i32(qw, i, width);
    let uniform = qw.shift.len() == 1;
    let out_view = SharedOut::new(&mut out[..]);
    split_col_tiles(pool, o, &|j0, j1| {
        if fits {
            gemm_i32_cols(x, &qw.w, 1, o, i, j0, j1, |_r, oi, acc| {
                let total = acc + qw.b_acc[oi] as i32;
                let sh = if uniform { qw.shift[0] } else { qw.shift[oi] };
                let mut v = clamp_to(rescale(i64::from(total), sh), width);
                if relu && v < 0 {
                    v = 0;
                }
                // SAFETY: this chunk owns output columns j0..j1.
                unsafe { out_view.write(oi, v) };
            });
        } else {
            gemm_i64_cols(x, &qw.w, 1, o, i, j0, j1, |_r, oi, acc| {
                let total = acc + qw.b_acc[oi];
                let sh = if uniform { qw.shift[0] } else { qw.shift[oi] };
                let mut v = clamp_to(rescale(total, sh), width);
                if relu && v < 0 {
                    v = 0;
                }
                // SAFETY: as above.
                unsafe { out_view.write(oi, v) };
            });
        }
    });
}

// ---------------------------------------------------------------------------
// Affine (TFLite-semantics) lowering
// ---------------------------------------------------------------------------

/// GEMM-lowered affine conv (1-D or 2-D): the zero-point-shifted operands
/// ride [`gemm_i64`]; bit-exact with `affine_exec::conv_affine_ref`
/// (exact i64 sums, identical epilogue cast into gemmlowp requantize).
#[allow(clippy::too_many_arguments)]
pub fn conv_affine_gemm(
    x: &[i32],
    ish: &[usize],
    wshape: &[usize],
    qw: &AffineNodeWeights,
    zp_in: i32,
    zp_out: i32,
    stride: usize,
    padding: Padding,
    relu: bool,
    dims: usize,
    pool: &IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) {
    let taps: usize = wshape[..wshape.len() - 1].iter().product();
    let f = *wshape.last().unwrap();
    let positions = if dims == 1 {
        conv1d_geometry(ish[0], wshape[0], stride, padding).1
    } else {
        let (_, (h_out, w_out)) =
            conv2d_geometry(ish[0], ish[1], wshape[0], wshape[1], stride, padding);
        h_out * w_out
    };
    if positions * f * taps < GEMM_MIN_MACCS {
        super::affine_exec::conv_affine_ref(
            x, ish, wshape, qw, zp_in, zp_out, stride, padding, relu, dims, out,
        );
        return;
    }
    conv_affine_gemm_impl(
        x, ish, wshape, qw, zp_in, zp_out, stride, padding, relu, dims, pool, scratch, out,
    );
}

#[allow(clippy::too_many_arguments)]
fn conv_affine_gemm_impl(
    x: &[i32],
    ish: &[usize],
    wshape: &[usize],
    qw: &AffineNodeWeights,
    zp_in: i32,
    zp_out: i32,
    stride: usize,
    padding: Padding,
    relu: bool,
    dims: usize,
    pool: &IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) {
    if dims == 1 {
        let (s, c) = (ish[0], ish[1]);
        let (k, f) = (wshape[0], wshape[2]);
        let (pad_lo, s_out) = conv1d_geometry(s, k, stride, padding);
        let taps = k * c;
        out.clear();
        out.resize(s_out * f, 0);
        let rows_cache = panel_rows(taps, s_out);
        let out_view = SharedOut::new(&mut out[..]);
        let body = |panel: &mut [i32], row0: usize, rows: usize| {
            // Zero-point pre-subtracted panel, packed by the owning worker.
            pack_1d_i32(
                x, s, c, k, stride, pad_lo, row0, rows, zp_in, 0, &mut panel[..rows * taps],
            );
            gemm_i64(&panel[..rows * taps], &qw.w, rows, f, taps, |r, fi, acc| {
                let total = qw.b[fi] + acc;
                let mut v = requantize(total as i32, qw.mult[fi], qw.shift[fi], zp_out);
                if relu {
                    v = v.max(zp_out);
                }
                // SAFETY: this chunk owns output rows row0..row0+rows.
                unsafe { out_view.write((row0 + r) * f + fi, v) };
            });
        };
        split_positions(pool, scratch, rows_cache * taps, rows_cache, s_out, &body);
    } else {
        let (h, wdt, c) = (ish[0], ish[1], ish[2]);
        let (kh, kw, f) = (wshape[0], wshape[1], wshape[3]);
        let ((ph, pw), (h_out, w_out)) = conv2d_geometry(h, wdt, kh, kw, stride, padding);
        let positions = h_out * w_out;
        let taps = kh * kw * c;
        out.clear();
        out.resize(positions * f, 0);
        let rows_cache = panel_rows(taps, positions);
        let out_view = SharedOut::new(&mut out[..]);
        let body = |panel: &mut [i32], row0: usize, rows: usize| {
            pack_2d_i32(
                x, h, wdt, c, kh, kw, stride, ph, pw, w_out, row0, rows, zp_in, 0,
                &mut panel[..rows * taps],
            );
            gemm_i64(&panel[..rows * taps], &qw.w, rows, f, taps, |r, fi, acc| {
                let total = qw.b[fi] + acc;
                let mut v = requantize(total as i32, qw.mult[fi], qw.shift[fi], zp_out);
                if relu {
                    v = v.max(zp_out);
                }
                // SAFETY: this chunk owns output rows row0..row0+rows.
                unsafe { out_view.write((row0 + r) * f + fi, v) };
            });
        };
        split_positions(pool, scratch, rows_cache * taps, rows_cache, positions, &body);
    }
}

/// GEMM-lowered affine dense: stages the zero-point-shifted input in
/// scratch slab 0 (read-shared by every worker), then runs the m = 1
/// i64 GEMM with the filter dimension split across the pool. Bit-exact
/// with `affine_exec::dense_affine_ref`.
#[allow(clippy::too_many_arguments)]
pub fn dense_affine_gemm(
    x: &[i32],
    qw: &AffineNodeWeights,
    zp_in: i32,
    zp_out: i32,
    o: usize,
    relu: bool,
    pool: &IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) {
    let i = x.len();
    if i * o < GEMM_MIN_MACCS {
        super::affine_exec::dense_affine_ref(x, qw, zp_in, zp_out, o, relu, out);
        return;
    }
    dense_affine_gemm_impl(x, qw, zp_in, zp_out, o, relu, pool, scratch, out);
}

#[allow(clippy::too_many_arguments)]
fn dense_affine_gemm_impl(
    x: &[i32],
    qw: &AffineNodeWeights,
    zp_in: i32,
    zp_out: i32,
    o: usize,
    relu: bool,
    pool: &IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) {
    let i = x.len();
    let slab = scratch.first_mut().expect("need at least one GEMM scratch slab");
    slab.clear();
    slab.resize(i, 0);
    for (d, &v) in slab.iter_mut().zip(x) {
        *d = v - zp_in;
    }
    out.clear();
    out.resize(o, 0);
    let shifted: &[i32] = slab;
    let out_view = SharedOut::new(&mut out[..]);
    split_col_tiles(pool, o, &|j0, j1| {
        gemm_i64_cols(shifted, &qw.w, 1, o, i, j0, j1, |_r, oi, acc| {
            let total = qw.b[oi] + acc;
            let mut v = requantize(total as i32, qw.mult[oi], qw.shift[oi], zp_out);
            if relu {
                v = v.max(zp_out);
            }
            // SAFETY: this chunk owns output columns j0..j1.
            unsafe { out_view.write(oi, v) };
        });
    });
}

/// Shared random-weight generators for the GEMM/packed bit-exactness
/// property tests — ONE copy of the `accum_fits_i32` admission-boundary
/// straddle logic, so the boundary the tests pin cannot silently diverge
/// between the per-call and prepacked suites.
#[cfg(test)]
pub(crate) mod testgen {
    use crate::quant::affine::{quantize_multiplier, AffineNodeWeights};
    use crate::quant::ptq::QNodeWeights;
    use crate::util::check::Gen;

    /// Random fixed-point node weights; with `straddle`, biases land
    /// right at (or just past) the i32-lane admission boundary so the
    /// lane dispatch must flip exactly with the reference kernel's.
    pub(crate) fn random_qw(
        g: &mut Gen,
        taps: usize,
        f: usize,
        width: u32,
        straddle: bool,
    ) -> QNodeWeights {
        let lim = (1i32 << (width - 1)) - 1;
        let w: Vec<i32> = (0..taps * f).map(|_| g.i32_in(-lim - 1, lim)).collect();
        let per_filter = g.bool();
        let shift: Vec<i32> = if per_filter {
            (0..f).map(|_| g.i32_in(0, 14)).collect()
        } else {
            vec![g.i32_in(0, 14)]
        };
        let max_prod = (1i64 << (width - 1)) * (1i64 << (width - 1));
        let boundary = i32::MAX as i64 / 2 - taps as i64 * max_prod;
        let b_acc: Vec<i64> = (0..f)
            .map(|_| {
                let sign = if g.bool() { 1i64 } else { -1 };
                if straddle && g.bool() {
                    let delta = g.i32_in(-1024, 1024) as i64;
                    sign * (boundary + delta).max(0)
                } else {
                    sign * g.i32_in(0, 1 << 20) as i64
                }
            })
            .collect();
        QNodeWeights { w, w_n: vec![0], b_acc, shift }
    }

    /// Random affine node weights with realistic requantization params.
    pub(crate) fn random_affine_weights(g: &mut Gen, taps: usize, f: usize) -> AffineNodeWeights {
        let w: Vec<i32> = (0..taps * f).map(|_| g.i32_in(-127, 127)).collect();
        let mut mult = Vec::with_capacity(f);
        let mut shift = Vec::with_capacity(f);
        let mut b = Vec::with_capacity(f);
        let mut w_scale = Vec::with_capacity(f);
        for _ in 0..f {
            let m = g.f32_in(1e-4, 0.9) as f64;
            let (m0, sh) = quantize_multiplier(m);
            mult.push(m0);
            shift.push(sh);
            b.push(g.i32_in(-(1 << 16), 1 << 16) as i64);
            w_scale.push(1.0);
        }
        AffineNodeWeights { w, w_scale, b, mult, shift }
    }
}

#[cfg(test)]
mod tests {
    use super::testgen::{random_affine_weights, random_qw};
    use super::*;
    use crate::nn::{affine_exec, float_ops};
    use crate::prop_assert;
    use crate::util::check::property;

    // --- microkernels vs naive triple loop ---

    fn naive_i64(a: &[i32], b: &[i32], m: usize, n: usize, k: usize) -> Vec<i64> {
        let mut c = vec![0i64; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p] as i64;
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j] as i64;
                }
            }
        }
        c
    }

    #[test]
    fn int_microkernels_match_naive_matmul() {
        property(60, |g| {
            let m = g.usize_in(1, 13);
            let n = g.usize_in(1, 19);
            let k = g.usize_in(1, 17);
            let a: Vec<i32> = (0..m * k).map(|_| g.i32_in(-128, 127)).collect();
            let b: Vec<i32> = (0..k * n).map(|_| g.i32_in(-128, 127)).collect();
            let want = naive_i64(&a, &b, m, n, k);
            let mut got64 = vec![0i64; m * n];
            gemm_i64(&a, &b, m, n, k, |i, j, acc| got64[i * n + j] = acc);
            prop_assert!(got64 == want, "i64 kernel diverged at m={m} n={n} k={k}");
            // i32 lanes: same values (operands small enough not to overflow).
            let mut got32 = vec![0i64; m * n];
            gemm_i32(&a, &b, m, n, k, |i, j, acc| got32[i * n + j] = i64::from(acc));
            prop_assert!(got32 == want, "i32 kernel diverged at m={m} n={n} k={k}");
            Ok(())
        });
    }

    #[test]
    fn f32_microkernel_close_to_f64_oracle() {
        property(40, |g| {
            let m = g.usize_in(1, 9);
            let n = g.usize_in(1, 17);
            let k = g.usize_in(1, 33);
            let a: Vec<f32> = g.vec_normal(m * k, 1.0);
            let b: Vec<f32> = g.vec_normal(k * n, 1.0);
            let mut got = vec![0.0f32; m * n];
            gemm_f32(&a, &b, m, n, k, |i, j, acc| got[i * n + j] = acc);
            for i in 0..m {
                for j in 0..n {
                    let mut exact = 0.0f64;
                    let mut abs = 0.0f64;
                    for p in 0..k {
                        let t = a[i * k + p] as f64 * b[p * n + j] as f64;
                        exact += t;
                        abs += t.abs();
                    }
                    let tol = (k as f64 + 2.0) * f32::EPSILON as f64 * abs.max(1e-6);
                    prop_assert!(
                        (got[i * n + j] as f64 - exact).abs() <= tol,
                        "f32 kernel off at ({i},{j}): got {} exact {exact} tol {tol}",
                        got[i * n + j]
                    );
                }
            }
            Ok(())
        });
    }

    // --- packing ---

    #[test]
    fn pack_1d_zero_pads_and_orders_taps() {
        // x = (3, 2) rows [1,2],[3,4],[5,6]; k=3 SAME stride 1 pad_lo=1.
        let x = [1, 2, 3, 4, 5, 6];
        let mut panel = vec![99; 3 * 6];
        pack_1d_i32(&x, 3, 2, 3, 1, 1, 0, 3, 0, 0, &mut panel);
        // row for o=0: taps x[-1] (pad), x[0], x[1]
        assert_eq!(&panel[0..6], &[0, 0, 1, 2, 3, 4]);
        // row for o=1: x[0], x[1], x[2]
        assert_eq!(&panel[6..12], &[1, 2, 3, 4, 5, 6]);
        // row for o=2: x[1], x[2], pad
        assert_eq!(&panel[12..18], &[3, 4, 5, 6, 0, 0]);
    }

    #[test]
    fn pack_1d_offset_subtracts_zero_point_only_in_range() {
        let x = [10, 20, 30];
        let mut panel = vec![0; 3];
        // k=3 pad_lo=1, c=1, one row at o=0: [pad, x0-5, x1-5]
        pack_1d_i32(&x, 3, 1, 3, 1, 1, 0, 1, 5, 0, &mut panel);
        assert_eq!(panel, vec![0, 5, 15]);
    }

    #[test]
    fn pack_1d_pad_payload_fills_out_of_range_taps() {
        // The prepacked affine path packs raw payloads with pad = zp_in
        // (the folded bias cancels the zp·w contribution of padded taps).
        let x = [10, 20, 30];
        let mut panel = vec![0; 3];
        pack_1d_i32(&x, 3, 1, 3, 1, 1, 0, 1, 0, 7, &mut panel);
        assert_eq!(panel, vec![7, 10, 20]);
    }

    // --- fixed-point conv/dense: bit-exact vs reference ---
    // (random_qw / random_affine_weights live in super::testgen, shared
    // with the prepacked suite in nn::packed.)

    #[test]
    fn conv1d_q_gemm_bit_exact_vs_ref_across_admission_boundary() {
        property(120, |g| {
            let width = *g.pick(&[8u32, 16]);
            let k = g.usize_in(1, 5);
            let c = g.usize_in(1, 6);
            let f = g.usize_in(1, 12);
            let s = g.usize_in(k, 48);
            let stride = g.usize_in(1, 2);
            let relu = g.bool();
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let qw = random_qw(g, k * c, f, width, width == 8);
            let x: Vec<i32> = {
                let lim = (1i32 << (width - 1)) - 1;
                (0..s * c).map(|_| g.i32_in(-lim - 1, lim)).collect()
            };
            let mut want = Vec::new();
            let so_ref =
                int_ops::conv1d_q_ref(&x, s, c, &qw, k, f, stride, padding, relu, width, &mut want);
            let mut got = Vec::new();
            let pool = IntraOpPool::serial();
            let mut scratch = vec![Vec::new()];
            let so_gemm = conv1d_q_gemm_impl(
                &x, s, c, &qw, k, f, stride, padding, relu, width, &pool, &mut scratch, &mut got,
            );
            prop_assert!(
                so_ref == so_gemm && want == got,
                "conv1d_q gemm diverged: width={width} k={k} c={c} f={f} s={s} stride={stride} \
                 relu={relu} want={want:?} got={got:?}"
            );
            // The public hybrid entry must agree too (either branch).
            let mut hybrid = Vec::new();
            conv1d_q_gemm(
                &x, s, c, &qw, k, f, stride, padding, relu, width, &pool, &mut scratch,
                &mut hybrid,
            );
            prop_assert!(hybrid == want, "hybrid conv1d_q_gemm diverged");
            Ok(())
        });
    }

    #[test]
    fn conv2d_q_gemm_bit_exact_vs_ref() {
        property(60, |g| {
            let width = *g.pick(&[8u32, 16]);
            let kh = g.usize_in(1, 3);
            let kw = g.usize_in(1, 3);
            let c = g.usize_in(1, 4);
            let f = g.usize_in(1, 9);
            let h = g.usize_in(kh, 12);
            let wdt = g.usize_in(kw, 12);
            let stride = g.usize_in(1, 2);
            let relu = g.bool();
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let qw = random_qw(g, kh * kw * c, f, width, width == 8);
            let x: Vec<i32> = {
                let lim = (1i32 << (width - 1)) - 1;
                (0..h * wdt * c).map(|_| g.i32_in(-lim - 1, lim)).collect()
            };
            let mut want = Vec::new();
            let sh_ref = int_ops::conv2d_q_ref(
                &x, h, wdt, c, &qw, kh, kw, f, stride, padding, relu, width, &mut want,
            );
            let mut got = Vec::new();
            let pool = IntraOpPool::serial();
            let mut scratch = vec![Vec::new()];
            let sh_gemm = conv2d_q_gemm_impl(
                &x, h, wdt, c, &qw, kh, kw, f, stride, padding, relu, width, &pool,
                &mut scratch, &mut got,
            );
            prop_assert!(
                sh_ref == sh_gemm && want == got,
                "conv2d_q gemm diverged: width={width} kh={kh} kw={kw} c={c} f={f} h={h} w={wdt}"
            );
            Ok(())
        });
    }

    #[test]
    fn dense_q_gemm_bit_exact_vs_ref() {
        property(100, |g| {
            let width = *g.pick(&[8u32, 16]);
            let i = g.usize_in(1, 96);
            let o = g.usize_in(1, 24);
            let qw = random_qw(g, i, o, width, width == 8);
            let lim = (1i32 << (width - 1)) - 1;
            let x: Vec<i32> = (0..i).map(|_| g.i32_in(-lim - 1, lim)).collect();
            let mut want = Vec::new();
            int_ops::dense_q_ref(&x, &qw, o, false, width, &mut want);
            let mut got = Vec::new();
            let pool = IntraOpPool::serial();
            dense_q_gemm_impl(&x, &qw, o, false, width, &pool, &mut got);
            prop_assert!(want == got, "dense_q gemm diverged at i={i} o={o} width={width}");
            Ok(())
        });
    }

    // --- f32 conv: ULP-bounded vs reference ---

    #[test]
    fn f32_conv_gemm_is_ulp_close_to_ref() {
        property(40, |g| {
            let k = g.usize_in(1, 5);
            let c = g.usize_in(1, 6);
            let f = g.usize_in(1, 10);
            let s = g.usize_in(k, 40);
            let stride = g.usize_in(1, 2);
            let relu = g.bool();
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let w: Vec<f32> = g.vec_normal(k * c * f, 0.5);
            let b: Vec<f32> = g.vec_normal(f, 0.1);
            let x: Vec<f32> = g.vec_normal(s * c, 1.0);
            let mut want = Vec::new();
            let so =
                float_ops::conv1d_ref(&x, s, c, &w, k, f, &b, stride, padding, relu, &mut want);
            let mut got = Vec::new();
            let pool = IntraOpPool::serial();
            let mut scratch = vec![Vec::new()];
            let so2 = conv1d_gemm_impl(
                &x, s, c, &w, k, f, &b, stride, padding, relu, &pool, &mut scratch, &mut got,
            );
            prop_assert!(so == so2, "s_out mismatch");
            let taps = k * c;
            let (pad_lo, _) = conv1d_geometry(s, k, stride, padding);
            for (o, chunk) in got.chunks(f).enumerate() {
                let base = (o * stride) as isize - pad_lo as isize;
                for (fi, (&gv, &rv)) in chunk.iter().zip(&want[o * f..(o + 1) * f]).enumerate() {
                    // Magnitude of the summands bounds the reordering error.
                    let mut abs = b[fi].abs() as f64;
                    for ki in 0..k {
                        let xi = base + ki as isize;
                        if xi < 0 || xi >= s as isize {
                            continue;
                        }
                        for ci in 0..c {
                            abs += (x[(xi as usize) * c + ci] * w[(ki * c + ci) * f + fi]).abs()
                                as f64;
                        }
                    }
                    let tol = 4.0 * (taps as f64 + 2.0) * f32::EPSILON as f64 * abs.max(1e-6);
                    prop_assert!(
                        (gv as f64 - rv as f64).abs() <= tol,
                        "f32 conv gemm off at (o={o}, f={fi}): gemm {gv} ref {rv} tol {tol}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn f32_conv2d_gemm_close_to_ref() {
        property(30, |g| {
            let kh = g.usize_in(1, 3);
            let kw = g.usize_in(1, 3);
            let c = g.usize_in(1, 4);
            let f = g.usize_in(1, 8);
            let h = g.usize_in(kh, 10);
            let wdt = g.usize_in(kw, 10);
            let stride = g.usize_in(1, 2);
            let relu = g.bool();
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let w: Vec<f32> = g.vec_normal(kh * kw * c * f, 0.5);
            let b: Vec<f32> = g.vec_normal(f, 0.1);
            let x: Vec<f32> = g.vec_normal(h * wdt * c, 1.0);
            let mut want = Vec::new();
            let dims_ref = float_ops::conv2d_ref(
                &x, h, wdt, c, &w, kh, kw, f, &b, stride, padding, relu, &mut want,
            );
            let mut got = Vec::new();
            let pool = IntraOpPool::serial();
            let mut scratch = vec![Vec::new()];
            let dims_gemm = conv2d_gemm_impl(
                &x, h, wdt, c, &w, kh, kw, f, &b, stride, padding, relu, &pool, &mut scratch,
                &mut got,
            );
            prop_assert!(dims_ref == dims_gemm, "out dims mismatch");
            let taps = (kh * kw * c) as f64;
            for (i, (&gv, &rv)) in got.iter().zip(&want).enumerate() {
                // Coarse reorder bound: inputs/weights are O(1) normals.
                let tol = 8.0 * (taps + 2.0) * f32::EPSILON as f64 * (taps + 1.0);
                prop_assert!(
                    (gv as f64 - rv as f64).abs() <= tol,
                    "f32 conv2d gemm off at {i}: gemm {gv} ref {rv} tol {tol}"
                );
            }
            Ok(())
        });
    }

    // --- affine: bit-exact vs reference ---

    #[test]
    fn affine_conv_gemm_bit_exact_vs_ref() {
        property(60, |g| {
            let dims = g.usize_in(1, 2);
            let relu = g.bool();
            let stride = g.usize_in(1, 2);
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let zp_in = g.i32_in(-128, 127);
            let zp_out = g.i32_in(-128, 127);
            let (ish, wshape): (Vec<usize>, Vec<usize>) = if dims == 1 {
                let (k, c, f) = (g.usize_in(1, 5), g.usize_in(1, 4), g.usize_in(1, 8));
                let s = g.usize_in(k, 24);
                (vec![s, c], vec![k, c, f])
            } else {
                let (kh, kw, c, f) =
                    (g.usize_in(1, 3), g.usize_in(1, 3), g.usize_in(1, 3), g.usize_in(1, 6));
                let h = g.usize_in(kh, 10);
                let wd = g.usize_in(kw, 10);
                (vec![h, wd, c], vec![kh, kw, c, f])
            };
            let taps: usize = wshape[..wshape.len() - 1].iter().product();
            let f = *wshape.last().unwrap();
            let qw = random_affine_weights(g, taps, f);
            let n_in: usize = ish.iter().product();
            let x: Vec<i32> = (0..n_in).map(|_| g.i32_in(-128, 127)).collect();
            let mut want = Vec::new();
            affine_exec::conv_affine_ref(
                &x, &ish, &wshape, &qw, zp_in, zp_out, stride, padding, relu, dims, &mut want,
            );
            // The _impl call forces the blocked path even for shapes the
            // hybrid entry would route to the reference.
            let mut got = Vec::new();
            let pool = IntraOpPool::serial();
            let mut scratch = vec![Vec::new()];
            conv_affine_gemm_impl(
                &x, &ish, &wshape, &qw, zp_in, zp_out, stride, padding, relu, dims, &pool,
                &mut scratch, &mut got,
            );
            prop_assert!(want == got, "affine conv gemm diverged (dims={dims})");
            // And the public hybrid entry agrees on either branch.
            let mut hybrid = Vec::new();
            conv_affine_gemm(
                &x, &ish, &wshape, &qw, zp_in, zp_out, stride, padding, relu, dims, &pool,
                &mut scratch, &mut hybrid,
            );
            prop_assert!(want == hybrid, "affine conv hybrid diverged (dims={dims})");
            Ok(())
        });
    }

    #[test]
    fn affine_dense_gemm_bit_exact_vs_ref() {
        property(80, |g| {
            let i = g.usize_in(1, 160);
            let o = g.usize_in(1, 24);
            let zp_in = g.i32_in(-128, 127);
            let zp_out = g.i32_in(-128, 127);
            let relu = g.bool();
            let qw = random_affine_weights(g, i, o);
            let x: Vec<i32> = (0..i).map(|_| g.i32_in(-128, 127)).collect();
            let mut want = Vec::new();
            affine_exec::dense_affine_ref(&x, &qw, zp_in, zp_out, o, relu, &mut want);
            let mut got = Vec::new();
            let pool = IntraOpPool::serial();
            let mut scratch = vec![Vec::new()];
            dense_affine_gemm_impl(&x, &qw, zp_in, zp_out, o, relu, &pool, &mut scratch, &mut got);
            prop_assert!(want == got, "affine dense gemm diverged at i={i} o={o}");
            Ok(())
        });
    }

    // --- intra-op parallelism: bit-exact vs single thread ---

    fn slabs(n: usize) -> Vec<Vec<i32>> {
        vec![Vec::new(); n]
    }

    #[test]
    fn parallel_conv_q_gemm_bit_identical_across_thread_counts() {
        // i32-lane and i64-wide flavors, with biases straddling the
        // accum_fits_i32 admission boundary, at threads ∈ {2, 4}: the
        // N-dimension panel split must reproduce the single-thread bits.
        let pools = [IntraOpPool::new(2), IntraOpPool::new(4)];
        property(60, |g| {
            let width = *g.pick(&[8u32, 16]);
            let k = g.usize_in(1, 5);
            let c = g.usize_in(1, 6);
            let f = g.usize_in(1, 12);
            let s = g.usize_in(k, 64);
            let stride = g.usize_in(1, 2);
            let relu = g.bool();
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let qw = random_qw(g, k * c, f, width, width == 8);
            let x: Vec<i32> = {
                let lim = (1i32 << (width - 1)) - 1;
                (0..s * c).map(|_| g.i32_in(-lim - 1, lim)).collect()
            };
            let serial = IntraOpPool::serial();
            let mut scratch1 = slabs(1);
            let mut want = Vec::new();
            conv1d_q_gemm_impl(
                &x, s, c, &qw, k, f, stride, padding, relu, width, &serial, &mut scratch1,
                &mut want,
            );
            for pool in &pools {
                let mut scratch = slabs(pool.threads());
                let mut got = Vec::new();
                conv1d_q_gemm_impl(
                    &x, s, c, &qw, k, f, stride, padding, relu, width, pool, &mut scratch,
                    &mut got,
                );
                prop_assert!(
                    want == got,
                    "conv1d_q diverged at threads={}: width={width} k={k} c={c} f={f} s={s}",
                    pool.threads()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_conv2d_q_gemm_bit_identical_across_thread_counts() {
        let pools = [IntraOpPool::new(2), IntraOpPool::new(4)];
        property(40, |g| {
            let width = *g.pick(&[8u32, 16]);
            let kh = g.usize_in(1, 3);
            let kw = g.usize_in(1, 3);
            let c = g.usize_in(1, 4);
            let f = g.usize_in(1, 9);
            let h = g.usize_in(kh, 14);
            let wdt = g.usize_in(kw, 14);
            let stride = g.usize_in(1, 2);
            let relu = g.bool();
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let qw = random_qw(g, kh * kw * c, f, width, width == 8);
            let x: Vec<i32> = {
                let lim = (1i32 << (width - 1)) - 1;
                (0..h * wdt * c).map(|_| g.i32_in(-lim - 1, lim)).collect()
            };
            let serial = IntraOpPool::serial();
            let mut scratch1 = slabs(1);
            let mut want = Vec::new();
            conv2d_q_gemm_impl(
                &x, h, wdt, c, &qw, kh, kw, f, stride, padding, relu, width, &serial,
                &mut scratch1, &mut want,
            );
            for pool in &pools {
                let mut scratch = slabs(pool.threads());
                let mut got = Vec::new();
                conv2d_q_gemm_impl(
                    &x, h, wdt, c, &qw, kh, kw, f, stride, padding, relu, width, pool,
                    &mut scratch, &mut got,
                );
                prop_assert!(
                    want == got,
                    "conv2d_q diverged at threads={}: width={width} kh={kh} kw={kw} c={c} f={f}",
                    pool.threads()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_dense_and_affine_bit_identical_across_thread_counts() {
        let pools = [IntraOpPool::new(2), IntraOpPool::new(4)];
        property(40, |g| {
            // Fixed-point dense (both accumulator flavors via straddle).
            let width = *g.pick(&[8u32, 16]);
            let i = g.usize_in(1, 96);
            let o = g.usize_in(1, 40);
            let qw = random_qw(g, i, o, width, width == 8);
            let lim = (1i32 << (width - 1)) - 1;
            let x: Vec<i32> = (0..i).map(|_| g.i32_in(-lim - 1, lim)).collect();
            let serial = IntraOpPool::serial();
            let mut want = Vec::new();
            dense_q_gemm_impl(&x, &qw, o, false, width, &serial, &mut want);
            for pool in &pools {
                let mut got = Vec::new();
                dense_q_gemm_impl(&x, &qw, o, false, width, pool, &mut got);
                prop_assert!(want == got, "dense_q diverged at threads={}", pool.threads());
            }

            // Affine conv (zero-point pre-subtracted panels) + dense.
            let zp_in = g.i32_in(-128, 127);
            let zp_out = g.i32_in(-128, 127);
            let relu = g.bool();
            let (k, c, f) = (g.usize_in(1, 5), g.usize_in(1, 4), g.usize_in(1, 8));
            let s = g.usize_in(k, 32);
            let (ish, wshape) = (vec![s, c], vec![k, c, f]);
            let aqw = random_affine_weights(g, k * c, f);
            let ax: Vec<i32> = (0..s * c).map(|_| g.i32_in(-128, 127)).collect();
            let mut scratch1 = slabs(1);
            let mut awant = Vec::new();
            conv_affine_gemm_impl(
                &ax, &ish, &wshape, &aqw, zp_in, zp_out, 1, Padding::Same, relu, 1, &serial,
                &mut scratch1, &mut awant,
            );
            let dqw = random_affine_weights(g, i, o);
            let mut dwant = Vec::new();
            dense_affine_gemm_impl(
                &x, &dqw, zp_in, zp_out, o, relu, &serial, &mut scratch1, &mut dwant,
            );
            for pool in &pools {
                let mut scratch = slabs(pool.threads());
                let mut agot = Vec::new();
                conv_affine_gemm_impl(
                    &ax, &ish, &wshape, &aqw, zp_in, zp_out, 1, Padding::Same, relu, 1, pool,
                    &mut scratch, &mut agot,
                );
                prop_assert!(awant == agot, "affine conv diverged at threads={}", pool.threads());
                let mut dgot = Vec::new();
                dense_affine_gemm_impl(
                    &x, &dqw, zp_in, zp_out, o, relu, pool, &mut scratch, &mut dgot,
                );
                prop_assert!(dwant == dgot, "affine dense diverged at threads={}", pool.threads());
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_f32_conv_ulp_bounded_vs_single_thread() {
        // Contract: ULP-bounded. (The current schedule is in fact
        // order-identical — thread assignment never changes per-element
        // accumulation order — so the observed error is 0, well inside
        // the bound this test pins.)
        let pools = [IntraOpPool::new(2), IntraOpPool::new(4)];
        property(30, |g| {
            let k = g.usize_in(1, 5);
            let c = g.usize_in(1, 6);
            let f = g.usize_in(1, 10);
            let s = g.usize_in(k, 48);
            let stride = g.usize_in(1, 2);
            let relu = g.bool();
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let w: Vec<f32> = g.vec_normal(k * c * f, 0.5);
            let b: Vec<f32> = g.vec_normal(f, 0.1);
            let x: Vec<f32> = g.vec_normal(s * c, 1.0);
            let serial = IntraOpPool::serial();
            let mut scratch1 = vec![Vec::new()];
            let mut want = Vec::new();
            conv1d_gemm_impl(
                &x, s, c, &w, k, f, &b, stride, padding, relu, &serial, &mut scratch1, &mut want,
            );
            for pool in &pools {
                let mut scratch = vec![Vec::new(); pool.threads()];
                let mut got = Vec::new();
                conv1d_gemm_impl(
                    &x, s, c, &w, k, f, &b, stride, padding, relu, pool, &mut scratch, &mut got,
                );
                prop_assert!(want.len() == got.len(), "length mismatch");
                for (idx, (&a, &bv)) in want.iter().zip(&got).enumerate() {
                    // 4-ULP bound around the single-thread value.
                    let tol = 4.0 * f32::EPSILON * a.abs().max(1e-6);
                    prop_assert!(
                        (a - bv).abs() <= tol,
                        "f32 conv diverged at {idx}, threads={}: {a} vs {bv}",
                        pool.threads()
                    );
                }
            }
            Ok(())
        });
    }

    // --- sizing ---

    #[test]
    fn panel_rows_bounds() {
        assert_eq!(panel_rows(27, 128), 128); // whole map fits the target
        assert_eq!(panel_rows(2048, 64), MR); // huge taps: one register tile
        assert_eq!(panel_rows(16, 100_000), PANEL_TARGET_ELEMS / 16);
        assert_eq!(panel_rows(3, 1), 1);
    }

    #[test]
    fn scratch_elems_covers_every_conv_panel() {
        use crate::graph::build::resnet_v1_6_shapes;
        use crate::graph::deploy_pipeline;
        let g = deploy_pipeline(&resnet_v1_6_shapes("t", 1, &[128, 9], 6, 16));
        let need = scratch_elems(&g);
        assert!(need > 0);
        for node in &g.nodes {
            if let LayerKind::Conv { w, .. } = &node.kind {
                let taps: usize = w.shape[..w.shape.len() - 1].iter().product();
                let positions: usize =
                    node.out_shape[..node.out_shape.len() - 1].iter().product();
                assert!(panel_rows(taps, positions) * taps <= need);
            }
        }
    }
}
