//! Runtime-dispatched GEMM microkernels (the ISSUE 10 tentpole).
//!
//! One [`KernelSet`] bundles the four fused panel kernels the packed
//! GEMM core runs — f32, i32-lane, i64-lane fixed-point, i64-lane
//! affine — as plain fn pointers, all sharing the [`super::packed`]
//! NR-tiled panel layout, 4×8 register tile, and `SharedOut` output
//! contract. `nn::packed` stores a `&'static KernelSet` on every
//! [`super::packed::PackedNode`] at build time, so dispatch costs one
//! indirect call per panel, decided once per session:
//!
//! - [`detected`]: `is_x86_feature_detected!("avx2")`/`("fma")` picks
//!   the widest [`avx2`] set the CPU supports (AVX2+FMA → all four
//!   lanes vectorized; AVX2 without FMA → integer lanes only). Non-x86
//!   targets, Miri, and `--no-default-features` builds compile the
//!   dispatch down to [`SCALAR`] unconditionally — no behavior change.
//! - [`scalar`]: the always-compiled portable set, for forced-baseline
//!   benches (`bench_hotpath --force-scalar`), the f32 bit-identity
//!   pins, and `SessionBuilder::force_scalar_kernels`.
//!
//! Contract (property-pinned here at the kernel level and in
//! `nn::packed` through the full conv/dense/attention paths): integer
//! lanes are BIT-EXACT across every set — vector integer add/mul are
//! exact, and the rescale/clamp/requantize epilogues always run the
//! scalar per-element instruction sequence — while f32 stays inside the
//! session's existing 1e-4 budget (FMA contracts mul+add to one
//! rounding; DESIGN.md §13).

use super::parallel::SharedOut;

pub(crate) mod scalar;

#[cfg(all(target_arch = "x86_64", feature = "simd", not(miri)))]
mod avx2;

/// f32 fused panel kernel:
/// `(a, bp, m, n, k, j0, j1, bias, relu, row0, out)`.
pub type KernF32 =
    fn(&[f32], &[f32], usize, usize, usize, usize, usize, &[f32], bool, usize, &SharedOut<f32>);

/// i32-lane fixed-point kernel:
/// `(a, bp, m, n, k, j0, j1, bias, shift, width, relu, row0, out)`.
pub type KernI32 = fn(
    &[i32],
    &[i32],
    usize,
    usize,
    usize,
    usize,
    usize,
    &[i64],
    &[i32],
    u32,
    bool,
    usize,
    &SharedOut<i32>,
);

/// i64 wide-lane fixed-point kernel (same parameter order as
/// [`KernI32`], B pre-widened to i64).
pub type KernI64Fixed = fn(
    &[i32],
    &[i64],
    usize,
    usize,
    usize,
    usize,
    usize,
    &[i64],
    &[i32],
    u32,
    bool,
    usize,
    &SharedOut<i32>,
);

/// i64 wide-lane affine kernel:
/// `(a, bp, m, n, k, j0, j1, bias, mult, shift, zp_out, relu, row0, out)`.
pub type KernI64Affine = fn(
    &[i32],
    &[i64],
    usize,
    usize,
    usize,
    usize,
    usize,
    &[i64],
    &[i32],
    &[i32],
    i32,
    bool,
    usize,
    &SharedOut<i32>,
);

/// One microkernel per accumulator lane, plus the name bench/serving
/// artifacts report so every measurement is attributable to the ISA
/// that produced it.
#[derive(Clone, Copy)]
pub struct KernelSet {
    /// `"scalar"`, `"avx2"`, or `"avx2+fma"` — surfaces in
    /// `SessionMeta::kernel` and the bench v6 `simd` row field.
    pub name: &'static str,
    pub f32: KernF32,
    pub i32: KernI32,
    pub i64_fixed: KernI64Fixed,
    pub i64_affine: KernI64Affine,
}

impl std::fmt::Debug for KernelSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KernelSet").field("name", &self.name).finish()
    }
}

/// The portable scalar set — always compiled, always tested, and the
/// bit-level (integer) / ULP-level (f32) definition the vector sets are
/// pinned against.
pub static SCALAR: KernelSet = KernelSet {
    name: "scalar",
    f32: scalar::kernel_f32,
    i32: scalar::kernel_i32,
    i64_fixed: scalar::kernel_i64_fixed,
    i64_affine: scalar::kernel_i64_affine,
};

/// The widest kernel set this CPU supports, decided by runtime feature
/// detection (cached by `std` after the first query). Called once per
/// packed node at session build — never on the inference hot path.
pub fn detected() -> &'static KernelSet {
    #[cfg(all(target_arch = "x86_64", feature = "simd", not(miri)))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            if std::arch::is_x86_feature_detected!("fma") {
                return &avx2::AVX2_FMA;
            }
            return &avx2::AVX2_INT;
        }
    }
    &SCALAR
}

/// The scalar set, by reference — the forced baseline for benches,
/// bit-identity tests, and `SessionBuilder::force_scalar_kernels`.
pub fn scalar() -> &'static KernelSet {
    &SCALAR
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::gemm::testgen::{random_affine_weights, random_qw};
    use crate::nn::gemm::NR;
    use crate::nn::int_ops::accum_fits_i32;
    use crate::nn::packed::pack_panels;
    use crate::prop_assert;
    use crate::util::check::{property, Gen};

    /// Random panel geometry hitting every tail class: m % MR ∈ {0..3},
    /// n % NR ∈ {0..7}, odd k, NR-aligned j0/j1 column windows (the
    /// pool-partition and batch-fold entry shapes), and row0 offsets
    /// (the batch-fold M-stacking shape).
    fn geometry(g: &mut Gen) -> (usize, usize, usize, usize, usize, usize) {
        let m = g.usize_in(1, 9);
        let n = g.usize_in(1, 20);
        let k = g.usize_in(1, 17);
        let t0 = g.usize_in(0, n.div_ceil(NR) - 1);
        let j0 = t0 * NR;
        let j1 = g.usize_in(j0, n);
        let row0 = g.usize_in(0, 3);
        (m, n, k, j0, j1, row0)
    }

    /// Pin `probe`'s four lanes against [`SCALAR`]: integer lanes
    /// bit-exact, f32 within the 1e-4 fused-reorder budget.
    fn pin_against_scalar(probe: &'static KernelSet, cases: u64) {
        use crate::nn::parallel::SharedOut;
        property(cases, |g| {
            let (m, n, k, j0, j1, row0) = geometry(g);
            let relu = g.bool();

            // f32 lane.
            let w = g.vec_normal(k * n, 0.5);
            let bias = g.vec_normal(n, 0.1);
            let a = g.vec_normal(m * k, 1.0);
            let bp = pack_panels(&w, k, n, |v| v);
            let mut want = vec![0.0f32; (row0 + m) * n];
            let mut got = want.clone();
            (SCALAR.f32)(&a, &bp, m, n, k, j0, j1, &bias, relu, row0, &SharedOut::new(&mut want));
            (probe.f32)(&a, &bp, m, n, k, j0, j1, &bias, relu, row0, &SharedOut::new(&mut got));
            for (idx, (&x, &y)) in want.iter().zip(&got).enumerate() {
                let tol = 1e-4f32.max(x.abs() * 1e-4);
                prop_assert!(
                    (x - y).abs() <= tol,
                    "{} f32 off at {idx}: {x} vs {y} (m={m} n={n} k={k} j0={j0} j1={j1})",
                    probe.name
                );
            }

            // Fixed-point lanes, across the accum_fits_i32 straddle: the
            // i64 wide lane always runs; the i32 narrow lane runs exactly
            // when the node would be admitted to it.
            let width = *g.pick(&[8u32, 16]);
            let qw = random_qw(g, k, n, width, width == 8);
            let lim = (1i32 << (width - 1)) - 1;
            let ia: Vec<i32> = (0..m * k).map(|_| g.i32_in(-lim - 1, lim)).collect();
            let bp64 = pack_panels(&qw.w, k, n, i64::from);
            let mut want = vec![0i32; (row0 + m) * n];
            let mut got = want.clone();
            (SCALAR.i64_fixed)(
                &ia, &bp64, m, n, k, j0, j1, &qw.b_acc, &qw.shift, width, relu, row0,
                &SharedOut::new(&mut want),
            );
            (probe.i64_fixed)(
                &ia, &bp64, m, n, k, j0, j1, &qw.b_acc, &qw.shift, width, relu, row0,
                &SharedOut::new(&mut got),
            );
            prop_assert!(want == got, "{} i64_fixed diverged (m={m} n={n} k={k})", probe.name);
            if accum_fits_i32(&qw, k, width) {
                let bp32 = pack_panels(&qw.w, k, n, |v| v);
                let mut want = vec![0i32; (row0 + m) * n];
                let mut got = want.clone();
                (SCALAR.i32)(
                    &ia, &bp32, m, n, k, j0, j1, &qw.b_acc, &qw.shift, width, relu, row0,
                    &SharedOut::new(&mut want),
                );
                (probe.i32)(
                    &ia, &bp32, m, n, k, j0, j1, &qw.b_acc, &qw.shift, width, relu, row0,
                    &SharedOut::new(&mut got),
                );
                prop_assert!(want == got, "{} i32 diverged (m={m} n={n} k={k})", probe.name);
            }

            // Affine lane (gemmlowp requantize epilogue).
            let aqw = random_affine_weights(g, k, n);
            let zp_out = g.i32_in(-128, 127);
            let aa: Vec<i32> = (0..m * k).map(|_| g.i32_in(-128, 127)).collect();
            let abp = pack_panels(&aqw.w, k, n, i64::from);
            let mut want = vec![0i32; (row0 + m) * n];
            let mut got = want.clone();
            (SCALAR.i64_affine)(
                &aa, &abp, m, n, k, j0, j1, &aqw.b, &aqw.mult, &aqw.shift, zp_out, relu, row0,
                &SharedOut::new(&mut want),
            );
            (probe.i64_affine)(
                &aa, &abp, m, n, k, j0, j1, &aqw.b, &aqw.mult, &aqw.shift, zp_out, relu, row0,
                &SharedOut::new(&mut got),
            );
            prop_assert!(want == got, "{} i64_affine diverged (m={m} n={n} k={k})", probe.name);
            Ok(())
        });
    }

    #[test]
    fn dispatch_names_are_attributable() {
        assert_eq!(SCALAR.name, "scalar");
        assert_eq!(scalar().name, "scalar");
        let d = detected();
        assert!(
            ["scalar", "avx2", "avx2+fma"].contains(&d.name),
            "unknown kernel set {:?}",
            d
        );
        // Non-x86 targets, Miri, and no-feature builds MUST resolve to
        // scalar — the fallback is unconditional, not best-effort.
        #[cfg(not(all(target_arch = "x86_64", feature = "simd", not(miri))))]
        assert_eq!(d.name, "scalar");
        // And where dispatch is live, the name must agree with the CPU.
        #[cfg(all(target_arch = "x86_64", feature = "simd", not(miri)))]
        {
            let want = if std::arch::is_x86_feature_detected!("avx2") {
                if std::arch::is_x86_feature_detected!("fma") {
                    "avx2+fma"
                } else {
                    "avx2"
                }
            } else {
                "scalar"
            };
            assert_eq!(d.name, want);
        }
    }

    /// Whatever `detected()` resolved to on this machine agrees with
    /// scalar. On non-AVX2 hosts (and under Miri) this compares scalar
    /// against itself — the always-green shim that keeps the suite
    /// cross-arch.
    #[test]
    fn detected_kernels_match_scalar_at_kernel_level() {
        pin_against_scalar(detected(), 60);
    }

    /// The cfg-gated forced-variant pin (ISSUE 10): run BOTH vector sets
    /// explicitly — not just whichever one dispatch would pick — so a
    /// `RUSTFLAGS=+avx2,+fma` CI leg and a plain leg both exercise the
    /// scalar and AVX2 arms on the same runner.
    #[cfg(all(target_arch = "x86_64", feature = "simd", not(miri)))]
    #[test]
    fn forced_avx2_variants_bit_exact_vs_scalar() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            eprintln!("skipping forced AVX2 pin: host CPU lacks avx2");
            return;
        }
        pin_against_scalar(&super::avx2::AVX2_INT, 60);
        if std::arch::is_x86_feature_detected!("fma") {
            pin_against_scalar(&super::avx2::AVX2_FMA, 60);
        }
    }
}
