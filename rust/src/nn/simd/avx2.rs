//! AVX2 / AVX2+FMA microkernels for the packed-panel GEMM core
//! (x86-64 only; compiled out on other targets, under Miri, and with
//! `--no-default-features`).
//!
//! Strategy per accumulator lane, on the SAME 4×8 register tile and
//! NR-tiled packed-B layout as [`super::scalar`]:
//!
//! - **f32 (AVX2+FMA)**: one `__m256` accumulator per tile row, B rows
//!   stream as full 8-wide `loadu`, A broadcasts per element,
//!   `_mm256_fmadd_ps` accumulates. Full (nr = NR) tiles run the fused
//!   bias/ReLU epilogue vectorized and store straight through the
//!   output window; tail tiles (nr < NR — the packed B is zero-filled
//!   there, so the extra lanes accumulate exact zeros) spill the
//!   accumulator to the stack and run the scalar epilogue per owned
//!   column. The fused multiply-add rounds once where scalar rounds
//!   twice, so f32 bits may differ from scalar within the session's
//!   existing 1e-4 fused-reorder budget (DESIGN.md §13).
//! - **i32 (AVX2)**: `_mm256_mullo_epi32` + `_mm256_add_epi32`. Both
//!   wrap mod 2³², exactly like the scalar kernel's release-mode
//!   arithmetic, and `accum_fits_i32`-admitted nodes never reach the
//!   wrap, so results are BIT-exact vs scalar. The `av == 0` ReLU
//!   sparsity skip is kept (exact for integers).
//! - **i64 (AVX2, fixed + affine)**: two `__m256i` accumulators per
//!   8-column tile row. Packed i64 weights are pre-widened from i32, so
//!   the low 32 bits of every 64-bit lane sign-extend back to the exact
//!   weight, and `_mm256_mul_epi32` (signed 32×32→64) produces the
//!   exact product `_mm256_add_epi64` then accumulates — bit-identical
//!   to the scalar `i64 += (av as i64) * bv`. Integer epilogues always
//!   spill and run the scalar per-element code, so rescale/clamp/
//!   requantize are the same instruction sequence as scalar.
//!
//! Safety regime (PR-7 audit): the public entries are plain fns (so
//! they coerce to the [`super::KernelSet`] fn pointers) whose only
//! `unsafe` is the call into the `#[target_feature]` impl, justified by
//! the dispatch contract — these entries are only reachable through a
//! `KernelSet` installed after `is_x86_feature_detected!` succeeded (or
//! under an explicit detection guard in the forced-variant tests). The
//! impls assert panel bounds at entry so every raw `loadu`/`storeu` is
//! provably in-bounds, and output writes go through the same
//! [`SharedOut`] disjoint-range contract as the scalar kernels.

use core::arch::x86_64::*;

use crate::fixedpoint::ops::{clamp_to, rescale};
use crate::nn::gemm::{MR, NR};
use crate::nn::packed::packed_cols;
use crate::nn::parallel::SharedOut;
use crate::quant::affine::requantize;

use super::scalar::{self, shift_at};
use super::KernelSet;

/// Integer kernels vectorized, f32 left scalar: the set for CPUs with
/// AVX2 but no FMA (integer SIMD never needs FMA).
pub(crate) static AVX2_INT: KernelSet = KernelSet {
    name: "avx2",
    f32: scalar::kernel_f32,
    i32: kernel_i32,
    i64_fixed: kernel_i64_fixed,
    i64_affine: kernel_i64_affine,
};

/// All four lanes vectorized (the common modern-x86 outcome).
pub(crate) static AVX2_FMA: KernelSet = KernelSet {
    name: "avx2+fma",
    f32: kernel_f32,
    i32: kernel_i32,
    i64_fixed: kernel_i64_fixed,
    i64_affine: kernel_i64_affine,
};

#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_f32(
    a: &[f32],
    bp: &[f32],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[f32],
    relu: bool,
    row0: usize,
    out: &SharedOut<f32>,
) {
    // SAFETY: reachable only through a KernelSet installed after
    // `is_x86_feature_detected!("avx2")`/`("fma")` succeeded (dispatch
    // contract; the forced-variant tests guard the same way), so the
    // target features the impl assumes are present on this CPU.
    unsafe { kernel_f32_impl(a, bp, m, n, k, j0, j1, bias, relu, row0, out) }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
unsafe fn kernel_f32_impl(
    a: &[f32],
    bp: &[f32],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[f32],
    relu: bool,
    row0: usize,
    out: &SharedOut<f32>,
) {
    assert!(j0 % NR == 0 && j0 <= j1 && j1 <= n, "bad packed column range");
    assert!(a.len() >= m * k, "A panel too small");
    assert!(bp.len() >= packed_cols(n) * k, "packed B too small");
    assert!(bias.len() >= j1, "bias too small");
    let tile_elems = k * NR;
    let bpp = bp.as_ptr();
    let mut i = 0usize;
    while i < m {
        let mr = MR.min(m - i);
        let mut j = j0;
        while j < j1 {
            let nr = NR.min(j1 - j);
            let tb = (j / NR) * tile_elems;
            // SAFETY: B loads — `j < j1 <= n` puts tile `j / NR` inside
            // the `packed_cols(n) / NR` tiles the entry assert covers,
            // so `tb + p·NR + NR <= packed_cols(n)·k <= bp.len()` for
            // every `p < k` (tail columns are zero-filled, never OOB).
            // Bias load — only on nr = NR tiles, where `j + NR <= j1 <=
            // bias.len()`. Output — the dispatch owns rows
            // row0..row0+m and columns j0..j1 exclusively (the same
            // SharedOut contract the scalar kernel relies on), and the
            // vector store targets base+j..base+j+NR only when the full
            // tile is owned (nr = NR).
            unsafe {
                let mut acc = [_mm256_setzero_ps(); MR];
                for p in 0..k {
                    let bvec = _mm256_loadu_ps(bpp.add(tb + p * NR));
                    for (mi, accv) in acc.iter_mut().enumerate().take(mr) {
                        let av = _mm256_set1_ps(a[(i + mi) * k + p]);
                        *accv = _mm256_fmadd_ps(av, bvec, *accv);
                    }
                }
                for (mi, accv) in acc.iter().enumerate().take(mr) {
                    let base = (row0 + i + mi) * n;
                    if nr == NR {
                        let mut v = _mm256_add_ps(*accv, _mm256_loadu_ps(bias.as_ptr().add(j)));
                        if relu {
                            v = _mm256_max_ps(v, _mm256_setzero_ps());
                        }
                        _mm256_storeu_ps(out.slice_mut(base + j, NR).as_mut_ptr(), v);
                    } else {
                        let mut spill = [0.0f32; NR];
                        _mm256_storeu_ps(spill.as_mut_ptr(), *accv);
                        for (ni, &sv) in spill.iter().enumerate().take(nr) {
                            let fi = j + ni;
                            let v = sv + bias[fi];
                            out.write(base + fi, if relu { v.max(0.0) } else { v });
                        }
                    }
                }
            }
            j += nr;
        }
        i += mr;
    }
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_i32(
    a: &[i32],
    bp: &[i32],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[i64],
    shift: &[i32],
    width: u32,
    relu: bool,
    row0: usize,
    out: &SharedOut<i32>,
) {
    // SAFETY: as in `kernel_f32` — only reachable behind a successful
    // AVX2 detection.
    unsafe { kernel_i32_impl(a, bp, m, n, k, j0, j1, bias, shift, width, relu, row0, out) }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
unsafe fn kernel_i32_impl(
    a: &[i32],
    bp: &[i32],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[i64],
    shift: &[i32],
    width: u32,
    relu: bool,
    row0: usize,
    out: &SharedOut<i32>,
) {
    assert!(j0 % NR == 0 && j0 <= j1 && j1 <= n, "bad packed column range");
    assert!(a.len() >= m * k, "A panel too small");
    assert!(bp.len() >= packed_cols(n) * k, "packed B too small");
    let tile_elems = k * NR;
    let bpp = bp.as_ptr();
    let mut i = 0usize;
    while i < m {
        let mr = MR.min(m - i);
        let mut j = j0;
        while j < j1 {
            let nr = NR.min(j1 - j);
            let tb = (j / NR) * tile_elems;
            // SAFETY: B loads in-bounds by the same tile-index argument
            // as `kernel_f32_impl` (entry assert + `j < j1 <= n`); the
            // stack spill stores into a local `[i32; NR]`; output
            // writes go element-wise through `SharedOut::write` under
            // the dispatch's disjoint row/column ownership contract.
            unsafe {
                let mut acc = [_mm256_setzero_si256(); MR];
                for p in 0..k {
                    let bvec = _mm256_loadu_si256(bpp.add(tb + p * NR) as *const __m256i);
                    for (mi, accv) in acc.iter_mut().enumerate().take(mr) {
                        let av = a[(i + mi) * k + p];
                        if av == 0 {
                            // ReLU sparsity: exact skip for integers.
                            continue;
                        }
                        let avv = _mm256_set1_epi32(av);
                        *accv = _mm256_add_epi32(*accv, _mm256_mullo_epi32(avv, bvec));
                    }
                }
                for (mi, accv) in acc.iter().enumerate().take(mr) {
                    let base = (row0 + i + mi) * n;
                    let mut spill = [0i32; NR];
                    _mm256_storeu_si256(spill.as_mut_ptr() as *mut __m256i, *accv);
                    for (ni, &sv) in spill.iter().enumerate().take(nr) {
                        let fi = j + ni;
                        let total = sv + bias[fi] as i32;
                        let mut v = clamp_to(rescale(i64::from(total), shift_at(shift, fi)), width);
                        if relu && v < 0 {
                            v = 0;
                        }
                        out.write(base + fi, v);
                    }
                }
            }
            j += nr;
        }
        i += mr;
    }
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_i64_fixed(
    a: &[i32],
    bp: &[i64],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[i64],
    shift: &[i32],
    width: u32,
    relu: bool,
    row0: usize,
    out: &SharedOut<i32>,
) {
    // SAFETY: as in `kernel_f32` — only reachable behind a successful
    // AVX2 detection.
    unsafe { kernel_i64_fixed_impl(a, bp, m, n, k, j0, j1, bias, shift, width, relu, row0, out) }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
unsafe fn kernel_i64_fixed_impl(
    a: &[i32],
    bp: &[i64],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[i64],
    shift: &[i32],
    width: u32,
    relu: bool,
    row0: usize,
    out: &SharedOut<i32>,
) {
    assert!(j0 % NR == 0 && j0 <= j1 && j1 <= n, "bad packed column range");
    assert!(a.len() >= m * k, "A panel too small");
    assert!(bp.len() >= packed_cols(n) * k, "packed B too small");
    let tile_elems = k * NR;
    let bpp = bp.as_ptr();
    let mut i = 0usize;
    while i < m {
        let mr = MR.min(m - i);
        let mut j = j0;
        while j < j1 {
            let nr = NR.min(j1 - j);
            let tb = (j / NR) * tile_elems;
            // SAFETY: B loads — each 8-i64 tile row splits into two
            // 4-lane halves at `tb + p·NR` and `tb + p·NR + 4`, both
            // inside `packed_cols(n)·k <= bp.len()` by the entry assert
            // and `j < j1 <= n`. `_mm256_mul_epi32` reads the low 32
            // bits of each i64 lane — exact, because packed i64 weights
            // are pre-widened from i32 so those bits sign-extend back
            // to the full value. Spills store into locals; output
            // writes go through `SharedOut::write` under the dispatch
            // ownership contract.
            unsafe {
                let mut acc_lo = [_mm256_setzero_si256(); MR];
                let mut acc_hi = [_mm256_setzero_si256(); MR];
                for p in 0..k {
                    let b_lo = _mm256_loadu_si256(bpp.add(tb + p * NR) as *const __m256i);
                    let b_hi = _mm256_loadu_si256(bpp.add(tb + p * NR + 4) as *const __m256i);
                    for (mi, (alo, ahi)) in
                        acc_lo.iter_mut().zip(acc_hi.iter_mut()).enumerate().take(mr)
                    {
                        let av = a[(i + mi) * k + p];
                        if av == 0 {
                            // ReLU sparsity: exact skip for integers.
                            continue;
                        }
                        let avv = _mm256_set1_epi64x(av as i64);
                        *alo = _mm256_add_epi64(*alo, _mm256_mul_epi32(avv, b_lo));
                        *ahi = _mm256_add_epi64(*ahi, _mm256_mul_epi32(avv, b_hi));
                    }
                }
                for (mi, (alo, ahi)) in acc_lo.iter().zip(acc_hi.iter()).enumerate().take(mr) {
                    let base = (row0 + i + mi) * n;
                    let mut spill = [0i64; NR];
                    _mm256_storeu_si256(spill.as_mut_ptr() as *mut __m256i, *alo);
                    _mm256_storeu_si256(spill.as_mut_ptr().add(4) as *mut __m256i, *ahi);
                    for (ni, &sv) in spill.iter().enumerate().take(nr) {
                        let fi = j + ni;
                        let mut v = clamp_to(rescale(sv + bias[fi], shift_at(shift, fi)), width);
                        if relu && v < 0 {
                            v = 0;
                        }
                        out.write(base + fi, v);
                    }
                }
            }
            j += nr;
        }
        i += mr;
    }
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_i64_affine(
    a: &[i32],
    bp: &[i64],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[i64],
    mult: &[i32],
    shift: &[i32],
    zp_out: i32,
    relu: bool,
    row0: usize,
    out: &SharedOut<i32>,
) {
    // SAFETY: as in `kernel_f32` — only reachable behind a successful
    // AVX2 detection.
    unsafe {
        kernel_i64_affine_impl(a, bp, m, n, k, j0, j1, bias, mult, shift, zp_out, relu, row0, out)
    }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
unsafe fn kernel_i64_affine_impl(
    a: &[i32],
    bp: &[i64],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[i64],
    mult: &[i32],
    shift: &[i32],
    zp_out: i32,
    relu: bool,
    row0: usize,
    out: &SharedOut<i32>,
) {
    assert!(j0 % NR == 0 && j0 <= j1 && j1 <= n, "bad packed column range");
    assert!(a.len() >= m * k, "A panel too small");
    assert!(bp.len() >= packed_cols(n) * k, "packed B too small");
    let tile_elems = k * NR;
    let bpp = bp.as_ptr();
    let mut i = 0usize;
    while i < m {
        let mr = MR.min(m - i);
        let mut j = j0;
        while j < j1 {
            let nr = NR.min(j1 - j);
            let tb = (j / NR) * tile_elems;
            // SAFETY: identical bounds/exactness argument to
            // `kernel_i64_fixed_impl` — only the (scalar, spilled)
            // epilogue differs.
            unsafe {
                let mut acc_lo = [_mm256_setzero_si256(); MR];
                let mut acc_hi = [_mm256_setzero_si256(); MR];
                for p in 0..k {
                    let b_lo = _mm256_loadu_si256(bpp.add(tb + p * NR) as *const __m256i);
                    let b_hi = _mm256_loadu_si256(bpp.add(tb + p * NR + 4) as *const __m256i);
                    for (mi, (alo, ahi)) in
                        acc_lo.iter_mut().zip(acc_hi.iter_mut()).enumerate().take(mr)
                    {
                        let av = a[(i + mi) * k + p];
                        if av == 0 {
                            // Raw-payload zero: contributes 0 to Σ x·w.
                            continue;
                        }
                        let avv = _mm256_set1_epi64x(av as i64);
                        *alo = _mm256_add_epi64(*alo, _mm256_mul_epi32(avv, b_lo));
                        *ahi = _mm256_add_epi64(*ahi, _mm256_mul_epi32(avv, b_hi));
                    }
                }
                for (mi, (alo, ahi)) in acc_lo.iter().zip(acc_hi.iter()).enumerate().take(mr) {
                    let base = (row0 + i + mi) * n;
                    let mut spill = [0i64; NR];
                    _mm256_storeu_si256(spill.as_mut_ptr() as *mut __m256i, *alo);
                    _mm256_storeu_si256(spill.as_mut_ptr().add(4) as *mut __m256i, *ahi);
                    for (ni, &sv) in spill.iter().enumerate().take(nr) {
                        let fi = j + ni;
                        let total = bias[fi] + sv;
                        let mut v = requantize(total as i32, mult[fi], shift[fi], zp_out);
                        if relu {
                            v = v.max(zp_out);
                        }
                        out.write(base + fi, v);
                    }
                }
            }
            j += nr;
        }
        i += mr;
    }
}
