//! Portable scalar microkernels — the always-compiled, always-tested
//! reference members of every [`super::KernelSet`].
//!
//! These are the PR-5 fused kernels moved verbatim out of `nn::packed`:
//! 4×8 register tiles, k-major per-element accumulation (thread- and
//! tile-invariant bits), epilogue fused into the register tail. Every
//! other kernel set in this module tree is defined by bit-equality (for
//! the integer lanes) or ULP-budget equality (f32) against THESE
//! functions; the property pins live in `nn::packed` (full-path, at
//! threads 1/2/4 across the `accum_fits_i32` straddle) and in
//! `super::tests` (kernel-level, forced-variant).

use crate::fixedpoint::ops::{clamp_to, rescale};
use crate::nn::gemm::{MR, NR};
use crate::nn::packed::packed_cols;
use crate::nn::parallel::SharedOut;
use crate::quant::affine::requantize;

#[inline(always)]
pub(crate) fn shift_at(shift: &[i32], fi: usize) -> i32 {
    if shift.len() == 1 {
        shift[0]
    } else {
        shift[fi]
    }
}

/// f32 fused kernel: identical per-element operation sequence to the
/// per-call `gemm_f32_cols` + bias/ReLU emit (k-major accumulate, then
/// `acc + bias`, then ReLU), so results are BIT-identical to the PR-3/4
/// path — only the B storage layout changed.
#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_f32(
    a: &[f32],
    bp: &[f32],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[f32],
    relu: bool,
    row0: usize,
    out: &SharedOut<f32>,
) {
    debug_assert!(j0 % NR == 0 && j0 <= j1 && j1 <= n, "bad packed column range");
    debug_assert!(a.len() >= m * k, "A panel too small");
    debug_assert!(bp.len() >= packed_cols(n) * k, "packed B too small");
    let tile_elems = k * NR;
    let mut i = 0usize;
    while i < m {
        let mr = MR.min(m - i);
        let mut j = j0;
        while j < j1 {
            let nr = NR.min(j1 - j);
            let tb = (j / NR) * tile_elems;
            let mut acc: [[f32; NR]; MR] = [[0.0; NR]; MR];
            for p in 0..k {
                let brow = &bp[tb + p * NR..tb + p * NR + nr];
                for (mi, accrow) in acc.iter_mut().enumerate().take(mr) {
                    let av = a[(i + mi) * k + p];
                    for (accv, &bv) in accrow.iter_mut().zip(brow) {
                        *accv += av * bv;
                    }
                }
            }
            for (mi, accrow) in acc.iter().enumerate().take(mr) {
                let base = (row0 + i + mi) * n;
                for (ni, &accv) in accrow.iter().enumerate().take(nr) {
                    let fi = j + ni;
                    let v = accv + bias[fi];
                    // SAFETY: the dispatch owns rows row0..row0+m and
                    // columns j0..j1 of the output exclusively.
                    unsafe { out.write(base + fi, if relu { v.max(0.0) } else { v }) };
                }
            }
            j += nr;
        }
        i += mr;
    }
}

/// i32-lane fused kernel (fixed-point, `accum_fits_i32`-admitted nodes):
/// bit-exact with the reference epilogue (`acc + b as i32`, widen,
/// rescale, clamp, ReLU).
#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_i32(
    a: &[i32],
    bp: &[i32],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[i64],
    shift: &[i32],
    width: u32,
    relu: bool,
    row0: usize,
    out: &SharedOut<i32>,
) {
    debug_assert!(j0 % NR == 0 && j0 <= j1 && j1 <= n, "bad packed column range");
    debug_assert!(a.len() >= m * k, "A panel too small");
    debug_assert!(bp.len() >= packed_cols(n) * k, "packed B too small");
    let tile_elems = k * NR;
    let mut i = 0usize;
    while i < m {
        let mr = MR.min(m - i);
        let mut j = j0;
        while j < j1 {
            let nr = NR.min(j1 - j);
            let tb = (j / NR) * tile_elems;
            let mut acc: [[i32; NR]; MR] = [[0; NR]; MR];
            for p in 0..k {
                let brow = &bp[tb + p * NR..tb + p * NR + nr];
                for (mi, accrow) in acc.iter_mut().enumerate().take(mr) {
                    let av = a[(i + mi) * k + p];
                    if av == 0 {
                        // ReLU sparsity: exact skip for integers.
                        continue;
                    }
                    for (accv, &bv) in accrow.iter_mut().zip(brow) {
                        *accv += av * bv;
                    }
                }
            }
            for (mi, accrow) in acc.iter().enumerate().take(mr) {
                let base = (row0 + i + mi) * n;
                for (ni, &accv) in accrow.iter().enumerate().take(nr) {
                    let fi = j + ni;
                    let total = accv + bias[fi] as i32;
                    let mut v = clamp_to(rescale(i64::from(total), shift_at(shift, fi)), width);
                    if relu && v < 0 {
                        v = 0;
                    }
                    // SAFETY: as in `kernel_f32`.
                    unsafe { out.write(base + fi, v) };
                }
            }
            j += nr;
        }
        i += mr;
    }
}

/// i64 wide fused kernel, fixed-point epilogue.
#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_i64_fixed(
    a: &[i32],
    bp: &[i64],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[i64],
    shift: &[i32],
    width: u32,
    relu: bool,
    row0: usize,
    out: &SharedOut<i32>,
) {
    debug_assert!(j0 % NR == 0 && j0 <= j1 && j1 <= n, "bad packed column range");
    debug_assert!(a.len() >= m * k, "A panel too small");
    debug_assert!(bp.len() >= packed_cols(n) * k, "packed B too small");
    let tile_elems = k * NR;
    let mut i = 0usize;
    while i < m {
        let mr = MR.min(m - i);
        let mut j = j0;
        while j < j1 {
            let nr = NR.min(j1 - j);
            let tb = (j / NR) * tile_elems;
            let mut acc: [[i64; NR]; MR] = [[0; NR]; MR];
            for p in 0..k {
                let brow = &bp[tb + p * NR..tb + p * NR + nr];
                for (mi, accrow) in acc.iter_mut().enumerate().take(mr) {
                    let av = a[(i + mi) * k + p];
                    if av == 0 {
                        // ReLU sparsity: exact skip for integers.
                        continue;
                    }
                    let av = av as i64;
                    for (accv, &bv) in accrow.iter_mut().zip(brow) {
                        *accv += av * bv;
                    }
                }
            }
            for (mi, accrow) in acc.iter().enumerate().take(mr) {
                let base = (row0 + i + mi) * n;
                for (ni, &accv) in accrow.iter().enumerate().take(nr) {
                    let fi = j + ni;
                    let mut v = clamp_to(rescale(accv + bias[fi], shift_at(shift, fi)), width);
                    if relu && v < 0 {
                        v = 0;
                    }
                    // SAFETY: as in `kernel_f32`.
                    unsafe { out.write(base + fi, v) };
                }
            }
            j += nr;
        }
        i += mr;
    }
}

/// i64 wide fused kernel, affine (gemmlowp requantize) epilogue. The
/// bias carries the build-time zero-point fold; the final accumulator is
/// the same integer the reference reaches, so the `as i32` cast into
/// `requantize` is bit-identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_i64_affine(
    a: &[i32],
    bp: &[i64],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    j1: usize,
    bias: &[i64],
    mult: &[i32],
    shift: &[i32],
    zp_out: i32,
    relu: bool,
    row0: usize,
    out: &SharedOut<i32>,
) {
    debug_assert!(j0 % NR == 0 && j0 <= j1 && j1 <= n, "bad packed column range");
    debug_assert!(a.len() >= m * k, "A panel too small");
    debug_assert!(bp.len() >= packed_cols(n) * k, "packed B too small");
    let tile_elems = k * NR;
    let mut i = 0usize;
    while i < m {
        let mr = MR.min(m - i);
        let mut j = j0;
        while j < j1 {
            let nr = NR.min(j1 - j);
            let tb = (j / NR) * tile_elems;
            let mut acc: [[i64; NR]; MR] = [[0; NR]; MR];
            for p in 0..k {
                let brow = &bp[tb + p * NR..tb + p * NR + nr];
                for (mi, accrow) in acc.iter_mut().enumerate().take(mr) {
                    let av = a[(i + mi) * k + p];
                    if av == 0 {
                        // Raw-payload zero: contributes 0 to Σ x·w.
                        continue;
                    }
                    let av = av as i64;
                    for (accv, &bv) in accrow.iter_mut().zip(brow) {
                        *accv += av * bv;
                    }
                }
            }
            for (mi, accrow) in acc.iter().enumerate().take(mr) {
                let base = (row0 + i + mi) * n;
                for (ni, &accv) in accrow.iter().enumerate().take(nr) {
                    let fi = j + ni;
                    let total = bias[fi] + accv;
                    let mut v = requantize(total as i32, mult[fi], shift[fi], zp_out);
                    if relu {
                        v = v.max(zp_out);
                    }
                    // SAFETY: as in `kernel_f32`.
                    unsafe { out.write(base + fi, v) };
                }
            }
            j += nr;
        }
        i += mr;
    }
}
