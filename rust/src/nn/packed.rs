//! Build-time weight pre-packing + fused GEMM epilogues (the PR-5
//! tentpole; DESIGN.md §8).
//!
//! The per-call GEMM lowering in [`super::gemm`] streams the weight
//! operand B straight out of graph storage in (K = taps) × (N = filters)
//! row-major order: every microkernel step gathers an NR-wide row slice
//! at stride N, and the affine engine additionally subtracts the input
//! zero point while packing its activation panels on every request.
//! Weights are constant, so all of that belongs at session-build time:
//!
//! - **NR-tiled B panels** ([`PackedNode`], one layout per accumulator
//!   lane: f32 / i32 / i64 — the i64 lane stores pre-widened weights so
//!   the kernel never casts). Tile `t` holds columns `t·NR..(t+1)·NR` of
//!   B as a contiguous K×NR block (tail columns zero-filled), so the
//!   inner k-loop streams B sequentially instead of striding by N.
//! - **Fused epilogues** ([`Epilogue`]): bias + activation +
//!   rescale/requantize run in the register-tile tail and write straight
//!   into the output slice — no `emit` closure, no second pass. Three
//!   variants matching the three engines: `BiasRelu` (float),
//!   `BiasShiftClamp` (fixed-point Qm.n), `BiasRequant` (affine). Which
//!   activation a node fuses is decided by the graph pass
//!   [`annotate_epilogues`].
//! - **Affine zero-point fold**: instead of subtracting `zp_in` from
//!   every packed activation element per call, the build step folds it
//!   into the packed bias — `b_eff[f] = b[f] − zp_in · Σ_p w[p][f]` —
//!   and activation panels pack RAW payloads with padding payload
//!   `zp_in`. Bit-identical: the reference computes
//!   `b + Σ_in-range (x − zp)·w`; the folded form computes
//!   `b − zp·Σ_all w + Σ_all x_t·w` with `x_t = zp` on padded taps, and
//!   the two integer sums are equal term-for-term (exact i64 arithmetic,
//!   no overflow at int8 magnitudes), so the final accumulator — and
//!   therefore the `as i32` cast into gemmlowp requantization — is the
//!   same integer. Bonus: the affine dense no longer stages `x − zp` in
//!   scratch at all, and 1×1 convs can use the raw input as the A panel.
//! - **Identity A-panel fast path**: dense layers and 1×1 stride-1 convs
//!   skip im2col entirely — the im2col row for output position `o` would
//!   be exactly `x[o·C..(o+1)·C]`, so the input tensor IS the A matrix.
//!
//! Semantics contract (property-pinned below): integer results are
//! **bit-exact** against the naive `*_ref` kernels across the
//! `accum_fits_i32` admission boundary, across thread counts (the
//! per-element accumulation order is k-major and thread-invariant,
//! exactly as in `super::gemm`) AND across kernel sets — the
//! ISSUE 10 [`super::simd`] dispatch swaps in AVX2 microkernels whose
//! integer lanes reproduce the scalar bits exactly. f32 results on the
//! scalar kernel set are **bit-identical to the per-call GEMM lowering**
//! (same per-element operation sequence — only the B storage layout
//! changed); the AVX2+FMA f32 kernel contracts mul+add to one rounding
//! and stays inside the session's 1e-4 budget (DESIGN.md §13).
//!
//! Ownership: a [`PackedWeights`] arena is built once per session plan
//! ([`crate::nn::session::InferenceBackend::pack_weights`]) and shared
//! read-only behind an `Arc` — `Session::fork` aliases it instead of
//! copying. Host-only, like the GEMM packing scratch: the device RAM/ROM
//! models are untouched (`Allocation::packed_b_elems` records the
//! element count as a lifetime fact, never charges it to device RAM).

use crate::fixedpoint::ops::{clamp_to, rescale};
use crate::graph::ir::{AttnWeights, Graph, LayerKind, Padding};
use crate::graph::{annotate_epilogues, EpilogueKind};
use crate::quant::affine::{requantize, AffineNodeWeights, AffineQuantizedGraph, AffineTxWeights};
use crate::quant::ptq::{QNodeWeights, QTxWeights, QuantizedGraph};
use crate::tensor::TensorF;

use super::affine_exec::softmax_affine_row;
use super::gemm::{self, MR, NR};
use super::int_ops::{accum_fits_i32, softmax_q_row};
use super::parallel::{IntraOpPool, SharedOut};
use super::simd::{self, KernelSet};

/// Columns of the packed B layout: N rounded up to a whole NR tile (tail
/// columns zero-filled, never emitted).
pub fn packed_cols(n: usize) -> usize {
    n.div_ceil(NR) * NR
}

/// Total packed-B elements the graph's conv/dense nodes need — the
/// allocator's host-only accounting fact (`Allocation::packed_b_elems`),
/// matched by `PackedWeights::panel_elems` for every backend builder.
pub fn packed_b_elems(graph: &Graph) -> usize {
    graph
        .nodes
        .iter()
        .map(|n| match &n.kind {
            // Attention packs its four d_model x d_model projections as
            // dense-style NR-tiled panels.
            LayerKind::SelfAttention { heads, head_dim, .. } => {
                let dm = heads * head_dim;
                4 * packed_cols(dm) * dm
            }
            kind => node_dims(kind).map_or(0, |(_, taps, f)| packed_cols(f) * taps),
        })
        .sum()
}

/// (spatial kernel dims, taps = K, filters = N) of a weighted node.
fn node_dims(kind: &LayerKind) -> Option<(Vec<usize>, usize, usize)> {
    match kind {
        LayerKind::Conv { w, .. } => {
            let n = *w.shape.last().unwrap();
            let taps = w.shape[..w.shape.len() - 1].iter().product();
            Some((w.shape[..w.shape.len() - 2].to_vec(), taps, n))
        }
        LayerKind::Dense { w, .. } => Some((Vec::new(), w.shape[0], w.shape[1])),
        _ => None,
    }
}

/// Pre-packed weight operand, one variant per accumulator lane width.
#[derive(Clone, Debug)]
pub enum PackedB {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// Pre-widened to i64 at build time (fixed-point wide lane + affine).
    I64(Vec<i64>),
}

impl PackedB {
    fn elems(&self) -> usize {
        match self {
            PackedB::F32(v) => v.len(),
            PackedB::I32(v) => v.len(),
            PackedB::I64(v) => v.len(),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            PackedB::F32(v) => v.len() * 4,
            PackedB::I32(v) => v.len() * 4,
            PackedB::I64(v) => v.len() * 8,
        }
    }
}

/// The fused kernel tail, applied per output element inside the register
/// tile before the store — the typed replacement for the per-element
/// `emit` closures and second-pass bias/activation sweeps.
#[derive(Clone, Debug)]
pub enum Epilogue {
    /// Float engine: `v = acc + bias[f]`, then ReLU.
    BiasRelu { bias: Vec<f32>, relu: bool },
    /// Fixed-point Qm.n: `clamp(rescale(acc + bias[f], shift), width)`,
    /// then ReLU at 0. `shift.len() == 1` means a uniform per-layer
    /// shift.
    BiasShiftClamp { bias: Vec<i64>, shift: Vec<i32>, width: u32, relu: bool },
    /// Affine (TFLite semantics): gemmlowp requantization of
    /// `acc + bias[f]` (bias carries the build-time zero-point fold),
    /// then ReLU at `zp_out`.
    BiasRequant { bias: Vec<i64>, mult: Vec<i32>, shift: Vec<i32>, zp_out: i32, relu: bool },
}

impl Epilogue {
    fn bytes(&self) -> usize {
        match self {
            Epilogue::BiasRelu { bias, .. } => bias.len() * 4,
            Epilogue::BiasShiftClamp { bias, shift, .. } => bias.len() * 8 + shift.len() * 4,
            Epilogue::BiasRequant { bias, mult, shift, .. } => {
                bias.len() * 8 + (mult.len() + shift.len()) * 4
            }
        }
    }
}

/// One conv/dense node's build-time transformed weights: NR-tiled B
/// panels plus the epilogue parameters its kernel tail applies. Holds
/// copies of everything the hot path reads — after a session is built,
/// no per-inference code path touches graph weight storage again.
#[derive(Clone, Debug)]
pub struct PackedNode {
    /// Spatial kernel dims: `[k]` (1-D conv), `[kh, kw]` (2-D conv),
    /// `[]` (dense).
    pub ks: Vec<usize>,
    /// K: taps per output position (k·C, kh·kw·C, or dense inputs).
    pub taps: usize,
    /// N: filters / output units.
    pub n: usize,
    /// Padding payload for out-of-range im2col taps (`zp_in` on the
    /// affine path — cancelled by the bias fold — 0 elsewhere).
    pub pad: i32,
    pub b: PackedB,
    pub epi: Epilogue,
    /// The microkernel set this node's GEMMs run on — resolved once at
    /// build time by [`simd::detected`] (scalar / AVX2 / AVX2+FMA), and
    /// overridable per node ([`PackedNode::with_kernels`]) or per plan
    /// ([`PackedWeights::set_kernels`]) for forced-scalar baselines.
    pub kern: &'static KernelSet,
}

/// NR-tile B: for each column tile, K contiguous NR-wide rows.
pub(crate) fn pack_panels<S: Copy, T: Copy + Default>(
    w: &[S],
    k: usize,
    n: usize,
    cast: impl Fn(S) -> T,
) -> Vec<T> {
    debug_assert!(w.len() >= k * n, "weight matrix too small");
    let mut out = Vec::with_capacity(packed_cols(n) * k);
    for t in 0..n.div_ceil(NR) {
        for p in 0..k {
            for jj in 0..NR {
                let col = t * NR + jj;
                out.push(if col < n { cast(w[p * n + col]) } else { T::default() });
            }
        }
    }
    out
}

impl PackedNode {
    /// Float node: f32 panels + `BiasRelu`.
    pub fn f32_node(
        w: &[f32],
        b: &[f32],
        ks: &[usize],
        taps: usize,
        n: usize,
        relu: bool,
    ) -> PackedNode {
        PackedNode {
            ks: ks.to_vec(),
            taps,
            n,
            pad: 0,
            b: PackedB::F32(pack_panels(w, taps, n, |v| v)),
            epi: Epilogue::BiasRelu { bias: b.to_vec(), relu },
            kern: simd::detected(),
        }
    }

    /// Fixed-point Qm.n node: the lane is decided HERE, once, by the same
    /// `accum_fits_i32` guard the reference kernels use — i32 panels when
    /// the worst-case accumulator provably fits, i64 (pre-widened) else.
    pub fn fixed_node(
        qw: &QNodeWeights,
        ks: &[usize],
        taps: usize,
        n: usize,
        width: u32,
        relu: bool,
    ) -> PackedNode {
        Self::fixed_node_with_lane(qw, ks, taps, n, width, relu, None)
    }

    /// [`PackedNode::fixed_node`] with a lane decision supplied by the
    /// range verifier: `Some(true)` = proven i32-safe, `Some(false)` =
    /// proven to need the wide lane, `None` = fall back to the
    /// `accum_fits_i32` heuristic (unverified plans, legacy entry
    /// points). The exact proof admits a superset of the heuristic (see
    /// `analysis::tests::proven_lanes_refine_the_heuristic`), so verified
    /// plans route MORE nodes through the fast i32 kernel, never fewer.
    pub fn fixed_node_with_lane(
        qw: &QNodeWeights,
        ks: &[usize],
        taps: usize,
        n: usize,
        width: u32,
        relu: bool,
        i32_lane: Option<bool>,
    ) -> PackedNode {
        let b = if i32_lane.unwrap_or_else(|| accum_fits_i32(qw, taps, width)) {
            PackedB::I32(pack_panels(&qw.w, taps, n, |v| v))
        } else {
            PackedB::I64(pack_panels(&qw.w, taps, n, i64::from))
        };
        PackedNode {
            ks: ks.to_vec(),
            taps,
            n,
            pad: 0,
            b,
            epi: Epilogue::BiasShiftClamp {
                bias: qw.b_acc.clone(),
                shift: qw.shift.clone(),
                width,
                relu,
            },
            kern: simd::detected(),
        }
    }

    /// Affine node: i64 panels + `BiasRequant`, with the input zero point
    /// folded into the bias at build time (see the module docs for the
    /// bit-exactness argument) so activation panels pack raw payloads
    /// with padding payload `zp_in`.
    #[allow(clippy::too_many_arguments)]
    pub fn affine_node(
        qw: &AffineNodeWeights,
        ks: &[usize],
        taps: usize,
        n: usize,
        zp_in: i32,
        zp_out: i32,
        relu: bool,
    ) -> PackedNode {
        let mut bias = qw.b.clone();
        for (fi, be) in bias.iter_mut().enumerate() {
            let mut col_sum = 0i64;
            for p in 0..taps {
                col_sum += qw.w[p * n + fi] as i64;
            }
            *be -= zp_in as i64 * col_sum;
        }
        PackedNode {
            ks: ks.to_vec(),
            taps,
            n,
            pad: zp_in,
            b: PackedB::I64(pack_panels(&qw.w, taps, n, i64::from)),
            epi: Epilogue::BiasRequant {
                bias,
                mult: qw.mult.clone(),
                shift: qw.shift.clone(),
                zp_out,
                relu,
            },
            kern: simd::detected(),
        }
    }

    /// Replace the kernel set this node's GEMMs run on (builder-style).
    /// Used by the forced-scalar bench baseline and the f32 bit-identity
    /// pins; panels and epilogues are untouched, so results stay inside
    /// the per-lane equivalence contract (`nn::simd` module docs).
    pub fn with_kernels(mut self, kern: &'static KernelSet) -> PackedNode {
        self.kern = kern;
        self
    }

    /// Host bytes this node's packed panels + epilogue copies occupy.
    pub fn host_bytes(&self) -> usize {
        self.b.bytes() + self.epi.bytes()
    }

    /// Whether this node packed into the narrow i32 accumulator lane.
    pub fn is_i32_lane(&self) -> bool {
        matches!(self.b, PackedB::I32(_))
    }
}

/// Backend-specific scalar parameters of a packed self-attention node:
/// everything the fused lowering needs between its two batched GEMMs
/// (score requantization, softmax argument scaling, context rescale).
#[derive(Clone, Debug)]
pub enum AttnParams {
    Float,
    /// Qm.n fixed point (shifts precomputed from the calibrated internal
    /// formats; see `int_ops::attention_q_ref`).
    Fixed {
        inv_sqrt_hd_q15: i32,
        score_sh: i32,
        ctx_sh: i32,
        n_s: i32,
        n_p: i32,
        width: u32,
    },
    /// TFLite-style affine (see `affine_exec::attention_affine_ref`).
    Affine {
        zp_q: i32,
        zp_k: i32,
        zp_v: i32,
        zp_s: i32,
        zp_ctx: i32,
        s_mult: i32,
        s_shift: i32,
        c_mult: i32,
        c_shift: i32,
        sm_mult: i32,
        sm_shift: i32,
    },
}

/// One self-attention node's build-time transformed weights: the four
/// d_model x d_model projections as dense-style [`PackedNode`]s (NR-tiled
/// panels + fused epilogues landing Q/K/V/out on their calibrated
/// formats) plus the inter-GEMM scalars.
#[derive(Clone, Debug)]
pub struct PackedAttention {
    pub heads: usize,
    pub head_dim: usize,
    pub wq: PackedNode,
    pub wk: PackedNode,
    pub wv: PackedNode,
    pub wo: PackedNode,
    pub params: AttnParams,
}

impl PackedAttention {
    /// Float backend: f32 panels, bias-only epilogues.
    pub fn float(w: &AttnWeights, heads: usize, head_dim: usize) -> PackedAttention {
        let dm = heads * head_dim;
        let pn =
            |w: &TensorF, b: &TensorF| PackedNode::f32_node(&w.data, &b.data, &[], dm, dm, false);
        PackedAttention {
            heads,
            head_dim,
            wq: pn(&w.wq, &w.bq),
            wk: pn(&w.wk, &w.bk),
            wv: pn(&w.wv, &w.bv),
            wo: pn(&w.wo, &w.bo),
            params: AttnParams::Float,
        }
    }

    /// Fixed-point Qm.n backend: lanes decided per projection by the same
    /// `accum_fits_i32` guard as conv/dense; stage shifts precomputed.
    pub fn fixed(tx: &QTxWeights, heads: usize, head_dim: usize, width: u32) -> PackedAttention {
        Self::fixed_with_lanes(tx, heads, head_dim, width, None)
    }

    /// [`PackedAttention::fixed`] with per-projection (wq, wk, wv, wo)
    /// lane decisions from the range verifier; `None` = heuristic.
    pub fn fixed_with_lanes(
        tx: &QTxWeights,
        heads: usize,
        head_dim: usize,
        width: u32,
        lanes: Option<[bool; 4]>,
    ) -> PackedAttention {
        let QTxWeights::Attn { wq, wk, wv, wo, n_q, n_k, n_v, n_s, n_p, n_ctx, inv_sqrt_hd_q15 } =
            tx
        else {
            panic!("PackedAttention::fixed wants Attn params");
        };
        let dm = heads * head_dim;
        let pn = |qw: &QNodeWeights, pi: usize| {
            PackedNode::fixed_node_with_lane(qw, &[], dm, dm, width, false, lanes.map(|ls| ls[pi]))
        };
        PackedAttention {
            heads,
            head_dim,
            wq: pn(wq, 0),
            wk: pn(wk, 1),
            wv: pn(wv, 2),
            wo: pn(wo, 3),
            params: AttnParams::Fixed {
                inv_sqrt_hd_q15: *inv_sqrt_hd_q15,
                score_sh: n_q + n_k + 15 - n_s,
                ctx_sh: n_p + n_v - n_ctx,
                n_s: *n_s,
                n_p: *n_p,
                width,
            },
        }
    }

    /// Affine backend: zero points folded into the projection biases
    /// (`zp_in` = the node input's, `zp_out` = the node output's; the
    /// internal tensors' come from the `Attn` params).
    pub fn affine(
        tx: &AffineTxWeights,
        heads: usize,
        head_dim: usize,
        zp_in: i32,
        zp_out: i32,
    ) -> PackedAttention {
        let AffineTxWeights::Attn {
            wq, wk, wv, wo, q, k, v, s, ctx, s_mult, s_shift, c_mult, c_shift, sm_mult, sm_shift,
        } = tx
        else {
            panic!("PackedAttention::affine wants Attn params");
        };
        let dm = heads * head_dim;
        PackedAttention {
            heads,
            head_dim,
            wq: PackedNode::affine_node(wq, &[], dm, dm, zp_in, q.zero_point, false),
            wk: PackedNode::affine_node(wk, &[], dm, dm, zp_in, k.zero_point, false),
            wv: PackedNode::affine_node(wv, &[], dm, dm, zp_in, v.zero_point, false),
            wo: PackedNode::affine_node(wo, &[], dm, dm, ctx.zero_point, zp_out, false),
            params: AttnParams::Affine {
                zp_q: q.zero_point,
                zp_k: k.zero_point,
                zp_v: v.zero_point,
                zp_s: s.zero_point,
                zp_ctx: ctx.zero_point,
                s_mult: *s_mult,
                s_shift: *s_shift,
                c_mult: *c_mult,
                c_shift: *c_shift,
                sm_mult: *sm_mult,
                sm_shift: *sm_shift,
            },
        }
    }

    /// Packed-B elements of the four projection panels (the allocator's
    /// accounting term for this node).
    pub fn panel_elems(&self) -> usize {
        self.wq.b.elems() + self.wk.b.elems() + self.wv.b.elems() + self.wo.b.elems()
    }

    /// Host bytes of the four projections' panels + epilogue copies.
    pub fn host_bytes(&self) -> usize {
        self.wq.host_bytes() + self.wk.host_bytes() + self.wv.host_bytes() + self.wo.host_bytes()
    }
}

/// The per-plan prepacked-weight arena: one optional [`PackedNode`] per
/// graph node, built once at session-build time and shared read-only
/// (behind an `Arc` on the plan) by every fork.
#[derive(Clone, Debug)]
pub struct PackedWeights {
    nodes: Vec<Option<PackedNode>>,
    attn: Vec<Option<PackedAttention>>,
    /// The kernel set every packed node in this arena runs on
    /// (`SessionMeta::kernel` reports its name). Builders resolve it via
    /// [`simd::detected`]; [`PackedWeights::set_kernels`] re-targets the
    /// whole arena (the forced-scalar session path).
    kern: &'static KernelSet,
}

impl PackedWeights {
    /// No packing (custom backends without a packer; legacy per-call
    /// entry points). Executors fall back to the per-call GEMM path —
    /// which is the scalar blocked GEMM, hence the scalar label.
    pub fn empty(n_nodes: usize) -> PackedWeights {
        PackedWeights {
            nodes: (0..n_nodes).map(|_| None).collect(),
            attn: (0..n_nodes).map(|_| None).collect(),
            kern: simd::scalar(),
        }
    }

    /// Name of the kernel set this arena's GEMMs dispatch to
    /// (`"scalar"` / `"avx2"` / `"avx2+fma"`).
    pub fn kernel_name(&self) -> &'static str {
        self.kern.name
    }

    /// Re-target every packed node (conv/dense and all four attention
    /// projections) onto `kern`. Panels and epilogues are untouched;
    /// integer results are bit-identical by the `nn::simd` contract.
    pub fn set_kernels(&mut self, kern: &'static KernelSet) {
        self.kern = kern;
        for pn in self.nodes.iter_mut().flatten() {
            pn.kern = kern;
        }
        for pa in self.attn.iter_mut().flatten() {
            for pn in [&mut pa.wq, &mut pa.wk, &mut pa.wv, &mut pa.wo] {
                pn.kern = kern;
            }
        }
    }

    pub fn get(&self, id: usize) -> Option<&PackedNode> {
        self.nodes.get(id).and_then(|n| n.as_ref())
    }

    /// Packed self-attention weights of node `id`, when packed.
    pub fn attn(&self, id: usize) -> Option<&PackedAttention> {
        self.attn.get(id).and_then(|n| n.as_ref())
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.iter().all(|n| n.is_none()) && self.attn.iter().all(|n| n.is_none())
    }

    /// Total packed-B elements — equals `packed_b_elems(graph)` (and the
    /// allocator's `Allocation::packed_b_elems`) for every builder.
    pub fn panel_elems(&self) -> usize {
        self.nodes.iter().flatten().map(|pn| pn.b.elems()).sum::<usize>()
            + self.attn.iter().flatten().map(PackedAttention::panel_elems).sum::<usize>()
    }

    /// Host bytes of the whole arena (panels + epilogue copies).
    pub fn host_bytes(&self) -> usize {
        self.nodes.iter().flatten().map(PackedNode::host_bytes).sum::<usize>()
            + self.attn.iter().flatten().map(PackedAttention::host_bytes).sum::<usize>()
    }

    /// Pack a float graph's conv/dense/attention weights.
    pub fn for_float(graph: &Graph) -> PackedWeights {
        let epi = annotate_epilogues(graph);
        let nodes = graph
            .nodes
            .iter()
            .map(|node| {
                let relu = matches!(epi[node.id], Some(EpilogueKind::Relu));
                match &node.kind {
                    LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } => {
                        let (ks, taps, n) = node_dims(&node.kind).unwrap();
                        Some(PackedNode::f32_node(&w.data, &b.data, &ks, taps, n, relu))
                    }
                    _ => None,
                }
            })
            .collect();
        let attn = graph
            .nodes
            .iter()
            .map(|node| match &node.kind {
                LayerKind::SelfAttention { heads, head_dim, w } => {
                    Some(PackedAttention::float(w, *heads, *head_dim))
                }
                _ => None,
            })
            .collect();
        PackedWeights { nodes, attn, kern: simd::detected() }
    }

    /// Pack a fixed-point Qm.n graph's conv/dense/attention weights with
    /// the `accum_fits_i32` lane heuristic (legacy / unverified path).
    pub fn for_fixed(qg: &QuantizedGraph) -> PackedWeights {
        Self::for_fixed_facts(qg, &crate::analysis::VerifiedFacts::unverified())
    }

    /// Pack a fixed-point Qm.n graph with lane decisions taken from the
    /// range verifier's proven per-node accumulator bounds — the exact
    /// Σ|w·x| proof replaces the width-census heuristic wherever a fact
    /// exists (unproven nodes keep the heuristic). The verified session
    /// path (`SessionBuilder::try_build`) lands here.
    pub fn for_fixed_facts(
        qg: &QuantizedGraph,
        facts: &crate::analysis::VerifiedFacts,
    ) -> PackedWeights {
        let epi = annotate_epilogues(&qg.graph);
        let nodes = qg
            .graph
            .nodes
            .iter()
            .map(|node| {
                let (ks, taps, n) = node_dims(&node.kind)?;
                let relu = matches!(epi[node.id], Some(EpilogueKind::Relu));
                Some(PackedNode::fixed_node_with_lane(
                    &qg.weights[&node.id],
                    &ks,
                    taps,
                    n,
                    qg.width,
                    relu,
                    facts.lane_is_i32(node.id),
                ))
            })
            .collect();
        let attn = qg
            .graph
            .nodes
            .iter()
            .map(|node| match &node.kind {
                LayerKind::SelfAttention { heads, head_dim, .. } => {
                    Some(PackedAttention::fixed_with_lanes(
                        &qg.tx[&node.id],
                        *heads,
                        *head_dim,
                        qg.width,
                        facts.attn_lanes_i32(node.id),
                    ))
                }
                _ => None,
            })
            .collect();
        PackedWeights { nodes, attn, kern: simd::detected() }
    }

    /// Pack an affine graph's conv/dense/attention weights (zero-point
    /// folded).
    pub fn for_affine(aq: &AffineQuantizedGraph) -> PackedWeights {
        let epi = annotate_epilogues(&aq.graph);
        let nodes = aq
            .graph
            .nodes
            .iter()
            .map(|node| {
                let (ks, taps, n) = node_dims(&node.kind)?;
                let relu = matches!(epi[node.id], Some(EpilogueKind::Relu));
                let zp_in = aq.act[node.inputs[0]].zero_point;
                let zp_out = aq.act[node.id].zero_point;
                Some(PackedNode::affine_node(
                    &aq.weights[&node.id], &ks, taps, n, zp_in, zp_out, relu,
                ))
            })
            .collect();
        let attn = aq
            .graph
            .nodes
            .iter()
            .map(|node| match &node.kind {
                LayerKind::SelfAttention { heads, head_dim, .. } => Some(PackedAttention::affine(
                    &aq.tx[&node.id],
                    *heads,
                    *head_dim,
                    aq.act[node.inputs[0]].zero_point,
                    aq.act[node.id].zero_point,
                )),
                _ => None,
            })
            .collect();
        PackedWeights { nodes, attn, kern: simd::detected() }
    }
}

// ---------------------------------------------------------------------------
// Microkernel dispatch (the fused kernels themselves live in `nn::simd`:
// scalar always, AVX2/AVX2+FMA behind runtime feature detection)
// ---------------------------------------------------------------------------

/// Dispatch one integer A panel through the node's (lane, epilogue)
/// combination on the node's selected kernel set.
fn run_int_kernel(
    a: &[i32],
    pn: &PackedNode,
    m: usize,
    j0: usize,
    j1: usize,
    row0: usize,
    out: &SharedOut<i32>,
) {
    let (n, k) = (pn.n, pn.taps);
    match (&pn.b, &pn.epi) {
        (PackedB::I32(bp), Epilogue::BiasShiftClamp { bias, shift, width, relu }) => {
            (pn.kern.i32)(a, bp, m, n, k, j0, j1, bias, shift, *width, *relu, row0, out)
        }
        (PackedB::I64(bp), Epilogue::BiasShiftClamp { bias, shift, width, relu }) => {
            (pn.kern.i64_fixed)(a, bp, m, n, k, j0, j1, bias, shift, *width, *relu, row0, out)
        }
        (PackedB::I64(bp), Epilogue::BiasRequant { bias, mult, shift, zp_out, relu }) => {
            (pn.kern.i64_affine)(
                a, bp, m, n, k, j0, j1, bias, mult, shift, *zp_out, *relu, row0, out,
            )
        }
        _ => panic!("mismatched packed lane / epilogue on an integer node"),
    }
}

// ---------------------------------------------------------------------------
// Prepacked conv/dense entry points
// ---------------------------------------------------------------------------

/// Prepacked float conv1d. 1×1 stride-1 convs use the input tensor as
/// the A matrix directly (identity im2col), everything else packs per-
/// worker panels exactly as the per-call path does.
#[allow(clippy::too_many_arguments)]
pub fn conv1d_f32_packed(
    x: &[f32],
    s: usize,
    pn: &PackedNode,
    stride: usize,
    padding: Padding,
    pool: &IntraOpPool,
    scratch: &mut [Vec<f32>],
    out: &mut Vec<f32>,
) -> usize {
    let (PackedB::F32(bp), Epilogue::BiasRelu { bias, relu }) = (&pn.b, &pn.epi) else {
        panic!("float conv on a non-float packed node");
    };
    let k = pn.ks[0];
    let c = pn.taps / k;
    let (pad_lo, s_out) = gemm::conv1d_geometry(s, k, stride, padding);
    let (taps, f) = (pn.taps, pn.n);
    out.clear();
    out.resize(s_out * f, 0.0);
    let out_view = SharedOut::new(&mut out[..]);
    if k == 1 && stride == 1 {
        pool.run_partitioned(s_out, &|_tid, s0, s1| {
            (pn.kern.f32)(&x[s0 * taps..s1 * taps], bp, s1 - s0, f, taps, 0, f, bias, *relu, s0,
                &out_view);
        });
        return s_out;
    }
    let rows_cache = gemm::panel_rows(taps, s_out);
    let body = |panel: &mut [f32], row0: usize, rows: usize| {
        gemm::pack_1d_f32(x, s, c, k, stride, pad_lo, row0, rows, &mut panel[..rows * taps]);
        (pn.kern.f32)(&panel[..rows * taps], bp, rows, f, taps, 0, f, bias, *relu, row0,
            &out_view);
    };
    gemm::split_positions(pool, scratch, rows_cache * taps, rows_cache, s_out, &body);
    s_out
}

/// Prepacked float conv2d (1×1 stride-1 fast path included).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_f32_packed(
    x: &[f32],
    h: usize,
    wdt: usize,
    pn: &PackedNode,
    stride: usize,
    padding: Padding,
    pool: &IntraOpPool,
    scratch: &mut [Vec<f32>],
    out: &mut Vec<f32>,
) -> (usize, usize) {
    let (PackedB::F32(bp), Epilogue::BiasRelu { bias, relu }) = (&pn.b, &pn.epi) else {
        panic!("float conv on a non-float packed node");
    };
    let (kh, kw) = (pn.ks[0], pn.ks[1]);
    let c = pn.taps / (kh * kw);
    let ((ph, pw), (h_out, w_out)) = gemm::conv2d_geometry(h, wdt, kh, kw, stride, padding);
    let positions = h_out * w_out;
    let (taps, f) = (pn.taps, pn.n);
    out.clear();
    out.resize(positions * f, 0.0);
    let out_view = SharedOut::new(&mut out[..]);
    if kh == 1 && kw == 1 && stride == 1 {
        pool.run_partitioned(positions, &|_tid, s0, s1| {
            (pn.kern.f32)(&x[s0 * taps..s1 * taps], bp, s1 - s0, f, taps, 0, f, bias, *relu, s0,
                &out_view);
        });
        return (h_out, w_out);
    }
    let rows_cache = gemm::panel_rows(taps, positions);
    let body = |panel: &mut [f32], row0: usize, rows: usize| {
        gemm::pack_2d_f32(
            x, h, wdt, c, kh, kw, stride, ph, pw, w_out, row0, rows, &mut panel[..rows * taps],
        );
        (pn.kern.f32)(&panel[..rows * taps], bp, rows, f, taps, 0, f, bias, *relu, row0,
            &out_view);
    };
    gemm::split_positions(pool, scratch, rows_cache * taps, rows_cache, positions, &body);
    (h_out, w_out)
}

/// Prepacked float dense: the input vector IS the m = 1 A panel; the
/// filter dimension splits across the pool in NR-aligned column tiles
/// (tile-aligned by construction, matching the packed-B layout).
pub fn dense_f32_packed(x: &[f32], pn: &PackedNode, pool: &IntraOpPool, out: &mut Vec<f32>) {
    let (PackedB::F32(bp), Epilogue::BiasRelu { bias, relu }) = (&pn.b, &pn.epi) else {
        panic!("float dense on a non-float packed node");
    };
    debug_assert_eq!(x.len(), pn.taps, "dense input length");
    let (taps, n) = (pn.taps, pn.n);
    out.clear();
    out.resize(n, 0.0);
    let out_view = SharedOut::new(&mut out[..]);
    gemm::split_col_tiles(pool, n, &|j0, j1| {
        (pn.kern.f32)(x, bp, 1, n, taps, j0, j1, bias, *relu, 0, &out_view);
    });
}

/// Prepacked integer conv1d (fixed-point or affine — the node's packed
/// lane + epilogue decide). Activation panels pack RAW payloads with
/// padding payload `pn.pad`; no per-call zero-point work.
#[allow(clippy::too_many_arguments)]
pub fn conv1d_int_packed(
    x: &[i32],
    s: usize,
    pn: &PackedNode,
    stride: usize,
    padding: Padding,
    pool: &IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) -> usize {
    let k = pn.ks[0];
    let c = pn.taps / k;
    let (pad_lo, s_out) = gemm::conv1d_geometry(s, k, stride, padding);
    let (taps, f) = (pn.taps, pn.n);
    out.clear();
    out.resize(s_out * f, 0);
    let out_view = SharedOut::new(&mut out[..]);
    if k == 1 && stride == 1 {
        pool.run_partitioned(s_out, &|_tid, s0, s1| {
            run_int_kernel(&x[s0 * taps..s1 * taps], pn, s1 - s0, 0, f, s0, &out_view);
        });
        return s_out;
    }
    let rows_cache = gemm::panel_rows(taps, s_out);
    let body = |panel: &mut [i32], row0: usize, rows: usize| {
        gemm::pack_1d_i32(
            x, s, c, k, stride, pad_lo, row0, rows, 0, pn.pad, &mut panel[..rows * taps],
        );
        run_int_kernel(&panel[..rows * taps], pn, rows, 0, f, row0, &out_view);
    };
    gemm::split_positions(pool, scratch, rows_cache * taps, rows_cache, s_out, &body);
    s_out
}

/// Prepacked integer conv2d (fixed-point or affine).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_int_packed(
    x: &[i32],
    h: usize,
    wdt: usize,
    pn: &PackedNode,
    stride: usize,
    padding: Padding,
    pool: &IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) -> (usize, usize) {
    let (kh, kw) = (pn.ks[0], pn.ks[1]);
    let c = pn.taps / (kh * kw);
    let ((ph, pw), (h_out, w_out)) = gemm::conv2d_geometry(h, wdt, kh, kw, stride, padding);
    let positions = h_out * w_out;
    let (taps, f) = (pn.taps, pn.n);
    out.clear();
    out.resize(positions * f, 0);
    let out_view = SharedOut::new(&mut out[..]);
    if kh == 1 && kw == 1 && stride == 1 {
        pool.run_partitioned(positions, &|_tid, s0, s1| {
            run_int_kernel(&x[s0 * taps..s1 * taps], pn, s1 - s0, 0, f, s0, &out_view);
        });
        return (h_out, w_out);
    }
    let rows_cache = gemm::panel_rows(taps, positions);
    let body = |panel: &mut [i32], row0: usize, rows: usize| {
        gemm::pack_2d_i32(
            x, h, wdt, c, kh, kw, stride, ph, pw, w_out, row0, rows, 0, pn.pad,
            &mut panel[..rows * taps],
        );
        run_int_kernel(&panel[..rows * taps], pn, rows, 0, f, row0, &out_view);
    };
    gemm::split_positions(pool, scratch, rows_cache * taps, rows_cache, positions, &body);
    (h_out, w_out)
}

/// Prepacked integer dense (fixed-point or affine). The affine flavor
/// consumes the RAW input directly — the per-call `x − zp` staging pass
/// is gone, folded into the packed bias at build time.
pub fn dense_int_packed(x: &[i32], pn: &PackedNode, pool: &IntraOpPool, out: &mut Vec<i32>) {
    debug_assert_eq!(x.len(), pn.taps, "dense input length");
    let n = pn.n;
    out.clear();
    out.resize(n, 0);
    let out_view = SharedOut::new(&mut out[..]);
    gemm::split_col_tiles(pool, n, &|j0, j1| {
        run_int_kernel(x, pn, 1, j0, j1, 0, &out_view);
    });
}

/// Prepacked float dense over a batch-folded micro-batch: the `batch`
/// examples (example-major rows of `xs`) stack into the M dimension of
/// ONE GEMM against the same packed B, filling the 4×8 register tiles
/// that m = 1 per-example calls leave mostly empty. Work splits across
/// the pool in MR-row × NR-column register-tile units, each owned by
/// exactly one worker (disjoint output rectangles). Per output element
/// the kernel runs the identical k-major accumulation + epilogue the
/// m = 1 call runs — results are BIT-identical to looping
/// [`dense_f32_packed`] per example, at any batch, tiling or thread
/// count (DESIGN.md §11).
pub fn dense_f32_batched(
    xs: &[f32],
    batch: usize,
    pn: &PackedNode,
    pool: &IntraOpPool,
    out: &mut Vec<f32>,
) {
    let (PackedB::F32(bp), Epilogue::BiasRelu { bias, relu }) = (&pn.b, &pn.epi) else {
        panic!("float dense on a non-float packed node");
    };
    debug_assert_eq!(xs.len(), batch * pn.taps, "batched dense input length");
    let (taps, n) = (pn.taps, pn.n);
    out.clear();
    out.resize(batch * n, 0.0);
    let out_view = SharedOut::new(&mut out[..]);
    let col_tiles = n.div_ceil(NR);
    let units = batch.div_ceil(MR) * col_tiles;
    pool.run_partitioned(units, &|_tid, u0, u1| {
        for u in u0..u1 {
            let (mi0, j0) = ((u / col_tiles) * MR, (u % col_tiles) * NR);
            let rows = MR.min(batch - mi0);
            (pn.kern.f32)(
                &xs[mi0 * taps..], bp, rows, n, taps, j0, (j0 + NR).min(n), bias, *relu,
                mi0, &out_view,
            );
        }
    });
}

/// Integer twin of [`dense_f32_batched`] (fixed-point or affine): one
/// GEMM per micro-batch, bit-exact with a per-example
/// [`dense_int_packed`] loop by the same per-element argument.
pub fn dense_int_batched(
    xs: &[i32],
    batch: usize,
    pn: &PackedNode,
    pool: &IntraOpPool,
    out: &mut Vec<i32>,
) {
    debug_assert_eq!(xs.len(), batch * pn.taps, "batched dense input length");
    let (taps, n) = (pn.taps, pn.n);
    out.clear();
    out.resize(batch * n, 0);
    let out_view = SharedOut::new(&mut out[..]);
    let col_tiles = n.div_ceil(NR);
    let units = batch.div_ceil(MR) * col_tiles;
    pool.run_partitioned(units, &|_tid, u0, u1| {
        for u in u0..u1 {
            let (mi0, j0) = ((u / col_tiles) * MR, (u % col_tiles) * NR);
            let rows = MR.min(batch - mi0);
            run_int_kernel(&xs[mi0 * taps..], pn, rows, j0, (j0 + NR).min(n), mi0, &out_view);
        }
    });
}

// ---------------------------------------------------------------------------
// Prepacked self-attention (two batched GEMMs around a row softmax)
// ---------------------------------------------------------------------------

/// Scratch elements one self-attention node needs in slab 0 of the
/// per-thread scratch: Q/K/V/context staging (4·S·D), the per-head
/// Q_h / K_hᵀ / V_h operands (3·S·hd; V_h doubles as the softmax temp
/// row), and one head's score matrix (S·S). `gemm::scratch_elems`
/// charges this per graph, so the Session arena preallocates it.
pub fn attn_scratch_elems(seq: usize, dm: usize, hd: usize) -> usize {
    4 * seq * dm + 3 * seq * hd + seq * seq
}

/// Carve the attention workspace out of one scratch slab. Returns
/// (q, k, v, ctx, qh, kt, vh, scores).
#[allow(clippy::type_complexity)]
fn carve<T: Copy + Default>(
    ws: &mut Vec<T>,
    seq: usize,
    dm: usize,
    hd: usize,
) -> (&mut [T], &mut [T], &mut [T], &mut [T], &mut [T], &mut [T], &mut [T], &mut [T]) {
    ws.clear();
    ws.resize(attn_scratch_elems(seq, dm, hd), T::default());
    let (q, rest) = ws.split_at_mut(seq * dm);
    let (k, rest) = rest.split_at_mut(seq * dm);
    let (v, rest) = rest.split_at_mut(seq * dm);
    let (ctx, rest) = rest.split_at_mut(seq * dm);
    let (qh, rest) = rest.split_at_mut(seq * hd);
    let (kt, rest) = rest.split_at_mut(hd * seq);
    let (vh, scores) = rest.split_at_mut(seq * hd);
    debug_assert_eq!(scores.len(), seq * seq);
    (q, k, v, ctx, qh, kt, vh, scores)
}

fn f32_parts(pn: &PackedNode) -> (&[f32], &[f32]) {
    let (PackedB::F32(bp), Epilogue::BiasRelu { bias, .. }) = (&pn.b, &pn.epi) else {
        panic!("float attention on a non-float packed projection");
    };
    (bp, bias)
}

/// Prepacked float self-attention: x (S, D) -> out (S, D). The four
/// projections run as m = S fused GEMMs over the packed panels (rows
/// partitioned across the pool); scores = Q_h·K_hᵀ / sqrt(hd) and
/// ctx_h = P·V_h are per-head batched GEMMs through the blocked f32
/// microkernel. Per-element accumulation stays k-major throughout, so
/// results are thread-count invariant and stay inside the session's
/// 1e-4 fused-reorder budget vs `float_ops::self_attention_ref`.
#[allow(clippy::too_many_arguments)]
pub fn attention_f32_packed(
    x: &[f32],
    seq: usize,
    dm: usize,
    heads: usize,
    hd: usize,
    pa: &PackedAttention,
    pool: &IntraOpPool,
    scratch: &mut [Vec<f32>],
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(heads * hd, dm, "head geometry");
    out.clear();
    out.resize(seq * dm, 0.0);
    let (q, k, v, ctx, qh, kt, vh, scores) = carve(&mut scratch[0], seq, dm, hd);
    {
        let mut proj = |pn: &PackedNode, dst: &mut [f32]| {
            let (bp, bias) = f32_parts(pn);
            let ov = SharedOut::new(dst);
            pool.run_partitioned(seq, &|_tid, s0, s1| {
                (pn.kern.f32)(
                    &x[s0 * dm..s1 * dm], bp, s1 - s0, dm, dm, 0, dm, bias, false, s0, &ov,
                );
            });
        };
        proj(&pa.wq, q);
        proj(&pa.wk, k);
        proj(&pa.wv, v);
    }
    let scale = 1.0 / (hd as f32).sqrt();
    for h in 0..heads {
        let off = h * hd;
        for i in 0..seq {
            qh[i * hd..(i + 1) * hd].copy_from_slice(&q[i * dm + off..i * dm + off + hd]);
        }
        for j in 0..seq {
            for t in 0..hd {
                kt[t * seq + j] = k[j * dm + off + t];
            }
        }
        gemm::gemm_f32(qh, kt, seq, seq, hd, |i, j, acc| scores[i * seq + j] = acc * scale);
        // Stable row softmax in place (V_h staging doubles as the temp).
        for i in 0..seq {
            let row = &mut scores[i * seq..(i + 1) * seq];
            let tmp = &mut vh[..seq];
            tmp.copy_from_slice(row);
            let m = tmp.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let mut sum = 0.0f32;
            for (e, &sv) in row.iter_mut().zip(tmp.iter()) {
                let ev = (sv - m).exp();
                *e = ev;
                sum += ev;
            }
            for e in row.iter_mut() {
                *e /= sum;
            }
        }
        for j in 0..seq {
            vh[j * hd..(j + 1) * hd].copy_from_slice(&v[j * dm + off..j * dm + off + hd]);
        }
        let scores = &*scores;
        gemm::gemm_f32(scores, vh, seq, hd, seq, |i, t, acc| ctx[i * dm + off + t] = acc);
    }
    let ctx = &*ctx;
    let (bp, bias) = f32_parts(&pa.wo);
    let ov = SharedOut::new(&mut out[..]);
    pool.run_partitioned(seq, &|_tid, s0, s1| {
        (pa.wo.kern.f32)(
            &ctx[s0 * dm..s1 * dm], bp, s1 - s0, dm, dm, 0, dm, bias, false, s0, &ov,
        );
    });
}

/// Prepacked integer self-attention (fixed-point Qm.n or affine — the
/// node's [`AttnParams`] decide). BIT-EXACT against the reference
/// kernels (`int_ops::attention_q_ref` / `affine_exec::attention_affine_ref`)
/// at every thread count: integer accumulation is exact in i64, so the
/// blocked GEMM reaches the same accumulator for every output element,
/// and the requantization points apply the identical scalar epilogues.
#[allow(clippy::too_many_arguments)]
pub fn attention_int_packed(
    x: &[i32],
    seq: usize,
    dm: usize,
    heads: usize,
    hd: usize,
    pa: &PackedAttention,
    pool: &IntraOpPool,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) {
    debug_assert_eq!(heads * hd, dm, "head geometry");
    out.clear();
    out.resize(seq * dm, 0);
    let (q, k, v, ctx, qh, kt, vh, scores) = carve(&mut scratch[0], seq, dm, hd);
    {
        let mut proj = |pn: &PackedNode, dst: &mut [i32]| {
            let ov = SharedOut::new(dst);
            pool.run_partitioned(seq, &|_tid, s0, s1| {
                run_int_kernel(&x[s0 * dm..s1 * dm], pn, s1 - s0, 0, dm, s0, &ov);
            });
        };
        proj(&pa.wq, q);
        proj(&pa.wk, k);
        proj(&pa.wv, v);
    }
    // The affine flavor stages zero-point-shifted operands for both
    // batched GEMMs (probabilities shift by their fixed zp of -128); the
    // fixed flavor stages raw payloads.
    let (sub_q, sub_k, sub_v) = match &pa.params {
        AttnParams::Fixed { .. } => (0, 0, 0),
        AttnParams::Affine { zp_q, zp_k, zp_v, .. } => (*zp_q, *zp_k, *zp_v),
        AttnParams::Float => panic!("integer attention on float packed weights"),
    };
    for h in 0..heads {
        let off = h * hd;
        for i in 0..seq {
            for t in 0..hd {
                qh[i * hd + t] = q[i * dm + off + t] - sub_q;
            }
        }
        for j in 0..seq {
            for t in 0..hd {
                kt[t * seq + j] = k[j * dm + off + t] - sub_k;
            }
        }
        match &pa.params {
            AttnParams::Fixed { inv_sqrt_hd_q15, score_sh, width, .. } => {
                gemm::gemm_i64(qh, kt, seq, seq, hd, |i, j, acc| {
                    scores[i * seq + j] =
                        clamp_to(rescale(acc * *inv_sqrt_hd_q15 as i64, *score_sh), *width);
                });
            }
            AttnParams::Affine { s_mult, s_shift, zp_s, .. } => {
                gemm::gemm_i64(qh, kt, seq, seq, hd, |i, j, acc| {
                    scores[i * seq + j] = requantize(acc as i32, *s_mult, *s_shift, *zp_s);
                });
            }
            AttnParams::Float => unreachable!(),
        }
        // Row softmax in place (V_h staging doubles as the temp row). The
        // affine branch immediately re-stages probabilities as p - zp_p
        // (zp_p = -128) for the P·V GEMM.
        for i in 0..seq {
            let row = &mut scores[i * seq..(i + 1) * seq];
            let tmp = &mut vh[..seq];
            tmp.copy_from_slice(row);
            match &pa.params {
                AttnParams::Fixed { n_s, n_p, width, .. } => {
                    softmax_q_row(tmp, *n_s, *n_p, *width, row);
                }
                AttnParams::Affine { sm_mult, sm_shift, .. } => {
                    softmax_affine_row(tmp, *sm_mult, *sm_shift, row);
                    for e in row.iter_mut() {
                        *e += 128;
                    }
                }
                AttnParams::Float => unreachable!(),
            }
        }
        for j in 0..seq {
            for t in 0..hd {
                vh[j * hd + t] = v[j * dm + off + t] - sub_v;
            }
        }
        let scores = &*scores;
        match &pa.params {
            AttnParams::Fixed { ctx_sh, width, .. } => {
                gemm::gemm_i64(scores, vh, seq, hd, seq, |i, t, acc| {
                    ctx[i * dm + off + t] = clamp_to(rescale(acc, *ctx_sh), *width);
                });
            }
            AttnParams::Affine { c_mult, c_shift, zp_ctx, .. } => {
                gemm::gemm_i64(scores, vh, seq, hd, seq, |i, t, acc| {
                    ctx[i * dm + off + t] = requantize(acc as i32, *c_mult, *c_shift, *zp_ctx);
                });
            }
            AttnParams::Float => unreachable!(),
        }
    }
    let ctx = &*ctx;
    let ov = SharedOut::new(&mut out[..]);
    pool.run_partitioned(seq, &|_tid, s0, s1| {
        run_int_kernel(&ctx[s0 * dm..s1 * dm], &pa.wo, s1 - s0, 0, dm, s0, &ov);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    // One shared copy of the admission-boundary straddle generators —
    // the per-call (nn::gemm) and prepacked suites must pin the SAME
    // boundary, so the generator lives in gemm::testgen.
    use crate::nn::gemm::testgen::{random_affine_weights, random_qw};
    use crate::nn::{affine_exec, int_ops};
    use crate::prop_assert;
    use crate::util::check::property;

    fn slabs(n: usize) -> Vec<Vec<i32>> {
        vec![Vec::new(); n]
    }

    #[test]
    fn pack_panels_tiles_and_zero_fills_tail() {
        // B = 2×10 row-major; NR = 8 → two tiles of 2×8 each.
        let b: Vec<i32> = (0..20).collect();
        let packed = pack_panels(&b, 2, 10, |v| v);
        assert_eq!(packed.len(), packed_cols(10) * 2);
        // Tile 0: rows [0..8] and [10..18].
        assert_eq!(&packed[0..8], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(&packed[8..16], &[10, 11, 12, 13, 14, 15, 16, 17]);
        // Tile 1: columns 8..10 then zero fill.
        assert_eq!(&packed[16..24], &[8, 9, 0, 0, 0, 0, 0, 0]);
        assert_eq!(&packed[24..32], &[18, 19, 0, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn fixed_conv1d_packed_bit_exact_vs_ref_across_admission_and_threads() {
        let pools = [IntraOpPool::serial(), IntraOpPool::new(2), IntraOpPool::new(4)];
        property(80, |g| {
            let width = *g.pick(&[8u32, 16]);
            let k = g.usize_in(1, 5);
            let c = g.usize_in(1, 6);
            let f = g.usize_in(1, 12);
            let s = g.usize_in(k, 48);
            let stride = g.usize_in(1, 2);
            let relu = g.bool();
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let qw = random_qw(g, k * c, f, width, width == 8);
            let x: Vec<i32> = {
                let lim = (1i32 << (width - 1)) - 1;
                (0..s * c).map(|_| g.i32_in(-lim - 1, lim)).collect()
            };
            let mut want = Vec::new();
            int_ops::conv1d_q_ref(&x, s, c, &qw, k, f, stride, padding, relu, width, &mut want);
            let pn = PackedNode::fixed_node(&qw, &[k], k * c, f, width, relu);
            for pool in &pools {
                let mut scratch = slabs(pool.threads());
                let mut got = Vec::new();
                conv1d_int_packed(&x, s, &pn, stride, padding, pool, &mut scratch, &mut got);
                prop_assert!(
                    want == got,
                    "fixed conv1d packed diverged at t={}: width={width} k={k} c={c} f={f} s={s}",
                    pool.threads()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn fixed_conv2d_packed_bit_exact_vs_ref() {
        let pools = [IntraOpPool::serial(), IntraOpPool::new(4)];
        property(50, |g| {
            let width = *g.pick(&[8u32, 16]);
            let kh = g.usize_in(1, 3);
            let kw = g.usize_in(1, 3);
            let c = g.usize_in(1, 4);
            let f = g.usize_in(1, 9);
            let h = g.usize_in(kh, 12);
            let wdt = g.usize_in(kw, 12);
            let stride = g.usize_in(1, 2);
            let relu = g.bool();
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let qw = random_qw(g, kh * kw * c, f, width, width == 8);
            let x: Vec<i32> = {
                let lim = (1i32 << (width - 1)) - 1;
                (0..h * wdt * c).map(|_| g.i32_in(-lim - 1, lim)).collect()
            };
            let mut want = Vec::new();
            int_ops::conv2d_q_ref(
                &x, h, wdt, c, &qw, kh, kw, f, stride, padding, relu, width, &mut want,
            );
            let pn = PackedNode::fixed_node(&qw, &[kh, kw], kh * kw * c, f, width, relu);
            for pool in &pools {
                let mut scratch = slabs(pool.threads());
                let mut got = Vec::new();
                conv2d_int_packed(&x, h, wdt, &pn, stride, padding, pool, &mut scratch, &mut got);
                prop_assert!(
                    want == got,
                    "fixed conv2d packed diverged at t={}: kh={kh} kw={kw} c={c} f={f}",
                    pool.threads()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn fixed_dense_packed_bit_exact_vs_ref() {
        let pools = [IntraOpPool::serial(), IntraOpPool::new(4)];
        property(80, |g| {
            let width = *g.pick(&[8u32, 16]);
            let i = g.usize_in(1, 96);
            let o = g.usize_in(1, 40);
            let relu = g.bool();
            let qw = random_qw(g, i, o, width, width == 8);
            let lim = (1i32 << (width - 1)) - 1;
            let x: Vec<i32> = (0..i).map(|_| g.i32_in(-lim - 1, lim)).collect();
            let mut want = Vec::new();
            int_ops::dense_q_ref(&x, &qw, o, relu, width, &mut want);
            let pn = PackedNode::fixed_node(&qw, &[], i, o, width, relu);
            for pool in &pools {
                let mut got = Vec::new();
                dense_int_packed(&x, &pn, pool, &mut got);
                prop_assert!(
                    want == got,
                    "fixed dense packed diverged at i={i} o={o} t={}",
                    pool.threads()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn affine_conv_packed_bit_exact_vs_ref_zero_point_fold() {
        // The critical property of the build-time fold: raw-payload
        // panels with padding payload zp_in, plus b − zp·Σw, must
        // reproduce the reference's (x − zp)·w sums exactly — SAME and
        // VALID, 1-D and 2-D, with and without fused ReLU.
        let pools = [IntraOpPool::serial(), IntraOpPool::new(2), IntraOpPool::new(4)];
        property(60, |g| {
            let dims = g.usize_in(1, 2);
            let relu = g.bool();
            let stride = g.usize_in(1, 2);
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let zp_in = g.i32_in(-128, 127);
            let zp_out = g.i32_in(-128, 127);
            let (ish, wshape): (Vec<usize>, Vec<usize>) = if dims == 1 {
                let (k, c, f) = (g.usize_in(1, 5), g.usize_in(1, 4), g.usize_in(1, 8));
                let s = g.usize_in(k, 24);
                (vec![s, c], vec![k, c, f])
            } else {
                let (kh, kw, c, f) =
                    (g.usize_in(1, 3), g.usize_in(1, 3), g.usize_in(1, 3), g.usize_in(1, 6));
                let h = g.usize_in(kh, 10);
                let wd = g.usize_in(kw, 10);
                (vec![h, wd, c], vec![kh, kw, c, f])
            };
            let taps: usize = wshape[..wshape.len() - 1].iter().product();
            let f = *wshape.last().unwrap();
            let qw = random_affine_weights(g, taps, f);
            let n_in: usize = ish.iter().product();
            let x: Vec<i32> = (0..n_in).map(|_| g.i32_in(-128, 127)).collect();
            let mut want = Vec::new();
            affine_exec::conv_affine_ref(
                &x, &ish, &wshape, &qw, zp_in, zp_out, stride, padding, relu, dims, &mut want,
            );
            let ks = &wshape[..wshape.len() - 2];
            let pn = PackedNode::affine_node(&qw, ks, taps, f, zp_in, zp_out, relu);
            for pool in &pools {
                let mut scratch = slabs(pool.threads());
                let mut got = Vec::new();
                if dims == 1 {
                    conv1d_int_packed(
                        &x, ish[0], &pn, stride, padding, pool, &mut scratch, &mut got,
                    );
                } else {
                    conv2d_int_packed(
                        &x, ish[0], ish[1], &pn, stride, padding, pool, &mut scratch, &mut got,
                    );
                }
                prop_assert!(
                    want == got,
                    "affine conv packed diverged (dims={dims}, t={}, zp_in={zp_in})",
                    pool.threads()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn affine_dense_packed_bit_exact_without_staging() {
        let pools = [IntraOpPool::serial(), IntraOpPool::new(4)];
        property(60, |g| {
            let i = g.usize_in(1, 160);
            let o = g.usize_in(1, 24);
            let zp_in = g.i32_in(-128, 127);
            let zp_out = g.i32_in(-128, 127);
            let relu = g.bool();
            let qw = random_affine_weights(g, i, o);
            let x: Vec<i32> = (0..i).map(|_| g.i32_in(-128, 127)).collect();
            let mut want = Vec::new();
            affine_exec::dense_affine_ref(&x, &qw, zp_in, zp_out, o, relu, &mut want);
            let pn = PackedNode::affine_node(&qw, &[], i, o, zp_in, zp_out, relu);
            for pool in &pools {
                let mut got = Vec::new();
                dense_int_packed(&x, &pn, pool, &mut got);
                prop_assert!(want == got, "affine dense packed diverged at i={i} o={o}");
            }
            Ok(())
        });
    }

    #[test]
    fn one_by_one_conv_identity_fast_path_bit_exact() {
        // k = 1, stride = 1: the A matrix is the input tensor itself.
        // Fixed and affine flavors must match the refs bit-for-bit; the
        // 2-D shape exercises the (kh, kw) = (1, 1) route.
        let pools = [IntraOpPool::serial(), IntraOpPool::new(3)];
        property(40, |g| {
            let c = g.usize_in(1, 8);
            let f = g.usize_in(1, 12);
            let s = g.usize_in(1, 40);
            let relu = g.bool();
            let width = *g.pick(&[8u32, 16]);
            let qw = random_qw(g, c, f, width, width == 8);
            let lim = (1i32 << (width - 1)) - 1;
            let x: Vec<i32> = (0..s * c).map(|_| g.i32_in(-lim - 1, lim)).collect();
            let mut want = Vec::new();
            int_ops::conv1d_q_ref(&x, s, c, &qw, 1, f, 1, Padding::Same, relu, width, &mut want);
            let pn = PackedNode::fixed_node(&qw, &[1], c, f, width, relu);
            for pool in &pools {
                let mut scratch = slabs(pool.threads());
                let mut got = Vec::new();
                conv1d_int_packed(&x, s, &pn, 1, Padding::Same, pool, &mut scratch, &mut got);
                prop_assert!(want == got, "1x1 fixed fast path diverged (t={})", pool.threads());
                // Scratch must be untouched: no im2col on the fast path.
                prop_assert!(scratch.iter().all(Vec::is_empty), "1x1 fast path used scratch");
            }

            // Affine 2-D 1×1 over an (h, w, c) map.
            let (h, wd) = (g.usize_in(1, 8), g.usize_in(1, 8));
            let aqw = random_affine_weights(g, c, f);
            let (zp_in, zp_out) = (g.i32_in(-128, 127), g.i32_in(-128, 127));
            let ax: Vec<i32> = (0..h * wd * c).map(|_| g.i32_in(-128, 127)).collect();
            let mut awant = Vec::new();
            affine_exec::conv_affine_ref(
                &ax, &[h, wd, c], &[1, 1, c, f], &aqw, zp_in, zp_out, 1, Padding::Valid, relu, 2,
                &mut awant,
            );
            let apn = PackedNode::affine_node(&aqw, &[1, 1], c, f, zp_in, zp_out, relu);
            for pool in &pools {
                let mut scratch = slabs(pool.threads());
                let mut agot = Vec::new();
                conv2d_int_packed(&ax, h, wd, &apn, 1, Padding::Valid, pool, &mut scratch,
                    &mut agot);
                prop_assert!(awant == agot, "1x1 affine fast path diverged");
            }
            Ok(())
        });
    }

    #[test]
    fn f32_packed_bit_identical_to_per_call_gemm_lowering() {
        // The f32 fused kernel preserves the per-call path's per-element
        // operation sequence exactly (only B's storage layout changed),
        // so packed results equal the PR-3/4 lowering BIT-FOR-BIT — which
        // keeps float sessions inside the existing 1e-4 fused-reorder
        // budget vs the naive reference by transitivity.
        let pools = [IntraOpPool::serial(), IntraOpPool::new(4)];
        property(40, |g| {
            let k = g.usize_in(1, 5);
            let c = g.usize_in(1, 6);
            let f = g.usize_in(1, 10);
            let s = g.usize_in(k, 40);
            let stride = g.usize_in(1, 2);
            let relu = g.bool();
            let padding = *g.pick(&[Padding::Same, Padding::Valid]);
            let w: Vec<f32> = g.vec_normal(k * c * f, 0.5);
            let b: Vec<f32> = g.vec_normal(f, 0.1);
            let x: Vec<f32> = g.vec_normal(s * c, 1.0);
            let serial = IntraOpPool::serial();
            let mut scratch1 = vec![Vec::new()];
            let mut want = Vec::new();
            gemm::conv1d_gemm(
                &x, s, c, &w, k, f, &b, stride, padding, relu, &serial, &mut scratch1, &mut want,
            );
            // Tiny shapes route the per-call entry to the reference
            // kernel, so bit-equality is asserted only when the per-call
            // entry took the blocked path; otherwise ULP-bounded. Forced
            // onto the scalar kernel set: bit-identity with the per-call
            // scalar GEMM is a SCALAR-kernel contract (the AVX2+FMA f32
            // kernel rounds differently; its own pin lives in nn::simd).
            let pn = PackedNode::f32_node(&w, &b, &[k], k * c, f, relu)
                .with_kernels(simd::scalar());
            for pool in &pools {
                let mut scratch = vec![Vec::new(); pool.threads()];
                let mut got = Vec::new();
                conv1d_f32_packed(&x, s, &pn, stride, padding, pool, &mut scratch, &mut got);
                let m: usize = got.len() / f;
                if m * f * k * c >= gemm::GEMM_MIN_MACCS {
                    prop_assert!(
                        want == got,
                        "f32 packed != per-call gemm bits (t={})",
                        pool.threads()
                    );
                } else {
                    // Reference fallback on the per-call side: ULP check.
                    for (idx, (&a, &bv)) in want.iter().zip(&got).enumerate() {
                        let tol = 1e-4f32.max(a.abs() * 1e-4);
                        prop_assert!((a - bv).abs() <= tol, "f32 packed off at {idx}: {a} vs {bv}");
                    }
                }
            }

            // Dense: same contract.
            let i = g.usize_in(1, 64);
            let o = g.usize_in(1, 24);
            let dw: Vec<f32> = g.vec_normal(i * o, 0.5);
            let db: Vec<f32> = g.vec_normal(o, 0.1);
            let dx: Vec<f32> = g.vec_normal(i, 1.0);
            let mut dwant = Vec::new();
            gemm::dense_gemm(&dx, &dw, &db, o, relu, &serial, &mut dwant);
            let dpn =
                PackedNode::f32_node(&dw, &db, &[], i, o, relu).with_kernels(simd::scalar());
            let mut dgot = Vec::new();
            dense_f32_packed(&dx, &dpn, &serial, &mut dgot);
            if i * o >= gemm::GEMM_MIN_MACCS {
                prop_assert!(dwant == dgot, "f32 dense packed != per-call gemm bits");
            } else {
                for (idx, (&a, &bv)) in dwant.iter().zip(&dgot).enumerate() {
                    let tol = 1e-4f32.max(a.abs() * 1e-4);
                    prop_assert!((a - bv).abs() <= tol, "f32 dense off at {idx}: {a} vs {bv}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn builders_cover_every_weighted_node_and_match_allocator_accounting() {
        use crate::graph::build::resnet_v1_6_shapes;
        use crate::graph::deploy_pipeline;
        use crate::nn::float_exec::ActStats;
        use crate::quant::{quantize, quantize_affine, QuantSpec};
        use crate::util::prng::Pcg32;

        let mut g = resnet_v1_6_shapes("p", 1, &[64, 6], 5, 8);
        let mut rng = Pcg32::seeded(7);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
        }
        let g = deploy_pipeline(&g);
        let mut stats = ActStats::new(g.nodes.len());
        for _ in 0..3 {
            let x: Vec<f32> = (0..64 * 6).map(|_| rng.normal()).collect();
            crate::nn::float_exec::run(&g, &x, Some(&mut stats));
        }
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let aq = quantize_affine(&g, &stats);

        let want_elems = packed_b_elems(&g);
        for pw in [
            PackedWeights::for_float(&g),
            PackedWeights::for_fixed(&qg),
            PackedWeights::for_affine(&aq),
        ] {
            assert!(!pw.is_empty());
            assert_eq!(pw.panel_elems(), want_elems, "builder/allocator accounting mismatch");
            assert!(pw.host_bytes() > 0);
            for n in &g.nodes {
                let weighted =
                    matches!(n.kind, LayerKind::Conv { .. } | LayerKind::Dense { .. });
                assert_eq!(pw.get(n.id).is_some(), weighted, "node {}", n.name);
            }
        }
        // Empty arena: no nodes, no bytes.
        let empty = PackedWeights::empty(g.nodes.len());
        assert!(empty.is_empty());
        assert_eq!(empty.panel_elems(), 0);
        assert_eq!(empty.host_bytes(), 0);
    }

    #[test]
    fn fixed_attention_packed_bit_exact_vs_ref() {
        // Odd sequence lengths and head_dims not divisible by NR are in
        // range on purpose: the packed GEMM's tile tails and the staging
        // copies must agree with the naive reference bit-for-bit at every
        // thread count.
        let pools = [IntraOpPool::serial(), IntraOpPool::new(2), IntraOpPool::new(4)];
        property(40, |g| {
            let width = *g.pick(&[8u32, 16]);
            let heads = g.usize_in(1, 3);
            let hd = g.usize_in(1, 10);
            let dm = heads * hd;
            let seq = g.usize_in(1, 17);
            let proj = |g: &mut crate::util::check::Gen| {
                let mut qw = random_qw(g, dm, dm, width, false);
                // Attention projections carry ONE per-layer shift (the
                // reference reads shift[0]); drop testgen's occasional
                // per-filter vector.
                qw.shift.truncate(1);
                qw
            };
            let tx = QTxWeights::Attn {
                wq: proj(g),
                wk: proj(g),
                wv: proj(g),
                wo: proj(g),
                n_q: g.usize_in(2, 9) as i32,
                n_k: g.usize_in(2, 9) as i32,
                n_v: g.usize_in(2, 9) as i32,
                n_s: g.usize_in(2, 9) as i32,
                n_p: width as i32 - 1,
                n_ctx: g.usize_in(2, 9) as i32,
                inv_sqrt_hd_q15: ((1 << 15) as f64 / (hd as f64).sqrt()).round() as i32,
            };
            let lim = (1i32 << (width - 1)) - 1;
            let x: Vec<i32> = (0..seq * dm).map(|_| g.i32_in(-lim - 1, lim)).collect();
            let mut want = Vec::new();
            int_ops::attention_q_ref(&x, seq, dm, heads, hd, &tx, width, &mut want);
            let pa = PackedAttention::fixed(&tx, heads, hd, width);
            for pool in &pools {
                let mut scratch = slabs(pool.threads());
                let mut got = Vec::new();
                attention_int_packed(&x, seq, dm, heads, hd, &pa, pool, &mut scratch, &mut got);
                prop_assert!(
                    want == got,
                    "fixed attention packed diverged: width={width} heads={heads} hd={hd} \
                     seq={seq} t={}",
                    pool.threads()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn affine_attention_packed_bit_exact_vs_ref() {
        use crate::quant::affine::{decompose, AffineParams};
        let pools = [IntraOpPool::serial(), IntraOpPool::new(4)];
        property(40, |g| {
            let heads = g.usize_in(1, 3);
            let hd = g.usize_in(1, 9);
            let dm = heads * hd;
            let seq = g.usize_in(1, 13);
            let p = |g: &mut crate::util::check::Gen| AffineParams {
                scale: 1.0,
                zero_point: g.i32_in(-128, 127),
            };
            let (s_mult, s_shift) = decompose(g.f32_in(1e-4, 0.9) as f64);
            let (c_mult, c_shift) = decompose(g.f32_in(1e-4, 0.9) as f64);
            let (sm_mult, sm_shift) = decompose(g.f32_in(1e-4, 0.9) as f64);
            let tx = AffineTxWeights::Attn {
                wq: random_affine_weights(g, dm, dm),
                wk: random_affine_weights(g, dm, dm),
                wv: random_affine_weights(g, dm, dm),
                wo: random_affine_weights(g, dm, dm),
                q: p(g),
                k: p(g),
                v: p(g),
                s: p(g),
                ctx: p(g),
                s_mult,
                s_shift,
                c_mult,
                c_shift,
                sm_mult,
                sm_shift,
            };
            let zp_in = g.i32_in(-128, 127);
            let zp_out = g.i32_in(-128, 127);
            let x: Vec<i32> = (0..seq * dm).map(|_| g.i32_in(-128, 127)).collect();
            let mut want = Vec::new();
            affine_exec::attention_affine_ref(
                &x, seq, dm, heads, hd, &tx, zp_in, zp_out, &mut want,
            );
            let pa = PackedAttention::affine(&tx, heads, hd, zp_in, zp_out);
            for pool in &pools {
                let mut scratch = slabs(pool.threads());
                let mut got = Vec::new();
                attention_int_packed(&x, seq, dm, heads, hd, &pa, pool, &mut scratch, &mut got);
                prop_assert!(
                    want == got,
                    "affine attention packed diverged: heads={heads} hd={hd} seq={seq} t={}",
                    pool.threads()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn f32_attention_packed_matches_ref_within_budget() {
        use crate::nn::float_ops::{self_attention_ref, AttnTmp};
        use crate::tensor::Tensor;
        let pools = [IntraOpPool::serial(), IntraOpPool::new(4)];
        property(30, |g| {
            let heads = g.usize_in(1, 3);
            let hd = g.usize_in(1, 9);
            let dm = heads * hd;
            let seq = g.usize_in(1, 13);
            let mat = |g: &mut crate::util::check::Gen| {
                Tensor::from_vec(&[dm, dm], g.vec_normal(dm * dm, 0.5))
            };
            let vecb =
                |g: &mut crate::util::check::Gen| Tensor::from_vec(&[dm], g.vec_normal(dm, 0.1));
            let w = AttnWeights {
                wq: mat(g),
                bq: vecb(g),
                wk: mat(g),
                bk: vecb(g),
                wv: mat(g),
                bv: vecb(g),
                wo: mat(g),
                bo: vecb(g),
            };
            let x: Vec<f32> = g.vec_normal(seq * dm, 1.0);
            let mut tmp = AttnTmp::default();
            let mut want = Vec::new();
            self_attention_ref(&x, seq, dm, heads, hd, &w, &mut tmp, &mut want);
            let pa = PackedAttention::float(&w, heads, hd);
            for pool in &pools {
                let mut scratch: Vec<Vec<f32>> = vec![Vec::new(); pool.threads()];
                let mut got = Vec::new();
                attention_f32_packed(&x, seq, dm, heads, hd, &pa, pool, &mut scratch, &mut got);
                for (idx, (&a, &b)) in want.iter().zip(&got).enumerate() {
                    let tol = 1e-4f32.max(a.abs() * 1e-4);
                    prop_assert!(
                        (a - b).abs() <= tol,
                        "f32 attention off at {idx}: {a} vs {b} (t={})",
                        pool.threads()
                    );
                }
            }
            Ok(())
        });
    }
}
