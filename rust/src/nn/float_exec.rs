//! Float32 graph executor — runs one example through a deployed `Graph`.
//!
//! Serves three roles: (a) the float32 deployment target of MicroAI, (b)
//! the calibration pass for post-training quantization (records per-node
//! activation ranges, §5.8), and (c) the semantic reference the integer
//! engines are validated against.

use crate::graph::ir::{Graph, LayerKind};

use super::float_ops as ops;
use super::gemm;

/// Range triple of one internal (non-node-output) tensor, used for the
/// attention internals that never appear as graph edges.
#[derive(Clone, Copy, Debug)]
pub struct TensorStats {
    pub max_abs: f32,
    pub min: f32,
    pub max: f32,
}

impl Default for TensorStats {
    fn default() -> Self {
        Self { max_abs: 0.0, min: f32::INFINITY, max: f32::NEG_INFINITY }
    }
}

impl TensorStats {
    pub fn record(&mut self, data: &[f32]) {
        for &x in data {
            self.max_abs = self.max_abs.max(x.abs());
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
    }

    fn merge(&mut self, other: &TensorStats) {
        self.max_abs = self.max_abs.max(other.max_abs);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Indices into an [`ActStats::attn`] entry: the Q/K/V projections, the
/// scaled pre-softmax scores, and the concatenated head context.
pub const ATTN_Q: usize = 0;
pub const ATTN_K: usize = 1;
pub const ATTN_V: usize = 2;
pub const ATTN_S: usize = 3;
pub const ATTN_CTX: usize = 4;

/// Per-node activation statistics collected during calibration (§5.8).
/// `max_abs` feeds the Qm.n scheme; `min`/`max` feed the affine
/// (TFLite-style) scheme's asymmetric ranges. `attn[id]` holds the ranges
/// of the attention-internal tensors of a `SelfAttention` node `id` —
/// those tensors are requantized inside the fused kernel, so the
/// quantizers need their ranges even though they are not node outputs.
#[derive(Clone, Debug, Default)]
pub struct ActStats {
    pub max_abs: Vec<f32>,
    pub min: Vec<f32>,
    pub max: Vec<f32>,
    pub attn: Vec<[TensorStats; 5]>,
}

impl ActStats {
    pub fn new(n_nodes: usize) -> Self {
        Self {
            max_abs: vec![0.0; n_nodes],
            min: vec![f32::INFINITY; n_nodes],
            max: vec![f32::NEG_INFINITY; n_nodes],
            attn: vec![[TensorStats::default(); 5]; n_nodes],
        }
    }

    fn record(&mut self, node: usize, data: &[f32]) {
        for &x in data {
            if x.abs() > self.max_abs[node] {
                self.max_abs[node] = x.abs();
            }
            if x < self.min[node] {
                self.min[node] = x;
            }
            if x > self.max[node] {
                self.max[node] = x;
            }
        }
    }

    fn record_attn(&mut self, node: usize, tmp: &ops::AttnTmp) {
        let s = &mut self.attn[node];
        s[ATTN_Q].record(&tmp.q);
        s[ATTN_K].record(&tmp.k);
        s[ATTN_V].record(&tmp.v);
        s[ATTN_S].record(&tmp.scores);
        s[ATTN_CTX].record(&tmp.ctx);
    }

    /// Attention-internal ranges of node `id`, tolerant of stats built
    /// before the transformer ops existed (empty `attn`).
    pub fn attn_of(&self, id: usize) -> [TensorStats; 5] {
        self.attn.get(id).copied().unwrap_or_default()
    }

    pub fn merge(&mut self, other: &ActStats) {
        for (a, &b) in self.max_abs.iter_mut().zip(&other.max_abs) {
            *a = a.max(b);
        }
        for (a, &b) in self.min.iter_mut().zip(&other.min) {
            *a = a.min(b);
        }
        for (a, &b) in self.max.iter_mut().zip(&other.max) {
            *a = a.max(b);
        }
        for (a, b) in self.attn.iter_mut().zip(&other.attn) {
            for (s, o) in a.iter_mut().zip(b) {
                s.merge(o);
            }
        }
    }
}

/// Execute `graph` on a single example (flattened input, channels-last).
/// Returns the output of the last node. If `stats` is provided, per-node
/// max-abs values are recorded (calibration mode).
///
/// Deprecated in favour of [`crate::nn::session::Session`]: this wrapper
/// re-runs the §5.7 lifetime analysis and reallocates the activation
/// pools on every call. A `Session` does both once and reuses the arena
/// across `run` calls.
pub fn run(graph: &Graph, input: &[f32], stats: Option<&mut ActStats>) -> Vec<f32> {
    let alloc = crate::allocator::allocate(graph);
    let node_elems = super::session::node_elems(graph);
    let mut pools: Vec<Vec<f32>> = vec![Vec::new(); alloc.n_pools()];
    let pool = super::parallel::IntraOpPool::serial();
    let mut scratch = vec![Vec::new()];
    let mut output = Vec::new();
    // Legacy per-call semantics: no prepacked weights, so the GEMM
    // lowering streams B from graph storage (the PR-3/4 path).
    let packed = super::packed::PackedWeights::empty(graph.nodes.len());
    run_pooled(
        graph, input, &alloc, &node_elems, &mut pools, &pool, &mut scratch, &packed, stats,
        &mut output,
    );
    output
}

/// Pooled core shared by [`run`] and the float [`crate::nn::session`]
/// backend: node outputs live in the allocator's §5.7 pools (`pools[p]`
/// holds the output of the pool's current occupant), so a reused arena
/// performs zero per-request heap allocation. `scratch` carries one
/// im2col slab per intra-op thread of `pool`. Conv/dense nodes present
/// in `packed` run the prepacked fused-epilogue kernels (`nn::packed`)
/// and never touch graph weight storage; absent nodes (legacy per-call
/// wrappers, custom backends without a packer) keep the per-call GEMM
/// lowering.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_pooled(
    graph: &Graph,
    input: &[f32],
    alloc: &crate::allocator::Allocation,
    node_elems: &[usize],
    pools: &mut [Vec<f32>],
    pool: &super::parallel::IntraOpPool,
    scratch: &mut [Vec<f32>],
    packed: &super::packed::PackedWeights,
    mut stats: Option<&mut ActStats>,
    output: &mut Vec<f32>,
) {
    assert_eq!(input.len(), graph.input_shape.iter().product::<usize>());
    for node in &graph.nodes {
        if matches!(node.kind, LayerKind::Input) {
            if let Some(stats) = stats.as_deref_mut() {
                stats.record(node.id, input);
            }
            continue;
        }
        let p = alloc.pool_of[node.id];
        if let Some(s) = alloc.inplace_with[node.id] {
            // In-place lowering: the slot already holds input `s`'s
            // payload (same class ⇒ same slot); mutate it directly.
            // Calibration already recorded `s` when it executed, so
            // overwriting its payload here cannot lose ranges.
            let mut buf = std::mem::take(&mut pools[p]);
            exec_node_inplace(node, s, 1, input, pools, &alloc.pool_of, node_elems, &mut buf);
            if let Some(stats) = stats.as_deref_mut() {
                stats.record(node.id, &buf);
            }
            pools[p] = buf;
            continue;
        }
        let mut out = std::mem::take(&mut pools[p]);
        {
            // Input slices: the graph input is the caller's buffer; every
            // other producer's output sits at the head of its pool. The
            // allocator invariant guarantees none of them share pool `p`.
            let src = |i: usize| super::session::pool_src(pools, input, &alloc.pool_of, node_elems, i);
            exec_node(graph, node, &src, packed, pool, scratch, &mut stats, &mut out);
        }
        if let Some(stats) = stats.as_deref_mut() {
            stats.record(node.id, &out);
        }
        pools[p] = out;
    }
    let out_id = graph.output_id();
    output.clear();
    let p = alloc.pool_of[out_id];
    if p == usize::MAX {
        output.extend_from_slice(input); // degenerate input-only graph
    } else {
        output.extend_from_slice(&pools[p][..node_elems[out_id]]);
    }
}

/// Batch-folded twin of [`run_pooled`] (no calibration — stats recording
/// stays per-example on [`run_pooled`]): dense layers and stride-1 1×1
/// convs fold the whole micro-batch into one packed GEMM; every other
/// layer loops per example through the shared [`exec_node`], staging one
/// example's output in `tmp`. Pools hold example-major payloads sized by
/// the arena's `max_batch` factor. See `int_exec::run_pooled_batch` for
/// the fold argument; the f32 fold is additionally BITWISE identical to
/// the per-example loop because the per-element k-major accumulation
/// order is unchanged.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_pooled_batch(
    graph: &Graph,
    inputs: &[f32],
    batch: usize,
    alloc: &crate::allocator::Allocation,
    node_elems: &[usize],
    pools: &mut [Vec<f32>],
    pool: &super::parallel::IntraOpPool,
    scratch: &mut [Vec<f32>],
    packed: &super::packed::PackedWeights,
    tmp: &mut Vec<f32>,
    output: &mut Vec<f32>,
) {
    if batch <= 1 {
        return run_pooled(
            graph, inputs, alloc, node_elems, pools, pool, scratch, packed, None, output,
        );
    }
    let ilen: usize = graph.input_shape.iter().product();
    assert_eq!(inputs.len(), batch * ilen, "ragged batch");

    for node in &graph.nodes {
        if matches!(node.kind, LayerKind::Input) {
            continue;
        }
        let p = alloc.pool_of[node.id];
        let ne = node_elems[node.id];
        if let Some(s) = alloc.inplace_with[node.id] {
            // In-place lowering over the example-major slot (flat for
            // elementwise arms, per-example rows for softmax).
            let mut buf = std::mem::take(&mut pools[p]);
            exec_node_inplace(node, s, batch, inputs, pools, &alloc.pool_of, node_elems, &mut buf);
            pools[p] = buf;
            continue;
        }
        let mut out = std::mem::take(&mut pools[p]);
        let folded = {
            // Whole-batch producer slice: example-major payloads are
            // contiguous, so a folded GEMM reads them as one A matrix.
            let whole = |i: usize| {
                let q = alloc.pool_of[i];
                if q == usize::MAX {
                    inputs
                } else {
                    &pools[q][..batch * node_elems[i]]
                }
            };
            match (&node.kind, packed.get(node.id)) {
                (LayerKind::Dense { .. }, Some(pn)) => {
                    super::packed::dense_f32_batched(
                        whole(node.inputs[0]), batch, pn, pool, &mut out,
                    );
                    true
                }
                (LayerKind::Conv { stride: 1, padding, .. }, Some(pn))
                    if pn.ks.iter().all(|&k| k == 1) =>
                {
                    // Pointwise conv: concatenating the batch along the
                    // leading spatial axis is the same computation (see
                    // int_exec::run_pooled_batch).
                    let ish = &graph.nodes[node.inputs[0]].out_shape;
                    if graph.dims == 1 {
                        super::packed::conv1d_f32_packed(
                            whole(node.inputs[0]), batch * ish[0], pn, 1, *padding, pool,
                            scratch, &mut out,
                        );
                    } else {
                        super::packed::conv2d_f32_packed(
                            whole(node.inputs[0]), batch * ish[0], ish[1], pn, 1, *padding,
                            pool, scratch, &mut out,
                        );
                    }
                    true
                }
                _ => false,
            }
        };
        if !folded {
            out.clear();
            out.resize(batch * ne, 0.0);
            for ex in 0..batch {
                {
                    let src = |i: usize| {
                        let q = alloc.pool_of[i];
                        if q == usize::MAX {
                            &inputs[ex * ilen..(ex + 1) * ilen]
                        } else {
                            let nei = node_elems[i];
                            &pools[q][ex * nei..(ex + 1) * nei]
                        }
                    };
                    exec_node(graph, node, &src, packed, pool, scratch, &mut None, tmp);
                }
                out[ex * ne..(ex + 1) * ne].copy_from_slice(tmp);
            }
        }
        pools[p] = out;
    }

    let out_id = graph.output_id();
    output.clear();
    let p = alloc.pool_of[out_id];
    if p == usize::MAX {
        output.extend_from_slice(inputs); // degenerate input-only graph
    } else {
        output.extend_from_slice(&pools[p][..batch * node_elems[out_id]]);
    }
}

/// One node's single-example compute: read producer payloads through
/// `src`, write the node's output into `out`. Shared verbatim by the
/// per-example driver ([`run_pooled`]) and the unfoldable arm of the
/// batch-folded driver ([`run_pooled_batch`]) — the batched path
/// inherits every property pinned on this code. `stats` is only ever
/// `Some` on the per-example calibration path.
#[allow(clippy::too_many_arguments)]
fn exec_node<'a>(
    graph: &Graph,
    node: &crate::graph::ir::Node,
    src: &dyn Fn(usize) -> &'a [f32],
    packed: &super::packed::PackedWeights,
    pool: &super::parallel::IntraOpPool,
    scratch: &mut [Vec<f32>],
    stats: &mut Option<&mut ActStats>,
    out: &mut Vec<f32>,
) {
    match &node.kind {
        LayerKind::Input => unreachable!(),
        LayerKind::Conv { w, b, stride, padding } => {
            // Prepacked fused path when the plan carries packed
            // weights; per-call im2col + blocked GEMM (nn::gemm)
            // otherwise. The naive loops survive as
            // float_ops::conv*_ref.
            let x = src(node.inputs[0]);
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            if let Some(pn) = packed.get(node.id) {
                if graph.dims == 1 {
                    super::packed::conv1d_f32_packed(
                        x, ish[0], pn, *stride, *padding, pool, scratch, out,
                    );
                } else {
                    super::packed::conv2d_f32_packed(
                        x, ish[0], ish[1], pn, *stride, *padding, pool, scratch, out,
                    );
                }
            } else if graph.dims == 1 {
                gemm::conv1d_gemm(
                    x, ish[0], ish[1], &w.data, w.shape[0], w.shape[2], &b.data,
                    *stride, *padding, node.fused_relu, pool, scratch, out,
                );
            } else {
                gemm::conv2d_gemm(
                    x, ish[0], ish[1], ish[2], &w.data, w.shape[0], w.shape[1],
                    w.shape[3], &b.data, *stride, *padding, node.fused_relu,
                    pool, scratch, out,
                );
            }
        }
        LayerKind::Dense { w, b } => {
            if let Some(pn) = packed.get(node.id) {
                super::packed::dense_f32_packed(src(node.inputs[0]), pn, pool, out);
            } else {
                gemm::dense_gemm(
                    src(node.inputs[0]), &w.data, &b.data, w.shape[1], node.fused_relu,
                    pool, out,
                );
            }
        }
        LayerKind::MaxPool { size } => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let c = *ish.last().unwrap();
            ops::maxpool(
                src(node.inputs[0]), &ish[..ish.len() - 1], c, *size, node.fused_relu, out,
            );
        }
        LayerKind::AvgPool { size } => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let c = *ish.last().unwrap();
            ops::avgpool(src(node.inputs[0]), &ish[..ish.len() - 1], c, *size, out);
        }
        LayerKind::GlobalAvgPool => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let c = *ish.last().unwrap();
            let positions: usize = ish[..ish.len() - 1].iter().product();
            ops::global_avgpool(src(node.inputs[0]), positions, c, out);
        }
        LayerKind::Add => {
            ops::add(src(node.inputs[0]), src(node.inputs[1]), node.fused_relu, out);
        }
        LayerKind::ReLU => {
            ops::relu(src(node.inputs[0]), out);
        }
        LayerKind::Softmax => {
            ops::softmax(src(node.inputs[0]), out);
        }
        LayerKind::ZeroPad { pad } => {
            // Materialized zero padding (only when not fused away).
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            zero_pad_into(src(node.inputs[0]), ish, pad, out);
        }
        LayerKind::BatchNorm { mean, var, gamma, beta, eps } => {
            let (w, b) = crate::graph::passes::batchnorm_affine(mean, var, gamma, beta, *eps);
            let c = *graph.nodes[node.inputs[0]].out_shape.last().unwrap();
            ops::batchnorm_affine(src(node.inputs[0]), c, &w, &b, out);
        }
        LayerKind::Flatten => {
            out.clear();
            out.extend_from_slice(src(node.inputs[0]));
        }
        LayerKind::Embedding { w } => {
            ops::embedding(src(node.inputs[0]), &w.data, w.shape[1], out);
        }
        LayerKind::LayerNorm { gamma, beta, eps } => {
            let c = *graph.nodes[node.inputs[0]].out_shape.last().unwrap();
            ops::layernorm(src(node.inputs[0]), c, gamma, beta, *eps, out);
        }
        LayerKind::SelfAttention { heads, head_dim, w } => {
            let ish = &graph.nodes[node.inputs[0]].out_shape;
            let (seq, dm) = (ish[0], ish[1]);
            // Calibration must see the attention-internal tensors,
            // which the fused packed kernel never materialises as a
            // whole; route stats runs through the reference path.
            let pa = if stats.is_some() { None } else { packed.attn(node.id) };
            if let Some(pa) = pa {
                super::packed::attention_f32_packed(
                    src(node.inputs[0]), seq, dm, *heads, *head_dim, pa, pool, scratch, out,
                );
            } else {
                // Per-call reference path; calibration rides it to
                // record the attention-internal ranges.
                let mut tmp = ops::AttnTmp::default();
                ops::self_attention_ref(
                    src(node.inputs[0]), seq, dm, *heads, *head_dim, w, &mut tmp, out,
                );
                if let Some(stats) = stats.as_deref_mut() {
                    stats.record_attn(node.id, &tmp);
                }
            }
        }
    }
}

/// In-place twin of [`exec_node`] for nodes the memory plan lowered onto
/// an input buffer (`alloc.inplace_with[id] = Some(s)`): the shared slot
/// already holds `s`'s example-major payloads, so the kernel mutates
/// `buf` directly. Only the planner's alias-safe kinds appear here
/// (checker-enforced); each arm is bit-exact against its out-of-place
/// twin (see the `float_ops` in-place kernels). `batch` folds flat where
/// the op is elementwise and loops per-example rows where it is not.
fn exec_node_inplace(
    node: &crate::graph::ir::Node,
    s: usize,
    batch: usize,
    input: &[f32],
    pools: &[Vec<f32>],
    pool_of: &[usize],
    node_elems: &[usize],
    buf: &mut Vec<f32>,
) {
    match &node.kind {
        LayerKind::Add => {
            // The other operand is proven by the checker to live in a
            // different slot, so this read never aliases `buf`.
            let o = if node.inputs[0] == s { node.inputs[1] } else { node.inputs[0] };
            let q = pool_of[o];
            let other: &[f32] =
                if q == usize::MAX { input } else { &pools[q][..batch * node_elems[o]] };
            ops::add_inplace(buf, other, node.fused_relu);
        }
        LayerKind::ReLU => ops::relu_inplace(buf),
        LayerKind::Flatten => {} // payload is already the flattened tensor
        LayerKind::Softmax => {
            let ne = node_elems[node.id];
            for row in buf.chunks_exact_mut(ne) {
                ops::softmax_inplace(row);
            }
        }
        LayerKind::Embedding { w } => ops::embedding_inplace(buf, &w.data, w.shape[1]),
        other => panic!("in-place lowering of non-elementwise layer {}", other.type_name()),
    }
}

fn zero_pad_into(src: &[f32], ish: &[usize], pad: &[(usize, usize)], out: &mut Vec<f32>) {
    let c = *ish.last().unwrap();
    out.clear();
    match pad.len() {
        1 => {
            let (lo, hi) = pad[0];
            let s = ish[0];
            out.resize((s + lo + hi) * c, 0.0);
            out[lo * c..(lo + s) * c].copy_from_slice(src);
        }
        2 => {
            let (hlo, hhi) = pad[0];
            let (wlo, whi) = pad[1];
            let (h, w) = (ish[0], ish[1]);
            let (nh, nw) = (h + hlo + hhi, w + wlo + whi);
            out.resize(nh * nw * c, 0.0);
            for r in 0..h {
                let dst = ((r + hlo) * nw + wlo) * c;
                out[dst..dst + w * c].copy_from_slice(&src[r * w * c..(r + 1) * w * c]);
            }
        }
        r => panic!("zero_pad rank {r}"),
    }
}

/// Argmax helper for classification.
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::resnet_v1_6_shapes;
    use crate::graph::deploy_pipeline;
    use crate::util::prng::Pcg32;

    fn random_resnet(filters: usize, seed: u64) -> Graph {
        let mut g = resnet_v1_6_shapes("t", 1, &[32, 3], 4, filters);
        let mut rng = Pcg32::seeded(seed);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
        }
        g
    }

    #[test]
    fn resnet_runs_and_outputs_classes() {
        let g = random_resnet(8, 1);
        let x: Vec<f32> = (0..96).map(|i| (i as f32 * 0.1).sin()).collect();
        let out = run(&g, &x, None);
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deploy_pipeline_preserves_float_semantics() {
        let g = random_resnet(8, 2);
        let fused = deploy_pipeline(&g);
        let mut rng = Pcg32::seeded(3);
        for _ in 0..5 {
            let x: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
            let a = run(&g, &x, None);
            let b = run(&fused, &x, None);
            for (u, v) in a.iter().zip(&b) {
                // 1e-4: BN-folding rounding plus the GEMM lowering's
                // reordered f32 summation (ULP-bounded per layer).
                assert!((u - v).abs() < 1e-4, "{u} vs {v}");
            }
        }
    }

    #[test]
    fn calibration_records_ranges() {
        let g = random_resnet(8, 4);
        let mut stats = ActStats::new(g.nodes.len());
        let x: Vec<f32> = (0..96).map(|i| i as f32 * 0.01).collect();
        run(&g, &x, Some(&mut stats));
        assert!(stats.max_abs.iter().skip(1).any(|&m| m > 0.0));
        // Input node records the input range.
        assert!((stats.max_abs[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn stats_merge_takes_max() {
        let mut a = ActStats::new(2);
        a.record(0, &[1.0]);
        a.record(1, &[-2.0]);
        let mut b = ActStats::new(2);
        b.record(0, &[-3.0]);
        b.record(1, &[1.0]);
        a.merge(&b);
        assert_eq!(a.max_abs, vec![3.0, 2.0]);
        assert_eq!(a.min, vec![-3.0, -2.0]);
        assert_eq!(a.max, vec![1.0, 1.0]);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
    }

    #[test]
    fn gtsrb_2d_resnet_runs() {
        let mut g = resnet_v1_6_shapes("g", 2, &[16, 16, 3], 5, 4);
        let mut rng = Pcg32::seeded(9);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = 0.01;
                }
            }
        }
        let x: Vec<f32> = (0..16 * 16 * 3).map(|_| rng.normal()).collect();
        let out = run(&g, &x, None);
        assert_eq!(out.len(), 5);
    }
}
