//! Float32 graph executor — runs one example through a deployed `Graph`.
//!
//! Serves three roles: (a) the float32 deployment target of MicroAI, (b)
//! the calibration pass for post-training quantization (records per-node
//! activation ranges, §5.8), and (c) the semantic reference the integer
//! engines are validated against.

use crate::graph::ir::{Graph, LayerKind};

use super::float_ops as ops;

/// Per-node activation statistics collected during calibration (§5.8).
/// `max_abs` feeds the Qm.n scheme; `min`/`max` feed the affine
/// (TFLite-style) scheme's asymmetric ranges.
#[derive(Clone, Debug, Default)]
pub struct ActStats {
    pub max_abs: Vec<f32>,
    pub min: Vec<f32>,
    pub max: Vec<f32>,
}

impl ActStats {
    pub fn new(n_nodes: usize) -> Self {
        Self {
            max_abs: vec![0.0; n_nodes],
            min: vec![f32::INFINITY; n_nodes],
            max: vec![f32::NEG_INFINITY; n_nodes],
        }
    }

    fn record(&mut self, node: usize, data: &[f32]) {
        for &x in data {
            if x.abs() > self.max_abs[node] {
                self.max_abs[node] = x.abs();
            }
            if x < self.min[node] {
                self.min[node] = x;
            }
            if x > self.max[node] {
                self.max[node] = x;
            }
        }
    }

    pub fn merge(&mut self, other: &ActStats) {
        for i in 0..self.max_abs.len() {
            self.max_abs[i] = self.max_abs[i].max(other.max_abs[i]);
            self.min[i] = self.min[i].min(other.min[i]);
            self.max[i] = self.max[i].max(other.max[i]);
        }
    }
}

/// Execute `graph` on a single example (flattened input, channels-last).
/// Returns the output of the last node. If `stats` is provided, per-node
/// max-abs values are recorded (calibration mode).
pub fn run(graph: &Graph, input: &[f32], mut stats: Option<&mut ActStats>) -> Vec<f32> {
    assert_eq!(input.len(), graph.input_shape.iter().product::<usize>());
    let mut acts: Vec<Vec<f32>> = vec![Vec::new(); graph.nodes.len()];
    let mut scratch: Vec<f32> = Vec::new();
    for node in &graph.nodes {
        let out: Vec<f32> = match &node.kind {
            LayerKind::Input => input.to_vec(),
            LayerKind::Conv { w, b, stride, padding } => {
                let src = &acts[node.inputs[0]];
                let ish = &graph.nodes[node.inputs[0]].out_shape;
                scratch.clear();
                if graph.dims == 1 {
                    ops::conv1d(
                        src, ish[0], ish[1], &w.data, w.shape[0], w.shape[2], &b.data,
                        *stride, *padding, node.fused_relu, &mut scratch,
                    );
                } else {
                    ops::conv2d(
                        src, ish[0], ish[1], ish[2], &w.data, w.shape[0], w.shape[1],
                        w.shape[3], &b.data, *stride, *padding, node.fused_relu,
                        &mut scratch,
                    );
                }
                std::mem::take(&mut scratch)
            }
            LayerKind::Dense { w, b } => {
                let src = &acts[node.inputs[0]];
                ops::dense(src, &w.data, &b.data, w.shape[1], node.fused_relu, &mut scratch);
                std::mem::take(&mut scratch)
            }
            LayerKind::MaxPool { size } => {
                let src = &acts[node.inputs[0]];
                let ish = &graph.nodes[node.inputs[0]].out_shape;
                let c = *ish.last().unwrap();
                ops::maxpool(src, &ish[..ish.len() - 1], c, *size, node.fused_relu, &mut scratch);
                std::mem::take(&mut scratch)
            }
            LayerKind::AvgPool { size } => {
                let src = &acts[node.inputs[0]];
                let ish = &graph.nodes[node.inputs[0]].out_shape;
                let c = *ish.last().unwrap();
                ops::avgpool(src, &ish[..ish.len() - 1], c, *size, &mut scratch);
                std::mem::take(&mut scratch)
            }
            LayerKind::GlobalAvgPool => {
                let src = &acts[node.inputs[0]];
                let ish = &graph.nodes[node.inputs[0]].out_shape;
                let c = *ish.last().unwrap();
                let positions: usize = ish[..ish.len() - 1].iter().product();
                ops::global_avgpool(src, positions, c, &mut scratch);
                std::mem::take(&mut scratch)
            }
            LayerKind::Add => {
                let a = &acts[node.inputs[0]];
                let b = &acts[node.inputs[1]];
                ops::add(a, b, node.fused_relu, &mut scratch);
                std::mem::take(&mut scratch)
            }
            LayerKind::ReLU => {
                ops::relu(&acts[node.inputs[0]], &mut scratch);
                std::mem::take(&mut scratch)
            }
            LayerKind::Softmax => {
                ops::softmax(&acts[node.inputs[0]], &mut scratch);
                std::mem::take(&mut scratch)
            }
            LayerKind::ZeroPad { pad } => {
                // Materialized zero padding (only when not fused away).
                let src = &acts[node.inputs[0]];
                let ish = &graph.nodes[node.inputs[0]].out_shape;
                zero_pad(src, ish, pad)
            }
            LayerKind::BatchNorm { mean, var, gamma, beta, eps } => {
                let (w, b) = crate::graph::passes::batchnorm_affine(mean, var, gamma, beta, *eps);
                let src = &acts[node.inputs[0]];
                let c = *graph.nodes[node.inputs[0]].out_shape.last().unwrap();
                ops::batchnorm_affine(src, c, &w, &b, &mut scratch);
                std::mem::take(&mut scratch)
            }
            LayerKind::Flatten => acts[node.inputs[0]].clone(),
        };
        if let Some(stats) = stats.as_deref_mut() {
            stats.record(node.id, &out);
        }
        acts[node.id] = out;
    }
    acts.pop().unwrap()
}

fn zero_pad(src: &[f32], ish: &[usize], pad: &[(usize, usize)]) -> Vec<f32> {
    let c = *ish.last().unwrap();
    match pad.len() {
        1 => {
            let (lo, hi) = pad[0];
            let s = ish[0];
            let mut out = vec![0.0; (s + lo + hi) * c];
            out[lo * c..(lo + s) * c].copy_from_slice(src);
            out
        }
        2 => {
            let (hlo, hhi) = pad[0];
            let (wlo, whi) = pad[1];
            let (h, w) = (ish[0], ish[1]);
            let (nh, nw) = (h + hlo + hhi, w + wlo + whi);
            let mut out = vec![0.0; nh * nw * c];
            for r in 0..h {
                let dst = ((r + hlo) * nw + wlo) * c;
                out[dst..dst + w * c].copy_from_slice(&src[r * w * c..(r + 1) * w * c]);
            }
            out
        }
        r => panic!("zero_pad rank {r}"),
    }
}

/// Argmax helper for classification.
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::resnet_v1_6_shapes;
    use crate::graph::deploy_pipeline;
    use crate::util::prng::Pcg32;

    fn random_resnet(filters: usize, seed: u64) -> Graph {
        let mut g = resnet_v1_6_shapes("t", 1, &[32, 3], 4, filters);
        let mut rng = Pcg32::seeded(seed);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
        }
        g
    }

    #[test]
    fn resnet_runs_and_outputs_classes() {
        let g = random_resnet(8, 1);
        let x: Vec<f32> = (0..96).map(|i| (i as f32 * 0.1).sin()).collect();
        let out = run(&g, &x, None);
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deploy_pipeline_preserves_float_semantics() {
        let g = random_resnet(8, 2);
        let fused = deploy_pipeline(&g);
        let mut rng = Pcg32::seeded(3);
        for _ in 0..5 {
            let x: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
            let a = run(&g, &x, None);
            let b = run(&fused, &x, None);
            for (u, v) in a.iter().zip(&b) {
                assert!((u - v).abs() < 1e-5, "{u} vs {v}");
            }
        }
    }

    #[test]
    fn calibration_records_ranges() {
        let g = random_resnet(8, 4);
        let mut stats = ActStats::new(g.nodes.len());
        let x: Vec<f32> = (0..96).map(|i| i as f32 * 0.01).collect();
        run(&g, &x, Some(&mut stats));
        assert!(stats.max_abs.iter().skip(1).any(|&m| m > 0.0));
        // Input node records the input range.
        assert!((stats.max_abs[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn stats_merge_takes_max() {
        let mut a = ActStats::new(2);
        a.record(0, &[1.0]);
        a.record(1, &[-2.0]);
        let mut b = ActStats::new(2);
        b.record(0, &[-3.0]);
        b.record(1, &[1.0]);
        a.merge(&b);
        assert_eq!(a.max_abs, vec![3.0, 2.0]);
        assert_eq!(a.min, vec![-3.0, -2.0]);
        assert_eq!(a.max, vec![1.0, 1.0]);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
    }

    #[test]
    fn gtsrb_2d_resnet_runs() {
        let mut g = resnet_v1_6_shapes("g", 2, &[16, 16, 3], 5, 4);
        let mut rng = Pcg32::seeded(9);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = 0.01;
                }
            }
        }
        let x: Vec<f32> = (0..16 * 16 * 3).map(|_| rng.normal()).collect();
        let out = run(&g, &x, None);
        assert_eq!(out.len(), 5);
    }
}
