//! Offset-based memory planner (DESIGN.md §12) — the UNTRUSTED half of
//! the planner/checker split.
//!
//! The paper's §5.7 allocator first-fits whole *pools*; TFLite-Micro's
//! greedy planner (the Table-A6 rival) packs buffers at byte offsets
//! inside one arena. This module closes that gap in three passes over
//! the exact liveness facts (`analysis::liveness`):
//!
//! 1. **In-place lowering** (`inplace_candidate`): an element-wise node
//!    may write straight into an input buffer when it is that buffer's
//!    LAST reader — `Add` residual tails, standalone `ReLU`, `Softmax`,
//!    `Flatten`, and `Embedding` gather targets. Legality additionally
//!    requires: the source is not the caller-owned Input; sizes match
//!    (Embedding grows by the row width, which is safe because the
//!    gather walks ids backwards — position `t` writes `[t·d, (t+1)·d)`,
//!    never clobbering an unread id at `t' < t ≤ t·d`); and `Add` never
//!    aliases when both operands are the same buffer. Chained in-place
//!    nodes merge into a *class* sharing one buffer whose size is the
//!    max member and whose live interval is the union (members tile it,
//!    overlapping only at the sanctioned producer/consumer handoff).
//! 2. **Host slots** (first-fit over classes): the Rust executors keep
//!    their take/put `Vec<Vec<T>>` arena, so classes — not nodes — get
//!    slots, with INCLUSIVE interval conflict (a consumer born at its
//!    producer's death still reads it while writing itself).
//! 3. **Device offsets** (best-fit-decreasing): class chunks plus the
//!    four `seq × d_model` attention stage windows (point intervals
//!    `[n, n]`, replacing the per-node `static` buffers the C emitter
//!    used to hoard for the model's whole lifetime) are sorted by size
//!    descending and each placed at the lowest gap that fits among
//!    temporally-overlapping, already-placed chunks.
//!
//! If the offset plan somehow beats nothing — i.e. the BFD arena comes
//! out LARGER than the §5.7 pools plus attention statics — the planner
//! falls back to the pooled layout expressed as offsets, so planned
//! RAM ≤ pooled RAM holds by construction on every graph.
//!
//! Nothing here is trusted: `super::check_no_conflict` independently
//! re-proves every placement at element/byte granularity, and
//! `Plan::validate` / `codegen` / the deployer refuse plans it rejects.

use crate::analysis::liveness::{self, LiveRange, Liveness};
use crate::graph::ir::{Graph, LayerKind, Node};

/// Kinds eligible for in-place lowering, and the legal source input if
/// the node is that input's last reader. Deterministic: the first legal
/// input wins (matters only for `Add`).
pub(crate) fn inplace_candidate(graph: &Graph, last: &[usize], node: &Node) -> Option<usize> {
    let elems = |i: usize| graph.nodes[i].out_shape.iter().product::<usize>();
    let legal = |i: usize, grow: usize| {
        !matches!(graph.nodes[i].kind, LayerKind::Input)
            && last[i] == node.id
            && elems(i) * grow == elems(node.id)
    };
    match &node.kind {
        LayerKind::Add => {
            // x + x reads the source twice; aliasing the accumulator over
            // it would double the first rescale. Refuse outright.
            if node.inputs[0] == node.inputs[1] {
                return None;
            }
            node.inputs.iter().copied().find(|&i| legal(i, 1))
        }
        LayerKind::ReLU | LayerKind::Softmax | LayerKind::Flatten => {
            let i = node.inputs[0];
            legal(i, 1).then_some(i)
        }
        LayerKind::Embedding { w } => {
            let i = node.inputs[0];
            legal(i, w.shape[1]).then_some(i)
        }
        _ => None,
    }
}

/// One buffer the device arena must hold: an in-place class of nodes or
/// a single attention stage window.
#[derive(Clone, Debug)]
struct Chunk {
    elems: usize,
    birth: usize,
    death: usize,
    /// Node ids whose `offset_of` this chunk defines (class members), or
    /// empty for attention windows (delivered via `attn_scratch_of`).
    members: Vec<usize>,
    /// `Some((node, k))` for the k-th q/k/v/ctx window of `node`.
    window: Option<(usize, usize)>,
}

impl Chunk {
    fn overlaps(&self, other: &Chunk) -> bool {
        self.birth <= other.death && other.birth <= self.death
    }
}

/// Greedy best-fit-decreasing placement: chunks sorted by size (desc,
/// then birth, then first member/window id for determinism) are dropped
/// at the lowest offset that fits among temporally-overlapping placed
/// chunks. Returns per-chunk offsets and the arena size in elements.
fn bfd_offsets(chunks: &[Chunk]) -> (Vec<usize>, usize) {
    let mut order: Vec<usize> = (0..chunks.len()).collect();
    order.sort_by_key(|&i| {
        let c = &chunks[i];
        let tie = c.members.first().copied().or(c.window.map(|(n, k)| n * 4 + k)).unwrap_or(0);
        (usize::MAX - c.elems, c.birth, tie)
    });
    let mut offsets = vec![0usize; chunks.len()];
    let mut placed: Vec<usize> = Vec::new();
    let mut arena = 0usize;
    for &i in &order {
        let live: Vec<usize> = placed
            .iter()
            .copied()
            .filter(|&j| chunks[i].overlaps(&chunks[j]))
            .collect();
        let mut candidates: Vec<usize> = std::iter::once(0)
            .chain(live.iter().map(|&j| offsets[j] + chunks[j].elems))
            .collect();
        candidates.sort_unstable();
        candidates.dedup();
        let off = candidates
            .into_iter()
            .find(|&c| {
                live.iter().all(|&j| {
                    c + chunks[i].elems <= offsets[j] || offsets[j] + chunks[j].elems <= c
                })
            })
            .expect("offset 0 or some gap end always fits");
        offsets[i] = off;
        arena = arena.max(off + chunks[i].elems);
        placed.push(i);
    }
    (offsets, arena)
}

/// The paper's §5.7 first-fit pool assignment, kept verbatim as the
/// baseline the planner must never lose to (and the fallback layout if
/// it somehow would). Returns (pool_of, pool_elems).
pub(crate) fn pooled_first_fit(graph: &Graph, last: &[usize]) -> (Vec<usize>, Vec<usize>) {
    let n = graph.nodes.len();
    let mut pool_of = vec![usize::MAX; n];
    let mut pool_elems: Vec<usize> = Vec::new();
    let mut occupant: Vec<Option<usize>> = Vec::new();
    for node in &graph.nodes {
        if matches!(node.kind, LayerKind::Input) {
            continue;
        }
        let elems: usize = node.out_shape.iter().product();
        let mut chosen = None;
        for (p, occ) in occupant.iter().enumerate() {
            let free = match occ {
                None => true,
                Some(o) => {
                    let still_needed = last[*o] > node.id;
                    let is_my_input = node.inputs.iter().any(|&i| pool_of[i] == p);
                    !still_needed && !is_my_input
                }
            };
            if free {
                chosen = Some(p);
                break;
            }
        }
        let p = match chosen {
            Some(p) => p,
            None => {
                occupant.push(None);
                pool_elems.push(0);
                occupant.len() - 1
            }
        };
        pool_of[node.id] = p;
        occupant[p] = Some(node.id);
        pool_elems[p] = pool_elems[p].max(elems);
    }
    (pool_of, pool_elems)
}

/// Build the full offset plan for `graph`. Untrusted — callers must run
/// it through [`super::check_no_conflict`].
pub(crate) fn plan(graph: &Graph) -> super::Allocation {
    let n = graph.nodes.len();
    let lv: Liveness = liveness::analyze(graph);
    let last = liveness::last_use(graph);

    // Pass 1: in-place annotations and their classes.
    let mut inplace_with: Vec<Option<usize>> = vec![None; n];
    let mut class_root: Vec<usize> = (0..n).collect();
    for node in &graph.nodes {
        if let Some(s) = inplace_candidate(graph, &last, node) {
            inplace_with[node.id] = Some(s);
            class_root[node.id] = class_root[s]; // s < id, so already final
        }
    }

    // Class chunks: size = max member, interval = union of members.
    let mut chunks: Vec<Chunk> = Vec::new();
    let mut chunk_of_root = vec![usize::MAX; n];
    for r in &lv.ranges {
        if r.caller_owned {
            continue;
        }
        let root = class_root[r.node];
        if chunk_of_root[root] == usize::MAX {
            chunk_of_root[root] = chunks.len();
            chunks.push(Chunk {
                elems: 0,
                birth: r.birth,
                death: r.death,
                members: Vec::new(),
                window: None,
            });
        }
        let c = &mut chunks[chunk_of_root[root]];
        c.elems = c.elems.max(r.elems);
        c.birth = c.birth.min(r.birth);
        c.death = c.death.max(r.death);
        c.members.push(r.node);
    }
    let n_classes = chunks.len();

    // Pass 2: host execution slots, first-fit over classes in birth
    // order with inclusive-interval conflict.
    let mut pool_of = vec![usize::MAX; n];
    let mut pool_elems: Vec<usize> = Vec::new();
    let mut slot_tenants: Vec<Vec<usize>> = Vec::new(); // chunk ids per slot
    for ci in 0..n_classes {
        let free = |tenants: &[usize]| tenants.iter().all(|&t| !chunks[ci].overlaps(&chunks[t]));
        let slot = match slot_tenants.iter().position(|t| free(t)) {
            Some(s) => s,
            None => {
                slot_tenants.push(Vec::new());
                pool_elems.push(0);
                slot_tenants.len() - 1
            }
        };
        slot_tenants[slot].push(ci);
        pool_elems[slot] = pool_elems[slot].max(chunks[ci].elems);
        for &m in &chunks[ci].members {
            pool_of[m] = slot;
        }
    }

    // Pass 3: device offsets — classes plus attention stage windows.
    for (id, w) in lv.attn_window_elems.iter().enumerate() {
        if let Some(sd) = w {
            for k in 0..4 {
                chunks.push(Chunk {
                    elems: *sd,
                    birth: id,
                    death: id,
                    members: Vec::new(),
                    window: Some((id, k)),
                });
            }
        }
    }
    let (chunk_off, arena_elems) = bfd_offsets(&chunks);
    let mut offset_of = vec![usize::MAX; n];
    let mut attn_scratch_of: Vec<Option<[usize; 4]>> = vec![None; n];
    for (ci, c) in chunks.iter().enumerate() {
        for &m in &c.members {
            offset_of[m] = chunk_off[ci];
        }
        if let Some((id, k)) = c.window {
            let w = attn_scratch_of[id].get_or_insert([0; 4]);
            w[k] = chunk_off[ci];
        }
    }

    // §5.7 baseline: pools plus the attention statics the old C emitter
    // kept alive forever — the apples-to-apples pooled RAM figure.
    let (pool_of_57, pool_elems_57) = pooled_first_fit(graph, &last);
    let attn_total: usize = lv.attn_window_elems.iter().flatten().map(|sd| 4 * sd).sum();
    let pooled_elems = pool_elems_57.iter().sum::<usize>() + attn_total;

    let mut alloc = super::Allocation {
        pool_of,
        pool_elems,
        inplace_with,
        offset_of,
        arena_elems,
        pooled_elems,
        attn_scratch_of,
        gemm_scratch_elems: lv.gemm_scratch_elems,
        packed_b_elems: crate::nn::packed::packed_b_elems(graph),
    };

    // Never-worse guard: if BFD lost to the paper's pools (it shouldn't,
    // but the planner is untrusted), ship the pooled layout as offsets.
    if alloc.arena_elems > pooled_elems {
        let mut base = vec![0usize; pool_elems_57.len()];
        let mut acc = 0usize;
        for (p, &e) in pool_elems_57.iter().enumerate() {
            base[p] = acc;
            acc += e;
        }
        alloc.offset_of = pool_of_57
            .iter()
            .map(|&p| if p == usize::MAX { usize::MAX } else { base[p] })
            .collect();
        alloc.attn_scratch_of = lv
            .attn_window_elems
            .iter()
            .map(|w| {
                w.map(|sd| {
                    let w0 = acc;
                    acc += 4 * sd;
                    [w0, w0 + sd, w0 + 2 * sd, w0 + 3 * sd]
                })
            })
            .collect();
        alloc.pool_of = pool_of_57;
        alloc.pool_elems = pool_elems_57;
        alloc.inplace_with = vec![None; n];
        alloc.arena_elems = pooled_elems;
    }
    alloc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocator::check_no_conflict;
    use crate::graph::build::{cnn, resnet_v1_6_shapes, transformer};
    use crate::graph::deploy_pipeline;
    use crate::graph::ir::PadSpec;
    use crate::tensor::TensorF;

    #[test]
    fn bfd_packs_disjoint_intervals_at_offset_zero() {
        let mk = |elems, birth, death, id| Chunk {
            elems,
            birth,
            death,
            members: vec![id],
            window: None,
        };
        let chunks = vec![mk(10, 1, 2, 1), mk(20, 3, 4, 3), mk(30, 5, 6, 5)];
        let (off, arena) = bfd_offsets(&chunks);
        assert_eq!(off, vec![0, 0, 0]);
        assert_eq!(arena, 30);
    }

    #[test]
    fn bfd_stacks_overlapping_intervals() {
        let mk = |elems, birth, death, id| Chunk {
            elems,
            birth,
            death,
            members: vec![id],
            window: None,
        };
        // All three alive at node 5: must occupy disjoint ranges; the
        // largest goes first (offset 0) and the rest best-fit above.
        let chunks = vec![mk(10, 1, 5, 1), mk(30, 2, 5, 2), mk(20, 3, 6, 3)];
        let (off, arena) = bfd_offsets(&chunks);
        assert_eq!(off[1], 0, "largest chunk first");
        assert_eq!(arena, 60);
        for (i, j) in [(0usize, 1usize), (0, 2), (1, 2)] {
            let disjoint =
                off[i] + chunks[i].elems <= off[j] || off[j] + chunks[j].elems <= off[i];
            assert!(disjoint, "chunks {i}/{j} overlap");
        }
    }

    #[test]
    fn bfd_reuses_gaps_best_fit() {
        let mk = |elems, birth, death, id| Chunk {
            elems,
            birth,
            death,
            members: vec![id],
            window: None,
        };
        // big [1,9] at 0; mid [1,3] stacks above it; small [5,9] should
        // re-use mid's range (dead by 5) instead of growing the arena.
        let chunks = vec![mk(100, 1, 9, 1), mk(40, 1, 3, 2), mk(20, 5, 9, 3)];
        let (off, arena) = bfd_offsets(&chunks);
        assert_eq!(off[0], 0);
        assert_eq!(off[1], 100);
        assert_eq!(off[2], 100, "dead chunk's range is reusable");
        assert_eq!(arena, 140);
    }

    #[test]
    fn residual_add_lowered_in_place() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("pr", 1, &[128, 9], 6, 16));
        let a = plan(&g);
        check_no_conflict(&g, &a).unwrap();
        let adds: Vec<_> = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, crate::graph::ir::LayerKind::Add))
            .collect();
        assert!(!adds.is_empty());
        for add in &adds {
            let s = a.inplace_with[add.id].expect("residual add should fuse in place");
            assert!(add.inputs.contains(&s));
            assert_eq!(a.offset_of[add.id], a.offset_of[s]);
            assert_eq!(a.pool_of[add.id], a.pool_of[s]);
        }
    }

    #[test]
    fn embedding_after_non_input_node_goes_in_place() {
        // The stock transformer embeds the caller-owned Input directly
        // (never in-place); pad the ids first so the gather's source is
        // a planner-managed buffer and the descending-gather rule fires.
        let mut g = crate::graph::ir::Graph::new("pe", 1, &[6, 1], 3);
        let pad: PadSpec = vec![(1, 1)];
        let z = g.add("z", LayerKind::ZeroPad { pad }, vec![0]);
        let e = g.add(
            "emb",
            LayerKind::Embedding { w: TensorF::from_vec(&[5, 4], vec![0.1; 20]) },
            vec![z],
        );
        let d = g.add(
            "fc",
            LayerKind::Dense {
                w: TensorF::from_vec(&[32, 3], vec![0.01; 96]),
                b: TensorF::from_vec(&[3], vec![0.0; 3]),
            },
            vec![e],
        );
        let _ = d;
        let a = plan(&g);
        check_no_conflict(&g, &a).unwrap();
        assert_eq!(a.inplace_with[e], Some(z), "embedding should gather in place");
        // The class chunk is sized for the GROWN output (ids * d).
        assert!(a.pool_elems[a.pool_of[e]] >= 8 * 4);
    }

    #[test]
    fn add_over_same_buffer_twice_is_refused() {
        let mut g = crate::graph::ir::Graph::new("px", 1, &[8, 1], 3);
        let r = g.add("r", LayerKind::ReLU, vec![0]);
        let a = g.add("a2", LayerKind::Add, vec![r, r]);
        let _ = a;
        let last = liveness::last_use(&g);
        assert_eq!(inplace_candidate(&g, &last, &g.nodes[a]), None);
        let alloc = plan(&g);
        check_no_conflict(&g, &alloc).unwrap();
        assert_eq!(alloc.inplace_with[a], None);
    }

    #[test]
    fn planned_never_exceeds_pooled_and_wins_on_paper_models() {
        // Acceptance criterion: planned <= pooled everywhere, strictly
        // smaller on at least 2 of {UCI-HAR, SMNIST, GTSRB, transformer}.
        let models: Vec<(&str, crate::graph::ir::Graph)> = vec![
            ("uci-har", deploy_pipeline(&resnet_v1_6_shapes("har", 1, &[128, 9], 6, 16))),
            ("smnist", deploy_pipeline(&cnn("smnist", 1, &[39, 13], 10, &[8, 8], 3, 32))),
            ("gtsrb", deploy_pipeline(&resnet_v1_6_shapes("gtsrb", 2, &[32, 32, 3], 43, 8))),
            ("transformer", deploy_pipeline(&transformer("tx", 12, 20, 16, 2, 2, 2, 5))),
        ];
        let mut strict_wins = 0usize;
        for (name, g) in &models {
            let a = plan(g);
            check_no_conflict(g, &a).unwrap();
            assert!(
                a.arena_elems <= a.pooled_elems,
                "{name}: planned {} > pooled {}",
                a.arena_elems,
                a.pooled_elems
            );
            if a.arena_elems < a.pooled_elems {
                strict_wins += 1;
            }
        }
        assert!(strict_wins >= 2, "only {strict_wins} strict RAM wins");
    }

    #[test]
    fn prop_random_resnets_pass_the_trusted_checker() {
        use crate::util::check::property;
        property(25, |pg| {
            let filters = pg.usize_in(4, 32);
            let s = 8 * pg.usize_in(2, 16);
            let c = pg.usize_in(1, 8);
            let graph = deploy_pipeline(&resnet_v1_6_shapes(
                "pp", 1, &[s, c], pg.usize_in(2, 10), filters,
            ));
            let a = plan(&graph);
            check_no_conflict(&graph, &a)?;
            if a.arena_elems > a.pooled_elems {
                return Err(format!("planned {} > pooled {}", a.arena_elems, a.pooled_elems));
            }
            Ok(())
        });
    }

    #[test]
    fn pooled_fallback_layout_also_passes_the_checker() {
        // The never-worse guard ships this layout when BFD loses; prove
        // it is sound in its own right by constructing it directly.
        let g = deploy_pipeline(&transformer("pf", 12, 20, 16, 2, 2, 2, 5));
        let last = liveness::last_use(&g);
        let lv = liveness::analyze(&g);
        let (pool_of, pool_elems) = pooled_first_fit(&g, &last);
        let mut base = vec![0usize; pool_elems.len()];
        let mut acc = 0usize;
        for (p, &e) in pool_elems.iter().enumerate() {
            base[p] = acc;
            acc += e;
        }
        let offset_of: Vec<usize> = pool_of
            .iter()
            .map(|&p| if p == usize::MAX { usize::MAX } else { base[p] })
            .collect();
        let attn_scratch_of: Vec<Option<[usize; 4]>> = lv
            .attn_window_elems
            .iter()
            .map(|w| {
                w.map(|sd| {
                    let w0 = acc;
                    acc += 4 * sd;
                    [w0, w0 + sd, w0 + 2 * sd, w0 + 3 * sd]
                })
            })
            .collect();
        let n = g.nodes.len();
        let alloc = crate::allocator::Allocation {
            pool_of,
            pool_elems,
            inplace_with: vec![None; n],
            offset_of,
            arena_elems: acc,
            pooled_elems: acc,
            attn_scratch_of,
            gemm_scratch_elems: lv.gemm_scratch_elems,
            packed_b_elems: crate::nn::packed::packed_b_elems(&g),
        };
        check_no_conflict(&g, &alloc).unwrap();
    }
}
