//! RAM output-buffer allocator (§5.7).
//!
//! "The allocator module aims at saving the RAM usage. To do so, it
//! allocates the layer's output buffers in the smallest number of pools
//! without conflicts. For each layer of the model, its output buffer is
//! allocated to the first pool that satisfies two conditions: it must
//! neither overwrite its input, nor the output of a layer that has not
//! already been consumed. If there is no such available pool, a new one is
//! created."
//!
//! We implement exactly that first-fit strategy, plus the lifetime
//! analysis it needs, and report the resulting RAM usage (pool sizes are
//! the max element count assigned to each pool). The paper notes pool-size
//! minimization is NOT attempted ("a harder problem"); we keep that
//! behaviour for fidelity and verify the no-conflict invariant by property
//! test.

use crate::graph::ir::{Graph, LayerKind};

/// Buffer assignment for one graph.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Pool index per node (usize::MAX for nodes with no buffer: Input).
    pub pool_of: Vec<usize>,
    /// Element capacity of each pool.
    pub pool_elems: Vec<usize>,
    /// HOST-side im2col/staging scratch (elements, PER intra-op thread)
    /// for the GEMM kernel lowering (`nn::gemm`): the lifetime analysis
    /// extension — a packing panel is live only inside one node's
    /// execution, so one buffer of this size per worker thread serves the
    /// whole graph (each worker packs the panels of its own output-
    /// position chunk). The Session arena preallocates `threads` slabs of
    /// this size and `Arena::buffer_ptrs` exposes every slab, so the
    /// arena-reuse tests catch undersizing on any worker. NOT part of the
    /// device RAM model ([`Allocation::ram_bytes`]), which prices the
    /// generated C.
    pub gemm_scratch_elems: usize,
    /// HOST-side prepacked weight-panel elements (`nn::packed`): total
    /// NR-tiled B-panel slots across every conv/dense node, built ONCE at
    /// session-build time and shared read-only by forks. Like
    /// `gemm_scratch_elems`, a host-only accounting fact — the device
    /// RAM/ROM models are untouched (the device executes the generated C
    /// straight from its row-major weight arrays).
    pub packed_b_elems: usize,
}

impl Allocation {
    pub fn n_pools(&self) -> usize {
        self.pool_elems.len()
    }

    /// Total RAM in bytes at `bytes_per_elem` (1 for int8, 2 for int16,
    /// 4 for float32), plus the input buffer held by the caller.
    pub fn ram_bytes(&self, bytes_per_elem: usize) -> usize {
        self.pool_elems.iter().sum::<usize>() * bytes_per_elem
    }
}

/// Last node (in topological order) that reads each node's output.
fn last_use(graph: &Graph) -> Vec<usize> {
    let mut last = vec![0usize; graph.nodes.len()];
    for node in &graph.nodes {
        for &i in &node.inputs {
            last[i] = last[i].max(node.id);
        }
    }
    // The graph output is "used" by the caller after everything.
    let out = graph.output_id();
    last[out] = usize::MAX;
    last
}

/// First-fit pool allocation per §5.7.
pub fn allocate(graph: &Graph) -> Allocation {
    let last = last_use(graph);
    let n = graph.nodes.len();
    let mut pool_of = vec![usize::MAX; n];
    let mut pool_elems: Vec<usize> = Vec::new();
    // For each pool, the id of the node whose output currently lives there.
    let mut occupant: Vec<Option<usize>> = Vec::new();

    for node in &graph.nodes {
        if matches!(node.kind, LayerKind::Input) {
            continue; // input buffer is provided by the caller
        }
        let elems: usize = node.out_shape.iter().product();
        // Pools holding an input of this node are forbidden (no in-place),
        // as are pools whose occupant still has readers after this node.
        let mut chosen = None;
        for (p, occ) in occupant.iter().enumerate() {
            let free = match occ {
                None => true,
                Some(o) => {
                    let still_needed = last[*o] > node.id;
                    let is_my_input = node.inputs.iter().any(|&i| pool_of[i] == p);
                    !still_needed && !is_my_input
                }
            };
            if free {
                chosen = Some(p);
                break;
            }
        }
        let p = match chosen {
            Some(p) => p,
            None => {
                occupant.push(None);
                pool_elems.push(0);
                occupant.len() - 1
            }
        };
        pool_of[node.id] = p;
        occupant[p] = Some(node.id);
        pool_elems[p] = pool_elems[p].max(elems);
    }
    let gemm_scratch_elems = crate::nn::gemm::scratch_elems(graph);
    let packed_b_elems = crate::nn::packed::packed_b_elems(graph);
    Allocation { pool_of, pool_elems, gemm_scratch_elems, packed_b_elems }
}

/// Check the §5.7 invariant: at no point does writing a node's output
/// clobber (a) one of its inputs or (b) a value still to be read.
pub fn check_no_conflict(graph: &Graph, alloc: &Allocation) -> Result<(), String> {
    let last = last_use(graph);
    for node in &graph.nodes {
        let p = alloc.pool_of[node.id];
        if p == usize::MAX {
            continue;
        }
        // (a) inputs must live elsewhere.
        for &i in &node.inputs {
            if alloc.pool_of[i] == p {
                return Err(format!("node {} overwrites its input {}", node.id, i));
            }
        }
        // (b) any earlier node in the same pool must be fully consumed.
        for other in &graph.nodes[..node.id] {
            if alloc.pool_of[other.id] == p && last[other.id] > node.id {
                return Err(format!(
                    "node {} overwrites node {} (still needed until {})",
                    node.id, other.id, last[other.id]
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::{cnn, resnet_v1_6_shapes};
    use crate::graph::deploy_pipeline;
    use crate::prop_assert;
    use crate::util::check::property;

    #[test]
    fn sequential_graph_uses_two_pools() {
        // A pure chain ping-pongs between two pools.
        let g = cnn("c", 1, &[64, 4], 5, &[8, 8], 3, 16);
        let a = allocate(&g);
        check_no_conflict(&g, &a).unwrap();
        assert_eq!(a.n_pools(), 2, "pools: {:?}", a.pool_elems);
    }

    #[test]
    fn resnet_needs_a_third_pool_for_the_residual() {
        // The residual tap keeps a value alive across the block body.
        let g = deploy_pipeline(&resnet_v1_6_shapes("r", 1, &[128, 9], 6, 16));
        let a = allocate(&g);
        check_no_conflict(&g, &a).unwrap();
        assert!(a.n_pools() >= 3);
        assert!(a.n_pools() <= 4, "first-fit should stay small: {}", a.n_pools());
    }

    #[test]
    fn ram_scales_with_dtype_width() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("r", 1, &[128, 9], 6, 16));
        let a = allocate(&g);
        assert_eq!(a.ram_bytes(4), 2 * a.ram_bytes(2));
    }

    #[test]
    fn prop_no_conflict_on_random_resnets() {
        property(30, |g| {
            let filters = g.usize_in(4, 32);
            let s = 8 * g.usize_in(2, 16);
            let c = g.usize_in(1, 8);
            let graph = deploy_pipeline(&resnet_v1_6_shapes(
                "p", 1, &[s, c], g.usize_in(2, 10), filters,
            ));
            let a = allocate(&graph);
            if let Err(e) = check_no_conflict(&graph, &a) {
                return Err(e);
            }
            // Every non-input node got a pool.
            for n in &graph.nodes {
                if !matches!(n.kind, LayerKind::Input) {
                    prop_assert!(a.pool_of[n.id] != usize::MAX, "node {} unallocated", n.id);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_scratch_recorded_but_not_charged_to_device_ram() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("r", 1, &[128, 9], 6, 16));
        let a = allocate(&g);
        assert_eq!(a.gemm_scratch_elems, crate::nn::gemm::scratch_elems(&g));
        assert!(a.gemm_scratch_elems > 0);
        // The device RAM model (§5.7 pools at device dtype) is untouched
        // by the host-side packing scratch.
        assert_eq!(a.ram_bytes(1), a.pool_elems.iter().sum::<usize>());
    }

    #[test]
    fn packed_b_elems_recorded_but_not_charged_to_device_ram() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("r", 1, &[128, 9], 6, 16));
        let a = allocate(&g);
        assert_eq!(a.packed_b_elems, crate::nn::packed::packed_b_elems(&g));
        assert!(a.packed_b_elems > 0);
        // Host-only, like the GEMM scratch: device RAM prices pools only.
        assert_eq!(a.ram_bytes(1), a.pool_elems.iter().sum::<usize>());
    }

    #[test]
    fn pool_capacity_fits_largest_assignment() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("r", 1, &[128, 9], 6, 24));
        let a = allocate(&g);
        for n in &g.nodes {
            let p = a.pool_of[n.id];
            if p != usize::MAX {
                let elems: usize = n.out_shape.iter().product();
                assert!(a.pool_elems[p] >= elems);
            }
        }
    }
}
