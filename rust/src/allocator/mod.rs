//! RAM planner for activation buffers (§5.7 upgraded, DESIGN.md §12).
//!
//! The paper's §5.7 allocator saves RAM by first-fit *pool* assignment:
//! "it allocates the layer's output buffers in the smallest number of
//! pools without conflicts". Table A6 compares that against
//! TFLite-Micro, whose greedy planner packs buffers at byte offsets in
//! one arena — the gap this module closes. The UNTRUSTED planner
//! (`planner`, fed by `analysis::liveness`) produces an offset-based
//! plan with in-place lowering; the TRUSTED checker here
//! ([`check_no_conflict`]) independently re-proves it at element/byte
//! granularity before any session, C library, or report will carry it.
//! The §5.7 pooled figure is retained in every [`Allocation`]
//! (`pooled_elems`) as the baseline the plan must never exceed.

// The planner/checker chain is a safety argument; keep it trivially
// auditable — no raw memory here (ISSUE 9 satellite).
#![forbid(unsafe_code)]

pub mod planner;

use crate::graph::ir::{Graph, LayerKind};

/// Buffer assignment for one graph: host execution slots (the executors'
/// take/put `Vec<Vec<T>>` arena), device arena offsets (the generated
/// C's single coalesced `arena[]`), in-place annotations tying the two
/// together, and the host-only scratch facts.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Host slot index per node (`usize::MAX` for the caller-owned
    /// Input). Nodes of one in-place class share a slot.
    pub pool_of: Vec<usize>,
    /// Element capacity of each host slot (max member of each class
    /// assigned to it; batched arenas multiply by `max_batch`).
    pub pool_elems: Vec<usize>,
    /// `Some(src)` when the node writes its output IN PLACE over input
    /// `src`'s buffer (it is that buffer's last reader and the op is
    /// alias-safe — see `planner::inplace_candidate`). The executors
    /// take the shared slot (already holding `src`'s payload) and
    /// mutate it; the C driver passes the same arena pointer twice.
    pub inplace_with: Vec<Option<usize>>,
    /// Device arena element offset per node (`usize::MAX` for Input).
    /// Offsets are single-example and in elements: the uniform
    /// activation dtype makes element disjointness ⇔ byte disjointness.
    pub offset_of: Vec<usize>,
    /// Total device arena size in elements — what [`ram_bytes`] prices
    /// and what codegen emits as `static number_t arena[..]`.
    ///
    /// [`ram_bytes`]: Allocation::ram_bytes
    pub arena_elems: usize,
    /// The §5.7 first-fit pool total PLUS the four per-attention-node
    /// stage windows the old C emitter kept as immortal statics — the
    /// apples-to-apples baseline. Invariant: `arena_elems <=
    /// pooled_elems` (the planner falls back to the pooled layout
    /// otherwise), re-proven by the deployer report.
    pub pooled_elems: usize,
    /// Per-`SelfAttention`-node offsets of the q/k/v/ctx stage windows
    /// inside the device arena (each `seq × d_model` elements, live only
    /// within the node's own execution). `None` on every other node.
    pub attn_scratch_of: Vec<Option<[usize; 4]>>,
    /// HOST-side im2col/staging scratch (elements, PER intra-op thread)
    /// for the GEMM kernel lowering (`nn::gemm`): a packing panel is
    /// live only inside one node's execution, so one buffer of this size
    /// per worker thread serves the whole graph. The Session arena
    /// preallocates `threads` slabs of this size and
    /// `Arena::buffer_ptrs` exposes every slab, so the arena-reuse tests
    /// catch undersizing on any worker. NOT part of the device RAM model
    /// ([`Allocation::ram_bytes`]), which prices the generated C.
    pub gemm_scratch_elems: usize,
    /// HOST-side prepacked weight-panel elements (`nn::packed`): total
    /// NR-tiled B-panel slots across every conv/dense node, built ONCE
    /// at session-build time and shared read-only by forks. Like
    /// `gemm_scratch_elems`, a host-only accounting fact — the device
    /// RAM/ROM models are untouched (the device executes the generated C
    /// straight from its row-major weight arrays).
    pub packed_b_elems: usize,
}

impl Allocation {
    pub fn n_pools(&self) -> usize {
        self.pool_elems.len()
    }

    /// Total device RAM in bytes at `bytes_per_elem` (1 for int8, 2 for
    /// int16, 4 for float32): the planned coalesced arena. The input
    /// buffer held by the caller is priced separately.
    pub fn ram_bytes(&self, bytes_per_elem: usize) -> usize {
        self.arena_elems * bytes_per_elem
    }

    /// What the same model costs under the paper's §5.7 pools (plus the
    /// attention statics) — the Table-A6 comparison figure.
    pub fn pooled_ram_bytes(&self, bytes_per_elem: usize) -> usize {
        self.pooled_elems * bytes_per_elem
    }
}

/// Trusted recompute of each node's last reader. Deliberately local to
/// the checker (the planner uses `analysis::liveness::last_use`): the
/// two sides of the planner/checker split must not share derivations.
fn last_use(graph: &Graph) -> Vec<usize> {
    // A node nobody reads dies the moment it is written (its own id).
    let mut last: Vec<usize> = (0..graph.nodes.len()).collect();
    for node in &graph.nodes {
        for &i in &node.inputs {
            last[i] = last[i].max(node.id);
        }
    }
    // The graph output is "used" by the caller after everything.
    last[graph.output_id()] = usize::MAX;
    last
}

/// Plan buffers for `graph`: exact liveness → in-place classes → host
/// slots → best-fit-decreasing device offsets, never worse than the
/// §5.7 pools. The result is UNTRUSTED until [`check_no_conflict`]
/// accepts it — `Plan::validate` (thus `SessionBuilder::try_build`),
/// `codegen::generate`, and the deployer report all insist on that.
pub fn allocate(graph: &Graph) -> Allocation {
    planner::plan(graph)
}

/// The TRUSTED checker: independently prove, at element/byte ranges,
/// that no two live buffers overlap in either layout (device arena
/// offsets AND host slots) and that every read happens inside the
/// producer's live interval. In-place pairs are the single sanctioned
/// exception: producer and consumer must alias EXACTLY (same offset,
/// same slot) and the op must be one whose kernel is alias-safe.
///
/// Everything is recomputed from the graph — the only planner outputs
/// consumed are the assignments under test.
pub fn check_no_conflict(graph: &Graph, alloc: &Allocation) -> Result<(), String> {
    let n = graph.nodes.len();
    if alloc.pool_of.len() != n
        || alloc.inplace_with.len() != n
        || alloc.offset_of.len() != n
        || alloc.attn_scratch_of.len() != n
    {
        return Err(format!("plan tables sized for a different graph ({n} nodes)"));
    }
    let last = last_use(graph);
    let elems: Vec<usize> = graph.nodes.iter().map(|nd| nd.out_shape.iter().product()).collect();
    // Closed live interval per node: [birth, death].
    let birth = |i: usize| i;
    let death = |i: usize| last[i].max(i);
    let lives_at = |i: usize, t: usize| birth(i) <= t && t <= death(i);
    let temporal = |i: usize, j: usize| birth(i) <= death(j) && birth(j) <= death(i);
    let disjoint = |o1: usize, e1: usize, o2: usize, e2: usize| o1 + e1 <= o2 || o2 + e2 <= o1;
    // Host layout derived ONLY from slot capacities: slot p occupies
    // [base[p], base[p] + pool_elems[p]).
    let mut host_base = vec![0usize; alloc.pool_elems.len()];
    let mut acc = 0usize;
    for (p, &e) in alloc.pool_elems.iter().enumerate() {
        host_base[p] = acc;
        acc += e;
    }

    for node in &graph.nodes {
        let id = node.id;
        if matches!(node.kind, LayerKind::Input) {
            if alloc.pool_of[id] != usize::MAX || alloc.offset_of[id] != usize::MAX {
                return Err(format!("caller-owned Input {id} must not be planned"));
            }
            if alloc.inplace_with[id].is_some() {
                return Err(format!("Input {id} cannot be in-place"));
            }
            continue;
        }
        let p = alloc.pool_of[id];
        if p == usize::MAX || p >= alloc.pool_elems.len() {
            return Err(format!("node {id} has no host slot"));
        }
        if alloc.pool_elems[p] < elems[id] {
            return Err(format!(
                "node {id} needs {} elems but host slot {p} holds {}",
                elems[id], alloc.pool_elems[p]
            ));
        }
        let off = alloc.offset_of[id];
        if off == usize::MAX || off + elems[id] > alloc.arena_elems {
            return Err(format!(
                "node {id} range [{off}, {off}+{}) escapes the {}-elem arena",
                elems[id], alloc.arena_elems
            ));
        }
        // Every read precedes its buffer's death: producers are earlier
        // in the schedule and, by the recomputed last_use, live at least
        // until here. (Definitional given the recompute; the schedule
        // sanity check is what can actually fail on a malformed graph.)
        for &i in &node.inputs {
            if i >= id {
                return Err(format!("node {id} reads {i} out of schedule order"));
            }
            if !lives_at(i, id) {
                return Err(format!("node {id} reads {i} after its death"));
            }
        }
        // In-place legality.
        if let Some(s) = alloc.inplace_with[id] {
            if !node.inputs.contains(&s) {
                return Err(format!("node {id} claims in-place over non-input {s}"));
            }
            if matches!(graph.nodes[s].kind, LayerKind::Input) {
                return Err(format!("node {id} may not overwrite the caller's input buffer"));
            }
            if last[s] != id {
                return Err(format!(
                    "node {id} overwrites {s} which is still read until {}",
                    last[s]
                ));
            }
            let size_ok = match &node.kind {
                LayerKind::Add => {
                    node.inputs[0] != node.inputs[1] && elems[id] == elems[s]
                }
                LayerKind::ReLU | LayerKind::Softmax | LayerKind::Flatten => {
                    elems[id] == elems[s]
                }
                // The descending gather writes [t·d, (t+1)·d) after
                // reading id t: safe for any d >= 1 (t <= t·d).
                LayerKind::Embedding { w } => elems[id] == elems[s] * w.shape[1],
                other => {
                    return Err(format!(
                        "node {id} ({}) is not an alias-safe in-place kind",
                        other.type_name()
                    ))
                }
            };
            if !size_ok {
                return Err(format!("node {id} in-place size rule violated over {s}"));
            }
            if alloc.offset_of[s] != off || alloc.pool_of[s] != p {
                return Err(format!("in-place node {id} does not alias {s} exactly"));
            }
        }
        // Attention stage windows: exactly the attention nodes carry
        // them, in bounds, pairwise disjoint, and disjoint from every
        // buffer live during the node's execution.
        match (&node.kind, &alloc.attn_scratch_of[id]) {
            (LayerKind::SelfAttention { heads, head_dim, .. }, Some(w)) => {
                let sd = node.out_shape[0] * heads * head_dim;
                for (k, &wo) in w.iter().enumerate() {
                    if wo + sd > alloc.arena_elems {
                        return Err(format!("attention window {k} of node {id} escapes arena"));
                    }
                    for (k2, &wo2) in w.iter().enumerate().skip(k + 1) {
                        if !disjoint(wo, sd, wo2, sd) {
                            return Err(format!(
                                "attention windows {k}/{k2} of node {id} overlap"
                            ));
                        }
                    }
                    for other in &graph.nodes {
                        let o = other.id;
                        if matches!(other.kind, LayerKind::Input) || !lives_at(o, id) {
                            continue;
                        }
                        if !disjoint(wo, sd, alloc.offset_of[o], elems[o]) {
                            return Err(format!(
                                "attention window {k} of node {id} overlaps live node {o}"
                            ));
                        }
                    }
                }
            }
            (LayerKind::SelfAttention { .. }, None) => {
                return Err(format!("attention node {id} lacks stage windows"));
            }
            (_, Some(_)) => {
                return Err(format!("non-attention node {id} carries stage windows"));
            }
            (_, None) => {}
        }
    }

    // Pairwise: temporally-overlapping buffers must occupy disjoint
    // ranges in BOTH layouts, except the sanctioned in-place handoff,
    // which must alias exactly (verified above).
    for i in 0..n {
        if matches!(graph.nodes[i].kind, LayerKind::Input) {
            continue;
        }
        for j in i + 1..n {
            if matches!(graph.nodes[j].kind, LayerKind::Input) || !temporal(i, j) {
                continue;
            }
            if alloc.inplace_with[j] == Some(i) {
                continue; // sanctioned alias
            }
            if !disjoint(alloc.offset_of[i], elems[i], alloc.offset_of[j], elems[j]) {
                return Err(format!(
                    "nodes {i} and {j} are both live on [{}, {}] but overlap in the arena",
                    birth(j),
                    death(i).min(death(j))
                ));
            }
            let (hi, hj) = (host_base[alloc.pool_of[i]], host_base[alloc.pool_of[j]]);
            if !disjoint(hi, elems[i], hj, elems[j]) {
                return Err(format!(
                    "nodes {i} and {j} are both live but share host slot bytes"
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::{cnn, resnet_v1_6_shapes};
    use crate::graph::deploy_pipeline;
    use crate::prop_assert;
    use crate::util::check::property;

    #[test]
    fn sequential_graph_uses_two_pools() {
        // A pure chain ping-pongs between two host slots (in-place
        // classes keep the count at the §5.7 figure).
        let g = cnn("c", 1, &[64, 4], 5, &[8, 8], 3, 16);
        let a = allocate(&g);
        check_no_conflict(&g, &a).unwrap();
        assert_eq!(a.n_pools(), 2, "pools: {:?}", a.pool_elems);
    }

    #[test]
    fn resnet_residual_is_planned_without_conflicts() {
        // The residual tap keeps a value alive across the block body;
        // in-place Add lowering may save one of the §5.7 pools but the
        // slot count must stay in the first-fit ballpark.
        let g = deploy_pipeline(&resnet_v1_6_shapes("r", 1, &[128, 9], 6, 16));
        let a = allocate(&g);
        check_no_conflict(&g, &a).unwrap();
        assert!(a.n_pools() >= 2);
        assert!(a.n_pools() <= 4, "first-fit should stay small: {}", a.n_pools());
        // The offset plan must beat or match the §5.7 pools.
        assert!(a.arena_elems <= a.pooled_elems);
    }

    #[test]
    fn ram_scales_with_dtype_width() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("r", 1, &[128, 9], 6, 16));
        let a = allocate(&g);
        assert_eq!(a.ram_bytes(4), 2 * a.ram_bytes(2));
        assert_eq!(a.pooled_ram_bytes(4), 2 * a.pooled_ram_bytes(2));
    }

    #[test]
    fn prop_no_conflict_on_random_resnets() {
        property(30, |g| {
            let filters = g.usize_in(4, 32);
            let s = 8 * g.usize_in(2, 16);
            let c = g.usize_in(1, 8);
            let graph = deploy_pipeline(&resnet_v1_6_shapes(
                "p", 1, &[s, c], g.usize_in(2, 10), filters,
            ));
            let a = allocate(&graph);
            if let Err(e) = check_no_conflict(&graph, &a) {
                return Err(e);
            }
            // Every non-input node got a slot and an offset.
            for n in &graph.nodes {
                if !matches!(n.kind, LayerKind::Input) {
                    prop_assert!(a.pool_of[n.id] != usize::MAX, "node {} unallocated", n.id);
                    prop_assert!(a.offset_of[n.id] != usize::MAX, "node {} unplaced", n.id);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn checker_rejects_crafted_overlapping_plan() {
        // Force a consumer onto its producer's offset WITHOUT the
        // in-place annotation: the trusted checker must refuse.
        let g = deploy_pipeline(&resnet_v1_6_shapes("bad", 1, &[128, 9], 6, 16));
        let good = allocate(&g);
        check_no_conflict(&g, &good).unwrap();
        let victim = g
            .nodes
            .iter()
            .find(|n| {
                !matches!(n.kind, LayerKind::Input)
                    && n.inputs.iter().any(|&i| {
                        !matches!(g.nodes[i].kind, LayerKind::Input)
                            && good.inplace_with[n.id] != Some(i)
                    })
            })
            .expect("some node reads a planned buffer");
        let src = *victim
            .inputs
            .iter()
            .find(|&&i| {
                !matches!(g.nodes[i].kind, LayerKind::Input)
                    && good.inplace_with[victim.id] != Some(i)
            })
            .unwrap();
        let mut evil = good.clone();
        evil.offset_of[victim.id] = evil.offset_of[src];
        let err = check_no_conflict(&g, &evil).unwrap_err();
        assert!(err.contains("overlap"), "unexpected refusal: {err}");

        // Claiming the overlap as in-place doesn't launder it either:
        // the legality rules (kind, last-reader, exact alias) re-check.
        let mut evil2 = good.clone();
        evil2.inplace_with[victim.id] = Some(src);
        evil2.offset_of[victim.id] = evil2.offset_of[src];
        assert!(check_no_conflict(&g, &evil2).is_err());
    }

    #[test]
    fn checker_rejects_arena_escape_and_undersized_slots() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("esc", 1, &[128, 9], 6, 16));
        let good = allocate(&g);
        let last_node = g.nodes.len() - 1;
        let mut evil = good.clone();
        evil.offset_of[last_node] = evil.arena_elems; // out of bounds
        assert!(check_no_conflict(&g, &evil).unwrap_err().contains("arena"));
        let mut evil2 = good.clone();
        evil2.pool_elems[evil2.pool_of[last_node]] = 0;
        assert!(check_no_conflict(&g, &evil2).is_err());
    }

    #[test]
    fn gemm_scratch_recorded_but_not_charged_to_device_ram() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("r", 1, &[128, 9], 6, 16));
        let a = allocate(&g);
        assert_eq!(a.gemm_scratch_elems, crate::nn::gemm::scratch_elems(&g));
        assert!(a.gemm_scratch_elems > 0);
        // The device RAM model (the planned arena at device dtype) is
        // untouched by the host-side packing scratch.
        assert_eq!(a.ram_bytes(1), a.arena_elems);
    }

    #[test]
    fn packed_b_elems_recorded_but_not_charged_to_device_ram() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("r", 1, &[128, 9], 6, 16));
        let a = allocate(&g);
        assert_eq!(a.packed_b_elems, crate::nn::packed::packed_b_elems(&g));
        assert!(a.packed_b_elems > 0);
        // Host-only, like the GEMM scratch: device RAM prices the arena.
        assert_eq!(a.ram_bytes(1), a.arena_elems);
    }

    #[test]
    fn pool_capacity_fits_largest_assignment() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("r", 1, &[128, 9], 6, 24));
        let a = allocate(&g);
        for n in &g.nodes {
            let p = a.pool_of[n.id];
            if p != usize::MAX {
                let elems: usize = n.out_shape.iter().product();
                assert!(a.pool_elems[p] >= elems);
                assert!(a.offset_of[n.id] + elems <= a.arena_elems);
            }
        }
    }
}
