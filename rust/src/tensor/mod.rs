//! Shaped tensors for the inference engine. Row-major (C-order) layout,
//! channels-last spatial convention (NWC / NHWC) matching the JAX model and
//! the generated C code (`input[channels][samples]` transposed note: the
//! paper's C uses channel-major for input delivery; internally we stay
//! channels-last and convert at the boundary).

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    pub shape: Vec<usize>,
    pub data: Vec<T>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI = Tensor<i32>;

impl<T: Clone + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![T::default(); shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs len {}",
            data.len()
        );
        Self { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }
}

impl TensorF {
    pub fn map(&self, f: impl Fn(f32) -> f32) -> TensorF {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    /// Max |diff| against another tensor of the same shape.
    pub fn max_diff(&self, other: &TensorF) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()))
    }
}

/// 3-D index helper for (B, S, C) tensors.
#[inline(always)]
pub fn idx3(s: usize, c: usize, i0: usize, i1: usize, i2: usize) -> usize {
    (i0 * s + i1) * c + i2
}

/// 4-D index helper for (B, H, W, C) tensors.
#[inline(always)]
pub fn idx4(h: usize, w: usize, c: usize, i0: usize, i1: usize, i2: usize, i3: usize) -> usize {
    ((i0 * h + i1) * w + i2) * c + i3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_from_vec() {
        let t: TensorF = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        let u = Tensor::from_vec(&[2, 2], vec![1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(u.shape, vec![2, 2]);
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_shape() {
        let _ = Tensor::from_vec(&[2, 2], vec![1.0f32]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[4], vec![1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]);
        assert_eq!(t.data[3], 4.0);
    }

    #[test]
    fn index_helpers_are_row_major() {
        assert_eq!(idx3(5, 3, 1, 2, 0), (1 * 5 + 2) * 3);
        assert_eq!(idx4(4, 5, 3, 1, 2, 3, 0), ((1 * 4 + 2) * 5 + 3) * 3);
    }

    #[test]
    fn max_abs_and_diff() {
        let a = Tensor::from_vec(&[3], vec![1.0f32, -4.0, 2.0]);
        let b = Tensor::from_vec(&[3], vec![1.0f32, -4.5, 2.0]);
        assert_eq!(a.max_abs(), 4.0);
        assert_eq!(a.max_diff(&b), 0.5);
    }
}
