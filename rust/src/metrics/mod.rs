//! Evaluation metrics: accuracy, confusion matrix, latency aggregation.

/// Classification accuracy from predictions and labels.
pub fn accuracy(pred: &[usize], labels: &[i32]) -> f64 {
    assert_eq!(pred.len(), labels.len());
    if pred.is_empty() {
        return 0.0;
    }
    let correct = pred
        .iter()
        .zip(labels)
        .filter(|(&p, &y)| p as i32 == y)
        .count();
    correct as f64 / pred.len() as f64
}

/// Row-major confusion matrix: rows = truth, cols = prediction.
#[derive(Clone, Debug)]
pub struct Confusion {
    pub classes: usize,
    pub counts: Vec<u32>,
}

impl Confusion {
    pub fn new(classes: usize) -> Self {
        Self { classes, counts: vec![0; classes * classes] }
    }

    pub fn record(&mut self, truth: i32, pred: usize) {
        self.counts[truth as usize * self.classes + pred] += 1;
    }

    pub fn accuracy(&self) -> f64 {
        let total: u32 = self.counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let diag: u32 = (0..self.classes).map(|i| self.counts[i * self.classes + i]).sum();
        diag as f64 / total as f64
    }

    /// Per-class recall.
    pub fn recall(&self, class: usize) -> f64 {
        let row: u32 = self.counts[class * self.classes..(class + 1) * self.classes]
            .iter()
            .sum();
        if row == 0 {
            return 0.0;
        }
        self.counts[class * self.classes + class] as f64 / row as f64
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        for r in 0..self.classes {
            for c in 0..self.classes {
                s.push_str(&format!("{:>5}", self.counts[r * self.classes + c]));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0, 1, 2], &[0, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn confusion_tracks_diag() {
        let mut c = Confusion::new(3);
        c.record(0, 0);
        c.record(1, 1);
        c.record(2, 0);
        assert!((c.accuracy() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(c.recall(2), 0.0);
        assert_eq!(c.recall(0), 1.0);
        assert!(c.render().lines().count() == 3);
    }
}
