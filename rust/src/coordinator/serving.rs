//! Serving coordinator with the big/LITTLE DNN cascade (§8 future work,
//! citing Park et al. [58]): every request first runs a small model; when
//! the classifier's confidence is below a threshold, it escalates to the
//! large model. The router tracks per-request latency and energy using the
//! MCU cost models, so the demo reports the paper-style "fast path for
//! most inputs" effect.
//!
//! Implementation is std-threads + channels (tokio is unavailable
//! offline): a router thread feeds a worker pool; each worker owns clones
//! of the quantized graphs (weights are shared via Arc).

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use crate::mcu::board::Board;
use crate::nn::{argmax, int_exec};
use crate::quant::QuantizedGraph;
use crate::util::prng::Pcg32;
use crate::util::stats::{summarize, Summary};

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prediction: usize,
    pub confidence: f32,
    pub escalated: bool,
    /// Simulated on-device latency (ms) for this request.
    pub device_ms: f64,
    pub energy_uwh: f64,
}

/// Softmax max-probability confidence.
pub fn confidence(logits: &[f32]) -> f32 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    let exps: Vec<f32> = logits.iter().map(|&v| (v - m).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().fold(0.0f32, |a, &e| a.max(e)) / sum
}

pub struct CascadeConfig {
    pub threshold: f32,
    pub workers: usize,
    /// Simulated per-inference device latency (ms) for little/big models.
    pub little_ms: f64,
    pub big_ms: f64,
    pub board_power_w: f64,
}

pub struct CascadeStats {
    pub responses: Vec<Response>,
    pub latency: Summary,
    pub escalation_rate: f64,
    pub total_energy_uwh: f64,
    pub accuracy: Option<f64>,
}

/// Run the cascade over a request stream; blocking, returns when all
/// requests are answered. `labels` (optional) enables accuracy reporting.
pub fn run_cascade(
    little: Arc<QuantizedGraph>,
    big: Arc<QuantizedGraph>,
    cfg: &CascadeConfig,
    requests: Vec<Request>,
    labels: Option<&[i32]>,
) -> CascadeStats {
    let n = requests.len();
    let (work_tx, work_rx) = mpsc::channel::<Request>();
    let work_rx = Arc::new(std::sync::Mutex::new(work_rx));
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();

    let mut handles = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let rx = work_rx.clone();
        let tx = resp_tx.clone();
        let little = little.clone();
        let big = big.clone();
        let threshold = cfg.threshold;
        let (lm, bm, pw) = (cfg.little_ms, cfg.big_ms, cfg.board_power_w);
        handles.push(thread::spawn(move || loop {
            let req = match rx.lock().unwrap().recv() {
                Ok(r) => r,
                Err(_) => break,
            };
            let logits = int_exec::run(&little, &req.input);
            let conf = confidence(&logits);
            let (pred, conf, escalated, ms) = if conf < threshold {
                let big_logits = int_exec::run(&big, &req.input);
                (argmax(&big_logits), confidence(&big_logits), true, lm + bm)
            } else {
                (argmax(&logits), conf, false, lm)
            };
            let energy = ms / 1e3 * pw / 3600.0 * 1e6;
            let _ = tx.send(Response {
                id: req.id,
                prediction: pred,
                confidence: conf,
                escalated,
                device_ms: ms,
                energy_uwh: energy,
            });
        }));
    }
    drop(resp_tx);

    for r in requests {
        work_tx.send(r).unwrap();
    }
    drop(work_tx);

    let mut responses: Vec<Response> = resp_rx.iter().collect();
    for h in handles {
        let _ = h.join();
    }
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), n, "router lost requests");

    let lat: Vec<f64> = responses.iter().map(|r| r.device_ms).collect();
    let esc = responses.iter().filter(|r| r.escalated).count() as f64 / n.max(1) as f64;
    let energy: f64 = responses.iter().map(|r| r.energy_uwh).sum();
    let accuracy = labels.map(|ys| {
        responses
            .iter()
            .filter(|r| r.prediction as i32 == ys[r.id as usize])
            .count() as f64
            / n.max(1) as f64
    });
    CascadeStats {
        responses,
        latency: summarize(&lat),
        escalation_rate: esc,
        total_energy_uwh: energy,
        accuracy,
    }
}

/// Build a synthetic Poisson request stream from test examples.
pub fn request_stream(
    data: &crate::datasets::RawDataModel,
    n: usize,
    seed: u64,
) -> (Vec<Request>, Vec<i32>) {
    let mut rng = Pcg32::seeded(seed);
    let mut reqs = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for id in 0..n {
        let i = rng.below(data.n_test() as u32) as usize;
        reqs.push(Request { id: id as u64, input: data.test_example(i).to_vec() });
        labels.push(data.test_y[i]);
    }
    (reqs, labels)
}

/// Device latency for a graph under the MicroAI engine on `board` (ms).
pub fn device_latency_ms(graph: &crate::graph::Graph, board: &Board, dtype: crate::mcu::DType) -> f64 {
    crate::engines::microai()
        .latency_s(graph, board, dtype)
        .map(|s| s * 1e3)
        .unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ir::LayerKind;
    use crate::graph::{deploy_pipeline, resnet_v1_6_shapes};
    use crate::nn::float_exec::ActStats;
    use crate::quant::{quantize, QuantSpec};

    fn tiny_qgraph(filters: usize, seed: u64) -> Arc<QuantizedGraph> {
        let mut g = resnet_v1_6_shapes("t", 1, &[32, 3], 4, filters);
        let mut rng = Pcg32::seeded(seed);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.4;
                }
                for v in b.data.iter_mut() {
                    *v = 0.01;
                }
            }
        }
        let g = deploy_pipeline(&g);
        let mut stats = ActStats::new(g.nodes.len());
        let mut rng = Pcg32::seeded(seed + 9);
        for _ in 0..6 {
            let x: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
            crate::nn::float_exec::run(&g, &x, Some(&mut stats));
        }
        Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()))
    }

    fn requests(n: usize, seed: u64) -> Vec<Request> {
        let mut rng = Pcg32::seeded(seed);
        (0..n)
            .map(|id| Request {
                id: id as u64,
                input: (0..96).map(|_| rng.normal()).collect(),
            })
            .collect()
    }

    #[test]
    fn no_request_lost_and_ordered() {
        let little = tiny_qgraph(4, 1);
        let big = tiny_qgraph(8, 2);
        let cfg = CascadeConfig {
            threshold: 0.5,
            workers: 4,
            little_ms: 10.0,
            big_ms: 40.0,
            board_power_w: 0.0027,
        };
        let stats = run_cascade(little, big, &cfg, requests(64, 3), None);
        assert_eq!(stats.responses.len(), 64);
        for (i, r) in stats.responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn threshold_one_always_escalates_threshold_zero_never() {
        let little = tiny_qgraph(4, 4);
        let big = tiny_qgraph(8, 5);
        let base = CascadeConfig {
            threshold: 0.0,
            workers: 2,
            little_ms: 10.0,
            big_ms: 40.0,
            board_power_w: 0.0027,
        };
        let s0 = run_cascade(little.clone(), big.clone(), &base, requests(32, 6), None);
        assert_eq!(s0.escalation_rate, 0.0);
        let cfg1 = CascadeConfig { threshold: 1.01, ..base };
        let s1 = run_cascade(little, big, &cfg1, requests(32, 6), None);
        assert_eq!(s1.escalation_rate, 1.0);
        // Full escalation costs little+big latency on every request.
        assert!(s1.latency.p50 > s0.latency.p50);
    }

    #[test]
    fn escalated_latency_is_sum_of_both() {
        let little = tiny_qgraph(4, 7);
        let big = tiny_qgraph(8, 8);
        let cfg = CascadeConfig {
            threshold: 1.01,
            workers: 1,
            little_ms: 7.0,
            big_ms: 13.0,
            board_power_w: 0.0027,
        };
        let s = run_cascade(little, big, &cfg, requests(8, 9), None);
        for r in &s.responses {
            assert!((r.device_ms - 20.0).abs() < 1e-9);
            assert!(r.escalated);
        }
    }

    #[test]
    fn confidence_is_a_probability() {
        let c = confidence(&[1.0, 2.0, 3.0]);
        assert!((0.0..=1.0).contains(&c));
        assert!(confidence(&[10.0, -10.0]) > 0.99);
    }
}
