//! Serving coordinator with the big/LITTLE DNN cascade (§8 future work,
//! citing Park et al. [58]): every request first runs a small model; when
//! the classifier's confidence is below a threshold, it escalates to the
//! large model.
//!
//! Workers own [`Session`]s (compile-once/run-many: weights shared via
//! `Arc`, activation arenas preallocated per worker), and per-request
//! latency/energy comes from the session metadata — i.e. from the
//! calibrated `mcu::cost` models for the configured board — instead of
//! hand-wired simulation constants.
//!
//! Implementation is std-threads + channels (tokio is unavailable
//! offline): a router thread feeds a worker pool.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use crate::mcu::board::{Board, SPARKFUN_EDGE};
use crate::nn::session::{Session, SessionBuilder};
use crate::quant::QuantizedGraph;
use crate::util::prng::Pcg32;
use crate::util::stats::{summarize, Summary};

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prediction: usize,
    pub confidence: f32,
    pub escalated: bool,
    /// Predicted on-device latency (ms) for this request, from the
    /// session metadata (little, plus big when escalated).
    pub device_ms: f64,
    pub energy_uwh: f64,
}

/// Softmax max-probability confidence.
pub fn confidence(logits: &[f32]) -> f32 {
    crate::nn::session::confidence(logits)
}

pub struct CascadeConfig {
    pub threshold: f32,
    pub workers: usize,
    /// Deployment board the cascade is priced on; session metadata
    /// supplies per-model latency/energy via `mcu::cost`.
    pub board: &'static Board,
}

impl Default for CascadeConfig {
    fn default() -> Self {
        CascadeConfig { threshold: 0.8, workers: 4, board: &SPARKFUN_EDGE }
    }
}

pub struct CascadeStats {
    pub responses: Vec<Response>,
    pub latency: Summary,
    pub escalation_rate: f64,
    pub total_energy_uwh: f64,
    pub accuracy: Option<f64>,
}

/// One worker's pair of sessions plus their metadata-derived prices.
struct CascadeWorker {
    little: Session,
    big: Session,
    threshold: f32,
    little_ms: f64,
    big_ms: f64,
    little_uwh: f64,
    big_uwh: f64,
}

impl CascadeWorker {
    fn new(little: &Session, big: &Session, threshold: f32) -> CascadeWorker {
        let (lm, bm) = (little.meta(), big.meta());
        CascadeWorker {
            little_ms: lm.device_latency_ms.unwrap_or(0.0),
            big_ms: bm.device_latency_ms.unwrap_or(0.0),
            little_uwh: lm.device_energy_uwh.unwrap_or(0.0),
            big_uwh: bm.device_energy_uwh.unwrap_or(0.0),
            little: little.fork(),
            big: big.fork(),
            threshold,
        }
    }

    fn serve(&mut self, req: &Request) -> Response {
        let pred = self.little.classify(&req.input);
        let (pred, escalated, ms, uwh) = if pred.confidence < self.threshold {
            (
                self.big.classify(&req.input),
                true,
                self.little_ms + self.big_ms,
                self.little_uwh + self.big_uwh,
            )
        } else {
            (pred, false, self.little_ms, self.little_uwh)
        };
        Response {
            id: req.id,
            prediction: pred.class,
            confidence: pred.confidence,
            escalated,
            device_ms: ms,
            energy_uwh: uwh,
        }
    }
}

/// Run the cascade over a request stream; blocking, returns when all
/// requests are answered. `labels` (optional) enables accuracy reporting.
pub fn run_cascade(
    little: Arc<QuantizedGraph>,
    big: Arc<QuantizedGraph>,
    cfg: &CascadeConfig,
    requests: Vec<Request>,
    labels: Option<&[i32]>,
) -> CascadeStats {
    let n = requests.len();
    // Compile once: template sessions carry the cost metadata; workers
    // fork them (shared weights, private arenas).
    let little_t = SessionBuilder::fixed_qmn(little).board(cfg.board).build();
    let big_t = SessionBuilder::fixed_qmn(big).board(cfg.board).build();

    let (work_tx, work_rx) = mpsc::channel::<Request>();
    let work_rx = Arc::new(std::sync::Mutex::new(work_rx));
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();

    let mut handles = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let rx = work_rx.clone();
        let tx = resp_tx.clone();
        let mut worker = CascadeWorker::new(&little_t, &big_t, cfg.threshold);
        handles.push(thread::spawn(move || loop {
            let req = match rx.lock().unwrap().recv() {
                Ok(r) => r,
                Err(_) => break,
            };
            let _ = tx.send(worker.serve(&req));
        }));
    }
    drop(resp_tx);

    for r in requests {
        work_tx.send(r).unwrap();
    }
    drop(work_tx);

    let mut responses: Vec<Response> = resp_rx.iter().collect();
    for h in handles {
        let _ = h.join();
    }
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), n, "router lost requests");

    let lat: Vec<f64> = responses.iter().map(|r| r.device_ms).collect();
    let esc = responses.iter().filter(|r| r.escalated).count() as f64 / n.max(1) as f64;
    let energy: f64 = responses.iter().map(|r| r.energy_uwh).sum();
    let accuracy = labels.map(|ys| {
        responses
            .iter()
            .filter(|r| r.prediction as i32 == ys[r.id as usize])
            .count() as f64
            / n.max(1) as f64
    });
    CascadeStats {
        responses,
        latency: summarize(&lat),
        escalation_rate: esc,
        total_energy_uwh: energy,
        accuracy,
    }
}

/// Build a synthetic Poisson request stream from test examples.
pub fn request_stream(
    data: &crate::datasets::RawDataModel,
    n: usize,
    seed: u64,
) -> (Vec<Request>, Vec<i32>) {
    let mut rng = Pcg32::seeded(seed);
    let mut reqs = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for id in 0..n {
        let i = rng.below(data.n_test() as u32) as usize;
        reqs.push(Request { id: id as u64, input: data.test_example(i).to_vec() });
        labels.push(data.test_y[i]);
    }
    (reqs, labels)
}

/// Device latency for a graph under the MicroAI engine on `board` (ms).
pub fn device_latency_ms(graph: &crate::graph::Graph, board: &Board, dtype: crate::mcu::DType) -> f64 {
    crate::engines::microai()
        .latency_s(graph, board, dtype)
        .map(|s| s * 1e3)
        .unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ir::LayerKind;
    use crate::graph::{deploy_pipeline, resnet_v1_6_shapes};
    use crate::mcu::board::NUCLEO_L452RE_P;
    use crate::nn::float_exec::ActStats;
    use crate::quant::{quantize, QuantSpec};

    fn tiny_qgraph(filters: usize, seed: u64) -> Arc<QuantizedGraph> {
        let mut g = resnet_v1_6_shapes("t", 1, &[32, 3], 4, filters);
        let mut rng = Pcg32::seeded(seed);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.4;
                }
                for v in b.data.iter_mut() {
                    *v = 0.01;
                }
            }
        }
        let g = deploy_pipeline(&g);
        let mut stats = ActStats::new(g.nodes.len());
        let mut rng = Pcg32::seeded(seed + 9);
        for _ in 0..6 {
            let x: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
            crate::nn::float_exec::run(&g, &x, Some(&mut stats));
        }
        Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()))
    }

    fn requests(n: usize, seed: u64) -> Vec<Request> {
        let mut rng = Pcg32::seeded(seed);
        (0..n)
            .map(|id| Request {
                id: id as u64,
                input: (0..96).map(|_| rng.normal()).collect(),
            })
            .collect()
    }

    #[test]
    fn no_request_lost_and_ordered() {
        let little = tiny_qgraph(4, 1);
        let big = tiny_qgraph(8, 2);
        let cfg = CascadeConfig { threshold: 0.5, workers: 4, board: &SPARKFUN_EDGE };
        let stats = run_cascade(little, big, &cfg, requests(64, 3), None);
        assert_eq!(stats.responses.len(), 64);
        for (i, r) in stats.responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn threshold_one_always_escalates_threshold_zero_never() {
        let little = tiny_qgraph(4, 4);
        let big = tiny_qgraph(8, 5);
        let base = CascadeConfig { threshold: 0.0, workers: 2, board: &SPARKFUN_EDGE };
        let s0 = run_cascade(little.clone(), big.clone(), &base, requests(32, 6), None);
        assert_eq!(s0.escalation_rate, 0.0);
        let cfg1 = CascadeConfig { threshold: 1.01, ..base };
        let s1 = run_cascade(little, big, &cfg1, requests(32, 6), None);
        assert_eq!(s1.escalation_rate, 1.0);
        // Full escalation costs little+big latency on every request.
        assert!(s1.latency.p50 > s0.latency.p50);
    }

    #[test]
    fn latency_and_energy_come_from_session_metadata() {
        let little = tiny_qgraph(4, 7);
        let big = tiny_qgraph(8, 8);
        // Expected prices straight from session metadata on this board.
        let lm = SessionBuilder::fixed_qmn(little.clone()).board(&NUCLEO_L452RE_P).build();
        let bm = SessionBuilder::fixed_qmn(big.clone()).board(&NUCLEO_L452RE_P).build();
        let exp_ms = lm.meta().device_latency_ms.unwrap() + bm.meta().device_latency_ms.unwrap();
        let exp_uwh = lm.meta().device_energy_uwh.unwrap() + bm.meta().device_energy_uwh.unwrap();
        assert!(exp_ms > 0.0 && exp_uwh > 0.0);

        let cfg = CascadeConfig { threshold: 1.01, workers: 1, board: &NUCLEO_L452RE_P };
        let s = run_cascade(little, big, &cfg, requests(8, 9), None);
        for r in &s.responses {
            assert!(r.escalated);
            assert!((r.device_ms - exp_ms).abs() < 1e-9);
            assert!((r.energy_uwh - exp_uwh).abs() < 1e-12);
        }
    }

    #[test]
    fn bigger_model_costs_more_on_the_same_board() {
        let little = tiny_qgraph(4, 10);
        let big = tiny_qgraph(16, 11);
        let ls = SessionBuilder::fixed_qmn(little).board(&SPARKFUN_EDGE).build();
        let bs = SessionBuilder::fixed_qmn(big).board(&SPARKFUN_EDGE).build();
        assert!(
            bs.meta().device_latency_ms.unwrap() > ls.meta().device_latency_ms.unwrap()
        );
        assert!(
            bs.meta().device_energy_uwh.unwrap() > ls.meta().device_energy_uwh.unwrap()
        );
    }

    #[test]
    fn confidence_is_a_probability() {
        let c = confidence(&[1.0, 2.0, 3.0]);
        assert!((0.0..=1.0).contains(&c));
        assert!(confidence(&[10.0, -10.0]) > 0.99);
    }
}
