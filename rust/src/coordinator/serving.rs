//! Serving coordinator with the big/LITTLE DNN cascade (§8 future work,
//! citing Park et al. [58]): every request first runs a small model; when
//! the classifier's confidence is below a threshold, it escalates to the
//! large model.
//!
//! # Scheduler
//!
//! Requests flow through a **sharded, batch-aware scheduler**:
//!
//! - one bounded queue per worker ([`std::sync::mpsc::sync_channel`]), so
//!   a slow worker exerts backpressure on the router instead of growing an
//!   unbounded backlog — there is no shared `Mutex<Receiver>` lock convoy;
//! - the router groups consecutive requests into micro-batches of up to
//!   [`CascadeConfig::max_batch`] and dispatches each batch to the
//!   **least-loaded** worker (pending-request count), breaking ties
//!   round-robin so equal load still spreads;
//! - workers own forked [`Session`]s (weights shared via `Arc`, activation
//!   arenas sized for [`CascadeConfig::max_batch`] examples via
//!   [`crate::nn::ForkOpts`]) and run the little model over the whole
//!   micro-batch through ONE [`Session::infer`] call — dense and 1×1
//!   stride-1 conv layers fold the batch into one GEMM — then escalate
//!   the low-confidence subset to the big model as a second batch;
//! - each worker session may additionally run its GEMM kernels across an
//!   intra-op thread pool ([`CascadeConfig::intra_op_threads`], bit-exact
//!   vs serial); the scheduler caps `workers × intra_op_threads` at the
//!   host's available parallelism ([`effective_intra_op_threads`]) so the
//!   two layers of parallelism never oversubscribe the cores.
//!
//! # Simulated time: `queue_ms` vs `device_ms`
//!
//! Latency/energy prices come from the session metadata (the calibrated
//! `mcu::cost` models), not from host wall time. An **open-loop Poisson
//! arrival clock** ([`CascadeConfig::arrival_rate_hz`]) stamps each
//! request with an arrival time; every worker advances a private virtual
//! clock by the device latency of each request it serves, in FIFO order.
//! A [`Response`] therefore reports
//!
//! - `queue_ms` — time between arrival and service start (the worker was
//!   still draining earlier requests), and
//! - `device_ms` — predicted on-device inference time (little, plus big
//!   when escalated),
//!
//! separately; total simulated latency is their sum. When a session
//! carries **no cost model** (no board attached), `device_ms`/`energy_uwh`
//! are `None` and the virtual clock cannot advance — the cascade still
//! classifies, but reports no latency/energy instead of silently pricing
//! requests at 0.0 (see [`CascadeStats`]).
//!
//! One deliberate approximation: request→worker assignment is made by
//! the *host* scheduler (live pending counts), while queue delays are
//! computed on the per-worker *virtual* clocks that assignment produces.
//! `CascadeConfig::seed` therefore makes the arrival process reproducible
//! but not the queue statistics — they are conditioned on the actual
//! host-time assignment of that run. Predictions, escalations and device
//! prices are always deterministic.
//!
//! Implementation is std-threads + channels (tokio is unavailable
//! offline).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use crate::mcu::board::{Board, SPARKFUN_EDGE};
use crate::nn::session::{Batch, ForkOpts, Predictions, Session, SessionBuilder};
use crate::quant::QuantizedGraph;
use crate::util::prng::Pcg32;
use crate::util::stats::{summarize, Summary};

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub prediction: usize,
    pub confidence: f32,
    pub escalated: bool,
    /// Simulated queueing delay (ms): arrival → service start on the
    /// worker's virtual clock. 0.0 when the worker was idle at arrival,
    /// and always 0.0 when the sessions carry no cost model (service
    /// times are unknown, so the virtual clock cannot advance).
    pub queue_ms: f64,
    /// Predicted on-device latency (ms) for this request, from the
    /// session metadata (little, plus big when escalated). `None` when
    /// the sessions carry no board cost model — never silently 0.0.
    pub device_ms: Option<f64>,
    /// Predicted energy (µWh); same `None` semantics as `device_ms`.
    pub energy_uwh: Option<f64>,
}

impl Response {
    /// Total simulated latency: queueing delay + device time.
    pub fn total_ms(&self) -> Option<f64> {
        self.device_ms.map(|d| d + self.queue_ms)
    }
}

/// Softmax max-probability confidence.
pub fn confidence(logits: &[f32]) -> f32 {
    crate::nn::session::confidence(logits)
}

#[derive(Clone, Copy, Debug)]
pub struct CascadeConfig {
    pub threshold: f32,
    pub workers: usize,
    /// Deployment board the cascade is priced on; session metadata
    /// supplies per-model latency/energy via `mcu::cost`.
    pub board: &'static Board,
    /// Micro-batch size: consecutive requests dispatched to one worker as
    /// a unit and run through one arena. 1 = unbatched.
    pub max_batch: usize,
    /// Per-worker queue bound, in batches. A full queue blocks the router
    /// (backpressure) instead of growing an unbounded backlog.
    pub queue_cap: usize,
    /// Open-loop Poisson arrival rate (requests/s) for the simulated
    /// arrival clock. `<= 0.0` means all requests arrive at t = 0 (pure
    /// backlog drain — maximum queueing).
    pub arrival_rate_hz: f64,
    /// Seed for the arrival clock's exponential inter-arrival draws.
    pub seed: u64,
    /// Requested intra-op GEMM threads per worker session (host-side
    /// kernel parallelism; 1 = serial). The scheduler caps the actual
    /// budget so `workers × intra_op_threads` never exceeds the host's
    /// available parallelism ([`effective_intra_op_threads`]) —
    /// oversubscribing cores would add context-switch latency to every
    /// request instead of throughput.
    pub intra_op_threads: usize,
}

impl Default for CascadeConfig {
    fn default() -> Self {
        CascadeConfig {
            threshold: 0.8,
            workers: 4,
            board: &SPARKFUN_EDGE,
            max_batch: 8,
            queue_cap: 4,
            arrival_rate_hz: 0.0,
            seed: 0x5EED,
            intra_op_threads: 1,
        }
    }
}

/// Intra-op thread budget each worker session actually gets: the
/// requested budget, capped so the whole pool (`workers` worker threads,
/// each owning a GEMM pool of this size) fits in `available` hardware
/// threads. Never below 1 — a single worker on a single-core host still
/// serves, just serially.
pub fn effective_intra_op_threads(workers: usize, requested: usize, available: usize) -> usize {
    let per_worker_budget = available.max(1) / workers.max(1);
    requested.max(1).min(per_worker_budget.max(1))
}

/// Aggregate serving statistics.
///
/// Cost-derived fields are `Option`: they are `Some` only when both
/// cascade sessions carry a board cost model. A cascade over board-less
/// sessions (built via [`run_cascade_sessions`] without
/// [`SessionBuilder::board`]) reports `None` — it does NOT report
/// zero-cost serving.
pub struct CascadeStats {
    pub responses: Vec<Response>,
    /// Total simulated latency (queue + device) per request.
    pub latency: Option<Summary>,
    /// Device-only latency per request.
    pub device_latency: Option<Summary>,
    /// Queueing delay per request (all-zero when unpriced).
    pub queue_latency: Summary,
    /// Pending-request depth of the chosen worker's queue, sampled at
    /// each batch dispatch (includes the batch just enqueued).
    pub queue_depth: Summary,
    /// Per-worker fraction of the simulated makespan spent serving.
    pub worker_utilization: Vec<f64>,
    pub escalation_rate: f64,
    pub total_energy_uwh: Option<f64>,
    /// Accuracy over requests whose id has a label (`None` when no label
    /// matched any request id).
    pub accuracy: Option<f64>,
    /// How many responses were matched against a label.
    pub matched_labels: usize,
    /// Host wall-clock time of the whole run (scheduler throughput, NOT
    /// simulated device time).
    pub wall_ms: f64,
    /// Host-side requests/s of the scheduler (`n / wall`).
    pub throughput_rps: f64,
}

/// Per-model prices from session metadata; present only when both
/// sessions carry a cost model.
#[derive(Clone, Copy, Debug)]
struct CascadePrices {
    little_ms: f64,
    big_ms: f64,
    little_uwh: f64,
    big_uwh: f64,
}

/// A request stamped with its simulated arrival time.
struct Scheduled {
    req: Request,
    arrival_ms: f64,
}

/// One worker's pair of sessions, prices, virtual clock and reusable
/// batch scratch buffers.
struct CascadeWorker {
    little: Session,
    big: Session,
    threshold: f32,
    prices: Option<CascadePrices>,
    /// Virtual clock: when this worker finishes its last accepted request.
    clock_ms: f64,
    /// Total device time served (utilization numerator).
    busy_ms: f64,
    preds: Predictions,
    esc_idx: Vec<usize>,
    esc_preds: Predictions,
    /// Contiguous staging of one micro-batch's inputs (little pass).
    batch_buf: Vec<f32>,
    /// Contiguous staging of the escalated subset (big pass).
    esc_buf: Vec<f32>,
}

impl CascadeWorker {
    fn new(little: &Session, big: &Session, threshold: f32, opts: ForkOpts) -> CascadeWorker {
        let (lm, bm) = (little.meta(), big.meta());
        // A board-attached session whose engine failed to price it is a
        // configuration bug (cost model not covering the board/dtype) —
        // surface it instead of serving silent zeros.
        debug_assert!(
            lm.board.is_none() || (lm.device_latency_ms.is_some() && lm.device_energy_uwh.is_some()),
            "little session has a board but no cost model (engine does not cover board/dtype)"
        );
        debug_assert!(
            bm.board.is_none() || (bm.device_latency_ms.is_some() && bm.device_energy_uwh.is_some()),
            "big session has a board but no cost model (engine does not cover board/dtype)"
        );
        let prices = match (
            lm.device_latency_ms,
            bm.device_latency_ms,
            lm.device_energy_uwh,
            bm.device_energy_uwh,
        ) {
            (Some(little_ms), Some(big_ms), Some(little_uwh), Some(big_uwh)) => {
                Some(CascadePrices { little_ms, big_ms, little_uwh, big_uwh })
            }
            _ => None,
        };
        CascadeWorker {
            little: little.fork_with(opts),
            big: big.fork_with(opts),
            threshold,
            prices,
            clock_ms: 0.0,
            busy_ms: 0.0,
            preds: Vec::new(),
            esc_idx: Vec::new(),
            esc_preds: Vec::new(),
            batch_buf: Vec::new(),
            esc_buf: Vec::new(),
        }
    }

    /// Serve one micro-batch: stage the inputs contiguously and run
    /// little over the whole batch through ONE [`Session::infer`] call
    /// (batch-folded GEMMs, bit-exact vs per-example), then the
    /// low-confidence subset through big as a second batch. Queue
    /// accounting is FIFO on this worker's virtual clock.
    fn serve_batch(&mut self, batch: &[Scheduled], out: &mut Vec<Response>) {
        let ilen = self.little.input_len();
        self.batch_buf.clear();
        for s in batch {
            assert_eq!(s.req.input.len(), ilen, "example/input length mismatch");
            self.batch_buf.extend_from_slice(&s.req.input);
        }
        self.preds.clear();
        self.little.infer(&Batch::contiguous(&self.batch_buf, ilen), &mut self.preds);

        self.esc_idx.clear();
        for (i, p) in self.preds.iter().enumerate() {
            if p.confidence < self.threshold {
                self.esc_idx.push(i);
            }
        }
        self.esc_buf.clear();
        for &i in &self.esc_idx {
            self.esc_buf.extend_from_slice(&batch[i].req.input);
        }
        self.esc_preds.clear();
        self.big.infer(&Batch::contiguous(&self.esc_buf, ilen), &mut self.esc_preds);

        let mut esc_cursor = 0usize;
        for (i, s) in batch.iter().enumerate() {
            let escalated = self.esc_idx.get(esc_cursor) == Some(&i);
            let pred = if escalated {
                let p = self.esc_preds[esc_cursor];
                esc_cursor += 1;
                p
            } else {
                self.preds[i]
            };
            let (device_ms, energy_uwh) = match self.prices {
                Some(p) if escalated => {
                    (Some(p.little_ms + p.big_ms), Some(p.little_uwh + p.big_uwh))
                }
                Some(p) => (Some(p.little_ms), Some(p.little_uwh)),
                None => (None, None),
            };
            let start = self.clock_ms.max(s.arrival_ms);
            let service = device_ms.unwrap_or(0.0);
            self.clock_ms = start + service;
            self.busy_ms += service;
            out.push(Response {
                id: s.req.id,
                prediction: pred.class,
                confidence: pred.confidence,
                escalated,
                queue_ms: start - s.arrival_ms,
                device_ms,
                energy_uwh,
            });
        }
    }
}

/// Final accounting a worker thread returns when its queue closes.
struct WorkerReport {
    busy_ms: f64,
    clock_ms: f64,
}

/// Run the cascade over a request stream; blocking, returns when all
/// requests are answered. `labels` (optional) enables accuracy reporting:
/// `labels[id]` is matched per response by checked lookup, so a label
/// slice shorter than the stream (or sparse request ids) only shrinks the
/// matched count — it never panics.
pub fn run_cascade(
    little: Arc<QuantizedGraph>,
    big: Arc<QuantizedGraph>,
    cfg: &CascadeConfig,
    requests: Vec<Request>,
    labels: Option<&[i32]>,
) -> CascadeStats {
    // Compile once: template sessions carry the cost metadata; workers
    // fork them (shared weights, private arenas).
    let little_t = SessionBuilder::fixed_qmn(little).board(cfg.board).build();
    let big_t = SessionBuilder::fixed_qmn(big).board(cfg.board).build();
    run_cascade_sessions(&little_t, &big_t, cfg, requests, labels)
}

/// Like [`run_cascade`], over caller-built template sessions (any boards —
/// including none, in which case all cost-derived stats are `None`).
pub fn run_cascade_sessions(
    little: &Session,
    big: &Session,
    cfg: &CascadeConfig,
    requests: Vec<Request>,
    labels: Option<&[i32]>,
) -> CascadeStats {
    let n = requests.len();
    let workers = cfg.workers.max(1);
    let max_batch = cfg.max_batch.max(1);
    let queue_cap = cfg.queue_cap.max(1);
    // Cap intra-op parallelism against what the host actually has, so
    // worker × GEMM threads never oversubscribe the cores.
    let available = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let intra = effective_intra_op_threads(workers, cfg.intra_op_threads, available);
    let t0 = Instant::now();

    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    let mut work_txs = Vec::with_capacity(workers);
    let mut pending: Vec<Arc<AtomicUsize>> = Vec::with_capacity(workers);
    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (tx, rx) = mpsc::sync_channel::<Vec<Scheduled>>(queue_cap);
        work_txs.push(tx);
        let depth = Arc::new(AtomicUsize::new(0));
        pending.push(depth.clone());
        let resp = resp_tx.clone();
        let opts = ForkOpts::inherit().threads(intra).max_batch(max_batch);
        let mut worker = CascadeWorker::new(little, big, cfg.threshold, opts);
        handles.push(thread::spawn(move || {
            let mut out = Vec::new();
            while let Ok(batch) = rx.recv() {
                out.clear();
                worker.serve_batch(&batch, &mut out);
                for r in out.drain(..) {
                    let _ = resp.send(r);
                }
                depth.fetch_sub(batch.len(), Ordering::AcqRel);
            }
            WorkerReport { busy_ms: worker.busy_ms, clock_ms: worker.clock_ms }
        }));
    }
    drop(resp_tx);

    // Router: stamp arrivals, micro-batch, dispatch least-loaded with a
    // round-robin tiebreak cursor. A full target queue blocks the send —
    // that is the backpressure path.
    let mut rng = Pcg32::seeded(cfg.seed);
    let mut arrival_ms = 0.0f64;
    let mut cursor = 0usize;
    let mut depth_samples: Vec<f64> = Vec::with_capacity(n / max_batch + 1);
    let mut it = requests.into_iter();
    loop {
        let batch: Vec<Scheduled> = it
            .by_ref()
            .take(max_batch)
            .map(|req| {
                if cfg.arrival_rate_hz > 0.0 {
                    arrival_ms += rng.exponential(cfg.arrival_rate_hz) * 1e3;
                }
                Scheduled { req, arrival_ms }
            })
            .collect();
        if batch.is_empty() {
            break;
        }
        let mut best = cursor;
        let mut best_depth = usize::MAX;
        for k in 0..workers {
            let w = (cursor + k) % workers;
            let d = pending[w].load(Ordering::Acquire);
            if d < best_depth {
                best_depth = d;
                best = w;
            }
        }
        cursor = (best + 1) % workers;
        let len = batch.len();
        pending[best].fetch_add(len, Ordering::AcqRel);
        depth_samples.push((best_depth + len) as f64);
        work_txs[best].send(batch).expect("worker queue closed early");
    }
    drop(work_txs);

    let mut responses: Vec<Response> = resp_rx.iter().collect();
    let mut reports = Vec::with_capacity(workers);
    for h in handles {
        reports.push(h.join().expect("worker panicked"));
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), n, "scheduler lost requests");

    let priced = !responses.is_empty() && responses.iter().all(|r| r.device_ms.is_some());
    let device: Vec<f64> = responses.iter().filter_map(|r| r.device_ms).collect();
    let total: Vec<f64> = responses.iter().filter_map(|r| r.total_ms()).collect();
    let queue: Vec<f64> = responses.iter().map(|r| r.queue_ms).collect();
    let esc = responses.iter().filter(|r| r.escalated).count() as f64 / n.max(1) as f64;
    let total_energy_uwh = if priced {
        Some(responses.iter().filter_map(|r| r.energy_uwh).sum())
    } else {
        None
    };

    // Checked label lookup: only pairs where the response id indexes into
    // `labels` count; short or sparse label slices are fine.
    let mut matched = 0usize;
    let mut correct = 0usize;
    if let Some(ys) = labels {
        for r in &responses {
            if let Some(&y) = usize::try_from(r.id).ok().and_then(|i| ys.get(i)) {
                matched += 1;
                if r.prediction as i32 == y {
                    correct += 1;
                }
            }
        }
    }
    let accuracy = (matched > 0).then(|| correct as f64 / matched as f64);

    let makespan = reports.iter().fold(0.0f64, |a, r| a.max(r.clock_ms));
    let worker_utilization = reports
        .iter()
        .map(|r| if makespan > 0.0 { r.busy_ms / makespan } else { 0.0 })
        .collect();

    CascadeStats {
        latency: priced.then(|| summarize(&total)),
        device_latency: priced.then(|| summarize(&device)),
        queue_latency: summarize(&queue),
        queue_depth: summarize(&depth_samples),
        worker_utilization,
        escalation_rate: esc,
        total_energy_uwh,
        accuracy,
        matched_labels: matched,
        wall_ms,
        throughput_rps: if wall_ms > 0.0 { n as f64 / (wall_ms / 1e3) } else { 0.0 },
        responses,
    }
}

/// The PR-1 scheduler, kept as the benchmark baseline: ONE shared channel
/// behind a `Mutex<Receiver>` (a lock convoy at high worker counts),
/// strictly one request per dispatch, no arrival clock and therefore no
/// queue accounting (`queue_ms` is 0.0 on every response).
/// `bench_serving` compares [`run_cascade_sessions`] against this.
pub fn run_cascade_single_channel(
    little: &Session,
    big: &Session,
    threshold: f32,
    workers: usize,
    requests: Vec<Request>,
) -> Vec<Response> {
    let n = requests.len();
    let (work_tx, work_rx) = mpsc::channel::<Request>();
    let work_rx = Arc::new(std::sync::Mutex::new(work_rx));
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();

    let mut handles = Vec::new();
    for _ in 0..workers.max(1) {
        let rx = work_rx.clone();
        let tx = resp_tx.clone();
        let opts = ForkOpts::inherit().threads(1).max_batch(1);
        let mut worker = CascadeWorker::new(little, big, threshold, opts);
        handles.push(thread::spawn(move || {
            let mut out = Vec::new();
            loop {
                let req = match rx.lock().unwrap().recv() {
                    Ok(r) => r,
                    Err(_) => break,
                };
                out.clear();
                worker.serve_batch(&[Scheduled { req, arrival_ms: 0.0 }], &mut out);
                for mut r in out.drain(..) {
                    r.queue_ms = 0.0; // no arrival clock in this baseline
                    let _ = tx.send(r);
                }
            }
        }));
    }
    drop(resp_tx);

    for r in requests {
        work_tx.send(r).unwrap();
    }
    drop(work_tx);

    let mut responses: Vec<Response> = resp_rx.iter().collect();
    for h in handles {
        let _ = h.join();
    }
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), n, "router lost requests");
    responses
}

/// Build a synthetic request stream from test examples (ids are dense;
/// labels align with ids).
pub fn request_stream(
    data: &crate::datasets::RawDataModel,
    n: usize,
    seed: u64,
) -> (Vec<Request>, Vec<i32>) {
    let mut rng = Pcg32::seeded(seed);
    let mut reqs = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for id in 0..n {
        let i = rng.below(data.n_test() as u32) as usize;
        reqs.push(Request { id: id as u64, input: data.test_example(i).to_vec() });
        labels.push(data.test_y[i]);
    }
    (reqs, labels)
}

/// Device latency for a graph under the MicroAI engine on `board` (ms).
pub fn device_latency_ms(graph: &crate::graph::Graph, board: &Board, dtype: crate::mcu::DType) -> f64 {
    crate::engines::microai()
        .latency_s(graph, board, dtype)
        .map(|s| s * 1e3)
        .unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ir::LayerKind;
    use crate::graph::{deploy_pipeline, resnet_v1_6_shapes};
    use crate::mcu::board::NUCLEO_L452RE_P;
    use crate::nn::float_exec::ActStats;
    use crate::quant::{quantize, QuantSpec};

    fn tiny_qgraph(filters: usize, seed: u64) -> Arc<QuantizedGraph> {
        let mut g = resnet_v1_6_shapes("t", 1, &[32, 3], 4, filters);
        let mut rng = Pcg32::seeded(seed);
        for n in g.nodes.iter_mut() {
            if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.4;
                }
                for v in b.data.iter_mut() {
                    *v = 0.01;
                }
            }
        }
        let g = deploy_pipeline(&g);
        let mut stats = ActStats::new(g.nodes.len());
        let mut rng = Pcg32::seeded(seed + 9);
        for _ in 0..6 {
            let x: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
            crate::nn::float_exec::run(&g, &x, Some(&mut stats));
        }
        Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()))
    }

    fn requests(n: usize, seed: u64) -> Vec<Request> {
        let mut rng = Pcg32::seeded(seed);
        (0..n)
            .map(|id| Request {
                id: id as u64,
                input: (0..96).map(|_| rng.normal()).collect(),
            })
            .collect()
    }

    fn cfg(threshold: f32, workers: usize) -> CascadeConfig {
        CascadeConfig { threshold, workers, ..CascadeConfig::default() }
    }

    #[test]
    fn no_request_lost_and_ordered() {
        let little = tiny_qgraph(4, 1);
        let big = tiny_qgraph(8, 2);
        let stats = run_cascade(little, big, &cfg(0.5, 4), requests(64, 3), None);
        assert_eq!(stats.responses.len(), 64);
        for (i, r) in stats.responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn threshold_one_always_escalates_threshold_zero_never() {
        let little = tiny_qgraph(4, 4);
        let big = tiny_qgraph(8, 5);
        let s0 = run_cascade(little.clone(), big.clone(), &cfg(0.0, 2), requests(32, 6), None);
        assert_eq!(s0.escalation_rate, 0.0);
        let s1 = run_cascade(little, big, &cfg(1.01, 2), requests(32, 6), None);
        assert_eq!(s1.escalation_rate, 1.0);
        // Full escalation costs little+big device latency on every request.
        let (d0, d1) = (s0.device_latency.unwrap(), s1.device_latency.unwrap());
        assert!(d1.p50 > d0.p50);
    }

    #[test]
    fn latency_and_energy_come_from_session_metadata() {
        let little = tiny_qgraph(4, 7);
        let big = tiny_qgraph(8, 8);
        // Expected prices straight from session metadata on this board.
        let lm = SessionBuilder::fixed_qmn(little.clone()).board(&NUCLEO_L452RE_P).build();
        let bm = SessionBuilder::fixed_qmn(big.clone()).board(&NUCLEO_L452RE_P).build();
        let exp_ms = lm.meta().device_latency_ms.unwrap() + bm.meta().device_latency_ms.unwrap();
        let exp_uwh = lm.meta().device_energy_uwh.unwrap() + bm.meta().device_energy_uwh.unwrap();
        assert!(exp_ms > 0.0 && exp_uwh > 0.0);

        let c = CascadeConfig { board: &NUCLEO_L452RE_P, ..cfg(1.01, 1) };
        let s = run_cascade(little, big, &c, requests(8, 9), None);
        for r in &s.responses {
            assert!(r.escalated);
            assert!((r.device_ms.unwrap() - exp_ms).abs() < 1e-9);
            assert!((r.energy_uwh.unwrap() - exp_uwh).abs() < 1e-12);
        }
    }

    #[test]
    fn total_latency_is_queue_plus_device() {
        let little = tiny_qgraph(4, 30);
        let big = tiny_qgraph(8, 31);
        // Saturating arrival rate so queueing actually happens.
        let c = CascadeConfig { arrival_rate_hz: 1e6, ..cfg(0.8, 2) };
        let s = run_cascade(little, big, &c, requests(48, 32), None);
        let mut queued = 0usize;
        for r in &s.responses {
            let total = r.total_ms().expect("priced cascade");
            assert!((total - (r.queue_ms + r.device_ms.unwrap())).abs() < 1e-12);
            assert!(r.queue_ms >= 0.0);
            if r.queue_ms > 0.0 {
                queued += 1;
            }
        }
        // At a near-infinite arrival rate, almost everything queues
        // behind the first request each worker serves.
        assert!(queued > 0, "no request ever waited under saturation");
        let lat = s.latency.unwrap();
        let dev = s.device_latency.unwrap();
        assert!(lat.p50 >= dev.p50);
        assert!(s.queue_latency.max > 0.0);
        assert!(s.queue_depth.max >= 1.0);
    }

    #[test]
    fn slow_poisson_arrivals_do_not_queue() {
        let little = tiny_qgraph(4, 33);
        let big = tiny_qgraph(8, 34);
        // Device latency is a few ms; at 1 request per 1000 simulated
        // seconds every worker is long idle before the next arrival.
        let c = CascadeConfig { arrival_rate_hz: 1e-3, ..cfg(0.8, 2) };
        let s = run_cascade(little, big, &c, requests(16, 35), None);
        for r in &s.responses {
            assert_eq!(r.queue_ms, 0.0, "request {} queued unexpectedly", r.id);
        }
    }

    #[test]
    fn boardless_sessions_report_none_not_zero_cost() {
        let little = tiny_qgraph(4, 10);
        let big = tiny_qgraph(8, 11);
        // Sessions WITHOUT a board: no cost model. The cascade must not
        // invent 0.0 ms / 0.0 µWh prices.
        let lt = SessionBuilder::fixed_qmn(little).build();
        let bt = SessionBuilder::fixed_qmn(big).build();
        let s = run_cascade_sessions(&lt, &bt, &cfg(0.8, 2), requests(16, 12), None);
        assert_eq!(s.responses.len(), 16);
        for r in &s.responses {
            assert!(r.device_ms.is_none());
            assert!(r.energy_uwh.is_none());
            assert!(r.total_ms().is_none());
        }
        assert!(s.latency.is_none());
        assert!(s.device_latency.is_none());
        assert!(s.total_energy_uwh.is_none());
        // Classification itself still works.
        assert!(s.responses.iter().all(|r| r.prediction < 4));
    }

    #[test]
    fn short_or_sparse_labels_use_checked_lookup() {
        let little = tiny_qgraph(4, 13);
        let big = tiny_qgraph(8, 14);
        // 32 requests but only 10 labels: pre-fix this indexed
        // ys[r.id] and panicked out of bounds.
        let labels: Vec<i32> = vec![0; 10];
        let s = run_cascade(
            little.clone(),
            big.clone(),
            &cfg(0.5, 2),
            requests(32, 15),
            Some(&labels),
        );
        assert_eq!(s.matched_labels, 10);
        let acc = s.accuracy.expect("some labels matched");
        assert!((0.0..=1.0).contains(&acc));

        // Sparse, non-dense ids beyond the label range: no panic, no match.
        let mut reqs = requests(4, 16);
        for (k, r) in reqs.iter_mut().enumerate() {
            r.id = 1000 + k as u64;
        }
        let s = run_cascade(little, big, &cfg(0.5, 2), reqs, Some(&labels));
        assert_eq!(s.matched_labels, 0);
        assert!(s.accuracy.is_none());
    }

    #[test]
    fn sharded_and_single_channel_agree_on_predictions() {
        let little = tiny_qgraph(4, 17);
        let big = tiny_qgraph(8, 18);
        let lt = SessionBuilder::fixed_qmn(little).board(&SPARKFUN_EDGE).build();
        let bt = SessionBuilder::fixed_qmn(big).board(&SPARKFUN_EDGE).build();
        let reqs = requests(40, 19);
        let a = run_cascade_sessions(&lt, &bt, &cfg(0.8, 3), reqs.clone(), None);
        let b = run_cascade_single_channel(&lt, &bt, 0.8, 3, reqs);
        assert_eq!(a.responses.len(), b.len());
        for (x, y) in a.responses.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.prediction, y.prediction);
            assert_eq!(x.escalated, y.escalated);
            assert_eq!(x.device_ms, y.device_ms);
        }
    }

    #[test]
    fn utilization_and_depth_are_reported() {
        let little = tiny_qgraph(4, 20);
        let big = tiny_qgraph(8, 21);
        let c = cfg(0.8, 3);
        let s = run_cascade(little, big, &c, requests(60, 22), None);
        assert_eq!(s.worker_utilization.len(), 3);
        assert!(s.worker_utilization.iter().all(|&u| (0.0..=1.0 + 1e-9).contains(&u)));
        // All requests arrive at t=0 (default rate 0): the busiest worker
        // is the makespan definition, so utilization peaks at 1.
        let peak = s.worker_utilization.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!((peak - 1.0).abs() < 1e-9, "peak utilization {peak}");
        assert!(s.queue_depth.n > 0);
        assert!(s.throughput_rps > 0.0);
    }

    #[test]
    fn bigger_model_costs_more_on_the_same_board() {
        let little = tiny_qgraph(4, 10);
        let big = tiny_qgraph(16, 11);
        let ls = SessionBuilder::fixed_qmn(little).board(&SPARKFUN_EDGE).build();
        let bs = SessionBuilder::fixed_qmn(big).board(&SPARKFUN_EDGE).build();
        assert!(
            bs.meta().device_latency_ms.unwrap() > ls.meta().device_latency_ms.unwrap()
        );
        assert!(
            bs.meta().device_energy_uwh.unwrap() > ls.meta().device_energy_uwh.unwrap()
        );
    }

    #[test]
    fn intra_op_cap_prevents_oversubscription() {
        // Pure budget arithmetic, independent of this machine's cores.
        assert_eq!(effective_intra_op_threads(4, 1024, 8), 2);
        assert_eq!(effective_intra_op_threads(4, 1, 8), 1);
        assert_eq!(effective_intra_op_threads(1, 4, 8), 4);
        assert_eq!(effective_intra_op_threads(8, 4, 8), 1);
        assert_eq!(effective_intra_op_threads(2, 3, 64), 3);
        // Degenerate hosts/configs never drop below one serial thread.
        assert_eq!(effective_intra_op_threads(0, 0, 0), 1);
        assert_eq!(effective_intra_op_threads(16, 16, 1), 1);
    }

    #[test]
    fn intra_op_threads_do_not_change_predictions() {
        // The cascade with intra-op GEMM parallelism must serve the exact
        // same predictions/escalations as the serial cascade (the kernel
        // core is bit-exact across thread counts).
        let little = tiny_qgraph(4, 40);
        let big = tiny_qgraph(8, 41);
        let reqs = requests(48, 42);
        let serial = run_cascade(little.clone(), big.clone(), &cfg(0.8, 2), reqs.clone(), None);
        let c = CascadeConfig { intra_op_threads: 2, ..cfg(0.8, 2) };
        let par = run_cascade(little, big, &c, reqs, None);
        assert_eq!(serial.responses.len(), par.responses.len());
        for (a, b) in serial.responses.iter().zip(&par.responses) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prediction, b.prediction);
            assert_eq!(a.confidence, b.confidence);
            assert_eq!(a.escalated, b.escalated);
            assert_eq!(a.device_ms, b.device_ms);
        }
    }

    #[test]
    fn confidence_is_a_probability() {
        let c = confidence(&[1.0, 2.0, 3.0]);
        assert!((0.0..=1.0).contains(&c));
        assert!(confidence(&[10.0, -10.0]) > 0.99);
    }
}
