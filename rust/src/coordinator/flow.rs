//! The MicroAI general flow (Fig 3 + §5.3): a TOML experiment description
//! drives preprocess → train → post-process (PTQ / QAT) → deploy →
//! evaluate, matching the `microai <config.toml> ...` commands of
//! Appendix C.
//!
//! Every evaluation arm runs through the Session API's batched path
//! (`deployer::session_accuracy` → [`crate::nn::Session::infer`] over one
//! contiguous [`crate::nn::Batch`] view): one compiled session, one
//! arena, the whole test set in batch-folded micro-batches.

use anyhow::{Context, Result};

use crate::coordinator::deployer;
use crate::coordinator::trainer::{LrSchedule, Trainer};
use crate::datasets;
use crate::engines::all_engines;
use crate::mcu::board::{BOARDS, SPARKFUN_EDGE};
use crate::nn::session::SessionBuilder;
use crate::quant::QuantSpec;
use crate::runtime::Runtime;
use crate::util::toml::{TomlDoc, TomlTable};

/// One [[model]] block: a quantization configuration to evaluate.
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    /// "float32" | "int16" | "int8-qat" | "int9" | "int8-affine"
    pub mode: String,
    pub qat_steps: usize,
}

#[derive(Clone, Debug)]
pub struct ExperimentCfg {
    pub dataset: String,
    pub filters: usize,
    pub seed: u64,
    pub train_steps: usize,
    pub lr: f32,
    pub calib_examples: usize,
    pub models: Vec<ModelCfg>,
    pub deploy: bool,
}

fn get_usize(t: &TomlTable, k: &str, d: usize) -> usize {
    t.get(k).and_then(|v| v.as_i64()).map(|v| v as usize).unwrap_or(d)
}

impl ExperimentCfg {
    pub fn parse(text: &str) -> Result<ExperimentCfg> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow::anyhow!(e))?;
        let root = &doc.root;
        let tmpl = doc.table("model_template").cloned().unwrap_or_default();
        let mut models = Vec::new();
        for m in doc.array("model") {
            let name = m
                .get("name")
                .and_then(|v| v.as_str())
                .context("[[model]] needs name")?
                .to_string();
            let mode = m
                .get("mode")
                .and_then(|v| v.as_str())
                .unwrap_or(name.as_str())
                .to_string();
            models.push(ModelCfg {
                name,
                mode,
                qat_steps: get_usize(m, "qat_steps", get_usize(&tmpl, "qat_steps", 40)),
            });
        }
        if models.is_empty() {
            for mode in ["float32", "int16", "int8-qat"] {
                models.push(ModelCfg { name: mode.into(), mode: mode.into(), qat_steps: 40 });
            }
        }
        Ok(ExperimentCfg {
            dataset: root
                .get("dataset")
                .and_then(|v| v.as_str())
                .unwrap_or("har")
                .to_string(),
            filters: get_usize(root, "filters", 16),
            seed: get_usize(root, "seed", 42) as u64,
            train_steps: get_usize(&tmpl, "steps", get_usize(root, "steps", 150)),
            lr: tmpl.get("lr").and_then(|v| v.as_f64()).unwrap_or(0.05) as f32,
            calib_examples: get_usize(root, "calib_examples", 64),
            models,
            deploy: root.get("deploy").and_then(|v| v.as_bool()).unwrap_or(true),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ModelResult {
    pub name: String,
    pub mode: String,
    pub accuracy: f64,
    pub weight_bytes: usize,
    /// Predicted per-inference latency (ms) on the SparkFun Edge, from
    /// the model's session metadata (`mcu::cost`).
    pub device_ms: Option<f64>,
}

pub struct ExperimentResult {
    pub cfg: ExperimentCfg,
    pub float_losses: Vec<f32>,
    pub results: Vec<ModelResult>,
    pub deployment: String,
}

/// Run the full flow. Needs the artifacts for `{dataset}_f{filters}`.
pub fn run(rt: &Runtime, cfg: &ExperimentCfg, verbose: bool) -> Result<ExperimentResult> {
    let tag = format!("{}_f{}", cfg.dataset, cfg.filters);
    let spec = rt.spec(&tag)?.clone();
    let data = datasets::load(&cfg.dataset, cfg.seed).context("unknown dataset")?;

    // --- train (float32 base model) ---
    let mut trainer = Trainer::new(rt, cfg.seed);
    let mut state = trainer.init(&tag)?;
    let sched = LrSchedule { initial: cfg.lr, factor: 0.13, milestones: vec![
        cfg.train_steps / 3, 2 * cfg.train_steps / 3, cfg.train_steps * 5 / 6], warmup: 10 };
    trainer.train(&mut state, &data, "train", cfg.train_steps, &sched,
        if verbose { (cfg.train_steps / 8).max(1) } else { 0 })?;
    let float_losses = state.losses.clone();

    // --- deployment graph from trained weights ---
    let params = trainer.params_to_host(&state)?;
    let graph = deployer::build_deployed_graph(&spec, params);

    // Arm helper: a Qm.n PTQ arm is (accuracy, ROM bytes, predicted ms)
    // with the latency coming from the session metadata on the paper's
    // most efficient board (Fig 13).
    let ptq_arm = |spec: QuantSpec, g: &crate::graph::Graph| {
        let (qg, acc) = deployer::ptq_accuracy(g, &data, spec, cfg.calib_examples);
        let sess = SessionBuilder::fixed_qmn(qg.clone()).board(&SPARKFUN_EDGE).build();
        (acc, qg.weight_bytes(), sess.meta().device_latency_ms)
    };

    let mut results = Vec::new();
    for m in &cfg.models {
        let (acc, bytes, device_ms) = match m.mode.as_str() {
            "float32" => {
                let sess =
                    SessionBuilder::float32(graph.clone()).board(&SPARKFUN_EDGE).build();
                let ms = sess.meta().device_latency_ms;
                (deployer::float_accuracy(&graph, &data), graph.param_count() * 4, ms)
            }
            "int16" => ptq_arm(QuantSpec::int16_per_layer(), &graph),
            "int16-q7.9" => ptq_arm(QuantSpec::int16_q7_9(), &graph),
            "int9" => ptq_arm(QuantSpec::int9_per_layer(), &graph),
            "int8" => ptq_arm(QuantSpec::int8_per_layer(), &graph),
            "int8-affine" => {
                let stats = deployer::calibrate(&graph, &data, cfg.calib_examples);
                let aq = crate::quant::quantize_affine(&graph, &stats);
                let mut sess =
                    SessionBuilder::affine_i8(aq).board(&SPARKFUN_EDGE).build();
                let acc = deployer::session_accuracy(&mut sess, &data);
                (acc, graph.param_count(), sess.meta().device_latency_ms)
            }
            "int8-qat" => {
                // QAT fine-tune on top of the float model (§4.3), then
                // evaluate the int8 engine on the fine-tuned weights.
                let mut qat_state = crate::coordinator::trainer::TrainState {
                    tag: state.tag.clone(),
                    params: state.params.clone(),
                    mom: state.mom.clone(),
                    losses: Vec::new(),
                };
                let qat_sched = LrSchedule {
                    initial: cfg.lr * 0.2,
                    factor: 0.1,
                    milestones: vec![m.qat_steps / 2], warmup: 10 };
                trainer.train(&mut qat_state, &data, "qat8_train", m.qat_steps, &qat_sched, 0)?;
                let qat_params = trainer.params_to_host(&qat_state)?;
                let qat_graph = deployer::build_deployed_graph(&spec, qat_params);
                ptq_arm(QuantSpec::int8_per_layer(), &qat_graph)
            }
            other => anyhow::bail!("unknown model mode {other:?}"),
        };
        if verbose {
            let ms = device_ms.map_or("-".to_string(), |v| format!("{v:.1}"));
            println!(
                "  model {:<12} mode {:<12} acc {:.4}  pred {ms} ms @SparkFunEdge",
                m.name, m.mode, acc
            );
        }
        results.push(ModelResult {
            name: m.name.clone(),
            mode: m.mode.clone(),
            accuracy: acc,
            weight_bytes: bytes,
            device_ms,
        });
    }

    let deployment = if cfg.deploy {
        deployer::render_matrix(&deployer::deployment_matrix(
            &graph, cfg.filters, &all_engines(), &BOARDS))
    } else {
        String::new()
    };

    Ok(ExperimentResult { cfg: cfg.clone(), float_losses, results, deployment })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
dataset = "har"
filters = 8
seed = 7
calib_examples = 32

[model_template]
steps = 30
lr = 0.05
qat_steps = 10

[[model]]
name = "float32"

[[model]]
name = "int16"

[[model]]
name = "qat8"
mode = "int8-qat"
"#;

    #[test]
    fn parses_experiment_toml() {
        let cfg = ExperimentCfg::parse(SAMPLE).unwrap();
        assert_eq!(cfg.dataset, "har");
        assert_eq!(cfg.filters, 8);
        assert_eq!(cfg.train_steps, 30);
        assert_eq!(cfg.models.len(), 3);
        assert_eq!(cfg.models[2].mode, "int8-qat");
        assert_eq!(cfg.models[2].qat_steps, 10);
    }

    #[test]
    fn default_models_when_none_given() {
        let cfg = ExperimentCfg::parse("dataset = \"smnist\"\n").unwrap();
        assert_eq!(cfg.models.len(), 3);
    }
}
