//! Training driver: runs the AOT-compiled JAX train-step from Rust.
//!
//! Python never executes here — the SGD(+momentum, +weight-decay, +mixup)
//! step was lowered once by aot.py; this module owns the training loop,
//! the LR schedule (§6: step decays at fixed epochs) and parameter state
//! (kept as PJRT literals between steps to avoid host round-trips).

use anyhow::{Context, Result};

use crate::datasets::RawDataModel;
use crate::runtime::exec::{lit_f32, lit_i32, lit_scalar_f32, lit_u32, to_f32};
use crate::runtime::Runtime;
use crate::util::prng::Pcg32;

/// The paper's LR schedules (§6.1.*): initial LR multiplied by `factor`
/// at each milestone, expressed here in steps.
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub initial: f32,
    pub factor: f32,
    pub milestones: Vec<usize>,
    /// Linear warmup over the first `warmup` steps (0 = none).
    pub warmup: usize,
}

impl LrSchedule {
    /// UCI-HAR float schedule scaled from epochs to a step budget.
    pub fn har_like(total_steps: usize) -> Self {
        // Paper: lr 0.05, x0.13 at 100/200/250 of 300 epochs.
        LrSchedule {
            initial: 0.05,
            factor: 0.13,
            milestones: vec![total_steps / 3, 2 * total_steps / 3, total_steps * 5 / 6],
            warmup: total_steps / 20,
        }
    }

    pub fn lr_at(&self, step: usize) -> f32 {
        let decays = self.milestones.iter().filter(|&&m| step >= m).count() as i32;
        let base = self.initial * self.factor.powi(decays);
        if self.warmup > 0 && step < self.warmup {
            base * (step + 1) as f32 / self.warmup as f32
        } else {
            base
        }
    }
}

/// Model parameters + optimizer state held as literals.
pub struct TrainState {
    pub tag: String,
    pub params: Vec<xla::Literal>,
    pub mom: Vec<xla::Literal>,
    pub losses: Vec<f32>,
}

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub rng: Pcg32,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, seed: u64) -> Self {
        Trainer { rt, rng: Pcg32::seeded(seed) }
    }

    /// Initialize parameters by executing the `init` artifact.
    pub fn init(&mut self, tag: &str) -> Result<TrainState> {
        let spec = self.rt.spec(tag)?.clone();
        let exe = self.rt.compile_model(tag, "init")?;
        let key = [self.rng.next_u32(), self.rng.next_u32()];
        let params = exe.run(&[lit_u32(&key)])?;
        anyhow::ensure!(
            params.len() == spec.n_params(),
            "init returned {} tensors, expected {}",
            params.len(),
            spec.n_params()
        );
        let mom = spec
            .param_shapes
            .iter()
            .map(|s| {
                let n: usize = s.iter().product();
                lit_f32(&vec![0.0; n], s)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TrainState { tag: tag.to_string(), params, mom, losses: Vec::new() })
    }

    /// Run `steps` SGD steps of `kind` ("train" or "qat8_train") on
    /// batches sampled from `data`. Returns the per-step losses appended
    /// to the state.
    pub fn train(
        &mut self,
        state: &mut TrainState,
        data: &RawDataModel,
        kind: &str,
        steps: usize,
        schedule: &LrSchedule,
        log_every: usize,
    ) -> Result<()> {
        let spec = self.rt.spec(&state.tag)?.clone();
        let exe = self.rt.compile_model(&state.tag, kind)?;
        let b = spec.train_batch;
        let ex_len = spec.example_len();
        let n_params = spec.n_params();
        let mut batch_shape = vec![b];
        batch_shape.extend_from_slice(&spec.input_shape);

        for step in 0..steps {
            // Sample a batch.
            let idx = data.sample_batch(&mut self.rng, b);
            let mut xs = Vec::with_capacity(b * ex_len);
            let mut ys = Vec::with_capacity(b);
            for &i in &idx {
                xs.extend_from_slice(data.train_example(i));
                ys.push(data.train_y[i]);
            }
            let key = [self.rng.next_u32(), self.rng.next_u32()];
            let lr = schedule.lr_at(step);

            // inputs: params..., mom..., x, y, key, lr
            let mut inputs: Vec<xla::Literal> =
                Vec::with_capacity(2 * n_params + 4);
            for p in &state.params {
                inputs.push(p.clone());
            }
            for m in &state.mom {
                inputs.push(m.clone());
            }
            inputs.push(lit_f32(&xs, &batch_shape)?);
            inputs.push(lit_i32(&ys));
            inputs.push(lit_u32(&key));
            inputs.push(lit_scalar_f32(lr));

            let mut out = exe.run(&inputs)?;
            anyhow::ensure!(out.len() == 2 * n_params + 1, "train step output arity");
            let loss_lit = out.pop().unwrap();
            let loss = loss_lit.get_first_element::<f32>()?;
            state.mom = out.split_off(n_params);
            state.params = out;
            state.losses.push(loss);
            if log_every > 0 && (step % log_every == 0 || step + 1 == steps) {
                println!("  [{kind}] step {step:>4}/{steps} lr={lr:.4} loss={loss:.4}");
            }
        }
        Ok(())
    }

    /// Extract parameters to host float tensors (deployment handoff).
    pub fn params_to_host(&self, state: &TrainState) -> Result<Vec<crate::tensor::TensorF>> {
        let spec = self.rt.spec(&state.tag)?;
        let mut out = Vec::with_capacity(state.params.len());
        for (lit, shape) in state.params.iter().zip(&spec.param_shapes) {
            out.push(crate::tensor::Tensor::from_vec(shape, to_f32(lit)?));
        }
        Ok(out)
    }

    /// Batched float-graph inference via the `fwd` (or `qfwd8`) artifact;
    /// returns test accuracy.
    pub fn eval_accuracy(
        &self,
        state: &TrainState,
        data: &RawDataModel,
        kind: &str,
    ) -> Result<f64> {
        let spec = self.rt.spec(&state.tag)?.clone();
        let exe = self.rt.compile_model(&state.tag, kind)?;
        let b = spec.eval_batch;
        let ex_len = spec.example_len();
        let mut batch_shape = vec![b];
        batch_shape.extend_from_slice(&spec.input_shape);
        let mut correct = 0usize;
        let mut total = 0usize;
        let n = data.n_test();
        let mut i = 0usize;
        while i < n {
            // Fixed batch size: pad the tail with example 0, ignore pads.
            let mut xs = Vec::with_capacity(b * ex_len);
            let take = (n - i).min(b);
            for j in 0..b {
                let src = if j < take { i + j } else { 0 };
                xs.extend_from_slice(data.test_example(src));
            }
            let mut inputs: Vec<xla::Literal> = state.params.to_vec();
            inputs.push(lit_f32(&xs, &batch_shape)?);
            let out = exe.run(&inputs).context("fwd exec")?;
            let logits = to_f32(&out[0])?;
            for j in 0..take {
                let row = &logits[j * spec.classes..(j + 1) * spec.classes];
                let pred = crate::nn::argmax(row);
                if pred as i32 == data.test_y[i + j] {
                    correct += 1;
                }
            }
            total += take;
            i += take;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_decays_at_milestones() {
        let s = LrSchedule { initial: 0.1, factor: 0.1, milestones: vec![10, 20], warmup: 0 };
        assert_eq!(s.lr_at(0), 0.1);
        assert!((s.lr_at(10) - 0.01).abs() < 1e-9);
        assert!((s.lr_at(25) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn har_like_schedule_monotone_after_warmup() {
        let s = LrSchedule::har_like(300);
        let mut last = f32::INFINITY;
        for step in [s.warmup, 99, 100, 200, 250, 299] {
            let lr = s.lr_at(step);
            assert!(lr <= last);
            last = lr;
        }
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule { initial: 0.1, factor: 0.1, milestones: vec![], warmup: 10 };
        assert!((s.lr_at(0) - 0.01).abs() < 1e-7);
        assert!((s.lr_at(4) - 0.05).abs() < 1e-7);
        assert!((s.lr_at(10) - 0.1).abs() < 1e-7);
    }
}
