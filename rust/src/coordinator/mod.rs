//! The L3 coordinator — MicroAI's end-to-end flow (Fig 3): training driver
//! over the AOT artifacts, deployment pipeline, TOML experiment runner and
//! the big/LITTLE serving cascade.

pub mod deployer;
pub mod flow;
pub mod serving;
pub mod trainer;

pub use deployer::{build_deployed_graph, deployment_matrix, ptq_accuracy};
pub use trainer::{LrSchedule, TrainState, Trainer};
