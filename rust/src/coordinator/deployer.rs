//! Deployment pipeline (Fig 3, right half): trained parameters → deployed
//! graph → calibration → quantization → engine/board reports → optional C
//! library.


use std::sync::Arc;

use crate::datasets::RawDataModel;
use crate::engines::Engine;
use crate::graph::{deploy_pipeline, resnet_v1_6, Graph};
use crate::mcu::board::Board;
use crate::mcu::paper_data::DType;
use crate::nn::float_exec::ActStats;
use crate::nn::session::{Batch, Session, SessionBuilder};
use crate::quant::{quantize, QuantSpec, QuantizedGraph};
use crate::runtime::ModelSpec;
use crate::tensor::TensorF;

/// Build the deployed (fused) graph from trained host parameters.
pub fn build_deployed_graph(spec: &ModelSpec, params: Vec<TensorF>) -> Graph {
    let g = resnet_v1_6(
        &spec.tag,
        spec.dims,
        &spec.input_shape,
        spec.classes,
        params,
    );
    deploy_pipeline(&g)
}

/// Calibrate activation ranges over `n` training examples (§5.8 PTQ),
/// through one reused float [`Session`].
pub fn calibrate(graph: &Graph, data: &RawDataModel, n: usize) -> ActStats {
    let mut stats = ActStats::new(graph.nodes.len());
    let mut sess = SessionBuilder::float32(graph.clone()).build();
    for i in 0..n.min(data.n_train()) {
        sess.calibrate(data.train_example(i), &mut stats);
    }
    stats
}

/// Test accuracy of one session over the whole test set (run-many half of
/// the compile-once/run-many contract). `test_x` is contiguous, so it
/// feeds [`Session::infer`] as one zero-copy [`Batch`] view: the whole
/// set is evaluated through one arena, in `max_batch`-sized folded
/// micro-batches.
pub fn session_accuracy(sess: &mut Session, data: &RawDataModel) -> f64 {
    let mut preds = Vec::with_capacity(data.n_test());
    sess.infer(&Batch::contiguous(&data.test_x, sess.input_len()), &mut preds);
    let correct = preds
        .iter()
        .zip(&data.test_y)
        .filter(|(p, &y)| p.class as i32 == y)
        .count();
    correct as f64 / data.n_test().max(1) as f64
}

/// PTQ + integer-engine test accuracy in one call. The returned graph is
/// shared (`Arc`) so callers can keep serving from it without re-quantizing.
pub fn ptq_accuracy(
    graph: &Graph,
    data: &RawDataModel,
    spec: QuantSpec,
    calib_examples: usize,
) -> (Arc<QuantizedGraph>, f64) {
    let stats = calibrate(graph, data, calib_examples);
    let qg = Arc::new(quantize(graph, &stats, spec));
    let mut sess = SessionBuilder::fixed_qmn(qg.clone()).build();
    let acc = session_accuracy(&mut sess, data);
    (qg, acc)
}

/// Float-engine test accuracy (Rust reference path).
pub fn float_accuracy(graph: &Graph, data: &RawDataModel) -> f64 {
    let mut sess = SessionBuilder::float32(graph.clone()).build();
    session_accuracy(&mut sess, data)
}

/// Affine (TFLite-scheme) PTQ accuracy — the Appendix B comparison arm.
pub fn affine_accuracy(graph: &Graph, data: &RawDataModel, calib_examples: usize) -> f64 {
    let stats = calibrate(graph, data, calib_examples);
    let aq = crate::quant::quantize_affine(graph, &stats);
    let mut sess = SessionBuilder::affine_i8(aq).build();
    session_accuracy(&mut sess, data)
}

/// One row of a deployment report (Figs 11–13 cells).
#[derive(Clone, Debug)]
pub struct DeployReport {
    pub engine: String,
    pub board: String,
    pub dtype: DType,
    pub rom_bytes: f64,
    pub ram_bytes: usize,
    pub latency_ms: f64,
    pub energy_uwh: f64,
    pub fits: bool,
}

/// Evaluate a deployed graph across engines × boards × dtypes.
pub fn deployment_matrix(
    graph: &Graph,
    filters: usize,
    engines: &[Engine],
    boards: &[&Board],
) -> Vec<DeployReport> {
    let alloc = crate::allocator::allocate(graph);
    let mut rows = Vec::new();
    for e in engines {
        for &b in boards {
            for dt in [DType::F32, DType::I16, DType::I8] {
                let (Some(lat), Some(rom)) = (
                    e.latency_s(graph, b, dt),
                    e.rom_bytes(graph, filters, dt),
                ) else {
                    continue;
                };
                let ram = alloc.ram_bytes(dt.bytes())
                    + graph.input_shape.iter().product::<usize>() * dt.bytes();
                rows.push(DeployReport {
                    engine: e.name.to_string(),
                    board: b.name.to_string(),
                    dtype: dt,
                    rom_bytes: rom,
                    ram_bytes: ram,
                    latency_ms: lat * 1e3,
                    energy_uwh: e.energy_uwh(graph, b, dt).unwrap(),
                    fits: b.fits(rom as usize, ram),
                });
            }
        }
    }
    rows
}

/// Render the range verifier's proof for a quantized deployment (see
/// README, "Reading the VerifiedFacts report"): per-node proven payload
/// ranges, accumulator bounds, lane admissions and clamp reachability. A
/// failed proof renders as an `UNVERIFIABLE` line with the reason, so the
/// deployment report can show WHY a model was refused without panicking
/// mid-pipeline.
pub fn verification_summary(qg: &QuantizedGraph) -> String {
    match crate::graph::passes::verify_fixed_ranges(qg) {
        Ok(facts) => {
            // The memory plan is part of the deployment proof surface:
            // the report carries the planned-vs-pooled RAM line (Table A6
            // framing) next to the range facts, re-running the trusted
            // byte-range checker on the plan it describes.
            let alloc = crate::allocator::allocate(&qg.graph);
            format!("{}{}\n", facts.render_report(), ram_plan_summary(&qg.graph, &alloc))
        }
        Err(e) => format!("UNVERIFIABLE: {e}\n"),
    }
}

/// One-line RAM plan report: the planner's coalesced arena against the
/// paper's §5.7 pool baseline (plus attention statics), in elements — the
/// Table A6 "offset calculation vs pool allocation" comparison. The plan
/// is re-proven by the trusted byte-range checker HERE, so a corrupted
/// plan renders as a refusal instead of advertising unsound savings.
pub fn ram_plan_summary(graph: &Graph, alloc: &crate::allocator::Allocation) -> String {
    match crate::allocator::check_no_conflict(graph, alloc) {
        Err(e) => format!("RAM plan REFUSED by the byte-range checker: {e}"),
        Ok(()) => format!(
            "RAM plan: {arena} arena elems vs {pooled} pooled ({saved} saved, \
             {pct:.1}%, byte-range checker verified)",
            arena = alloc.arena_elems,
            pooled = alloc.pooled_elems,
            saved = alloc.pooled_elems - alloc.arena_elems,
            pct = 100.0 * (alloc.pooled_elems - alloc.arena_elems) as f64
                / alloc.pooled_elems.max(1) as f64,
        ),
    }
}

/// Render a deployment matrix as a paper-style table.
pub fn render_matrix(rows: &[DeployReport]) -> String {
    let mut s = String::from(
        "Engine        Board           DType    ROM(kiB)  RAM(kiB)  Time(ms)  E(µWh)  Fits\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:<13} {:<15} {:<8} {:>8.1} {:>9.1} {:>9.1} {:>7.3}  {}\n",
            r.engine,
            r.board,
            r.dtype.label(),
            r.rom_bytes / 1024.0,
            r.ram_bytes as f64 / 1024.0,
            r.latency_ms,
            r.energy_uwh,
            if r.fits { "yes" } else { "NO" },
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::all_engines;
    use crate::graph::resnet_v1_6_shapes;
    use crate::mcu::board::BOARDS;

    #[test]
    fn matrix_covers_supported_combos() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("har", 1, &[128, 9], 6, 16));
        let rows = deployment_matrix(&g, 16, &all_engines(), &BOARDS);
        // MicroAI: 2 boards x 3 dtypes; TFLM: 2 x 2; CubeAI: 1 board x 2.
        assert_eq!(rows.len(), 6 + 4 + 2);
        assert!(rows.iter().all(|r| r.latency_ms > 0.0 && r.rom_bytes > 0.0));
        // Everything fits these boards at f=16.
        assert!(rows.iter().all(|r| r.fits));
        let txt = render_matrix(&rows);
        assert!(txt.contains("MicroAI"));
    }

    #[test]
    fn int16_row_exists_only_for_microai() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("har", 1, &[128, 9], 6, 16));
        let rows = deployment_matrix(&g, 16, &all_engines(), &BOARDS);
        assert!(rows
            .iter()
            .all(|r| r.dtype != DType::I16 || r.engine == "MicroAI"));
    }

    #[test]
    fn verification_summary_renders_proofs_and_refusals() {
        use crate::nn::int_exec::{calib, random_inputs, randomized_resnet};
        let g = randomized_resnet(51);
        let stats = calib(&g, &random_inputs(4, 96, 52));
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let report = verification_summary(&qg);
        assert!(report.contains("VerifiedFacts (fixed-qmn)"));
        // Header + one line per node + the RAM plan line.
        assert_eq!(report.lines().count(), qg.graph.nodes.len() + 2);
        assert!(report.contains("RAM plan:"), "missing RAM plan line: {report}");

        // A graph the prover refuses renders the reason, not a panic.
        let mut g0 = Graph::new("overflow", 1, &[4, 1], 2);
        let f = g0.add("fl", crate::graph::ir::LayerKind::Flatten, vec![0]);
        let w = TensorF::from_vec(&[4, 2], vec![0.01; 8]);
        let mut b = TensorF::from_vec(&[2], vec![0.0, 0.0]);
        b.data[0] = 1.0e16;
        g0.add("fc", crate::graph::ir::LayerKind::Dense { w, b }, vec![f]);
        let bad = deploy_pipeline(&g0);
        let bstats = calib(&bad, &random_inputs(4, 4, 53));
        let bq = quantize(&bad, &bstats, QuantSpec::int16_per_layer());
        let refusal = verification_summary(&bq);
        assert!(refusal.starts_with("UNVERIFIABLE:"), "got: {refusal}");
    }

    #[test]
    fn ram_plan_line_never_exceeds_pooled_and_refuses_corrupt_plans() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("har", 1, &[128, 9], 6, 16));
        let alloc = crate::allocator::allocate(&g);
        let line = ram_plan_summary(&g, &alloc);
        assert!(line.starts_with("RAM plan:"), "got: {line}");
        assert!(alloc.arena_elems <= alloc.pooled_elems);

        // Deliberately overlapping plan: a consumer parked on its live
        // producer's offset with no in-place sanction → REFUSED in the
        // report (third refusal site after try_build and codegen).
        let mut bad = alloc.clone();
        let victim = g
            .nodes
            .iter()
            .find(|n| {
                !matches!(n.kind, crate::graph::ir::LayerKind::Input)
                    && bad.inplace_with[n.id].is_none()
                    && n.inputs.iter().any(|&i| bad.offset_of[i] != usize::MAX)
            })
            .expect("no corruptible node");
        let producer =
            *victim.inputs.iter().find(|&&i| bad.offset_of[i] != usize::MAX).unwrap();
        bad.offset_of[victim.id] = bad.offset_of[producer];
        let refusal = ram_plan_summary(&g, &bad);
        assert!(refusal.starts_with("RAM plan REFUSED"), "got: {refusal}");
    }

    #[test]
    fn large_float_model_may_not_fit_nucleo() {
        // f=80 float32 ROM ~372 kiB fits 512 kiB flash; RAM check matters
        // at larger sizes. Sanity: report stays consistent.
        let g = deploy_pipeline(&resnet_v1_6_shapes("har", 1, &[128, 9], 6, 80));
        let rows = deployment_matrix(&g, 80, &all_engines(), &BOARDS);
        for r in &rows {
            assert_eq!(
                r.fits,
                r.rom_bytes as usize <= Board::by_name(&r.board).unwrap().flash_bytes
                    && r.ram_bytes <= Board::by_name(&r.board).unwrap().ram_bytes
            );
        }
    }
}
