//! The paper's published measurements (Tables A2–A5), embedded as the
//! calibration + validation reference for the MCU cost models.
//!
//! Calibration policy (DESIGN.md §8): each (framework, board, dtype) series
//! uses ONLY its f=16 and f=80 endpoints to fit the two model constants
//! (effective cycles-per-ideal-cycle and per-layer dispatch overhead; code
//! size affine terms for ROM). The five intermediate filter counts are
//! never fitted — they validate the model's shape.

/// The paper's filter sweep for the framework comparison (§6.2).
pub const FILTERS: [usize; 7] = [16, 24, 32, 40, 48, 64, 80];

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I16,
    I8,
}

impl DType {
    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::I16 => 2,
            DType::I8 => 1,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I16 => "int16",
            DType::I8 => "int8",
        }
    }
}

/// One measured series: framework, board, dtype, 7 values over FILTERS.
pub struct Series {
    pub framework: &'static str,
    pub board: &'static str,
    pub dtype: DType,
    pub values: [f64; 7],
}

/// Table A4 — inference time for one input (ms).
pub const TABLE_A4_MS: [Series; 10] = [
    Series { framework: "TFLiteMicro", board: "SparkFunEdge", dtype: DType::F32,
             values: [179.633, 294.157, 438.541, 624.172, 860.835, 1406.945, 2087.241] },
    Series { framework: "MicroAI", board: "SparkFunEdge", dtype: DType::F32,
             values: [53.247, 153.732, 259.212, 394.494, 569.852, 1017.118, 1561.264] },
    Series { framework: "MicroAI", board: "NucleoL452REP", dtype: DType::F32,
             values: [55.762, 152.426, 259.160, 395.721, 559.249, 976.732, 1512.143] },
    Series { framework: "STM32Cube.AI", board: "NucleoL452REP", dtype: DType::F32,
             values: [85.359, 174.082, 271.362, 403.898, 544.406, 921.646, 1387.083] },
    Series { framework: "MicroAI", board: "SparkFunEdge", dtype: DType::I16,
             values: [40.867, 113.035, 191.439, 287.655, 389.450, 667.547, 1041.617] },
    Series { framework: "MicroAI", board: "NucleoL452REP", dtype: DType::I16,
             values: [44.915, 120.308, 205.499, 318.310, 459.880, 796.310, 1223.513] },
    Series { framework: "TFLiteMicro", board: "SparkFunEdge", dtype: DType::I8,
             values: [92.529, 130.760, 172.673, 225.092, 280.942, 418.198, 591.785] },
    Series { framework: "MicroAI", board: "SparkFunEdge", dtype: DType::I8,
             values: [39.417, 101.704, 172.551, 259.830, 375.840, 658.441, 1003.365] },
    Series { framework: "MicroAI", board: "NucleoL452REP", dtype: DType::I8,
             values: [43.003, 107.705, 180.830, 272.986, 383.761, 659.996, 1034.033] },
    Series { framework: "STM32Cube.AI", board: "NucleoL452REP", dtype: DType::I8,
             values: [32.297, 53.871, 80.388, 111.635, 146.022, 242.002, 352.079] },
];

/// Table A3 — ROM footprint (kiB).
pub const TABLE_A3_KIB: [Series; 10] = [
    Series { framework: "TFLiteMicro", board: "SparkFunEdge", dtype: DType::F32,
             values: [116.520, 133.988, 157.957, 188.426, 225.395, 318.926, 438.363] },
    Series { framework: "MicroAI", board: "SparkFunEdge", dtype: DType::F32,
             values: [54.316, 67.066, 91.035, 121.512, 158.473, 251.863, 371.332] },
    Series { framework: "MicroAI", board: "NucleoL452REP", dtype: DType::F32,
             values: [55.770, 68.145, 92.129, 122.582, 159.559, 253.004, 372.434] },
    Series { framework: "STM32Cube.AI", board: "NucleoL452REP", dtype: DType::F32,
             values: [61.965, 79.449, 103.410, 133.898, 170.859, 264.289, 383.742] },
    Series { framework: "MicroAI", board: "SparkFunEdge", dtype: DType::I16,
             values: [46.952, 50.629, 62.629, 77.832, 96.355, 142.973, 202.699] },
    Series { framework: "MicroAI", board: "NucleoL452REP", dtype: DType::I16,
             values: [48.129, 51.629, 63.613, 78.855, 97.340, 144.051, 203.770] },
    Series { framework: "TFLiteMicro", board: "SparkFunEdge", dtype: DType::I8,
             values: [111.051, 117.066, 124.691, 133.957, 144.832, 171.473, 204.613] },
    Series { framework: "MicroAI", board: "SparkFunEdge", dtype: DType::I8,
             values: [43.256, 42.249, 48.229, 55.854, 65.089, 88.343, 118.202] },
    Series { framework: "MicroAI", board: "NucleoL452REP", dtype: DType::I8,
             values: [45.038, 43.474, 49.464, 57.078, 66.322, 89.683, 119.541] },
    Series { framework: "STM32Cube.AI", board: "NucleoL452REP", dtype: DType::I8,
             values: [72.742, 77.746, 84.336, 92.582, 102.430, 126.996, 158.098] },
];

/// Table A5 — energy for one input (µWh).
pub const TABLE_A5_UWH: [Series; 10] = [
    Series { framework: "TFLiteMicro", board: "SparkFunEdge", dtype: DType::F32,
             values: [0.135, 0.221, 0.330, 0.469, 0.647, 1.058, 1.569] },
    Series { framework: "MicroAI", board: "SparkFunEdge", dtype: DType::F32,
             values: [0.040, 0.116, 0.195, 0.297, 0.428, 0.765, 1.174] },
    Series { framework: "MicroAI", board: "NucleoL452REP", dtype: DType::F32,
             values: [0.247, 0.675, 1.148, 1.753, 2.478, 4.327, 6.700] },
    Series { framework: "STM32Cube.AI", board: "NucleoL452REP", dtype: DType::F32,
             values: [0.378, 0.771, 1.202, 1.789, 2.412, 4.083, 6.146] },
    Series { framework: "MicroAI", board: "SparkFunEdge", dtype: DType::I16,
             values: [0.031, 0.085, 0.144, 0.216, 0.293, 0.502, 0.783] },
    Series { framework: "MicroAI", board: "NucleoL452REP", dtype: DType::I16,
             values: [0.199, 0.533, 0.910, 1.410, 2.038, 3.528, 5.421] },
    Series { framework: "TFLiteMicro", board: "SparkFunEdge", dtype: DType::I8,
             values: [0.070, 0.098, 0.130, 0.169, 0.211, 0.314, 0.445] },
    Series { framework: "MicroAI", board: "SparkFunEdge", dtype: DType::I8,
             values: [0.030, 0.076, 0.130, 0.195, 0.283, 0.495, 0.754] },
    Series { framework: "MicroAI", board: "NucleoL452REP", dtype: DType::I8,
             values: [0.191, 0.477, 0.801, 1.209, 1.700, 2.924, 4.581] },
    Series { framework: "STM32Cube.AI", board: "NucleoL452REP", dtype: DType::I8,
             values: [0.143, 0.239, 0.356, 0.495, 0.647, 1.072, 1.560] },
];

/// Table A2 — float32 inference time (ms) on MCU / CPU / GPU.
pub const TABLE_A2_MCU_MS: [f64; 7] = [85.0, 174.0, 271.0, 404.0, 544.0, 921.0, 1387.0];
pub const TABLE_A2_CPU_MS: [f64; 7] = [0.0396, 0.0552, 0.0720, 0.0937, 0.1134, 0.1538, 0.2046];
pub const TABLE_A2_GPU_MS: [f64; 7] = [0.0227, 0.0197, 0.0223, 0.0284, 0.0317, 0.0395, 0.0515];

pub fn find<'a>(
    table: &'a [Series],
    framework: &str,
    board: &str,
    dtype: DType,
) -> Option<&'a Series> {
    table
        .iter()
        .find(|s| s.framework == framework && s.board == board && s.dtype == dtype)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_table_is_consistent_with_time_and_power() {
        // Table A5 == Table A4 * V * I / 3600 (the paper's own method).
        use crate::mcu::board::Board;
        for (a4, a5) in TABLE_A4_MS.iter().zip(TABLE_A5_UWH.iter()) {
            let b = Board::by_name(a4.board).unwrap();
            for i in 0..7 {
                let predicted_uwh = a4.values[i] / 1000.0 * b.power_w() / 3600.0 * 1e6;
                let rel = (predicted_uwh - a5.values[i]).abs() / a5.values[i];
                assert!(
                    rel < 0.08,
                    "{} {} {:?} f={} predicted {predicted_uwh} vs {}",
                    a4.framework, a4.board, a4.dtype, FILTERS[i], a5.values[i]
                );
            }
        }
    }

    #[test]
    fn tables_align() {
        for (a, b) in TABLE_A4_MS.iter().zip(TABLE_A5_UWH.iter()) {
            assert_eq!(a.framework, b.framework);
            assert_eq!(a.board, b.board);
            assert_eq!(a.dtype, b.dtype);
        }
    }

    #[test]
    fn headline_values_present() {
        // §6.2 headline numbers appear in the tables.
        let cube8 = find(&TABLE_A4_MS, "STM32Cube.AI", "NucleoL452REP", DType::I8).unwrap();
        assert!((cube8.values[6] - 352.079).abs() < 1e-9);
        let tflm8 = find(&TABLE_A5_UWH, "TFLiteMicro", "SparkFunEdge", DType::I8).unwrap();
        assert!((tflm8.values[6] - 0.445).abs() < 1e-9);
    }
}
