//! MCU deployment-target models: boards (Table 3), Cortex-M4 op counts
//! (Table A6), and the calibrated latency / ROM / energy cost models that
//! substitute for the paper's physical Nucleo-L452RE-P and SparkFun Edge
//! measurements (DESIGN.md §3).

pub mod board;
pub mod cost;
pub mod opcounts;
pub mod paper_data;

pub use board::{Board, BOARDS, NUCLEO_L452RE_P, SPARKFUN_EDGE};
pub use cost::{energy_uwh, har_graph, LatencyModel, RomModel};
pub use opcounts::{graph_ops, layer_count, node_gemm_shape, node_ops, GemmShape, OpCounts};
pub use paper_data::DType;
