//! Embedded platform models (paper Table 3).

/// A microcontroller board as the paper characterizes it: core, clock,
/// memories, CoreMark score and measured run current at the evaluation
/// operating point (3.3 V, 48 MHz).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Board {
    pub name: &'static str,
    pub mcu: &'static str,
    pub core: &'static str,
    /// Evaluation clock (both boards are run at 48 MHz in §6.2).
    pub clock_hz: f64,
    pub max_clock_hz: f64,
    pub ram_bytes: usize,
    pub flash_bytes: usize,
    pub coremark_per_mhz: f64,
    /// Run current at 3.3 V, 48 MHz (A). SparkFun Edge value is after
    /// removing on-board peripherals, as in the paper.
    pub run_current_a: f64,
    pub supply_v: f64,
}

/// Nucleo-L452RE-P (STM32L452RE, Cortex-M4F).
pub const NUCLEO_L452RE_P: Board = Board {
    name: "NucleoL452REP",
    mcu: "STM32L452RE",
    core: "Cortex-M4F",
    clock_hz: 48.0e6,
    max_clock_hz: 80.0e6,
    ram_bytes: 128 * 1024,
    flash_bytes: 512 * 1024,
    coremark_per_mhz: 3.42,
    run_current_a: 4.80e-3,
    supply_v: 3.3,
};

/// SparkFun Edge (Ambiq Apollo3, Cortex-M4F, subthreshold operation).
pub const SPARKFUN_EDGE: Board = Board {
    name: "SparkFunEdge",
    mcu: "Ambiq Apollo3",
    core: "Cortex-M4F",
    clock_hz: 48.0e6,
    max_clock_hz: 96.0e6, // "Burst Mode"
    ram_bytes: 384 * 1024,
    flash_bytes: 1024 * 1024,
    coremark_per_mhz: 2.479,
    run_current_a: 0.82e-3,
    supply_v: 3.3,
};

pub const BOARDS: [&Board; 2] = [&NUCLEO_L452RE_P, &SPARKFUN_EDGE];

impl Board {
    pub fn by_name(name: &str) -> Option<&'static Board> {
        BOARDS.iter().copied().find(|b| {
            b.name.eq_ignore_ascii_case(name) || b.mcu.eq_ignore_ascii_case(name)
        })
    }

    /// Seconds for a cycle count at the evaluation clock.
    pub fn seconds(&self, cycles: f64) -> f64 {
        cycles / self.clock_hz
    }

    /// Run power at the evaluation operating point (W).
    pub fn power_w(&self) -> f64 {
        self.supply_v * self.run_current_a
    }

    /// Does a deployment fit? (ROM in flash, RAM within budget.)
    pub fn fits(&self, rom_bytes: usize, ram_bytes: usize) -> bool {
        rom_bytes <= self.flash_bytes && ram_bytes <= self.ram_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_values() {
        assert_eq!(NUCLEO_L452RE_P.ram_bytes, 131072);
        assert_eq!(SPARKFUN_EDGE.flash_bytes, 1048576);
        assert!((NUCLEO_L452RE_P.power_w() - 15.84e-3).abs() < 1e-6);
        assert!((SPARKFUN_EDGE.power_w() - 2.706e-3).abs() < 1e-6);
    }

    #[test]
    fn sparkfun_is_6x_lower_power() {
        // §6.2: "the SparkFun Edge board power consumption is approximately
        // 6 times lower compared to the Nucleo-L452RE-P".
        let ratio = NUCLEO_L452RE_P.power_w() / SPARKFUN_EDGE.power_w();
        assert!((5.0..7.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Board::by_name("sparkfunedge").unwrap().mcu, "Ambiq Apollo3");
        assert_eq!(Board::by_name("STM32L452RE").unwrap().name, "NucleoL452REP");
        assert!(Board::by_name("nope").is_none());
    }

    #[test]
    fn timing_at_48mhz() {
        let t = NUCLEO_L452RE_P.seconds(48.0e6);
        assert!((t - 1.0).abs() < 1e-12);
    }
}
