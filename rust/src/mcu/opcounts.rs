//! Integer ALU operation counts per layer (paper Table A6) evaluated over
//! the real graph shapes, plus the Cortex-M4 cycle weights the paper uses:
//! MACC/add/shift = 1 cycle, max/saturate = 2 cycles (compare + conditional
//! move — the paper notes the compiler does not emit SSAT).

use crate::graph::ir::{Graph, LayerKind};

/// Operation counts for one layer or a whole graph.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCounts {
    pub macc: u64,
    pub add: u64,
    pub shift: u64,
    /// max / saturate ops (2 cycles each).
    pub sat: u64,
    /// integer divisions (average pooling; ~2-12 cycles on Cortex-M4,
    /// we charge the worst case the paper cites for divisions).
    pub div: u64,
}

pub const CYCLES_MACC: u64 = 1;
pub const CYCLES_ADD: u64 = 1;
pub const CYCLES_SHIFT: u64 = 1;
pub const CYCLES_SAT: u64 = 2;
pub const CYCLES_DIV: u64 = 12;

impl OpCounts {
    pub fn plus(self, o: OpCounts) -> OpCounts {
        OpCounts {
            macc: self.macc + o.macc,
            add: self.add + o.add,
            shift: self.shift + o.shift,
            sat: self.sat + o.sat,
            div: self.div + o.div,
        }
    }

    /// Ideal single-issue cycle count (Table A6 weights).
    pub fn ideal_cycles(&self) -> u64 {
        self.macc * CYCLES_MACC
            + self.add * CYCLES_ADD
            + self.shift * CYCLES_SHIFT
            + self.sat * CYCLES_SAT
            + self.div * CYCLES_DIV
    }

    pub fn total_ops(&self) -> u64 {
        self.macc + self.add + self.shift + self.sat + self.div
    }
}

/// Table A6 formulas for one node, using its actual output shape.
pub fn node_ops(graph: &Graph, id: usize) -> OpCounts {
    let node = &graph.nodes[id];
    let out_elems: u64 = node.out_shape.iter().product::<usize>() as u64;
    match &node.kind {
        LayerKind::Input | LayerKind::Flatten => OpCounts::default(),
        // Kept-at-inference softmax (transformer head): per element one
        // max-compare, one LUT subtract+shift, one sum add, one divide.
        LayerKind::Softmax => OpCounts {
            macc: 0,
            add: 2 * out_elems,
            shift: out_elems,
            sat: 2 * out_elems,
            div: out_elems,
        },
        // Row gather from the embedding table: pure copies, like Flatten.
        LayerKind::Embedding { .. } => OpCounts::default(),
        // Two-pass mean/var (adds + one div each per row), rsqrt LUT shift,
        // then per element d·r·γ (2 multiplies) + β add + saturate.
        LayerKind::LayerNorm { .. } => {
            let c = *node.out_shape.last().unwrap() as u64;
            let rows = out_elems / c.max(1);
            OpCounts {
                macc: 2 * out_elems,
                add: 2 * out_elems,
                shift: 2 * out_elems,
                sat: out_elems,
                div: 2 * rows,
            }
        }
        // Four d_model×d_model projections + per-head Q·Kᵀ and P·V GEMMs,
        // requantize (2 shifts + sat) on every projection/score/context
        // output, and the per-row integer softmax over the score matrix.
        LayerKind::SelfAttention { heads, head_dim } => {
            let seq = node.out_shape[0] as u64;
            let dm = (*heads * *head_dim) as u64;
            let h = *heads as u64;
            let scores = h * seq * seq;
            let outs = 4 * seq * dm + scores + seq * dm;
            OpCounts {
                macc: 4 * seq * dm * dm + 2 * seq * seq * dm,
                add: 2 * scores,
                shift: 2 * outs + scores,
                sat: outs + 2 * scores,
                div: scores,
            }
        }
        LayerKind::Conv { w, .. } => {
            let f = *w.shape.last().unwrap() as u64;
            let taps: u64 = w.shape[..w.shape.len() - 1].iter().product::<usize>() as u64; // k*c
            let positions = out_elems / f; // s (output positions)
            let relu_sat = if node.fused_relu { out_elems } else { 0 };
            OpCounts {
                macc: positions * f * taps,        // f*s*c*k
                add: 0,
                shift: 2 * f * positions,          // 2*f*s
                sat: f * positions + relu_sat,     // f*s (+ fused ReLU max)
                div: 0,
            }
        }
        LayerKind::Dense { w, .. } => {
            let (i, o) = (w.shape[0] as u64, w.shape[1] as u64);
            let relu_sat = if node.fused_relu { o } else { 0 };
            OpCounts { macc: i * o, add: 0, shift: 2 * o, sat: o + relu_sat, div: 0 }
        }
        LayerKind::MaxPool { .. } => {
            // stride == size ⇒ the SAME-style windows partition the input,
            // so every input sample is compared exactly once — in_elems,
            // which equals out_elems·size^dims on even dims and stays
            // correct for the ceil remainder windows on odd dims.
            let in_elems: u64 =
                graph.nodes[node.inputs[0]].out_shape.iter().product::<usize>() as u64;
            let relu_sat = if node.fused_relu { out_elems } else { 0 };
            OpCounts { macc: 0, add: 0, shift: 0, sat: in_elems + relu_sat, div: 0 }
        }
        LayerKind::AvgPool { .. } => {
            let in_elems: u64 =
                graph.nodes[node.inputs[0]].out_shape.iter().product::<usize>() as u64;
            OpCounts { macc: 0, add: in_elems, shift: 0, sat: 0, div: out_elems }
        }
        LayerKind::GlobalAvgPool => {
            let in_elems: u64 =
                graph.nodes[node.inputs[0]].out_shape.iter().product::<usize>() as u64;
            OpCounts { macc: 0, add: in_elems, shift: 0, sat: 0, div: out_elems }
        }
        LayerKind::Add => {
            let i = node.inputs.len() as u64;
            let relu_sat = if node.fused_relu { out_elems } else { 0 };
            OpCounts {
                macc: 0,
                add: out_elems * (i - 1), // s*c*(i-1)
                shift: out_elems * i,     // s*c*i
                sat: out_elems + relu_sat,
                div: 0,
            }
        }
        LayerKind::ReLU => OpCounts { sat: out_elems, ..Default::default() },
        LayerKind::ZeroPad { .. } => OpCounts::default(),
        LayerKind::BatchNorm { .. } => OpCounts {
            macc: out_elems,
            shift: 2 * out_elems,
            sat: out_elems,
            ..Default::default()
        },
    }
}

/// GEMM-lowering dims of a weighted node (HOST engine accounting, not the
/// device cost model): the im2col + blocked-GEMM path computes
/// C(m×n) = A(m×k)·B(k×n) with `pack_elems` panel copies per inference
/// (`nn::gemm`). `m·n·k` equals the Table A6 MACC count, which
/// `bench_hotpath` uses to normalize per-shape throughput.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmShape {
    /// Output positions (1 for dense).
    pub m: u64,
    /// Filters / output units.
    pub n: u64,
    /// Taps: k·C (1-D), kh·kw·C (2-D), input units (dense).
    pub k: u64,
    /// im2col elements packed per inference (0: dense needs no packing).
    pub pack_elems: u64,
}

/// GEMM dims for node `id`; None for non-weighted layers.
pub fn node_gemm_shape(graph: &Graph, id: usize) -> Option<GemmShape> {
    let node = &graph.nodes[id];
    match &node.kind {
        LayerKind::Conv { w, .. } => {
            let n = *w.shape.last().unwrap() as u64;
            let k: u64 = w.shape[..w.shape.len() - 1].iter().product::<usize>() as u64;
            let m: u64 =
                node.out_shape[..node.out_shape.len() - 1].iter().product::<usize>() as u64;
            Some(GemmShape { m, n, k, pack_elems: m * k })
        }
        LayerKind::Dense { w, .. } => Some(GemmShape {
            m: 1,
            n: w.shape[1] as u64,
            k: w.shape[0] as u64,
            pack_elems: 0,
        }),
        _ => None,
    }
}

/// Whole-graph op counts.
pub fn graph_ops(graph: &Graph) -> OpCounts {
    (0..graph.nodes.len()).fold(OpCounts::default(), |acc, id| acc.plus(node_ops(graph, id)))
}

/// Number of "dispatched" layers (per-layer engine overhead unit).
pub fn layer_count(graph: &Graph) -> u64 {
    graph
        .nodes
        .iter()
        .filter(|n| !matches!(n.kind, LayerKind::Input | LayerKind::Flatten))
        .count() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::resnet_v1_6_shapes;
    use crate::graph::deploy_pipeline;

    #[test]
    fn conv_macc_matches_table_a6_formula() {
        // Conv1D over (128, 9) with 16 filters k=3, SAME stride 1:
        // f*s*c*k = 16*128*9*3.
        let g = resnet_v1_6_shapes("t", 1, &[128, 9], 6, 16);
        let conv1 = g.nodes.iter().find(|n| n.name == "conv1").unwrap();
        let ops = node_ops(&g, conv1.id);
        assert_eq!(ops.macc, 16 * 128 * 9 * 3);
        assert_eq!(ops.shift, 2 * 16 * 128);
        assert_eq!(ops.sat, 16 * 128);
    }

    #[test]
    fn dense_matches_table_a6() {
        let g = resnet_v1_6_shapes("t", 1, &[128, 9], 6, 16);
        let fc = g.nodes.iter().find(|n| n.name == "fc").unwrap();
        let ops = node_ops(&g, fc.id);
        assert_eq!(ops.macc, 16 * 6);
        assert_eq!(ops.shift, 2 * 6);
        assert_eq!(ops.sat, 6);
    }

    #[test]
    fn add_matches_table_a6() {
        let g = resnet_v1_6_shapes("t", 1, &[128, 9], 6, 16);
        let add1 = g.nodes.iter().find(|n| n.name == "add1").unwrap();
        let ops = node_ops(&g, add1.id);
        let sc: u64 = add1.out_shape.iter().product::<usize>() as u64;
        assert_eq!(ops.add, sc); // i = 2 inputs -> s*c*(i-1)
        assert_eq!(ops.shift, 2 * sc);
        assert_eq!(ops.sat, sc);
    }

    #[test]
    fn ideal_cycles_weights() {
        let o = OpCounts { macc: 10, add: 5, shift: 3, sat: 2, div: 1 };
        assert_eq!(o.ideal_cycles(), 10 + 5 + 3 + 4 + 12);
    }

    #[test]
    fn macc_grows_quadratically_in_filters() {
        let m = |f| {
            let g = resnet_v1_6_shapes("t", 1, &[128, 9], 6, f);
            graph_ops(&g).macc as f64
        };
        // Block convs are f x f: quadrupling should be ~4x between 20 and 40.
        let r = m(40) / m(20);
        assert!((3.0..4.2).contains(&r), "ratio {r}");
    }

    #[test]
    fn fused_graph_has_fewer_sat_ops() {
        let g = resnet_v1_6_shapes("t", 1, &[128, 9], 6, 16);
        let fused = deploy_pipeline(&g);
        // ReLU fusing merges the standalone c*s saturations into the conv
        // epilogue, so total sat count is unchanged, but layer count drops.
        assert!(layer_count(&fused) < layer_count(&g));
        assert_eq!(graph_ops(&fused).macc, graph_ops(&g).macc);
    }

    #[test]
    fn gemm_shape_maccs_match_table_a6() {
        // The GEMM lowering does exactly the Table A6 MACC work: m·n·k
        // equals the formula count for every weighted node, 1-D and 2-D.
        for g in [
            deploy_pipeline(&resnet_v1_6_shapes("t", 1, &[128, 9], 6, 16)),
            deploy_pipeline(&resnet_v1_6_shapes("g", 2, &[16, 16, 3], 5, 8)),
        ] {
            let mut weighted = 0;
            for n in &g.nodes {
                if let Some(gs) = node_gemm_shape(&g, n.id) {
                    weighted += 1;
                    assert_eq!(gs.m * gs.n * gs.k, node_ops(&g, n.id).macc, "{}", n.name);
                    match &n.kind {
                        LayerKind::Dense { .. } => assert_eq!(gs.pack_elems, 0),
                        _ => assert_eq!(gs.pack_elems, gs.m * gs.k),
                    }
                }
            }
            assert_eq!(weighted, 7); // 6 convs + 1 dense in ResNetv1-6
        }
    }

    #[test]
    fn paper_macc_magnitude_at_80_filters() {
        // Sanity: ~4M MACCs at f=80 on UCI-HAR (drives the ~1s @48MHz
        // inference the paper reports with ~12 cycles/MACC effective).
        let g = deploy_pipeline(&resnet_v1_6_shapes("t", 1, &[128, 9], 6, 80));
        let macc = graph_ops(&g).macc;
        assert!((3_000_000..6_000_000).contains(&macc), "macc {macc}");
    }
}
