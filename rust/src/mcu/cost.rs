//! MCU latency / ROM / energy cost models.
//!
//! Latency: cycles(G) = k · ideal_cycles(G) + dispatch · n_layers(G),
//! where ideal_cycles comes from Table A6's op counts over the real graph
//! and (k, dispatch) are the per-(framework, board, dtype) constants
//! calibrated from the series endpoints (see `paper_data`). k absorbs
//! loads/stores/loop overhead around each ALU op; dispatch absorbs
//! per-layer runtime cost (interpreter dispatch for TFLM, function-call
//! setup for compiled engines).
//!
//! ROM: weights·bytes(dtype) + code(f) with code affine in the filter
//! count, fitted from the same endpoints.
//!
//! Energy: E = t · V · I — the paper's own §6.2 method, no fitting.

use crate::graph::ir::Graph;
use crate::graph::resnet_v1_6_shapes;

use super::board::Board;
use super::opcounts::{graph_ops, layer_count};
use super::paper_data::{DType, Series, FILTERS};
#[cfg(test)]
use super::paper_data;

/// Calibrated latency model for one (framework, board, dtype) series.
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel {
    pub k: f64,
    pub dispatch_cycles: f64,
}

/// Calibrated ROM model: code_bytes(filters) = a + b * filters.
#[derive(Clone, Copy, Debug)]
pub struct RomModel {
    pub code_a: f64,
    pub code_b: f64,
    pub dtype: DType,
}

/// The UCI-HAR ResNet the paper's §6.2 sweep uses, post-deployment.
pub fn har_graph(filters: usize) -> Graph {
    crate::graph::deploy_pipeline(&resnet_v1_6_shapes("har", 1, &[128, 9], 6, filters))
}

fn ideal_cycles_har(filters: usize) -> f64 {
    graph_ops(&har_graph(filters)).ideal_cycles() as f64
}

fn layers_har(filters: usize) -> f64 {
    layer_count(&har_graph(filters)) as f64
}

impl LatencyModel {
    /// Fit from a paper series' f=16 and f=80 endpoints.
    pub fn calibrate(series: &Series, board: &Board) -> LatencyModel {
        let c16 = series.values[0] / 1e3 * board.clock_hz;
        let c80 = series.values[6] / 1e3 * board.clock_hz;
        let (i16_, i80) = (ideal_cycles_har(16), ideal_cycles_har(80));
        let n_layers = layers_har(16); // constant across the sweep
        let k = (c80 - c16) / (i80 - i16_);
        let dispatch = (c16 - k * i16_) / n_layers;
        // The affine fit is unconstrained: a negative dispatch term means
        // the small-model endpoint runs sub-linearly (flash caches cover
        // the whole model at f=16 — the paper observes such memory-system
        // effects in §6.2). Predictions are floored at a fraction of the
        // ideal cycle count so the model stays physical off the fitted
        // family.
        LatencyModel { k, dispatch_cycles: dispatch }
    }

    /// Predicted cycles for an arbitrary deployed graph.
    pub fn cycles(&self, graph: &Graph) -> f64 {
        let ideal = graph_ops(graph).ideal_cycles() as f64;
        let affine = self.k * ideal + self.dispatch_cycles * layer_count(graph) as f64;
        affine.max(ideal)
    }

    pub fn latency_s(&self, graph: &Graph, board: &Board) -> f64 {
        board.seconds(self.cycles(graph))
    }
}

impl RomModel {
    /// Fit from a paper ROM series' endpoints, subtracting exact weight
    /// bytes of the HAR ResNet.
    pub fn calibrate(series: &Series) -> RomModel {
        let wbytes = |f: usize| {
            (har_graph(f).param_count() * series.dtype.bytes()) as f64
        };
        let code16 = series.values[0] * 1024.0 - wbytes(16);
        let code80 = series.values[6] * 1024.0 - wbytes(80);
        let b = (code80 - code16) / (80.0 - 16.0);
        let a = code16 - b * 16.0;
        RomModel { code_a: a, code_b: b, dtype: series.dtype }
    }

    /// Predicted ROM bytes for a deployed graph with `filters` per conv.
    pub fn rom_bytes(&self, graph: &Graph, filters: usize) -> f64 {
        (graph.param_count() * self.dtype.bytes()) as f64
            + self.code_a
            + self.code_b * filters as f64
    }
}

/// Energy for one inference: E[µWh] = t[s] · P[W] / 3600 · 1e6 (§6.2).
pub fn energy_uwh(latency_s: f64, board: &Board) -> f64 {
    latency_s * board.power_w() / 3600.0 * 1e6
}

/// Validation record comparing model predictions to the paper's rows.
#[derive(Clone, Debug)]
pub struct SeriesValidation {
    pub framework: String,
    pub board: String,
    pub dtype: DType,
    pub predicted: Vec<f64>,
    pub paper: Vec<f64>,
    /// Max relative error over the 5 held-out filter counts.
    pub max_held_out_rel_err: f64,
}

/// Predict a full Table A4-style latency series and compare to the paper.
pub fn validate_latency(series: &Series) -> SeriesValidation {
    let board = Board::by_name(series.board).unwrap();
    let model = LatencyModel::calibrate(series, board);
    let mut predicted = Vec::new();
    let mut max_err = 0.0f64;
    for (i, &f) in FILTERS.iter().enumerate() {
        let ms = model.latency_s(&har_graph(f), board) * 1e3;
        predicted.push(ms);
        if i != 0 && i != 6 {
            max_err = max_err.max((ms - series.values[i]).abs() / series.values[i]);
        }
    }
    SeriesValidation {
        framework: series.framework.to_string(),
        board: series.board.to_string(),
        dtype: series.dtype,
        predicted,
        paper: series.values.to_vec(),
        max_held_out_rel_err: max_err,
    }
}

/// Predict a Table A3-style ROM series and compare to the paper.
pub fn validate_rom(series: &Series) -> SeriesValidation {
    let model = RomModel::calibrate(series);
    let mut predicted = Vec::new();
    let mut max_err = 0.0f64;
    for (i, &f) in FILTERS.iter().enumerate() {
        let kib = model.rom_bytes(&har_graph(f), f) / 1024.0;
        predicted.push(kib);
        if i != 0 && i != 6 {
            max_err = max_err.max((kib - series.values[i]).abs() / series.values[i]);
        }
    }
    SeriesValidation {
        framework: series.framework.to_string(),
        board: series.board.to_string(),
        dtype: series.dtype,
        predicted,
        paper: series.values.to_vec(),
        max_held_out_rel_err: max_err,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_reproduces_endpoints() {
        for s in &paper_data::TABLE_A4_MS {
            let v = validate_latency(s);
            let rel16 = (v.predicted[0] - s.values[0]).abs() / s.values[0];
            let rel80 = (v.predicted[6] - s.values[6]).abs() / s.values[6];
            // Affine fit reproduces both endpoints exactly.
            assert!(rel80 < 1e-6, "{} {} {:?}: f80 {rel80}", s.framework, s.board, s.dtype);
            assert!(rel16 < 1e-6, "{} {} {:?}: f16 {rel16}", s.framework, s.board, s.dtype);
        }
    }

    #[test]
    fn held_out_filter_counts_within_tolerance() {
        // The shape claim: intermediate filter counts, never fitted, stay
        // within a modest error band.
        for s in &paper_data::TABLE_A4_MS {
            let v = validate_latency(s);
            assert!(
                v.max_held_out_rel_err < 0.22,
                "{} {} {:?}: held-out err {}",
                s.framework, s.board, s.dtype, v.max_held_out_rel_err
            );
        }
    }

    #[test]
    fn rom_model_held_out_error() {
        for s in &paper_data::TABLE_A3_KIB {
            let v = validate_rom(s);
            assert!(
                v.max_held_out_rel_err < 0.12,
                "{} {} {:?}: ROM held-out err {}",
                s.framework, s.board, s.dtype, v.max_held_out_rel_err
            );
        }
    }

    #[test]
    fn energy_matches_paper_method() {
        // MicroAI float32 SparkFun f=80: 1.561264 s * 2.706 mW -> 1.174 µWh.
        let b = Board::by_name("SparkFunEdge").unwrap();
        let e = energy_uwh(1.561264, b);
        assert!((e - 1.174).abs() < 0.01, "{e}");
    }

    #[test]
    fn who_wins_is_preserved() {
        // The paper's ordering claims at f=80, reproduced by the model:
        // int8 CubeAI < int8 TFLM < int8 MicroAI (§6.2).
        use paper_data::{find, TABLE_A4_MS};
        let get = |fw: &str, board: &str, dt: DType| {
            let s = find(&TABLE_A4_MS, fw, board, dt).unwrap();
            validate_latency(s).predicted[6]
        };
        let cube = get("STM32Cube.AI", "NucleoL452REP", DType::I8);
        let tflm = get("TFLiteMicro", "SparkFunEdge", DType::I8);
        let micro = get("MicroAI", "NucleoL452REP", DType::I8);
        assert!(cube < tflm && tflm < micro, "{cube} {tflm} {micro}");
        // And float is slower than int for every MicroAI series.
        let mf = get("MicroAI", "NucleoL452REP", DType::F32);
        assert!(micro < mf);
    }

    #[test]
    fn latency_model_generalizes_to_other_graphs() {
        // Prediction must be positive, monotone in filters for a 2D net.
        let s = find_micro_int8();
        let board = Board::by_name(s.board).unwrap();
        let model = LatencyModel::calibrate(s, board);
        let g8 = crate::graph::deploy_pipeline(
            &resnet_v1_6_shapes("g", 2, &[32, 32, 3], 43, 8));
        let g16 = crate::graph::deploy_pipeline(
            &resnet_v1_6_shapes("g", 2, &[32, 32, 3], 43, 16));
        let (t8, t16) = (model.latency_s(&g8, board), model.latency_s(&g16, board));
        assert!(t8 > 0.0 && t16 > t8);
    }

    fn find_micro_int8() -> &'static Series {
        paper_data::find(&paper_data::TABLE_A4_MS, "MicroAI", "NucleoL452REP", DType::I8).unwrap()
    }

}
