//! Shared integer LUTs for the transformer ops: exp (softmax) and rsqrt
//! (layernorm).
//!
//! Both tables are the single source of truth for every consumer — the
//! Rust integer kernels (`nn::int_ops`, `nn::affine_exec`), their naive
//! references, and the C emitter (which bakes the same values into
//! `model.c` as static arrays) — so the lowering cannot drift from the
//! reference semantics.
//!
//! Error bounds (documented in DESIGN.md §9):
//! - `EXP_LUT` buckets [0, 8) into 256 cells of width 1/32 and stores the
//!   midpoint exp(−u) in Q0.15; the worst-case relative error of one
//!   lookup is ≤ 1/64 (half a bucket times |d exp(−u)/du| / exp(−u) = 1)
//!   plus Q0.15 rounding. Distances ≥ 8 underflow to 0 (exp(−8) < 2^−11).
//! - `RSQRT_LUT` buckets the normalized mantissa m ∈ [1, 2) into 64 cells
//!   and stores the midpoint 1/sqrt(m) in Q2.30; worst-case relative
//!   error ≤ 1/256 (half a bucket times 1/2, the rsqrt log-derivative).

use std::sync::OnceLock;

use super::ops::rescale;

/// Entries of the exp table (bucket count over the [0, 8) distance range).
pub const EXP_LUT_SIZE: usize = 256;
/// Buckets per unit distance: 256 / 8 = 32 = 2^5.
pub const EXP_IDX_SHIFT: i32 = 5;
/// exp outputs are Q0.15 (so a full softmax row sums ≲ seq · 2^15 in i64).
pub const EXP_FRAC_BITS: i32 = 15;

/// Entries of the rsqrt mantissa table (m ∈ [1, 2) in 64 buckets).
pub const RSQRT_LUT_SIZE: usize = 64;
/// rsqrt outputs are Q2.30.
pub const RSQRT_FRAC_BITS: i32 = 30;
/// round(2^30 / sqrt(2)) — folds the odd-exponent half-shift.
pub const INV_SQRT2_Q30: i64 = 759_250_125;

/// exp(−(j + 0.5) / 32) in Q0.15 for bucket j.
pub fn exp_lut() -> &'static [i32; EXP_LUT_SIZE] {
    static LUT: OnceLock<[i32; EXP_LUT_SIZE]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0i32; EXP_LUT_SIZE];
        for (j, e) in t.iter_mut().enumerate() {
            let u = (j as f64 + 0.5) / 32.0;
            *e = ((-u).exp() * f64::from(1 << EXP_FRAC_BITS)).round() as i32;
        }
        t
    })
}

/// 1/sqrt((64 + idx + 0.5) / 64) in Q2.30 for mantissa bucket idx.
pub fn rsqrt_lut() -> &'static [i32; RSQRT_LUT_SIZE] {
    static LUT: OnceLock<[i32; RSQRT_LUT_SIZE]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0i32; RSQRT_LUT_SIZE];
        for (idx, r) in t.iter_mut().enumerate() {
            let m = (64.0 + idx as f64 + 0.5) / 64.0;
            *r = (f64::from(1u32 << RSQRT_FRAC_BITS as u32) / m.sqrt()).round() as i32;
        }
        t
    })
}

/// Bucket index the exp lookup reads for distance `d` at format `n` —
/// the single definition shared by [`exp_q`] and the range verifier
/// (`analysis`), so the proven index bound cannot drift from the kernel.
/// Indices ≥ [`EXP_LUT_SIZE`] underflow to probability 0 by design.
#[inline]
pub fn exp_q_index(d: i64, n: i32) -> i64 {
    rescale(d << EXP_IDX_SHIFT, n)
}

/// exp(−d · 2^−n) in Q0.15 for a non-negative payload distance `d` at
/// fixed-point format n (the softmax inner lookup). Distances past the
/// table range return 0 — the softmax max-subtraction guarantees d ≥ 0.
#[inline]
pub fn exp_q(d: i64, n: i32) -> i32 {
    debug_assert!(d >= 0, "exp_q wants a max-subtracted distance");
    let j = exp_q_index(d, n);
    if j >= EXP_LUT_SIZE as i64 {
        0
    } else {
        exp_lut()[j as usize]
    }
}

/// Normalized reciprocal square root of an integer v ≥ 1: returns
/// (r, h) with 1/sqrt(v) ≈ r · 2^(−30 − h), r in Q2.30. The layernorm
/// kernels call this on (var_payload + 1), so v ≥ 1 always holds.
#[inline]
pub fn rsqrt_norm(v: i64) -> (i64, i32) {
    debug_assert!(v >= 1, "rsqrt_norm domain is v >= 1");
    let e = 63 - v.leading_zeros() as i32; // floor(log2 v)
    let idx = if e >= 6 {
        ((v >> (e - 6)) & 63) as usize
    } else {
        ((v << (6 - e)) & 63) as usize
    };
    let r = rsqrt_lut()[idx] as i64;
    if e & 1 == 1 {
        ((r * INV_SQRT2_Q30) >> 30, (e - 1) / 2)
    } else {
        (r, e / 2)
    }
}

/// Inclusive bounds of the Q2.30 mantissa `r` that [`rsqrt_norm`] can
/// return for ANY v ≥ 1: the smallest is the last table cell folded by
/// 1/sqrt(2) (odd exponent), the largest the first cell. Used by the
/// range verifier's layernorm transfer function.
pub fn rsqrt_r_bounds() -> (i64, i64) {
    let lut = rsqrt_lut();
    (
        (lut[RSQRT_LUT_SIZE - 1] as i64 * INV_SQRT2_Q30) >> 30,
        lut[0] as i64,
    )
}

/// Largest half-exponent `h` that [`rsqrt_norm`] can return over the
/// domain 1 ≤ v ≤ `v_max` (h grows monotonically with floor(log2 v)).
pub fn rsqrt_h_max(v_max: i64) -> i32 {
    debug_assert!(v_max >= 1, "rsqrt_norm domain is v >= 1");
    (63 - v_max.leading_zeros() as i32) / 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::check::property;

    #[test]
    fn exp_lut_endpoints_and_monotone() {
        let lut = exp_lut();
        // First bucket midpoint: exp(-1/64) ≈ 0.9845 → ~32261 in Q0.15.
        assert!((lut[0] - 32261).abs() <= 1);
        // Strictly decreasing, positive throughout the table.
        for j in 1..EXP_LUT_SIZE {
            assert!(lut[j] < lut[j - 1], "exp LUT not decreasing at {j}");
        }
        assert!(lut[EXP_LUT_SIZE - 1] > 0);
    }

    #[test]
    fn exp_q_tracks_float_exp_within_bucket_error() {
        property(500, |g| {
            let n = g.i32_in(0, 15);
            let d = g.i32_in(0, (8i64 << n).min(1 << 24) as i32 - 1) as i64;
            let got = exp_q(d, n) as f64 / f64::from(1 << EXP_FRAC_BITS);
            let want = (-(d as f64) / f64::powi(2.0, n)).exp();
            // Half-bucket + quantization slack: 1/64 relative on the value
            // scale, floored by one Q0.15 ulp.
            prop_assert!(
                (got - want).abs() <= want / 32.0 + 2.0 / 32768.0,
                "exp_q off at d={d} n={n}: got {got} want {want}"
            );
            Ok(())
        });
    }

    #[test]
    fn exp_q_underflows_to_zero_past_range() {
        assert_eq!(exp_q(8 << 10, 10), 0);
        assert_eq!(exp_q(1 << 30, 5), 0);
    }

    #[test]
    fn rsqrt_norm_tracks_float_rsqrt() {
        property(500, |g| {
            let v = g.i32_in(1, i32::MAX) as i64 * (1 + g.i32_in(0, 1 << 20) as i64);
            let (r, h) = rsqrt_norm(v);
            let got = r as f64 * f64::powi(2.0, -30 - h);
            let want = 1.0 / (v as f64).sqrt();
            prop_assert!(
                (got - want).abs() <= want / 128.0,
                "rsqrt_norm off at v={v}: got {got} want {want}"
            );
            Ok(())
        });
    }

    // Soundness of the verifier-facing transfer functions: the bounds
    // must dominate the exact kernel over the whole sampled domain.
    #[test]
    fn prop_exp_q_index_is_the_kernel_index() {
        property(500, |g| {
            let n = g.i32_in(0, 20);
            let d = g.i32_in(0, i32::MAX) as i64;
            let j = exp_q_index(d, n);
            prop_assert!(j >= 0, "negative index for d={d} n={n}");
            if j >= EXP_LUT_SIZE as i64 {
                prop_assert!(exp_q(d, n) == 0, "underflow mismatch at d={d} n={n}");
            } else {
                prop_assert!(
                    exp_q(d, n) == exp_lut()[j as usize],
                    "index {j} disagrees with exp_q at d={d} n={n}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_rsqrt_bounds_contain_every_return() {
        let (r_lo, r_hi) = rsqrt_r_bounds();
        assert!(0 < r_lo && r_lo < r_hi && r_hi < 1i64 << 31);
        property(500, |g| {
            let v_max = 1 + g.i32_in(0, i32::MAX) as i64 * (1 + g.i32_in(0, 1 << 16) as i64);
            let v = 1 + (g.i32_in(0, i32::MAX) as i64 * 65537) % v_max;
            let (r, h) = rsqrt_norm(v);
            prop_assert!(
                (r_lo..=r_hi).contains(&r),
                "r={r} escapes [{r_lo}, {r_hi}] at v={v}"
            );
            prop_assert!(
                (0..=rsqrt_h_max(v_max)).contains(&h),
                "h={h} escapes [0, {}] at v={v} v_max={v_max}",
                rsqrt_h_max(v_max)
            );
            Ok(())
        });
    }

    #[test]
    fn rsqrt_norm_powers_of_two_are_near_exact() {
        for k in 0..30 {
            let (r, h) = rsqrt_norm(1i64 << (2 * k));
            let got = r as f64 * f64::powi(2.0, -30 - h);
            let want = f64::powi(2.0, -(k as i32));
            assert!(
                (got - want).abs() <= want / 128.0,
                "v=2^{}: got {got} want {want}",
                2 * k
            );
        }
    }
}
