//! The Qm.n format and the paper's scale-factor rule (Eqs 1–4).

/// A signed fixed-point format: `width` total bits (incl. sign) with `n`
/// fractional bits. `m = width - n - 1` integer bits (Eq 2). `n` may exceed
/// `width` (small-magnitude vectors recover leading unused bits, §4.1.4) or
/// be negative (integer part not fully representable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QFormat {
    pub width: u32,
    pub n: i32,
}

impl QFormat {
    pub fn new(width: u32, n: i32) -> Self {
        assert!((2..=32).contains(&width), "width {width}");
        Self { width, n }
    }

    /// The paper's fixed Q7.9-on-16-bit network-wide format (§6: "Quantization
    /// is performed using the Q7.9 format for the whole network").
    pub fn q7_9() -> Self {
        Self::new(16, 9)
    }

    /// Eqs 1–2: derive the format from the max absolute value of a vector.
    /// An all-zero vector takes m = 0 (matches quant_math.py).
    pub fn from_max_abs(max_abs: f32, width: u32) -> Self {
        let m = if max_abs > 0.0 {
            1 + max_abs.abs().log2().floor() as i32
        } else {
            0
        };
        Self::new(width, width as i32 - m - 1)
    }

    pub fn from_slice(xs: &[f32], width: u32) -> Self {
        let max_abs = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        Self::from_max_abs(max_abs, width)
    }

    /// Integer payload limits (two's complement, Eq in §3.2).
    pub fn limits(&self) -> (i32, i32) {
        let lo = -(1i64 << (self.width - 1)) as i32;
        let hi = ((1i64 << (self.width - 1)) - 1) as i32;
        (lo, hi)
    }

    /// Scale factor s = 2^-n (Eq 4).
    pub fn scale(&self) -> f32 {
        (2.0f32).powi(-self.n)
    }

    /// Resolution of the format = 2^-n; dynamic range per §3.2.
    pub fn resolution(&self) -> f32 {
        self.scale()
    }

    pub fn dynamic_range(&self) -> (f32, f32) {
        let (lo, hi) = self.limits();
        (lo as f32 * self.scale(), hi as f32 * self.scale())
    }

    /// Eq 3 with saturation: float → integer payload, truncation toward 0.
    pub fn quantize(&self, x: f32) -> i32 {
        let (lo, hi) = self.limits();
        let scaled = (x * (2.0f32).powi(self.n)).trunc();
        if scaled <= lo as f32 {
            lo
        } else if scaled >= hi as f32 {
            hi
        } else {
            scaled as i32
        }
    }

    /// Integer payload → float.
    pub fn dequantize(&self, q: i32) -> f32 {
        q as f32 * self.scale()
    }

    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<i32> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    pub fn dequantize_slice(&self, qs: &[i32]) -> Vec<f32> {
        qs.iter().map(|&q| self.dequantize(q)).collect()
    }

    /// Worst-case quantization step (useful for error-bound tests).
    pub fn step(&self) -> f32 {
        self.scale()
    }

    /// [`QFormat::limits`] widened to the i64 accumulator domain — the
    /// payload interval every value of this format inhabits (the range
    /// verifier's Input/clamp transfer).
    pub fn payload_interval(&self) -> (i64, i64) {
        let (lo, hi) = self.limits();
        (lo as i64, hi as i64)
    }
}

/// Monotone interval transfer of [`super::ops::rescale`]: the image of
/// `[lo, hi]` under the floor-shift. Returns `None` when a left shift
/// would push an endpoint past i64 — the runtime shift would silently
/// drop high bits there, so the range verifier treats it as a proof
/// failure rather than an interval.
pub fn rescale_interval(lo: i64, hi: i64, shift: i32) -> Option<(i64, i64)> {
    debug_assert!(lo <= hi);
    if shift >= 0 {
        // Arithmetic right shift is total and monotone.
        Some((lo >> shift.min(63), hi >> shift.min(63)))
    } else {
        let k = (-shift).min(63) as u32;
        let (llo, lhi) = ((lo as i128) << k, (hi as i128) << k);
        if llo < i64::MIN as i128 || lhi > i64::MAX as i128 {
            None
        } else {
            Some((llo as i64, lhi as i64))
        }
    }
}

/// Interval transfer of [`super::ops::clamp_to`]: the clamped image of
/// `[lo, hi]` plus whether the saturation is reachable (some value of the
/// input interval actually hits a rail).
pub fn clamp_interval(lo: i64, hi: i64, width: u32) -> ((i64, i64), bool) {
    debug_assert!(lo <= hi);
    let (llo, lhi) = QFormat::new(width, 0).payload_interval();
    ((lo.clamp(llo, lhi), hi.clamp(llo, lhi)), lo < llo || hi > lhi)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Pinned to python/tests/test_quant_math.py::PINNED_N — the cross-layer
    // contract.
    #[test]
    fn pinned_scale_vectors() {
        let cases: &[(f32, u32, i32)] = &[
            (1.0, 8, 6),
            (1.98, 8, 6),
            (2.0, 8, 5),
            (0.49, 8, 8),
            (0.25, 8, 8),
            (100.0, 8, 0),
            (200.0, 8, -1),
            (1.0, 16, 14),
            (3.0, 16, 13),
            (0.0078125, 16, 21),
        ];
        for &(maxabs, width, expect_n) in cases {
            let q = QFormat::from_max_abs(maxabs, width);
            assert_eq!(q.n, expect_n, "max_abs={maxabs} width={width}");
        }
    }

    #[test]
    fn zero_vector_convention() {
        assert_eq!(QFormat::from_max_abs(0.0, 8).n, 7);
        assert_eq!(QFormat::from_max_abs(0.0, 16).n, 15);
    }

    #[test]
    fn q7_9_matches_paper_table2_style() {
        let q = QFormat::q7_9();
        let (lo, hi) = q.dynamic_range();
        assert_eq!(lo, -64.0); // Q7.9: m=6 magnitude bits + sign
        assert!((hi - (64.0 - q.step())).abs() < 1e-6);
        assert!((q.resolution() - 0.001953125).abs() < 1e-9);
    }

    #[test]
    fn q16_16_table2() {
        // Table 2: Q16.16 on 32 bits -> range [-32768, 32767.9999847],
        // resolution 1.5259e-5.
        let q = QFormat::new(32, 16);
        let (lo, hi) = q.dynamic_range();
        assert_eq!(lo, -32768.0);
        assert!((hi - 32767.99998).abs() < 1e-3);
        assert!((q.resolution() - 1.5259e-5).abs() < 1e-9);
    }

    #[test]
    fn quantize_truncates_toward_zero() {
        let q = QFormat::new(8, 0);
        assert_eq!(q.quantize(1.9), 1);
        assert_eq!(q.quantize(-1.9), -1);
    }

    #[test]
    fn quantize_saturates() {
        let q = QFormat::new(8, 0);
        assert_eq!(q.quantize(300.0), 127);
        assert_eq!(q.quantize(-300.0), -128);
    }

    #[test]
    fn roundtrip_error_below_step() {
        use crate::util::check::property;
        property(200, |g| {
            let width = *g.pick(&[8u32, 9, 16]);
            let xs = g.vec_normal(64, 2.0);
            let q = QFormat::from_slice(&xs, width);
            for &x in &xs {
                let rt = q.dequantize(q.quantize(x));
                let err = (rt - x).abs();
                crate::prop_assert!(
                    err < q.step() + 1e-6,
                    "width={width} n={} x={x} rt={rt} err={err}",
                    q.n
                );
            }
            Ok(())
        });
    }

    #[test]
    fn negative_n_loses_low_bits_only() {
        // max 200 at width 8 -> n = -1: representable multiples of 2.
        let q = QFormat::from_max_abs(200.0, 8);
        assert_eq!(q.n, -1);
        assert_eq!(q.quantize(200.0), 100); // payload 100 * 2^1 = 200
        assert_eq!(q.dequantize(q.quantize(200.0)), 200.0);
        assert_eq!(q.dequantize(q.quantize(3.0)), 2.0); // truncated
    }

    // Soundness of the range verifier's primitive transfers: the interval
    // image must contain the exact kernel result for every in-interval
    // point (monotone over-approximation), across random widths/shifts.
    #[test]
    fn prop_rescale_interval_contains_rescale() {
        use crate::fixedpoint::ops::rescale;
        use crate::util::check::property;
        property(500, |g| {
            let a = g.i32_in(i32::MIN, i32::MAX) as i64 * (1 + g.i32_in(0, 1 << 20) as i64);
            let b = g.i32_in(i32::MIN, i32::MAX) as i64;
            let (lo, hi) = (a.min(b), a.max(b));
            let shift = g.i32_in(-20, 40);
            let v = lo + ((g.i32_in(0, i32::MAX) as i64 * 65537) % (hi - lo + 1)).abs();
            match rescale_interval(lo, hi, shift) {
                Some((rlo, rhi)) => {
                    let r = rescale(v, shift);
                    crate::prop_assert!(
                        (rlo..=rhi).contains(&r),
                        "rescale({v}, {shift}) = {r} escapes [{rlo}, {rhi}]"
                    );
                }
                None => {
                    // Refusal must only happen when an endpoint genuinely
                    // escapes i64 under the capped left shift.
                    let k = (-shift).min(63) as u32;
                    let worst =
                        ((lo as i128) << k).abs().max(((hi as i128) << k).abs());
                    crate::prop_assert!(
                        shift < 0 && worst > i64::MAX as i128,
                        "spurious refusal at [{lo}, {hi}] shift {shift}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_clamp_interval_contains_clamp_to() {
        use crate::fixedpoint::ops::clamp_to;
        use crate::util::check::property;
        property(500, |g| {
            let width = *g.pick(&[8u32, 9, 16]);
            let a = g.i32_in(i32::MIN, i32::MAX) as i64;
            let b = g.i32_in(i32::MIN, i32::MAX) as i64;
            let (lo, hi) = (a.min(b), a.max(b));
            let v = lo + ((g.i32_in(0, i32::MAX) as i64 * 31) % (hi - lo + 1)).abs();
            let ((clo, chi), sat) = clamp_interval(lo, hi, width);
            let c = clamp_to(v, width) as i64;
            crate::prop_assert!(
                (clo..=chi).contains(&c),
                "clamp_to({v}, {width}) = {c} escapes [{clo}, {chi}]"
            );
            // The saturation flag is exact: reachable iff some endpoint
            // maps to a rail from outside.
            let (llo, lhi) = QFormat::new(width, 0).payload_interval();
            crate::prop_assert!(
                sat == (lo < llo || hi > lhi),
                "saturation flag wrong on [{lo}, {hi}] width {width}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_quantize_lands_in_payload_interval() {
        use crate::util::check::property;
        property(300, |g| {
            let width = *g.pick(&[8u32, 9, 16]);
            let q = QFormat::from_max_abs(g.f32_in(0.0, 100.0), width);
            let (lo, hi) = q.payload_interval();
            let v = q.quantize(g.f32_in(-1000.0, 1000.0)) as i64;
            crate::prop_assert!(
                (lo..=hi).contains(&v),
                "payload {v} escapes [{lo}, {hi}] at width {width}"
            );
            Ok(())
        });
    }

    #[test]
    fn dequantize_slice_roundtrip() {
        let q = QFormat::new(16, 9);
        let xs = vec![0.5, -0.25, 1.75, 63.0];
        let rt = q.dequantize_slice(&q.quantize_slice(&xs));
        for (a, b) in xs.iter().zip(rt.iter()) {
            assert!((a - b).abs() <= q.step());
        }
    }
}
