//! Scalar fixed-point primitives with the generated-C semantics of §5.8.
//!
//! Payloads are carried as `i32` (operands) and `i64` (accumulators — the
//! `long_number_t` of the C headers). The hot loops in `nn::int_ops` inline
//! these; they are kept as free functions so the property tests and the C
//! code generator share one definition.

/// Saturate an i64 accumulator to a `width`-bit signed payload
/// (`clamp_to_number_t` in the generated number.h).
#[inline(always)]
pub fn clamp_to(acc: i64, width: u32) -> i32 {
    let lo = -(1i64 << (width - 1));
    let hi = (1i64 << (width - 1)) - 1;
    acc.clamp(lo, hi) as i32
}

/// Multiply-accumulate: acc += a * b, widening (SMLABB on Cortex-M4,
/// Table A6: 1 cycle).
#[inline(always)]
pub fn macc_i32(acc: i64, a: i32, b: i32) -> i64 {
    acc + (a as i64) * (b as i64)
}

/// Arithmetic-shift-right rescale with floor semantics; negative `shift`
/// shifts left (scale up). Matches `>>` on two's-complement C integers.
#[inline(always)]
pub fn rescale(acc: i64, shift: i32) -> i64 {
    if shift >= 0 {
        acc >> shift.min(63)
    } else {
        acc << (-shift).min(63)
    }
}

/// Full epilogue: rescale then saturate (the per-output-element tail of the
/// conv/dense loops — Table A6 counts this as 2 shifts + 1 saturate).
#[inline(always)]
pub fn sat_mul_shift(acc: i64, shift: i32, width: u32) -> i32 {
    clamp_to(rescale(acc, shift), width)
}

/// Saturating i32 addition at a given width (element-wise Add layer, §4.3).
#[inline(always)]
pub fn sat_add_i32(a: i32, b: i32, width: u32) -> i32 {
    clamp_to(a as i64 + b as i64, width)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::check::property;

    #[test]
    fn clamp_bounds() {
        assert_eq!(clamp_to(1_000_000, 8), 127);
        assert_eq!(clamp_to(-1_000_000, 8), -128);
        assert_eq!(clamp_to(100, 8), 100);
        assert_eq!(clamp_to(40_000, 16), 32_767);
        assert_eq!(clamp_to(-40_000, 16), -32_768);
    }

    #[test]
    fn rescale_is_floor_division() {
        assert_eq!(rescale(7, 1), 3);
        assert_eq!(rescale(-7, 1), -4); // ASR floors, not truncates
        assert_eq!(rescale(-1, 4), -1);
        assert_eq!(rescale(5, -2), 20);
    }

    #[test]
    fn macc_widens() {
        let acc = macc_i32(0, i32::MAX, i32::MAX);
        assert_eq!(acc, (i32::MAX as i64) * (i32::MAX as i64));
    }

    #[test]
    fn sat_add_saturates_like_qadd() {
        assert_eq!(sat_add_i32(120, 30, 8), 127);
        assert_eq!(sat_add_i32(-120, -30, 8), -128);
        assert_eq!(sat_add_i32(50, 20, 8), 70);
    }

    // Property: rescale+clamp equals exact arithmetic when in range.
    #[test]
    fn prop_epilogue_exact_when_in_range() {
        property(500, |g| {
            let width = *g.pick(&[8u32, 16]);
            let shift = g.i32_in(0, 12);
            let (lo, hi) = (-(1i64 << (width - 1)), (1i64 << (width - 1)) - 1);
            let acc = g.i32_in(-100_000, 100_000) as i64;
            let exact = (acc as f64 / f64::powi(2.0, shift)).floor() as i64;
            let got = sat_mul_shift(acc, shift, width) as i64;
            if (lo..=hi).contains(&exact) {
                prop_assert!(got == exact, "acc={acc} shift={shift} got={got} exact={exact}");
            } else {
                prop_assert!(got == lo || got == hi, "saturation expected");
            }
            Ok(())
        });
    }

    // Property: saturation is monotone — larger accumulator never maps to a
    // smaller payload.
    #[test]
    fn prop_saturation_monotone() {
        property(500, |g| {
            let width = *g.pick(&[8u32, 16]);
            let shift = g.i32_in(0, 8);
            let a = g.i32_in(-1_000_000, 1_000_000) as i64;
            let b = g.i32_in(-1_000_000, 1_000_000) as i64;
            let (x, y) = if a <= b { (a, b) } else { (b, a) };
            prop_assert!(
                sat_mul_shift(x, shift, width) <= sat_mul_shift(y, shift, width),
                "monotonicity violated at {x} vs {y}"
            );
            Ok(())
        });
    }

    // Property: sat_add is commutative and bounded.
    #[test]
    fn prop_sat_add_commutative_bounded() {
        property(500, |g| {
            let width = *g.pick(&[8u32, 16]);
            let (lo, hi) = (-(1i32 << (width - 1)), (1i32 << (width - 1)) - 1);
            let a = g.i32_in(lo, hi);
            let b = g.i32_in(lo, hi);
            let ab = sat_add_i32(a, b, width);
            let ba = sat_add_i32(b, a, width);
            prop_assert!(ab == ba, "not commutative");
            prop_assert!((lo..=hi).contains(&ab), "out of range");
            Ok(())
        });
    }
}
