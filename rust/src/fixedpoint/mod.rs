//! Fixed-point Qm.n arithmetic (paper §3.2, §4.1, §5.8).
//!
//! This module is the numeric substrate of the MicroAI integer inference
//! engine (`nn::int_ops`) and the quantizer (`quant`). Semantics mirror the
//! generated C code described in the paper:
//!
//! - signed two's-complement payloads in `i8`/`i16` (generically `i32`),
//! - widening multiply-accumulate into a payload twice the operand width
//!   (`long_number_t` in the C headers),
//! - rescale by arithmetic shift right (floor semantics, like `>>` in C),
//! - saturation on the way back to the narrow type
//!   (`clamp_to_number_t`, §5.6).
//!
//! The scale-factor rule (Eqs 1–4) lives in [`QFormat`]; it is pinned to the
//! same vectors as `python/compile/kernels/quant_math.py`.

pub mod lut;
pub mod ops;
pub mod qformat;

pub use ops::{clamp_to, macc_i32, rescale, sat_add_i32, sat_mul_shift};
pub use qformat::QFormat;
