//! Hand-rolled substrates for the offline environment: PRNG, JSON, TOML,
//! CLI parsing, statistics, property-test and bench harnesses.
//!
//! These replace `rand`, `serde_json`, `toml`, `clap`, `proptest` and
//! `criterion`, which are not available without network access.

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod prng;
pub mod stats;
pub mod toml;
