//! Minimal JSON parser + writer (no serde in this offline environment).
//!
//! Parses the artifact manifest written by `python/compile/aot.py` and
//! serializes result records for `results/*.json`. Supports the full JSON
//! grammar except `\u` surrogate pairs outside the BMP are passed through
//! unvalidated.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path accessor: `v.at(&["models", "har_f16", "filters"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_into(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    pub fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.as_obj().unwrap()["a"].as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"m":{"x":[1,2.5,"s",true,null]},"n":-3}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"version": 1, "models": {"har_f16": {"filters": 16,
            "param_shapes": [[3, 9, 16], [16]]}}}"#;
        let v = Json::parse(src).unwrap();
        let shapes = v
            .at(&["models", "har_f16", "param_shapes"])
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shapes[0].as_arr().unwrap()[1].as_usize(), Some(9));
    }
}
