//! Small statistics helpers shared by the bench harness, the evaluator and
//! the serving simulator: mean/median/percentiles/MAD over f64 samples.

#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&s, 0.5)
}

/// Median absolute deviation (robust spread, used for bench noise checks).
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n: s.len(),
        mean: mean(&s),
        std: stddev(&s),
        min: s[0],
        p50: percentile(&s, 0.5),
        p90: percentile(&s, 0.9),
        p99: percentile(&s, 0.99),
        max: s[s.len() - 1],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 0.5), 50.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert!((percentile(&xs, 0.9) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }

    #[test]
    fn summary_fields() {
        let s = summarize(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn empty_is_safe() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(mean(&[]), 0.0);
    }
}
