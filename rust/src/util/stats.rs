//! Small statistics helpers shared by the bench harness, the evaluator and
//! the serving simulator: mean/median/percentiles/MAD over f64 samples.
//!
//! NaN policy: order statistics ([`median`], [`mad`], [`summarize`])
//! silently DROP NaN samples instead of panicking — a single poisoned
//! sample (e.g. a 0/0 ratio from a zero-duration timer tick) must not
//! abort an entire bench or serving run. [`Summary::nan_dropped`] reports
//! how many samples were discarded so the caller can surface it.
//! (Pre-fix, these sorted with `partial_cmp(..).unwrap()` and panicked on
//! the first NaN.)

#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Number of FINITE-ordered (non-NaN) samples summarized.
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
    /// NaN samples dropped before summarizing (0 on clean data).
    pub nan_dropped: usize,
}

/// Sorted non-NaN samples plus the dropped-NaN count.
fn sorted_finite(xs: &[f64]) -> (Vec<f64>, usize) {
    let mut s: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    let dropped = xs.len() - s.len();
    s.sort_by(f64::total_cmp);
    (s, dropped)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median over the non-NaN samples (0.0 when none survive).
pub fn median(xs: &[f64]) -> f64 {
    let (s, _) = sorted_finite(xs);
    percentile(&s, 0.5)
}

/// Median absolute deviation (robust spread, used for bench noise
/// checks), over the non-NaN samples.
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().filter(|x| !x.is_nan()).map(|x| (x - m).abs()).collect();
    median(&dev)
}

pub fn summarize(xs: &[f64]) -> Summary {
    let (s, nan_dropped) = sorted_finite(xs);
    if s.is_empty() {
        return Summary { nan_dropped, ..Summary::default() };
    }
    Summary {
        n: s.len(),
        mean: mean(&s),
        std: stddev(&s),
        min: s[0],
        p50: percentile(&s, 0.5),
        p90: percentile(&s, 0.9),
        p99: percentile(&s, 0.99),
        max: s[s.len() - 1],
        nan_dropped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 0.5), 50.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert!((percentile(&xs, 0.9) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }

    #[test]
    fn summary_fields() {
        let s = summarize(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.nan_dropped, 0);
    }

    #[test]
    fn empty_is_safe() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(mean(&[]), 0.0);
    }

    // Regression: before the NaN fix, every one of these calls panicked
    // inside `sort_by(|a, b| a.partial_cmp(b).unwrap())`, taking the
    // whole bench/serving run down with it.
    #[test]
    fn median_ignores_nan_samples() {
        assert_eq!(median(&[1.0, f64::NAN, 3.0]), 2.0);
        assert_eq!(median(&[f64::NAN, 5.0]), 5.0);
    }

    #[test]
    fn mad_ignores_nan_samples() {
        let clean = mad(&[1.0, 1.1, 0.9, 1.0]);
        let dirty = mad(&[1.0, f64::NAN, 1.1, 0.9, 1.0]);
        assert_eq!(clean, dirty);
    }

    #[test]
    fn summarize_reports_dropped_nan_count() {
        let s = summarize(&[f64::NAN, 2.0, f64::NAN, 4.0]);
        assert_eq!(s.n, 2);
        assert_eq!(s.nan_dropped, 2);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 4.0);
        assert!(s.mean.is_finite() && s.std.is_finite());
    }

    #[test]
    fn all_nan_degrades_to_empty_summary() {
        let s = summarize(&[f64::NAN, f64::NAN]);
        assert_eq!(s.n, 0);
        assert_eq!(s.nan_dropped, 2);
        assert_eq!(s.p50, 0.0);
        assert_eq!(mad(&[f64::NAN]), 0.0);
        assert_eq!(median(&[f64::NAN]), 0.0);
    }

    #[test]
    fn infinities_are_ordered_not_dropped() {
        // total_cmp orders ±inf correctly; only NaN is dropped.
        let s = summarize(&[f64::INFINITY, 1.0, f64::NEG_INFINITY]);
        assert_eq!(s.n, 3);
        assert_eq!(s.nan_dropped, 0);
        assert_eq!(s.min, f64::NEG_INFINITY);
        assert_eq!(s.max, f64::INFINITY);
        assert_eq!(s.p50, 1.0);
    }
}
