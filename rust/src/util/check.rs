//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `property(cases, |rng| { ... })` runs a closure over `cases` seeded RNG
//! draws. On failure the seed is reported so the case can be replayed with
//! `property_seeded`. Generators live on `Gen`, a thin wrapper over Pcg32.

use super::prng::Pcg32;

pub struct Gen {
    pub rng: Pcg32,
}

impl Gen {
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u32) as usize
    }

    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        lo + self.rng.below((hi - lo + 1) as u32) as i32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal() * scale).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u32) as usize]
    }
}

/// Run `f` over `cases` random cases; panic with the failing seed on error.
pub fn property<F: FnMut(&mut Gen) -> Result<(), String>>(cases: u64, mut f: F) {
    for case in 0..cases {
        let seed = 0xC0FF_EE00 + case;
        let mut g = Gen { rng: Pcg32::seeded(seed) };
        if let Err(msg) = f(&mut g) {
            panic!("property failed (replay with seed {seed}): {msg}");
        }
    }
}

/// Replay a single failing case.
pub fn property_seeded<F: FnMut(&mut Gen) -> Result<(), String>>(seed: u64, mut f: F) {
    let mut g = Gen { rng: Pcg32::seeded(seed) };
    if let Err(msg) = f(&mut g) {
        panic!("property failed (seed {seed}): {msg}");
    }
}

/// Assertion helpers returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        property(50, |g| {
            let x = g.f32_in(-10.0, 10.0);
            prop_assert!(x.abs() <= 10.0, "out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        property(50, |g| {
            let x = g.usize_in(0, 100);
            prop_assert!(x < 90, "x = {x}");
            Ok(())
        });
    }

    #[test]
    fn gen_ranges_inclusive() {
        property(100, |g| {
            let v = g.usize_in(3, 5);
            prop_assert!((3..=5).contains(&v), "v = {v}");
            let w = g.i32_in(-2, 2);
            prop_assert!((-2..=2).contains(&w), "w = {w}");
            Ok(())
        });
    }
}
