//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `binary <subcommand> [positional...] [--flag] [--key value]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // --key value  or  --flag
                match iter.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let val = iter.next().unwrap();
                        out.options.insert(name.to_string(), val);
                    }
                    _ => out.flags.push(name.to_string()),
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // Convention: boolean flags go last or before another `--option`
        // (a bare token after `--name` is taken as its value).
        let a = parse("train --dataset har --filters 16 out.bin --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.opt("dataset"), Some("har"));
        assert_eq!(a.opt_usize("filters", 0), 16);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.bin"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --quick");
        assert!(a.flag("quick"));
        assert!(a.opt("quick").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.opt_or("x", "d"), "d");
        assert_eq!(a.opt_f64("lr", 0.05), 0.05);
    }

    #[test]
    fn empty() {
        let a = parse("");
        assert!(a.subcommand.is_none());
    }
}
