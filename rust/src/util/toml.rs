//! Minimal TOML parser for MicroAI experiment configuration files (§5.3).
//!
//! Supports the subset the paper's configuration format needs: top-level
//! key/value pairs, `[table]`, `[[array-of-tables]]` (the paper's
//! `[[model]]` blocks), strings, integers, floats, booleans, and flat
//! arrays. Dotted keys and inline tables are out of scope (the experiment
//! schema does not use them); unknown syntax is reported with a line number.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Some(a),
            _ => None,
        }
    }
}

pub type TomlTable = BTreeMap<String, TomlValue>;

/// A parsed TOML document: top-level keys, named tables, arrays of tables.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub root: TomlTable,
    pub tables: BTreeMap<String, TomlTable>,
    pub table_arrays: BTreeMap<String, Vec<TomlTable>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        // Where key/value pairs currently land.
        enum Target {
            Root,
            Table(String),
            ArrayElem(String),
        }
        let mut target = Target::Root;

        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                let name = name.trim().to_string();
                doc.table_arrays.entry(name.clone()).or_default().push(TomlTable::new());
                target = Target::ArrayElem(name);
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim().to_string();
                doc.tables.entry(name.clone()).or_default();
                target = Target::Table(name);
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim().trim_matches('"').to_string();
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let table = match &target {
                Target::Root => &mut doc.root,
                Target::Table(name) => doc.tables.get_mut(name).unwrap(),
                Target::ArrayElem(name) => {
                    doc.table_arrays.get_mut(name).unwrap().last_mut().unwrap()
                }
            };
            table.insert(key, val);
        }
        Ok(doc)
    }

    pub fn table(&self, name: &str) -> Option<&TomlTable> {
        self.tables.get(name)
    }

    pub fn array(&self, name: &str) -> &[TomlTable] {
        self.table_arrays.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.find('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let mut items = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

/// Split on commas not nested inside brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# MicroAI experiment (paper Appendix C style)
iterations = 15
dataset = "uci-har"
seed = 42

[preprocessing]
normalize = "z-score"

[model_template]
epochs = 300
batch_size = 64
lr = 0.05
lr_steps = [100, 200, 250]

[[model]]
name = "float32"
quantize = false

[[model]]
name = "int8"
quantize = true
bits = 8

[target]
boards = ["nucleo-l452re-p", "sparkfun-edge"]
"#;

    #[test]
    fn parses_experiment_config() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.root["iterations"].as_i64(), Some(15));
        assert_eq!(doc.root["dataset"].as_str(), Some("uci-har"));
        assert_eq!(
            doc.table("model_template").unwrap()["lr"].as_f64(),
            Some(0.05)
        );
        let steps = doc.table("model_template").unwrap()["lr_steps"].as_arr().unwrap();
        assert_eq!(steps.len(), 3);
        assert_eq!(steps[2].as_i64(), Some(250));
        let models = doc.array("model");
        assert_eq!(models.len(), 2);
        assert_eq!(models[0]["name"].as_str(), Some("float32"));
        assert_eq!(models[1]["bits"].as_i64(), Some(8));
        let boards = doc.table("target").unwrap()["boards"].as_arr().unwrap();
        assert_eq!(boards[1].as_str(), Some("sparkfun-edge"));
    }

    #[test]
    fn comments_and_blank_lines_are_ignored() {
        let doc = TomlDoc::parse("a = 1 # c\n\n# whole line\nb = \"x # y\"\n").unwrap();
        assert_eq!(doc.root["a"].as_i64(), Some(1));
        assert_eq!(doc.root["b"].as_str(), Some("x # y"));
    }

    #[test]
    fn rejects_missing_equals() {
        assert!(TomlDoc::parse("justakey\n").is_err());
    }

    #[test]
    fn nested_arrays() {
        let doc = TomlDoc::parse("a = [[1, 2], [3]]\n").unwrap();
        let a = doc.root["a"].as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_i64(), Some(3));
    }

    #[test]
    fn int_vs_float() {
        let doc = TomlDoc::parse("i = 5\nf = 5.0\ne = 1e-3\n").unwrap();
        assert!(matches!(doc.root["i"], TomlValue::Int(5)));
        assert!(matches!(doc.root["f"], TomlValue::Float(_)));
        assert_eq!(doc.root["e"].as_f64(), Some(1e-3));
    }
}
