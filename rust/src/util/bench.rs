//! Criterion-free micro-benchmark harness (criterion is unavailable
//! offline). Warmup + timed iterations, robust statistics (median/MAD),
//! and a compact report format shared by all `cargo bench` targets.

use std::time::{Duration, Instant};

use super::stats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub mean_ns: f64,
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let unit = |ns: f64| {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} µs", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        };
        let mut line = format!(
            "{:<44} {:>12} ± {:<10} ({} iters)",
            self.name,
            unit(self.median_ns),
            unit(self.mad_ns),
            self.iters
        );
        if let Some((v, u)) = self.throughput {
            line.push_str(&format!("  [{v:.2} {u}]"));
        }
        line
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            max_iters: 2_000,
        }
    }

    /// Time `f` repeatedly; returns robust per-iteration statistics.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0usize;
        while start.elapsed() < self.warmup && warm_iters < self.max_iters {
            f();
            warm_iters += 1;
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples_ns.len() < self.max_iters {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        if samples_ns.is_empty() {
            // One mandatory sample for very slow bodies.
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        BenchResult {
            name: name.to_string(),
            iters: samples_ns.len(),
            median_ns: stats::median(&samples_ns),
            mad_ns: stats::mad(&samples_ns),
            mean_ns: stats::mean(&samples_ns),
            throughput: None,
        }
    }

    /// Like `run`, attaching an ops/sec-style throughput annotation:
    /// `ops_per_iter` units of `unit` happen per call.
    pub fn run_throughput<F: FnMut()>(
        &self,
        name: &str,
        ops_per_iter: f64,
        unit: &'static str,
        f: F,
    ) -> BenchResult {
        let mut r = self.run(name, f);
        if r.median_ns > 0.0 {
            r.throughput = Some((ops_per_iter / (r.median_ns / 1e9), unit));
        }
        r
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept behind one name so benches read uniformly).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

pub fn print_header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let r = b.run("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 0);
        assert!(r.median_ns >= 0.0);
    }

    #[test]
    fn throughput_annotation() {
        let b = Bencher::quick();
        let r = b.run_throughput("tp", 1000.0, "ops/s", || {
            black_box((0..500).sum::<u64>());
        });
        assert!(r.throughput.is_some());
        assert!(r.report().contains("ops/s"));
    }
}
