//! Deterministic PRNGs (no `rand` crate in this offline environment).
//!
//! `SplitMix64` for seeding, `Pcg32` as the workhorse generator used by the
//! synthetic dataset generators, the property-test harness and the serving
//! simulator. Both are tiny, well-studied generators with reproducible
//! streams across platforms.

/// SplitMix64 — used to expand a single u64 seed into stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32) — main generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed from a single u64 via SplitMix64.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = sm.next_u64();
        let inc = sm.next_u64();
        Self::new(s, inc)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased for n > 0).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (used by the serving arrival process).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = (self.next_u32() as f64 + 1.0) / (u32::MAX as f64 + 2.0);
        -u.ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg32_is_deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg32_streams_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_are_sane() {
        let mut r = Pcg32::seeded(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg32::seeded(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
