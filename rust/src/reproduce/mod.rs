//! Regeneration harnesses — one entry per paper figure/table (DESIGN.md §5).
//!
//! Timing/footprint/energy tables (Figs 11–13, Tables A2–A6) are printed by
//! `cargo bench`; the accuracy figures (Figs 5–10, A1) require training and
//! live here, invoked via `microai reproduce <fig> [--steps N] [--out DIR]`.
//! Each harness prints the paper-style series and writes a CSV.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::deployer;
use crate::coordinator::trainer::{LrSchedule, Trainer};
use crate::datasets;
use crate::mcu::board::SPARKFUN_EDGE;
use crate::nn::session::SessionBuilder;
use crate::quant::QuantSpec;
use crate::runtime::Runtime;

pub struct RepConfig {
    pub steps: usize,
    pub qat_steps: usize,
    pub seed: u64,
    pub out_dir: String,
    pub calib: usize,
}

impl Default for RepConfig {
    fn default() -> Self {
        Self { steps: 200, qat_steps: 50, seed: 42, out_dir: "results".into(), calib: 64 }
    }
}

fn write_csv(dir: &str, name: &str, header: &str, rows: &[String]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = Path::new(dir).join(name);
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    println!("  wrote {}", path.display());
    Ok(())
}

/// Train a float model for (dataset, filters); return (trainer graph, data,
/// trained state) for downstream quantization arms.
struct Trained {
    graph: crate::graph::Graph,
    qat_graph: crate::graph::Graph,
    data: datasets::RawDataModel,
}

fn train_arms(rt: &Runtime, dataset: &str, filters: usize, cfg: &RepConfig) -> Result<Trained> {
    let tag = format!("{dataset}_f{filters}");
    let spec = rt.spec(&tag)?.clone();
    let data = datasets::load(dataset, cfg.seed).context("dataset")?;
    let mut trainer = Trainer::new(rt, cfg.seed ^ filters as u64);
    let mut state = trainer.init(&tag)?;
    // GTSRB (43 classes, 2D) needs a longer budget to clear the ln(C)
    // plateau — the paper trains it for 120 epochs on a training set 5x
    // larger than UCI-HAR's.
    let steps = if dataset == "gtsrb" { cfg.steps * 2 } else { cfg.steps };
    let sched = LrSchedule {
        initial: 0.05,
        factor: 0.13,
        milestones: vec![steps * 5 / 8, steps * 3 / 4, steps * 7 / 8], warmup: 10 };
    trainer.train(&mut state, &data, "train", steps, &sched, 0)?;
    let params = trainer.params_to_host(&state)?;
    let graph = deployer::build_deployed_graph(&spec, params);

    // QAT fine-tune (int8, §4.3) from the float weights.
    let mut qat_state = crate::coordinator::trainer::TrainState {
        tag: state.tag.clone(),
        params: state.params.clone(),
        mom: state.mom.clone(),
        losses: Vec::new(),
    };
    let qat_sched = LrSchedule { initial: 0.01, factor: 0.1, milestones: vec![cfg.qat_steps / 2], warmup: 10 };
    trainer.train(&mut qat_state, &data, "qat8_train", cfg.qat_steps, &qat_sched, 0)?;
    let qat_params = trainer.params_to_host(&qat_state)?;
    let qat_graph = deployer::build_deployed_graph(&spec, qat_params);
    Ok(Trained { graph, qat_graph, data })
}

/// Figs 5/6 (UCI-HAR), 7/8 (SMNIST), 9/10 (GTSRB): accuracy vs filters and
/// vs parameter memory for float32 / int16 PTQ / int8 QAT.
pub fn accuracy_figs(rt: &Runtime, dataset: &str, cfg: &RepConfig) -> Result<()> {
    let filters: Vec<usize> = rt
        .manifest
        .models
        .values()
        .filter(|m| m.dataset == dataset)
        .map(|m| m.filters)
        .collect();
    let mut filters = filters;
    filters.sort_unstable();
    anyhow::ensure!(!filters.is_empty(), "no artifacts for {dataset}");
    println!("== {dataset}: accuracy vs filters (float32 / int16 PTQ / int8 QAT) ==");
    println!("{:>7} {:>9} {:>10} {:>10} {:>10} {:>12} {:>12} {:>9} {:>9}",
        "filters", "params", "float32", "int16", "int8-QAT", "mem16(B)", "mem8(B)",
        "ms16", "ms8");
    let mut rows = Vec::new();
    for &f in &filters {
        let t = train_arms(rt, dataset, f, cfg)?;
        let acc_f = deployer::float_accuracy(&t.graph, &t.data);
        let (q16, acc16) =
            deployer::ptq_accuracy(&t.graph, &t.data, QuantSpec::int16_per_layer(), cfg.calib);
        let (q8, acc8) =
            deployer::ptq_accuracy(&t.qat_graph, &t.data, QuantSpec::int8_per_layer(), cfg.calib);
        // Device cost from session metadata (mcu::cost on the SparkFun
        // Edge, the paper's most efficient board).
        let s16 = SessionBuilder::fixed_qmn(q16.clone()).board(&SPARKFUN_EDGE).build();
        let s8 = SessionBuilder::fixed_qmn(q8.clone()).board(&SPARKFUN_EDGE).build();
        let ms16 = s16.meta().device_latency_ms.unwrap_or(0.0);
        let ms8 = s8.meta().device_latency_ms.unwrap_or(0.0);
        let params = t.graph.param_count();
        println!(
            "{f:>7} {params:>9} {acc_f:>10.4} {acc16:>10.4} {acc8:>10.4} {:>12} {:>12} \
             {ms16:>9.1} {ms8:>9.1}",
            q16.weight_bytes(),
            q8.weight_bytes()
        );
        rows.push(format!(
            "{f},{params},{acc_f:.4},{acc16:.4},{acc8:.4},{},{},{ms16:.2},{ms8:.2}",
            q16.weight_bytes(),
            q8.weight_bytes()
        ));
    }
    write_csv(
        &cfg.out_dir,
        &format!("fig_accuracy_{dataset}.csv"),
        "filters,params,float32,int16_ptq,int8_qat,mem_int16_bytes,mem_int8_bytes,ms16_sfe,ms8_sfe",
        &rows,
    )?;
    println!(
        "(paper shape: int16 tracks float32 everywhere; int8 QAT drops up to ~1%)\n"
    );
    Ok(())
}

/// Fig A1 (Appendix B): int8 affine PTQ (TFLite scheme) vs int8 MicroAI QAT
/// vs int9 MicroAI PTQ vs float32 baseline, on UCI-HAR.
pub fn fig_a1(rt: &Runtime, cfg: &RepConfig) -> Result<()> {
    let dataset = "har";
    let filters: Vec<usize> = rt
        .manifest
        .models
        .values()
        .filter(|m| m.dataset == dataset && m.filters >= 16)
        .map(|m| m.filters)
        .collect();
    let mut filters = filters;
    filters.sort_unstable();
    println!("== Fig A1: quantization scheme comparison (UCI-HAR) ==");
    println!("{:>7} {:>10} {:>14} {:>14} {:>14}",
        "filters", "float32", "int8-TFLitePTQ", "int8-MicroAIQAT", "int9-MicroAIPTQ");
    let mut rows = Vec::new();
    for &f in &filters {
        let t = train_arms(rt, dataset, f, cfg)?;
        let acc_f = deployer::float_accuracy(&t.graph, &t.data);
        let acc_affine = deployer::affine_accuracy(&t.graph, &t.data, cfg.calib);
        let (_q8, acc_qat) =
            deployer::ptq_accuracy(&t.qat_graph, &t.data, QuantSpec::int8_per_layer(), cfg.calib);
        let (_q9, acc9) =
            deployer::ptq_accuracy(&t.graph, &t.data, QuantSpec::int9_per_layer(), cfg.calib);
        println!("{f:>7} {acc_f:>10.4} {acc_affine:>14.4} {acc_qat:>14.4} {acc9:>14.4}");
        rows.push(format!("{f},{acc_f:.4},{acc_affine:.4},{acc_qat:.4},{acc9:.4}"));
    }
    write_csv(
        &cfg.out_dir,
        "fig_a1_schemes.csv",
        "filters,float32,int8_tflite_ptq,int8_microai_qat,int9_microai_ptq",
        &rows,
    )?;
    println!("(paper shape: int9 PTQ ≥ TFLite int8 PTQ ≥ MicroAI int8 QAT)\n");
    Ok(())
}

/// Fig 1: distribution of a trained conv kernel's weights (printed as an
/// ASCII histogram + CSV of bin counts).
pub fn fig1(rt: &Runtime, cfg: &RepConfig) -> Result<()> {
    let t = train_arms(rt, "har", 16, cfg)?;
    let conv = t
        .graph
        .nodes
        .iter()
        .find(|n| n.name == "b1conv1")
        .context("conv node")?;
    let w = match &conv.kind {
        crate::graph::LayerKind::Conv { w, .. } => &w.data,
        _ => unreachable!(),
    };
    let max_abs = w.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-6);
    let bins = 41usize;
    let mut hist = vec![0usize; bins];
    for &x in w {
        let b = (((x / max_abs) + 1.0) / 2.0 * (bins - 1) as f32).round() as usize;
        hist[b.min(bins - 1)] += 1;
    }
    println!("== Fig 1: conv kernel weight distribution (trained, b1conv1) ==");
    let peak = *hist.iter().max().unwrap() as f32;
    let mut rows = Vec::new();
    for (i, &h) in hist.iter().enumerate() {
        let x = -max_abs + 2.0 * max_abs * i as f32 / (bins - 1) as f32;
        let bar = "#".repeat(((h as f32 / peak) * 50.0) as usize);
        println!("{x:>8.3} | {bar}");
        rows.push(format!("{x:.5},{h}"));
    }
    write_csv(&cfg.out_dir, "fig1_weight_hist.csv", "weight,count", &rows)?;
    println!("(paper: approximately Gaussian, centered near 0)\n");
    Ok(())
}

/// Dispatch by figure name. "all" runs everything.
pub fn run(rt: &Runtime, what: &str, cfg: &RepConfig) -> Result<()> {
    match what {
        "fig1" => fig1(rt, cfg),
        "fig5" | "fig6" | "har" => accuracy_figs(rt, "har", cfg),
        "fig7" | "fig8" | "smnist" => accuracy_figs(rt, "smnist", cfg),
        "fig9" | "fig10" | "gtsrb" => accuracy_figs(rt, "gtsrb", cfg),
        "figa1" => fig_a1(rt, cfg),
        "all" => {
            fig1(rt, cfg)?;
            accuracy_figs(rt, "har", cfg)?;
            accuracy_figs(rt, "smnist", cfg)?;
            accuracy_figs(rt, "gtsrb", cfg)?;
            fig_a1(rt, cfg)
        }
        other => anyhow::bail!(
            "unknown target {other:?} (fig1|fig5|fig7|fig9|figa1|all; \
             tables A2-A6 + figs 11-13 come from `cargo bench`)"
        ),
    }
}
