//! MicroAI command-line interface — the Appendix C commands plus the
//! reproduction harnesses:
//!
//!   microai experiment <config.toml> [--quiet]    full Fig-3 flow
//!   microai train --dataset har --filters 16 --steps 200
//!   microai deploy --dataset har --filters 16     engines x boards matrix
//!   microai codegen --dataset har --filters 16 --width 8 --out dir/
//!   microai reproduce <fig1|fig5|fig7|fig9|figa1|all> [--steps N]
//!   microai serve-demo [--requests N]             big/LITTLE cascade
//!   microai summary                               graph/topology dump

use anyhow::{Context, Result};

use microai::coordinator::trainer::{LrSchedule, Trainer};
use microai::coordinator::{deployer, flow, serving};
use microai::datasets;
use microai::engines::all_engines;
use microai::mcu::board::{BOARDS, SPARKFUN_EDGE};
use microai::quant::QuantSpec;
use microai::reproduce;
use microai::runtime::Runtime;
use microai::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("experiment") => cmd_experiment(args),
        Some("train") => cmd_train(args),
        Some("deploy") => cmd_deploy(args),
        Some("codegen") => cmd_codegen(args),
        Some("reproduce") => cmd_reproduce(args),
        Some("serve-demo") => cmd_serve(args),
        Some("summary") => cmd_summary(args),
        _ => {
            println!(
                "MicroAI — quantization and deployment of DNNs on microcontrollers\n\
                 (Rust+JAX+Pallas reproduction of Novac et al., Sensors 2021)\n\n\
                 subcommands: experiment train deploy codegen reproduce serve-demo summary\n\
                 run `make artifacts` first to build the HLO artifacts."
            );
            Ok(())
        }
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let path = args.positional.first().context("usage: microai experiment <config.toml>")?;
    let text = std::fs::read_to_string(path)?;
    let cfg = flow::ExperimentCfg::parse(&text)?;
    let rt = Runtime::open_default()?;
    let res = flow::run(&rt, &cfg, !args.flag("quiet"))?;
    println!("\n== experiment results ({} f={}) ==", cfg.dataset, cfg.filters);
    println!(
        "{:<14} {:<14} {:>9} {:>12} {:>14}",
        "model", "mode", "accuracy", "weights(B)", "pred ms (SFE)"
    );
    for r in &res.results {
        let ms = r.device_ms.map_or("-".into(), |v| format!("{v:.1}"));
        println!(
            "{:<14} {:<14} {:>9.4} {:>12} {:>14}",
            r.name, r.mode, r.accuracy, r.weight_bytes, ms
        );
    }
    if !res.deployment.is_empty() {
        println!("\n== deployment matrix ==\n{}", res.deployment);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let dataset = args.opt_or("dataset", "har").to_string();
    let filters = args.opt_usize("filters", 16);
    let steps = args.opt_usize("steps", 200);
    let seed = args.opt_usize("seed", 42) as u64;
    let tag = format!("{dataset}_f{filters}");
    let rt = Runtime::open_default()?;
    let data = datasets::load(&dataset, seed).context("unknown dataset")?;
    let mut trainer = Trainer::new(&rt, seed);
    let mut state = trainer.init(&tag)?;
    let sched = LrSchedule {
        initial: args.opt_f64("lr", 0.05) as f32,
        factor: 0.13,
        milestones: vec![steps * 5 / 8, steps * 3 / 4, steps * 7 / 8], warmup: 10 };
    println!("training {tag} for {steps} steps on synthetic {dataset}...");
    trainer.train(&mut state, &data, "train", steps, &sched, (steps / 10).max(1))?;
    let acc = trainer.eval_accuracy(&state, &data, "fwd")?;
    println!("float32 test accuracy (fwd artifact): {acc:.4}");
    Ok(())
}

fn cmd_deploy(args: &Args) -> Result<()> {
    let dataset = args.opt_or("dataset", "har").to_string();
    let filters = args.opt_usize("filters", 16);
    let (dims, shape, classes): (usize, Vec<usize>, usize) = match dataset.as_str() {
        "har" => (1, vec![128, 9], 6),
        "smnist" => (1, vec![39, 13], 10),
        "gtsrb" => (2, vec![32, 32, 3], 43),
        d => anyhow::bail!("unknown dataset {d}"),
    };
    let g = microai::graph::deploy_pipeline(&microai::graph::resnet_v1_6_shapes(
        &dataset, dims, &shape, classes, filters,
    ));
    let rows = deployer::deployment_matrix(&g, filters, &all_engines(), &BOARDS);
    println!("{}", deployer::render_matrix(&rows));
    let alloc = microai::allocator::allocate(&g);
    println!(
        "allocator: {} pools, {} elements total",
        alloc.n_pools(),
        alloc.pool_elems.iter().sum::<usize>()
    );
    println!("host gemm kernels: {}", microai::nn::simd::detected().name);
    Ok(())
}

fn cmd_codegen(args: &Args) -> Result<()> {
    let dataset = args.opt_or("dataset", "har").to_string();
    let filters = args.opt_usize("filters", 16);
    let width = args.opt_usize("width", 8) as u32;
    let steps = args.opt_usize("steps", 120);
    let out = args.opt_or("out", "results/generated_c").to_string();
    let tag = format!("{dataset}_f{filters}");
    let rt = Runtime::open_default()?;
    let spec = rt.spec(&tag)?.clone();
    anyhow::ensure!(spec.dims == 1, "C generation targets 1-D models (paper §5.6)");
    let data = datasets::load(&dataset, 42).context("dataset")?;
    let mut trainer = Trainer::new(&rt, 42);
    let mut state = trainer.init(&tag)?;
    let sched = LrSchedule { initial: 0.05, factor: 0.13, milestones: vec![steps / 2], warmup: 10 };
    println!("training {tag} ({steps} steps) before codegen...");
    trainer.train(&mut state, &data, "train", steps, &sched, 0)?;
    let params = trainer.params_to_host(&state)?;
    let graph = deployer::build_deployed_graph(&spec, params);
    let stats = deployer::calibrate(&graph, &data, 64);
    let qspec = if width == 16 { QuantSpec::int16_per_layer() } else { QuantSpec::int8_per_layer() };
    let qg = microai::quant::quantize(&graph, &stats, qspec);
    let lib = microai::codegen::generate(&qg);
    let paths = microai::codegen::write_to(&lib, std::path::Path::new(&out))?;
    for p in &paths {
        println!("wrote {}", p.display());
    }
    println!("INPUT_SCALE_FACTOR = {}", qg.input_n());
    Ok(())
}

fn cmd_reproduce(args: &Args) -> Result<()> {
    let what = args.positional.first().map(String::as_str).unwrap_or("all");
    let cfg = reproduce::RepConfig {
        steps: args.opt_usize("steps", 200),
        qat_steps: args.opt_usize("qat-steps", 50),
        seed: args.opt_usize("seed", 42) as u64,
        out_dir: args.opt_or("out", "results").to_string(),
        calib: args.opt_usize("calib", 64),
    };
    let rt = Runtime::open_default()?;
    reproduce::run(&rt, what, &cfg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n = args.opt_usize("requests", 200);
    let threshold = args.opt_f64("threshold", 0.8) as f32;
    let steps = args.opt_usize("steps", 150);
    let rt = Runtime::open_default()?;
    let data = datasets::load("har", 42).unwrap();

    println!("training little (f=8) and big (f=32) models...");
    let mut graphs = Vec::new();
    for f in [8usize, 32] {
        let tag = format!("har_f{f}");
        let spec = rt.spec(&tag)?.clone();
        let mut trainer = Trainer::new(&rt, 42 + f as u64);
        let mut state = trainer.init(&tag)?;
        let sched = LrSchedule { initial: 0.05, factor: 0.13, milestones: vec![steps / 2], warmup: 10 };
        trainer.train(&mut state, &data, "train", steps, &sched, 0)?;
        let params = trainer.params_to_host(&state)?;
        let g = deployer::build_deployed_graph(&spec, params);
        let stats = deployer::calibrate(&g, &data, 64);
        graphs.push(std::sync::Arc::new(microai::quant::quantize(
            &g, &stats, QuantSpec::int8_per_layer())));
    }
    let big = graphs.pop().unwrap();
    let little = graphs.pop().unwrap();

    // Session metadata prices the two models on the target board.
    let little_sess = microai::nn::SessionBuilder::fixed_qmn(little.clone())
        .board(&SPARKFUN_EDGE)
        .build();
    let big_sess = microai::nn::SessionBuilder::fixed_qmn(big.clone())
        .board(&SPARKFUN_EDGE)
        .build();
    let little_ms = little_sess.meta().device_latency_ms.unwrap_or(0.0);
    let big_ms = big_sess.meta().device_latency_ms.unwrap_or(0.0);
    let (reqs, labels) = serving::request_stream(&data, n, 7);
    // Open-loop arrivals at roughly the little model's service rate so the
    // queueing report is non-trivial.
    let rate = if little_ms > 0.0 { 1e3 / little_ms } else { 0.0 };
    let cfg = serving::CascadeConfig {
        threshold,
        workers: 4,
        board: &SPARKFUN_EDGE,
        arrival_rate_hz: rate,
        ..serving::CascadeConfig::default()
    };
    let stats = serving::run_cascade(little.clone(), big.clone(), &cfg, reqs.clone(), Some(&labels));
    println!("\n== big/LITTLE cascade on simulated SparkFun Edge ==");
    println!(
        "little={little_ms:.1} ms  big={big_ms:.1} ms  threshold={threshold}  arrivals={rate:.1}/s  \
         kernel={}",
        little_sess.meta().kernel
    );
    println!(
        "requests={n} escalation={:.1}%  accuracy={:.4}",
        stats.escalation_rate * 100.0,
        stats.accuracy.unwrap()
    );
    let lat = stats.latency.as_ref().expect("board-priced cascade");
    println!(
        "total latency p50={:.1} ms p99={:.1} ms (queue p50={:.1} ms)  energy={:.2} µWh",
        lat.p50,
        lat.p99,
        stats.queue_latency.p50,
        stats.total_energy_uwh.unwrap()
    );
    println!(
        "queue depth p50={:.0} p99={:.0}  worker utilization={}",
        stats.queue_depth.p50,
        stats.queue_depth.p99,
        stats
            .worker_utilization
            .iter()
            .map(|u| format!("{:.0}%", u * 100.0))
            .collect::<Vec<_>>()
            .join(" "),
    );
    // Comparison: big-only baseline. Arrivals stay tuned to the LITTLE
    // service rate, so the big-only queue is unstable and total latency
    // would just measure backlog length — compare device time, and show
    // the queue blow-up separately as the point of the cascade.
    let cfg_all_big = serving::CascadeConfig { threshold: 1.01, ..cfg };
    let sb = serving::run_cascade(little, big, &cfg_all_big, reqs, Some(&labels));
    println!(
        "big-only baseline: device p50={:.1} ms (queue p50={:.1} ms at the same arrivals) \
         accuracy={:.4}  energy={:.2} µWh",
        sb.device_latency.as_ref().expect("board-priced cascade").p50,
        sb.queue_latency.p50,
        sb.accuracy.unwrap(),
        sb.total_energy_uwh.unwrap()
    );
    Ok(())
}

fn cmd_summary(args: &Args) -> Result<()> {
    let dataset = args.opt_or("dataset", "har").to_string();
    let filters = args.opt_usize("filters", 16);
    let (dims, shape, classes): (usize, Vec<usize>, usize) = match dataset.as_str() {
        "har" => (1, vec![128, 9], 6),
        "smnist" => (1, vec![39, 13], 10),
        "gtsrb" => (2, vec![32, 32, 3], 43),
        d => anyhow::bail!("unknown dataset {d}"),
    };
    let g = microai::graph::resnet_v1_6_shapes(&dataset, dims, &shape, classes, filters);
    println!("{}", g.summary());
    let d = microai::graph::deploy_pipeline(&g);
    println!("after deployment passes:\n{}", d.summary());
    let ops = microai::mcu::graph_ops(&d);
    println!(
        "ops: MACC={} add={} shift={} sat/max={} div={}  ideal cycles={}",
        ops.macc, ops.add, ops.shift, ops.sat, ops.div, ops.ideal_cycles()
    );
    Ok(())
}
