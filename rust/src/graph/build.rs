//! Model builders — the architecture templates MicroAI ships (§5.4):
//! MLP, CNN, and the ResNetv1-6 of Fig 4 (the one used in every experiment).
//!
//! `resnet_v1_6` mirrors python/compile/model.py::apply EXACTLY — same
//! topology, same parameter order (model.py::PARAM_NAMES) — so that weights
//! trained through the HLO artifacts drop straight into this graph.

use crate::tensor::{Tensor, TensorF};

use super::ir::{Graph, LayerKind, Padding};

/// The 14 parameter tensors of ResNetv1-6 in the shared deployment order.
pub const RESNET_PARAM_NAMES: [&str; 14] = [
    "c1w", "c1b", "b1c1w", "b1c1b", "b1c2w", "b1c2b", "b2c1w", "b2c1b",
    "b2c2w", "b2c2b", "scw", "scb", "dw", "db",
];

fn conv(w: TensorF, b: TensorF, stride: usize) -> LayerKind {
    LayerKind::Conv { w, b, stride, padding: Padding::Same }
}

/// Build the ResNetv1-6 graph from its parameter list (model.py order).
///
/// dims=1: input (S, C); dims=2: input (H, W, C). `params` must hold the 14
/// tensors named in RESNET_PARAM_NAMES with JAX shapes ((k,C,F) / (kh,kw,C,F)
/// convs, (in,out) dense).
pub fn resnet_v1_6(
    name: &str,
    dims: usize,
    input_shape: &[usize],
    classes: usize,
    params: Vec<TensorF>,
) -> Graph {
    assert_eq!(params.len(), 14, "expected 14 parameter tensors");
    let mut it = params.into_iter();
    let mut next = || it.next().unwrap();
    let (c1w, c1b) = (next(), next());
    let (b1c1w, b1c1b) = (next(), next());
    let (b1c2w, b1c2b) = (next(), next());
    let (b2c1w, b2c1b) = (next(), next());
    let (b2c2w, b2c2b) = (next(), next());
    let (scw, scb) = (next(), next());
    let (dw, db) = (next(), next());

    let mut g = Graph::new(name, dims, input_shape, classes);
    let c1 = g.add("conv1", conv(c1w, c1b, 1), vec![0]);
    let r1 = g.add("relu1", LayerKind::ReLU, vec![c1]);
    let p1 = g.add("pool1", LayerKind::MaxPool { size: 2 }, vec![r1]);

    // Block 1: identity shortcut.
    let b1a = g.add("b1conv1", conv(b1c1w, b1c1b, 1), vec![p1]);
    let b1r = g.add("b1relu", LayerKind::ReLU, vec![b1a]);
    let b1b = g.add("b1conv2", conv(b1c2w, b1c2b, 1), vec![b1r]);
    let add1 = g.add("add1", LayerKind::Add, vec![p1, b1b]);
    let r2 = g.add("relu2", LayerKind::ReLU, vec![add1]);
    let p2 = g.add("pool2", LayerKind::MaxPool { size: 2 }, vec![r2]);

    // Block 2: stride-2, 1x1-conv shortcut.
    let b2a = g.add("b2conv1", conv(b2c1w, b2c1b, 2), vec![p2]);
    let b2r = g.add("b2relu", LayerKind::ReLU, vec![b2a]);
    let b2b = g.add("b2conv2", conv(b2c2w, b2c2b, 1), vec![b2r]);
    let sc = g.add("shortcut", conv(scw, scb, 2), vec![p2]);
    let add2 = g.add("add2", LayerKind::Add, vec![sc, b2b]);
    let r3 = g.add("relu3", LayerKind::ReLU, vec![add2]);

    let gap = g.add("gap", LayerKind::GlobalAvgPool, vec![r3]);
    let _fc = g.add("fc", LayerKind::Dense { w: dw, b: db }, vec![gap]);
    g
}

/// ResNetv1-6 with zero weights of the right shapes — used by the cost /
/// ROM models and the allocator, where only the topology matters.
pub fn resnet_v1_6_shapes(
    name: &str,
    dims: usize,
    input_shape: &[usize],
    classes: usize,
    filters: usize,
) -> Graph {
    let c = *input_shape.last().unwrap();
    let f = filters;
    let k = 3usize;
    let conv_t = |ci: usize, co: usize, kk: usize| -> TensorF {
        if dims == 1 {
            Tensor::zeros(&[kk, ci, co])
        } else {
            Tensor::zeros(&[kk, kk, ci, co])
        }
    };
    let params = vec![
        conv_t(c, f, k), Tensor::zeros(&[f]),
        conv_t(f, f, k), Tensor::zeros(&[f]),
        conv_t(f, f, k), Tensor::zeros(&[f]),
        conv_t(f, f, k), Tensor::zeros(&[f]),
        conv_t(f, f, k), Tensor::zeros(&[f]),
        conv_t(f, f, 1), Tensor::zeros(&[f]),
        Tensor::zeros(&[f, classes]), Tensor::zeros(&[classes]),
    ];
    resnet_v1_6(name, dims, input_shape, classes, params)
}

/// Simple sequential CNN template (§5.4): conv-relu-pool stacks + dense.
pub fn cnn(
    name: &str,
    dims: usize,
    input_shape: &[usize],
    classes: usize,
    conv_filters: &[usize],
    kernel: usize,
    dense_units: usize,
) -> Graph {
    let mut g = Graph::new(name, dims, input_shape, classes);
    let mut prev = 0usize;
    let mut in_ch = *input_shape.last().unwrap();
    for (i, &f) in conv_filters.iter().enumerate() {
        let w = if dims == 1 {
            Tensor::zeros(&[kernel, in_ch, f])
        } else {
            Tensor::zeros(&[kernel, kernel, in_ch, f])
        };
        let c = g.add(&format!("conv{i}"), conv(w, Tensor::zeros(&[f]), 1), vec![prev]);
        let r = g.add(&format!("relu{i}"), LayerKind::ReLU, vec![c]);
        prev = g.add(&format!("pool{i}"), LayerKind::MaxPool { size: 2 }, vec![r]);
        in_ch = f;
    }
    let fl = g.add("flatten", LayerKind::Flatten, vec![prev]);
    let fl_units: usize = g.node(fl).out_shape.iter().product();
    let d1 = g.add(
        "fc1",
        LayerKind::Dense { w: Tensor::zeros(&[fl_units, dense_units]), b: Tensor::zeros(&[dense_units]) },
        vec![fl],
    );
    let r = g.add("fcrelu", LayerKind::ReLU, vec![d1]);
    g.add(
        "fc2",
        LayerKind::Dense { w: Tensor::zeros(&[dense_units, classes]), b: Tensor::zeros(&[classes]) },
        vec![r],
    );
    g
}

/// Tiny encoder-style transformer classifier — the second model family
/// (ISSUE 6): token ids (seq, 1) → Embedding → `blocks` × [pre-LN
/// self-attention + residual, pre-LN 1×1-conv FFN + residual] →
/// GlobalAvgPool → Dense → Softmax. The softmax head is an inference-time
/// op here (probability output), so the graph opts out of
/// RemoveKerasSoftmax. Weights are zero; randomize for tests as usual.
///
/// Sized for MCU deployment: keep `d_model` ≤ 64 and `seq` ≤ 64.
pub fn transformer(
    name: &str,
    seq: usize,
    vocab: usize,
    d_model: usize,
    heads: usize,
    blocks: usize,
    ffn_mult: usize,
    classes: usize,
) -> Graph {
    assert!(d_model % heads == 0, "heads must divide d_model");
    assert!(d_model <= 64 && seq <= 64, "MCU envelope: d_model/seq <= 64");
    let head_dim = d_model / heads;
    let ffn = d_model * ffn_mult;
    let attn_w = || {
        Box::new(super::ir::AttnWeights {
            wq: Tensor::zeros(&[d_model, d_model]),
            bq: Tensor::zeros(&[d_model]),
            wk: Tensor::zeros(&[d_model, d_model]),
            bk: Tensor::zeros(&[d_model]),
            wv: Tensor::zeros(&[d_model, d_model]),
            bv: Tensor::zeros(&[d_model]),
            wo: Tensor::zeros(&[d_model, d_model]),
            bo: Tensor::zeros(&[d_model]),
        })
    };
    let ln = |c: usize| LayerKind::LayerNorm {
        gamma: vec![1.0; c],
        beta: vec![0.0; c],
        eps: 1e-5,
    };

    let mut g = Graph::new(name, 1, &[seq, 1], classes);
    g.strip_softmax = false;
    let mut prev = g.add("embed", LayerKind::Embedding { w: Tensor::zeros(&[vocab, d_model]) }, vec![0]);
    for bi in 0..blocks {
        let n1 = g.add(&format!("b{bi}ln1"), ln(d_model), vec![prev]);
        let at = g.add(
            &format!("b{bi}attn"),
            LayerKind::SelfAttention { heads, head_dim, w: attn_w() },
            vec![n1],
        );
        let a1 = g.add(&format!("b{bi}add1"), LayerKind::Add, vec![prev, at]);
        let n2 = g.add(&format!("b{bi}ln2"), ln(d_model), vec![a1]);
        // Position-wise FFN as two 1x1 convs (the GEMM core's native form).
        let up = g.add(
            &format!("b{bi}ffn1"),
            conv(Tensor::zeros(&[1, d_model, ffn]), Tensor::zeros(&[ffn]), 1),
            vec![n2],
        );
        let ur = g.add(&format!("b{bi}ffnrelu"), LayerKind::ReLU, vec![up]);
        let dn = g.add(
            &format!("b{bi}ffn2"),
            conv(Tensor::zeros(&[1, ffn, d_model]), Tensor::zeros(&[d_model]), 1),
            vec![ur],
        );
        prev = g.add(&format!("b{bi}add2"), LayerKind::Add, vec![a1, dn]);
    }
    let gap = g.add("gap", LayerKind::GlobalAvgPool, vec![prev]);
    let fc = g.add(
        "fc",
        LayerKind::Dense { w: Tensor::zeros(&[d_model, classes]), b: Tensor::zeros(&[classes]) },
        vec![gap],
    );
    g.add("probs", LayerKind::Softmax, vec![fc]);
    g
}

/// Multi-layer perceptron template (§5.4).
pub fn mlp(name: &str, input_units: usize, hidden: &[usize], classes: usize) -> Graph {
    let mut g = Graph::new(name, 1, &[input_units, 1], classes);
    let mut prev = g.add("flatten", LayerKind::Flatten, vec![0]);
    let mut in_u = input_units;
    for (i, &h) in hidden.iter().enumerate() {
        let d = g.add(
            &format!("fc{i}"),
            LayerKind::Dense { w: Tensor::zeros(&[in_u, h]), b: Tensor::zeros(&[h]) },
            vec![prev],
        );
        prev = g.add(&format!("relu{i}"), LayerKind::ReLU, vec![d]);
        in_u = h;
    }
    g.add(
        "out",
        LayerKind::Dense { w: Tensor::zeros(&[in_u, classes]), b: Tensor::zeros(&[classes]) },
        vec![prev],
    );
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet_har_16_param_count_matches_paper() {
        // §6.1.1 / Fig 6: 3958 parameters at 16 filters on UCI-HAR.
        let g = resnet_v1_6_shapes("har", 1, &[128, 9], 6, 16);
        assert_eq!(g.param_count(), 3958);
    }

    #[test]
    fn resnet_shapes_1d() {
        let g = resnet_v1_6_shapes("har", 1, &[128, 9], 6, 16);
        let out = &g.nodes[g.output_id()];
        assert_eq!(out.out_shape, vec![6]);
        // block2 output spatial: 128 -> pool 64 -> pool 32 -> stride2 16
        let add2 = g.nodes.iter().find(|n| n.name == "add2").unwrap();
        assert_eq!(add2.out_shape, vec![16, 16]);
    }

    #[test]
    fn resnet_shapes_2d() {
        let g = resnet_v1_6_shapes("gtsrb", 2, &[32, 32, 3], 43, 8);
        let add2 = g.nodes.iter().find(|n| n.name == "add2").unwrap();
        assert_eq!(add2.out_shape, vec![4, 4, 8]);
        assert_eq!(g.nodes[g.output_id()].out_shape, vec![43]);
    }

    #[test]
    fn resnet_smnist_odd_sizes() {
        let g = resnet_v1_6_shapes("smnist", 1, &[39, 13], 10, 8);
        // SAME-window pooling: 39 -> pool 20 -> pool 10 -> stride2 SAME 5
        // (the remainder sample is kept, not dropped).
        let add2 = g.nodes.iter().find(|n| n.name == "add2").unwrap();
        assert_eq!(add2.out_shape, vec![5, 8]);
        let p1 = g.nodes.iter().find(|n| n.name == "pool1").unwrap();
        assert_eq!(p1.out_shape, vec![20, 8]);
    }

    #[test]
    fn cnn_and_mlp_build() {
        let g = cnn("c", 1, &[64, 4], 5, &[8, 16], 3, 32);
        assert_eq!(g.nodes[g.output_id()].out_shape, vec![5]);
        let m = mlp("m", 100, &[32, 16], 4);
        assert_eq!(m.nodes[m.output_id()].out_shape, vec![4]);
        assert_eq!(m.param_count(), 100 * 32 + 32 + 32 * 16 + 16 + 16 * 4 + 4);
    }

    #[test]
    fn transformer_shapes_and_params() {
        let g = transformer("tx", 16, 32, 24, 3, 2, 2, 5);
        // Output is the kept softmax head over the classes.
        let out = &g.nodes[g.output_id()];
        assert!(matches!(out.kind, LayerKind::Softmax));
        assert_eq!(out.out_shape, vec![5]);
        assert!(!g.strip_softmax);
        // Embedding output and every block output carry (seq, d_model).
        let emb = g.nodes.iter().find(|n| n.name == "embed").unwrap();
        assert_eq!(emb.out_shape, vec![16, 24]);
        let a2 = g.nodes.iter().find(|n| n.name == "b1add2").unwrap();
        assert_eq!(a2.out_shape, vec![16, 24]);
        // Params: table + per block (2 LN + 4 attn proj + FFN pair) + head.
        let block = 2 * 2 * 24 + 4 * (24 * 24 + 24) + (24 * 48 + 48) + (48 * 24 + 24);
        assert_eq!(g.param_count(), 32 * 24 + 2 * block + 24 * 5 + 5);
    }

    #[test]
    fn paper_filter_sweep_param_counts_monotone() {
        let mut last = 0usize;
        for f in [16, 24, 32, 40, 48, 64, 80] {
            let g = resnet_v1_6_shapes("har", 1, &[128, 9], 6, f);
            assert!(g.param_count() > last);
            last = g.param_count();
        }
        // 80 filters: conv1 2240 + 4*19280 + shortcut 6480 + fc 486
        let g = resnet_v1_6_shapes("har", 1, &[128, 9], 6, 80);
        assert_eq!(g.param_count(), 2240 + 4 * 19280 + 6480 + 486);
    }
}
