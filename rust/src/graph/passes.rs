//! Deployment graph transformations (§5.7):
//!
//! 1. Combine ZeroPad layers with the following Conv.
//! 2. Fuse ReLU activation layers into the previous Conv / MaxPool / Dense
//!    / Add layer.
//! 3. Convert BatchNorm weights to a (w, b) affine pair (Eqs 5–7) and fold
//!    them into the preceding convolution (the paper notes folding "is not
//!    implemented yet" — we implement it, as the flag-gated extension).
//! 4. Remove the trailing SoftMax (§5.4 RemoveKerasSoftmax).
//!
//! Every pass preserves float semantics; `nn::float_exec` equality is the
//! property test (`tests/graph_passes.rs` + unit tests here).


use super::ir::{Graph, LayerKind, Padding};

/// Run the standard deployment pipeline.
pub fn deploy_pipeline(g: &Graph) -> Graph {
    let g = remove_softmax(g);
    let g = fuse_zeropad_conv(&g);
    let g = fold_batchnorm(&g);
    fuse_relu(&g)
}

/// Rebuild the graph skipping nodes for which `replace` maps their id to a
/// source id (consumers are rewired to the replacement).
fn rebuild(g: &Graph, replace: &[Option<usize>], edits: &[Option<LayerKind>]) -> Graph {
    let mut out = Graph::new(&g.name, g.dims, &g.input_shape, g.classes);
    out.strip_softmax = g.strip_softmax;
    out.nodes.clear();
    // old id -> new id (following replacement chains first).
    let mut newid: Vec<usize> = vec![usize::MAX; g.nodes.len()];
    let resolve = |id: usize| -> usize {
        let mut cur = id;
        while let Some(src) = replace[cur] {
            cur = src;
        }
        cur
    };
    for n in &g.nodes {
        if replace[n.id].is_some() {
            continue;
        }
        let kind = edits[n.id].clone().unwrap_or_else(|| n.kind.clone());
        let inputs: Vec<usize> = n.inputs.iter().map(|&i| newid[resolve(i)]).collect();
        let id = out.nodes.len();
        let out_shape = if matches!(kind, LayerKind::Input) {
            g.input_shape.clone()
        } else {
            // Recompute to keep inference consistent after edits.
            let tmp_inputs = inputs.clone();
            infer_with(&out, &kind, &tmp_inputs)
        };
        out.nodes.push(super::ir::Node {
            id,
            name: n.name.clone(),
            kind,
            inputs,
            out_shape,
            fused_relu: n.fused_relu,
        });
        newid[n.id] = id;
    }
    out
}

fn infer_with(g: &Graph, kind: &LayerKind, inputs: &[usize]) -> Vec<usize> {
    // Reuse Graph::infer_shape through a temporary add/pop.
    let mut tmp = g.clone();
    let id = tmp.add("__tmp", kind.clone(), inputs.to_vec());
    tmp.nodes[id].out_shape.clone()
}

/// Pass 4: drop a trailing SoftMax node. Opt-in per graph: a graph with
/// `strip_softmax == false` (the transformer family, whose softmax is an
/// inference-time op) passes through untouched.
pub fn remove_softmax(g: &Graph) -> Graph {
    let mut replace: Vec<Option<usize>> = vec![None; g.nodes.len()];
    let edits: Vec<Option<LayerKind>> = vec![None; g.nodes.len()];
    let out_id = g.output_id();
    if g.strip_softmax {
        if let LayerKind::Softmax = g.nodes[out_id].kind {
            replace[out_id] = Some(g.nodes[out_id].inputs[0]);
        }
    }
    rebuild(g, &replace, &edits)
}

/// Pass 1: ZeroPad followed by a VALID/SAME Conv becomes a Conv with
/// explicit padding folded in. We keep the IR simple by converting the conv
/// to `Padding::Valid` and materializing the pad into a retained ZeroPad
/// only when it cannot be represented; the common Keras pattern
/// (ZeroPad -> VALID conv) folds completely.
pub fn fuse_zeropad_conv(g: &Graph) -> Graph {
    let mut replace: Vec<Option<usize>> = vec![None; g.nodes.len()];
    let mut edits: Vec<Option<LayerKind>> = vec![None; g.nodes.len()];
    for n in &g.nodes {
        if let LayerKind::ZeroPad { pad } = &n.kind {
            let consumers = g.consumers(n.id);
            // Fold only when the single consumer is a VALID conv whose pad
            // equals the ZeroPad amounts — then SAME-like explicit padding
            // is recreated inside the conv loop.
            if consumers.len() == 1 {
                if let LayerKind::Conv { w, b, stride, padding: Padding::Valid } =
                    &g.nodes[consumers[0]].kind
                {
                    // Represent as SAME only if amounts match the SAME rule;
                    // otherwise keep the pad node (rare in our templates).
                    let in_spatial = &g.nodes[n.inputs[0]].out_shape;
                    let mut matches_same = true;
                    for (d, (lo, hi)) in pad.iter().enumerate() {
                        let k = w.shape[d];
                        let (slo, shi) = Graph::same_padding(in_spatial[d], k, *stride);
                        if (*lo, *hi) != (slo, shi) {
                            matches_same = false;
                        }
                    }
                    if matches_same {
                        replace[n.id] = Some(n.inputs[0]);
                        edits[consumers[0]] = Some(LayerKind::Conv {
                            w: w.clone(),
                            b: b.clone(),
                            stride: *stride,
                            padding: Padding::Same,
                        });
                    }
                }
            }
        }
    }
    rebuild(g, &replace, &edits)
}

/// Pass 2: fuse standalone ReLU nodes into their producer when the producer
/// is Conv / Dense / Add / MaxPool and the ReLU is its only consumer path.
pub fn fuse_relu(g: &Graph) -> Graph {
    let mut replace: Vec<Option<usize>> = vec![None; g.nodes.len()];
    let edits: Vec<Option<LayerKind>> = vec![None; g.nodes.len()];
    let mut fuse_flags: Vec<bool> = g.nodes.iter().map(|n| n.fused_relu).collect();
    for n in &g.nodes {
        if matches!(n.kind, LayerKind::ReLU) {
            let src = n.inputs[0];
            let fusable = matches!(
                g.nodes[src].kind,
                LayerKind::Conv { .. }
                    | LayerKind::Dense { .. }
                    | LayerKind::Add
                    | LayerKind::MaxPool { .. }
            );
            // Only fuse when the producer has no other consumer: otherwise
            // the pre-activation value is still needed (residual taps).
            if fusable && g.consumers(src).len() == 1 && !fuse_flags[src] {
                replace[n.id] = Some(src);
                fuse_flags[src] = true;
            }
        }
    }
    let mut out = rebuild(g, &replace, &edits);
    // Transfer fuse flags to the surviving nodes (rebuild keeps order).
    let mut j = 0usize;
    for (old_id, n) in g.nodes.iter().enumerate() {
        if replace[old_id].is_none() {
            out.nodes[j].fused_relu = fuse_flags[n.id];
            j += 1;
        }
    }
    out
}

/// Pass 3: BatchNorm -> affine (Eqs 5–7), folded into the previous Conv.
///
///   sigma = sqrt(V + eps);  w = gamma / sigma;  b = beta - gamma*mu/sigma
///
/// When the producer is a Conv with single consumer, scale its filters and
/// rewrite its bias; otherwise the BatchNorm stays (executed as affine).
pub fn fold_batchnorm(g: &Graph) -> Graph {
    let mut replace: Vec<Option<usize>> = vec![None; g.nodes.len()];
    let mut edits: Vec<Option<LayerKind>> = vec![None; g.nodes.len()];
    for n in &g.nodes {
        if let LayerKind::BatchNorm { mean, var, gamma, beta, eps } = &n.kind {
            let src = n.inputs[0];
            if g.consumers(src).len() != 1 {
                continue;
            }
            if let LayerKind::Conv { w, b, stride, padding } = &g.nodes[src].kind {
                let f = *w.shape.last().unwrap();
                assert_eq!(mean.len(), f);
                let mut w2 = w.clone();
                let mut b2 = b.clone();
                let per_filter = w.len() / f;
                for fi in 0..f {
                    let sigma = (var[fi] + eps).sqrt();
                    let scale = gamma[fi] / sigma;
                    for e in 0..per_filter {
                        w2.data[e * f + fi] *= scale;
                    }
                    b2.data[fi] = b.data[fi] * scale + beta[fi] - gamma[fi] * mean[fi] / sigma;
                }
                edits[src] = Some(LayerKind::Conv {
                    w: w2,
                    b: b2,
                    stride: *stride,
                    padding: *padding,
                });
                replace[n.id] = Some(src);
            }
        }
    }
    rebuild(g, &replace, &edits)
}

/// Kernel-tail epilogue a weighted (Conv / Dense) node's GEMM applies in
/// its register tile: the bias add always runs there; `Relu` additionally
/// clamps before the store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EpilogueKind {
    /// Bias only (plus the backend's rescale/requantize stage).
    Linear,
    /// Bias + fused ReLU (folded from a standalone ReLU by [`fuse_relu`]).
    Relu,
}

/// Pass 5 (annotation): classify every weighted node's fused epilogue so
/// the kernel lowering consumes activation fusion decided here — a ReLU
/// folded by [`fuse_relu`] executes inside the GEMM register-tile tail
/// (`nn::packed`), never as a separate activation sweep. Returns one
/// entry per node id: `None` for non-weighted layers, otherwise the
/// epilogue the build-time weight packer bakes into the node's
/// [`crate::nn::packed::Epilogue`].
pub fn annotate_epilogues(g: &Graph) -> Vec<Option<EpilogueKind>> {
    g.nodes
        .iter()
        .map(|n| match n.kind {
            LayerKind::Conv { .. } | LayerKind::Dense { .. } => Some(if n.fused_relu {
                EpilogueKind::Relu
            } else {
                EpilogueKind::Linear
            }),
            _ => None,
        })
        .collect()
}

/// Pass 6 (verification): abstract-interpretation range proof over a
/// quantized deployment graph (`crate::analysis`) — the pass-layer entry
/// point for callers that verify without building a session (the C
/// emitter's `_Static_assert` block, the deployer report). Every integer
/// accumulator, rescale and requantize cast is bounded under worst-case
/// inputs; `Err` means the graph can wrap at runtime.
pub fn verify_fixed_ranges(
    qg: &crate::quant::ptq::QuantizedGraph,
) -> Result<crate::analysis::VerifiedFacts, crate::analysis::VerifyError> {
    crate::analysis::analyze_fixed(qg)
}

/// [`verify_fixed_ranges`] for the affine int8 scheme: additionally
/// proves the pack-time zero-point fold `b_eff = b − zp·Σw` and every
/// `as i32` requantize cast in range.
pub fn verify_affine_ranges(
    aq: &crate::quant::affine::AffineQuantizedGraph,
) -> Result<crate::analysis::VerifiedFacts, crate::analysis::VerifyError> {
    crate::analysis::analyze_affine(aq)
}

/// Compute the affine (w, b) of a BatchNorm per Eqs 5–7 (exposed for the C
/// emitter, which keeps unfolded BatchNorms as multiply-add layers).
pub fn batchnorm_affine(
    mean: &[f32],
    var: &[f32],
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
) -> (Vec<f32>, Vec<f32>) {
    let mut w = Vec::with_capacity(mean.len());
    let mut b = Vec::with_capacity(mean.len());
    for (((&m, &v), &g), &bt) in mean.iter().zip(var).zip(gamma).zip(beta) {
        let sigma = (v + eps).sqrt();
        w.push(g / sigma);
        b.push(bt - g * m / sigma);
    }
    (w, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::resnet_v1_6_shapes;

    #[test]
    fn relu_fusion_shrinks_resnet() {
        let g = resnet_v1_6_shapes("har", 1, &[128, 9], 6, 8);
        let before = g.nodes.len();
        let fused = fuse_relu(&g);
        let relus = fused
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::ReLU))
            .count();
        assert_eq!(relus, 0, "all ReLUs fusable in ResNetv1-6:\n{}", fused.summary());
        assert!(fused.nodes.len() < before);
        // conv1, b1conv1, b2conv1, add1, add2 carry the fused flag.
        let flagged: Vec<&str> = fused
            .nodes
            .iter()
            .filter(|n| n.fused_relu)
            .map(|n| n.name.as_str())
            .collect();
        assert!(flagged.contains(&"conv1"));
        assert!(flagged.contains(&"add1"));
        assert!(flagged.contains(&"add2"));
    }

    #[test]
    fn relu_not_fused_when_producer_has_other_consumers() {
        use crate::graph::ir::{LayerKind as LK, Padding};
        use crate::tensor::Tensor;
        let mut g = Graph::new("t", 1, &[8, 2], 2);
        let c = g.add(
            "c",
            LK::Conv {
                w: Tensor::zeros(&[3, 2, 4]),
                b: Tensor::zeros(&[4]),
                stride: 1,
                padding: Padding::Same,
            },
            vec![0],
        );
        let r = g.add("r", LK::ReLU, vec![c]);
        let _tap = g.add("p", LK::MaxPool { size: 2 }, vec![c]); // second consumer
        let _r2 = g.add("p2", LK::MaxPool { size: 2 }, vec![r]);
        let fused = fuse_relu(&g);
        assert!(fused.nodes.iter().any(|n| matches!(n.kind, LayerKind::ReLU)));
    }

    #[test]
    fn batchnorm_affine_eqs_5_7() {
        let (w, b) = batchnorm_affine(&[1.0], &[4.0], &[2.0], &[0.5], 0.0);
        // sigma = 2, w = 1.0, b = 0.5 - 2*1/2 = -0.5
        assert!((w[0] - 1.0).abs() < 1e-6);
        assert!((b[0] + 0.5).abs() < 1e-6);
    }

    #[test]
    fn batchnorm_folds_into_conv() {
        use crate::graph::ir::{LayerKind as LK, Padding};
        use crate::tensor::Tensor;
        let mut g = Graph::new("t", 1, &[8, 2], 2);
        let c = g.add(
            "c",
            LK::Conv {
                w: Tensor::from_vec(&[1, 2, 1], vec![1.0, 1.0]),
                b: Tensor::from_vec(&[1], vec![0.5]),
                stride: 1,
                padding: Padding::Same,
            },
            vec![0],
        );
        let _bn = g.add(
            "bn",
            LK::BatchNorm {
                mean: vec![1.0],
                var: vec![4.0],
                gamma: vec![2.0],
                beta: vec![0.5],
                eps: 0.0,
            },
            vec![c],
        );
        let folded = fold_batchnorm(&g);
        assert!(!folded.nodes.iter().any(|n| matches!(n.kind, LayerKind::BatchNorm { .. })));
        if let LK::Conv { w, b, .. } = &folded.nodes[1].kind {
            assert!((w.data[0] - 1.0).abs() < 1e-6); // scaled by gamma/sigma = 1
            assert!((b.data[0] - 0.0).abs() < 1e-6); // 0.5*1 + 0.5 - 1 = 0
        } else {
            panic!("expected conv");
        }
    }

    #[test]
    fn softmax_removed() {
        use crate::graph::ir::LayerKind as LK;
        use crate::tensor::Tensor;
        let mut g = Graph::new("t", 1, &[4, 1], 2);
        let f = g.add("fl", LK::Flatten, vec![0]);
        let d = g.add(
            "d",
            LK::Dense { w: Tensor::zeros(&[4, 2]), b: Tensor::zeros(&[2]) },
            vec![f],
        );
        let _s = g.add("sm", LK::Softmax, vec![d]);
        let out = remove_softmax(&g);
        assert!(matches!(out.nodes[out.output_id()].kind, LK::Dense { .. }));
    }

    #[test]
    fn transformer_softmax_survives_pipeline() {
        // Regression for the strip_softmax opt-out: the transformer's
        // inference-time softmax head must ride through the whole
        // deployment pipeline, while its FFN ReLUs still fuse.
        let g = crate::graph::build::transformer("tx", 8, 16, 8, 2, 2, 2, 4);
        let d = deploy_pipeline(&g);
        assert!(matches!(d.nodes[d.output_id()].kind, LayerKind::Softmax));
        assert!(!d.nodes.iter().any(|n| matches!(n.kind, LayerKind::ReLU)));
        assert!(d.nodes.iter().any(|n| n.fused_relu));
        assert_eq!(d.param_count(), g.param_count());
        // Attention / LayerNorm / Embedding nodes pass through untouched.
        for kind in ["SelfAttention", "LayerNorm", "Embedding"] {
            assert_eq!(
                d.nodes.iter().filter(|n| n.kind.type_name() == kind).count(),
                g.nodes.iter().filter(|n| n.kind.type_name() == kind).count(),
                "{kind} count changed across the pipeline"
            );
        }
    }

    #[test]
    fn annotate_epilogues_tracks_fused_relu() {
        let g = deploy_pipeline(&resnet_v1_6_shapes("har", 1, &[128, 9], 6, 8));
        let epi = annotate_epilogues(&g);
        assert_eq!(epi.len(), g.nodes.len());
        for n in &g.nodes {
            match &n.kind {
                LayerKind::Conv { .. } | LayerKind::Dense { .. } => {
                    let want = if n.fused_relu { EpilogueKind::Relu } else { EpilogueKind::Linear };
                    assert_eq!(epi[n.id], Some(want), "node {}", n.name);
                }
                _ => assert_eq!(epi[n.id], None, "node {}", n.name),
            }
        }
        // The pipeline fuses conv1's ReLU, so at least one Relu epilogue
        // reaches the kernel tail.
        assert!(epi.iter().flatten().any(|e| *e == EpilogueKind::Relu));
    }

    #[test]
    fn pipeline_runs_on_resnet() {
        let g = resnet_v1_6_shapes("har", 1, &[128, 9], 6, 16);
        let d = deploy_pipeline(&g);
        assert_eq!(d.param_count(), g.param_count());
        assert_eq!(d.nodes[d.output_id()].out_shape, vec![6]);
    }

    #[test]
    fn verify_passes_prove_the_deployed_resnet() {
        use crate::nn::int_exec::{calib, random_inputs, randomized_resnet};
        use crate::quant::affine::quantize_affine;
        use crate::quant::{quantize, QuantSpec};
        let g = randomized_resnet(41);
        let inputs = random_inputs(4, 96, 42);
        let stats = calib(&g, &inputs);
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let facts = verify_fixed_ranges(&qg).expect("deployed resnet verifies");
        assert_eq!(facts.nodes.len(), qg.graph.nodes.len());
        assert!(facts.nodes.iter().any(|n| n.lane.is_some()));
        let aq = quantize_affine(&g, &stats);
        let afacts = verify_affine_ranges(&aq).expect("affine resnet verifies");
        assert_eq!(afacts.backend, "affine-i8");
    }
}
