//! Layer-graph intermediate representation (§5.6–5.7).
//!
//! KerasCNN2C parses a trained Keras model into "an internal representation
//! of the topology (i.e., a graph), with each node corresponding to a
//! layer". This is that IR on the Rust side: nodes are layers, edges are
//! data dependencies (multi-input nodes — `Add` — enable residual
//! topologies). Deployment passes (`passes.rs`), the allocator, the integer
//! engine, the C emitter and the MCU cost model all consume this one IR.

use crate::tensor::TensorF;

/// Spatial padding policy (XLA semantics; SAME matches the JAX model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Padding {
    Same,
    Valid,
}

/// Explicit zero padding amounts per spatial dim (lo, hi).
pub type PadSpec = Vec<(usize, usize)>;

#[derive(Clone, Debug)]
pub enum LayerKind {
    /// Graph input placeholder.
    Input,
    /// Convolution, 1D or 2D according to `Graph::dims`. Weights are
    /// channels-last: (k, C, F) or (kh, kw, C, F).
    Conv { w: TensorF, b: TensorF, stride: usize, padding: Padding },
    /// Fully connected: w (in, out), b (out).
    Dense { w: TensorF, b: TensorF },
    /// Max pooling, VALID, stride == size (the paper's usage).
    MaxPool { size: usize },
    /// Average pooling, VALID, stride == size.
    AvgPool { size: usize },
    /// Mean over all spatial positions.
    GlobalAvgPool,
    /// Element-wise residual addition (two inputs).
    Add,
    /// Standalone ReLU (§4.3 treats it as a separate layer; passes fuse it).
    ReLU,
    /// Softmax (stripped for deployment, §5.4 RemoveKerasSoftmax).
    Softmax,
    /// Explicit zero padding (fused into the next conv by passes).
    ZeroPad { pad: PadSpec },
    /// Batch normalization; folded to y = w*x + b by passes (Eqs 5–7).
    BatchNorm { mean: Vec<f32>, var: Vec<f32>, gamma: Vec<f32>, beta: Vec<f32>, eps: f32 },
    /// Flatten spatial dims (before Dense in the CNN template).
    Flatten,
    /// Token embedding lookup: w is (vocab, d_model); the input carries
    /// integer token ids in a (seq, 1) tensor. Lowered as a packed-row
    /// gather — no arithmetic, so payloads quantize once at build time.
    Embedding { w: TensorF },
    /// Layer normalization over the channel (last) dim per position:
    /// y = (x − mean) / sqrt(var + eps) · gamma + beta. Integer backends
    /// lower the rsqrt through the shared Q30 LUT (`fixedpoint::lut`).
    LayerNorm { gamma: Vec<f32>, beta: Vec<f32>, eps: f32 },
    /// Multi-head self-attention over a (seq, d_model) input with
    /// d_model = heads · head_dim. Lowered as two batched GEMMs per head
    /// (Q·Kᵀ and P·V) around a numerically-stable softmax, with the four
    /// projection weight matrices as build-time packed B panels.
    SelfAttention { heads: usize, head_dim: usize, w: Box<AttnWeights> },
}

/// The four projection weight sets of a [`LayerKind::SelfAttention`] node.
/// Each w is (d_model, d_model) row-major (input-major, like Dense), each
/// b is (d_model,).
#[derive(Clone, Debug)]
pub struct AttnWeights {
    pub wq: TensorF,
    pub bq: TensorF,
    pub wk: TensorF,
    pub bk: TensorF,
    pub wv: TensorF,
    pub bv: TensorF,
    pub wo: TensorF,
    pub bo: TensorF,
}

impl LayerKind {
    pub fn type_name(&self) -> &'static str {
        match self {
            LayerKind::Input => "Input",
            LayerKind::Conv { .. } => "Conv",
            LayerKind::Dense { .. } => "Dense",
            LayerKind::MaxPool { .. } => "MaxPool",
            LayerKind::AvgPool { .. } => "AvgPool",
            LayerKind::GlobalAvgPool => "GlobalAvgPool",
            LayerKind::Add => "Add",
            LayerKind::ReLU => "ReLU",
            LayerKind::Softmax => "Softmax",
            LayerKind::ZeroPad { .. } => "ZeroPad",
            LayerKind::BatchNorm { .. } => "BatchNorm",
            LayerKind::Flatten => "Flatten",
            LayerKind::Embedding { .. } => "Embedding",
            LayerKind::LayerNorm { .. } => "LayerNorm",
            LayerKind::SelfAttention { .. } => "SelfAttention",
        }
    }

    pub fn has_weights(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv { .. }
                | LayerKind::Dense { .. }
                | LayerKind::Embedding { .. }
                | LayerKind::LayerNorm { .. }
                | LayerKind::SelfAttention { .. }
        )
    }

    /// Bytes of parameters at `bytes_per_weight` (ROM model input).
    pub fn param_count(&self) -> usize {
        match self {
            LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } => w.len() + b.len(),
            LayerKind::BatchNorm { mean, .. } => 2 * mean.len(),
            LayerKind::Embedding { w } => w.len(),
            LayerKind::LayerNorm { gamma, .. } => 2 * gamma.len(),
            LayerKind::SelfAttention { w, .. } => {
                w.wq.len() + w.bq.len() + w.wk.len() + w.bk.len() + w.wv.len() + w.bv.len()
                    + w.wo.len() + w.bo.len()
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub name: String,
    pub kind: LayerKind,
    pub inputs: Vec<usize>,
    /// Per-example output shape (batch dim excluded): (S, C) / (H, W, C) /
    /// (units,) after GlobalAvgPool/Flatten/Dense.
    pub out_shape: Vec<usize>,
    /// ReLU fused into this node by the deployment pass (§5.7).
    pub fused_relu: bool,
}

#[derive(Clone, Debug)]
pub struct Graph {
    /// 1 or 2 spatial dimensions.
    pub dims: usize,
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub nodes: Vec<Node>,
    pub name: String,
    /// Whether `deploy_pipeline` may strip a trailing Softmax (§5.4
    /// RemoveKerasSoftmax). Default on — the CNN classifiers only use
    /// softmax as a training-time head. Transformer graphs opt out so an
    /// inference-time softmax survives deployment.
    pub strip_softmax: bool,
}

impl Graph {
    pub fn new(name: &str, dims: usize, input_shape: &[usize], classes: usize) -> Self {
        let mut g = Graph {
            dims,
            input_shape: input_shape.to_vec(),
            classes,
            nodes: Vec::new(),
            name: name.to_string(),
            strip_softmax: true,
        };
        g.nodes.push(Node {
            id: 0,
            name: "input".into(),
            kind: LayerKind::Input,
            inputs: vec![],
            out_shape: input_shape.to_vec(),
            fused_relu: false,
        });
        g
    }

    /// Append a node; returns its id. Nodes are always in topological order.
    pub fn add(&mut self, name: &str, kind: LayerKind, inputs: Vec<usize>) -> usize {
        for &i in &inputs {
            assert!(i < self.nodes.len(), "forward reference {i}");
        }
        let out_shape = self.infer_shape(&kind, &inputs);
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            kind,
            inputs,
            out_shape,
            fused_relu: false,
        });
        id
    }

    pub fn output_id(&self) -> usize {
        self.nodes.len() - 1
    }

    pub fn node(&self, id: usize) -> &Node {
        &self.nodes[id]
    }

    /// Total parameter count over all layers.
    pub fn param_count(&self) -> usize {
        self.nodes.iter().map(|n| n.kind.param_count()).sum()
    }

    /// Ids of nodes that consume node `id`.
    pub fn consumers(&self, id: usize) -> Vec<usize> {
        self.nodes
            .iter()
            .filter(|n| n.inputs.contains(&id))
            .map(|n| n.id)
            .collect()
    }

    fn spatial(&self, shape: &[usize]) -> Vec<usize> {
        shape[..shape.len() - 1].to_vec()
    }

    fn infer_shape(&self, kind: &LayerKind, inputs: &[usize]) -> Vec<usize> {
        let in_shape = |i: usize| self.nodes[inputs[i]].out_shape.clone();
        match kind {
            LayerKind::Input => self.input_shape.clone(),
            LayerKind::Conv { w, stride, padding, .. } => {
                let ish = in_shape(0);
                let spatial = self.spatial(&ish);
                assert_eq!(spatial.len(), self.dims, "conv rank mismatch");
                let filters = *w.shape.last().unwrap();
                let mut out: Vec<usize> = Vec::new();
                for (d, &s) in spatial.iter().enumerate() {
                    let k = w.shape[d];
                    let o = match padding {
                        Padding::Same => s.div_ceil(*stride),
                        Padding::Valid => (s - k) / stride + 1,
                    };
                    out.push(o);
                }
                out.push(filters);
                out
            }
            LayerKind::Dense { w, .. } => vec![w.shape[1]],
            LayerKind::MaxPool { size } | LayerKind::AvgPool { size } => {
                let ish = in_shape(0);
                let mut out = self.spatial(&ish);
                for o in out.iter_mut() {
                    // SAME-style ceil: odd spatial dims keep a remainder
                    // window instead of silently dropping the tail samples
                    // (Graph::pool_geometry; kernels and codegen agree).
                    *o = o.div_ceil(*size);
                }
                out.push(*ish.last().unwrap());
                out
            }
            LayerKind::GlobalAvgPool => vec![*in_shape(0).last().unwrap()],
            LayerKind::Add => {
                let a = in_shape(0);
                let b = in_shape(1);
                assert_eq!(a, b, "Add shape mismatch");
                a
            }
            LayerKind::ReLU | LayerKind::Softmax | LayerKind::BatchNorm { .. } => in_shape(0),
            LayerKind::ZeroPad { pad } => {
                let ish = in_shape(0);
                let mut out = self.spatial(&ish);
                assert_eq!(pad.len(), out.len());
                for (o, (lo, hi)) in out.iter_mut().zip(pad.iter()) {
                    *o += lo + hi;
                }
                out.push(*ish.last().unwrap());
                out
            }
            LayerKind::Flatten => vec![in_shape(0).iter().product()],
            LayerKind::Embedding { w } => {
                let ish = in_shape(0);
                assert_eq!(ish.len(), 2, "Embedding expects a (seq, 1) id tensor");
                assert_eq!(ish[1], 1, "Embedding input must carry one id per position");
                vec![ish[0], w.shape[1]]
            }
            LayerKind::LayerNorm { gamma, .. } => {
                let ish = in_shape(0);
                assert_eq!(
                    gamma.len(),
                    *ish.last().unwrap(),
                    "LayerNorm gamma/beta length must match the channel dim"
                );
                ish
            }
            LayerKind::SelfAttention { heads, head_dim, w } => {
                let ish = in_shape(0);
                assert_eq!(ish.len(), 2, "SelfAttention expects a (seq, d_model) input");
                let d_model = ish[1];
                assert_eq!(heads * head_dim, d_model, "heads · head_dim must equal d_model");
                assert_eq!(w.wq.shape, vec![d_model, d_model], "Wq must be (d_model, d_model)");
                ish
            }
        }
    }

    /// Per-spatial-dim SAME padding (lo, hi) for a conv node — XLA rule.
    pub fn same_padding(in_size: usize, kernel: usize, stride: usize) -> (usize, usize) {
        let out = in_size.div_ceil(stride);
        let total = ((out - 1) * stride + kernel).saturating_sub(in_size);
        (total / 2, total - total / 2)
    }

    /// Pooling geometry with the SAME-style remainder window: ceil(s/size)
    /// windows, padding distributed exactly like XLA `reduce_window` with
    /// "SAME" (lo = total/2 — 0 for the ubiquitous size-2 pools, which
    /// places the odd remainder at the end). Returns (pad_lo, out_size);
    /// window `o` covers `[o*size - pad_lo, o*size - pad_lo + size) ∩ [0, s)`.
    pub fn pool_geometry(in_size: usize, size: usize) -> (usize, usize) {
        (Self::same_padding(in_size, size, size).0, in_size.div_ceil(size))
    }

    /// Clamped in-range sample interval `[lo, hi)` of pooling window `o`
    /// under [`Graph::pool_geometry`]. The single definition every Rust
    /// pooling kernel uses, so the window rule cannot drift between
    /// kernels (the C emitter's remainder loop mirrors it).
    pub fn pool_window(o: usize, size: usize, pad_lo: usize, in_size: usize) -> (usize, usize) {
        let base = (o * size) as isize - pad_lo as isize;
        let lo = base.max(0) as usize;
        let hi = (base + size as isize).min(in_size as isize) as usize;
        (lo, hi)
    }

    /// Human-readable topology dump (debugging / docs).
    pub fn summary(&self) -> String {
        let mut s = format!("Graph {} (dims={}, classes={})\n", self.name, self.dims, self.classes);
        for n in &self.nodes {
            s.push_str(&format!(
                "  [{:>2}] {:<14} {:<12} in={:?} out={:?} params={}{}\n",
                n.id,
                n.name,
                n.kind.type_name(),
                n.inputs,
                n.out_shape,
                n.kind.param_count(),
                if n.fused_relu { " +ReLU" } else { "" },
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn conv_kind(k: usize, c: usize, f: usize, stride: usize) -> LayerKind {
        LayerKind::Conv {
            w: Tensor::zeros(&[k, c, f]),
            b: Tensor::zeros(&[f]),
            stride,
            padding: Padding::Same,
        }
    }

    #[test]
    fn shape_inference_1d_chain() {
        let mut g = Graph::new("t", 1, &[128, 9], 6);
        let c = g.add("c1", conv_kind(3, 9, 16, 1), vec![0]);
        assert_eq!(g.node(c).out_shape, vec![128, 16]);
        let p = g.add("p1", LayerKind::MaxPool { size: 2 }, vec![c]);
        assert_eq!(g.node(p).out_shape, vec![64, 16]);
        let s = g.add("c2", conv_kind(3, 16, 16, 2), vec![p]);
        assert_eq!(g.node(s).out_shape, vec![32, 16]);
        let gap = g.add("gap", LayerKind::GlobalAvgPool, vec![s]);
        assert_eq!(g.node(gap).out_shape, vec![16]);
        let d = g.add(
            "fc",
            LayerKind::Dense { w: Tensor::zeros(&[16, 6]), b: Tensor::zeros(&[6]) },
            vec![gap],
        );
        assert_eq!(g.node(d).out_shape, vec![6]);
    }

    #[test]
    fn same_padding_matches_xla() {
        assert_eq!(Graph::same_padding(128, 3, 1), (1, 1));
        assert_eq!(Graph::same_padding(9, 3, 2), (1, 1)); // out = 5
        assert_eq!(Graph::same_padding(8, 3, 2), (0, 1)); // out = 4
        assert_eq!(Graph::same_padding(39, 3, 1), (1, 1));
    }

    #[test]
    fn odd_pool_keeps_remainder_window() {
        // Pre-fix behaviour floored to 19, silently dropping sample 38.
        let mut g = Graph::new("t", 1, &[39, 13], 10);
        let p = g.add("p", LayerKind::MaxPool { size: 2 }, vec![0]);
        assert_eq!(g.node(p).out_shape, vec![20, 13]);
        assert_eq!(Graph::pool_geometry(39, 2), (0, 20));
        assert_eq!(Graph::pool_geometry(40, 2), (0, 20));
        assert_eq!(Graph::pool_geometry(10, 3), (1, 4)); // lo pad like XLA SAME
    }

    #[test]
    fn add_requires_same_shape() {
        let mut g = Graph::new("t", 1, &[16, 4], 2);
        let c1 = g.add("c1", conv_kind(3, 4, 8, 1), vec![0]);
        let c2 = g.add("c2", conv_kind(3, 4, 8, 1), vec![0]);
        let a = g.add("add", LayerKind::Add, vec![c1, c2]);
        assert_eq!(g.node(a).out_shape, vec![16, 8]);
    }

    #[test]
    fn consumers_are_found() {
        let mut g = Graph::new("t", 1, &[16, 4], 2);
        let c1 = g.add("c1", conv_kind(3, 4, 8, 1), vec![0]);
        let _r = g.add("r", LayerKind::ReLU, vec![c1]);
        let _p = g.add("p", LayerKind::MaxPool { size: 2 }, vec![c1]);
        assert_eq!(g.consumers(c1).len(), 2);
    }

    #[test]
    fn zeropad_shape() {
        let mut g = Graph::new("t", 1, &[10, 2], 2);
        let z = g.add("z", LayerKind::ZeroPad { pad: vec![(1, 2)] }, vec![0]);
        assert_eq!(g.node(z).out_shape, vec![13, 2]);
    }

    #[test]
    fn flatten_2d() {
        let mut g = Graph::new("t", 2, &[8, 8, 3], 2);
        let f = g.add("f", LayerKind::Flatten, vec![0]);
        assert_eq!(g.node(f).out_shape, vec![192]);
    }
}
