//! Layer-graph IR, model builders and deployment passes (§5.6–5.7).

pub mod build;
pub mod ir;
pub mod passes;

pub use build::{cnn, mlp, resnet_v1_6, resnet_v1_6_shapes, RESNET_PARAM_NAMES};
pub use ir::{Graph, LayerKind, Node, Padding};
pub use passes::{annotate_epilogues, deploy_pipeline, EpilogueKind};
