//! Artifact manifest + compiled-executable cache.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One model entry from artifacts/manifest.json.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub tag: String,
    pub dataset: String,
    pub filters: usize,
    pub dims: usize,
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    /// artifact kind -> file name (init/train/qat8_train/fwd/qfwd8).
    pub artifacts: BTreeMap<String, String>,
}

impl ModelSpec {
    pub fn n_params(&self) -> usize {
        self.param_shapes.len()
    }

    pub fn example_len(&self) -> usize {
        self.input_shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelSpec>,
    pub kernels: BTreeMap<String, String>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mut models = BTreeMap::new();
        for (tag, m) in v.get("models").and_then(Json::as_obj).context("models")? {
            let arr_usize = |key: &str| -> Vec<usize> {
                m.get(key)
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default()
            };
            let spec = ModelSpec {
                tag: tag.clone(),
                dataset: m.get("dataset").and_then(Json::as_str).unwrap_or("").to_string(),
                filters: m.get("filters").and_then(Json::as_usize).context("filters")?,
                dims: m.get("dims").and_then(Json::as_usize).context("dims")?,
                input_shape: arr_usize("input_shape"),
                classes: m.get("classes").and_then(Json::as_usize).context("classes")?,
                train_batch: m.get("train_batch").and_then(Json::as_usize).unwrap_or(64),
                eval_batch: m.get("eval_batch").and_then(Json::as_usize).unwrap_or(128),
                param_names: m
                    .get("param_names")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                    .unwrap_or_default(),
                param_shapes: m
                    .get("param_shapes")
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .map(|s| {
                                s.as_arr()
                                    .map(|d| d.iter().filter_map(Json::as_usize).collect())
                                    .unwrap_or_default()
                            })
                            .collect()
                    })
                    .unwrap_or_default(),
                artifacts: m
                    .get("artifacts")
                    .and_then(Json::as_obj)
                    .map(|o| {
                        o.iter()
                            .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                            .collect()
                    })
                    .unwrap_or_default(),
            };
            models.insert(tag.clone(), spec);
        }
        let kernels = v
            .get("kernels")
            .and_then(Json::as_obj)
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| {
                        v.get("file").and_then(Json::as_str).map(|f| (k.clone(), f.to_string()))
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(Manifest { models, kernels })
    }
}

/// A compiled artifact ready to execute. Inputs are passed as literals;
/// the output tuple is decomposed into flat literals.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let mut lit = result[0][0].to_literal_sync()?;
        Ok(lit.decompose_tuple()?)
    }
}

/// The process-wide PJRT runtime: client + manifest + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Open the artifact directory (expects manifest.json inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json — run `make artifacts`", dir.display()))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT: {e}"))?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(BTreeMap::new()) })
    }

    /// Default artifact location relative to the repo root.
    pub fn open_default() -> Result<Runtime> {
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(cand).join("manifest.json").exists() {
                return Self::open(cand);
            }
        }
        Self::open("artifacts")
    }

    pub fn spec(&self, tag: &str) -> Result<&ModelSpec> {
        self.manifest
            .models
            .get(tag)
            .with_context(|| format!("unknown model tag {tag:?} (have: {:?})",
                self.manifest.models.keys().collect::<Vec<_>>()))
    }

    /// Compile (or fetch from cache) an artifact by file name.
    pub fn compile(&self, file: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        let exe = Rc::new(Executable { exe, name: file.to_string() });
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Compile a model's artifact by (tag, kind).
    pub fn compile_model(&self, tag: &str, kind: &str) -> Result<Rc<Executable>> {
        let spec = self.spec(tag)?;
        let file = spec
            .artifacts
            .get(kind)
            .with_context(|| format!("model {tag} has no {kind} artifact"))?
            .clone();
        self.compile(&file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_minimal() {
        let text = r#"{"version":1,"models":{"har_f8":{"dataset":"har",
            "filters":8,"dims":1,"input_shape":[128,9],"classes":6,
            "train_batch":64,"eval_batch":128,
            "param_names":["c1w"],"param_shapes":[[3,9,8]],
            "artifacts":{"init":"init_har_f8.hlo.txt"}}},
            "kernels":{"fixed_matmul":{"file":"k.hlo.txt","m":32}}}"#;
        let m = Manifest::parse(text).unwrap();
        let spec = &m.models["har_f8"];
        assert_eq!(spec.filters, 8);
        assert_eq!(spec.input_shape, vec![128, 9]);
        assert_eq!(spec.param_shapes[0], vec![3, 9, 8]);
        assert_eq!(m.kernels["fixed_matmul"], "k.hlo.txt");
        assert_eq!(spec.example_len(), 1152);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("{").is_err());
        assert!(Manifest::parse("{}").is_err());
    }
}
