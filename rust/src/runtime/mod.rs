//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! The interchange format is HLO TEXT (`HloModuleProto::from_text_file`),
//! not a serialized proto: jax ≥ 0.5 emits 64-bit instruction ids that the
//! bundled xla_extension 0.5.1 rejects; the text parser reassigns ids
//! (see /opt/xla-example/README.md). One `PjRtClient` is shared per
//! process; compiled executables are cached per artifact file.

pub mod artifact;
pub mod exec;

pub use artifact::{Manifest, ModelSpec, Runtime};
pub use exec::{lit_f32, lit_i32, lit_scalar_f32, lit_u32, to_f32};
