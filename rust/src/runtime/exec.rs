//! Literal marshalling helpers between Rust buffers and PJRT.

use anyhow::Result;

/// Shaped f32 literal from a flat buffer.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {dims:?} vs len {}", data.len());
    let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i)?)
}

/// Rank-1 i32 literal (labels).
pub fn lit_i32(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Rank-1 u32 literal (PRNG key payloads).
pub fn lit_u32(data: &[u32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Scalar f32 literal (learning rate).
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Flatten a literal back to f32.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
