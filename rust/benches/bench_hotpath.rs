//! Hot-path benchmarks + the repo's recorded perf trajectory.
//!
//! Two jobs:
//! 1. **Kernel race** — every distinct conv/dense layer shape of the three
//!    paper topologies (UCI-HAR, SMNIST, GTSRB) raced GEMM vs the naive
//!    `*_ref` kernels across all numeric flavors (f32 / int8-i32 lanes /
//!    int16-i64 / affine). Results land in machine-readable
//!    `BENCH_hotpath.json`; `--check` turns the per-shape speedup into a
//!    CI gate (fail when GEMM is slower than reference beyond measurement
//!    tolerance).
//! 2. **Whole-graph** — Session inference throughput per backend, plus the
//!    longstanding quantizer/calibration/allocator/codegen sections (full
//!    mode only).
//!
//! Run: `cargo bench --bench bench_hotpath`
//! CI:  `cargo bench --bench bench_hotpath -- --smoke --check --out BENCH_hotpath.json`

use std::collections::BTreeSet;

use microai::graph::ir::LayerKind;
use microai::graph::{deploy_pipeline, resnet_v1_6_shapes, Graph};
use microai::mcu::node_gemm_shape;
use microai::nn::float_exec::{self, ActStats};
use microai::nn::{affine_exec, float_ops, gemm, int_exec, int_ops, SessionBuilder};
use microai::quant::affine::AffineQuantizedGraph;
use microai::quant::{quantize, quantize_affine, QuantSpec, QuantizedGraph};
use microai::util::bench::{black_box, print_header, Bencher};
use microai::util::json::Json;
use microai::util::prng::Pcg32;

/// Measurement-noise deadband for the `--check` gate: a tie (hybrid
/// small-shape fallback runs the identical reference code) must not flap
/// CI, while a real regression (ratios well under 1.0) still fails.
const CHECK_TOLERANCE: f64 = 0.05;

struct RaceRow {
    model: String,
    layer: String,
    kind: &'static str,
    backend: &'static str,
    m: u64,
    n: u64,
    k: u64,
    ref_ns: f64,
    gemm_ns: f64,
}

impl RaceRow {
    fn speedup(&self) -> f64 {
        self.ref_ns / self.gemm_ns.max(1.0)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("layer", Json::str(&self.layer)),
            ("kind", Json::str(self.kind)),
            ("backend", Json::str(self.backend)),
            ("m", Json::num(self.m as f64)),
            ("n", Json::num(self.n as f64)),
            ("k", Json::num(self.k as f64)),
            ("ref_ns", Json::num(self.ref_ns)),
            ("gemm_ns", Json::num(self.gemm_ns)),
            ("speedup", Json::num(self.speedup())),
        ])
    }
}

fn randomized(mut g: Graph, seed: u64) -> Graph {
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.3;
            }
            for v in b.data.iter_mut() {
                *v = rng.normal() * 0.02;
            }
        }
    }
    deploy_pipeline(&g)
}

fn calibrated_stats(g: &Graph, ex_len: usize) -> ActStats {
    let mut stats = ActStats::new(g.nodes.len());
    let mut rng = Pcg32::seeded(2);
    for _ in 0..4 {
        let x: Vec<f32> = (0..ex_len).map(|_| rng.normal()).collect();
        float_exec::run(g, &x, Some(&mut stats));
    }
    stats
}

fn rand_payloads(rng: &mut Pcg32, len: usize, width: u32) -> Vec<i32> {
    let lim = (1i32 << (width - 1)) - 1;
    (0..len).map(|_| rng.below((2 * lim) as u32) as i32 - lim).collect()
}

/// Race one fixed-point conv/dense node: `*_q_ref` vs GEMM lowering.
#[allow(clippy::too_many_arguments)]
fn race_qmn(
    b: &Bencher,
    model: &str,
    node_name: &str,
    qg: &QuantizedGraph,
    id: usize,
    backend: &'static str,
    rows: &mut Vec<RaceRow>,
    rng: &mut Pcg32,
) {
    let g = &qg.graph;
    let node = &g.nodes[id];
    let qw = &qg.weights[&id];
    let width = qg.width;
    let gs = node_gemm_shape(g, id).unwrap();
    let relu = node.fused_relu;
    let mut out = Vec::new();
    let mut scratch = Vec::new();
    let (kind, r_ref, r_gemm) = match &node.kind {
        LayerKind::Conv { w, stride, padding, .. } => {
            let ish = &g.nodes[node.inputs[0]].out_shape;
            let x = rand_payloads(rng, ish.iter().product(), width);
            if g.dims == 1 {
                let (s, c, k, f) = (ish[0], ish[1], w.shape[0], w.shape[2]);
                let r_ref = b.run(&format!("{backend:<5} ref  {model}/{node_name}"), || {
                    black_box(int_ops::conv1d_q_ref(
                        &x, s, c, qw, k, f, *stride, *padding, relu, width, &mut out,
                    ));
                });
                let r_gemm = b.run(&format!("{backend:<5} gemm {model}/{node_name}"), || {
                    black_box(gemm::conv1d_q_gemm(
                        &x, s, c, qw, k, f, *stride, *padding, relu, width, &mut scratch,
                        &mut out,
                    ));
                });
                ("conv1d", r_ref, r_gemm)
            } else {
                let (h, wd, c) = (ish[0], ish[1], ish[2]);
                let (kh, kw, f) = (w.shape[0], w.shape[1], w.shape[3]);
                let r_ref = b.run(&format!("{backend:<5} ref  {model}/{node_name}"), || {
                    black_box(int_ops::conv2d_q_ref(
                        &x, h, wd, c, qw, kh, kw, f, *stride, *padding, relu, width, &mut out,
                    ));
                });
                let r_gemm = b.run(&format!("{backend:<5} gemm {model}/{node_name}"), || {
                    black_box(gemm::conv2d_q_gemm(
                        &x, h, wd, c, qw, kh, kw, f, *stride, *padding, relu, width,
                        &mut scratch, &mut out,
                    ));
                });
                ("conv2d", r_ref, r_gemm)
            }
        }
        LayerKind::Dense { w, .. } => {
            let x = rand_payloads(rng, w.shape[0], width);
            let o = w.shape[1];
            let r_ref = b.run(&format!("{backend:<5} ref  {model}/{node_name}"), || {
                black_box(int_ops::dense_q_ref(&x, qw, o, relu, width, &mut out));
            });
            let r_gemm = b.run(&format!("{backend:<5} gemm {model}/{node_name}"), || {
                black_box(gemm::dense_q_gemm(&x, qw, o, relu, width, &mut out));
            });
            ("dense", r_ref, r_gemm)
        }
        _ => return,
    };
    rows.push(RaceRow {
        model: model.to_string(),
        layer: node_name.to_string(),
        kind,
        backend,
        m: gs.m,
        n: gs.n,
        k: gs.k,
        ref_ns: r_ref.median_ns,
        gemm_ns: r_gemm.median_ns,
    });
}

/// Race one float conv/dense node.
#[allow(clippy::too_many_arguments)]
fn race_f32(
    b: &Bencher,
    model: &str,
    node_name: &str,
    g: &Graph,
    id: usize,
    rows: &mut Vec<RaceRow>,
    rng: &mut Pcg32,
) {
    let node = &g.nodes[id];
    let gs = node_gemm_shape(g, id).unwrap();
    let relu = node.fused_relu;
    let mut out = Vec::new();
    let mut scratch = Vec::new();
    let (kind, r_ref, r_gemm) = match &node.kind {
        LayerKind::Conv { w, b: wb, stride, padding } => {
            let ish = &g.nodes[node.inputs[0]].out_shape;
            let x: Vec<f32> =
                (0..ish.iter().product::<usize>()).map(|_| rng.normal()).collect();
            if g.dims == 1 {
                let (s, c, k, f) = (ish[0], ish[1], w.shape[0], w.shape[2]);
                let r_ref = b.run(&format!("f32   ref  {model}/{node_name}"), || {
                    black_box(float_ops::conv1d_ref(
                        &x, s, c, &w.data, k, f, &wb.data, *stride, *padding, relu, &mut out,
                    ));
                });
                let r_gemm = b.run(&format!("f32   gemm {model}/{node_name}"), || {
                    black_box(gemm::conv1d_gemm(
                        &x, s, c, &w.data, k, f, &wb.data, *stride, *padding, relu,
                        &mut scratch, &mut out,
                    ));
                });
                ("conv1d", r_ref, r_gemm)
            } else {
                let (h, wd, c) = (ish[0], ish[1], ish[2]);
                let (kh, kw, f) = (w.shape[0], w.shape[1], w.shape[3]);
                let r_ref = b.run(&format!("f32   ref  {model}/{node_name}"), || {
                    black_box(float_ops::conv2d_ref(
                        &x, h, wd, c, &w.data, kh, kw, f, &wb.data, *stride, *padding, relu,
                        &mut out,
                    ));
                });
                let r_gemm = b.run(&format!("f32   gemm {model}/{node_name}"), || {
                    black_box(gemm::conv2d_gemm(
                        &x, h, wd, c, &w.data, kh, kw, f, &wb.data, *stride, *padding, relu,
                        &mut scratch, &mut out,
                    ));
                });
                ("conv2d", r_ref, r_gemm)
            }
        }
        LayerKind::Dense { w, b: wb } => {
            let x: Vec<f32> = (0..w.shape[0]).map(|_| rng.normal()).collect();
            let o = w.shape[1];
            let r_ref = b.run(&format!("f32   ref  {model}/{node_name}"), || {
                black_box(float_ops::dense_ref(&x, &w.data, &wb.data, o, relu, &mut out));
            });
            let r_gemm = b.run(&format!("f32   gemm {model}/{node_name}"), || {
                black_box(gemm::dense_gemm(&x, &w.data, &wb.data, o, relu, &mut out));
            });
            ("dense", r_ref, r_gemm)
        }
        _ => return,
    };
    rows.push(RaceRow {
        model: model.to_string(),
        layer: node_name.to_string(),
        kind,
        backend: "f32",
        m: gs.m,
        n: gs.n,
        k: gs.k,
        ref_ns: r_ref.median_ns,
        gemm_ns: r_gemm.median_ns,
    });
}

/// Race one affine conv/dense node.
#[allow(clippy::too_many_arguments)]
fn race_affine(
    b: &Bencher,
    model: &str,
    node_name: &str,
    aq: &AffineQuantizedGraph,
    id: usize,
    rows: &mut Vec<RaceRow>,
    rng: &mut Pcg32,
) {
    let g = &aq.graph;
    let node = &g.nodes[id];
    let qw = &aq.weights[&id];
    let gs = node_gemm_shape(g, id).unwrap();
    let relu = node.fused_relu;
    let src_id = node.inputs[0];
    let (zp_in, zp_out) = (aq.act[src_id].zero_point, aq.act[id].zero_point);
    let mut out = Vec::new();
    let mut scratch = Vec::new();
    let (kind, r_ref, r_gemm) = match &node.kind {
        LayerKind::Conv { w, stride, padding, .. } => {
            let ish = &g.nodes[src_id].out_shape;
            let x = rand_payloads(rng, ish.iter().product(), 8);
            let r_ref = b.run(&format!("affin ref  {model}/{node_name}"), || {
                affine_exec::conv_affine_ref(
                    &x, ish, &w.shape, qw, zp_in, zp_out, *stride, *padding, relu, g.dims,
                    &mut out,
                );
                black_box(&out);
            });
            let r_gemm = b.run(&format!("affin gemm {model}/{node_name}"), || {
                gemm::conv_affine_gemm(
                    &x, ish, &w.shape, qw, zp_in, zp_out, *stride, *padding, relu, g.dims,
                    &mut scratch, &mut out,
                );
                black_box(&out);
            });
            (if g.dims == 1 { "conv1d" } else { "conv2d" }, r_ref, r_gemm)
        }
        LayerKind::Dense { w, .. } => {
            let x = rand_payloads(rng, w.shape[0], 8);
            let o = w.shape[1];
            let r_ref = b.run(&format!("affin ref  {model}/{node_name}"), || {
                affine_exec::dense_affine_ref(&x, qw, zp_in, zp_out, o, relu, &mut out);
                black_box(&out);
            });
            let r_gemm = b.run(&format!("affin gemm {model}/{node_name}"), || {
                gemm::dense_affine_gemm(&x, qw, zp_in, zp_out, o, relu, &mut scratch, &mut out);
                black_box(&out);
            });
            ("dense", r_ref, r_gemm)
        }
        _ => return,
    };
    rows.push(RaceRow {
        model: model.to_string(),
        layer: node_name.to_string(),
        kind,
        backend: "affine",
        m: gs.m,
        n: gs.n,
        k: gs.k,
        ref_ns: r_ref.median_ns,
        gemm_ns: r_gemm.median_ns,
    });
}

/// Distinct-shape weighted nodes of a deployed graph (duplicate residual
/// block convs share one race).
fn distinct_weighted_nodes(g: &Graph) -> Vec<usize> {
    let mut seen = BTreeSet::new();
    let mut ids = Vec::new();
    for node in &g.nodes {
        let sig = match &node.kind {
            LayerKind::Conv { w, stride, padding, .. } => format!(
                "conv {:?} {:?} {stride} {padding:?} {} in {:?}",
                w.shape, node.out_shape, node.fused_relu, g.nodes[node.inputs[0]].out_shape
            ),
            LayerKind::Dense { w, .. } => {
                format!("dense {:?} {}", w.shape, node.fused_relu)
            }
            _ => continue,
        };
        if seen.insert(sig) {
            ids.push(node.id);
        }
    }
    ids
}

struct GraphRow {
    model: String,
    backend: String,
    ns_per_inference: f64,
    macc_per_s: f64,
}

fn main() {
    let mut smoke = std::env::var("MICROAI_BENCH_SMOKE").is_ok();
    let mut check = false;
    let mut out_path = String::from("BENCH_hotpath.json");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--check" => check = true,
            "--out" => out_path = args.next().expect("--out needs a path"),
            "--bench" => {} // appended by `cargo bench`
            other => eprintln!("bench_hotpath: ignoring unknown arg {other}"),
        }
    }
    // The race needs real medians even in CI: the smoke profile spends
    // 100 ms warmup + 400 ms measurement per arm (vs the serving bench's
    // 1-iteration smoke) so the --check ratio gate sees stable medians on
    // shared runners. If a runner still proves noisy, widen
    // CHECK_TOLERANCE rather than disabling the gate.
    let b = if smoke {
        Bencher {
            warmup: std::time::Duration::from_millis(100),
            measure: std::time::Duration::from_millis(400),
            max_iters: 5_000,
        }
    } else {
        Bencher::default()
    };
    let mut rng = Pcg32::seeded(3);
    let mut race_rows: Vec<RaceRow> = Vec::new();
    let mut graph_rows: Vec<GraphRow> = Vec::new();

    let mut topologies: Vec<(&str, Graph, usize)> = vec![
        (
            "uci-har",
            randomized(resnet_v1_6_shapes("har", 1, &[128, 9], 6, 16), 1),
            128 * 9,
        ),
        (
            "smnist",
            randomized(resnet_v1_6_shapes("smnist", 1, &[39, 13], 10, 8), 2),
            39 * 13,
        ),
        (
            "gtsrb",
            randomized(resnet_v1_6_shapes("gtsrb", 2, &[32, 32, 3], 43, 8), 3),
            32 * 32 * 3,
        ),
    ];
    if !smoke {
        topologies.push((
            "uci-har-f80",
            randomized(resnet_v1_6_shapes("har80", 1, &[128, 9], 6, 80), 4),
            128 * 9,
        ));
    }

    for (model, g, ex_len) in &topologies {
        let model: &str = model;
        let ex_len: usize = *ex_len;
        print_header(&format!("kernel race GEMM vs *_ref — {model}"));
        let stats = calibrated_stats(g, ex_len);
        let q8 = quantize(g, &stats, QuantSpec::int8_per_layer());
        let q16 = quantize(g, &stats, QuantSpec::int16_per_layer());
        let aq = quantize_affine(g, &stats);
        for id in distinct_weighted_nodes(g) {
            let name = g.nodes[id].name.clone();
            race_f32(&b, model, &name, g, id, &mut race_rows, &mut rng);
            race_qmn(&b, model, &name, &q8, id, "int8", &mut race_rows, &mut rng);
            race_qmn(&b, model, &name, &q16, id, "int16", &mut race_rows, &mut rng);
            race_affine(&b, model, &name, &aq, id, &mut race_rows, &mut rng);
        }
        for row in race_rows.iter().filter(|r| r.model == *model) {
            println!(
                "{:<28} {:<6} {:<7} m={:<5} n={:<4} k={:<5} ref {:>10.0} ns  gemm {:>10.0} ns  \
                 {:>5.2}x",
                row.layer, row.kind, row.backend, row.m, row.n, row.k, row.ref_ns, row.gemm_ns,
                row.speedup()
            );
        }

        print_header(&format!("whole-graph Session inference — {model}"));
        let macc = microai::mcu::graph_ops(g).macc as f64;
        let x: Vec<f32> = (0..ex_len).map(|_| rng.normal()).collect();
        let mut record = |backend: &str, r: microai::util::bench::BenchResult| {
            println!("{}", r.report());
            graph_rows.push(GraphRow {
                model: model.to_string(),
                backend: backend.to_string(),
                ns_per_inference: r.median_ns,
                macc_per_s: r.throughput.map(|(v, _)| v).unwrap_or(0.0),
            });
        };
        let mut fsess = SessionBuilder::float32(g.clone()).build();
        let r = b.run_throughput(&format!("float32     {model}"), macc, "MACC/s", || {
            black_box(fsess.run(&x));
        });
        record("float32", r);
        let mut s8 = SessionBuilder::fixed_qmn(q8.clone()).build();
        let r = b.run_throughput(&format!("int8        {model}"), macc, "MACC/s", || {
            black_box(s8.run(&x));
        });
        record("int8", r);
        let mut s16 = SessionBuilder::fixed_qmn(q16.clone()).build();
        let r = b.run_throughput(&format!("int16       {model}"), macc, "MACC/s", || {
            black_box(s16.run(&x));
        });
        record("int16", r);
        let mut sa = SessionBuilder::affine_i8(aq.clone()).build();
        let r = b.run_throughput(&format!("affine-int8 {model}"), macc, "MACC/s", || {
            black_box(sa.run(&x));
        });
        record("affine-int8", r);
    }

    if !smoke {
        legacy_sections(&b, &mut rng);
    }

    // --- machine-readable trajectory + CI gate ---
    let min_speedup = race_rows.iter().map(RaceRow::speedup).fold(f64::INFINITY, f64::min);
    let pass = race_rows.iter().all(|r| r.speedup() >= 1.0 - CHECK_TOLERANCE);
    let doc = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("bench", Json::str("hotpath")),
        ("mode", Json::str(if smoke { "smoke" } else { "full" })),
        (
            "gate",
            Json::obj(vec![
                ("enforced", Json::Bool(check)),
                ("rule", Json::str("speedup >= 1.0 - tolerance on every measured shape")),
                ("tolerance", Json::num(CHECK_TOLERANCE)),
                ("min_speedup", Json::num(if min_speedup.is_finite() { min_speedup } else { 0.0 })),
                ("pass", Json::Bool(pass)),
            ]),
        ),
        ("kernel_race", Json::Arr(race_rows.iter().map(RaceRow::to_json).collect())),
        (
            "whole_graph",
            Json::Arr(
                graph_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("model", Json::str(&r.model)),
                            ("backend", Json::str(&r.backend)),
                            ("ns_per_inference", Json::num(r.ns_per_inference)),
                            ("macc_per_s", Json::num(r.macc_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let mut text = doc.to_string();
    text.push('\n');
    std::fs::write(&out_path, text).expect("write bench json");
    println!("\nwrote {out_path} (min GEMM speedup {min_speedup:.2}x over {} shapes)",
        race_rows.len());

    if check && !pass {
        eprintln!("--check FAILED: GEMM slower than reference on:");
        for r in race_rows.iter().filter(|r| r.speedup() < 1.0 - CHECK_TOLERANCE) {
            eprintln!(
                "  {}/{} {} {}: {:.2}x (ref {:.0} ns, gemm {:.0} ns)",
                r.model, r.layer, r.kind, r.backend, r.speedup(), r.ref_ns, r.gemm_ns
            );
        }
        std::process::exit(1);
    }
}

/// The pre-existing sections: quantizer, calibration, allocator, codegen,
/// datasets, and the session-reuse-vs-per-call-alloc comparison.
fn legacy_sections(b: &Bencher, rng: &mut Pcg32) {
    let g = randomized(resnet_v1_6_shapes("har", 1, &[128, 9], 6, 32), 9);
    let stats = calibrated_stats(&g, 128 * 9);

    print_header("session reuse vs per-call allocation (int8, single input)");
    let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
    let x: Vec<f32> = (0..128 * 9).map(|_| rng.normal()).collect();
    let macc = microai::mcu::graph_ops(&g).macc as f64;
    let mut sess = SessionBuilder::fixed_qmn(qg.clone()).build();
    let r = b.run_throughput("session reuse (arena)", macc, "MACC/s", || {
        black_box(sess.run(&x));
    });
    println!("{}", r.report());
    let r = b.run_throughput("per-call exec (allocs)", macc, "MACC/s", || {
        black_box(int_exec::run(&qg, &x));
    });
    println!("{}", r.report());
    let batch: Vec<f32> = (0..8 * 128 * 9).map(|_| rng.normal()).collect();
    let mut preds = Vec::new();
    let r = b.run_throughput("session classify_batch(8)", 8.0 * macc, "MACC/s", || {
        preds.clear();
        sess.classify_batch_into(&batch, &mut preds);
        black_box(&preds);
    });
    println!("{}", r.report());

    print_header("quantizer (PTQ over full graph, f=32)");
    for (label, spec) in [
        ("int8 per-layer ", QuantSpec::int8_per_layer()),
        ("int8 per-filter", QuantSpec::int8_per_filter()),
        ("int16 per-layer", QuantSpec::int16_per_layer()),
    ] {
        let r = b.run(label, || {
            black_box(quantize(&g, &stats, spec));
        });
        println!("{}", r.report());
    }

    print_header("calibration pass (float forward with stats, f=32)");
    let r = b.run("calibrate 1 example", || {
        let mut s = ActStats::new(g.nodes.len());
        black_box(float_exec::run(&g, &x, Some(&mut s)));
    });
    println!("{}", r.report());

    print_header("allocator (§5.7 first-fit, f=80)");
    let g80 = randomized(resnet_v1_6_shapes("har", 1, &[128, 9], 6, 80), 10);
    let r = b.run("allocate ResNet", || {
        black_box(microai::allocator::allocate(&g80));
    });
    println!("{}", r.report());

    print_header("C code generation (f=32, int8)");
    let r = b.run("generate C library", || {
        black_box(microai::codegen::generate(&qg));
    });
    println!("{}", r.report());

    print_header("synthetic dataset generation");
    let r = b.run("har full dataset", || {
        black_box(microai::datasets::load("har", 1));
    });
    println!("{}", r.report());
}
