//! Hot-path micro-benchmarks (criterion-free harness, util::bench):
//! the integer conv/dense kernels, whole-graph inference per dtype, the
//! quantizer and the allocator. These are the numbers the §Perf pass in
//! EXPERIMENTS.md tracks.
//!
//! Run: `cargo bench --bench bench_hotpath`

use microai::graph::ir::LayerKind;
use microai::graph::{deploy_pipeline, resnet_v1_6_shapes, Graph};
use microai::nn::float_exec::{self, ActStats};
use microai::nn::{affine_exec, int_exec, SessionBuilder};
use microai::quant::{quantize, quantize_affine, QuantSpec};
use microai::util::bench::{black_box, print_header, Bencher};
use microai::util::prng::Pcg32;

fn randomized_har(filters: usize) -> Graph {
    let mut g = resnet_v1_6_shapes("har", 1, &[128, 9], 6, filters);
    let mut rng = Pcg32::seeded(1);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.3;
            }
            for v in b.data.iter_mut() {
                *v = 0.01;
            }
        }
    }
    deploy_pipeline(&g)
}

fn calibrated_stats(g: &Graph, ex_len: usize) -> ActStats {
    let mut stats = ActStats::new(g.nodes.len());
    let mut rng = Pcg32::seeded(2);
    for _ in 0..8 {
        let x: Vec<f32> = (0..ex_len).map(|_| rng.normal()).collect();
        float_exec::run(g, &x, Some(&mut stats));
    }
    stats
}

fn main() {
    let b = Bencher::default();
    let mut rng = Pcg32::seeded(3);

    print_header("whole-graph single-input inference (UCI-HAR ResNet, Session API)");
    for filters in [16usize, 80] {
        let g = randomized_har(filters);
        let ex_len = 128 * 9;
        let stats = calibrated_stats(&g, ex_len);
        let x: Vec<f32> = (0..ex_len).map(|_| rng.normal()).collect();
        let macc = microai::mcu::graph_ops(&g).macc as f64;

        let mut fsess = SessionBuilder::float32(g.clone()).build();
        let r = b.run_throughput(&format!("float32 f={filters}"), macc, "MACC/s", || {
            black_box(fsess.run(&x));
        });
        println!("{}", r.report());

        for (label, spec) in [
            ("int8 ", QuantSpec::int8_per_layer()),
            ("int16", QuantSpec::int16_per_layer()),
        ] {
            let qg = quantize(&g, &stats, spec);
            let mut sess = SessionBuilder::fixed_qmn(qg).build();
            let r = b.run_throughput(&format!("{label} f={filters}"), macc, "MACC/s", || {
                black_box(sess.run(&x));
            });
            println!("{}", r.report());
        }

        let aq = quantize_affine(&g, &stats);
        let mut asess = SessionBuilder::affine_i8(aq).build();
        let r = b.run_throughput(&format!("affine int8 f={filters}"), macc, "MACC/s", || {
            black_box(asess.run(&x));
        });
        println!("{}", r.report());
    }

    // The arena win: a reused Session performs zero per-request
    // activation-buffer allocation; the legacy free functions redo the
    // lifetime analysis and reallocate every pool on every call.
    print_header("session reuse vs per-call allocation (int8, single input)");
    for filters in [16usize, 80] {
        let g = randomized_har(filters);
        let ex_len = 128 * 9;
        let stats = calibrated_stats(&g, ex_len);
        let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
        let x: Vec<f32> = (0..ex_len).map(|_| rng.normal()).collect();
        let macc = microai::mcu::graph_ops(&g).macc as f64;

        let mut sess = SessionBuilder::fixed_qmn(qg.clone()).build();
        let r = b.run_throughput(
            &format!("session reuse (arena)    f={filters}"), macc, "MACC/s",
            || {
                black_box(sess.run(&x));
            },
        );
        println!("{}", r.report());

        let r = b.run_throughput(
            &format!("per-call exec (allocs)   f={filters}"), macc, "MACC/s",
            || {
                black_box(int_exec::run(&qg, &x));
            },
        );
        println!("{}", r.report());

        // Batch execution amortizes the borrow/setup per example too.
        let batch: Vec<f32> = (0..8 * ex_len).map(|_| rng.normal()).collect();
        let mut out = Vec::new();
        let r = b.run_throughput(
            &format!("session run_batch(8)     f={filters}"), 8.0 * macc, "MACC/s",
            || {
                out.clear();
                sess.run_batch_into(&batch, &mut out);
                black_box(&out);
            },
        );
        println!("{}", r.report());

        // classify_batch: the serving cascade's per-batch hot path (one
        // arena, one reused prediction buffer, no per-request alloc).
        let mut preds = Vec::new();
        let r = b.run_throughput(
            &format!("session classify_batch(8) f={filters}"), 8.0 * macc, "MACC/s",
            || {
                preds.clear();
                sess.classify_batch_into(&batch, &mut preds);
                black_box(&preds);
            },
        );
        println!("{}", r.report());
    }

    print_header("quantizer (PTQ over full graph, f=32)");
    let g = randomized_har(32);
    let stats = calibrated_stats(&g, 128 * 9);
    for (label, spec) in [
        ("int8 per-layer ", QuantSpec::int8_per_layer()),
        ("int8 per-filter", QuantSpec::int8_per_filter()),
        ("int16 per-layer", QuantSpec::int16_per_layer()),
    ] {
        let r = b.run(label, || {
            black_box(quantize(&g, &stats, spec));
        });
        println!("{}", r.report());
    }

    print_header("calibration pass (float forward with stats, f=32)");
    let x: Vec<f32> = (0..128 * 9).map(|_| rng.normal()).collect();
    let r = b.run("calibrate 1 example", || {
        let mut s = ActStats::new(g.nodes.len());
        black_box(float_exec::run(&g, &x, Some(&mut s)));
    });
    println!("{}", r.report());

    print_header("allocator (§5.7 first-fit, f=80)");
    let g80 = randomized_har(80);
    let r = b.run("allocate ResNet", || {
        black_box(microai::allocator::allocate(&g80));
    });
    println!("{}", r.report());

    print_header("C code generation (f=16, int8)");
    let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
    let r = b.run("generate C library", || {
        black_box(microai::codegen::generate(&qg));
    });
    println!("{}", r.report());

    print_header("synthetic dataset generation");
    let r = b.run("har full dataset", || {
        black_box(microai::datasets::load("har", 1));
    });
    println!("{}", r.report());
}
