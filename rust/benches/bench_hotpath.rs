//! Hot-path benchmarks + the repo's recorded perf trajectory.
//!
//! Two jobs:
//! 1. **Kernel race** — conv/dense layer shapes of the three paper
//!    topologies (UCI-HAR, SMNIST, GTSRB; every distinct shape in full
//!    mode, the 3 largest per dataset in `--smoke` so the CI job stays
//!    under its minute budget) raced three ways per numeric flavor
//!    (f32 / int8-i32 lanes / int16-i64 / affine): the naive `*_ref`
//!    kernel, the PR-3/4 per-call-packing GEMM lowering, and the PR-5
//!    prepacked + fused-epilogue path (`prepack_ns`,
//!    `prepack_speedup = gemm_ns / prepack_ns`). With `--threads N > 1`
//!    the per-call GEMM is additionally raced at one thread, so the JSON
//!    records the parallel speedup per shape (`gemm_1t_ns`,
//!    `parallel_speedup`). Foldable shapes (dense, stride-1 1×1 conv)
//!    additionally race the PR-8 batch-folded path at batch 8 against 8
//!    looped per-example prepacked calls (`looped_ns`, `batched_ns`,
//!    `batched_speedup` — schema v4). Results land in machine-readable
//!    `BENCH_hotpath.json`; `--check` turns the per-shape speedups into a
//!    CI gate (fail when GEMM is slower than reference, the prepacked
//!    path slower than per-call GEMM, or the batch-folded path slower
//!    than the per-example loop, beyond measurement tolerance, or a
//!    regression vs the committed baseline — unless that baseline is
//!    still the schema placeholder, which is skipped loudly).
//! 2. **Whole-graph** — Session inference throughput per backend, plus the
//!    longstanding quantizer/calibration/allocator/codegen sections (full
//!    mode only).
//!
//! Since ISSUE 9 the JSON additionally carries a `ram_plan` section
//! (schema v5): per dataset topology (incl. the transformer), the
//! checker-verified coalesced-arena element count vs the §5.7 pooled
//! baseline it replaced — the Table-A6 RAM trajectory, measured by
//! analysis rather than a timer, so it is stable across runners.
//!
//! Since ISSUE 10 (schema v6) every prepacked arm is additionally raced
//! against the SAME prepacked path forced onto the scalar kernel set
//! (`scalar_kern_ns`, `simd_speedup = scalar_kern_ns / prepack_ns`) —
//! same panels, same epilogue, only the microkernel differs — so the
//! SIMD dispatch (`nn::simd`) pays for itself on every raced shape.
//! Rows carry `simd` (the kernel-set name the dispatched arm ran);
//! `--force-scalar` pins everything to the scalar set (the extra arm is
//! then skipped, since it would race scalar against itself). `--check`
//! gates `simd_speedup >= 1.0 - tolerance` on every row where a
//! non-scalar set was dispatched.
//!
//! Run: `cargo bench --bench bench_hotpath`
//! CI:  `cargo bench --bench bench_hotpath -- --smoke --check --threads 4 --out BENCH_hotpath.json`

use std::collections::BTreeSet;

use microai::graph::ir::{LayerKind, Padding};
use microai::graph::{deploy_pipeline, resnet_v1_6_shapes, Graph};
use microai::mcu::node_gemm_shape;
use microai::nn::float_exec::{self, ActStats};
use microai::nn::packed::{self, PackedNode};
use microai::nn::simd;
use microai::nn::{
    affine_exec, float_ops, gemm, int_exec, int_ops, Batch, IntraOpPool, SessionBuilder,
};
use microai::quant::affine::AffineQuantizedGraph;
use microai::quant::{quantize, quantize_affine, QuantSpec, QuantizedGraph};
use microai::util::bench::{black_box, print_header, Bencher};
use microai::util::json::Json;
use microai::util::prng::Pcg32;

/// Measurement-noise deadband for the `--check` gate: a tie (hybrid
/// small-shape fallback runs the identical reference code) must not flap
/// CI, while a real regression (ratios well under 1.0) still fails.
const CHECK_TOLERANCE: f64 = 0.05;
/// Per-shape regression tolerance against the committed baseline's
/// recorded speedups (the ratio is machine-relative, so it travels better
/// than raw nanoseconds; shared CI runners are still noisy, hence the
/// generous band).
const BASELINE_REGRESSION_TOLERANCE: f64 = 0.25;
/// Micro-batch size for the PR-8 batch-folded race: one batched call vs
/// this many looped per-example prepacked calls on every foldable shape.
const FOLD_BATCH: usize = 8;

struct RaceRow {
    model: String,
    layer: String,
    kind: &'static str,
    backend: &'static str,
    threads: usize,
    m: u64,
    n: u64,
    k: u64,
    ref_ns: f64,
    gemm_ns: f64,
    /// PR-5 prepacked + fused-epilogue path at the same thread budget.
    prepack_ns: f64,
    /// Single-thread GEMM median, measured only when `threads > 1`.
    gemm_1t_ns: Option<f64>,
    /// `FOLD_BATCH` looped per-example prepacked calls; measured only on
    /// foldable shapes (dense, stride-1 1×1 conv).
    looped_ns: Option<f64>,
    /// ONE batch-folded call over the same `FOLD_BATCH` examples.
    batched_ns: Option<f64>,
    /// Kernel-set name the dispatched prepacked arm ran ("scalar",
    /// "avx2", "avx2+fma").
    simd: &'static str,
    /// The SAME prepacked path forced onto the scalar kernel set (same
    /// panels and epilogue, scalar microkernel); measured only when a
    /// non-scalar set was dispatched.
    scalar_kern_ns: Option<f64>,
}

impl RaceRow {
    fn speedup(&self) -> f64 {
        self.ref_ns / self.gemm_ns.max(1.0)
    }

    /// Prepacked path vs the PR-4 per-call-packing GEMM (the ISSUE 5
    /// gate: must stay ≥ 1.0 minus the noise deadband on every gated
    /// shape).
    fn prepack_speedup(&self) -> f64 {
        self.gemm_ns / self.prepack_ns.max(1.0)
    }

    /// Whether the prepack gate applies to this shape: below
    /// `GEMM_MIN_MACCS` the per-call arm falls back to the naive
    /// reference (blocked packing cannot amortize there, by design), so
    /// `prepack_speedup` compares packed-vs-REF with no tie-by-
    /// construction — measured and reported, but not gated. Every
    /// smoke-raced shape (3 largest per dataset) is far above the
    /// floor, so the CI gate still covers all raced shapes.
    fn prepack_gated(&self) -> bool {
        self.m * self.n * self.k >= gemm::GEMM_MIN_MACCS as u64
    }

    /// threads=N GEMM vs the same GEMM at one thread (None at threads=1).
    fn parallel_speedup(&self) -> Option<f64> {
        self.gemm_1t_ns.map(|one| one / self.gemm_ns.max(1.0))
    }

    /// PR-8 gate: one batch-folded call vs the per-example loop at
    /// `FOLD_BATCH` (None on unfoldable shapes). Must stay ≥ 1.0 minus
    /// the noise deadband on every foldable shape.
    fn batched_speedup(&self) -> Option<f64> {
        match (self.looped_ns, self.batched_ns) {
            (Some(lo), Some(ba)) => Some(lo / ba.max(1.0)),
            _ => None,
        }
    }

    /// ISSUE 10 gate: the dispatched microkernel vs the scalar set on the
    /// same prepacked panels (None when scalar was dispatched — nothing
    /// to race). Must stay ≥ 1.0 minus the noise deadband on every row
    /// where a non-scalar set ran.
    fn simd_speedup(&self) -> Option<f64> {
        self.scalar_kern_ns.map(|sc| sc / self.prepack_ns.max(1.0))
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("model", Json::str(&self.model)),
            ("layer", Json::str(&self.layer)),
            ("kind", Json::str(self.kind)),
            ("backend", Json::str(self.backend)),
            ("threads", Json::num(self.threads as f64)),
            ("m", Json::num(self.m as f64)),
            ("n", Json::num(self.n as f64)),
            ("k", Json::num(self.k as f64)),
            ("ref_ns", Json::num(self.ref_ns)),
            ("gemm_ns", Json::num(self.gemm_ns)),
            ("speedup", Json::num(self.speedup())),
            ("prepack_ns", Json::num(self.prepack_ns)),
            ("prepack_speedup", Json::num(self.prepack_speedup())),
            ("prepack_gated", Json::Bool(self.prepack_gated())),
            ("simd", Json::str(self.simd)),
        ];
        if let (Some(sc), Some(s)) = (self.scalar_kern_ns, self.simd_speedup()) {
            pairs.push(("scalar_kern_ns", Json::num(sc)));
            pairs.push(("simd_speedup", Json::num(s)));
        }
        if let (Some(one), Some(par)) = (self.gemm_1t_ns, self.parallel_speedup()) {
            pairs.push(("gemm_1t_ns", Json::num(one)));
            pairs.push(("parallel_speedup", Json::num(par)));
        }
        if let (Some(lo), Some(ba), Some(s)) =
            (self.looped_ns, self.batched_ns, self.batched_speedup())
        {
            pairs.push(("looped_ns", Json::num(lo)));
            pairs.push(("batched_ns", Json::num(ba)));
            pairs.push(("batched_speedup", Json::num(s)));
        }
        Json::obj(pairs)
    }
}

/// Shared measurement context for the kernel race.
struct RaceCtx<'a> {
    b: &'a Bencher,
    pool: &'a IntraOpPool,
    serial: &'a IntraOpPool,
    threads: usize,
    /// Kernel-set name the dispatched arms run: `simd::detected()`, or
    /// "scalar" under `--force-scalar`.
    simd: &'static str,
}

impl RaceCtx<'_> {
    /// Retarget a freshly built node under `--force-scalar` (constructors
    /// default to the detected set).
    fn tune(&self, pn: PackedNode) -> PackedNode {
        if self.simd == "scalar" {
            pn.with_kernels(simd::scalar())
        } else {
            pn
        }
    }

    /// Whether the extra scalar-kernel arm is worth racing: skipped when
    /// scalar is what the dispatched arm already runs.
    fn simd_raced(&self) -> bool {
        self.simd != "scalar"
    }
}

/// Clone of a packed attention block with all four projection kernels
/// retargeted to the scalar set (the per-head score GEMMs inside the
/// attention body are per-call `gemm_i64` and unaffected by dispatch).
fn scalarized_attention(pa: &packed::PackedAttention) -> packed::PackedAttention {
    let mut p = pa.clone();
    for pn in [&mut p.wq, &mut p.wk, &mut p.wv, &mut p.wo] {
        *pn = pn.clone().with_kernels(simd::scalar());
    }
    p
}

fn randomized(mut g: Graph, seed: u64) -> Graph {
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.3;
            }
            for v in b.data.iter_mut() {
                *v = rng.normal() * 0.02;
            }
        }
    }
    deploy_pipeline(&g)
}

fn calibrated_stats(g: &Graph, ex_len: usize) -> ActStats {
    let mut stats = ActStats::new(g.nodes.len());
    let mut rng = Pcg32::seeded(2);
    for _ in 0..4 {
        let x: Vec<f32> = (0..ex_len).map(|_| rng.normal()).collect();
        float_exec::run(g, &x, Some(&mut stats));
    }
    stats
}

fn rand_payloads(rng: &mut Pcg32, len: usize, width: u32) -> Vec<i32> {
    let lim = (1i32 << (width - 1)) - 1;
    (0..len).map(|_| rng.below((2 * lim) as u32) as i32 - lim).collect()
}

/// Batch-folded race on one foldable integer node (dense or stride-1 1×1
/// conv): `FOLD_BATCH` looped per-example prepacked calls vs ONE batched
/// call over the same examples. `conv_ish` is `Some((input_shape,
/// padding))` for the conv form, `None` for dense. Returns
/// (looped_ns, batched_ns).
#[allow(clippy::too_many_arguments)]
fn race_fold_int(
    ctx: &RaceCtx,
    tag: &str,
    model: &str,
    node_name: &str,
    pn: &PackedNode,
    conv_ish: Option<(&[usize], Padding)>,
    dims: usize,
    width: u32,
    rng: &mut Pcg32,
    scratch: &mut [Vec<i32>],
    out: &mut Vec<i32>,
) -> (f64, f64) {
    match conv_ish {
        None => {
            let taps = pn.taps;
            let xb = rand_payloads(rng, FOLD_BATCH * taps, width);
            let lo = ctx
                .b
                .run(&format!("{tag:<5} loop {model}/{node_name}"), || {
                    for ex in 0..FOLD_BATCH {
                        black_box(packed::dense_int_packed(
                            &xb[ex * taps..(ex + 1) * taps], pn, ctx.pool, out,
                        ));
                    }
                })
                .median_ns;
            let ba = ctx
                .b
                .run(&format!("{tag:<5} bat8 {model}/{node_name}"), || {
                    black_box(packed::dense_int_batched(&xb, FOLD_BATCH, pn, ctx.pool, out));
                })
                .median_ns;
            (lo, ba)
        }
        Some((ish, padding)) => {
            let el: usize = ish.iter().product();
            let xb = rand_payloads(rng, FOLD_BATCH * el, width);
            if dims == 1 {
                let s = ish[0];
                let lo = ctx
                    .b
                    .run(&format!("{tag:<5} loop {model}/{node_name}"), || {
                        for ex in 0..FOLD_BATCH {
                            black_box(packed::conv1d_int_packed(
                                &xb[ex * el..(ex + 1) * el], s, pn, 1, padding, ctx.pool,
                                scratch, out,
                            ));
                        }
                    })
                    .median_ns;
                let ba = ctx
                    .b
                    .run(&format!("{tag:<5} bat8 {model}/{node_name}"), || {
                        black_box(packed::conv1d_int_packed(
                            &xb, FOLD_BATCH * s, pn, 1, padding, ctx.pool, scratch, out,
                        ));
                    })
                    .median_ns;
                (lo, ba)
            } else {
                let (h, wd) = (ish[0], ish[1]);
                let lo = ctx
                    .b
                    .run(&format!("{tag:<5} loop {model}/{node_name}"), || {
                        for ex in 0..FOLD_BATCH {
                            black_box(packed::conv2d_int_packed(
                                &xb[ex * el..(ex + 1) * el], h, wd, pn, 1, padding, ctx.pool,
                                scratch, out,
                            ));
                        }
                    })
                    .median_ns;
                let ba = ctx
                    .b
                    .run(&format!("{tag:<5} bat8 {model}/{node_name}"), || {
                        black_box(packed::conv2d_int_packed(
                            &xb, FOLD_BATCH * h, wd, pn, 1, padding, ctx.pool, scratch, out,
                        ));
                    })
                    .median_ns;
                (lo, ba)
            }
        }
    }
}

/// Float twin of [`race_fold_int`].
#[allow(clippy::too_many_arguments)]
fn race_fold_f32(
    ctx: &RaceCtx,
    model: &str,
    node_name: &str,
    pn: &PackedNode,
    conv_ish: Option<(&[usize], Padding)>,
    dims: usize,
    rng: &mut Pcg32,
    scratch: &mut [Vec<f32>],
    out: &mut Vec<f32>,
) -> (f64, f64) {
    match conv_ish {
        None => {
            let taps = pn.taps;
            let xb: Vec<f32> = (0..FOLD_BATCH * taps).map(|_| rng.normal()).collect();
            let lo = ctx
                .b
                .run(&format!("f32   loop {model}/{node_name}"), || {
                    for ex in 0..FOLD_BATCH {
                        black_box(packed::dense_f32_packed(
                            &xb[ex * taps..(ex + 1) * taps], pn, ctx.pool, out,
                        ));
                    }
                })
                .median_ns;
            let ba = ctx
                .b
                .run(&format!("f32   bat8 {model}/{node_name}"), || {
                    black_box(packed::dense_f32_batched(&xb, FOLD_BATCH, pn, ctx.pool, out));
                })
                .median_ns;
            (lo, ba)
        }
        Some((ish, padding)) => {
            let el: usize = ish.iter().product();
            let xb: Vec<f32> = (0..FOLD_BATCH * el).map(|_| rng.normal()).collect();
            if dims == 1 {
                let s = ish[0];
                let lo = ctx
                    .b
                    .run(&format!("f32   loop {model}/{node_name}"), || {
                        for ex in 0..FOLD_BATCH {
                            black_box(packed::conv1d_f32_packed(
                                &xb[ex * el..(ex + 1) * el], s, pn, 1, padding, ctx.pool,
                                scratch, out,
                            ));
                        }
                    })
                    .median_ns;
                let ba = ctx
                    .b
                    .run(&format!("f32   bat8 {model}/{node_name}"), || {
                        black_box(packed::conv1d_f32_packed(
                            &xb, FOLD_BATCH * s, pn, 1, padding, ctx.pool, scratch, out,
                        ));
                    })
                    .median_ns;
                (lo, ba)
            } else {
                let (h, wd) = (ish[0], ish[1]);
                let lo = ctx
                    .b
                    .run(&format!("f32   loop {model}/{node_name}"), || {
                        for ex in 0..FOLD_BATCH {
                            black_box(packed::conv2d_f32_packed(
                                &xb[ex * el..(ex + 1) * el], h, wd, pn, 1, padding, ctx.pool,
                                scratch, out,
                            ));
                        }
                    })
                    .median_ns;
                let ba = ctx
                    .b
                    .run(&format!("f32   bat8 {model}/{node_name}"), || {
                        black_box(packed::conv2d_f32_packed(
                            &xb, FOLD_BATCH * h, wd, pn, 1, padding, ctx.pool, scratch, out,
                        ));
                    })
                    .median_ns;
                (lo, ba)
            }
        }
    }
}

/// Race one fixed-point conv/dense node: `*_q_ref` vs GEMM lowering (at
/// the context's thread budget, plus a 1-thread arm when threads > 1).
#[allow(clippy::too_many_arguments)]
fn race_qmn(
    ctx: &RaceCtx,
    model: &str,
    node_name: &str,
    qg: &QuantizedGraph,
    id: usize,
    backend: &'static str,
    rows: &mut Vec<RaceRow>,
    rng: &mut Pcg32,
) {
    let g = &qg.graph;
    let node = &g.nodes[id];
    let qw = &qg.weights[&id];
    let width = qg.width;
    let gs = node_gemm_shape(g, id).unwrap();
    let relu = node.fused_relu;
    let mut out = Vec::new();
    let mut scratch = vec![Vec::new(); ctx.threads.max(1)];
    let (kind, r_ref, gemm_ns, prepack_ns, gemm_1t_ns, fold, sc) = match &node.kind {
        LayerKind::Conv { w, stride, padding, .. } => {
            let ish = &g.nodes[node.inputs[0]].out_shape;
            let x = rand_payloads(rng, ish.iter().product(), width);
            if g.dims == 1 {
                let (s, c, k, f) = (ish[0], ish[1], w.shape[0], w.shape[2]);
                let r_ref = ctx.b.run(&format!("{backend:<5} ref  {model}/{node_name}"), || {
                    black_box(int_ops::conv1d_q_ref(
                        &x, s, c, qw, k, f, *stride, *padding, relu, width, &mut out,
                    ));
                });
                let mut arm = |pool: &IntraOpPool, label: String| {
                    ctx.b
                        .run(&label, || {
                            black_box(gemm::conv1d_q_gemm(
                                &x, s, c, qw, k, f, *stride, *padding, relu, width, pool,
                                &mut scratch, &mut out,
                            ));
                        })
                        .median_ns
                };
                let par = arm(ctx.pool, format!("{backend:<5} gemm {model}/{node_name}"));
                let one = (ctx.threads > 1)
                    .then(|| arm(ctx.serial, format!("{backend:<5} g@1t {model}/{node_name}")));
                let pn = ctx.tune(PackedNode::fixed_node(qw, &[k], k * c, f, width, relu));
                let mut parm = |pn: &PackedNode, label: String| {
                    ctx.b
                        .run(&label, || {
                            black_box(packed::conv1d_int_packed(
                                &x, s, pn, *stride, *padding, ctx.pool, &mut scratch, &mut out,
                            ));
                        })
                        .median_ns
                };
                let pre = parm(&pn, format!("{backend:<5} pack {model}/{node_name}"));
                let sc = ctx.simd_raced().then(|| {
                    parm(
                        &pn.clone().with_kernels(simd::scalar()),
                        format!("{backend:<5} sclr {model}/{node_name}"),
                    )
                });
                let fold = (k == 1 && *stride == 1).then(|| {
                    race_fold_int(
                        ctx, backend, model, node_name, &pn, Some((ish, *padding)), 1, width,
                        rng, &mut scratch, &mut out,
                    )
                });
                ("conv1d", r_ref, par, pre, one, fold, sc)
            } else {
                let (h, wd, c) = (ish[0], ish[1], ish[2]);
                let (kh, kw, f) = (w.shape[0], w.shape[1], w.shape[3]);
                let r_ref = ctx.b.run(&format!("{backend:<5} ref  {model}/{node_name}"), || {
                    black_box(int_ops::conv2d_q_ref(
                        &x, h, wd, c, qw, kh, kw, f, *stride, *padding, relu, width, &mut out,
                    ));
                });
                let mut arm = |pool: &IntraOpPool, label: String| {
                    ctx.b
                        .run(&label, || {
                            black_box(gemm::conv2d_q_gemm(
                                &x, h, wd, c, qw, kh, kw, f, *stride, *padding, relu, width,
                                pool, &mut scratch, &mut out,
                            ));
                        })
                        .median_ns
                };
                let par = arm(ctx.pool, format!("{backend:<5} gemm {model}/{node_name}"));
                let one = (ctx.threads > 1)
                    .then(|| arm(ctx.serial, format!("{backend:<5} g@1t {model}/{node_name}")));
                let pn =
                    ctx.tune(PackedNode::fixed_node(qw, &[kh, kw], kh * kw * c, f, width, relu));
                let mut parm = |pn: &PackedNode, label: String| {
                    ctx.b
                        .run(&label, || {
                            black_box(packed::conv2d_int_packed(
                                &x, h, wd, pn, *stride, *padding, ctx.pool, &mut scratch,
                                &mut out,
                            ));
                        })
                        .median_ns
                };
                let pre = parm(&pn, format!("{backend:<5} pack {model}/{node_name}"));
                let sc = ctx.simd_raced().then(|| {
                    parm(
                        &pn.clone().with_kernels(simd::scalar()),
                        format!("{backend:<5} sclr {model}/{node_name}"),
                    )
                });
                let fold = (kh == 1 && kw == 1 && *stride == 1).then(|| {
                    race_fold_int(
                        ctx, backend, model, node_name, &pn, Some((ish, *padding)), 2, width,
                        rng, &mut scratch, &mut out,
                    )
                });
                ("conv2d", r_ref, par, pre, one, fold, sc)
            }
        }
        LayerKind::Dense { w, .. } => {
            let x = rand_payloads(rng, w.shape[0], width);
            let o = w.shape[1];
            let r_ref = ctx.b.run(&format!("{backend:<5} ref  {model}/{node_name}"), || {
                black_box(int_ops::dense_q_ref(&x, qw, o, relu, width, &mut out));
            });
            let mut arm = |pool: &IntraOpPool, label: String| {
                ctx.b
                    .run(&label, || {
                        black_box(gemm::dense_q_gemm(&x, qw, o, relu, width, pool, &mut out));
                    })
                    .median_ns
            };
            let par = arm(ctx.pool, format!("{backend:<5} gemm {model}/{node_name}"));
            let one = (ctx.threads > 1)
                .then(|| arm(ctx.serial, format!("{backend:<5} g@1t {model}/{node_name}")));
            let pn = ctx.tune(PackedNode::fixed_node(qw, &[], w.shape[0], o, width, relu));
            let mut parm = |pn: &PackedNode, label: String| {
                ctx.b
                    .run(&label, || {
                        black_box(packed::dense_int_packed(&x, pn, ctx.pool, &mut out));
                    })
                    .median_ns
            };
            let pre = parm(&pn, format!("{backend:<5} pack {model}/{node_name}"));
            let sc = ctx.simd_raced().then(|| {
                parm(
                    &pn.clone().with_kernels(simd::scalar()),
                    format!("{backend:<5} sclr {model}/{node_name}"),
                )
            });
            let fold = Some(race_fold_int(
                ctx, backend, model, node_name, &pn, None, g.dims, width, rng, &mut scratch,
                &mut out,
            ));
            ("dense", r_ref, par, pre, one, fold, sc)
        }
        _ => return,
    };
    rows.push(RaceRow {
        model: model.to_string(),
        layer: node_name.to_string(),
        kind,
        backend,
        threads: ctx.threads,
        m: gs.m,
        n: gs.n,
        k: gs.k,
        ref_ns: r_ref.median_ns,
        gemm_ns,
        prepack_ns,
        gemm_1t_ns,
        looped_ns: fold.map(|f| f.0),
        batched_ns: fold.map(|f| f.1),
        simd: ctx.simd,
        scalar_kern_ns: sc,
    });
}

/// Race one float conv/dense node.
fn race_f32(
    ctx: &RaceCtx,
    model: &str,
    node_name: &str,
    g: &Graph,
    id: usize,
    rows: &mut Vec<RaceRow>,
    rng: &mut Pcg32,
) {
    let node = &g.nodes[id];
    let gs = node_gemm_shape(g, id).unwrap();
    let relu = node.fused_relu;
    let mut out = Vec::new();
    let mut scratch = vec![Vec::new(); ctx.threads.max(1)];
    let (kind, r_ref, gemm_ns, prepack_ns, gemm_1t_ns, fold, sc) = match &node.kind {
        LayerKind::Conv { w, b: wb, stride, padding } => {
            let ish = &g.nodes[node.inputs[0]].out_shape;
            let x: Vec<f32> =
                (0..ish.iter().product::<usize>()).map(|_| rng.normal()).collect();
            if g.dims == 1 {
                let (s, c, k, f) = (ish[0], ish[1], w.shape[0], w.shape[2]);
                let r_ref = ctx.b.run(&format!("f32   ref  {model}/{node_name}"), || {
                    black_box(float_ops::conv1d_ref(
                        &x, s, c, &w.data, k, f, &wb.data, *stride, *padding, relu, &mut out,
                    ));
                });
                let mut arm = |pool: &IntraOpPool, label: String| {
                    ctx.b
                        .run(&label, || {
                            black_box(gemm::conv1d_gemm(
                                &x, s, c, &w.data, k, f, &wb.data, *stride, *padding, relu,
                                pool, &mut scratch, &mut out,
                            ));
                        })
                        .median_ns
                };
                let par = arm(ctx.pool, format!("f32   gemm {model}/{node_name}"));
                let one = (ctx.threads > 1)
                    .then(|| arm(ctx.serial, format!("f32   g@1t {model}/{node_name}")));
                let pn = ctx.tune(PackedNode::f32_node(&w.data, &wb.data, &[k], k * c, f, relu));
                let mut parm = |pn: &PackedNode, label: String| {
                    ctx.b
                        .run(&label, || {
                            black_box(packed::conv1d_f32_packed(
                                &x, s, pn, *stride, *padding, ctx.pool, &mut scratch, &mut out,
                            ));
                        })
                        .median_ns
                };
                let pre = parm(&pn, format!("f32   pack {model}/{node_name}"));
                let sc = ctx.simd_raced().then(|| {
                    parm(
                        &pn.clone().with_kernels(simd::scalar()),
                        format!("f32   sclr {model}/{node_name}"),
                    )
                });
                let fold = (k == 1 && *stride == 1).then(|| {
                    race_fold_f32(
                        ctx, model, node_name, &pn, Some((ish, *padding)), 1, rng,
                        &mut scratch, &mut out,
                    )
                });
                ("conv1d", r_ref, par, pre, one, fold, sc)
            } else {
                let (h, wd, c) = (ish[0], ish[1], ish[2]);
                let (kh, kw, f) = (w.shape[0], w.shape[1], w.shape[3]);
                let r_ref = ctx.b.run(&format!("f32   ref  {model}/{node_name}"), || {
                    black_box(float_ops::conv2d_ref(
                        &x, h, wd, c, &w.data, kh, kw, f, &wb.data, *stride, *padding, relu,
                        &mut out,
                    ));
                });
                let mut arm = |pool: &IntraOpPool, label: String| {
                    ctx.b
                        .run(&label, || {
                            black_box(gemm::conv2d_gemm(
                                &x, h, wd, c, &w.data, kh, kw, f, &wb.data, *stride, *padding,
                                relu, pool, &mut scratch, &mut out,
                            ));
                        })
                        .median_ns
                };
                let par = arm(ctx.pool, format!("f32   gemm {model}/{node_name}"));
                let one = (ctx.threads > 1)
                    .then(|| arm(ctx.serial, format!("f32   g@1t {model}/{node_name}")));
                let pn = ctx.tune(PackedNode::f32_node(
                    &w.data, &wb.data, &[kh, kw], kh * kw * c, f, relu,
                ));
                let mut parm = |pn: &PackedNode, label: String| {
                    ctx.b
                        .run(&label, || {
                            black_box(packed::conv2d_f32_packed(
                                &x, h, wd, pn, *stride, *padding, ctx.pool, &mut scratch,
                                &mut out,
                            ));
                        })
                        .median_ns
                };
                let pre = parm(&pn, format!("f32   pack {model}/{node_name}"));
                let sc = ctx.simd_raced().then(|| {
                    parm(
                        &pn.clone().with_kernels(simd::scalar()),
                        format!("f32   sclr {model}/{node_name}"),
                    )
                });
                let fold = (kh == 1 && kw == 1 && *stride == 1).then(|| {
                    race_fold_f32(
                        ctx, model, node_name, &pn, Some((ish, *padding)), 2, rng,
                        &mut scratch, &mut out,
                    )
                });
                ("conv2d", r_ref, par, pre, one, fold, sc)
            }
        }
        LayerKind::Dense { w, b: wb } => {
            let x: Vec<f32> = (0..w.shape[0]).map(|_| rng.normal()).collect();
            let o = w.shape[1];
            let r_ref = ctx.b.run(&format!("f32   ref  {model}/{node_name}"), || {
                black_box(float_ops::dense_ref(&x, &w.data, &wb.data, o, relu, &mut out));
            });
            let mut arm = |pool: &IntraOpPool, label: String| {
                ctx.b
                    .run(&label, || {
                        black_box(gemm::dense_gemm(&x, &w.data, &wb.data, o, relu, pool, &mut out));
                    })
                    .median_ns
            };
            let par = arm(ctx.pool, format!("f32   gemm {model}/{node_name}"));
            let one = (ctx.threads > 1)
                .then(|| arm(ctx.serial, format!("f32   g@1t {model}/{node_name}")));
            let pn = ctx.tune(PackedNode::f32_node(&w.data, &wb.data, &[], w.shape[0], o, relu));
            let mut parm = |pn: &PackedNode, label: String| {
                ctx.b
                    .run(&label, || {
                        black_box(packed::dense_f32_packed(&x, pn, ctx.pool, &mut out));
                    })
                    .median_ns
            };
            let pre = parm(&pn, format!("f32   pack {model}/{node_name}"));
            let sc = ctx.simd_raced().then(|| {
                parm(
                    &pn.clone().with_kernels(simd::scalar()),
                    format!("f32   sclr {model}/{node_name}"),
                )
            });
            let fold = Some(race_fold_f32(
                ctx, model, node_name, &pn, None, g.dims, rng, &mut scratch, &mut out,
            ));
            ("dense", r_ref, par, pre, one, fold, sc)
        }
        _ => return,
    };
    rows.push(RaceRow {
        model: model.to_string(),
        layer: node_name.to_string(),
        kind,
        backend: "f32",
        threads: ctx.threads,
        m: gs.m,
        n: gs.n,
        k: gs.k,
        ref_ns: r_ref.median_ns,
        gemm_ns,
        prepack_ns,
        gemm_1t_ns,
        looped_ns: fold.map(|f| f.0),
        batched_ns: fold.map(|f| f.1),
        simd: ctx.simd,
        scalar_kern_ns: sc,
    });
}

/// Race one affine conv/dense node.
fn race_affine(
    ctx: &RaceCtx,
    model: &str,
    node_name: &str,
    aq: &AffineQuantizedGraph,
    id: usize,
    rows: &mut Vec<RaceRow>,
    rng: &mut Pcg32,
) {
    let g = &aq.graph;
    let node = &g.nodes[id];
    let qw = &aq.weights[&id];
    let gs = node_gemm_shape(g, id).unwrap();
    let relu = node.fused_relu;
    let src_id = node.inputs[0];
    let (zp_in, zp_out) = (aq.act[src_id].zero_point, aq.act[id].zero_point);
    let mut out = Vec::new();
    let mut scratch = vec![Vec::new(); ctx.threads.max(1)];
    let (kind, r_ref, gemm_ns, prepack_ns, gemm_1t_ns, fold, sc) = match &node.kind {
        LayerKind::Conv { w, stride, padding, .. } => {
            let ish = &g.nodes[src_id].out_shape;
            let x = rand_payloads(rng, ish.iter().product(), 8);
            let r_ref = ctx.b.run(&format!("affin ref  {model}/{node_name}"), || {
                affine_exec::conv_affine_ref(
                    &x, ish, &w.shape, qw, zp_in, zp_out, *stride, *padding, relu, g.dims,
                    &mut out,
                );
                black_box(&out);
            });
            let mut arm = |pool: &IntraOpPool, label: String| {
                ctx.b
                    .run(&label, || {
                        gemm::conv_affine_gemm(
                            &x, ish, &w.shape, qw, zp_in, zp_out, *stride, *padding, relu,
                            g.dims, pool, &mut scratch, &mut out,
                        );
                        black_box(&out);
                    })
                    .median_ns
            };
            let par = arm(ctx.pool, format!("affin gemm {model}/{node_name}"));
            let one = (ctx.threads > 1)
                .then(|| arm(ctx.serial, format!("affin g@1t {model}/{node_name}")));
            let taps: usize = w.shape[..w.shape.len() - 1].iter().product();
            let f = *w.shape.last().unwrap();
            let pn = ctx.tune(PackedNode::affine_node(
                qw, &w.shape[..w.shape.len() - 2], taps, f, zp_in, zp_out, relu,
            ));
            let mut parm = |pn: &PackedNode, label: String| {
                ctx.b
                    .run(&label, || {
                        if g.dims == 1 {
                            packed::conv1d_int_packed(
                                &x, ish[0], pn, *stride, *padding, ctx.pool, &mut scratch,
                                &mut out,
                            );
                        } else {
                            packed::conv2d_int_packed(
                                &x, ish[0], ish[1], pn, *stride, *padding, ctx.pool,
                                &mut scratch, &mut out,
                            );
                        }
                        black_box(&out);
                    })
                    .median_ns
            };
            let pre = parm(&pn, format!("affin pack {model}/{node_name}"));
            let sc = ctx.simd_raced().then(|| {
                parm(
                    &pn.clone().with_kernels(simd::scalar()),
                    format!("affin sclr {model}/{node_name}"),
                )
            });
            let fold = (*stride == 1 && pn.ks.iter().all(|&k| k == 1)).then(|| {
                race_fold_int(
                    ctx, "affin", model, node_name, &pn, Some((ish, *padding)), g.dims, 8,
                    rng, &mut scratch, &mut out,
                )
            });
            (if g.dims == 1 { "conv1d" } else { "conv2d" }, r_ref, par, pre, one, fold, sc)
        }
        LayerKind::Dense { w, .. } => {
            let x = rand_payloads(rng, w.shape[0], 8);
            let o = w.shape[1];
            let r_ref = ctx.b.run(&format!("affin ref  {model}/{node_name}"), || {
                affine_exec::dense_affine_ref(&x, qw, zp_in, zp_out, o, relu, &mut out);
                black_box(&out);
            });
            let mut arm = |pool: &IntraOpPool, label: String| {
                ctx.b
                    .run(&label, || {
                        gemm::dense_affine_gemm(
                            &x, qw, zp_in, zp_out, o, relu, pool, &mut scratch, &mut out,
                        );
                        black_box(&out);
                    })
                    .median_ns
            };
            let par = arm(ctx.pool, format!("affin gemm {model}/{node_name}"));
            let one = (ctx.threads > 1)
                .then(|| arm(ctx.serial, format!("affin g@1t {model}/{node_name}")));
            let pn =
                ctx.tune(PackedNode::affine_node(qw, &[], w.shape[0], o, zp_in, zp_out, relu));
            let mut parm = |pn: &PackedNode, label: String| {
                ctx.b
                    .run(&label, || {
                        packed::dense_int_packed(&x, pn, ctx.pool, &mut out);
                        black_box(&out);
                    })
                    .median_ns
            };
            let pre = parm(&pn, format!("affin pack {model}/{node_name}"));
            let sc = ctx.simd_raced().then(|| {
                parm(
                    &pn.clone().with_kernels(simd::scalar()),
                    format!("affin sclr {model}/{node_name}"),
                )
            });
            let fold = Some(race_fold_int(
                ctx, "affin", model, node_name, &pn, None, g.dims, 8, rng, &mut scratch,
                &mut out,
            ));
            ("dense", r_ref, par, pre, one, fold, sc)
        }
        _ => return,
    };
    rows.push(RaceRow {
        model: model.to_string(),
        layer: node_name.to_string(),
        kind,
        backend: "affine",
        threads: ctx.threads,
        m: gs.m,
        n: gs.n,
        k: gs.k,
        ref_ns: r_ref.median_ns,
        gemm_ns,
        prepack_ns,
        gemm_1t_ns,
        looped_ns: fold.map(|f| f.0),
        batched_ns: fold.map(|f| f.1),
        simd: ctx.simd,
        scalar_kern_ns: sc,
    });
}

/// Race the fused packed attention (two batched GEMMs + LUT softmax,
/// ISSUE 6) against the naive integer reference on the transformer GEMM
/// shapes. The packed path IS the prepacked arm here — there is no
/// per-call-packing middle path for attention — so `gemm_ns` and
/// `prepack_ns` both record it and the row rides the same
/// `speedup >= 1.0 - tolerance` gate as the conv/dense races.
fn race_attention(ctx: &RaceCtx, rows: &mut Vec<RaceRow>, rng: &mut Pcg32) {
    use microai::quant::ptq::{QNodeWeights, QTxWeights};
    // (seq, heads, head_dim) — square d_model projection GEMMs (m=seq,
    // n=k=d_model) plus the per-head seq×seq score GEMMs behind them.
    let shapes = [(64usize, 8usize, 8usize), (32, 4, 16), (48, 6, 8)];
    for &(seq, heads, hd) in &shapes {
        let dm = heads * hd;
        for width in [8u32, 16] {
            let backend: &'static str = if width == 8 { "int8" } else { "int16" };
            let proj = |rng: &mut Pcg32| QNodeWeights {
                w: rand_payloads(rng, dm * dm, width),
                w_n: vec![width as i32 - 1],
                b_acc: (0..dm).map(|_| rng.below(1 << 12) as i64 - (1 << 11)).collect(),
                shift: vec![width as i32 - 1],
            };
            let tx = QTxWeights::Attn {
                wq: proj(rng),
                wk: proj(rng),
                wv: proj(rng),
                wo: proj(rng),
                n_q: 6,
                n_k: 6,
                n_v: 6,
                n_s: 6,
                n_p: width as i32 - 1,
                n_ctx: 6,
                inv_sqrt_hd_q15: ((1 << 15) as f64 / (hd as f64).sqrt()).round() as i32,
            };
            let x = rand_payloads(rng, seq * dm, width);
            let mut out = Vec::new();
            let name = format!("attn_s{seq}h{heads}d{hd}");
            let r_ref = ctx.b.run(&format!("{backend:<5} ref  transformer/{name}"), || {
                black_box(int_ops::attention_q_ref(
                    &x, seq, dm, heads, hd, &tx, width, &mut out,
                ));
            });
            let mut pa = packed::PackedAttention::fixed(&tx, heads, hd, width);
            if ctx.simd == "scalar" {
                pa = scalarized_attention(&pa);
            }
            let mut scratch: Vec<Vec<i32>> = vec![Vec::new(); ctx.threads.max(1)];
            let mut arm = |pa: &packed::PackedAttention, pool: &IntraOpPool, label: String| {
                ctx.b
                    .run(&label, || {
                        black_box(packed::attention_int_packed(
                            &x, seq, dm, heads, hd, pa, pool, &mut scratch, &mut out,
                        ));
                    })
                    .median_ns
            };
            let par = arm(&pa, ctx.pool, format!("{backend:<5} pack transformer/{name}"));
            let one = (ctx.threads > 1).then(|| {
                arm(&pa, ctx.serial, format!("{backend:<5} p@1t transformer/{name}"))
            });
            let sc = ctx.simd_raced().then(|| {
                arm(
                    &scalarized_attention(&pa),
                    ctx.pool,
                    format!("{backend:<5} sclr transformer/{name}"),
                )
            });
            rows.push(RaceRow {
                model: "transformer".to_string(),
                layer: name,
                kind: "attention",
                backend,
                threads: ctx.threads,
                m: seq as u64,
                n: dm as u64,
                k: dm as u64,
                ref_ns: r_ref.median_ns,
                gemm_ns: par,
                prepack_ns: par,
                gemm_1t_ns: one,
                looped_ns: None,
                batched_ns: None,
                simd: ctx.simd,
                scalar_kern_ns: sc,
            });
        }
    }
}

/// Distinct-shape weighted nodes of a deployed graph (duplicate residual
/// block convs share one race).
fn distinct_weighted_nodes(g: &Graph) -> Vec<usize> {
    let mut seen = BTreeSet::new();
    let mut ids = Vec::new();
    for node in &g.nodes {
        let sig = match &node.kind {
            LayerKind::Conv { w, stride, padding, .. } => format!(
                "conv {:?} {:?} {stride} {padding:?} {} in {:?}",
                w.shape, node.out_shape, node.fused_relu, g.nodes[node.inputs[0]].out_shape
            ),
            LayerKind::Dense { w, .. } => {
                format!("dense {:?} {}", w.shape, node.fused_relu)
            }
            _ => continue,
        };
        if seen.insert(sig) {
            ids.push(node.id);
        }
    }
    ids
}

struct GraphRow {
    model: String,
    backend: String,
    ns_per_inference: f64,
    macc_per_s: f64,
}

/// True when the committed baseline is still the schema placeholder
/// (authored without a toolchain): no measured kernel_race samples.
fn baseline_is_placeholder(doc: &Json) -> bool {
    doc.get("mode").and_then(Json::as_str) == Some("baseline-pending")
        || doc.get("kernel_race").and_then(Json::as_arr).is_none_or(|a| a.is_empty())
}

/// Per-shape regressions of the measured rows against a REAL committed
/// baseline: compares recorded speedups (machine-relative) for rows
/// matched on (model, layer, kind, backend). A baseline row at the same
/// `threads` supplies its `speedup` directly; for a threads=1 run gated
/// against the canonical threads=4 baseline, the baseline's embedded
/// single-thread medians (`gemm_1t_ns`, with `ref_ns`) reconstruct the
/// 1-thread speedup — without this the t1 CI job would silently match
/// nothing and gate nothing. Emits a warning when a real baseline
/// matches zero shapes (schema drift), so a vacuous gate is visible.
fn baseline_regressions(rows: &[RaceRow], doc: &Json) -> Vec<String> {
    let mut bad = Vec::new();
    let Some(base_rows) = doc.get("kernel_race").and_then(Json::as_arr) else {
        return bad;
    };
    let mut matched_shapes = 0usize;
    for row in rows {
        let shape_rows = || {
            base_rows.iter().filter(|b| {
                b.get("model").and_then(Json::as_str) == Some(&row.model)
                    && b.get("layer").and_then(Json::as_str) == Some(&row.layer)
                    && b.get("kind").and_then(Json::as_str) == Some(row.kind)
                    && b.get("backend").and_then(Json::as_str) == Some(row.backend)
            })
        };
        // Exact thread-count match first; else reconstruct the 1-thread
        // speedup from a baseline row that embeds gemm_1t_ns.
        let base_speedup = shape_rows()
            .find(|b| b.get("threads").and_then(Json::as_usize).unwrap_or(1) == row.threads)
            .and_then(|b| b.get("speedup"))
            .and_then(Json::as_f64)
            .or_else(|| {
                (row.threads == 1)
                    .then(|| {
                        shape_rows().find_map(|b| {
                            let ref_ns = b.get("ref_ns").and_then(Json::as_f64)?;
                            let one = b.get("gemm_1t_ns").and_then(Json::as_f64)?;
                            Some(ref_ns / one.max(1.0))
                        })
                    })
                    .flatten()
            });
        if let Some(base_speedup) = base_speedup {
            matched_shapes += 1;
            let floor = base_speedup * (1.0 - BASELINE_REGRESSION_TOLERANCE);
            if row.speedup() < floor {
                bad.push(format!(
                    "{}/{} {} {} t={}: {:.2}x vs baseline {:.2}x (floor {:.2}x)",
                    row.model,
                    row.layer,
                    row.kind,
                    row.backend,
                    row.threads,
                    row.speedup(),
                    base_speedup,
                    floor
                ));
            }
        }
    }
    if matched_shapes == 0 && !rows.is_empty() {
        eprintln!(
            "bench_hotpath WARNING: real baseline matched 0 of {} measured shapes — the \
             baseline gate is vacuous this run (schema drift? threads mismatch without \
             embedded gemm_1t_ns?).",
            rows.len()
        );
    }
    bad
}

fn main() {
    let mut smoke = std::env::var("MICROAI_BENCH_SMOKE").is_ok();
    let mut check = false;
    let mut force_scalar = false;
    let mut threads = 1usize;
    let mut out_path = String::from("BENCH_hotpath.json");
    // Cargo runs bench binaries with CWD = the package root (rust/), but
    // the committed baseline lives at the REPO root — resolve the default
    // against the manifest dir so the gate arms without an explicit flag.
    let mut baseline_path = format!("{}/../BENCH_hotpath.json", env!("CARGO_MANIFEST_DIR"));
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--check" => check = true,
            "--force-scalar" => force_scalar = true,
            "--threads" => {
                threads = args
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--threads needs a positive integer");
            }
            "--out" => out_path = args.next().expect("--out needs a path"),
            "--baseline" => baseline_path = args.next().expect("--baseline needs a path"),
            "--bench" => {} // appended by `cargo bench`
            other => eprintln!("bench_hotpath: ignoring unknown arg {other}"),
        }
    }
    threads = threads.max(1);
    // Read the committed baseline BEFORE the run (the --out default
    // overwrites the same path).
    let baseline = std::fs::read_to_string(&baseline_path)
        .ok()
        .and_then(|t| Json::parse(&t).ok());
    // The race needs real medians even in CI: the smoke profile spends
    // 75 ms warmup + 300 ms measurement per arm (vs the serving bench's
    // 1-iteration smoke) so the --check ratio gate sees stable medians on
    // shared runners while the whole job — now four arms per shape ×
    // backend — stays inside the CI minute budget together with the
    // 3-largest-shapes smoke cap below. If a runner still proves noisy,
    // widen CHECK_TOLERANCE rather than disabling the gate.
    let b = if smoke {
        Bencher {
            warmup: std::time::Duration::from_millis(75),
            measure: std::time::Duration::from_millis(300),
            max_iters: 5_000,
        }
    } else {
        Bencher::default()
    };
    let pool = IntraOpPool::new(threads);
    let serial = IntraOpPool::serial();
    // `--force-scalar` pins every dispatched arm (and the Sessions below)
    // to the scalar kernel set — an A/B switch, not a different code path.
    let kern_name = if force_scalar { "scalar" } else { simd::detected().name };
    println!("gemm kernel set: {kern_name}");
    let ctx = RaceCtx { b: &b, pool: &pool, serial: &serial, threads, simd: kern_name };
    let mut rng = Pcg32::seeded(3);
    let mut race_rows: Vec<RaceRow> = Vec::new();
    let mut graph_rows: Vec<GraphRow> = Vec::new();

    let mut topologies: Vec<(&str, Graph, usize)> = vec![
        (
            "uci-har",
            randomized(resnet_v1_6_shapes("har", 1, &[128, 9], 6, 16), 1),
            128 * 9,
        ),
        (
            "smnist",
            randomized(resnet_v1_6_shapes("smnist", 1, &[39, 13], 10, 8), 2),
            39 * 13,
        ),
        (
            "gtsrb",
            randomized(resnet_v1_6_shapes("gtsrb", 2, &[32, 32, 3], 43, 8), 3),
            32 * 32 * 3,
        ),
    ];
    if !smoke {
        topologies.push((
            "uci-har-f80",
            randomized(resnet_v1_6_shapes("har80", 1, &[128, 9], 6, 80), 4),
            128 * 9,
        ));
    }

    for (model, g, ex_len) in &topologies {
        let model: &str = model;
        let ex_len: usize = *ex_len;
        print_header(&format!("kernel race GEMM vs *_ref — {model} (threads={threads})"));
        let stats = calibrated_stats(g, ex_len);
        let q8 = quantize(g, &stats, QuantSpec::int8_per_layer());
        let q16 = quantize(g, &stats, QuantSpec::int16_per_layer());
        let aq = quantize_affine(g, &stats);
        let mut ids = distinct_weighted_nodes(g);
        if smoke {
            // Smoke cap (ISSUE 5): racing EVERY distinct shape blew the
            // CI minute budget once the prepacked arm landed — keep only
            // the 3 largest shapes (by GEMM MACCs) per dataset. Known
            // coverage tradeoff: the tiny dense/shortcut shapes (where
            // sessions no longer take the reference fallback) are gated
            // only by FULL-mode runs, which still race everything — run
            // full mode when touching the packed kernels or epilogues.
            ids.sort_by_key(|&id| {
                std::cmp::Reverse(node_gemm_shape(g, id).map(|gs| gs.m * gs.n * gs.k).unwrap_or(0))
            });
            ids.truncate(3);
        }
        for id in ids {
            let name = g.nodes[id].name.clone();
            race_f32(&ctx, model, &name, g, id, &mut race_rows, &mut rng);
            race_qmn(&ctx, model, &name, &q8, id, "int8", &mut race_rows, &mut rng);
            race_qmn(&ctx, model, &name, &q16, id, "int16", &mut race_rows, &mut rng);
            race_affine(&ctx, model, &name, &aq, id, &mut race_rows, &mut rng);
        }
        for row in race_rows.iter().filter(|r| r.model == *model) {
            let par = row
                .parallel_speedup()
                .map(|p| format!("  par {p:>4.2}x"))
                .unwrap_or_default();
            let bat = row
                .batched_speedup()
                .map(|s| format!("  bat8 {s:>4.2}x"))
                .unwrap_or_default();
            let sd = row
                .simd_speedup()
                .map(|s| format!("  simd {s:>4.2}x"))
                .unwrap_or_default();
            println!(
                "{:<28} {:<6} {:<7} m={:<5} n={:<4} k={:<5} ref {:>10.0} ns  gemm {:>10.0} ns  \
                 {:>5.2}x  pack {:>10.0} ns  {:>4.2}x{par}{bat}{sd}",
                row.layer, row.kind, row.backend, row.m, row.n, row.k, row.ref_ns, row.gemm_ns,
                row.speedup(), row.prepack_ns, row.prepack_speedup()
            );
        }

        print_header(&format!("whole-graph Session inference — {model}"));
        let macc = microai::mcu::graph_ops(g).macc as f64;
        let x: Vec<f32> = (0..ex_len).map(|_| rng.normal()).collect();
        let mut record = |backend: &str, r: microai::util::bench::BenchResult| {
            println!("{}", r.report());
            graph_rows.push(GraphRow {
                model: model.to_string(),
                backend: backend.to_string(),
                ns_per_inference: r.median_ns,
                macc_per_s: r.throughput.map(|(v, _)| v).unwrap_or(0.0),
            });
        };
        let mut fsess = SessionBuilder::float32(g.clone())
            .threads(threads)
            .force_scalar_kernels(force_scalar)
            .build();
        let r = b.run_throughput(&format!("float32     {model}"), macc, "MACC/s", || {
            black_box(fsess.run(&x));
        });
        record("float32", r);
        let mut s8 = SessionBuilder::fixed_qmn(q8.clone())
            .threads(threads)
            .force_scalar_kernels(force_scalar)
            .build();
        let r = b.run_throughput(&format!("int8        {model}"), macc, "MACC/s", || {
            black_box(s8.run(&x));
        });
        record("int8", r);
        let mut s16 = SessionBuilder::fixed_qmn(q16.clone())
            .threads(threads)
            .force_scalar_kernels(force_scalar)
            .build();
        let r = b.run_throughput(&format!("int16       {model}"), macc, "MACC/s", || {
            black_box(s16.run(&x));
        });
        record("int16", r);
        let mut sa = SessionBuilder::affine_i8(aq.clone())
            .threads(threads)
            .force_scalar_kernels(force_scalar)
            .build();
        let r = b.run_throughput(&format!("affine-int8 {model}"), macc, "MACC/s", || {
            black_box(sa.run(&x));
        });
        record("affine-int8", r);
    }

    // ISSUE 6: transformer attention shapes under the same speedup gate.
    print_header(&format!("kernel race attention packed vs *_ref (threads={threads})"));
    race_attention(&ctx, &mut race_rows, &mut rng);
    for row in race_rows.iter().filter(|r| r.kind == "attention") {
        let par = row
            .parallel_speedup()
            .map(|p| format!("  par {p:>4.2}x"))
            .unwrap_or_default();
        let sd = row
            .simd_speedup()
            .map(|s| format!("  simd {s:>4.2}x"))
            .unwrap_or_default();
        println!(
            "{:<28} {:<6} {:<7} seq={:<4} dm={:<4} ref {:>10.0} ns  packed {:>10.0} ns  \
             {:>5.2}x{par}{sd}",
            row.layer, row.kind, row.backend, row.m, row.n, row.ref_ns, row.gemm_ns,
            row.speedup()
        );
    }

    if !smoke {
        legacy_sections(&b, &mut rng);
    }

    // Parallel-speedup headline: the largest GTSRB conv2d shape is the
    // ROADMAP's tracked scaling witness.
    if threads > 1 {
        if let Some(row) = race_rows
            .iter()
            .filter(|r| r.model == "gtsrb" && r.kind == "conv2d")
            .max_by_key(|r| r.m * r.n * r.k)
        {
            let par = row.parallel_speedup().unwrap_or(0.0);
            println!(
                "\nlargest GTSRB conv2d ({}x{}x{}, {}): {par:.2}x at threads={threads}",
                row.m, row.n, row.k, row.backend
            );
            if par < 1.5 {
                eprintln!(
                    "bench_hotpath WARNING: largest GTSRB conv2d parallel speedup {par:.2}x \
                     < 1.5x at threads={threads} (tracked, not gated — see ISSUE 4)"
                );
            }
        }
    }

    // --- machine-readable trajectory + CI gate ---
    let min_speedup = race_rows.iter().map(RaceRow::speedup).fold(f64::INFINITY, f64::min);
    let min_prepack = race_rows
        .iter()
        .filter(|r| r.prepack_gated())
        .map(RaceRow::prepack_speedup)
        .fold(f64::INFINITY, f64::min);
    let live_pass = race_rows.iter().all(|r| r.speedup() >= 1.0 - CHECK_TOLERANCE);
    // ISSUE 5 gate: the prepacked + fused-epilogue path must never lose
    // to the PR-4 per-call-packing path on any raced shape where that
    // path ran the blocked kernel (below GEMM_MIN_MACCS the per-call
    // arm IS the naive reference, so there is no tie-by-construction —
    // those rows are reported but not gated; see RaceRow::prepack_gated).
    let prepack_pass = race_rows
        .iter()
        .filter(|r| r.prepack_gated())
        .all(|r| r.prepack_speedup() >= 1.0 - CHECK_TOLERANCE);
    // PR-8 gate: the batch-folded path must never lose to the per-example
    // loop at batch 8 on any foldable (dense / stride-1 1×1 conv) shape.
    let min_batched = race_rows
        .iter()
        .filter_map(RaceRow::batched_speedup)
        .fold(f64::INFINITY, f64::min);
    let batched_pass = race_rows
        .iter()
        .all(|r| r.batched_speedup().is_none_or(|s| s >= 1.0 - CHECK_TOLERANCE));
    // ISSUE 10 gate: the dispatched microkernel must never lose to the
    // scalar set on the same prepacked panels, on any raced shape. Rows
    // where scalar was dispatched (non-AVX2 host or --force-scalar) have
    // no extra arm and gate nothing.
    let min_simd = race_rows
        .iter()
        .filter_map(RaceRow::simd_speedup)
        .fold(f64::INFINITY, f64::min);
    let simd_pass = race_rows
        .iter()
        .all(|r| r.simd_speedup().is_none_or(|s| s >= 1.0 - CHECK_TOLERANCE));
    // Baseline ratio gate: only against a REAL committed baseline. A
    // schema placeholder (no measured samples) must not gate anything —
    // skip it loudly so CI uploads this run as the first real baseline.
    let mut baseline_bad: Vec<String> = Vec::new();
    let mut baseline_state = "absent";
    match &baseline {
        None => {
            if check {
                eprintln!(
                    "bench_hotpath WARNING: no readable baseline at {baseline_path} — \
                     skipping the baseline ratio gate (live ref-vs-gemm gate still applies)."
                );
            }
        }
        Some(doc) if baseline_is_placeholder(doc) => {
            baseline_state = "placeholder";
            if check {
                eprintln!(
                    "bench_hotpath WARNING: committed {baseline_path} is a SCHEMA PLACEHOLDER \
                     (mode=baseline-pending / empty kernel_race) — it contains no measured \
                     samples, so the baseline ratio gate is SKIPPED. Upload this run's JSON \
                     artifact as the first real baseline to arm the gate."
                );
            }
        }
        Some(doc) => {
            baseline_state = "real";
            baseline_bad = baseline_regressions(&race_rows, doc);
        }
    }
    let pass = live_pass && prepack_pass && batched_pass && simd_pass && baseline_bad.is_empty();
    // ISSUE 9: planned-vs-pooled activation RAM per dataset topology.
    // Pure analysis (no timer), so the rows are identical on every
    // runner; the transformer is planned here too since its graph never
    // enters the `topologies` race above.
    let tx_graph = deploy_pipeline(&microai::graph::build::transformer(
        "tx", 12, 20, 16, 2, 2, 2, 5,
    ));
    let mut ram_models: Vec<(&str, &Graph)> =
        topologies.iter().map(|(m, g, _)| (*m, g)).collect();
    ram_models.push(("transformer", &tx_graph));
    let ram_plan_rows: Vec<Json> = ram_models
        .iter()
        .map(|(model, g)| {
            let alloc = microai::allocator::allocate(g);
            microai::allocator::check_no_conflict(g, &alloc)
                .unwrap_or_else(|e| panic!("{model}: shipped plan refused: {e}"));
            assert!(
                alloc.arena_elems <= alloc.pooled_elems,
                "{model}: planned arena exceeds the pooled baseline"
            );
            Json::obj(vec![
                ("model", Json::str(model)),
                ("planned_elems", Json::num(alloc.arena_elems as f64)),
                ("pooled_elems", Json::num(alloc.pooled_elems as f64)),
                ("planned_bytes_int8", Json::num(alloc.ram_bytes(1) as f64)),
                ("pooled_bytes_int8", Json::num(alloc.pooled_ram_bytes(1) as f64)),
                (
                    "saved_pct",
                    Json::num(if alloc.pooled_elems == 0 {
                        0.0
                    } else {
                        100.0 * (alloc.pooled_elems - alloc.arena_elems) as f64
                            / alloc.pooled_elems as f64
                    }),
                ),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("version", Json::num(6.0)),
        ("bench", Json::str("hotpath")),
        ("mode", Json::str(if smoke { "smoke" } else { "full" })),
        ("threads", Json::num(threads as f64)),
        ("kernel", Json::str(kern_name)),
        (
            "gate",
            Json::obj(vec![
                ("enforced", Json::Bool(check)),
                ("rule", Json::str("speedup >= 1.0 - tolerance on every measured shape")),
                (
                    "prepack_rule",
                    Json::str(
                        "prepack_speedup (per-call gemm_ns / prepacked prepack_ns) >= \
                         1.0 - tolerance on every shape with m*n*k >= GEMM_MIN_MACCS \
                         (below the floor the per-call arm is the naive reference, so \
                         the row is reported but not gated)",
                    ),
                ),
                (
                    "batched_rule",
                    Json::str(
                        "batched_speedup (looped_ns / batched_ns at batch 8) >= \
                         1.0 - tolerance on every foldable shape (dense, stride-1 1x1 conv)",
                    ),
                ),
                (
                    "simd_rule",
                    Json::str(
                        "simd_speedup (scalar-kernel scalar_kern_ns / dispatched prepack_ns, \
                         same prepacked panels) >= 1.0 - tolerance on every row where a \
                         non-scalar kernel set was dispatched (rows with simd == \"scalar\" \
                         have no extra arm and gate nothing)",
                    ),
                ),
                ("tolerance", Json::num(CHECK_TOLERANCE)),
                ("baseline_rule", Json::str(
                    "speedup >= baseline speedup * (1 - baseline_tolerance) per matched shape; \
                     skipped (loudly) when the committed baseline is a schema placeholder",
                )),
                ("baseline_tolerance", Json::num(BASELINE_REGRESSION_TOLERANCE)),
                ("baseline_state", Json::str(baseline_state)),
                ("min_speedup", Json::num(if min_speedup.is_finite() { min_speedup } else { 0.0 })),
                (
                    "min_prepack_speedup",
                    Json::num(if min_prepack.is_finite() { min_prepack } else { 0.0 }),
                ),
                (
                    "min_batched_speedup",
                    Json::num(if min_batched.is_finite() { min_batched } else { 0.0 }),
                ),
                (
                    "min_simd_speedup",
                    Json::num(if min_simd.is_finite() { min_simd } else { 0.0 }),
                ),
                ("pass", Json::Bool(pass)),
            ]),
        ),
        ("kernel_race", Json::Arr(race_rows.iter().map(RaceRow::to_json).collect())),
        (
            "whole_graph",
            Json::Arr(
                graph_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("model", Json::str(&r.model)),
                            ("backend", Json::str(&r.backend)),
                            ("ns_per_inference", Json::num(r.ns_per_inference)),
                            ("macc_per_s", Json::num(r.macc_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("ram_plan", Json::Arr(ram_plan_rows)),
    ]);
    let mut text = doc.to_string();
    text.push('\n');
    std::fs::write(&out_path, text).expect("write bench json");
    println!(
        "\nwrote {out_path} (threads={threads}, kernel={kern_name}, min GEMM speedup \
         {min_speedup:.2}x, min prepack speedup {min_prepack:.2}x, min batched speedup {:.2}x, \
         min simd speedup {:.2}x over {} shapes)",
        if min_batched.is_finite() { min_batched } else { 0.0 },
        if min_simd.is_finite() { min_simd } else { 0.0 },
        race_rows.len()
    );

    if check && !pass {
        if !live_pass {
            eprintln!("--check FAILED: GEMM slower than reference on:");
            for r in race_rows.iter().filter(|r| r.speedup() < 1.0 - CHECK_TOLERANCE) {
                eprintln!(
                    "  {}/{} {} {}: {:.2}x (ref {:.0} ns, gemm {:.0} ns)",
                    r.model, r.layer, r.kind, r.backend, r.speedup(), r.ref_ns, r.gemm_ns
                );
            }
        }
        if !prepack_pass {
            eprintln!("--check FAILED: prepacked path slower than per-call GEMM on:");
            for r in race_rows
                .iter()
                .filter(|r| r.prepack_gated() && r.prepack_speedup() < 1.0 - CHECK_TOLERANCE)
            {
                eprintln!(
                    "  {}/{} {} {}: {:.2}x (gemm {:.0} ns, prepacked {:.0} ns)",
                    r.model, r.layer, r.kind, r.backend, r.prepack_speedup(), r.gemm_ns,
                    r.prepack_ns
                );
            }
        }
        if !batched_pass {
            eprintln!("--check FAILED: batch-folded path slower than the per-example loop on:");
            for r in race_rows
                .iter()
                .filter(|r| r.batched_speedup().is_some_and(|s| s < 1.0 - CHECK_TOLERANCE))
            {
                eprintln!(
                    "  {}/{} {} {}: {:.2}x (looped {:.0} ns, batched {:.0} ns)",
                    r.model,
                    r.layer,
                    r.kind,
                    r.backend,
                    r.batched_speedup().unwrap_or(0.0),
                    r.looped_ns.unwrap_or(0.0),
                    r.batched_ns.unwrap_or(0.0)
                );
            }
        }
        if !simd_pass {
            eprintln!("--check FAILED: dispatched SIMD kernel slower than the scalar set on:");
            for r in race_rows
                .iter()
                .filter(|r| r.simd_speedup().is_some_and(|s| s < 1.0 - CHECK_TOLERANCE))
            {
                eprintln!(
                    "  {}/{} {} {} [{}]: {:.2}x (scalar {:.0} ns, dispatched {:.0} ns)",
                    r.model,
                    r.layer,
                    r.kind,
                    r.backend,
                    r.simd,
                    r.simd_speedup().unwrap_or(0.0),
                    r.scalar_kern_ns.unwrap_or(0.0),
                    r.prepack_ns
                );
            }
        }
        if !baseline_bad.is_empty() {
            eprintln!("--check FAILED: regression vs committed baseline on:");
            for line in &baseline_bad {
                eprintln!("  {line}");
            }
        }
        std::process::exit(1);
    }
}

/// The pre-existing sections: quantizer, calibration, allocator, codegen,
/// datasets, and the session-reuse-vs-per-call-alloc comparison.
fn legacy_sections(b: &Bencher, rng: &mut Pcg32) {
    let g = randomized(resnet_v1_6_shapes("har", 1, &[128, 9], 6, 32), 9);
    let stats = calibrated_stats(&g, 128 * 9);

    print_header("session reuse vs per-call allocation (int8, single input)");
    let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
    let x: Vec<f32> = (0..128 * 9).map(|_| rng.normal()).collect();
    let macc = microai::mcu::graph_ops(&g).macc as f64;
    let mut sess = SessionBuilder::fixed_qmn(qg.clone()).max_batch(8).build();
    let r = b.run_throughput("session reuse (arena)", macc, "MACC/s", || {
        black_box(sess.run(&x));
    });
    println!("{}", r.report());
    let r = b.run_throughput("per-call exec (allocs)", macc, "MACC/s", || {
        black_box(int_exec::run(&qg, &x));
    });
    println!("{}", r.report());
    let batch: Vec<f32> = (0..8 * 128 * 9).map(|_| rng.normal()).collect();
    let mut preds = Vec::new();
    let r = b.run_throughput("session infer batch(8)", 8.0 * macc, "MACC/s", || {
        preds.clear();
        sess.infer(&Batch::contiguous(&batch, 128 * 9), &mut preds);
        black_box(&preds);
    });
    println!("{}", r.report());

    print_header("quantizer (PTQ over full graph, f=32)");
    for (label, spec) in [
        ("int8 per-layer ", QuantSpec::int8_per_layer()),
        ("int8 per-filter", QuantSpec::int8_per_filter()),
        ("int16 per-layer", QuantSpec::int16_per_layer()),
    ] {
        let r = b.run(label, || {
            black_box(quantize(&g, &stats, spec));
        });
        println!("{}", r.report());
    }

    print_header("calibration pass (float forward with stats, f=32)");
    let r = b.run("calibrate 1 example", || {
        let mut s = ActStats::new(g.nodes.len());
        black_box(float_exec::run(&g, &x, Some(&mut s)));
    });
    println!("{}", r.report());

    print_header("allocator (§5.7 first-fit, f=80)");
    let g80 = randomized(resnet_v1_6_shapes("har", 1, &[128, 9], 6, 80), 10);
    let r = b.run("allocate ResNet", || {
        black_box(microai::allocator::allocate(&g80));
    });
    println!("{}", r.report());

    print_header("C code generation (f=32, int8)");
    let r = b.run("generate C library", || {
        black_box(microai::codegen::generate(&qg));
    });
    println!("{}", r.report());

    print_header("synthetic dataset generation");
    let r = b.run("har full dataset", || {
        black_box(microai::datasets::load("har", 1));
    });
    println!("{}", r.report());
}
