//! Regenerates the paper's footprint / latency / energy tables and figures
//! from the calibrated cost models, printing predicted-vs-paper rows:
//!
//!   Table 3  — board specs
//!   Table 4  — framework capability matrix
//!   Table A3 / Fig 11 — ROM footprint vs filters
//!   Table A4 / Fig 12 — inference time vs filters
//!   Table A5 / Fig 13 — energy vs filters
//!   Table A6 — per-layer integer op counts
//!
//! Calibration uses ONLY each series' f=16 / f=80 endpoints; the five
//! intermediate filter counts validate the model shape (DESIGN.md §8).
//! Run: `cargo bench --bench bench_tables`

use microai::engines::all_engines;
use microai::mcu::board::{Board, BOARDS};
use microai::mcu::cost::{har_graph, validate_latency, validate_rom, SeriesValidation};
use microai::mcu::opcounts::node_ops;
use microai::mcu::paper_data::{self, FILTERS};

fn print_validation(title: &str, vs: &[SeriesValidation]) {
    println!("\n==== {title} ====");
    let mut worst = 0.0f64;
    for v in vs {
        print!("{:<13} {:<14} {:<8} pred ", v.framework, v.board, format!("{:?}", v.dtype));
        for p in &v.predicted {
            print!("{p:>9.1}");
        }
        println!();
        print!("{:<37} papr ", "");
        for p in &v.paper {
            print!("{p:>9.1}");
        }
        println!("   held-out err {:.1}%", v.max_held_out_rel_err * 100.0);
        worst = worst.max(v.max_held_out_rel_err);
    }
    println!("-- worst held-out relative error: {:.1}% --", worst * 100.0);
}

fn table3() {
    println!("\n==== Table 3: embedded platforms ====");
    println!(
        "{:<16} {:<14} {:<11} {:>9} {:>10} {:>13} {:>13} {:>10}",
        "Board", "MCU", "Core", "RAM(kiB)", "Flash(kiB)", "CoreMark/MHz", "I@3.3V/48MHz", "Power(mW)"
    );
    for b in BOARDS {
        println!(
            "{:<16} {:<14} {:<11} {:>9} {:>10} {:>13.3} {:>10.2} mA {:>9.2}",
            b.name,
            b.mcu,
            b.core,
            b.ram_bytes / 1024,
            b.flash_bytes / 1024,
            b.coremark_per_mhz,
            b.run_current_a * 1e3,
            b.power_w() * 1e3,
        );
    }
}

fn table4() {
    println!("\n==== Table 4: embedded AI frameworks ====");
    println!(
        "{:<13} {:<18} {:<18} {:<22} {:<9} {:<12} {}",
        "Framework", "Sources", "Portability", "Data types", "OpenSrc", "Coding", "Deployment"
    );
    for e in all_engines() {
        let dts: Vec<&str> = e.caps.dtypes.iter().map(|d| d.label()).collect();
        println!(
            "{:<13} {:<18} {:<18} {:<22} {:<9} {:<12} {}",
            e.name,
            e.caps.sources.join(","),
            e.caps.portability,
            dts.join(","),
            if e.caps.open_source { "yes" } else { "no" },
            format!("{:?}", e.caps.coding),
            if e.caps.compiled { "codegen" } else { "interpreter" },
        );
    }
}

fn table_a6() {
    println!("\n==== Table A6: integer op counts (UCI-HAR ResNet, f=16) ====");
    let g = har_graph(16);
    println!(
        "{:<12} {:>12} {:>10} {:>10} {:>12} {:>8}",
        "Layer", "MACC(1cy)", "Add(1cy)", "Shift(1cy)", "Max/Sat(2cy)", "Div"
    );
    for n in &g.nodes {
        let ops = node_ops(&g, n.id);
        if ops.total_ops() == 0 {
            continue;
        }
        println!(
            "{:<12} {:>12} {:>10} {:>10} {:>12} {:>8}",
            n.name, ops.macc, ops.add, ops.shift, ops.sat, ops.div
        );
    }
    let total = microai::mcu::graph_ops(&g);
    println!(
        "{:<12} {:>12} {:>10} {:>10} {:>12} {:>8}  ideal cycles = {}",
        "TOTAL", total.macc, total.add, total.shift, total.sat, total.div,
        total.ideal_cycles()
    );
}

fn table_a5_energy() {
    println!("\n==== Table A5 / Fig 13: energy per inference (µWh), model vs paper ====");
    let mut worst = 0.0f64;
    for s in &paper_data::TABLE_A5_UWH {
        let lat_series =
            paper_data::find(&paper_data::TABLE_A4_MS, s.framework, s.board, s.dtype).unwrap();
        let board = Board::by_name(s.board).unwrap();
        let v = validate_latency(lat_series);
        print!("{:<13} {:<14} {:<8} pred ", s.framework, s.board, format!("{:?}", s.dtype));
        for (i, ms) in v.predicted.iter().enumerate() {
            let e = microai::mcu::energy_uwh(ms / 1e3, board);
            print!("{e:>8.3}");
            if i != 0 && i != 6 {
                worst = worst.max((e - s.values[i]).abs() / s.values[i]);
            }
        }
        println!();
        print!("{:<37} papr ", "");
        for p in &s.values {
            print!("{p:>8.3}");
        }
        println!();
    }
    println!("-- worst held-out relative error: {:.1}% --", worst * 100.0);
}

fn main() {
    println!("MicroAI paper-table regeneration (cost models; see DESIGN.md §8)");
    println!("filters sweep: {FILTERS:?}");

    table3();
    table4();

    let rom: Vec<_> = paper_data::TABLE_A3_KIB.iter().map(validate_rom).collect();
    print_validation("Table A3 / Fig 11: ROM footprint (kiB)", &rom);

    let lat: Vec<_> = paper_data::TABLE_A4_MS.iter().map(validate_latency).collect();
    print_validation("Table A4 / Fig 12: inference time (ms)", &lat);

    table_a5_energy();
    table_a6();

    // Headline ordering assertions (the "who wins" shape).
    let a4 = &paper_data::TABLE_A4_MS;
    let pred = |fw: &str, bd: &str, dt: paper_data::DType| {
        validate_latency(paper_data::find(a4, fw, bd, dt).unwrap()).predicted[6]
    };
    use paper_data::DType::*;
    assert!(pred("STM32Cube.AI", "NucleoL452REP", I8) < pred("TFLiteMicro", "SparkFunEdge", I8));
    assert!(pred("TFLiteMicro", "SparkFunEdge", I8) < pred("MicroAI", "NucleoL452REP", I8));
    assert!(pred("MicroAI", "NucleoL452REP", I8) < pred("MicroAI", "NucleoL452REP", F32));
    println!("\nordering checks (Fig 12 who-wins at f=80): OK");
}
